# Top-level targets. `artifacts` is the ONLY Python invocation in the
# project (build time); everything after it is the self-contained Rust
# coordinator (see README.md).

.PHONY: artifacts check perfgate

# Train the default model ladder, generate corpora + zero-shot tasks, and
# lower the L1/L2 graphs to HLO text under ./artifacts.
# Override sizes with: make artifacts GPTQ_SIZES=nano,micro
artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

# Tier-1 gate (delegates to rust/Makefile).
check:
	$(MAKE) -C rust check

# Perf-regression gate: bench subset + diff vs the committed
# rust/BENCH_*.json baselines (delegates to rust/Makefile).
perfgate:
	$(MAKE) -C rust perfgate
