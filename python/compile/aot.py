"""AOT compile path: corpus → trained weights → HLO-text artifacts.

Runs once via `make artifacts`; the Rust coordinator is self-contained
afterwards. Interchange is HLO TEXT, not serialized HloModuleProto —
the crate's xla_extension 0.5.1 rejects jax≥0.5 64-bit instruction ids
(see /opt/xla-example/README.md); the text parser reassigns ids.

Emitted tree (artifacts/):
  manifest.json                 everything Rust needs: model configs,
                                tensor index (name/shape/offset), artifact
                                signatures, quantization defaults
  corpus/…                      synthetic corpora + zero-shot tasks
  weights_<size>.bin            raw little-endian f32, tensor_index order
  hlo/lm_fwd_<size>.hlo.txt     tokens+params → logits      (PPL eval)
  hlo/embed_<size>.hlo.txt      tokens,embed,pos → x        (pipeline head)
  hlo/block_capture_<size>.…    x+block params → y + 4 linear inputs
  hlo/head_<size>.hlo.txt       x,lnf,unembed → logits
  hlo/hessian_<d>.hlo.txt       X → 2·XᵀX                    (L1 kernel)
  hlo/gptq_layer_<o>x<i>_b<bits>.hlo.txt   W,H → codes,scales,zeros,wq
  hlo/packmatvec_<o>x<i>_b<bits>.hlo.txt   words,scales,zeros,x → y

Incremental: artifacts are skipped when already present (make passes
--force to rebuild). Model training dominates the cost.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus as corpus_mod
from . import model as M
from . import train as train_mod
from .gptq_layer import gptq_quantize_layer
from .kernels.hessian import hessian as hessian_kernel
from .kernels.packmatvec import codes_per_word, packmatvec

EVAL_BATCH = 8
SEQ_LEN = 128
CALIB_TOKENS = EVAL_BATCH * SEQ_LEN  # tokens per capture/hessian call
GPTQ_ARTIFACT_BITS = (3, 4)
PACKMATVEC_BITS = (2, 3, 4)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the only interchange that
    round-trips into xla_extension 0.5.1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path: Path, log) -> dict:
    t0 = time.time()
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    path.write_text(text)
    log(f"  wrote {path.name}  ({len(text)//1024} KiB, {time.time()-t0:.1f}s)")
    return {
        "file": f"hlo/{path.name}",
        "params": [list(np.shape(a)) for a in jax.tree.leaves(example_args)],
    }


# ---------------------------------------------------------------------------
# model entry points, flattened to positional tensor args (= HLO parameters)
# ---------------------------------------------------------------------------

def _flat_args(cfg: M.ModelConfig, params: dict) -> list[jnp.ndarray]:
    flat = M.params_to_flat(cfg, params)
    return [jnp.asarray(flat[name]) for name, _ in M.tensor_index(cfg)]


def _args_to_params(cfg: M.ModelConfig, args) -> dict:
    flat = {name: a for (name, _), a in zip(M.tensor_index(cfg), args)}
    return M.flat_to_params(cfg, flat)


def make_lm_fwd(cfg: M.ModelConfig):
    def f(tokens, *tensors):
        return (M.fwd(cfg, _args_to_params(cfg, tensors), tokens),)

    return f


def make_embed(cfg: M.ModelConfig):
    def f(tokens, emb, pos):
        seq = tokens.shape[1]
        return (emb[tokens] + pos[:seq][None],)

    return f


BLOCK_TENSORS = [
    "ln1_g", "ln1_b", "ln2_g", "ln2_b",
    "wqkv", "wqkv_b", "wo", "wo_b", "wup", "wup_b", "wdn", "wdn_b",
]


def make_block_capture(cfg: M.ModelConfig):
    def f(x, *tensors):
        blk = dict(zip(BLOCK_TENSORS, tensors))
        y, caps = M.block_capture(cfg, blk, x)
        return (y, caps["wqkv"], caps["wo"], caps["wup"], caps["wdn"])

    return f


def make_head(cfg: M.ModelConfig):
    def f(x, lnf_g, lnf_b, unembed):
        return (M.head({"lnf_g": lnf_g, "lnf_b": lnf_b, "unembed": unembed}, x),)

    return f


def block_example_args(cfg: M.ModelConfig):
    d = cfg.d_model
    shapes = dict(cfg.linear_shapes())
    args = [jnp.zeros((EVAL_BATCH, SEQ_LEN, d), jnp.float32)]
    for nm in BLOCK_TENSORS:
        if nm.startswith("ln"):
            args.append(jnp.zeros((d,), jnp.float32))
        elif nm.endswith("_b"):
            args.append(jnp.zeros((shapes[nm[:-2]][0],), jnp.float32))
        else:
            args.append(jnp.zeros(shapes[nm], jnp.float32))
    return args


# ---------------------------------------------------------------------------
# build steps
# ---------------------------------------------------------------------------

def build(out_root: Path, sizes: list[str], force: bool, log=print) -> None:
    hlo = out_root / "hlo"
    hlo.mkdir(parents=True, exist_ok=True)
    corpus_dir = out_root / "corpus"

    if force or not (corpus_dir / "train.bin").exists():
        log("[aot] building corpus")
        corpus_mod.build_corpus(corpus_dir)
    else:
        log("[aot] corpus up to date")

    manifest: dict = {
        "version": 1,
        "seq_len": SEQ_LEN,
        "eval_batch": EVAL_BATCH,
        "calib_tokens": CALIB_TOKENS,
        "quant": {
            "blocksize": 128,
            "percdamp": 0.01,
            "gptq_artifact_bits": list(GPTQ_ARTIFACT_BITS),
        },
        "models": {},
        "artifacts": {},
    }

    gptq_shapes: set[tuple[int, int]] = set()
    hessian_dims: set[int] = set()

    for size in sizes:
        cfg = M.CONFIGS[size]
        wpath = out_root / f"weights_{size}.bin"
        if force or not wpath.exists():
            log(f"[aot] training {size} ({cfg.n_params():,} params)")
            params = train_mod.train_model(cfg, corpus_dir, log=log)
            flat = M.params_to_flat(cfg, params)
            with open(wpath, "wb") as f:
                for name, _ in M.tensor_index(cfg):
                    f.write(flat[name].astype("<f4").tobytes())
        else:
            log(f"[aot] weights_{size}.bin up to date")

        index = []
        offset = 0
        for name, shape in M.tensor_index(cfg):
            n = int(np.prod(shape))
            index.append({"name": name, "shape": list(shape), "offset": offset, "len": n})
            offset += n * 4
        manifest["models"][size] = {
            "config": {
                "d_model": cfg.d_model,
                "n_layers": cfg.n_layers,
                "n_heads": cfg.n_heads,
                "d_ff": cfg.d_ff,
                "vocab": cfg.vocab,
                "max_seq": cfg.max_seq,
            },
            "n_params": cfg.n_params(),
            "weights": f"weights_{size}.bin",
            "tensors": index,
        }

        for (o, i) in cfg.linear_shapes().values():
            gptq_shapes.add((o, i))
            hessian_dims.add(i)

        # -- model graphs ----------------------------------------------------
        tokens = jnp.zeros((EVAL_BATCH, SEQ_LEN), jnp.int32)
        zero_params = jax.tree.map(
            jnp.zeros_like, M.init_params(cfg, jax.random.PRNGKey(0))
        )
        targets = {
            f"lm_fwd_{size}": (make_lm_fwd(cfg), [tokens, *_flat_args(cfg, zero_params)]),
            f"embed_{size}": (
                make_embed(cfg),
                [tokens, zero_params["embed"], zero_params["pos"]],
            ),
            f"block_capture_{size}": (make_block_capture(cfg), block_example_args(cfg)),
            f"head_{size}": (
                make_head(cfg),
                [
                    jnp.zeros((EVAL_BATCH, SEQ_LEN, cfg.d_model), jnp.float32),
                    zero_params["lnf_g"],
                    zero_params["lnf_b"],
                    zero_params["unembed"],
                ],
            ),
        }
        for name, (fn, args) in targets.items():
            path = hlo / f"{name}.hlo.txt"
            if force or not path.exists():
                manifest["artifacts"][name] = lower_to_file(fn, args, path, log)
            else:
                manifest["artifacts"][name] = {
                    "file": f"hlo/{path.name}",
                    "params": [list(np.shape(a)) for a in args],
                }

    # -- shape-keyed quantization graphs (shared across model sizes) ---------
    for d in sorted(hessian_dims):
        name = f"hessian_{d}"
        path = hlo / f"{name}.hlo.txt"
        x = jnp.zeros((CALIB_TOKENS, d), jnp.float32)
        if force or not path.exists():
            manifest["artifacts"][name] = lower_to_file(
                lambda x: (hessian_kernel(x),), [x], path, log
            )
        else:
            manifest["artifacts"][name] = {"file": f"hlo/{path.name}", "params": [[CALIB_TOKENS, d]]}

    for (o, i) in sorted(gptq_shapes):
        for bits in GPTQ_ARTIFACT_BITS:
            name = f"gptq_layer_{o}x{i}_b{bits}"
            path = hlo / f"{name}.hlo.txt"
            if not force and path.exists():
                manifest["artifacts"][name] = {"file": f"hlo/{path.name}", "params": [[o, i], [i, i]]}
                continue

            def gfn(w, h, bits=bits):
                return gptq_quantize_layer(w, h, bits)

            manifest["artifacts"][name] = lower_to_file(
                gfn,
                [jnp.zeros((o, i), jnp.float32), jnp.zeros((i, i), jnp.float32)],
                path,
                log,
            )

    # -- packed matvec kernel demo (one representative shape per bit width) --
    o, i = 1024, 256
    for bits in PACKMATVEC_BITS:
        name = f"packmatvec_{o}x{i}_b{bits}"
        path = hlo / f"{name}.hlo.txt"
        nwords = (i + codes_per_word(bits) - 1) // codes_per_word(bits)
        if not force and path.exists():
            manifest["artifacts"][name] = {
                "file": f"hlo/{path.name}",
                "params": [[o, nwords], [o, 1], [o, 1], [i]],
            }
            continue

        def pfn(words, scales, zeros, x, bits=bits):
            return (packmatvec(words, scales, zeros, x, bits),)

        manifest["artifacts"][name] = lower_to_file(
            pfn,
            [
                jnp.zeros((o, nwords), jnp.uint32),
                jnp.zeros((o, 1), jnp.float32),
                jnp.zeros((o, 1), jnp.float32),
                jnp.zeros((i,), jnp.float32),
            ],
            path,
            log,
        )

    golden_path = out_root / "golden.json"
    if force or not golden_path.exists():
        from .golden import write_golden

        write_golden(golden_path)
        log("[aot] golden cross-check vectors written")

    (out_root / "manifest.json").write_text(json.dumps(manifest, indent=1))
    log(f"[aot] manifest written: {len(manifest['artifacts'])} artifacts, "
        f"{len(manifest['models'])} models")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sizes", default=os.environ.get("GPTQ_SIZES", ",".join(M.DEFAULT_SIZES)))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    sizes = [s for s in args.sizes.split(",") if s]
    for s in sizes:
        if s not in M.CONFIGS:
            sys.exit(f"unknown size {s!r}; choose from {list(M.CONFIGS)}")
    build(Path(args.out), sizes, args.force)


if __name__ == "__main__":
    main()
