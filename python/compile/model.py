"""L2: the byte-level transformer LM family (the OPT/BLOOM stand-in).

Pre-norm decoder-only transformer over a byte vocabulary (256), with the
four quantizable linears per block the pipeline targets:

    wqkv (3d, d)   fused q/k/v projection
    wo   (d, d)    attention output projection
    wup  (ff, d)   MLP up projection (GELU)
    wdn  (d, ff)   MLP down projection

Weights are stored in (out_features, in_features) layout — the same layout
the GPTQ solver and the Rust checkpoint use — and applied as x @ W.T.
Embedding / positional / unembedding / LayerNorm parameters stay full
precision, as in the paper (§Practical Speedups: "embeddings and the output
layer ... kept in full FP16 precision").

Entry points lowered by aot.py:
  * fwd            — batched logits, for perplexity evaluation;
  * embed          — token+position embedding (start of the block-wise
                     calibration pipeline);
  * block_capture  — one block's forward returning the INPUTS of each of
                     its four linears (feeds Hessian accumulation; the Rust
                     coordinator re-runs it with quantized weights to
                     propagate "actual layer inputs in the already
                     partially quantized" model, paper §4 Setup);
  * block_fwd      — one block's forward only;
  * head           — final LN + unembedding → logits;
  * quant_fwd      — batched logits computed from PACKED weights via the
                     L1 packmatvec kernel (kernel-path parity check).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import packmatvec as pmv


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int = 256
    max_seq: int = 128

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def linear_shapes(self) -> dict[str, tuple[int, int]]:
        """(out, in) shape of each quantizable linear in one block."""
        d, ff = self.d_model, self.d_ff
        return {"wqkv": (3 * d, d), "wo": (d, d), "wup": (ff, d), "wdn": (d, ff)}

    def n_params(self) -> int:
        counts = 2 * self.vocab * self.d_model + self.max_seq * self.d_model
        per_block = sum(o * i + o for o, i in self.linear_shapes().values())
        per_block += 4 * self.d_model  # two LayerNorms
        return counts + self.n_layers * per_block + 2 * self.d_model


# The model family: the OPT-125M…175B / BLOOM ladder analog (DESIGN.md
# §Substitutions). Sizes chosen so `make artifacts` trains the default trio
# on CPU in minutes while preserving the size-scaling axis of Figs. 1/3/4.
CONFIGS: dict[str, ModelConfig] = {
    "nano": ModelConfig("nano", d_model=64, n_layers=2, n_heads=2, d_ff=256),
    "micro": ModelConfig("micro", d_model=128, n_layers=4, n_heads=4, d_ff=512),
    "small": ModelConfig("small", d_model=256, n_layers=4, n_heads=8, d_ff=1024),
    "med": ModelConfig("med", d_model=384, n_layers=6, n_heads=8, d_ff=1536),
}
DEFAULT_SIZES = ["nano", "micro", "small"]

QUANT_LINEARS = ["wqkv", "wo", "wup", "wdn"]


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> dict[str, Any]:
    keys = iter(jax.random.split(key, 64))

    def dense(shape, fan_in):
        return (jax.random.normal(next(keys), shape, jnp.float32) / np.sqrt(fan_in))

    params: dict[str, Any] = {
        "embed": jax.random.normal(next(keys), (cfg.vocab, cfg.d_model)) * 0.02,
        "pos": jax.random.normal(next(keys), (cfg.max_seq, cfg.d_model)) * 0.01,
        "lnf_g": jnp.ones((cfg.d_model,)),
        "lnf_b": jnp.zeros((cfg.d_model,)),
        "unembed": dense((cfg.vocab, cfg.d_model), cfg.d_model),
        "blocks": [],
    }
    for _ in range(cfg.n_layers):
        blk = {
            "ln1_g": jnp.ones((cfg.d_model,)),
            "ln1_b": jnp.zeros((cfg.d_model,)),
            "ln2_g": jnp.ones((cfg.d_model,)),
            "ln2_b": jnp.zeros((cfg.d_model,)),
        }
        for name, (o, i) in cfg.linear_shapes().items():
            blk[name] = dense((o, i), i)
            blk[name + "_b"] = jnp.zeros((o,))
        # scale residual-path output projections down with depth (GPT-2 trick)
        blk["wo"] = blk["wo"] / np.sqrt(2 * cfg.n_layers)
        blk["wdn"] = blk["wdn"] / np.sqrt(2 * cfg.n_layers)
        params["blocks"].append(blk)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(cfg: ModelConfig, qkv: jax.Array) -> jax.Array:
    """Causal multi-head attention from the fused qkv tensor (B, S, 3d)."""
    bsz, seq, _ = qkv.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(bsz, seq, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(bsz, seq, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(bsz, seq, h, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(hd)
    causal = jnp.tril(jnp.ones((seq, seq), bool))
    att = jnp.where(causal[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    return out.transpose(0, 2, 1, 3).reshape(bsz, seq, h * hd)


def block_capture(cfg: ModelConfig, blk: dict, x: jax.Array):
    """One transformer block; returns (y, captures).

    captures maps each quantizable linear to ITS INPUT activations
    (B, S, in_features) — exactly what the Hessian H = 2XᵀX needs."""
    x1 = layer_norm(x, blk["ln1_g"], blk["ln1_b"])
    qkv = x1 @ blk["wqkv"].T + blk["wqkv_b"]
    attn = _attention(cfg, qkv)
    x = x + attn @ blk["wo"].T + blk["wo_b"]
    x2 = layer_norm(x, blk["ln2_g"], blk["ln2_b"])
    hidden = jax.nn.gelu(x2 @ blk["wup"].T + blk["wup_b"])
    y = x + hidden @ blk["wdn"].T + blk["wdn_b"]
    captures = {"wqkv": x1, "wo": attn, "wup": x2, "wdn": hidden}
    return y, captures


def embed(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    seq = tokens.shape[1]
    return params["embed"][tokens] + params["pos"][:seq][None]


def head(params: dict, x: jax.Array) -> jax.Array:
    x = layer_norm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["unembed"].T


def fwd(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    """Full forward: tokens (B, S) int32 → logits (B, S, vocab)."""
    x = embed(cfg, params, tokens)
    for blk in params["blocks"]:
        x, _ = block_capture(cfg, blk, x)
    return head(params, x)


def loss_fn(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    """Next-byte cross-entropy."""
    logits = fwd(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


# ---------------------------------------------------------------------------
# quantized forward (L1 kernel path)
# ---------------------------------------------------------------------------

def _quant_linear(qw: dict, x: jax.Array, bits: int, groupsize: int) -> jax.Array:
    """x (..., in) @ dequant(Ŵ).T via the packmatvec kernel, vmapped over
    all leading positions (each position is one matvec — the batch-1
    generative-inference shape the paper optimizes)."""
    lead = x.shape[:-1]
    flat = x.reshape(-1, x.shape[-1])
    f = lambda v: pmv.packmatvec(qw["words"], qw["scales"], qw["zeros"], v, bits, groupsize)
    y = jax.vmap(f)(flat)
    return y.reshape(*lead, -1)


def quant_block_fwd(cfg: ModelConfig, blk: dict, qblk: dict, x: jax.Array, bits: int, groupsize: int) -> jax.Array:
    """Block forward with all four linears replaced by the packed kernel."""
    x1 = layer_norm(x, blk["ln1_g"], blk["ln1_b"])
    qkv = _quant_linear(qblk["wqkv"], x1, bits, groupsize) + blk["wqkv_b"]
    attn = _attention(cfg, qkv)
    x = x + _quant_linear(qblk["wo"], attn, bits, groupsize) + blk["wo_b"]
    x2 = layer_norm(x, blk["ln2_g"], blk["ln2_b"])
    hidden = jax.nn.gelu(_quant_linear(qblk["wup"], x2, bits, groupsize) + blk["wup_b"])
    return x + _quant_linear(qblk["wdn"], hidden, bits, groupsize) + blk["wdn_b"]


def quant_fwd(cfg: ModelConfig, params: dict, qparams: list, tokens: jax.Array, bits: int, groupsize: int = 0) -> jax.Array:
    """Full forward with packed quantized weights (qparams: per-block dicts
    of {words, scales, zeros} per linear)."""
    x = embed(cfg, params, tokens)
    for blk, qblk in zip(params["blocks"], qparams):
        x = quant_block_fwd(cfg, blk, qblk, x, bits, groupsize)
    return head(params, x)


# ---------------------------------------------------------------------------
# flat (de)serialization — the checkpoint tensor order shared with Rust
# ---------------------------------------------------------------------------

def tensor_index(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Deterministic (name, shape) list defining the checkpoint layout."""
    idx: list[tuple[str, tuple[int, ...]]] = [
        ("embed", (cfg.vocab, cfg.d_model)),
        ("pos", (cfg.max_seq, cfg.d_model)),
        ("lnf_g", (cfg.d_model,)),
        ("lnf_b", (cfg.d_model,)),
        ("unembed", (cfg.vocab, cfg.d_model)),
    ]
    for li in range(cfg.n_layers):
        for nm in ("ln1_g", "ln1_b", "ln2_g", "ln2_b"):
            idx.append((f"blocks.{li}.{nm}", (cfg.d_model,)))
        for nm, (o, i) in cfg.linear_shapes().items():
            idx.append((f"blocks.{li}.{nm}", (o, i)))
            idx.append((f"blocks.{li}.{nm}_b", (o,)))
    return idx


def params_to_flat(cfg: ModelConfig, params: dict) -> dict[str, np.ndarray]:
    flat: dict[str, np.ndarray] = {}
    for name, shape in tensor_index(cfg):
        if name.startswith("blocks."):
            _, li, nm = name.split(".")
            arr = params["blocks"][int(li)][nm]
        else:
            arr = params[name]
        arr = np.asarray(arr, dtype=np.float32)
        assert arr.shape == shape, (name, arr.shape, shape)
        flat[name] = arr
    return flat


def flat_to_params(cfg: ModelConfig, flat: dict[str, np.ndarray]) -> dict:
    params: dict[str, Any] = {"blocks": [dict() for _ in range(cfg.n_layers)]}
    for name, _ in tensor_index(cfg):
        arr = jnp.asarray(flat[name])
        if name.startswith("blocks."):
            _, li, nm = name.split(".")
            params["blocks"][int(li)][nm] = arr
        else:
            params[name] = arr
    return params


@functools.lru_cache(maxsize=None)
def config_by_name(name: str) -> ModelConfig:
    return CONFIGS[name]
