"""Build-time training of the model family (the "pre-trained OPT/BLOOM
checkpoint" substitute — DESIGN.md §Substitutions).

Runs ONCE inside `make artifacts`. Adam + cosine decay on next-byte
cross-entropy over the mixed-style training corpus. Deterministic (fixed
seeds). Step counts are modest — the point is trained (correlated,
outlier-bearing) weight/activation statistics, not SOTA perplexity.
"""

from __future__ import annotations

import functools
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M

# per-size training budgets (CPU-friendly)
TRAIN_PLAN = {
    "nano": dict(steps=400, batch=32, lr=3e-3),
    "micro": dict(steps=350, batch=24, lr=2e-3),
    "small": dict(steps=900, batch=16, lr=1.5e-3),
    "med": dict(steps=220, batch=8, lr=1e-3),
}
SEQ_LEN = 128


def load_tokens(corpus_dir: Path, name: str) -> np.ndarray:
    return np.frombuffer((corpus_dir / name).read_bytes(), dtype=np.uint8).astype(np.int32)


def sample_batch(rng: np.random.Generator, data: np.ndarray, batch: int, seq: int) -> np.ndarray:
    starts = rng.integers(0, len(data) - seq - 1, size=batch)
    return np.stack([data[s : s + seq + 1] for s in starts])


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree.map(lambda m: m / (1 - b1**t), m)
    vhat = jax.tree.map(lambda v: v / (1 - b2**t), v)
    params = jax.tree.map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return params, {"m": m, "v": v, "t": t}


def train_model(cfg: M.ModelConfig, corpus_dir: Path, seed: int = 7, log=print):
    plan = TRAIN_PLAN[cfg.name]
    steps, batch, base_lr = plan["steps"], plan["batch"], plan["lr"]
    data = load_tokens(corpus_dir, "train.bin")
    val = load_tokens(corpus_dir, "narrative_val.bin")
    rng = np.random.default_rng(seed)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, tokens, lr):
        loss, grads = jax.value_and_grad(lambda p: M.loss_fn(cfg, p, tokens))(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    @jax.jit
    def eval_fn(params, tokens):
        return M.loss_fn(cfg, params, tokens)

    t0 = time.time()
    for step in range(steps):
        lr = base_lr * 0.5 * (1 + np.cos(np.pi * step / steps))
        tokens = jnp.asarray(sample_batch(rng, data, batch, SEQ_LEN))
        params, opt, loss = step_fn(params, opt, tokens, lr)
        if step % 50 == 0 or step == steps - 1:
            vtok = jnp.asarray(sample_batch(rng, val, 8, SEQ_LEN))
            vloss = float(eval_fn(params, vtok))
            log(
                f"[train {cfg.name}] step {step:4d}/{steps} "
                f"loss {float(loss):.3f} val {vloss:.3f} "
                f"({time.time()-t0:.0f}s)"
            )
    return params
