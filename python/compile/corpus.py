"""Synthetic corpus + zero-shot task generator (the WikiText2/PTB/C4 and
LAMBADA/ARC/PIQA/StoryCloze stand-ins — DESIGN.md §Substitutions).

Three styles, mirroring the paper's three perplexity datasets:
  * narrative — templated English-like prose (the WikiText2 analog);
  * markup    — config/markup/log-structured text (the PTB analog: a
                distribution shift from prose);
  * crawl     — a noisy mixture of both plus boilerplate (the C4 analog;
                this is also what calibration samples are drawn from, as in
                the paper).

Everything is seeded and byte-level (vocab = 256). The generator also
emits the zero-shot task files:
  * cloze.jsonl  — last-word prediction with a discourse-determined target
                   (LAMBADA analog);
  * mcq.jsonl    — 4-way multiple choice scored by likelihood (ARC analog);
  * binary.jsonl — 2-way plausibility choice (PIQA / StoryCloze analog).

Task targets are template-determined (an attentive reader of the corpus
can always answer), so a well-trained LM scores far above chance and
quantization damage is measurable — the same property the paper's
zero-shot suite relies on.
"""

from __future__ import annotations

import json
import random
from pathlib import Path

# -- vocabulary -------------------------------------------------------------

SUBJECTS = [
    "the archivist", "the engineer", "the cartographer", "the miller",
    "the astronomer", "the captain", "the gardener", "the apprentice",
    "the merchant", "the scribe", "the watchmaker", "the surveyor",
    "the librarian", "the blacksmith", "the navigator", "the printer",
]
PLACES = [
    "the harbor", "the observatory", "the old mill", "the market square",
    "the northern valley", "the archive", "the lighthouse", "the foundry",
    "the botanical garden", "the river delta", "the granary", "the workshop",
]
OBJECTS = [
    "a brass compass", "a sealed ledger", "a worn map", "a copper lantern",
    "a bundle of letters", "a glass prism", "a carved token", "an iron key",
    "a silk banner", "a clay tablet", "a silver coin", "a wooden crate",
]
VERBS_PAST = [
    "carried", "examined", "repaired", "catalogued", "delivered",
    "measured", "sketched", "recovered", "traded", "inspected",
]
WEATHER = ["rain", "fog", "frost", "wind", "heat", "snow"]
SEASONS = ["spring", "summer", "autumn", "winter"]
QUALITIES = ["careful", "patient", "meticulous", "swift", "quiet", "steady"]
MATERIALS = ["copper", "iron", "oak", "granite", "linen", "amber"]

KEYS = [
    "route", "cargo", "depth", "bearing", "signal", "ration", "ledger",
    "tariff", "berth", "draft", "manifest", "quota",
]
UNITS = ["m", "kg", "kn", "deg", "pct", "hr"]


class CorpusGen:
    """Deterministic corpus generator over a fixed template grammar."""

    def __init__(self, seed: int):
        self.rng = random.Random(seed)

    # -- narrative ----------------------------------------------------------

    def sentence(self) -> str:
        r = self.rng
        t = r.randrange(6)
        s, p, o = r.choice(SUBJECTS), r.choice(PLACES), r.choice(OBJECTS)
        v, q = r.choice(VERBS_PAST), r.choice(QUALITIES)
        if t == 0:
            return f"In {p}, {s} {v} {o}."
        if t == 1:
            return f"{s.capitalize()} {v} {o} before the {r.choice(WEATHER)} arrived."
        if t == 2:
            return f"Every {r.choice(SEASONS)}, {s} returned to {p} with {o}."
        if t == 3:
            return f"The {q} work of {s.split(' ')[1]} kept {p} in order."
        if t == 4:
            return f"{s.capitalize()} noted that the {r.choice(MATERIALS)} fittings of {p} had weathered the {r.choice(WEATHER)}."
        return f"By the {r.choice(SEASONS)}, {o} had been {v} twice and stored near {p}."

    # -- recall patterns --------------------------------------------------
    # These two-sentence discourse patterns are deliberately part of the
    # TRAINING distribution; the zero-shot tasks below instantiate the same
    # templates with held-out combinations. A trained model must COPY an
    # entity across ~60 bytes of context to continue them — the byte-level
    # analog of LAMBADA's "word is predictable from discourse, not from
    # the local sentence".

    def recall_object(self) -> tuple[str, str]:
        """('In {p}, {s} {v} {o}. Later that {season}, everyone asked
        about the', ' {noun}.') — the cloze pattern."""
        r = self.rng
        s, p, o = r.choice(SUBJECTS), r.choice(PLACES), r.choice(OBJECTS)
        noun = o.split(" ")[-1]
        ctx = (
            f"In {p}, {s} {r.choice(VERBS_PAST)} {o}. "
            f"Later that {r.choice(SEASONS)}, everyone asked about the"
        )
        return ctx, f" {noun}."

    def recall_subject(self) -> tuple[str, str, list[str]]:
        """('In {p}, {s} {v} {o}. The one seen in {p} was', ' {s}.',
        distractors) — the MCQ pattern."""
        r = self.rng
        subjects = r.sample(SUBJECTS, 4)
        s, p, o = subjects[0], r.choice(PLACES), r.choice(OBJECTS)
        ctx = f"In {p}, {s} {r.choice(VERBS_PAST)} {o}. The one seen in {p} was"
        return ctx, f" {s}.", [f" {d}." for d in subjects[1:]]

    def recall_carry(self) -> tuple[str, str, str]:
        """('{S} found {o1} in {p}. At dusk {s2}', good, bad) — the
        binary-choice pattern."""
        r = self.rng
        s, p = r.choice(SUBJECTS), r.choice(PLACES)
        o1, o2 = r.sample(OBJECTS, 2)
        ctx = f"{s.capitalize()} found {o1} in {p}. At dusk {s.split(' ')[1]}"
        return ctx, f" carried {o1} home.", f" carried {o2} home."

    def paragraph(self, n_sentences: int | None = None) -> str:
        n = n_sentences or self.rng.randrange(3, 7)
        parts = [self.sentence() for _ in range(n)]
        # weave the recall patterns into the training distribution
        roll = self.rng.random()
        if roll < 0.30:
            ctx, tail = self.recall_object()
            parts.append(ctx + tail)
        elif roll < 0.50:
            ctx, ans, _ = self.recall_subject()
            parts.append(ctx + ans)
        elif roll < 0.70:
            ctx, good, _ = self.recall_carry()
            parts.append(ctx + good)
        return " ".join(parts)

    def narrative(self, nbytes: int) -> str:
        parts = []
        size = 0
        while size < nbytes:
            p = self.paragraph() + "\n\n"
            parts.append(p)
            size += len(p)
        return "".join(parts)[:nbytes]

    # -- markup ---------------------------------------------------------------

    def record(self) -> str:
        r = self.rng
        name = r.choice(KEYS)
        lines = [f"[{name}.{r.randrange(100)}]"]
        for _ in range(r.randrange(2, 6)):
            k = r.choice(KEYS)
            if r.random() < 0.5:
                lines.append(f"  {k} = {r.randrange(1000)}{r.choice(UNITS)}")
            else:
                lines.append(f"  {k} = \"{r.choice(MATERIALS)}-{r.choice(SEASONS)}\"")
        return "\n".join(lines) + "\n"

    def markup(self, nbytes: int) -> str:
        parts = []
        size = 0
        while size < nbytes:
            p = self.record() + "\n"
            parts.append(p)
            size += len(p)
        return "".join(parts)[:nbytes]

    # -- crawl ----------------------------------------------------------------

    BOILER = [
        "subscribe to the bulletin for weekly updates.",
        "all measurements are approximate.",
        "contact the harbor office for details.",
        "archive index updated nightly.",
    ]

    def crawl(self, nbytes: int) -> str:
        parts = []
        size = 0
        while size < nbytes:
            roll = self.rng.random()
            if roll < 0.5:
                p = self.paragraph() + "\n"
            elif roll < 0.8:
                p = self.record()
            else:
                p = self.rng.choice(self.BOILER) + "\n"
            parts.append(p)
            size += len(p)
        return "".join(parts)[:nbytes]

    # -- zero-shot tasks --------------------------------------------------------

    def cloze_item(self) -> dict:
        """LAMBADA analog: object recall over ~60 bytes of discourse.
        Carries both the exact-match `target` and 4 likelihood `choices`
        (distractor nouns), mirroring LAMBADA's two evaluation modes."""
        r = self.rng
        ctx, tail = self.recall_object()
        noun_with_dot = tail[1:]  # "compass."
        noun = noun_with_dot[:-1]
        others = [o.split(" ")[-1] for o in OBJECTS if o.split(" ")[-1] != noun]
        distract = r.sample(others, 3)
        choices = [f" {noun}."] + [f" {d}." for d in distract]
        order = list(range(4))
        r.shuffle(order)
        return {
            "context": ctx,
            "target": " " + noun,
            "choices": [choices[i] for i in order],
            "answer": order.index(0),
        }

    def mcq_item(self) -> dict:
        """ARC analog: which subject was seen at a place, 4 choices."""
        r = self.rng
        ctx, ans, distractors = self.recall_subject()
        choices = [ans] + distractors
        order = list(range(4))
        r.shuffle(order)
        return {
            "context": ctx,
            "choices": [choices[i] for i in order],
            "answer": order.index(0),
        }

    def binary_item(self) -> dict:
        """PIQA/StoryCloze analog: pick the consistent ending."""
        r = self.rng
        ctx, good, bad = self.recall_carry()
        if r.random() < 0.5:
            return {"context": ctx, "choices": [good, bad], "answer": 0}
        return {"context": ctx, "choices": [bad, good], "answer": 1}


# ---------------------------------------------------------------------------

STYLES = ("narrative", "markup", "crawl")


def build_corpus(
    out_dir: Path,
    seed: int = 1234,
    train_bytes: int = 2_000_000,
    eval_bytes: int = 65_536,
    n_tasks: int = 400,
) -> None:
    """Write the full corpus + task tree under `out_dir`."""
    out_dir.mkdir(parents=True, exist_ok=True)
    gen = CorpusGen(seed)
    # training mixture: all three styles (like training on diverse text)
    third = train_bytes // 3
    train = gen.narrative(third) + gen.markup(third) + gen.crawl(third)
    (out_dir / "train.bin").write_bytes(train.encode())
    for i, style in enumerate(STYLES):
        g = CorpusGen(seed + 100 + i)
        text = getattr(g, style)(2 * eval_bytes)
        (out_dir / f"{style}_val.bin").write_bytes(text[:eval_bytes].encode())
        (out_dir / f"{style}_test.bin").write_bytes(text[eval_bytes:].encode())
    # calibration pool: fresh crawl text (disjoint seed), as in the paper
    calib = CorpusGen(seed + 999).crawl(512 * 1024)
    (out_dir / "calib.bin").write_bytes(calib.encode())

    tasks = out_dir / "tasks"
    tasks.mkdir(exist_ok=True)
    tg = CorpusGen(seed + 5000)
    for name, fn in (
        ("cloze", tg.cloze_item),
        ("mcq", tg.mcq_item),
        ("binary", tg.binary_item),
    ):
        with open(tasks / f"{name}.jsonl", "w") as f:
            for _ in range(n_tasks):
                f.write(json.dumps(fn()) + "\n")
