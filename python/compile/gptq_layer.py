"""L2: the per-layer GPTQ quantization graph (paper Algorithm 1).

Composes the L1 `gptq_block` Pallas kernel with jnp glue:

    H → dead-column fix → damping → Cholesky(H⁻¹, upper)
      → for each column block: per-group grid params from the CURRENT
        weights → L1 kernel (in-block solve) → batched tail update
        W[:, i2:] −= Err · U[i1:i2, i2:]            (paper Eq. 4)

The block loop is unrolled at trace time (shapes are static per AOT
artifact; dcol/B ≤ a few dozen), so the whole layer lowers to ONE fused
HLO program that the Rust coordinator executes per layer.

All Hessian algebra is f32 here (XLA CPU path); the paper's dampening
(λ = 1% of mean diagonal) plus the Cholesky formulation keeps this stable
at our scales — the Rust substrate additionally offers f64 for the
stability ablation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.gptq import gptq_block
from .kernels.ref import DEFAULT_BLOCKSIZE, DEFAULT_PERCDAMP


def _quant_params(w: jax.Array, bits: int):
    """jnp twin of ref.quant_params (per-row asymmetric min-max)."""
    maxq = float(2**bits - 1)
    wmin = jnp.minimum(w.min(axis=1), 0.0)
    wmax = jnp.maximum(w.max(axis=1), 0.0)
    degenerate = wmin == wmax
    wmin = jnp.where(degenerate, wmin - 0.5, wmin)
    wmax = jnp.where(degenerate, wmax + 0.5, wmax)
    scale = (wmax - wmin) / maxq
    zero = jnp.round(-wmin / scale)
    return scale, zero


def _cholesky_lower_jnp(a: jax.Array) -> jax.Array:
    """Pure-jnp lower Cholesky (outer-product form, fori_loop).

    jnp.linalg.cholesky/inv lower to LAPACK *custom calls* on the CPU
    backend, which the runtime's xla_extension 0.5.1 cannot compile
    ("Unknown custom-call API version ... TYPED_FFI"). This loop lowers to
    a plain HLO while-loop instead — slower to solve but fully portable,
    and the solve is a tiny fraction of layer-quantization cost.
    """
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(j, carry):
        a, l = carry
        d = jnp.sqrt(a[j, j])
        col = jnp.where(idx > j, a[:, j] / d, 0.0)
        col = col.at[j].set(d)
        l = l.at[:, j].set(col)
        a = a - jnp.outer(col, col)
        return a, l

    _, l = jax.lax.fori_loop(0, n, body, (a, jnp.zeros_like(a)))
    return l


def _solve_lower_jnp(l: jax.Array, b: jax.Array) -> jax.Array:
    """Solve L Y = B by forward substitution (pure jnp)."""
    n = l.shape[0]

    def body(i, y):
        row = (b[i] - l[i] @ y) / l[i, i]
        return y.at[i].set(row)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))


def _solve_upper_jnp(u: jax.Array, b: jax.Array) -> jax.Array:
    """Solve U Y = B by backward substitution (pure jnp)."""
    n = u.shape[0]

    def body(k, y):
        i = n - 1 - k
        row = (b[i] - u[i] @ y) / u[i, i]
        return y.at[i].set(row)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))


def prepare_cholesky(h: jax.Array, w: jax.Array, percdamp: float = DEFAULT_PERCDAMP):
    """Dead columns + damping + upper Cholesky of H⁻¹ (paper Step 3)."""
    dcol = h.shape[0]
    diag = jnp.diagonal(h)
    dead = diag == 0.0
    h = h + jnp.diag(jnp.where(dead, 1.0, 0.0))
    w = jnp.where(dead[None, :], 0.0, w)
    damp = percdamp * jnp.mean(jnp.diagonal(h))
    h = h + damp * jnp.eye(dcol, dtype=h.dtype)
    # H⁻¹ via Cholesky solves (no LAPACK custom calls — see above)
    l = _cholesky_lower_jnp(h)
    eye = jnp.eye(dcol, dtype=h.dtype)
    hinv = _solve_upper_jnp(l.T, _solve_lower_jnp(l, eye))
    # symmetrize before the second factorization (solve drift)
    hinv = 0.5 * (hinv + hinv.T)
    lower = _cholesky_lower_jnp(hinv)
    return lower.T, w


def gptq_quantize_layer(
    w: jax.Array,
    h: jax.Array,
    bits: int,
    blocksize: int = DEFAULT_BLOCKSIZE,
    groupsize: int = 0,
    percdamp: float = DEFAULT_PERCDAMP,
    row_tile: int = 256,
):
    """Quantize one (drow, dcol) layer. Returns (codes, scales, zeros, wq).

    Semantics identical to kernels.ref.gptq_ref (the pytest oracle) and to
    rust/src/quant/gptq.rs."""
    drow, dcol = w.shape
    w = w.astype(jnp.float32)
    u, wf = prepare_cholesky(h.astype(jnp.float32), w, percdamp)
    g = groupsize if groupsize else dcol
    assert dcol % g == 0, (dcol, g)
    bs = min(blocksize, g, dcol)
    assert dcol % bs == 0, (dcol, bs)
    ngroups = dcol // g
    tile = min(row_tile, drow)
    while drow % tile:
        tile //= 2

    if groupsize == 0:
        s0, z0 = _quant_params(wf, bits)

    codes_blocks, wq_blocks = [], []
    scales = jnp.zeros((drow, ngroups), jnp.float32)
    zeros = jnp.zeros((drow, ngroups), jnp.float32)
    for i1 in range(0, dcol, bs):
        i2 = i1 + bs
        if groupsize and i1 % g == 0:
            s0, z0 = _quant_params(
                jax.lax.dynamic_slice_in_dim(wf, i1, g, axis=1), bits
            )
        gi = i1 // g
        scales = scales.at[:, gi].set(s0)
        zeros = zeros.at[:, gi].set(z0)
        q, wq, err = gptq_block(
            wf[:, i1:i2], u[i1:i2, i1:i2], s0, z0, bits, row_tile=tile
        )
        codes_blocks.append(q)
        wq_blocks.append(wq)
        if i2 < dcol:
            # batched tail compensation across the remaining columns
            tail = wf[:, i2:] - err @ u[i1:i2, i2:]
            wf = jnp.concatenate([wf[:, :i2], tail], axis=1)
    codes = jnp.concatenate(codes_blocks, axis=1)
    wq = jnp.concatenate(wq_blocks, axis=1)
    return codes, scales, zeros, wq


def rtn_quantize_layer(w: jax.Array, bits: int, groupsize: int = 0):
    """RTN on the same grid (the paper's baseline), pure jnp."""
    drow, dcol = w.shape
    g = groupsize if groupsize else dcol
    ngroups = dcol // g
    maxq = float(2**bits - 1)
    wg = w.reshape(drow, ngroups, g)
    wmin = jnp.minimum(wg.min(axis=2), 0.0)
    wmax = jnp.maximum(wg.max(axis=2), 0.0)
    degenerate = wmin == wmax
    wmin = jnp.where(degenerate, wmin - 0.5, wmin)
    wmax = jnp.where(degenerate, wmax + 0.5, wmax)
    scale = (wmax - wmin) / maxq
    zero = jnp.round(-wmin / scale)
    q = jnp.clip(jnp.round(wg / scale[..., None]) + zero[..., None], 0.0, maxq)
    wq = scale[..., None] * (q - zero[..., None])
    return (
        q.reshape(drow, dcol),
        scale,
        zero,
        wq.reshape(drow, dcol),
    )


@functools.partial(jax.jit, static_argnames=("bits", "blocksize", "groupsize"))
def gptq_quantize_layer_jit(w, h, bits, blocksize=DEFAULT_BLOCKSIZE, groupsize=0):
    return gptq_quantize_layer(w, h, bits, blocksize, groupsize)
