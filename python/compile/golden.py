"""Golden cross-check vectors: Python (oracle) → Rust (quant substrate).

Written into artifacts/golden.json by aot.py; rust integration tests load
it and assert the pure-Rust GPTQ/RTN/packing implementations reproduce the
Python oracles bit-exactly (codes) / to tolerance (floats).
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from .kernels import ref


def _case(rng, drow, dcol, bits, blocksize, groupsize):
    w = rng.normal(size=(drow, dcol)).astype(np.float32)
    # correlated calibration inputs + a few outlier feature dims, the
    # regime where GPTQ's error compensation matters
    mix = rng.normal(size=(dcol, dcol)).astype(np.float32) / np.sqrt(dcol)
    x = rng.normal(size=(4 * dcol, dcol)).astype(np.float32) @ mix
    x[:, rng.integers(0, dcol, 2)] *= 8.0
    h = ref.hessian_ref(x)
    codes, scales, zeros, wq = ref.gptq_ref(w, h, bits, blocksize, groupsize)
    rcodes, rscales, rzeros, rwq = ref.rtn_ref(w, bits, groupsize)
    words = ref.pack_codes(codes, bits)
    return {
        "drow": drow,
        "dcol": dcol,
        "bits": bits,
        "blocksize": blocksize,
        "groupsize": groupsize,
        "w": w.flatten().tolist(),
        "h": h.flatten().tolist(),
        "gptq_codes": codes.flatten().astype(int).tolist(),
        "gptq_scales": scales.flatten().tolist(),
        "gptq_zeros": zeros.flatten().tolist(),
        "gptq_wq": wq.flatten().tolist(),
        "rtn_codes": rcodes.flatten().astype(int).tolist(),
        "rtn_wq": rwq.flatten().tolist(),
        "packed_words": words.flatten().astype(int).tolist(),
    }


def write_golden(path: Path, seed: int = 42) -> None:
    rng = np.random.default_rng(seed)
    cases = [
        _case(rng, 8, 16, 4, 16, 0),
        _case(rng, 8, 16, 3, 8, 0),
        _case(rng, 16, 32, 4, 8, 8),
        _case(rng, 12, 24, 2, 128, 0),
        _case(rng, 16, 32, 3, 16, 16),
        _case(rng, 32, 64, 4, 32, 0),
    ]
    path.write_text(json.dumps({"seed": seed, "cases": cases}))
