"""L1 Pallas kernel: the GPTQ blocked column solver (paper §3.3, Fig. 2).

One `pallas_call` processes ONE block of `B` consecutive columns for a tile
of rows. The sequential data dependence of GPTQ lives along columns; rows
are independent, so the grid parallelizes over row tiles (the exact
parallelism the paper's vectorized implementation exploits across rows).

Inputs per call:
  w      (drow, B)  current (already tail-compensated) weight block
  u      (B, B)     the diagonal block of the upper Cholesky factor of H⁻¹
  scale  (drow, 1)  per-row grid scale (computed by L2 at group boundaries)
  zero   (drow, 1)  per-row grid zero point
Outputs:
  q      (drow, B)  integer codes (as f32)
  wq     (drow, B)  dequantized weights
  err    (drow, B)  per-column compensation errors (w − ŵ)/U[j,j]; the L2
                    graph applies the batched tail update  W_tail −= err·U_tail
                    (paper Eq. 4) after the call.

The column loop is a `fori_loop`; the in-block compensation
`W[:, j+1:] −= err ⊗ U[j, j+1:]` is expressed as a masked full-width
rank-1 update so the kernel stays fully vectorized over the lane dimension
(no dynamic inner slices — maps to VPU-friendly selects on TPU).

`interpret=True` always: the CPU PJRT client cannot run Mosaic custom
calls; structure (tiling, masking) is still the TPU design.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 256


def _gptq_block_kernel(w_ref, u_ref, scale_ref, zero_ref, q_ref, wq_ref, err_ref, *, bits: int, block: int):
    maxq = float(2**bits - 1)
    scale = scale_ref[:, 0]
    zero = zero_ref[:, 0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)

    def body(j, w):
        col = w[:, j]
        q = jnp.clip(jnp.round(col / scale) + zero, 0.0, maxq)
        dq = scale * (q - zero)
        d = u_ref[j, j]
        e = (col - dq) / d
        # masked rank-1 update of the columns strictly right of j
        urow = u_ref[j, :]
        mask = (cols > j).astype(w.dtype)
        w = w - (e[:, None] * urow[None, :]) * mask
        q_ref[:, j] = q
        wq_ref[:, j] = dq
        err_ref[:, j] = e
        return w

    jax.lax.fori_loop(0, block, body, w_ref[...])


def gptq_block(
    w: jax.Array,
    u: jax.Array,
    scale: jax.Array,
    zero: jax.Array,
    bits: int,
    row_tile: int = DEFAULT_ROW_TILE,
):
    """Run the GPTQ solver on one column block.

    w: (drow, B); u: (B, B) upper-Cholesky diagonal block; scale/zero:
    (drow,). Returns (q, wq, err), each (drow, B)."""
    drow, block = w.shape
    assert u.shape == (block, block)
    tile = min(row_tile, drow)
    assert drow % tile == 0, f"row tile {tile} must divide drow {drow}"
    grid = (drow // tile,)
    kernel = functools.partial(_gptq_block_kernel, bits=bits, block=block)
    out_shape = [jax.ShapeDtypeStruct((drow, block), jnp.float32)] * 3
    q, wq, err = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, block), lambda i: (i, 0)),
            pl.BlockSpec((block, block), lambda i: (0, 0)),
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((tile, block), lambda i: (i, 0))] * 3,
        out_shape=out_shape,
        interpret=True,
    )(w.astype(jnp.float32), u.astype(jnp.float32), scale.reshape(-1, 1), zero.reshape(-1, 1))
    return q, wq, err
