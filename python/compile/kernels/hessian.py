"""L1 Pallas kernel: tiled Hessian accumulation H = 2 XᵀX.

The calibration pass streams activation batches through this kernel; the
grid walks row-blocks of X and accumulates partial Gram matrices into the
output (revisited output block + @pl.when zero-init — the standard Pallas
reduction idiom, the analog of the paper's batched Hessian accumulation
over calibration samples).

Unlike the batch-1 matvec, this IS an MXU-shaped op on TPU: f32 (or bf16)
Gram tiles feed the systolic array; the n-dimension tiling bounds the VMEM
working set to 2·tile_n·dcol·4 B + dcol²·4 B.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_N_TILE = 256


def _hessian_kernel(x_ref, h_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[...]
    h_ref[...] += 2.0 * jnp.dot(x.T, x)


def hessian(x: jax.Array, n_tile: int = DEFAULT_N_TILE) -> jax.Array:
    """H = 2 XᵀX for X (n, dcol), accumulated over n-tiles."""
    n, dcol = x.shape
    tile = min(n_tile, n)
    assert n % tile == 0, f"n tile {tile} must divide n {n}"
    return pl.pallas_call(
        _hessian_kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((tile, dcol), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((dcol, dcol), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((dcol, dcol), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))
