"""Pure-numpy oracles for every L1 kernel and the L2 GPTQ graph.

These functions define the *canonical semantics* of the library: the Pallas
kernels (gptq.py, packmatvec.py, rtn.py, hessian.py), the L2 graph
(gptq_layer.py) and the pure-Rust implementations (rust/src/quant/) must all
match these bit-for-bit (integer codes) / to float tolerance (dequantized
values).

Conventions (see DESIGN.md §Quantization semantics):
  * weight matrices are (drow, dcol) = (out_features, in_features);
  * the Hessian is over in_features: H = 2 XᵀX with X of shape (n, dcol);
  * grids are uniform asymmetric min-max, per row or per group of G
    consecutive in-row weights;
  * GPTQ quantizes columns left-to-right in blocks of `blocksize`,
    compensating the error via the upper Cholesky factor of H⁻¹.
"""

from __future__ import annotations

import numpy as np

DEFAULT_BLOCKSIZE = 128
DEFAULT_PERCDAMP = 0.01


# ---------------------------------------------------------------------------
# grids
# ---------------------------------------------------------------------------

def quant_params(w: np.ndarray, bits: int):
    """Per-row asymmetric min-max grid over the columns of `w`.

    Returns (scale, zero) with shapes (drow,). `zero` is an integer-valued
    float (the code that maps to 0.0). The range is always widened to
    include 0 (so zero-valued weights dequantize exactly); degenerate rows
    (max == min) get a symmetric unit range.
    """
    maxq = float(2**bits - 1)
    wmin = np.minimum(w.min(axis=1), 0.0)
    wmax = np.maximum(w.max(axis=1), 0.0)
    degenerate = wmin == wmax
    wmin = np.where(degenerate, wmin - 0.5, wmin)
    wmax = np.where(degenerate, wmax + 0.5, wmax)
    scale = (wmax - wmin) / maxq
    zero = np.round(-wmin / scale)
    return scale.astype(np.float32), zero.astype(np.float32)


def quantize_col(w: np.ndarray, scale: np.ndarray, zero: np.ndarray, bits: int):
    """Quantize one column (or any array broadcastable with scale/zero).

    Returns (codes, dequantized)."""
    maxq = float(2**bits - 1)
    q = np.clip(np.round(w / scale) + zero, 0.0, maxq)
    return q, scale * (q - zero)


# ---------------------------------------------------------------------------
# RTN baseline
# ---------------------------------------------------------------------------

def rtn_ref(w: np.ndarray, bits: int, groupsize: int = 0):
    """Round-to-nearest on the min-max grid; groupsize 0 means per-row.

    Returns (codes (drow, dcol) float-valued ints, scales (drow, ngroups),
    zeros (drow, ngroups), wq (drow, dcol))."""
    drow, dcol = w.shape
    g = groupsize if groupsize else dcol
    assert dcol % g == 0, f"groupsize {g} must divide dcol {dcol}"
    ngroups = dcol // g
    codes = np.empty_like(w, dtype=np.float32)
    wq = np.empty_like(w, dtype=np.float32)
    scales = np.empty((drow, ngroups), dtype=np.float32)
    zeros = np.empty((drow, ngroups), dtype=np.float32)
    for gi in range(ngroups):
        sl = slice(gi * g, (gi + 1) * g)
        s, z = quant_params(w[:, sl], bits)
        scales[:, gi] = s
        zeros[:, gi] = z
        q, dq = quantize_col(w[:, sl], s[:, None], z[:, None], bits)
        codes[:, sl] = q
        wq[:, sl] = dq
    return codes, scales, zeros, wq


# ---------------------------------------------------------------------------
# Hessian
# ---------------------------------------------------------------------------

def hessian_ref(x: np.ndarray) -> np.ndarray:
    """H = 2 XᵀX for X of shape (n, dcol). Accumulate over batches by
    summing results."""
    x = x.astype(np.float32)
    return 2.0 * (x.T @ x)


def prepare_hinv_cholesky(
    h: np.ndarray, w: np.ndarray, percdamp: float = DEFAULT_PERCDAMP
):
    """Dead-column handling + damping + upper Cholesky factor of H⁻¹.

    Returns (U, w_fixed) where U is upper-triangular with UᵀU = (H + λI)⁻¹
    (the factor GPTQ consumes) and w_fixed has dead columns zeroed.
    """
    h = h.astype(np.float64).copy()
    w = w.astype(np.float64).copy()
    dead = np.diag(h) == 0.0
    h[dead, dead] = 1.0
    w[:, dead] = 0.0
    damp = percdamp * float(np.mean(np.diag(h)))
    h[np.diag_indices_from(h)] += damp
    hinv = np.linalg.inv(h)
    # lower Cholesky L with L Lᵀ = Hinv; U = Lᵀ is upper with UᵀU = Hinv.
    lower = np.linalg.cholesky(hinv)
    return lower.T.copy(), w


# ---------------------------------------------------------------------------
# GPTQ (Algorithm 1 of the paper, in-place group-stat semantics)
# ---------------------------------------------------------------------------

def gptq_ref(
    w: np.ndarray,
    h: np.ndarray,
    bits: int,
    blocksize: int = DEFAULT_BLOCKSIZE,
    groupsize: int = 0,
    percdamp: float = DEFAULT_PERCDAMP,
):
    """Reference GPTQ. Returns (codes, scales, zeros, wq).

    scales/zeros are (drow, ngroups) with ngroups = dcol/groupsize (1 if
    groupsize == 0; then computed once from the original weights, the
    paper's per-row default). With grouping, grid parameters are recomputed
    at every group boundary from the *current, error-compensated* weights
    ("always using the most current updated weights", §Additional Tricks).
    Group boundaries are processing-block boundaries too (the effective
    block size is min(blocksize, groupsize)), which makes the in-place
    semantics exact.
    """
    drow, dcol = w.shape
    u, wf = prepare_hinv_cholesky(h, w, percdamp)
    g = groupsize if groupsize else dcol
    assert dcol % g == 0
    bs = min(blocksize, g, dcol)
    codes = np.zeros((drow, dcol), dtype=np.float64)
    wq = np.zeros((drow, dcol), dtype=np.float64)
    ngroups = dcol // g
    scales = np.empty((drow, ngroups), dtype=np.float32)
    zeros = np.empty((drow, ngroups), dtype=np.float32)
    if groupsize == 0:
        s, z = quant_params(wf.astype(np.float32), bits)
        scales[:, 0] = s
        zeros[:, 0] = z

    for i1 in range(0, dcol, bs):
        i2 = min(i1 + bs, dcol)
        err = np.zeros((drow, i2 - i1), dtype=np.float64)
        for j in range(i1, i2):
            if groupsize and j % g == 0:
                s, z = quant_params(wf[:, j : j + g].astype(np.float32), bits)
                scales[:, j // g] = s
                zeros[:, j // g] = z
            gi = j // g if groupsize else 0
            s64 = scales[:, gi].astype(np.float64)
            z64 = zeros[:, gi].astype(np.float64)
            col = wf[:, j]
            q, dq = quantize_col(col, s64, z64, bits)
            codes[:, j] = q
            wq[:, j] = dq
            e = (col - dq) / u[j, j]
            # compensate the remaining columns of this block
            if j + 1 < i2:
                wf[:, j + 1 : i2] -= np.outer(e, u[j, j + 1 : i2])
            err[:, j - i1] = e
        # batched tail update (paper Eq. 4/5 via the Cholesky rows)
        if i2 < dcol:
            wf[:, i2:] -= err @ u[i1:i2, i2:]
    return (
        codes.astype(np.float32),
        scales,
        zeros,
        wq.astype(np.float32),
    )


# ---------------------------------------------------------------------------
# packing + quantized matvec
# ---------------------------------------------------------------------------

def codes_per_word(bits: int) -> int:
    return 32 // bits  # 2->16, 3->10 (2 pad bits), 4->8


def pack_codes(codes: np.ndarray, bits: int) -> np.ndarray:
    """Little-endian field packing of integer codes into u32 words, per row.

    codes: (drow, dcol) integer-valued. Returns (drow, nwords) uint32 with
    nwords = ceil(dcol / codes_per_word)."""
    drow, dcol = codes.shape
    cpw = codes_per_word(bits)
    nwords = (dcol + cpw - 1) // cpw
    padded = np.zeros((drow, nwords * cpw), dtype=np.uint64)
    padded[:, :dcol] = codes.astype(np.uint64)
    padded = padded.reshape(drow, nwords, cpw)
    shifts = (bits * np.arange(cpw, dtype=np.uint64))[None, None, :]
    words = (padded << shifts).sum(axis=2)
    assert (words < (1 << 32)).all()
    return words.astype(np.uint32)


def unpack_codes(words: np.ndarray, bits: int, dcol: int) -> np.ndarray:
    """Inverse of pack_codes; returns float32 codes of shape (drow, dcol)."""
    drow, nwords = words.shape
    cpw = codes_per_word(bits)
    shifts = (bits * np.arange(cpw, dtype=np.uint64))[None, None, :]
    mask = np.uint64(2**bits - 1)
    fields = (words.astype(np.uint64)[:, :, None] >> shifts) & mask
    return fields.reshape(drow, nwords * cpw)[:, :dcol].astype(np.float32)


def packmatvec_ref(
    words: np.ndarray,
    scales: np.ndarray,
    zeros: np.ndarray,
    x: np.ndarray,
    bits: int,
    groupsize: int = 0,
) -> np.ndarray:
    """y = Ŵ x where Ŵ is dequantized on the fly from packed codes.

    words: (drow, nwords) uint32; scales/zeros: (drow, ngroups);
    x: (dcol,) float32. The paper's inference-kernel semantics."""
    dcol = x.shape[0]
    codes = unpack_codes(words, bits, dcol)
    g = groupsize if groupsize else dcol
    ngroups = dcol // g
    s = np.repeat(scales[:, :ngroups], g, axis=1)
    z = np.repeat(zeros[:, :ngroups], g, axis=1)
    wq = s * (codes - z)
    return (wq @ x.astype(np.float32)).astype(np.float32)


def layer_sq_error(w: np.ndarray, wq: np.ndarray, x: np.ndarray) -> float:
    """||WX − ŴX||² / n, the objective of Eq. (1), X given as (n, dcol)."""
    d = (w - wq) @ x.T
    return float((d * d).sum() / x.shape[0])
