"""L1 Pallas kernel: quantized-matrix × full-precision-vector product.

This is the paper's inference kernel (§Practical Speedups): weights stay in
packed b-bit form in (H)BM; each grid program stages one row-tile of packed
words into VMEM, unpacks + dequantizes in registers, and accumulates the
matvec. No activation quantization — x stays f32, exactly as in the paper.

GPU→TPU adaptation (DESIGN.md §Hardware-Adaptation): the CUDA kernel's
per-threadblock shared-memory staging becomes the BlockSpec HBM→VMEM
schedule; the unpack is a vectorized shift/mask over the lane dimension
(VPU), and batch-1 matvec deliberately avoids the MXU (bandwidth-bound).

VMEM footprint per tile (documented for the TPU path):
  tile_r·nwords·4 B (codes) + tile_r·ngroups·8 B (scale+zero) + dcol·4 B (x)
e.g. tile_r=256, dcol=1024, 3-bit: 256·103·4 ≈ 103 KiB ≪ 16 MiB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 256


def codes_per_word(bits: int) -> int:
    return 32 // bits


def _packmatvec_kernel(words_ref, scale_ref, zero_ref, x_ref, o_ref, *, bits: int, dcol: int, groupsize: int):
    cpw = codes_per_word(bits)
    mask = jnp.uint32(2**bits - 1)
    words = words_ref[...]  # (tile_r, nwords) uint32
    tile_r, nwords = words.shape
    # vectorized unpack: (tile_r, nwords, cpw) field extraction
    shifts = (bits * jax.lax.broadcasted_iota(jnp.uint32, (1, 1, cpw), 2)).astype(jnp.uint32)
    fields = (words[:, :, None] >> shifts) & mask
    codes = fields.reshape(tile_r, nwords * cpw)[:, :dcol].astype(jnp.float32)
    g = groupsize if groupsize else dcol
    ngroups = dcol // g
    s = jnp.repeat(scale_ref[:, :ngroups], g, axis=1)
    z = jnp.repeat(zero_ref[:, :ngroups], g, axis=1)
    wq = s * (codes - z)
    o_ref[:, 0] = wq @ x_ref[:, 0]


def packmatvec(
    words: jax.Array,
    scales: jax.Array,
    zeros: jax.Array,
    x: jax.Array,
    bits: int,
    groupsize: int = 0,
    row_tile: int = DEFAULT_ROW_TILE,
):
    """y = dequant(words; scales, zeros) @ x.

    words: (drow, nwords) uint32; scales/zeros: (drow, ngroups); x: (dcol,).
    Returns y: (drow,) float32."""
    drow, nwords = words.shape
    dcol = x.shape[0]
    ngroups = scales.shape[1]
    tile = min(row_tile, drow)
    assert drow % tile == 0
    kernel = functools.partial(
        _packmatvec_kernel, bits=bits, dcol=dcol, groupsize=groupsize
    )
    y = pl.pallas_call(
        kernel,
        grid=(drow // tile,),
        in_specs=[
            pl.BlockSpec((tile, nwords), lambda i: (i, 0)),
            pl.BlockSpec((tile, ngroups), lambda i: (i, 0)),
            pl.BlockSpec((tile, ngroups), lambda i: (i, 0)),
            pl.BlockSpec((dcol, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((drow, 1), jnp.float32),
        interpret=True,
    )(words, scales.astype(jnp.float32), zeros.astype(jnp.float32), x.reshape(-1, 1).astype(jnp.float32))
    return y[:, 0]
