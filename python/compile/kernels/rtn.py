"""L1 Pallas kernel: round-to-nearest (RTN) quantization — the paper's
baseline (the method used by ZeroQuant / LLM.int8() / nuQmm at scale).

Grid parallelizes over row tiles; each program quantizes a full row tile
against its per-row (or per-group) grid in one vectorized pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 256


def _rtn_kernel(w_ref, scale_ref, zero_ref, q_ref, wq_ref, *, bits: int, groupsize: int, dcol: int):
    maxq = float(2**bits - 1)
    w = w_ref[...]
    g = groupsize if groupsize else dcol
    ngroups = dcol // g
    s = jnp.repeat(scale_ref[:, :ngroups], g, axis=1)
    z = jnp.repeat(zero_ref[:, :ngroups], g, axis=1)
    q = jnp.clip(jnp.round(w / s) + z, 0.0, maxq)
    q_ref[...] = q
    wq_ref[...] = s * (q - z)


def rtn(
    w: jax.Array,
    scales: jax.Array,
    zeros: jax.Array,
    bits: int,
    groupsize: int = 0,
    row_tile: int = DEFAULT_ROW_TILE,
):
    """RTN-quantize `w` (drow, dcol) against precomputed grids.

    scales/zeros: (drow, ngroups). Returns (codes, wq)."""
    drow, dcol = w.shape
    ngroups = scales.shape[1]
    tile = min(row_tile, drow)
    assert drow % tile == 0
    kernel = functools.partial(_rtn_kernel, bits=bits, groupsize=groupsize, dcol=dcol)
    q, wq = pl.pallas_call(
        kernel,
        grid=(drow // tile,),
        in_specs=[
            pl.BlockSpec((tile, dcol), lambda i: (i, 0)),
            pl.BlockSpec((tile, ngroups), lambda i: (i, 0)),
            pl.BlockSpec((tile, ngroups), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((tile, dcol), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((drow, dcol), jnp.float32)] * 2,
        interpret=True,
    )(w.astype(jnp.float32), scales.astype(jnp.float32), zeros.astype(jnp.float32))
    return q, wq
