"""Bit-packing semantics (shared with rust/src/quant/pack.rs)."""

import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # property sweeps need hypothesis
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

settings.register_profile("packing", deadline=None, max_examples=50)
settings.load_profile("packing")


@given(
    bits=st.sampled_from([2, 3, 4]),
    drow=st.integers(1, 8),
    dcol=st.integers(1, 70),
    seed=st.integers(0, 2**31),
)
def test_pack_unpack_roundtrip(bits, drow, dcol, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 2**bits, size=(drow, dcol)).astype(np.float32)
    words = ref.pack_codes(codes, bits)
    out = ref.unpack_codes(words, bits, dcol)
    np.testing.assert_array_equal(out, codes)


def test_codes_per_word():
    assert ref.codes_per_word(2) == 16
    assert ref.codes_per_word(3) == 10  # 2 pad bits per word
    assert ref.codes_per_word(4) == 8


def test_pack_width():
    codes = np.zeros((3, 25), dtype=np.float32)
    assert ref.pack_codes(codes, 4).shape == (3, 4)   # ceil(25/8)
    assert ref.pack_codes(codes, 3).shape == (3, 3)   # ceil(25/10)
    assert ref.pack_codes(codes, 2).shape == (3, 2)   # ceil(25/16)


def test_pack_is_little_endian_fields():
    codes = np.array([[1, 2, 3]], dtype=np.float32)
    w = ref.pack_codes(codes, 4)
    assert w[0, 0] == 1 | (2 << 4) | (3 << 8)


def test_storage_ratio():
    """3-bit packing moves 10 codes per 4 bytes → 3.2 effective bits, the
    overhead quoted in DESIGN.md / the memory tables."""
    drow, dcol = 4, 640
    codes = np.zeros((drow, dcol), dtype=np.float32)
    words = ref.pack_codes(codes, 3)
    eff_bits = words.size * 32 / codes.size
    assert abs(eff_bits - 3.2) < 1e-9
