"""Corpus/task generator: determinism, byte-safety, task answerability."""

import json

import pytest

from compile.corpus import STYLES, CorpusGen, build_corpus


def test_deterministic():
    a = CorpusGen(7).narrative(4096)
    b = CorpusGen(7).narrative(4096)
    assert a == b
    assert CorpusGen(8).narrative(4096) != a


def test_all_styles_ascii():
    g = CorpusGen(1)
    for style in STYLES:
        text = getattr(g, style)(8192)
        assert len(text.encode()) == len(text)  # pure ASCII → 1 byte/char
        assert len(text) == 8192


def test_styles_differ():
    g = CorpusGen(2)
    n = g.narrative(4096)
    m = g.markup(4096)
    assert "[" in m and "=" in m
    assert n.count(".") > m.count(".")


def test_cloze_target_in_context():
    """The cloze answer is recoverable from the context (discourse-determined,
    the LAMBADA property), and the labeled choice is the target."""
    g = CorpusGen(3)
    for _ in range(50):
        item = g.cloze_item()
        assert item["target"].strip() in item["context"]
        assert item["choices"][item["answer"]].strip().rstrip(".") == item["target"].strip()


def test_mcq_answer_present():
    g = CorpusGen(4)
    for _ in range(50):
        item = g.mcq_item()
        assert len(item["choices"]) == 4
        assert item["choices"][item["answer"]].strip().rstrip(".") in item["context"]
        assert len(set(item["choices"])) == 4


def test_recall_patterns_in_training_text():
    """The task templates must be part of the training distribution — the
    property that makes the zero-shot suite learnable (and therefore
    quantization-sensitive)."""
    text = CorpusGen(11).narrative(200_000)
    assert "everyone asked about the" in text
    assert "The one seen in" in text
    assert "At dusk" in text and "home." in text


def test_binary_items_balanced():
    g = CorpusGen(5)
    answers = [g.binary_item()["answer"] for _ in range(200)]
    assert 0.3 < sum(answers) / len(answers) < 0.7


def test_build_corpus_tree(tmp_path):
    build_corpus(tmp_path, train_bytes=30_000, eval_bytes=2_048, n_tasks=10)
    assert (tmp_path / "train.bin").stat().st_size >= 29_000
    for s in STYLES:
        assert (tmp_path / f"{s}_val.bin").stat().st_size == 2048
        assert (tmp_path / f"{s}_test.bin").stat().st_size == 2048
        # val and test must be disjoint text
        assert (tmp_path / f"{s}_val.bin").read_bytes() != (tmp_path / f"{s}_test.bin").read_bytes()
    for t in ("cloze", "mcq", "binary"):
        lines = (tmp_path / "tasks" / f"{t}.jsonl").read_text().splitlines()
        assert len(lines) == 10
        json.loads(lines[0])
