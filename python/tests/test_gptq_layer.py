"""L2 GPTQ graph (gptq_layer.py) vs the numpy oracle, plus the algorithmic
properties the paper claims (GPTQ ≤ RTN layer error; blocking is exact)."""

import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # property sweeps need hypothesis
from hypothesis import given, settings, strategies as st

from compile.gptq_layer import gptq_quantize_layer, rtn_quantize_layer
from compile.kernels import ref

from conftest import correlated_inputs

settings.register_profile("layer", deadline=None, max_examples=8)
settings.load_profile("layer")


def _case(seed, drow, dcol, outliers=2):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(drow, dcol)).astype(np.float32)
    x = correlated_inputs(rng, 4 * dcol, dcol, outliers=outliers)
    return w, ref.hessian_ref(x), x


@given(
    seed=st.integers(0, 2**31),
    bits=st.sampled_from([3, 4]),
    blocksize=st.sampled_from([8, 16, 64]),
)
def test_graph_matches_ref(seed, bits, blocksize):
    w, h, _ = _case(seed, 16, 32)
    codes, scales, zeros, wq = gptq_quantize_layer(
        jnp.asarray(w), jnp.asarray(h), bits, blocksize=blocksize, row_tile=8
    )
    codes_r, scales_r, zeros_r, wq_r = ref.gptq_ref(w, h, bits, blocksize=blocksize)
    np.testing.assert_array_equal(np.asarray(codes), codes_r)
    np.testing.assert_allclose(np.asarray(scales), scales_r, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(zeros), zeros_r, atol=0)
    np.testing.assert_allclose(np.asarray(wq), wq_r, atol=2e-4, rtol=1e-4)


@given(seed=st.integers(0, 2**31), groupsize=st.sampled_from([8, 16]))
def test_graph_matches_ref_grouped(seed, groupsize):
    w, h, _ = _case(seed, 8, 32)
    codes, scales, zeros, wq = gptq_quantize_layer(
        jnp.asarray(w), jnp.asarray(h), 3, blocksize=16, groupsize=groupsize, row_tile=8
    )
    codes_r, scales_r, zeros_r, wq_r = ref.gptq_ref(w, h, 3, 16, groupsize)
    np.testing.assert_array_equal(np.asarray(codes), codes_r)
    np.testing.assert_allclose(np.asarray(scales), scales_r, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(wq), wq_r, atol=2e-4, rtol=1e-4)


def test_blocking_is_exact():
    """Paper Step 2: blocking batches memory traffic but does NOT change the
    result — blocked and unblocked solves must agree."""
    w, h, _ = _case(5, 8, 64)
    full = ref.gptq_ref(w, h, 4, blocksize=64)
    blocked = ref.gptq_ref(w, h, 4, blocksize=8)
    np.testing.assert_allclose(full[3], blocked[3], atol=1e-6)
    np.testing.assert_array_equal(full[0], blocked[0])


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_gptq_beats_rtn_on_correlated_inputs(bits):
    """The paper's core claim at layer level: second-order compensation
    strictly reduces ||WX − ŴX||² vs round-to-nearest when inputs are
    correlated (averaged over several draws)."""
    wins, ratio = 0, []
    for seed in range(5):
        w, h, x = _case(100 + seed, 32, 64)
        _, _, _, wq_g = ref.gptq_ref(w, h, bits)
        _, _, _, wq_r = ref.rtn_ref(w, bits)
        eg = ref.layer_sq_error(w, wq_g, x)
        er = ref.layer_sq_error(w, wq_r, x)
        wins += eg < er
        ratio.append(eg / er)
    assert wins >= 4, f"GPTQ won only {wins}/5 (ratios {ratio})"
    assert np.mean(ratio) < 0.9


def test_grouping_reduces_error_at_2bit():
    """Table 6's mechanism: finer groups → lower quantization error."""
    w, h, x = _case(7, 16, 64, outliers=4)
    errs = []
    for g in (0, 32, 16, 8):
        _, _, _, wq = ref.gptq_ref(w, h, 2, groupsize=g)
        errs.append(ref.layer_sq_error(w, wq, x))
    assert errs[-1] < errs[0], errs


def test_rtn_layer_matches_ref():
    w, _, _ = _case(9, 8, 32)
    for g in (0, 8):
        q, s, z, wq = rtn_quantize_layer(jnp.asarray(w), 4, g)
        q_r, s_r, z_r, wq_r = ref.rtn_ref(w, 4, g)
        np.testing.assert_array_equal(np.asarray(q), q_r)
        np.testing.assert_allclose(np.asarray(wq), wq_r, atol=1e-6)


def test_dead_columns_handled():
    """Zero-variance input dims (dead units, cf. the OPT-66B footnote) must
    not produce NaNs and their weights must quantize to exactly 0."""
    rng = np.random.default_rng(3)
    w = rng.normal(size=(8, 16)).astype(np.float32)
    x = correlated_inputs(rng, 64, 16, outliers=0)
    x[:, [3, 7]] = 0.0
    h = ref.hessian_ref(x)
    codes, scales, zeros, wq = ref.gptq_ref(w, h, 4)
    assert np.isfinite(wq).all()
    np.testing.assert_allclose(wq[:, [3, 7]], 0.0, atol=1e-6)


def test_rounding_idempotent_on_fixed_grid():
    """Fixed point at grid level: re-quantizing dequantized values against
    the SAME grid reproduces the codes exactly (RTN is a projection)."""
    w, _, _ = _case(13, 8, 32)
    codes, scales, zeros, wq = ref.rtn_ref(w, 4)
    q2, dq2 = ref.quantize_col(wq, scales[:, :1], zeros[:, :1], 4)
    np.testing.assert_array_equal(q2, codes)
    np.testing.assert_allclose(dq2, wq, atol=0)
