"""L2 model: shapes, causality, training step, serialization round-trip,
and the packed-kernel quantized forward parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.gptq_layer import rtn_quantize_layer
from compile.kernels import ref

CFG = M.ModelConfig("test", d_model=32, n_layers=2, n_heads=2, d_ff=64, max_seq=32)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_fwd_shapes(params):
    tokens = jnp.zeros((2, 16), jnp.int32)
    logits = M.fwd(CFG, params, tokens)
    assert logits.shape == (2, 16, 256)
    assert np.isfinite(np.asarray(logits)).all()


def test_causality(params):
    """Changing a future token must not change past logits."""
    rng = np.random.default_rng(0)
    t1 = rng.integers(0, 256, size=(1, 16)).astype(np.int32)
    t2 = t1.copy()
    t2[0, 10:] = (t2[0, 10:] + 1) % 256
    l1 = np.asarray(M.fwd(CFG, params, jnp.asarray(t1)))
    l2 = np.asarray(M.fwd(CFG, params, jnp.asarray(t2)))
    np.testing.assert_allclose(l1[0, :10], l2[0, :10], atol=1e-5)
    assert np.abs(l1[0, 10:] - l2[0, 10:]).max() > 1e-4


def test_block_capture_shapes(params):
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 8, 32)), jnp.float32)
    y, caps = M.block_capture(CFG, params["blocks"][0], x)
    assert y.shape == x.shape
    assert caps["wqkv"].shape == (2, 8, 32)
    assert caps["wo"].shape == (2, 8, 32)
    assert caps["wup"].shape == (2, 8, 32)
    assert caps["wdn"].shape == (2, 8, 64)


def test_capture_feeds_correct_hessian(params):
    """The captured tensor for a linear must be exactly the input that
    multiplies its weight — verified by recomputing the layer output."""
    blk = params["blocks"][0]
    x = jnp.asarray(np.random.default_rng(1).normal(size=(1, 4, 32)), jnp.float32)
    _, caps = M.block_capture(CFG, blk, x)
    qkv = caps["wqkv"] @ blk["wqkv"].T + blk["wqkv_b"]
    assert qkv.shape == (1, 4, 96)


def test_loss_decreases():
    cfg = CFG
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, 64, size=(8, 17)).astype(np.int32))
    loss0 = float(M.loss_fn(cfg, params, tokens))

    grad = jax.grad(lambda p: M.loss_fn(cfg, p, tokens))(params)
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g, params, grad)
    loss1 = float(M.loss_fn(cfg, params2, tokens))
    assert loss1 < loss0
    assert loss0 == pytest.approx(np.log(256), rel=0.3)  # near-uniform init


def test_flat_roundtrip(params):
    flat = M.params_to_flat(CFG, params)
    back = M.flat_to_params(CFG, flat)
    tokens = jnp.zeros((1, 8), jnp.int32)
    np.testing.assert_allclose(
        np.asarray(M.fwd(CFG, params, tokens)),
        np.asarray(M.fwd(CFG, back, tokens)),
        atol=1e-6,
    )


def test_tensor_index_covers_params(params):
    flat = M.params_to_flat(CFG, params)
    total = sum(a.size for a in flat.values())
    assert total == CFG.n_params()


def test_quant_fwd_matches_dense_dequant(params):
    """quant_fwd (packed weights through the L1 kernel) must equal the plain
    fwd run on dequantized dense weights — the kernel-path parity check."""
    bits = 4
    qparams = []
    dq_params = jax.tree.map(lambda x: x, params)  # shallow-ish copy
    dq_blocks = []
    for blk in params["blocks"]:
        qblk, dblk = {}, dict(blk)
        for nm in M.QUANT_LINEARS:
            w = np.asarray(blk[nm])
            codes, scales, zeros, wq = ref.rtn_ref(w, bits, 0)
            qblk[nm] = {
                "words": jnp.asarray(ref.pack_codes(codes, bits)),
                "scales": jnp.asarray(scales),
                "zeros": jnp.asarray(zeros),
            }
            dblk[nm] = jnp.asarray(wq)
        qparams.append(qblk)
        dq_blocks.append(dblk)
    dq_params = dict(params)
    dq_params["blocks"] = dq_blocks

    tokens = jnp.asarray(np.random.default_rng(2).integers(0, 256, (1, 8)).astype(np.int32))
    lq = np.asarray(M.quant_fwd(CFG, params, qparams, tokens, bits))
    ld = np.asarray(M.fwd(CFG, dq_params, tokens))
    np.testing.assert_allclose(lq, ld, atol=2e-3, rtol=1e-3)


def test_configs_sane():
    for name, cfg in M.CONFIGS.items():
        assert cfg.d_model % cfg.n_heads == 0
        assert cfg.name == name
        shapes = cfg.linear_shapes()
        assert shapes["wqkv"] == (3 * cfg.d_model, cfg.d_model)
        assert cfg.n_params() > 0
