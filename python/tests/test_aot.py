"""AOT lowering: every entry-point family lowers to parseable HLO text and
executes correctly when reloaded through the XLA client (the same pathway
the Rust runtime uses)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.aot import (
    BLOCK_TENSORS,
    block_example_args,
    make_block_capture,
    make_embed,
    make_head,
    make_lm_fwd,
    to_hlo_text,
)
from compile.gptq_layer import gptq_quantize_layer
from compile.kernels import ref
from compile.kernels.hessian import hessian

CFG = M.ModelConfig("t", d_model=16, n_layers=1, n_heads=2, d_ff=32, max_seq=16)


def roundtrip_exec(fn, args):
    """Lower → HLO text → re-parse through the XLA text parser (the exact
    ingestion path of the Rust runtime), and check parameter/result shapes
    survive. Numeric execution of text-parsed modules is covered by the
    Rust integration tests (rust/tests/runtime_integration.rs) — this
    jaxlib build exposes no Python API to execute a round-tripped module.
    The direct jax execution below guards numerical sanity of the graph."""
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    mod = xc._xla.hlo_module_from_text(text)  # raises on parse failure
    reparsed = mod.to_string()
    # one parameter instruction per argument in the ENTRY computation
    # (nested/fused computations have their own parameters — skip them)
    entry = reparsed[reparsed.rindex("ENTRY ") :]
    entry = entry[: entry.index("\n}")]
    assert entry.count("parameter(") == len(jax.tree.leaves(args))
    direct = fn(*args)
    for leaf in jax.tree.leaves(direct):
        assert np.isfinite(np.asarray(leaf)).all()
    return text


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def _flat(params):
    flat = M.params_to_flat(CFG, params)
    return [jnp.asarray(flat[n]) for n, _ in M.tensor_index(CFG)]


def test_lm_fwd_roundtrip(params):
    tokens = jnp.asarray(np.random.default_rng(0).integers(0, 256, (2, 8)).astype(np.int32))
    roundtrip_exec(make_lm_fwd(CFG), [tokens, *_flat(params)])


def test_embed_roundtrip(params):
    tokens = jnp.asarray(np.random.default_rng(1).integers(0, 256, (2, 8)).astype(np.int32))
    roundtrip_exec(make_embed(CFG), [tokens, params["embed"], params["pos"]])


def test_block_capture_roundtrip(params):
    blk = params["blocks"][0]
    args = [jnp.asarray(np.random.default_rng(2).normal(size=(2, 8, 16)), jnp.float32)]
    args += [blk[nm] for nm in BLOCK_TENSORS]
    roundtrip_exec(make_block_capture(CFG), args)


def test_head_roundtrip(params):
    x = jnp.asarray(np.random.default_rng(3).normal(size=(2, 8, 16)), jnp.float32)
    roundtrip_exec(
        make_head(CFG), [x, params["lnf_g"], params["lnf_b"], params["unembed"]]
    )


def test_gptq_layer_roundtrip():
    rng = np.random.default_rng(4)
    w = rng.normal(size=(16, 32)).astype(np.float32)
    x = rng.normal(size=(64, 32)).astype(np.float32)
    h = ref.hessian_ref(x)

    def fn(w, h):
        return gptq_quantize_layer(w, h, 4, blocksize=16, row_tile=8)

    text = roundtrip_exec(fn, [jnp.asarray(w), jnp.asarray(h)])
    # the unrolled blocked solve must still be a single HLO module
    assert text.count("ENTRY") == 1


def test_hessian_roundtrip():
    x = jnp.asarray(np.random.default_rng(5).normal(size=(64, 16)), jnp.float32)
    roundtrip_exec(lambda x: (hessian(x, n_tile=32),), [x])


def test_block_example_args_match_signature():
    args = block_example_args(CFG)
    assert len(args) == 1 + len(BLOCK_TENSORS)
    assert args[0].shape[-1] == CFG.d_model
