"""L1 Pallas kernels vs the numpy oracles in kernels/ref.py.

hypothesis sweeps shapes/bits/tilings; codes must match bit-exactly,
floats to tolerance. This is the CORE correctness signal for the kernels
that get lowered into the AOT artifacts.
"""

import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # property sweeps need hypothesis
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gptq import gptq_block
from compile.kernels.hessian import hessian
from compile.kernels.packmatvec import packmatvec
from compile.kernels.rtn import rtn

from conftest import correlated_inputs

BITS = st.sampled_from([2, 3, 4])
settings.register_profile("kernels", deadline=None, max_examples=12)
settings.load_profile("kernels")


def _case(seed, drow, dcol):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(drow, dcol)).astype(np.float32)
    x = correlated_inputs(rng, 4 * dcol, dcol)
    return w, ref.hessian_ref(x), x


# -- gptq block kernel -------------------------------------------------------

@given(
    seed=st.integers(0, 2**31),
    bits=BITS,
    drow=st.sampled_from([4, 8, 16]),
    dcol=st.sampled_from([8, 16, 32]),
)
def test_gptq_block_matches_ref(seed, bits, drow, dcol):
    w, h, _ = _case(seed, drow, dcol)
    u, wf = ref.prepare_hinv_cholesky(h, w)
    s, z = ref.quant_params(w, bits)
    q, wq, err = gptq_block(
        jnp.asarray(w), jnp.asarray(u), jnp.asarray(s), jnp.asarray(z), bits,
        row_tile=drow // 2,
    )
    codes_r, _, _, wq_r = ref.gptq_ref(w, h, bits, blocksize=dcol)
    np.testing.assert_array_equal(np.asarray(q), codes_r)
    np.testing.assert_allclose(np.asarray(wq), wq_r, atol=1e-5, rtol=1e-5)


def test_gptq_block_err_columns_consistent(rng):
    """err[:, j] must equal (w_updated − ŵ)/U[j,j] — checked via the
    invariant that applying err to the tail reproduces the ref's multi-block
    result (exercised end-to-end in test_gptq_layer)."""
    w, h, _ = _case(3, 8, 16)
    u, _ = ref.prepare_hinv_cholesky(h, w)
    s, z = ref.quant_params(w, 4)
    q, wq, err = gptq_block(jnp.asarray(w), jnp.asarray(u), jnp.asarray(s), jnp.asarray(z), 4, row_tile=8)
    assert np.isfinite(np.asarray(err)).all()
    # last column's error never compensates anything but must still be emitted
    assert np.abs(np.asarray(err)[:, -1]).sum() > 0


def test_gptq_block_row_tile_invariance():
    w, h, _ = _case(11, 16, 16)
    u, _ = ref.prepare_hinv_cholesky(h, w)
    s, z = ref.quant_params(w, 3)
    outs = [
        gptq_block(jnp.asarray(w), jnp.asarray(u), jnp.asarray(s), jnp.asarray(z), 3, row_tile=t)
        for t in (4, 8, 16)
    ]
    for q, wq, err in outs[1:]:
        np.testing.assert_array_equal(np.asarray(q), np.asarray(outs[0][0]))
        np.testing.assert_allclose(np.asarray(err), np.asarray(outs[0][2]), atol=1e-6)


# -- rtn kernel ---------------------------------------------------------------

@given(
    seed=st.integers(0, 2**31),
    bits=BITS,
    groupsize=st.sampled_from([0, 8, 16]),
)
def test_rtn_matches_ref(seed, bits, groupsize):
    drow, dcol = 8, 32
    w, _, _ = _case(seed, drow, dcol)
    codes, scales, zeros, wq = ref.rtn_ref(w, bits, groupsize)
    qk, wqk = rtn(jnp.asarray(w), jnp.asarray(scales), jnp.asarray(zeros), bits, groupsize, row_tile=4)
    np.testing.assert_array_equal(np.asarray(qk), codes)
    np.testing.assert_allclose(np.asarray(wqk), wq, atol=1e-6)


# -- hessian kernel -------------------------------------------------------------

@given(seed=st.integers(0, 2**31), n_tile=st.sampled_from([16, 32, 64]))
def test_hessian_matches_ref(seed, n_tile):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(64, 24)).astype(np.float32)
    h = np.asarray(hessian(jnp.asarray(x), n_tile=n_tile))
    np.testing.assert_allclose(h, ref.hessian_ref(x), rtol=1e-4, atol=1e-4)


def test_hessian_psd(rng):
    x = rng.normal(size=(128, 16)).astype(np.float32)
    h = np.asarray(hessian(jnp.asarray(x), n_tile=32))
    eig = np.linalg.eigvalsh(h.astype(np.float64))
    assert eig.min() > -1e-3


# -- packmatvec kernel -----------------------------------------------------------

@given(
    seed=st.integers(0, 2**31),
    bits=BITS,
    groupsize=st.sampled_from([0, 8]),
)
def test_packmatvec_matches_ref(seed, bits, groupsize):
    rng = np.random.default_rng(seed)
    drow, dcol = 16, 32
    w = rng.normal(size=(drow, dcol)).astype(np.float32)
    codes, scales, zeros, _ = ref.rtn_ref(w, bits, groupsize)
    words = ref.pack_codes(codes, bits)
    x = rng.normal(size=(dcol,)).astype(np.float32)
    y_ref = ref.packmatvec_ref(words, scales, zeros, x, bits, groupsize)
    y = packmatvec(
        jnp.asarray(words), jnp.asarray(scales), jnp.asarray(zeros),
        jnp.asarray(x), bits, groupsize, row_tile=8,
    )
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)


def test_packmatvec_equals_dense_dequant(rng):
    """Kernel result == dense Ŵ@x computed without packing."""
    drow, dcol, bits = 8, 16, 4
    w = rng.normal(size=(drow, dcol)).astype(np.float32)
    codes, scales, zeros, wq = ref.rtn_ref(w, bits, 0)
    words = ref.pack_codes(codes, bits)
    x = rng.normal(size=(dcol,)).astype(np.float32)
    y = packmatvec(jnp.asarray(words), jnp.asarray(scales), jnp.asarray(zeros), jnp.asarray(x), bits, row_tile=8)
    np.testing.assert_allclose(np.asarray(y), wq @ x, rtol=1e-4, atol=1e-4)
