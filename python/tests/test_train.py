"""Build-time training loop: optimizer correctness and data plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import train as T
from compile import model as M


def test_adam_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = T.adam_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, opt = T.adam_update(params, grads, opt, lr=0.1)
    assert float(loss(params)) < 1e-3


def test_adam_bias_correction_first_step():
    """After one step from zero moments, the update magnitude must be ≈ lr
    (the whole point of bias correction)."""
    params = {"w": jnp.asarray([1.0])}
    opt = T.adam_init(params)
    grads = {"w": jnp.asarray([0.5])}
    new, _ = T.adam_update(params, grads, opt, lr=0.01)
    step = float(params["w"][0] - new["w"][0])
    assert step == pytest.approx(0.01, rel=1e-3)


def test_sample_batch_shape_and_range():
    rng = np.random.default_rng(0)
    data = np.arange(10_000, dtype=np.int32) % 256
    batch = T.sample_batch(rng, data, batch=4, seq=32)
    assert batch.shape == (4, 33)  # seq + 1 target byte
    assert batch.min() >= 0 and batch.max() < 256


def test_sample_batch_deterministic_with_seed():
    data = np.arange(10_000, dtype=np.int32) % 256
    a = T.sample_batch(np.random.default_rng(7), data, 4, 16)
    b = T.sample_batch(np.random.default_rng(7), data, 4, 16)
    np.testing.assert_array_equal(a, b)


def test_train_plan_covers_all_configs():
    for name in M.CONFIGS:
        assert name in T.TRAIN_PLAN, f"no training plan for {name}"


def test_one_training_step_decreases_loss(tmp_path):
    """Micro smoke-run of the real loop: 8 steps on a tiny model must beat
    the initial loss."""
    from compile.corpus import build_corpus

    build_corpus(tmp_path, train_bytes=60_000, eval_bytes=2048, n_tasks=5)
    cfg = M.ModelConfig("t", d_model=16, n_layers=1, n_heads=2, d_ff=32, max_seq=32)
    data = T.load_tokens(tmp_path, "train.bin")
    rng = np.random.default_rng(0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = T.adam_init(params)
    tokens0 = jnp.asarray(T.sample_batch(rng, data, 8, 31))
    loss0 = float(M.loss_fn(cfg, params, tokens0))
    for _ in range(8):
        tokens = jnp.asarray(T.sample_batch(rng, data, 8, 31))
        loss, grads = jax.value_and_grad(lambda p: M.loss_fn(cfg, p, tokens))(params)
        params, opt = T.adam_update(params, grads, opt, lr=3e-3)
    loss1 = float(M.loss_fn(cfg, params, tokens0))
    assert loss1 < loss0
