import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


def correlated_inputs(rng, n, dcol, outliers=2, outlier_scale=8.0):
    """Calibration-like inputs: correlated features + outlier dims (the
    activation-outlier regime LLM.int8()/GPTQ discuss)."""
    mix = rng.normal(size=(dcol, dcol)).astype(np.float32) / np.sqrt(dcol)
    x = rng.normal(size=(n, dcol)).astype(np.float32) @ mix
    if outliers:
        idx = rng.integers(0, dcol, outliers)
        x[:, idx] *= outlier_scale
    return x.astype(np.float32)
