//! # gptq-rs — GPTQ (Frantar et al., 2022) in Rust + JAX + Pallas
//!
//! A three-layer reproduction of *GPTQ: Accurate Post-Training Quantization
//! for Generative Pre-trained Transformers*:
//!
//! * **L1** (Pallas, build-time): the blocked GPTQ column solver and the
//!   packed dequantizing matvec kernel (`python/compile/kernels/`), lowered
//!   into the HLO artifacts this crate executes.
//! * **L2** (JAX, build-time): the transformer LM family, the per-layer
//!   quantization graph, and the AOT export (`python/compile/`).
//! * **L3** (this crate): the coordinator — calibration streaming, Hessian
//!   accumulation, block-by-block quantization with quantized-input
//!   propagation, packed checkpoints, perplexity / zero-shot evaluation,
//!   and a continuous-batching generation server (paged KV pool,
//!   iteration-level scheduling) with a quantized hot path.
//!
//! Python never runs on the request path: `make artifacts` is the only
//! Python invocation; afterwards the `gptq` binary is self-contained.
//!
//! Module map (see DESIGN.md for the paper-experiment index):
//!
//! * [`quant`] — grids, RTN, OBQ (the baseline GPTQ descends from), the
//!   GPTQ solver itself, f64 Cholesky linear algebra, bit packing.
//! * [`model`] — tensors, checkpoints (dense + packed), the pure-Rust
//!   transformer forward (the serving hot path) and its packed matvec.
//! * [`data`] — corpus access, calibration sampling, zero-shot task files.
//! * [`eval`] — perplexity and zero-shot accuracy harnesses.
//! * [`runtime`] — the pluggable execution backend (`ExecBackend`): the
//!   pure-Rust reference engine (default, runs everywhere) and, under
//!   `--features pjrt`, the PJRT client that loads
//!   `artifacts/hlo/*.hlo.txt` (HLO **text**; see
//!   /opt/xla-example/README.md for why not protos), compiles once, and
//!   executes from the pipeline. DESIGN.md §Backends has the full story.
//! * [`coordinator`] — the quantization pipeline and the serving stack
//!   (router, continuous-batching scheduler, paged KV pool, metrics).

pub mod coordinator;
pub mod data;
pub mod eval;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod tables;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Root of the artifact tree produced by `make artifacts`. Overridable for
/// tests and deployments via `GPTQ_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("GPTQ_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
