//! Perplexity evaluation — `exp(mean NLL per byte)` over non-overlapping
//! segments, the protocol behind every perplexity table in the paper
//! (Tables 2–4, 10–13; Figure 1).

use super::log_prob;
use crate::data::CorpusFile;
use crate::model::CpuModel;
use crate::runtime::client::{literal_f32, literal_i32, to_vec_f32};
use crate::runtime::Runtime;
use crate::Result;

/// Perplexity of a CPU model (dense or packed) over a corpus.
/// `max_segments` bounds the work (the tables use 24–64 segments).
pub fn perplexity(model: &mut CpuModel, corpus: &CorpusFile, seq_len: usize, max_segments: usize) -> f64 {
    let vocab = model.config.vocab;
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for seg in corpus.eval_segments(seq_len, max_segments) {
        let inputs = &seg[..seq_len];
        let targets = &seg[1..];
        let logits = model.logits_all(inputs);
        for (pos, &t) in targets.iter().enumerate() {
            nll -= log_prob(&logits[pos * vocab..(pos + 1) * vocab], t as usize);
            count += 1;
        }
    }
    (nll / count as f64).exp()
}

/// Perplexity via the XLA `lm_fwd_<size>` artifact — the fast batched path
/// (and the L2-graph parity check for the CPU forward). `weights` must be
/// the flattened tensor literals in manifest order.
pub fn perplexity_xla(
    rt: &mut Runtime,
    size: &str,
    weights: &[xla::Literal],
    corpus: &CorpusFile,
    max_batches: usize,
) -> Result<f64> {
    let seq = rt.manifest.seq_len;
    let batch = rt.manifest.eval_batch;
    let vocab = 256usize;
    let segs = corpus.eval_segments(seq, max_batches * batch);
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for chunk in segs.chunks(batch) {
        if chunk.len() < batch {
            break;
        }
        let tokens: Vec<i32> = chunk.iter().flat_map(|s| s[..seq].iter().map(|&b| b as i32)).collect();
        let mut inputs = vec![literal_i32(&tokens, &[batch, seq])?];
        for w in weights {
            inputs.push(w.clone());
        }
        let out = rt.execute(&format!("lm_fwd_{size}"), &inputs)?;
        let logits = to_vec_f32(&out[0])?;
        for (bi, seg) in chunk.iter().enumerate() {
            for pos in 0..seq - 1 {
                let target = seg[pos + 1] as usize;
                let off = (bi * seq + pos) * vocab;
                nll -= log_prob(&logits[off..off + vocab], target);
                count += 1;
            }
        }
    }
    Ok((nll / count as f64).exp())
}

/// Helper for literal reuse across executions (xla::Literal is not Clone;
/// re-marshal from f32).
pub fn weight_literals(
    tensors: &[(Vec<f32>, Vec<usize>)],
) -> Result<Vec<xla::Literal>> {
    tensors.iter().map(|(d, s)| literal_f32(d, s)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::tiny_checkpoint;
    use crate::model::CpuModel;

    #[test]
    fn random_model_near_uniform_ppl() {
        let ckpt = tiny_checkpoint(1);
        let mut m = CpuModel::from_checkpoint(&ckpt);
        let corpus = CorpusFile { bytes: (0..2048u32).map(|i| (i % 32) as u8).collect(), name: "t".into() };
        let ppl = perplexity(&mut m, &corpus, 15, 4);
        // untrained tiny model on vocab-32 bytes: ppl should be within an
        // order of magnitude of uniform (32) and strictly > 1
        assert!(ppl > 1.0 && ppl < 400.0, "ppl {ppl}");
    }

    #[test]
    fn ppl_deterministic_and_segment_count_sensitive() {
        let ckpt = tiny_checkpoint(2);
        let mut m = CpuModel::from_checkpoint(&ckpt);
        let corpus = CorpusFile { bytes: (0..4096u32).map(|i| (i % 29) as u8).collect(), name: "c".into() };
        let a = perplexity(&mut m, &corpus, 15, 4);
        let b = perplexity(&mut m, &corpus, 15, 4);
        assert_eq!(a, b, "perplexity must be deterministic");
        assert!(a > 1.0);
        // different coverage -> (generally) different estimate, never NaN
        let c = perplexity(&mut m, &corpus, 15, 8);
        assert!(c.is_finite());
    }
}
