//! Perplexity evaluation — `exp(mean NLL per byte)` over non-overlapping
//! segments, the protocol behind every perplexity table in the paper
//! (Tables 2–4, 10–13; Figure 1).

use super::log_prob;
use crate::data::CorpusFile;
use crate::model::{Checkpoint, CpuModel};
use crate::runtime::{Runtime, Value};
use crate::util::par::{self, Pool};
use crate::Result;

/// NLL of one evaluation segment (`seq_len + 1` bytes: inputs + targets).
fn segment_nll(model: &mut CpuModel, seg: &[u8], seq_len: usize, vocab: usize) -> f64 {
    let inputs = &seg[..seq_len];
    let targets = &seg[1..];
    let logits = model.logits_all(inputs);
    let mut nll = 0.0f64;
    for (pos, &t) in targets.iter().enumerate() {
        nll -= log_prob(&logits[pos * vocab..(pos + 1) * vocab], t as usize);
    }
    nll
}

/// Perplexity of a CPU model (dense or packed) over a corpus.
/// `max_segments` bounds the work (the tables use 24–64 segments).
///
/// Segments are scored independently (each worker clones the model —
/// decode state is per-instance) into per-segment NLL subtotals reduced
/// in segment order, so the result is bit-identical at every thread
/// count. (The subtotal-then-reduce shape is also what the serial path
/// computes; it differs from the historical single-accumulator fold only
/// at f64 rounding level.)
pub fn perplexity(model: &mut CpuModel, corpus: &CorpusFile, seq_len: usize, max_segments: usize) -> f64 {
    let vocab = model.config.vocab;
    let segs = corpus.eval_segments(seq_len, max_segments);
    let mut seg_nll = vec![0.0f64; segs.len()];
    let pool = Pool::global();
    if pool.nthreads() > 1 && segs.len() > 1 {
        let parts = par::SliceParts::new(&mut seg_nll);
        let proto: &CpuModel = model;
        let segs_ref: &[&[u8]] = &segs;
        pool.run_with(
            segs_ref.len(),
            || {
                // segment workers already saturate the pool: pin their
                // decode matvecs to the serial kernels (bit-identical) so
                // every matvec doesn't nest another thread scope
                let mut m = proto.clone();
                m.set_serial_kernels(true);
                m
            },
            |m, j| {
                let nll = segment_nll(m, segs_ref[j], seq_len, vocab);
                // SAFETY: each job owns exactly slot j
                unsafe { parts.range(j..j + 1)[0] = nll };
            },
        );
    } else {
        for (j, seg) in segs.iter().enumerate() {
            seg_nll[j] = segment_nll(model, seg, seq_len, vocab);
        }
    }
    let nll: f64 = seg_nll.iter().sum(); // fixed segment-order reduction
    let count = segs.len() * seq_len; // one target per input position
    (nll / count as f64).exp()
}

/// Perplexity via the `lm_fwd_<size>` artifact contract on the runtime's
/// execution backend — the batched path, and the graph-parity check for
/// the CPU forward (reference backend: same math, different code path;
/// PJRT backend: the lowered L2 graph).
///
/// Evaluates the same segment/target protocol as [`perplexity`], so the
/// two are directly comparable (see `coordinator::serve::verify_parity`).
pub fn perplexity_artifact(
    rt: &mut Runtime,
    size: &str,
    ckpt: &Checkpoint,
    corpus: &CorpusFile,
    max_batches: usize,
) -> Result<f64> {
    let seq = rt.manifest.seq_len;
    let batch = rt.manifest.eval_batch;
    let entry = rt.manifest.model(size)?;
    let vocab = entry.config.vocab;
    // inputs built ONCE: tokens placeholder + weight values in manifest
    // tensor order (the AOT parameter order); only the tokens slot is
    // rewritten per batch — the weights are multi-MB and never change
    let mut inputs = Vec::with_capacity(1 + entry.tensors.len());
    inputs.push(Value::i32(vec![0; batch * seq], &[batch, seq])?);
    for t in &entry.tensors {
        let tensor = ckpt.get(&t.name);
        inputs.push(Value::f32(tensor.data.clone(), &tensor.shape)?);
    }
    let name = format!("lm_fwd_{size}");

    let segs = corpus.eval_segments(seq, max_batches * batch);
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for chunk in segs.chunks(batch) {
        if chunk.len() < batch {
            break;
        }
        let tokens: Vec<i32> =
            chunk.iter().flat_map(|s| s[..seq].iter().map(|&b| b as i32)).collect();
        inputs[0] = Value::i32(tokens, &[batch, seq])?;
        let out = rt.execute(&name, &inputs)?;
        anyhow::ensure!(!out.is_empty(), "{name} returned no outputs");
        let logits = out.into_iter().next().unwrap().into_f32()?;
        for (bi, seg) in chunk.iter().enumerate() {
            // same targets as `perplexity`: every position of the segment
            // (segments carry seq_len + 1 bytes)
            for pos in 0..seq {
                let target = seg[pos + 1] as usize;
                let off = (bi * seq + pos) * vocab;
                nll -= log_prob(&logits[off..off + vocab], target);
                count += 1;
            }
        }
    }
    anyhow::ensure!(count > 0, "no full evaluation batches (corpus too small?)");
    Ok((nll / count as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::{tiny_checkpoint, tiny_corpus, tiny_manifest, TINY_SIZE};
    use crate::model::CpuModel;

    #[test]
    fn random_model_near_uniform_ppl() {
        let ckpt = tiny_checkpoint(1);
        let mut m = CpuModel::from_checkpoint(&ckpt);
        let corpus = CorpusFile { bytes: (0..2048u32).map(|i| (i % 32) as u8).collect(), name: "t".into() };
        let ppl = perplexity(&mut m, &corpus, 15, 4);
        // untrained tiny model on vocab-32 bytes: ppl should be within an
        // order of magnitude of uniform (32) and strictly > 1
        assert!(ppl > 1.0 && ppl < 400.0, "ppl {ppl}");
    }

    #[test]
    fn ppl_deterministic_and_segment_count_sensitive() {
        let ckpt = tiny_checkpoint(2);
        let mut m = CpuModel::from_checkpoint(&ckpt);
        let corpus = CorpusFile { bytes: (0..4096u32).map(|i| (i % 29) as u8).collect(), name: "c".into() };
        let a = perplexity(&mut m, &corpus, 15, 4);
        let b = perplexity(&mut m, &corpus, 15, 4);
        assert_eq!(a, b, "perplexity must be deterministic");
        assert!(a > 1.0);
        // different coverage -> (generally) different estimate, never NaN
        let c = perplexity(&mut m, &corpus, 15, 8);
        assert!(c.is_finite());
    }

    #[test]
    fn artifact_ppl_matches_cpu_ppl() {
        // The lm_fwd contract on the reference backend and the KV-cached
        // CPU decode must produce (near-)identical perplexity.
        let (seq, batch) = (12usize, 2usize);
        let mut rt = Runtime::new(tiny_manifest(seq, batch)).unwrap();
        let ckpt = tiny_checkpoint(4);
        let corpus = tiny_corpus(seq.max(16) * 40, 5);
        let mut m = CpuModel::from_checkpoint(&ckpt);
        let batches = 2usize;
        let ppl_cpu = perplexity(&mut m, &corpus, seq, batches * batch);
        let ppl_art = perplexity_artifact(&mut rt, TINY_SIZE, &ckpt, &corpus, batches).unwrap();
        let rel = (ppl_cpu - ppl_art).abs() / ppl_art;
        assert!(rel < 1e-3, "cpu {ppl_cpu} vs artifact {ppl_art} (rel {rel})");
    }
}
