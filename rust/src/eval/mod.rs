//! Evaluation harnesses: perplexity (the paper's primary metric — "known
//! to be a very stringent accuracy metric") and the zero-shot task suite.

pub mod ppl;
pub mod zeroshot;

pub use ppl::{perplexity, perplexity_artifact};
pub use zeroshot::{eval_choice, eval_cloze};

/// log-softmax at one position; returns log p(target).
pub fn log_prob(logits: &[f32], target: usize) -> f64 {
    let maxv = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mut denom = 0.0f64;
    for &l in logits {
        denom += ((l as f64) - maxv).exp();
    }
    (logits[target] as f64 - maxv) - denom.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_prob_uniform() {
        let logits = vec![0.0f32; 4];
        assert!((log_prob(&logits, 2) - (0.25f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn log_prob_peaked() {
        let mut logits = vec![0.0f32; 4];
        logits[1] = 100.0;
        assert!(log_prob(&logits, 1) > -1e-6);
        assert!(log_prob(&logits, 0) < -50.0);
    }

    #[test]
    fn log_prob_shift_invariant() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [11.0f32, 12.0, 13.0];
        assert!((log_prob(&a, 0) - log_prob(&b, 0)).abs() < 1e-6);
    }
}
