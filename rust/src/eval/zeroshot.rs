//! Zero-shot evaluation (paper §4 Zero-Shot Tasks, Figure 4, Tables 14–23).
//!
//! * cloze (LAMBADA analog): greedy-decode the target continuation and
//!   require an exact byte match — the LAMBADA "last word prediction"
//!   protocol.
//! * choice (ARC / PIQA / StoryCloze analog): score every choice by
//!   length-normalized log-likelihood of its bytes given the context;
//!   accuracy = fraction where the labeled answer wins.

use super::log_prob;
use crate::data::TaskItem;
use crate::model::{CpuModel, KvCache};

/// Greedy exact-match accuracy on cloze items.
pub fn eval_cloze(model: &mut CpuModel, items: &[TaskItem], max_items: usize) -> f64 {
    let mut correct = 0usize;
    let mut total = 0usize;
    for item in items.iter().take(max_items) {
        let Some(target) = &item.target else { continue };
        let ctx = item.context.as_bytes();
        let tgt = target.as_bytes();
        if ctx.len() + tgt.len() >= model.config.max_seq {
            continue;
        }
        let mut cache = KvCache::new(&model.config);
        let mut logits: Vec<f32> = Vec::new();
        for &b in ctx {
            logits = model.decode_step(&mut cache, b).to_vec();
        }
        let mut ok = true;
        for &want in tgt {
            let pred = argmax(&logits) as u8;
            if pred != want {
                ok = false;
                break;
            }
            logits = model.decode_step(&mut cache, want).to_vec();
        }
        correct += ok as usize;
        total += 1;
    }
    if total == 0 {
        return 0.0;
    }
    correct as f64 / total as f64
}

/// Length-normalized likelihood choice accuracy on MCQ/binary items.
pub fn eval_choice(model: &mut CpuModel, items: &[TaskItem], max_items: usize) -> f64 {
    let mut correct = 0usize;
    let mut total = 0usize;
    for item in items.iter().take(max_items) {
        if item.choices.is_empty() {
            continue;
        }
        let ctx = item.context.as_bytes();
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for (ci, choice) in item.choices.iter().enumerate() {
            let cb = choice.as_bytes();
            if ctx.len() + cb.len() >= model.config.max_seq {
                continue;
            }
            let score = continuation_logprob(model, ctx, cb) / cb.len() as f64;
            if score > best_score {
                best_score = score;
                best = ci;
            }
        }
        correct += (best == item.answer) as usize;
        total += 1;
    }
    if total == 0 {
        return 0.0;
    }
    correct as f64 / total as f64
}

/// Σ log p(continuation bytes | context) via teacher forcing.
fn continuation_logprob(model: &mut CpuModel, ctx: &[u8], cont: &[u8]) -> f64 {
    let mut cache = KvCache::new(&model.config);
    let mut logits: Vec<f32> = Vec::new();
    for &b in ctx {
        logits = model.decode_step(&mut cache, b).to_vec();
    }
    let mut lp = 0.0f64;
    for &b in cont {
        lp += log_prob(&logits, b as usize);
        logits = model.decode_step(&mut cache, b).to_vec();
    }
    lp
}

fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::tiny_checkpoint;
    use crate::model::CpuModel;

    // tiny_checkpoint has vocab 32 — keep test bytes below that
    const CTX: &str = "\u{01}\u{02}";
    const CH_A: &str = "\u{03}";
    const CH_B: &str = "\u{04}";

    fn items_choice() -> Vec<TaskItem> {
        (0..8)
            .map(|i| TaskItem {
                context: CTX.into(),
                target: None,
                choices: vec![CH_A.into(), CH_B.into()],
                answer: i % 2,
            })
            .collect()
    }

    #[test]
    fn choice_accuracy_in_unit_interval() {
        let ckpt = tiny_checkpoint(1);
        let mut m = CpuModel::from_checkpoint(&ckpt);
        let acc = eval_choice(&mut m, &items_choice(), 8);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn cloze_skips_overlong_items() {
        let ckpt = tiny_checkpoint(2);
        let mut m = CpuModel::from_checkpoint(&ckpt);
        let items = vec![TaskItem {
            context: "\u{01}".repeat(1000),
            target: Some(CH_A.into()),
            choices: vec![],
            answer: 0,
        }];
        // all items skipped -> 0.0 and no panic
        assert_eq!(eval_cloze(&mut m, &items, 10), 0.0);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[2.0]), 0);
    }

    #[test]
    fn continuation_logprob_negative() {
        let ckpt = tiny_checkpoint(3);
        let mut m = CpuModel::from_checkpoint(&ckpt);
        let lp = continuation_logprob(&mut m, &[1, 2], &[3, 4]);
        assert!(lp < 0.0);
    }
}
