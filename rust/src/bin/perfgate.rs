//! `perfgate` — the perf-regression gate (README.md §Perf gate).
//!
//! Diffs the summary metrics of freshly recorded `BENCH_*.json` files
//! against committed baselines under per-metric tolerance bands
//! (`util::bench::default_specs`), honoring each metric's direction
//! (tokens/s up is good, TTFT up is bad). Machine classes
//! (arch/ISA/cores, recorded in every bench header) must match — a NEON
//! runner is never judged against an AVX2 baseline.
//!
//! ```bash
//! perfgate --baseline-dir . --current-dir target/perfgate \
//!          --benches kernels,decode,serve [--skip-mismatch]
//! ```
//!
//! Exit codes: 0 = all gated metrics within band; 1 = at least one
//! regression; 2 = structural error (unreadable file, missing/extra
//! metric keys, machine-class mismatch). `--skip-mismatch` downgrades a
//! machine-class mismatch to a skip (exit 0 for that bench) so shared CI
//! runners of a different class stay green instead of red-herring.

use gptq_rs::util::bench::{compare, default_specs, BenchDoc};
use gptq_rs::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "usage: perfgate --baseline-dir DIR --current-dir DIR \
         [--benches kernels,decode,serve] [--skip-mismatch]"
    );
    std::process::exit(2)
}

fn main() {
    let args = Args::from_env();
    let Some(baseline_dir) = args.get("baseline-dir") else { usage() };
    let Some(current_dir) = args.get("current-dir") else { usage() };
    let benches = args.str_or("benches", "kernels,decode,serve");
    let skip_mismatch = args.flag("skip-mismatch");

    let mut regressions = 0usize;
    let mut errors = 0usize;
    for bench in benches.split(',').map(str::trim).filter(|b| !b.is_empty()) {
        let file = format!("BENCH_{bench}.json");
        let baseline = match BenchDoc::load(&format!("{baseline_dir}/{file}")) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("perfgate: baseline {e}");
                errors += 1;
                continue;
            }
        };
        let current = match BenchDoc::load(&format!("{current_dir}/{file}")) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("perfgate: current {e}");
                errors += 1;
                continue;
            }
        };
        if skip_mismatch {
            if let (Some(b), Some(c)) = (&baseline.machine, &current.machine) {
                if b.key() != c.key() {
                    println!(
                        "== perfgate: bench `{bench}` SKIPPED — machine class {} vs baseline {} \
                         (--skip-mismatch)",
                        c.key(),
                        b.key()
                    );
                    continue;
                }
            }
        }
        let report = compare(&baseline, &current, &default_specs(bench));
        print!("{}", report.render());
        regressions += report.regressions();
        errors += report.errors.len();
    }

    if errors > 0 {
        eprintln!("perfgate: FAIL ({errors} errors, {regressions} regressions)");
        std::process::exit(2);
    }
    if regressions > 0 {
        eprintln!("perfgate: FAIL ({regressions} regressed metrics)");
        std::process::exit(1);
    }
    println!("perfgate: PASS");
}
