//! `tables` — regenerate every paper table/figure analog (DESIGN.md
//! experiment index). Placeholder main; rows are implemented in
//! `gptq_rs::tables` (see that module for the experiment mapping).

fn main() -> gptq_rs::Result<()> {
    gptq_rs::tables::main_cli()
}
