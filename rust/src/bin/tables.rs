//! `tables` — regenerate every paper table/figure analog (DESIGN.md
//! experiment index). Placeholder main; rows are implemented in
//! `gptq_rs::tables` (see that module for the experiment mapping).
//! Accepts the global `--threads N` flag (0 = all cores).

fn main() -> gptq_rs::Result<()> {
    let args = gptq_rs::util::cli::Args::from_env();
    if let Some(t) = args.get("threads").and_then(|v| v.parse::<usize>().ok()) {
        gptq_rs::util::par::set_threads(t);
    }
    gptq_rs::tables::main_cli()
}
