//! The pluggable execution backend: host-side tensor values, the
//! [`ExecBackend`] trait every engine implements, and the [`Runtime`] the
//! coordinator drives.
//!
//! An artifact (named in `artifacts/manifest.json`) is a *contract*: a
//! fixed parameter list in AOT order and a fixed result tuple. Backends
//! differ only in how they honor it:
//!
//! * [`super::ReferenceBackend`] (default) — executes the contracts in
//!   pure Rust against the crate's own `model::`/`quant::` code paths; no
//!   external toolchain, works everywhere, and is the semantic oracle the
//!   integration tests compare other engines against.
//! * `PjrtBackend` (`--features pjrt`) — compiles the AOT HLO-text
//!   artifacts through the XLA PJRT CPU client (the L1 Pallas kernels and
//!   L2 graphs, lowered at build time). Requires the XLA toolchain; the
//!   vendored `xla` stub lets the path typecheck offline (DESIGN.md
//!   §Backends).
//!
//! Later scaling work (sharded executors, remote pools, batched servers)
//! plugs in here: implement [`ExecBackend`], register it in
//! [`backend_by_name`], and the whole pipeline — calibrate → Hessian →
//! GPTQ → pack → eval → serve — runs on it unchanged.

use crate::runtime::Manifest;
use crate::Result;

/// The 12 per-block tensors following `x` in the `block_capture_<size>`
/// contract, in AOT parameter order — shared by the producer
/// (`aot.py::BLOCK_TENSORS`), the pipeline's call site, and the reference
/// backend's decoder. Order is load-bearing: parameters are positional.
pub const BLOCK_TENSORS: [&str; 12] = [
    "ln1_g", "ln1_b", "ln2_g", "ln2_b", "wqkv", "wqkv_b", "wo", "wo_b", "wup", "wup_b", "wdn",
    "wdn_b",
];

/// A host-side tensor value passed to / returned from artifact execution —
/// the backend-neutral replacement for `xla::Literal` on the coordinator
/// side.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
    U32 { data: Vec<u32>, dims: Vec<usize> },
}

fn check_dims(len: usize, dims: &[usize]) -> Result<()> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == len, "value shape {dims:?} does not hold {len} elements");
    Ok(())
}

impl Value {
    pub fn f32(data: Vec<f32>, dims: &[usize]) -> Result<Value> {
        check_dims(data.len(), dims)?;
        Ok(Value::F32 { data, dims: dims.to_vec() })
    }

    pub fn i32(data: Vec<i32>, dims: &[usize]) -> Result<Value> {
        check_dims(data.len(), dims)?;
        Ok(Value::I32 { data, dims: dims.to_vec() })
    }

    pub fn u32(data: Vec<u32>, dims: &[usize]) -> Result<Value> {
        check_dims(data.len(), dims)?;
        Ok(Value::U32 { data, dims: dims.to_vec() })
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Value::F32 { .. } => "f32",
            Value::I32 { .. } => "i32",
            Value::U32 { .. } => "u32",
        }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Value::F32 { dims, .. } | Value::I32 { dims, .. } | Value::U32 { dims, .. } => dims,
        }
    }

    pub fn element_count(&self) -> usize {
        match self {
            Value::F32 { data, .. } => data.len(),
            Value::I32 { data, .. } => data.len(),
            Value::U32 { data, .. } => data.len(),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32 { data, .. } => Ok(data),
            other => anyhow::bail!("expected f32 value, got {}", other.dtype()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32 { data, .. } => Ok(data),
            other => anyhow::bail!("expected i32 value, got {}", other.dtype()),
        }
    }

    pub fn as_u32(&self) -> Result<&[u32]> {
        match self {
            Value::U32 { data, .. } => Ok(data),
            other => anyhow::bail!("expected u32 value, got {}", other.dtype()),
        }
    }

    /// Consume into the f32 buffer (the common output path — avoids a copy
    /// on multi-megabyte activations).
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Value::F32 { data, .. } => Ok(data),
            other => anyhow::bail!("expected f32 value, got {}", other.dtype()),
        }
    }
}

/// An execution engine for manifest artifacts.
pub trait ExecBackend {
    /// Stable name, as accepted by [`backend_by_name`] / `--backend`.
    fn name(&self) -> &'static str;

    /// Can this backend execute `name`? The default requires the artifact
    /// to be lowered (listed in the manifest); synthetic backends may
    /// accept any name matching a known contract.
    fn supports(&self, manifest: &Manifest, name: &str) -> bool {
        manifest.has_artifact(name)
    }

    /// Execute artifact `name`. `inputs` are in the AOT parameter order;
    /// the return is the flattened result tuple.
    fn execute(&mut self, manifest: &Manifest, name: &str, inputs: &[Value]) -> Result<Vec<Value>>;

    /// Cumulative setup/compile time, ms (0 for backends that don't
    /// compile).
    fn compile_ms(&self) -> f64 {
        0.0
    }
}

/// Construct a backend from its CLI name.
pub fn backend_by_name(name: &str) -> Result<Box<dyn ExecBackend>> {
    match name {
        "reference" | "rust" => Ok(Box::new(crate::runtime::ReferenceBackend::new())),
        "pjrt" | "xla" => {
            #[cfg(feature = "pjrt")]
            {
                Ok(Box::new(crate::runtime::pjrt::PjrtBackend::new()?))
            }
            #[cfg(not(feature = "pjrt"))]
            {
                Err(anyhow::anyhow!(
                    "backend {name:?} requires `--features pjrt` (and the XLA toolchain — \
                     see README.md)"
                ))
            }
        }
        other => anyhow::bail!("unknown backend {other:?} (reference|pjrt)"),
    }
}

/// The manifest plus a pluggable execution backend — what the pipeline,
/// evaluation, and serving layers drive.
pub struct Runtime {
    pub manifest: Manifest,
    backend: Box<dyn ExecBackend>,
    /// cumulative execute() calls (telemetry)
    pub exec_calls: u64,
}

impl Runtime {
    /// Wrap a manifest with an explicit backend.
    pub fn with_backend(manifest: Manifest, backend: Box<dyn ExecBackend>) -> Self {
        Self { manifest, backend, exec_calls: 0 }
    }

    /// Default backend (reference — runs everywhere).
    pub fn new(manifest: Manifest) -> Result<Self> {
        Ok(Self::with_backend(manifest, Box::new(crate::runtime::ReferenceBackend::new())))
    }

    pub fn from_artifacts_dir(dir: &std::path::Path) -> Result<Self> {
        Self::new(Manifest::load(dir)?)
    }

    pub fn from_artifacts_dir_with(dir: &std::path::Path, backend: &str) -> Result<Self> {
        Ok(Self::with_backend(Manifest::load(dir)?, backend_by_name(backend)?))
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn compile_ms(&self) -> f64 {
        self.backend.compile_ms()
    }

    /// Whether the current backend can execute `name`.
    pub fn supports(&self, name: &str) -> bool {
        self.backend.supports(&self.manifest, name)
    }

    /// Execute an artifact by manifest name.
    pub fn execute(&mut self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        self.exec_calls += 1;
        self.backend.execute(&self.manifest, name, inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shape_validated() {
        assert!(Value::f32(vec![1.0, 2.0], &[3]).is_err());
        let v = Value::f32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(v.dims(), &[2, 3]);
        assert_eq!(v.element_count(), 6);
        assert_eq!(v.as_f32().unwrap().len(), 6);
        assert!(v.as_i32().is_err());
    }

    #[test]
    fn value_typed_accessors() {
        let v = Value::u32(vec![7, 0xFFFF_FFFF, 3], &[3]).unwrap();
        assert_eq!(v.as_u32().unwrap(), &[7, 0xFFFF_FFFF, 3]);
        assert_eq!(v.dtype(), "u32");
        let v = Value::i32(vec![-1, 2], &[2, 1]).unwrap();
        assert_eq!(v.as_i32().unwrap(), &[-1, 2]);
    }

    #[test]
    fn backend_factory_names() {
        assert_eq!(backend_by_name("reference").unwrap().name(), "reference");
        assert!(backend_by_name("no-such-backend").is_err());
        #[cfg(not(feature = "pjrt"))]
        {
            let err = backend_by_name("pjrt").unwrap_err().to_string();
            assert!(err.contains("pjrt"), "{err}");
        }
    }
}
