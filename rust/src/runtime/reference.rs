//! The pure-Rust reference backend: executes every artifact *contract*
//! (aot.py's entry points) against this crate's own `model::`/`quant::`
//! code paths, with no external toolchain.
//!
//! This is the default engine — `cargo build` with default features gives
//! a fully working pipeline (calibrate → Hessian → GPTQ → pack → eval →
//! serve) — and the semantic oracle: the PJRT integration tests compare
//! the lowered L1/L2 graphs against exactly these functions.
//!
//! Contracts implemented (see `python/compile/aot.py` for the producers):
//!
//! | artifact                     | inputs (AOT order)                   | outputs                    |
//! |------------------------------|--------------------------------------|----------------------------|
//! | `embed_<size>`               | tokens i32 (B,S); embed; pos         | x (B,S,d)                  |
//! | `block_capture_<size>`       | x; 4 LN vecs; 4 linears + biases     | y; 4 per-linear inputs     |
//! | `lm_fwd_<size>`              | tokens; all tensors, manifest order  | logits (B,S,V)             |
//! | `head_<size>`                | x; lnf_g; lnf_b; unembed             | logits (B,S,V)             |
//! | `hessian_<d>`                | X (n,d)                              | 2·XᵀX (d,d)                |
//! | `gptq_layer_<o>x<i>_b<bits>` | W (o,i); H (i,i)                     | codes; scales; zeros; wq   |
//! | `packmatvec_<o>x<i>_b<bits>` | words u32; scales; zeros; x          | y (o)                      |

use crate::model::forward::{gelu, layer_norm};
use crate::model::matvec::{matvec_f32_bias_serial, matvec_packed};
use crate::model::ModelConfig;
use crate::util::par::{self, Pool};
use crate::quant::pack::{words_per_row, PackedMatrix};
use crate::quant::{accumulate_hessian, gptq_quantize, GptqConfig};
use crate::runtime::backend::{ExecBackend, Value, BLOCK_TENSORS};
use crate::runtime::Manifest;
use crate::Result;
use std::collections::BTreeMap;

/// Parse the `<o>x<i>_b<bits>` suffix of shape-keyed artifact names.
fn parse_shape_bits(s: &str) -> Option<(usize, usize, u32)> {
    let (shape, bits) = s.split_once("_b")?;
    let (o, i) = shape.split_once('x')?;
    Some((o.parse().ok()?, i.parse().ok()?, bits.parse().ok()?))
}

/// The pure-Rust execution engine.
#[derive(Debug, Default)]
pub struct ReferenceBackend;

impl ReferenceBackend {
    pub fn new() -> Self {
        ReferenceBackend
    }
}

impl ExecBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    /// Any name matching a known contract is executable — no lowered HLO
    /// needed, so pipelines run even before `make artifacts` has produced
    /// the XLA tree (the manifest must still name the model sizes).
    fn supports(&self, manifest: &Manifest, name: &str) -> bool {
        for prefix in ["embed_", "block_capture_", "lm_fwd_", "head_"] {
            if let Some(size) = name.strip_prefix(prefix) {
                return manifest.models.contains_key(size);
            }
        }
        if let Some(d) = name.strip_prefix("hessian_") {
            return d.parse::<usize>().is_ok();
        }
        if let Some(rest) = name.strip_prefix("gptq_layer_") {
            // same bit widths the packed format (and the lowered artifacts)
            // support — anything else must fail fast at the engine check
            return parse_shape_bits(rest).map(|(_, _, b)| matches!(b, 2 | 3 | 4)).unwrap_or(false);
        }
        if let Some(rest) = name.strip_prefix("packmatvec_") {
            return parse_shape_bits(rest).map(|(_, _, b)| matches!(b, 2 | 3 | 4)).unwrap_or(false);
        }
        false
    }

    fn execute(&mut self, manifest: &Manifest, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        if let Some(size) = name.strip_prefix("embed_") {
            let _ = manifest.model(size)?;
            return exec_embed(inputs);
        }
        if let Some(size) = name.strip_prefix("block_capture_") {
            let cfg = manifest.model(size)?.config.clone();
            return exec_block_capture(&cfg, inputs);
        }
        if let Some(size) = name.strip_prefix("lm_fwd_") {
            return exec_lm_fwd(manifest, size, inputs);
        }
        if let Some(size) = name.strip_prefix("head_") {
            let _ = manifest.model(size)?;
            return exec_head(inputs);
        }
        if name.strip_prefix("hessian_").is_some() {
            return exec_hessian(inputs);
        }
        if let Some(rest) = name.strip_prefix("gptq_layer_") {
            let (o, i, bits) = parse_shape_bits(rest)
                .ok_or_else(|| anyhow::anyhow!("malformed gptq_layer artifact name {name}"))?;
            return exec_gptq_layer(manifest, o, i, bits, inputs);
        }
        if let Some(rest) = name.strip_prefix("packmatvec_") {
            let (o, i, bits) = parse_shape_bits(rest)
                .ok_or_else(|| anyhow::anyhow!("malformed packmatvec artifact name {name}"))?;
            return exec_packmatvec(o, i, bits, inputs);
        }
        anyhow::bail!("reference backend: no contract for artifact {name:?}")
    }
}

// ---------------------------------------------------------------------------
// model contracts
// ---------------------------------------------------------------------------

fn exec_embed(inputs: &[Value]) -> Result<Vec<Value>> {
    anyhow::ensure!(inputs.len() == 3, "embed expects (tokens, embed, pos), got {}", inputs.len());
    let tokens = inputs[0].as_i32()?;
    let (batch, seq) = dims2(&inputs[0])?;
    let emb = inputs[1].as_f32()?;
    let (vocab, d) = dims2(&inputs[1])?;
    let pos = inputs[2].as_f32()?;
    let (max_seq, pd) = dims2(&inputs[2])?;
    anyhow::ensure!(pd == d, "embed/pos width mismatch: {d} vs {pd}");
    anyhow::ensure!(seq <= max_seq, "seq {seq} exceeds positional table {max_seq}");
    let mut x = vec![0.0f32; batch * seq * d];
    for bi in 0..batch {
        for si in 0..seq {
            let t = tokens[bi * seq + si];
            anyhow::ensure!(
                (0..vocab as i32).contains(&t),
                "token {t} out of vocab {vocab}"
            );
            let erow = &emb[t as usize * d..(t as usize + 1) * d];
            let prow = &pos[si * d..(si + 1) * d];
            let out = &mut x[(bi * seq + si) * d..(bi * seq + si + 1) * d];
            for i in 0..d {
                out[i] = erow[i] + prow[i];
            }
        }
    }
    Ok(vec![Value::f32(x, &[batch, seq, d])?])
}

struct BlockIn<'a> {
    ln1_g: &'a [f32],
    ln1_b: &'a [f32],
    ln2_g: &'a [f32],
    ln2_b: &'a [f32],
    wqkv: &'a [f32],
    wqkv_b: &'a [f32],
    wo: &'a [f32],
    wo_b: &'a [f32],
    wup: &'a [f32],
    wup_b: &'a [f32],
    wdn: &'a [f32],
    wdn_b: &'a [f32],
}

impl<'a> BlockIn<'a> {
    fn from_values(vals: &'a [Value]) -> Result<Self> {
        anyhow::ensure!(vals.len() == 12, "block expects 12 tensors, got {}", vals.len());
        Ok(Self {
            ln1_g: vals[0].as_f32()?,
            ln1_b: vals[1].as_f32()?,
            ln2_g: vals[2].as_f32()?,
            ln2_b: vals[3].as_f32()?,
            wqkv: vals[4].as_f32()?,
            wqkv_b: vals[5].as_f32()?,
            wo: vals[6].as_f32()?,
            wo_b: vals[7].as_f32()?,
            wup: vals[8].as_f32()?,
            wup_b: vals[9].as_f32()?,
            wdn: vals[10].as_f32()?,
            wdn_b: vals[11].as_f32()?,
        })
    }

    fn from_named(layer: usize, by_name: &BTreeMap<&str, &'a [f32]>) -> Result<Self> {
        let get = |nm: &str| -> Result<&'a [f32]> {
            named(by_name, &format!("blocks.{layer}.{nm}"))
        };
        Ok(Self {
            ln1_g: get("ln1_g")?,
            ln1_b: get("ln1_b")?,
            ln2_g: get("ln2_g")?,
            ln2_b: get("ln2_b")?,
            wqkv: get("wqkv")?,
            wqkv_b: get("wqkv_b")?,
            wo: get("wo")?,
            wo_b: get("wo_b")?,
            wup: get("wup")?,
            wup_b: get("wup_b")?,
            wdn: get("wdn")?,
            wdn_b: get("wdn_b")?,
        })
    }
}

/// Below this much per-stage work (≈ inner-product MACs) the batched
/// block forward stays serial (DESIGN.md §Parallelism).
const REF_PAR_MIN_WORK: usize = 1 << 16;

/// Batched teacher-forced block forward — the reference twin of the L2
/// `block_capture` graph. Returns (y, [inputs of wqkv, wo, wup, wdn]).
///
/// The per-sample loops (projections, residuals, MLP) and the per-batch
/// attention loop are row-range parallel with disjoint writes; each
/// row's arithmetic is unchanged from the serial loop, so results are
/// bit-identical at every thread count. Inner matvecs use the serial
/// kernels to avoid nested thread scopes; like every matvec in the crate
/// they run on the runtime-dispatched ISA kernels (`model::kernels`), so
/// the backend inherits SIMD for free while `GPTQ_ISA=scalar` keeps the
/// historical bit-exact arithmetic.
fn block_forward_batched(
    cfg: &ModelConfig,
    x: &[f32],
    batch: usize,
    seq: usize,
    w: &BlockIn,
) -> (Vec<f32>, [Vec<f32>; 4]) {
    let d = cfg.d_model;
    let ff = cfg.d_ff;
    let heads = cfg.n_heads;
    let hd = cfg.head_dim();
    let n = batch * seq;
    assert_eq!(x.len(), n * d);
    let pool = if n * d * d >= REF_PAR_MIN_WORK {
        Pool::global()
    } else {
        Pool::serial()
    };

    // LN1 → capture for wqkv
    let mut x1 = vec![0.0f32; n * d];
    for row in 0..n {
        layer_norm(&x[row * d..(row + 1) * d], w.ln1_g, w.ln1_b, &mut x1[row * d..(row + 1) * d]);
    }
    // fused qkv projection
    let mut qkv = vec![0.0f32; n * 3 * d];
    par::for_rows_mut(&pool, &mut qkv, n, 3 * d, |rows, out| {
        for (i, orow) in out.chunks_exact_mut(3 * d).enumerate() {
            let row = rows.start + i;
            matvec_f32_bias_serial(w.wqkv, &x1[row * d..(row + 1) * d], w.wqkv_b, 3 * d, d, orow);
        }
    });
    // causal multi-head attention → capture for wo (parallel over batch:
    // each sequence's attention rows are disjoint in `attn`)
    let mut attn = vec![0.0f32; n * d];
    let scale = 1.0 / (hd as f32).sqrt();
    par::for_rows_mut(&pool, &mut attn, batch, seq * d, |brange, aout| {
        let mut scores = vec![0.0f32; seq];
        for (ob, bi) in brange.clone().enumerate() {
            for head in 0..heads {
                let hoff = head * hd;
                for qs in 0..seq {
                    let qrow = (bi * seq + qs) * 3 * d;
                    let q = &qkv[qrow + hoff..qrow + hoff + hd];
                    let mut maxv = f32::NEG_INFINITY;
                    for ks in 0..=qs {
                        let krow = (bi * seq + ks) * 3 * d + d;
                        let k = &qkv[krow + hoff..krow + hoff + hd];
                        let mut dot = 0.0f32;
                        for i in 0..hd {
                            dot += q[i] * k[i];
                        }
                        scores[ks] = dot * scale;
                        maxv = maxv.max(scores[ks]);
                    }
                    let mut denom = 0.0f32;
                    for s in scores[..=qs].iter_mut() {
                        *s = (*s - maxv).exp();
                        denom += *s;
                    }
                    let out =
                        &mut aout[(ob * seq + qs) * d + hoff..(ob * seq + qs) * d + hoff + hd];
                    for ks in 0..=qs {
                        let vrow = (bi * seq + ks) * 3 * d + 2 * d;
                        let v = &qkv[vrow + hoff..vrow + hoff + hd];
                        let wgt = scores[ks] / denom;
                        for i in 0..hd {
                            out[i] += wgt * v[i];
                        }
                    }
                }
            }
        }
    });
    // attention residual
    let mut xr = x.to_vec();
    par::for_rows_mut(&pool, &mut xr, n, d, |rows, out| {
        let mut proj = vec![0.0f32; d];
        for (i, xrow) in out.chunks_exact_mut(d).enumerate() {
            let row = rows.start + i;
            matvec_f32_bias_serial(w.wo, &attn[row * d..(row + 1) * d], w.wo_b, d, d, &mut proj);
            for k in 0..d {
                xrow[k] += proj[k];
            }
        }
    });
    // LN2 → capture for wup
    let mut x2 = vec![0.0f32; n * d];
    for row in 0..n {
        layer_norm(&xr[row * d..(row + 1) * d], w.ln2_g, w.ln2_b, &mut x2[row * d..(row + 1) * d]);
    }
    // GELU MLP hidden → capture for wdn
    let mut hidden = vec![0.0f32; n * ff];
    par::for_rows_mut(&pool, &mut hidden, n, ff, |rows, out| {
        for (i, h) in out.chunks_exact_mut(ff).enumerate() {
            let row = rows.start + i;
            matvec_f32_bias_serial(w.wup, &x2[row * d..(row + 1) * d], w.wup_b, ff, d, h);
            for v in h.iter_mut() {
                *v = gelu(*v);
            }
        }
    });
    // MLP residual
    let mut y = xr;
    par::for_rows_mut(&pool, &mut y, n, d, |rows, out| {
        let mut proj = vec![0.0f32; d];
        for (i, yrow) in out.chunks_exact_mut(d).enumerate() {
            let row = rows.start + i;
            matvec_f32_bias_serial(
                w.wdn,
                &hidden[row * ff..(row + 1) * ff],
                w.wdn_b,
                d,
                ff,
                &mut proj,
            );
            for k in 0..d {
                yrow[k] += proj[k];
            }
        }
    });
    (y, [x1, attn, x2, hidden])
}

fn exec_block_capture(cfg: &ModelConfig, inputs: &[Value]) -> Result<Vec<Value>> {
    anyhow::ensure!(
        inputs.len() == 1 + BLOCK_TENSORS.len(),
        "block_capture expects x + {} tensors, got {}",
        BLOCK_TENSORS.len(),
        inputs.len()
    );
    let x = inputs[0].as_f32()?;
    let (batch, seq, d) = dims3(&inputs[0])?;
    anyhow::ensure!(d == cfg.d_model, "x width {d} != d_model {}", cfg.d_model);
    let w = BlockIn::from_values(&inputs[1..])?;
    let (y, [c_qkv, c_wo, c_wup, c_wdn]) = block_forward_batched(cfg, x, batch, seq, &w);
    Ok(vec![
        Value::f32(y, &[batch, seq, d])?,
        Value::f32(c_qkv, &[batch, seq, d])?,
        Value::f32(c_wo, &[batch, seq, d])?,
        Value::f32(c_wup, &[batch, seq, d])?,
        Value::f32(c_wdn, &[batch, seq, cfg.d_ff])?,
    ])
}

fn head_logits(x: &[f32], n: usize, d: usize, lnf_g: &[f32], lnf_b: &[f32], unembed: &[f32]) -> Vec<f32> {
    let vocab = unembed.len() / d;
    let mut logits = vec![0.0f32; n * vocab];
    let pool = if n * vocab * d >= REF_PAR_MIN_WORK {
        Pool::global()
    } else {
        Pool::serial()
    };
    // row-range parallel over positions: the unembed matmul dominates the
    // eval path; per-row arithmetic is unchanged (bit-identical)
    par::for_rows_mut(&pool, &mut logits, n, vocab, |rows, out| {
        let mut x1 = vec![0.0f32; d];
        for (i, lrow) in out.chunks_exact_mut(vocab).enumerate() {
            let row = rows.start + i;
            layer_norm(&x[row * d..(row + 1) * d], lnf_g, lnf_b, &mut x1);
            for (v, lv) in lrow.iter_mut().enumerate() {
                let urow = &unembed[v * d..(v + 1) * d];
                let mut acc = 0.0f32;
                for i in 0..d {
                    acc += urow[i] * x1[i];
                }
                *lv = acc;
            }
        }
    });
    logits
}

fn exec_head(inputs: &[Value]) -> Result<Vec<Value>> {
    anyhow::ensure!(inputs.len() == 4, "head expects (x, lnf_g, lnf_b, unembed)");
    let x = inputs[0].as_f32()?;
    let (batch, seq, d) = dims3(&inputs[0])?;
    let lnf_g = inputs[1].as_f32()?;
    let lnf_b = inputs[2].as_f32()?;
    let unembed = inputs[3].as_f32()?;
    let (vocab, ud) = dims2(&inputs[3])?;
    anyhow::ensure!(ud == d, "unembed width {ud} != d_model {d}");
    let logits = head_logits(x, batch * seq, d, lnf_g, lnf_b, unembed);
    Ok(vec![Value::f32(logits, &[batch, seq, vocab])?])
}

fn exec_lm_fwd(manifest: &Manifest, size: &str, inputs: &[Value]) -> Result<Vec<Value>> {
    let entry = manifest.model(size)?;
    let cfg = entry.config.clone();
    anyhow::ensure!(
        inputs.len() == 1 + entry.tensors.len(),
        "lm_fwd_{size} expects tokens + {} tensors (manifest order), got {}",
        entry.tensors.len(),
        inputs.len()
    );
    let mut by_name: BTreeMap<&str, &[f32]> = BTreeMap::new();
    for (t, v) in entry.tensors.iter().zip(&inputs[1..]) {
        let data = v.as_f32()?;
        anyhow::ensure!(
            data.len() == t.shape.iter().product::<usize>(),
            "lm_fwd_{size}: tensor {} has {} elements, manifest says {:?}",
            t.name,
            data.len(),
            t.shape
        );
        by_name.insert(t.name.as_str(), data);
    }

    // embed
    let embedded = exec_embed(&[
        inputs[0].clone(),
        Value::f32(named(&by_name, "embed")?.to_vec(), &[cfg.vocab, cfg.d_model])?,
        Value::f32(named(&by_name, "pos")?.to_vec(), &[cfg.max_seq, cfg.d_model])?,
    ])?;
    let (batch, seq, d) = dims3(&embedded[0])?;
    let mut x = embedded.into_iter().next().unwrap().into_f32()?;

    // blocks
    for layer in 0..cfg.n_layers {
        let w = BlockIn::from_named(layer, &by_name)?;
        let (y, _) = block_forward_batched(&cfg, &x, batch, seq, &w);
        x = y;
    }

    // head
    let logits = head_logits(
        &x,
        batch * seq,
        d,
        named(&by_name, "lnf_g")?,
        named(&by_name, "lnf_b")?,
        named(&by_name, "unembed")?,
    );
    Ok(vec![Value::f32(logits, &[batch, seq, cfg.vocab])?])
}

/// Look up a tensor by manifest name in the borrowed input map.
fn named<'a>(map: &BTreeMap<&str, &'a [f32]>, nm: &str) -> Result<&'a [f32]> {
    map.get(nm).copied().ok_or_else(|| anyhow::anyhow!("lm_fwd: tensor {nm} missing"))
}

// ---------------------------------------------------------------------------
// quantization contracts
// ---------------------------------------------------------------------------

fn exec_hessian(inputs: &[Value]) -> Result<Vec<Value>> {
    anyhow::ensure!(inputs.len() == 1, "hessian expects (x,)");
    let x = inputs[0].as_f32()?;
    let (n, d) = dims2(&inputs[0])?;
    let mut h64 = vec![0.0f64; d * d];
    accumulate_hessian(&mut h64, x, n, d);
    let h: Vec<f32> = h64.iter().map(|&v| v as f32).collect();
    Ok(vec![Value::f32(h, &[d, d])?])
}

fn exec_gptq_layer(
    manifest: &Manifest,
    drow: usize,
    dcol: usize,
    bits: u32,
    inputs: &[Value],
) -> Result<Vec<Value>> {
    anyhow::ensure!(inputs.len() == 2, "gptq_layer expects (w, h)");
    let w = inputs[0].as_f32()?;
    anyhow::ensure!(w.len() == drow * dcol, "gptq_layer: w has {} elements", w.len());
    let hf = inputs[1].as_f32()?;
    anyhow::ensure!(hf.len() == dcol * dcol, "gptq_layer: h has {} elements", hf.len());
    let h: Vec<f64> = hf.iter().map(|&v| v as f64).collect();
    let cfg = GptqConfig {
        bits,
        blocksize: manifest.quant.blocksize,
        percdamp: manifest.quant.percdamp,
        ..GptqConfig::new(bits)
    };
    let r = gptq_quantize(w, drow, dcol, &h, &cfg).map_err(|e| anyhow::anyhow!(e))?;
    let codes: Vec<f32> = r.codes.iter().map(|&c| c as f32).collect();
    Ok(vec![
        Value::f32(codes, &[drow, dcol])?,
        Value::f32(r.scales, &[drow, r.ngroups])?,
        Value::f32(r.zeros, &[drow, r.ngroups])?,
        Value::f32(r.wq, &[drow, dcol])?,
    ])
}

fn exec_packmatvec(drow: usize, dcol: usize, bits: u32, inputs: &[Value]) -> Result<Vec<Value>> {
    anyhow::ensure!(inputs.len() == 4, "packmatvec expects (words, scales, zeros, x)");
    let words = inputs[0].as_u32()?;
    let scales = inputs[1].as_f32()?;
    let zeros = inputs[2].as_f32()?;
    let x = inputs[3].as_f32()?;
    let nwords = words_per_row(dcol, bits);
    anyhow::ensure!(
        words.len() == drow * nwords,
        "packmatvec: {} words for shape {drow}x{dcol} b{bits} (want {})",
        words.len(),
        drow * nwords
    );
    anyhow::ensure!(scales.len() % drow == 0 && scales.len() == zeros.len(), "grid shape mismatch");
    anyhow::ensure!(x.len() == dcol, "x has {} elements, want {dcol}", x.len());
    let p = PackedMatrix {
        words: words.to_vec(),
        scales: scales.to_vec(),
        zeros: zeros.to_vec(),
        drow,
        dcol,
        nwords,
        ngroups: scales.len() / drow,
        bits,
    };
    let mut y = vec![0.0f32; drow];
    matvec_packed(&p, x, &mut y);
    Ok(vec![Value::f32(y, &[drow])?])
}

// ---------------------------------------------------------------------------

fn dims2(v: &Value) -> Result<(usize, usize)> {
    let d = v.dims();
    anyhow::ensure!(d.len() == 2, "expected rank-2 value, got {d:?}");
    Ok((d[0], d[1]))
}

fn dims3(v: &Value) -> Result<(usize, usize, usize)> {
    let d = v.dims();
    anyhow::ensure!(d.len() == 3, "expected rank-3 value, got {d:?}");
    Ok((d[0], d[1], d[2]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::{tiny_checkpoint, tiny_manifest, TINY_SIZE};
    use crate::model::CpuModel;
    use crate::quant::rtn_quantize;

    fn rng_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::data::Rng::new(seed);
        (0..n).map(|_| rng.unit()).collect()
    }

    #[test]
    fn supports_known_contracts() {
        let m = tiny_manifest(12, 2);
        let b = ReferenceBackend::new();
        assert!(b.supports(&m, &format!("embed_{TINY_SIZE}")));
        assert!(b.supports(&m, &format!("block_capture_{TINY_SIZE}")));
        assert!(b.supports(&m, &format!("lm_fwd_{TINY_SIZE}")));
        assert!(b.supports(&m, "hessian_64"));
        assert!(b.supports(&m, "gptq_layer_48x16_b4"));
        assert!(b.supports(&m, "packmatvec_64x32_b3"));
        assert!(!b.supports(&m, "embed_unknown-size"));
        assert!(!b.supports(&m, "gptq_layer_bogus"));
        assert!(!b.supports(&m, "something_else"));
    }

    #[test]
    fn embed_contract_matches_manual() {
        let m = tiny_manifest(12, 2);
        let mut b = ReferenceBackend::new();
        let ckpt = tiny_checkpoint(3);
        let (batch, seq) = (2usize, 4usize);
        let tokens: Vec<i32> = vec![1, 5, 9, 2, 0, 31, 7, 7];
        let out = b
            .execute(
                &m,
                &format!("embed_{TINY_SIZE}"),
                &[
                    Value::i32(tokens.clone(), &[batch, seq]).unwrap(),
                    Value::f32(ckpt.get("embed").data.clone(), &ckpt.get("embed").shape).unwrap(),
                    Value::f32(ckpt.get("pos").data.clone(), &ckpt.get("pos").shape).unwrap(),
                ],
            )
            .unwrap();
        let x = out[0].as_f32().unwrap();
        let d = ckpt.config.d_model;
        for bi in 0..batch {
            for si in 0..seq {
                let t = tokens[bi * seq + si] as usize;
                for i in 0..d {
                    let want = ckpt.get("embed").data[t * d + i] + ckpt.get("pos").data[si * d + i];
                    let got = x[(bi * seq + si) * d + i];
                    assert!((got - want).abs() < 1e-6);
                }
            }
        }
    }

    #[test]
    fn hessian_contract_matches_rust_accumulator() {
        let m = tiny_manifest(12, 2);
        let mut b = ReferenceBackend::new();
        let (n, d) = (24usize, 8usize);
        let x = rng_vec(n * d, 7);
        let out = b
            .execute(&m, "hessian_8", &[Value::f32(x.clone(), &[n, d]).unwrap()])
            .unwrap();
        let h = out[0].as_f32().unwrap();
        let mut want = vec![0.0f64; d * d];
        accumulate_hessian(&mut want, &x, n, d);
        for (a, b) in h.iter().zip(&want) {
            assert!((*a as f64 - b).abs() < 1e-3 * b.abs().max(1.0));
        }
    }

    #[test]
    fn packmatvec_contract_matches_kernel() {
        let m = tiny_manifest(12, 2);
        let mut b = ReferenceBackend::new();
        let (drow, dcol, bits) = (16usize, 64usize, 3u32);
        let w = rng_vec(drow * dcol, 11);
        let r = rtn_quantize(&w, drow, dcol, bits, 0);
        let p = PackedMatrix::from_result(&r);
        let x = rng_vec(dcol, 13);
        let out = b
            .execute(
                &m,
                &format!("packmatvec_{drow}x{dcol}_b{bits}"),
                &[
                    Value::u32(p.words.clone(), &[drow, p.nwords]).unwrap(),
                    Value::f32(p.scales.clone(), &[drow, 1]).unwrap(),
                    Value::f32(p.zeros.clone(), &[drow, 1]).unwrap(),
                    Value::f32(x.clone(), &[dcol]).unwrap(),
                ],
            )
            .unwrap();
        let mut want = vec![0.0f32; drow];
        matvec_packed(&p, &x, &mut want);
        assert_eq!(out[0].as_f32().unwrap(), &want[..]);
    }

    #[test]
    fn gptq_layer_contract_matches_solver() {
        let m = tiny_manifest(12, 2);
        let mut b = ReferenceBackend::new();
        let (drow, dcol) = (8usize, 16usize);
        let w = rng_vec(drow * dcol, 5);
        let x = rng_vec(4 * dcol * dcol, 6);
        let mut h64 = vec![0.0f64; dcol * dcol];
        accumulate_hessian(&mut h64, &x, 4 * dcol, dcol);
        let hf: Vec<f32> = h64.iter().map(|&v| v as f32).collect();
        let out = b
            .execute(
                &m,
                &format!("gptq_layer_{drow}x{dcol}_b4"),
                &[
                    Value::f32(w.clone(), &[drow, dcol]).unwrap(),
                    Value::f32(hf.clone(), &[dcol, dcol]).unwrap(),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 4);
        // the contract runs on the f32 Hessian it was handed
        let h32: Vec<f64> = hf.iter().map(|&v| v as f64).collect();
        let cfg = GptqConfig {
            blocksize: m.quant.blocksize,
            percdamp: m.quant.percdamp,
            ..GptqConfig::new(4)
        };
        let want = gptq_quantize(&w, drow, dcol, &h32, &cfg).unwrap();
        let codes = out[0].as_f32().unwrap();
        for (a, b) in codes.iter().zip(&want.codes) {
            assert_eq!(*a as u8, *b);
        }
        for (a, b) in out[3].as_f32().unwrap().iter().zip(&want.wq) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn lm_fwd_contract_matches_cpu_decode() {
        // The strongest no-artifact parity check: the batched reference
        // forward must agree with the KV-cached CPU decode path.
        let manifest = tiny_manifest(12, 2);
        let mut b = ReferenceBackend::new();
        let ckpt = tiny_checkpoint(9);
        let entry = manifest.model(TINY_SIZE).unwrap().clone();
        let (batch, seq) = (2usize, 6usize);
        let tokens: Vec<i32> = (0..batch * seq).map(|i| ((i * 7 + 3) % 32) as i32).collect();
        let mut inputs = vec![Value::i32(tokens.clone(), &[batch, seq]).unwrap()];
        for t in &entry.tensors {
            let tensor = ckpt.get(&t.name);
            inputs.push(Value::f32(tensor.data.clone(), &tensor.shape).unwrap());
        }
        let out = b.execute(&manifest, &format!("lm_fwd_{TINY_SIZE}"), &inputs).unwrap();
        let logits = out[0].as_f32().unwrap();
        assert_eq!(out[0].dims(), &[batch, seq, 32]);

        let mut cpu = CpuModel::from_checkpoint(&ckpt);
        for bi in 0..batch {
            let row: Vec<u8> = tokens[bi * seq..(bi + 1) * seq].iter().map(|&t| t as u8).collect();
            let want = cpu.logits_all(&row);
            let got = &logits[bi * seq * 32..(bi + 1) * seq * 32];
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }
}
