//! PJRT execution backend (`--features pjrt`): loads the AOT artifacts
//! (`artifacts/hlo/*.hlo.txt`, HLO **text** — see /opt/xla-example/README.md
//! for why not serialized protos), compiles them once on the XLA CPU
//! client, and executes them behind the [`ExecBackend`] trait.
//!
//! Builds offline against the vendored `xla` stub (typecheck + literal
//! marshalling only); real execution needs the XLA toolchain — swap the
//! path dependency in `rust/Cargo.toml` for the real binding.

use crate::runtime::backend::{ExecBackend, Value};
use crate::runtime::Manifest;
use crate::Result;
use std::collections::HashMap;
use std::time::Instant;

/// A loaded PJRT CPU backend with an executable cache keyed by artifact
/// name — artifacts compile once per process and are reused across the
/// whole pipeline (no retrace/recompile on the hot path).
pub struct PjrtBackend {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// cumulative compile time, ms
    compile_ms: f64,
}

impl PjrtBackend {
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self { client, executables: HashMap::new(), compile_ms: 0.0 })
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    fn ensure_loaded(&mut self, manifest: &Manifest, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = manifest.artifact_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
        self.compile_ms += t0.elapsed().as_secs_f64() * 1e3;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }
}

impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn compile_ms(&self) -> f64 {
        self.compile_ms
    }

    /// Execute an artifact. Inputs are marshalled to literals in the AOT
    /// parameter order; outputs are the flattened result-tuple literals.
    fn execute(&mut self, manifest: &Manifest, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        self.ensure_loaded(manifest, name)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<Vec<_>>>()?;
        let exe = &self.executables[name];
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e}"))?;
        let tuple = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow::anyhow!("{name}: empty result"))?
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{name} fetch: {e}"))?;
        // aot.py lowers with return_tuple=True: unwrap the tuple
        let parts = tuple.to_tuple().map_err(|e| anyhow::anyhow!("{name} untuple: {e}"))?;
        parts.iter().map(from_literal).collect()
    }
}

// ---------------------------------------------------------------------------
// literal marshalling
// ---------------------------------------------------------------------------

fn to_literal(v: &Value) -> Result<xla::Literal> {
    match v {
        Value::F32 { data, dims } => literal_f32(data, dims),
        Value::I32 { data, dims } => literal_i32(data, dims),
        Value::U32 { data, dims } => literal_u32(data, dims),
    }
}

/// Graph outputs are f32 tensors; dims come from the literal so both
/// backends return identically-shaped [`Value`]s for the same contract.
/// (The vendored stub exposes `dims()` directly; a real `xla` binding may
/// need a one-line adapter via its `shape()` accessor.)
fn from_literal(lit: &xla::Literal) -> Result<Value> {
    let data = to_vec_f32(lit)?;
    let dims: Vec<usize> = lit.dims().iter().map(|&d| d as usize).collect();
    Value::f32(data, &dims)
}

pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "literal_f32: {dims:?} vs {} elements", data.len());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape: {e}"))
}

pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape: {e}"))
}

pub fn literal_u32(data: &[u32], dims: &[usize]) -> Result<xla::Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape: {e}"))
}

pub fn literal_f64_as_f32(data: &[f64], dims: &[usize]) -> Result<xla::Literal> {
    let f32s: Vec<f32> = data.iter().map(|&v| v as f32).collect();
    literal_f32(&f32s, dims)
}

pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec f32: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = literal_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
        assert_eq!(lit.element_count(), 6);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn literal_u32_roundtrip() {
        let data = vec![7u32, 0xFFFF_FFFF, 3];
        let lit = literal_u32(&data, &[3]).unwrap();
        assert_eq!(lit.to_vec::<u32>().unwrap(), data);
    }

    #[test]
    fn value_to_literal_marshalling() {
        let v = Value::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        let lit = to_literal(&v).unwrap();
        assert_eq!(lit.element_count(), 4);
        let back = from_literal(&lit).unwrap();
        assert_eq!(back.as_f32().unwrap(), v.as_f32().unwrap());
    }
}
