//! PJRT client wrapper: HLO-text artifact loading, executable caching, and
//! literal marshalling. Adapted from /opt/xla-example/load_hlo/.

use crate::runtime::Manifest;
use crate::Result;
use std::collections::HashMap;
use std::time::Instant;

/// A loaded PJRT CPU runtime with an executable cache keyed by artifact
/// name — artifacts compile once per process and are reused across the
/// whole pipeline (no retrace/recompile on the hot path).
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// cumulative (compile_ms, exec_calls) telemetry
    pub compile_ms: f64,
    pub exec_calls: u64,
}

impl Runtime {
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self { client, manifest, executables: HashMap::new(), compile_ms: 0.0, exec_calls: 0 })
    }

    pub fn from_artifacts_dir(dir: &std::path::Path) -> Result<Self> {
        Self::new(Manifest::load(dir)?)
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn ensure_loaded(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.artifact_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
        self.compile_ms += t0.elapsed().as_secs_f64() * 1e3;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact. Inputs are literals in the AOT parameter order;
    /// outputs are the flattened result-tuple literals.
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.ensure_loaded(name)?;
        let exe = &self.executables[name];
        self.exec_calls += 1;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e}"))?;
        let tuple = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow::anyhow!("{name}: empty result"))?
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{name} fetch: {e}"))?;
        // aot.py lowers with return_tuple=True: unwrap the tuple
        tuple.to_tuple().map_err(|e| anyhow::anyhow!("{name} untuple: {e}"))
    }
}

// ---------------------------------------------------------------------------
// literal marshalling
// ---------------------------------------------------------------------------

pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "literal_f32: {dims:?} vs {} elements", data.len());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape: {e}"))
}

pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape: {e}"))
}

pub fn literal_u32(data: &[u32], dims: &[usize]) -> Result<xla::Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape: {e}"))
}

pub fn literal_f64_as_f32(data: &[f64], dims: &[usize]) -> Result<xla::Literal> {
    let f32s: Vec<f32> = data.iter().map(|&v| v as f32).collect();
    literal_f32(&f32s, dims)
}

pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec f32: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = literal_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
        assert_eq!(lit.element_count(), 6);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn literal_u32_roundtrip() {
        let data = vec![7u32, 0xFFFF_FFFF, 3];
        let lit = literal_u32(&data, &[3]).unwrap();
        assert_eq!(lit.to_vec::<u32>().unwrap(), data);
    }
}
