//! `artifacts/manifest.json` — the contract between the Python compile
//! path (aot.py) and this crate. Parsed with the crate's own JSON
//! substrate (offline environment; see util::json).

use crate::model::ModelConfig;
use crate::util::Json;
use crate::Result;
use anyhow::{anyhow, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct TensorEntry {
    pub name: String,
    pub shape: Vec<usize>,
    /// byte offset into the weights file
    pub offset: usize,
    /// element count
    pub len: usize,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub config: ModelConfig,
    pub n_params: usize,
    pub weights: String,
    pub tensors: Vec<TensorEntry>,
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    /// parameter shapes, in call order
    pub params: Vec<Vec<usize>>,
}

#[derive(Debug, Clone)]
pub struct QuantDefaults {
    pub blocksize: usize,
    pub percdamp: f64,
    pub gptq_artifact_bits: Vec<u32>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub seq_len: usize,
    pub eval_batch: usize,
    pub calib_tokens: usize,
    pub quant: QuantDefaults,
    pub models: BTreeMap<String, ModelEntry>,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
    pub root: PathBuf,
}

fn je(e: String) -> anyhow::Error {
    anyhow!("manifest: {e}")
}

impl Manifest {
    pub fn from_json_text(text: &str, root: &Path) -> Result<Self> {
        let j = Json::parse(text).map_err(je)?;
        let quant = j.req("quant").map_err(je)?;
        let mut models = BTreeMap::new();
        for (name, m) in j.req("models").map_err(je)?.as_obj().context("models not an object")? {
            let c = m.req("config").map_err(je)?;
            let config = ModelConfig {
                d_model: c.req("d_model").map_err(je)?.as_usize().context("d_model")?,
                n_layers: c.req("n_layers").map_err(je)?.as_usize().context("n_layers")?,
                n_heads: c.req("n_heads").map_err(je)?.as_usize().context("n_heads")?,
                d_ff: c.req("d_ff").map_err(je)?.as_usize().context("d_ff")?,
                vocab: c.req("vocab").map_err(je)?.as_usize().context("vocab")?,
                max_seq: c.req("max_seq").map_err(je)?.as_usize().context("max_seq")?,
            };
            let tensors = m
                .req("tensors")
                .map_err(je)?
                .as_arr()
                .context("tensors")?
                .iter()
                .map(|t| -> Result<TensorEntry> {
                    Ok(TensorEntry {
                        name: t.req("name").map_err(je)?.as_str().context("name")?.to_string(),
                        shape: t.req("shape").map_err(je)?.usize_vec().context("shape")?,
                        offset: t.req("offset").map_err(je)?.as_usize().context("offset")?,
                        len: t.req("len").map_err(je)?.as_usize().context("len")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelEntry {
                    config,
                    n_params: m.req("n_params").map_err(je)?.as_usize().context("n_params")?,
                    weights: m.req("weights").map_err(je)?.as_str().context("weights")?.to_string(),
                    tensors,
                },
            );
        }
        let mut artifacts = BTreeMap::new();
        for (name, a) in j.req("artifacts").map_err(je)?.as_obj().context("artifacts")? {
            let params = a
                .req("params")
                .map_err(je)?
                .as_arr()
                .context("params")?
                .iter()
                .map(|p| p.usize_vec().context("param shape"))
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    file: a.req("file").map_err(je)?.as_str().context("file")?.to_string(),
                    params,
                },
            );
        }
        Ok(Self {
            version: j.req("version").map_err(je)?.as_u32().context("version")?,
            seq_len: j.req("seq_len").map_err(je)?.as_usize().context("seq_len")?,
            eval_batch: j.req("eval_batch").map_err(je)?.as_usize().context("eval_batch")?,
            calib_tokens: j.req("calib_tokens").map_err(je)?.as_usize().context("calib_tokens")?,
            quant: QuantDefaults {
                blocksize: quant.req("blocksize").map_err(je)?.as_usize().context("blocksize")?,
                percdamp: quant.req("percdamp").map_err(je)?.as_f64().context("percdamp")?,
                gptq_artifact_bits: quant
                    .req("gptq_artifact_bits")
                    .map_err(je)?
                    .as_arr()
                    .context("bits")?
                    .iter()
                    .filter_map(|b| b.as_u32())
                    .collect(),
            },
            models,
            artifacts,
            root: root.to_path_buf(),
        })
    }

    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("cannot read {} (run `make artifacts` first)", path.display())
        })?;
        Self::from_json_text(&text, artifacts_dir)
    }

    pub fn model(&self, size: &str) -> Result<&ModelEntry> {
        self.models.get(size).ok_or_else(|| {
            anyhow!("model size {size:?} not in manifest (have: {:?})", self.models.keys().collect::<Vec<_>>())
        })
    }

    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let entry = self.artifacts.get(name).ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?;
        Ok(self.root.join(&entry.file))
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    pub fn corpus_path(&self, file: &str) -> PathBuf {
        self.root.join("corpus").join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let json = r#"{
            "version": 1, "seq_len": 128, "eval_batch": 8, "calib_tokens": 1024,
            "quant": {"blocksize": 128, "percdamp": 0.01, "gptq_artifact_bits": [3, 4]},
            "models": {"nano": {"config": {"d_model": 64, "n_layers": 2, "n_heads": 2,
                "d_ff": 256, "vocab": 256, "max_seq": 128}, "n_params": 1000,
                "weights": "weights_nano.bin",
                "tensors": [{"name": "embed", "shape": [256, 64], "offset": 0, "len": 16384}]}},
            "artifacts": {"lm_fwd_nano": {"file": "hlo/lm_fwd_nano.hlo.txt", "params": [[8, 128]]}}
        }"#;
        let m = Manifest::from_json_text(json, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.models["nano"].config.d_model, 64);
        assert_eq!(m.models["nano"].tensors[0].len, 16384);
        assert_eq!(m.artifacts["lm_fwd_nano"].params[0], vec![8, 128]);
        assert!(m.quant.gptq_artifact_bits.contains(&4));
        assert_eq!(m.artifact_path("lm_fwd_nano").unwrap(), PathBuf::from("/tmp/a/hlo/lm_fwd_nano.hlo.txt"));
        assert!(m.model("missing").is_err());
    }

    #[test]
    fn missing_keys_are_errors() {
        assert!(Manifest::from_json_text("{}", Path::new("/tmp")).is_err());
    }
}
