//! PJRT runtime: loads the AOT artifacts (`artifacts/hlo/*.hlo.txt`,
//! HLO **text** — see /opt/xla-example/README.md for why not serialized
//! protos) and executes them on the XLA CPU client from the coordinator's
//! pipeline. Compiled executables are cached per artifact name.

pub mod client;
pub mod manifest;

pub use client::Runtime;
pub use manifest::{ArtifactEntry, Manifest, ModelEntry, TensorEntry};
