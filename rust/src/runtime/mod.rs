//! The runtime layer: the artifact manifest (the Python↔Rust contract) and
//! the pluggable execution backend behind it.
//!
//! * [`backend`] — [`Value`] host tensors, the [`ExecBackend`] trait, and
//!   the [`Runtime`] the coordinator drives.
//! * [`reference`] — the default pure-Rust engine: executes every artifact
//!   contract against this crate's own model/quant code; no toolchain.
//! * `pjrt` (`--features pjrt`) — compiles `artifacts/hlo/*.hlo.txt` (HLO
//!   **text**; see /opt/xla-example/README.md for why not protos) on the
//!   XLA PJRT CPU client; executables are cached per artifact name.
//! * [`manifest`] — `artifacts/manifest.json` parsing.

pub mod backend;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;

pub use backend::{backend_by_name, ExecBackend, Runtime, Value, BLOCK_TENSORS};
pub use manifest::{ArtifactEntry, Manifest, ModelEntry, TensorEntry};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use reference::ReferenceBackend;
