//! The GPTQ solver (paper §3.3) — fixed column order, blocked error
//! compensation, Cholesky-factored inverse Hessian.
//!
//! Semantics are identical to `kernels/ref.py::gptq_ref` (cross-checked via
//! `artifacts/golden.json`) and to the L2 graph `gptq_layer.py` the Rust
//! pipeline can alternatively execute through PJRT.
//!
//! Ablation switches reproduce the paper's design discussion:
//! * [`Order::ActOrder`] — quantize columns by decreasing Hessian diagonal
//!   (the "greedy-ish" shared order; paper Step 1 argues fixed order is
//!   nearly as good — `tables -- ablations` measures it);
//! * `use_cholesky = false` — the naive repeated Eq. (3) inverse updates
//!   the Cholesky reformulation replaces (paper Step 3; slower and less
//!   numerically robust);
//! * `percdamp = 0` — no dampening (stability ablation).

use super::grid::{quant_params, quantize_value};
use super::linalg::{cholesky_upper, matmul_acc, spd_inverse};
use super::sparse::{self, Sparsity};
use crate::util::par::{self, Pool};

/// Below this many weight elements (`drow · dcol`) the solver stays
/// serial (DESIGN.md §Parallelism, threshold rationale). Low on purpose:
/// per-row solver work is O(dcol²), so even small layers amortise spawn.
pub const GPTQ_PAR_MIN_ELEMS: usize = 512;

/// Column processing order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Order {
    /// Left-to-right — the paper's key insight: an arbitrary fixed order
    /// shared by all rows costs little accuracy and 1000× less compute.
    #[default]
    Natural,
    /// Decreasing `diag(H)` (quantize "important" columns first while many
    /// compensators remain).
    ActOrder,
}

/// Solver configuration; defaults follow the paper (§4 Setup).
#[derive(Debug, Clone)]
pub struct GptqConfig {
    pub bits: u32,
    /// Lazy-batch block size B (paper Step 2; default 128).
    pub blocksize: usize,
    /// Group size G for grouped grids (0 = one per-row grid, the default).
    pub groupsize: usize,
    /// Dampening λ as a fraction of mean(diag(H)) (paper: 1%).
    pub percdamp: f64,
    pub order: Order,
    /// false → naive repeated-inverse ablation (paper pre-Step-3).
    pub use_cholesky: bool,
    /// Joint sparsify+quantize policy (SparseGPT); `None` leaves the
    /// solver bit-identical to the pre-sparsity path.
    pub sparsity: Sparsity,
}

impl Default for GptqConfig {
    fn default() -> Self {
        Self {
            bits: 4,
            blocksize: 128,
            groupsize: 0,
            percdamp: 0.01,
            order: Order::Natural,
            use_cholesky: true,
            sparsity: Sparsity::None,
        }
    }
}

impl GptqConfig {
    pub fn new(bits: u32) -> Self {
        Self { bits, ..Self::default() }
    }
    pub fn with_groupsize(mut self, g: usize) -> Self {
        self.groupsize = g;
        self
    }
}

/// Output of a layer quantization: integer codes, per-group grids, and the
/// dequantized weights (row-major, like the input).
#[derive(Debug, Clone)]
pub struct QuantResult {
    pub codes: Vec<u8>,
    pub scales: Vec<f32>,
    pub zeros: Vec<f32>,
    pub wq: Vec<f32>,
    pub drow: usize,
    pub dcol: usize,
    pub ngroups: usize,
    pub bits: u32,
}

/// Dead-column handling + dampening + the upper Cholesky factor of H⁻¹.
/// Returns (U, wf) with `wf` the f64 working copy (dead columns zeroed).
fn prepare(
    w: &[f32],
    drow: usize,
    dcol: usize,
    h: &[f64],
    percdamp: f64,
) -> Result<(Vec<f64>, Vec<f64>), String> {
    let mut hh = h.to_vec();
    let mut wf: Vec<f64> = w.iter().map(|&v| v as f64).collect();
    let mut diag_mean = 0.0;
    for j in 0..dcol {
        if hh[j * dcol + j] == 0.0 {
            hh[j * dcol + j] = 1.0;
            for r in 0..drow {
                wf[r * dcol + j] = 0.0;
            }
        }
        diag_mean += hh[j * dcol + j];
    }
    diag_mean /= dcol as f64;
    let damp = percdamp * diag_mean;
    for j in 0..dcol {
        hh[j * dcol + j] += damp;
    }
    let hinv = spd_inverse(&hh, dcol)?;
    let u = cholesky_upper(&hinv, dcol)?;
    Ok((u, wf))
}

/// Quantize one linear layer with GPTQ. `w` is (drow × dcol) row-major,
/// `h` the (dcol × dcol) accumulated Hessian `2 XᵀX` (undamped).
pub fn gptq_quantize(
    w: &[f32],
    drow: usize,
    dcol: usize,
    h: &[f64],
    cfg: &GptqConfig,
) -> Result<QuantResult, String> {
    assert_eq!(w.len(), drow * dcol);
    assert_eq!(h.len(), dcol * dcol);
    if cfg.sparsity != Sparsity::None {
        if cfg.order == Order::ActOrder {
            return Err("sparsity requires natural column order".into());
        }
        if !cfg.use_cholesky {
            return Err("sparsity requires the Cholesky solver".into());
        }
    }
    if cfg.order == Order::ActOrder {
        return gptq_act_order(w, drow, dcol, h, cfg);
    }
    if !cfg.use_cholesky {
        return gptq_naive_inverse(w, drow, dcol, h, cfg);
    }

    let g = if cfg.groupsize == 0 { dcol } else { cfg.groupsize };
    if dcol % g != 0 {
        return Err(format!("groupsize {g} must divide dcol {dcol}"));
    }
    let ngroups = dcol / g;
    let mut bs = cfg.blocksize.min(g).min(dcol).max(1);
    if cfg.sparsity == Sparsity::TwoOfFour {
        // 2:4 mask selection reads all 4 columns of a block from the
        // CURRENT compensated weights, so solver blocks must not split an
        // aligned 4-block: require 4 | dcol, 4 | g, and round bs up to 4.
        if dcol % 4 != 0 {
            return Err(format!("2:4 sparsity requires dcol % 4 == 0 (got {dcol})"));
        }
        if g % 4 != 0 {
            return Err(format!("2:4 sparsity requires groupsize % 4 == 0 (got {g})"));
        }
        bs = (bs.div_ceil(4) * 4).min(g).min(dcol);
    }
    let bs = bs;

    let (u, mut wf) = prepare(w, drow, dcol, h, cfg.percdamp)?;
    let mut codes = vec![0u8; drow * dcol];
    let mut wq64 = vec![0.0f64; drow * dcol];
    let mut scales = vec![0.0f32; drow * ngroups];
    let mut zeros = vec![0.0f32; drow * ngroups];
    let grouped = cfg.groupsize != 0;

    // Rows are independent given the shared factor U: every per-row
    // buffer (wf, codes, wq, grids, err) partitions by row, so contiguous
    // row ranges can run on separate workers with identical arithmetic —
    // bit-identical results at any thread count.
    let pool = if drow >= 2 && drow * dcol >= GPTQ_PAR_MIN_ELEMS {
        Pool::global()
    } else {
        Pool::serial()
    };
    let nw = pool.nthreads().min(drow.max(1));
    if nw > 1 {
        let ranges = par::split_ranges(drow, nw);
        let wf_p = par::SliceParts::new(&mut wf);
        let codes_p = par::SliceParts::new(&mut codes);
        let wq_p = par::SliceParts::new(&mut wq64);
        let sc_p = par::SliceParts::new(&mut scales);
        let zr_p = par::SliceParts::new(&mut zeros);
        let ranges_ref = &ranges;
        pool.run(ranges_ref.len(), |wi| {
            let r = ranges_ref[wi].clone();
            let (rs, re) = (r.start, r.end);
            // SAFETY: worker ranges are pairwise disjoint rows
            let (wfs, cds, wqs, scs, zrs) = unsafe {
                (
                    wf_p.range(rs * dcol..re * dcol),
                    codes_p.range(rs * dcol..re * dcol),
                    wq_p.range(rs * dcol..re * dcol),
                    sc_p.range(rs * ngroups..re * ngroups),
                    zr_p.range(rs * ngroups..re * ngroups),
                )
            };
            gptq_rows(
                &u,
                wfs,
                cds,
                wqs,
                scs,
                zrs,
                re - rs,
                dcol,
                g,
                ngroups,
                bs,
                cfg.bits,
                grouped,
                cfg.sparsity,
            );
        });
    } else {
        gptq_rows(
            &u,
            &mut wf,
            &mut codes,
            &mut wq64,
            &mut scales,
            &mut zeros,
            drow,
            dcol,
            g,
            ngroups,
            bs,
            cfg.bits,
            grouped,
            cfg.sparsity,
        );
    }

    Ok(QuantResult {
        codes,
        scales,
        zeros,
        wq: wq64.iter().map(|&v| v as f32).collect(),
        drow,
        dcol,
        ngroups,
        bits: cfg.bits,
    })
}

/// The natural-order column loop over a contiguous slice of rows — the
/// serial core of [`gptq_quantize`]. All buffers are row-sliced
/// (`nrows × dcol` / `nrows × ngroups`); `u` is the shared Cholesky
/// factor. Per-row arithmetic (grids included: [`quant_params`] is
/// per-row min-max) never reads another row, so any row partition
/// produces bit-identical output.
///
/// Sparsity (SparseGPT, solved jointly in this same sweep): a pruned
/// weight is "quantized" to the zero-point code (dequantizes to exactly
/// 0.0) and its full value propagates as error `w/d` through the
/// unchanged compensation path below. With `Sparsity::None` no mask code
/// executes and the arithmetic is bit-identical to the pre-sparsity
/// solver (pinned by `tests/sparsity.rs`).
#[allow(clippy::too_many_arguments)]
fn gptq_rows(
    u: &[f64],
    wf: &mut [f64],
    codes: &mut [u8],
    wq64: &mut [f64],
    scales: &mut [f32],
    zeros: &mut [f32],
    nrows: usize,
    dcol: usize,
    g: usize,
    ngroups: usize,
    bs: usize,
    bits: u32,
    grouped: bool,
    sparsity: Sparsity,
) {
    let maxq = ((1u32 << bits) - 1) as f64;
    let sparse = sparsity != Sparsity::None;

    // per-row grid from the ORIGINAL weights when ungrouped (paper default)
    if !grouped {
        let wf32: Vec<f32> = wf.iter().map(|&v| v as f32).collect();
        let grid = quant_params(&wf32, nrows, dcol, bits);
        for r in 0..nrows {
            scales[r * ngroups] = grid.scale[r];
            zeros[r * ngroups] = grid.zero[r];
        }
    }

    let mut err = vec![0.0f64; nrows * bs];
    let mut group_buf = vec![0.0f32; nrows * g];
    // prune mask for the current solver block (row-major, nrows × bs)
    let mut prune: Vec<bool> = if sparse { vec![false; nrows * bs] } else { Vec::new() };
    let mut sal: Vec<f64> = if sparse { vec![0.0; bs] } else { Vec::new() };
    let mut i1 = 0;
    while i1 < dcol {
        let i2 = (i1 + bs).min(dcol);
        let bw = i2 - i1;
        if sparsity == Sparsity::Unstructured50 {
            // SparseGPT iterative blocking: per row, prune the ⌊bw/2⌋
            // lowest-saliency columns of this block, judged from the
            // weights as compensated by all previous blocks.
            let k = bw / 2;
            for r in 0..nrows {
                for (bj, j) in (i1..i2).enumerate() {
                    let d = u[j * dcol + j];
                    let wv = wf[r * dcol + j];
                    sal[bj] = (wv * wv) / (d * d);
                }
                let pr = &mut prune[r * bs..r * bs + bw];
                pr.fill(false);
                sparse::mask_smallest_k(&sal[..bw], k, pr);
            }
        }
        for j in i1..i2 {
            // group boundary: refresh grid from the CURRENT compensated
            // weights ("always the most current updated weights")
            if grouped && j % g == 0 {
                for r in 0..nrows {
                    for c in 0..g {
                        group_buf[r * g + c] = wf[r * dcol + j + c] as f32;
                    }
                }
                let grid = quant_params(&group_buf, nrows, g, bits);
                let gi = j / g;
                for r in 0..nrows {
                    scales[r * ngroups + gi] = grid.scale[r];
                    zeros[r * ngroups + gi] = grid.zero[r];
                }
            }
            if sparsity == Sparsity::TwoOfFour && j % 4 == 0 {
                // 2:4 mask for the aligned block j..j+4, chosen per row
                // from the current compensated weights (bs % 4 == 0, so
                // the whole block lies inside this solver block).
                for r in 0..nrows {
                    let mut s4 = [0.0f64; 4];
                    for (c, sv) in s4.iter_mut().enumerate() {
                        let d = u[(j + c) * dcol + j + c];
                        let wv = wf[r * dcol + j + c];
                        *sv = (wv * wv) / (d * d);
                    }
                    let m = sparse::mask_2of4(&s4);
                    for c in 0..4 {
                        prune[r * bs + (j - i1) + c] = m[c];
                    }
                }
            }
            let gi = j / g;
            let d = u[j * dcol + j];
            let urow = &u[j * dcol..(j + 1) * dcol];
            for r in 0..nrows {
                let s = scales[r * ngroups + gi] as f64;
                let z = zeros[r * ngroups + gi] as f64;
                let wv = wf[r * dcol + j];
                let (q, dq) = if sparse && prune[r * bs + (j - i1)] {
                    // prune: the zero-point is an integral code, so this
                    // dequantizes to exactly 0.0 through any pack path
                    (z, 0.0)
                } else {
                    quantize_value(wv, s, z, maxq)
                };
                codes[r * dcol + j] = q as u8;
                wq64[r * dcol + j] = dq;
                let e = (wv - dq) / d;
                err[r * bs + (j - i1)] = e;
                // in-block compensation (columns j+1..i2)
                let wrow = &mut wf[r * dcol + j + 1..r * dcol + i2];
                for (wv, &uv) in wrow.iter_mut().zip(&urow[j + 1..i2]) {
                    *wv -= e * uv;
                }
            }
        }
        // batched tail update: W[:, i2..] -= Err · U[i1..i2, i2..]  (Eq. 4)
        if i2 < dcol {
            let tail = dcol - i2;
            // build the U block (bw × tail) contiguously for the matmul
            let mut ub = vec![0.0f64; bw * tail];
            for bj in 0..bw {
                ub[bj * tail..(bj + 1) * tail]
                    .copy_from_slice(&u[(i1 + bj) * dcol + i2..(i1 + bj + 1) * dcol]);
            }
            // stride-aware accumulate into wf[:, i2..]
            for r in 0..nrows {
                let erow = &err[r * bs..r * bs + bw];
                let wrow = &mut wf[r * dcol + i2..(r + 1) * dcol];
                for (bj, &e) in erow.iter().enumerate() {
                    if e == 0.0 {
                        continue;
                    }
                    let urow = &ub[bj * tail..(bj + 1) * tail];
                    for (wv, &uv) in wrow.iter_mut().zip(urow) {
                        *wv -= e * uv;
                    }
                }
            }
        }
        i1 = i2;
    }
}

/// Act-order variant: quantize columns by decreasing Hessian diagonal.
/// Implemented by permuting (W, H), running the natural-order solver, and
/// un-permuting. Grouped grids would regroup non-adjacent columns, so this
/// path requires `groupsize == 0`.
fn gptq_act_order(
    w: &[f32],
    drow: usize,
    dcol: usize,
    h: &[f64],
    cfg: &GptqConfig,
) -> Result<QuantResult, String> {
    if cfg.groupsize != 0 {
        return Err("act-order requires groupsize == 0".into());
    }
    let mut perm: Vec<usize> = (0..dcol).collect();
    perm.sort_by(|&a, &b| {
        h[b * dcol + b].partial_cmp(&h[a * dcol + a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut wp = vec![0.0f32; drow * dcol];
    for r in 0..drow {
        for (c, &p) in perm.iter().enumerate() {
            wp[r * dcol + c] = w[r * dcol + p];
        }
    }
    let mut hp = vec![0.0f64; dcol * dcol];
    for (i, &pi) in perm.iter().enumerate() {
        for (j, &pj) in perm.iter().enumerate() {
            hp[i * dcol + j] = h[pi * dcol + pj];
        }
    }
    let inner = GptqConfig { order: Order::Natural, ..cfg.clone() };
    let rp = gptq_quantize(&wp, drow, dcol, &hp, &inner)?;
    let mut out = rp.clone();
    for r in 0..drow {
        for (c, &p) in perm.iter().enumerate() {
            out.codes[r * dcol + p] = rp.codes[r * dcol + c];
            out.wq[r * dcol + p] = rp.wq[r * dcol + c];
        }
    }
    Ok(out)
}

/// Stability ablation: the pre-Cholesky formulation that repeatedly applies
/// Eq. (3) to shrink H⁻¹ after every column — O(dcol³) inverse maintenance
/// and the numerically fragile path the paper's Step 3 replaces.
fn gptq_naive_inverse(
    w: &[f32],
    drow: usize,
    dcol: usize,
    h: &[f64],
    cfg: &GptqConfig,
) -> Result<QuantResult, String> {
    if cfg.groupsize != 0 {
        return Err("naive-inverse ablation supports groupsize == 0 only".into());
    }
    let maxq = ((1u32 << cfg.bits) - 1) as f64;
    let mut hh = h.to_vec();
    let mut wf: Vec<f64> = w.iter().map(|&v| v as f64).collect();
    let mut diag_mean = 0.0;
    for j in 0..dcol {
        if hh[j * dcol + j] == 0.0 {
            hh[j * dcol + j] = 1.0;
            for r in 0..drow {
                wf[r * dcol + j] = 0.0;
            }
        }
        diag_mean += hh[j * dcol + j];
    }
    for j in 0..dcol {
        hh[j * dcol + j] += cfg.percdamp * diag_mean / dcol as f64;
    }
    let mut hinv = spd_inverse(&hh, dcol)?;

    let wf32: Vec<f32> = wf.iter().map(|&v| v as f32).collect();
    let grid = quant_params(&wf32, drow, dcol, cfg.bits);
    let mut codes = vec![0u8; drow * dcol];
    let mut wq64 = vec![0.0f64; drow * dcol];

    for j in 0..dcol {
        let d = hinv[j * dcol + j];
        for r in 0..drow {
            let (q, dq) = quantize_value(wf[r * dcol + j], grid.scale[r] as f64, grid.zero[r] as f64, maxq);
            codes[r * dcol + j] = q as u8;
            wq64[r * dcol + j] = dq;
            let e = (wf[r * dcol + j] - dq) / d;
            for c in (j + 1)..dcol {
                wf[r * dcol + c] -= e * hinv[j * dcol + c];
            }
        }
        // Eq. (3): remove row/column j from the inverse by one step of
        // Gaussian elimination — the repeated-update path
        if j + 1 < dcol {
            let hj: Vec<f64> = (0..dcol).map(|c| hinv[j * dcol + c]).collect();
            let scale = 1.0 / d;
            let hcol: Vec<f64> = (0..dcol).map(|r| hinv[r * dcol + j]).collect();
            matmul_acc(&mut hinv, &hcol, &hj, dcol, 1, dcol, -scale);
        }
    }

    let mut scales = vec![0.0f32; drow];
    let mut zeros = vec![0.0f32; drow];
    scales.copy_from_slice(&grid.scale);
    zeros.copy_from_slice(&grid.zero);
    Ok(QuantResult {
        codes,
        scales,
        zeros,
        wq: wq64.iter().map(|&v| v as f32).collect(),
        drow,
        dcol,
        ngroups: 1,
        bits: cfg.bits,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::rtn_quantize;
    use crate::quant::{accumulate_hessian, layer_sq_error};

    fn lcg(seed: &mut u64) -> f32 {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (((*seed >> 33) as f64 / (1u64 << 31) as f64) - 1.0) as f32
    }

    fn case(seed: u64, drow: usize, dcol: usize, n: usize) -> (Vec<f32>, Vec<f64>, Vec<f32>) {
        let mut s = seed;
        let w: Vec<f32> = (0..drow * dcol).map(|_| lcg(&mut s)).collect();
        // correlated inputs: x = raw @ mix
        let mix: Vec<f32> = (0..dcol * dcol).map(|_| lcg(&mut s) / (dcol as f32).sqrt()).collect();
        let mut x = vec![0.0f32; n * dcol];
        for i in 0..n {
            let raw: Vec<f32> = (0..dcol).map(|_| lcg(&mut s)).collect();
            for j in 0..dcol {
                let mut acc = 0.0f32;
                for k in 0..dcol {
                    acc += raw[k] * mix[k * dcol + j];
                }
                x[i * dcol + j] = acc;
            }
            x[i * dcol] *= 6.0; // outlier feature
        }
        let mut h = vec![0.0f64; dcol * dcol];
        accumulate_hessian(&mut h, &x, n, dcol);
        (w, h, x)
    }

    #[test]
    fn beats_rtn_on_correlated_inputs() {
        let (w, h, x) = case(1, 16, 32, 128);
        for bits in [2u32, 3, 4] {
            let g = gptq_quantize(&w, 16, 32, &h, &GptqConfig::new(bits)).unwrap();
            let r = rtn_quantize(&w, 16, 32, bits, 0);
            let eg = layer_sq_error(&w, &g.wq, &x, 16, 32);
            let er = layer_sq_error(&w, &r.wq, &x, 16, 32);
            assert!(eg < er, "bits={bits}: gptq {eg} !< rtn {er}");
        }
    }

    #[test]
    fn blocking_is_exact() {
        let (w, h, _) = case(2, 8, 64, 256);
        let full = gptq_quantize(&w, 8, 64, &h, &GptqConfig { blocksize: 64, ..GptqConfig::new(4) }).unwrap();
        let blocked = gptq_quantize(&w, 8, 64, &h, &GptqConfig { blocksize: 8, ..GptqConfig::new(4) }).unwrap();
        assert_eq!(full.codes, blocked.codes);
        for (a, b) in full.wq.iter().zip(&blocked.wq) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn grouped_grids_shape() {
        let (w, h, _) = case(3, 4, 32, 128);
        let r = gptq_quantize(&w, 4, 32, &h, &GptqConfig::new(3).with_groupsize(8)).unwrap();
        assert_eq!(r.ngroups, 4);
        assert_eq!(r.scales.len(), 16);
        assert_eq!(r.codes.len(), 4 * 32);
    }

    #[test]
    fn finer_groups_reduce_error_at_2bit() {
        let (w, h, x) = case(4, 16, 64, 256);
        let coarse = gptq_quantize(&w, 16, 64, &h, &GptqConfig::new(2)).unwrap();
        let fine = gptq_quantize(&w, 16, 64, &h, &GptqConfig::new(2).with_groupsize(8)).unwrap();
        let ec = layer_sq_error(&w, &coarse.wq, &x, 16, 64);
        let ef = layer_sq_error(&w, &fine.wq, &x, 16, 64);
        assert!(ef < ec, "fine {ef} !< coarse {ec}");
    }

    #[test]
    fn dead_columns_zeroed() {
        let (w, mut h, _) = case(5, 8, 16, 64);
        // kill column 3: zero its H row/col
        for c in 0..16 {
            h[3 * 16 + c] = 0.0;
            h[c * 16 + 3] = 0.0;
        }
        let r = gptq_quantize(&w, 8, 16, &h, &GptqConfig::new(4)).unwrap();
        for row in 0..8 {
            assert!(r.wq[row * 16 + 3].abs() < 1e-6);
        }
        assert!(r.wq.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn act_order_runs_and_is_finite() {
        let (w, h, x) = case(6, 8, 32, 128);
        let cfg = GptqConfig { order: Order::ActOrder, ..GptqConfig::new(3) };
        let r = gptq_quantize(&w, 8, 32, &h, &cfg).unwrap();
        assert!(r.wq.iter().all(|v| v.is_finite()));
        // still a sane quantization: within 3x of natural order error
        let nat = gptq_quantize(&w, 8, 32, &h, &GptqConfig::new(3)).unwrap();
        let ea = layer_sq_error(&w, &r.wq, &x, 8, 32);
        let en = layer_sq_error(&w, &nat.wq, &x, 8, 32);
        assert!(ea < 3.0 * en, "act {ea} vs nat {en}");
    }

    #[test]
    fn naive_inverse_close_to_cholesky_small() {
        // on small well-conditioned problems both formulations agree
        let (w, h, x) = case(7, 4, 16, 64);
        let chol = gptq_quantize(&w, 4, 16, &h, &GptqConfig::new(4)).unwrap();
        let naive = gptq_quantize(&w, 4, 16, &h, &GptqConfig { use_cholesky: false, ..GptqConfig::new(4) }).unwrap();
        let ec = layer_sq_error(&w, &chol.wq, &x, 4, 16);
        let en = layer_sq_error(&w, &naive.wq, &x, 4, 16);
        assert!((ec - en).abs() / ec.max(1e-12) < 0.25, "chol {ec} vs naive {en}");
    }

    #[test]
    fn codes_within_bit_range() {
        let (w, h, _) = case(8, 8, 16, 64);
        for bits in [2u32, 3, 4] {
            let r = gptq_quantize(&w, 8, 16, &h, &GptqConfig::new(bits)).unwrap();
            assert!(r.codes.iter().all(|&c| (c as u32) < (1 << bits)));
        }
    }

    fn sparse_cfg(bits: u32, s: Sparsity) -> GptqConfig {
        GptqConfig { sparsity: s, ..GptqConfig::new(bits) }
    }

    #[test]
    fn unstructured50_hits_half_zeros() {
        let (w, h, _) = case(9, 8, 64, 256);
        let r = gptq_quantize(&w, 8, 64, &h, &sparse_cfg(4, Sparsity::Unstructured50)).unwrap();
        let zeros = r.wq.iter().filter(|v| **v == 0.0).count();
        let frac = zeros as f64 / r.wq.len() as f64;
        // exactly 50% pruned (dcol=64, ⌊64/2⌋ per block-row), plus a few
        // surviving weights that legitimately round to the zero-point
        assert!((0.5..0.62).contains(&frac), "sparsity {frac}");
    }

    #[test]
    fn two_of_four_invariant_on_every_block() {
        for g in [0usize, 16] {
            let (w, h, _) = case(10, 8, 64, 256);
            let cfg = GptqConfig { groupsize: g, ..sparse_cfg(4, Sparsity::TwoOfFour) };
            let r = gptq_quantize(&w, 8, 64, &h, &cfg).unwrap();
            for (bi, block) in r.wq.chunks_exact(4).enumerate() {
                let nz = block.iter().filter(|v| **v != 0.0).count();
                assert!(nz <= 2, "g={g} block {bi}: {nz} nonzeros {block:?}");
            }
            // exactly half the weights are pruned to exact zeros
            let zeros = r.wq.iter().filter(|v| **v == 0.0).count();
            assert!(zeros >= r.wq.len() / 2, "g={g}: only {zeros} zeros");
        }
    }

    #[test]
    fn sparse_blocking_is_exact_for_2of4() {
        // 2:4 masks depend only on aligned 4-blocks, never on the solver
        // block size, so blocking stays a pure perf knob for this policy
        let (w, h, _) = case(11, 6, 64, 256);
        let full =
            gptq_quantize(&w, 6, 64, &h, &GptqConfig { blocksize: 64, ..sparse_cfg(4, Sparsity::TwoOfFour) })
                .unwrap();
        let blocked =
            gptq_quantize(&w, 6, 64, &h, &GptqConfig { blocksize: 8, ..sparse_cfg(4, Sparsity::TwoOfFour) })
                .unwrap();
        assert_eq!(full.codes, blocked.codes);
        for (a, b) in full.wq.iter().zip(&blocked.wq) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn joint_solve_beats_prune_after_quantize() {
        // the SparseGPT claim in miniature: propagating pruning error
        // through the Cholesky compensation beats magnitude-pruning the
        // already-quantized weights
        let (w, h, x) = case(12, 16, 64, 256);
        let joint = gptq_quantize(&w, 16, 64, &h, &sparse_cfg(4, Sparsity::TwoOfFour)).unwrap();
        let mut after = gptq_quantize(&w, 16, 64, &h, &GptqConfig::new(4)).unwrap();
        crate::quant::sparse::prune_2of4_by_magnitude(&mut after);
        let ej = layer_sq_error(&w, &joint.wq, &x, 16, 64);
        let ea = layer_sq_error(&w, &after.wq, &x, 16, 64);
        assert!(ej < ea, "joint {ej} !< prune-after {ea}");
    }

    #[test]
    fn sparsity_rejects_ablation_paths_and_bad_shapes() {
        let (w, h, _) = case(13, 4, 16, 64);
        let act = GptqConfig { order: Order::ActOrder, ..sparse_cfg(4, Sparsity::TwoOfFour) };
        assert!(gptq_quantize(&w, 4, 16, &h, &act).is_err());
        let naive = GptqConfig { use_cholesky: false, ..sparse_cfg(4, Sparsity::Unstructured50) };
        assert!(gptq_quantize(&w, 4, 16, &h, &naive).is_err());
        // dcol not a multiple of 4
        let (w2, h2, _) = case(14, 4, 18, 64);
        assert!(gptq_quantize(&w2, 4, 18, &h2, &sparse_cfg(4, Sparsity::TwoOfFour)).is_err());
    }
}
