//! Uniform asymmetric min-max quantization grids (paper §4 Setup:
//! "standard uniform per-row asymmetric quantization on the min-max grid").
//!
//! Semantics mirror `ref.quant_params` / `ref.quantize_col` exactly,
//! including numpy's round-half-to-even (`round_ties_even`).

/// A per-row grid for one group of consecutive columns: `scale`/`zero`
/// have one entry per output row. `zero` is the integer-valued code that
/// dequantizes to 0.0.
#[derive(Debug, Clone)]
pub struct Grid {
    pub scale: Vec<f32>,
    pub zero: Vec<f32>,
    pub bits: u32,
}

impl Grid {
    pub fn maxq(&self) -> f32 {
        ((1u32 << self.bits) - 1) as f32
    }
}

/// Compute the per-row asymmetric min-max grid over a (drow × dcol)
/// row-major slice. The range is widened to include 0 and degenerate rows
/// (min == max) get a symmetric unit range — identical to the oracle.
pub fn quant_params(w: &[f32], drow: usize, dcol: usize, bits: u32) -> Grid {
    assert_eq!(w.len(), drow * dcol);
    let maxq = ((1u32 << bits) - 1) as f32;
    let mut scale = Vec::with_capacity(drow);
    let mut zero = Vec::with_capacity(drow);
    for row in w.chunks_exact(dcol) {
        let mut wmin = 0.0f32;
        let mut wmax = 0.0f32;
        for &v in row {
            wmin = wmin.min(v);
            wmax = wmax.max(v);
        }
        if wmin == wmax {
            wmin -= 0.5;
            wmax += 0.5;
        }
        let s = (wmax - wmin) / maxq;
        scale.push(s);
        zero.push((-wmin / s).round_ties_even());
    }
    Grid { scale, zero, bits }
}

/// Quantize a single value against (scale, zero); returns (code, dequant).
/// f64 arithmetic, matching the oracle's float64 path inside GPTQ.
#[inline]
pub fn quantize_value(w: f64, scale: f64, zero: f64, maxq: f64) -> (f64, f64) {
    let q = ((w / scale).round_ties_even() + zero).clamp(0.0, maxq);
    (q, scale * (q - zero))
}

/// f32 twin of [`quantize_value`] (the RTN fast path).
#[inline]
pub fn quantize_value_f32(w: f32, scale: f32, zero: f32, maxq: f32) -> (f32, f32) {
    let q = ((w / scale).round_ties_even() + zero).clamp(0.0, maxq);
    (q, scale * (q - zero))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_range() {
        let w = [-1.0f32, 0.0, 0.5, 2.0];
        let g = quant_params(&w, 1, 4, 4);
        assert_eq!(g.scale.len(), 1);
        // grid must represent both extremes with ≤ half-step error
        for &v in &w {
            let (_, dq) = quantize_value_f32(v, g.scale[0], g.zero[0], g.maxq());
            assert!((dq - v).abs() <= g.scale[0] / 2.0 + 1e-6, "{v} -> {dq}");
        }
    }

    #[test]
    fn zero_is_exact() {
        // the grid always contains exactly 0.0 (zero-point quantization)
        let w = [-0.73f32, 0.41, 0.02, 1.3, -0.9, 0.88];
        let g = quant_params(&w, 2, 3, 3);
        for r in 0..2 {
            let (_, dq) = quantize_value_f32(0.0, g.scale[r], g.zero[r], g.maxq());
            assert_eq!(dq, 0.0);
        }
    }

    #[test]
    fn degenerate_row_unit_range() {
        let w = [0.0f32; 4];
        let g = quant_params(&w, 1, 4, 4);
        assert!((g.scale[0] - 1.0 / 15.0).abs() < 1e-7);
        let (_, dq) = quantize_value_f32(0.0, g.scale[0], g.zero[0], 15.0);
        assert_eq!(dq, 0.0);
    }

    #[test]
    fn positive_only_row_still_contains_zero() {
        let w = [0.5f32, 1.0, 2.0, 3.0];
        let g = quant_params(&w, 1, 4, 2);
        assert_eq!(g.zero[0], 0.0); // wmin widened to 0
        let (q, dq) = quantize_value_f32(3.0, g.scale[0], g.zero[0], 3.0);
        assert_eq!(q, 3.0);
        assert!((dq - 3.0).abs() < 1e-6);
    }

    #[test]
    fn codes_clamped() {
        let g = Grid { scale: vec![0.1], zero: vec![1.0], bits: 2 };
        let (q, _) = quantize_value_f32(100.0, 0.1, 1.0, g.maxq());
        assert_eq!(q, 3.0);
        let (q, _) = quantize_value_f32(-100.0, 0.1, 1.0, g.maxq());
        assert_eq!(q, 0.0);
    }

    #[test]
    fn round_ties_even_matches_numpy() {
        // numpy rounds 0.5 -> 0, 1.5 -> 2, 2.5 -> 2
        assert_eq!(0.5f32.round_ties_even(), 0.0);
        assert_eq!(1.5f32.round_ties_even(), 2.0);
        assert_eq!(2.5f32.round_ties_even(), 2.0);
    }
}
