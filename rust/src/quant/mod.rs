//! Quantization substrate: the paper's algorithms as pure Rust.
//!
//! Everything here mirrors `python/compile/kernels/ref.py` (the canonical
//! semantics) and is cross-checked against it bit-exactly through the
//! golden vectors in `artifacts/golden.json`.
//!
//! * [`grid`] — uniform asymmetric min-max grids, per-row and grouped.
//! * [`linalg`] — f64 Cholesky factorization / SPD inverse (paper Step 3).
//! * [`rtn`] — round-to-nearest, the baseline of every prior LLM
//!   quantization work the paper compares to (§2 Large-model Quantization).
//! * [`obq`] — full greedy Optimal Brain Quantization (paper §3.2), the
//!   accurate-but-cubic method GPTQ accelerates; used for Table 1/7 and
//!   the Fig. 3 runtime extrapolation.
//! * [`gptq`] — the paper's contribution (§3.3): fixed column order,
//!   blocked compensation, Cholesky-factored inverse Hessian, with
//!   ablation switches (greedy order, naive inverse, no damping).
//! * [`pack`] — 2/3/4/8-bit code packing into `u32` words (the storage
//!   format of the inference kernel).
//! * [`sparse`] — SparseGPT-style joint sparsify+quantize: mask policies
//!   (50% unstructured, 2:4 semi-structured) solved inside the GPTQ
//!   column sweep, plus the 2:4 pack format the sparse kernels execute.

pub mod gptq;
pub mod grid;
pub mod linalg;
pub mod obq;
pub mod pack;
pub mod rtn;
pub mod sparse;

pub use gptq::{gptq_quantize, GptqConfig, Order, QuantResult};
pub use grid::{quant_params, quantize_value, Grid};
pub use obq::obq_quantize;
pub use pack::PackedMatrix;
pub use rtn::rtn_quantize;
pub use sparse::{Sparse24Matrix, Sparsity};

/// Below this many input elements (`n · dcol`) Hessian accumulation
/// stays serial (DESIGN.md §Parallelism, threshold rationale).
pub const HESSIAN_PAR_MIN_ELEMS: usize = 1 << 12;

/// Hessian accumulation: `H += 2 XᵀX` for a batch of rows `x` (n × dcol),
/// row-major, into the f64 accumulator `h` (dcol × dcol).
///
/// The f64 accumulator mirrors the paper's numerical-stability care; the
/// XLA-side twin is the L1 Pallas kernel `kernels/hessian.py`.
///
/// Parallelism partitions the OUTPUT rows of H (disjoint writes), not the
/// samples: every H entry is a left fold over samples 0..n in both the
/// sample-major serial loop and the row-range parallel loop, so results
/// are bit-identical at every thread count. (Per-worker partial-H
/// reduction was rejected: summing partials reorders the f64 fold.)
pub fn accumulate_hessian(h: &mut [f64], x: &[f32], n: usize, dcol: usize) {
    assert_eq!(h.len(), dcol * dcol);
    assert_eq!(x.len(), n * dcol);
    let pool = if n * dcol >= HESSIAN_PAR_MIN_ELEMS && dcol > 1 {
        crate::util::par::Pool::global()
    } else {
        crate::util::par::Pool::serial()
    };
    if pool.nthreads() <= 1 {
        // sample-major: one streaming pass over x (cache-friendly)
        for row in x.chunks_exact(dcol) {
            for i in 0..dcol {
                let xi = 2.0 * row[i] as f64;
                let hrow = &mut h[i * dcol..(i + 1) * dcol];
                for (hj, &xj) in hrow.iter_mut().zip(row) {
                    *hj += xi * xj as f64;
                }
            }
        }
        return;
    }
    // H-row-major: each worker re-streams x but owns a disjoint row range;
    // per-entry fold order over samples is identical to the serial loop
    crate::util::par::for_rows_mut(&pool, h, dcol, dcol, |rows, hrows| {
        for row in x.chunks_exact(dcol) {
            for (oi, i) in rows.clone().enumerate() {
                let xi = 2.0 * row[i] as f64;
                let hrow = &mut hrows[oi * dcol..(oi + 1) * dcol];
                for (hj, &xj) in hrow.iter_mut().zip(row) {
                    *hj += xi * xj as f64;
                }
            }
        }
    });
}

/// Layer-wise objective of paper Eq. (1): `||WX − ŴX||² / n` with X given
/// row-major (n × dcol); `w`/`wq` are (drow × dcol) row-major.
pub fn layer_sq_error(w: &[f32], wq: &[f32], x: &[f32], drow: usize, dcol: usize) -> f64 {
    let n = x.len() / dcol;
    let mut total = 0.0f64;
    let mut diff = vec![0.0f32; dcol];
    for r in 0..drow {
        for c in 0..dcol {
            diff[c] = w[r * dcol + c] - wq[r * dcol + c];
        }
        for xr in x.chunks_exact(dcol) {
            let mut dot = 0.0f64;
            for c in 0..dcol {
                dot += (diff[c] * xr[c]) as f64;
            }
            total += dot * dot;
        }
    }
    total / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hessian_matches_naive() {
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3 rows x 2 cols
        let mut h = vec![0.0f64; 4];
        accumulate_hessian(&mut h, &x, 3, 2);
        // H = 2 XtX
        let xtx = [
            1.0 + 9.0 + 25.0,
            2.0 + 12.0 + 30.0,
            2.0 + 12.0 + 30.0,
            4.0 + 16.0 + 36.0,
        ];
        for (a, b) in h.iter().zip(xtx) {
            assert!((a - 2.0 * b).abs() < 1e-9, "{a} vs {}", 2.0 * b);
        }
    }

    #[test]
    fn hessian_accumulates_over_batches() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let mut h1 = vec![0.0f64; 4];
        accumulate_hessian(&mut h1, &x, 2, 2);
        let mut h2 = vec![0.0f64; 4];
        accumulate_hessian(&mut h2, &x[..2], 1, 2);
        accumulate_hessian(&mut h2, &x[2..], 1, 2);
        assert_eq!(h1, h2);
    }

    #[test]
    fn sq_error_zero_for_identical() {
        let w = [1.0f32, -2.0, 0.5, 3.0];
        let x = [0.3f32, -0.7, 1.1, 0.2];
        assert_eq!(layer_sq_error(&w, &w, &x, 2, 2), 0.0);
    }

    #[test]
    fn sq_error_positive_and_scales() {
        let w = [1.0f32, 0.0, 0.0, 1.0];
        let wq = [0.0f32, 0.0, 0.0, 0.0];
        let x = [1.0f32, 0.0, 0.0, 1.0];
        let e = layer_sq_error(&w, &wq, &x, 2, 2);
        assert!((e - 1.0).abs() < 1e-12, "{e}");
    }
}
