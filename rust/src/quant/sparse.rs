//! Joint sparsify+quantize support (SparseGPT; Frantar & Alistarh 2023).
//!
//! SparseGPT's key observation is that the GPTQ column solver already
//! contains everything one-shot pruning needs: walking columns left to
//! right with the Cholesky-factored inverse Hessian, *zeroing* a weight is
//! just another quantization target — the OBS error `w²/[H⁻¹]ⱼⱼ` ranks
//! which weights to prune, and the pruning error `w/d` propagates through
//! the exact same compensation path as quantization error. This module
//! holds the mask-selection policies consumed by `gptq::gptq_rows` and the
//! 2:4 semi-structured pack format the sparse kernels execute.
//!
//! Policies ([`Sparsity`]):
//! * `Unstructured50` — per solver block of B columns, each row prunes the
//!   ⌊B/2⌋ columns with the smallest saliency `w²/d²` (d = the Cholesky
//!   diagonal, so `d² = [H⁻¹_F]ⱼⱼ` at the step the column is reached).
//! * `TwoOfFour` — per aligned group of 4 columns, each row keeps the 2
//!   with the largest saliency; the hardware-friendly 2:4 pattern.
//!
//! Pruned weights quantize to the *zero-point code*: the asymmetric grid
//! widens to include 0 ([`crate::quant::grid::quant_params`]), so `zero`
//! is an integral code in `[0, maxq]` and `s·(zero − zero) == 0.0`
//! exactly. That means unstructured-sparse layers round-trip through the
//! ordinary dense [`crate::quant::pack::PackedMatrix`] unchanged, while
//! 2:4 layers can additionally drop into [`Sparse24Matrix`], which stores
//! only the two surviving codes per block plus a 2-bit-pair index nibble.
//!
//! Determinism: mask selection is per-row arithmetic over row-local
//! state (ties broken by column index via a total order), so the solver's
//! threads=N ≡ threads=1 bitwise contract is preserved.

use super::gptq::QuantResult;

/// Weight-sparsity policy solved jointly with quantization.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Sparsity {
    /// Dense — the solver is bit-identical to the pre-sparsity GPTQ path.
    #[default]
    None,
    /// 50% unstructured, selected per solver block by OBS saliency.
    Unstructured50,
    /// 2:4 semi-structured — exactly 2 survivors per 4 aligned columns.
    TwoOfFour,
}

impl Sparsity {
    /// CLI name (`--sparsity {none,unstructured50,2of4}`).
    pub fn name(self) -> &'static str {
        match self {
            Sparsity::None => "none",
            Sparsity::Unstructured50 => "unstructured50",
            Sparsity::TwoOfFour => "2of4",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "none" | "dense" => Some(Sparsity::None),
            "unstructured50" | "unstructured" | "50" => Some(Sparsity::Unstructured50),
            "2of4" | "2:4" | "24" => Some(Sparsity::TwoOfFour),
            _ => None,
        }
    }

    /// `GPTQ_SPARSITY` env (same contract as `GPTQ_ISA` / `GPTQ_KV_DTYPE`);
    /// unset or unparsable → `None` (dense).
    pub fn from_env() -> Self {
        std::env::var("GPTQ_SPARSITY").ok().and_then(|v| Self::parse(&v)).unwrap_or_default()
    }
}

impl std::fmt::Display for Sparsity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Mark the `k` smallest saliencies in `sal` as pruned (`prune[i] = true`).
/// Ties break by column index (total order), so the mask is deterministic
/// for any input — including duplicated saliencies and dead columns.
pub fn mask_smallest_k(sal: &[f64], k: usize, prune: &mut [bool]) {
    debug_assert_eq!(sal.len(), prune.len());
    let mut order: Vec<usize> = (0..sal.len()).collect();
    order.sort_unstable_by(|&a, &b| sal[a].total_cmp(&sal[b]).then(a.cmp(&b)));
    for &i in order.iter().take(k.min(sal.len())) {
        prune[i] = true;
    }
}

/// The 2:4 policy for one aligned block: prune the 2 smallest of the 4
/// saliencies (ties by index). Always prunes exactly two.
pub fn mask_2of4(sal: &[f64; 4]) -> [bool; 4] {
    let mut order = [0usize, 1, 2, 3];
    order.sort_unstable_by(|&a, &b| sal[a].total_cmp(&sal[b]).then(a.cmp(&b)));
    let mut m = [false; 4];
    m[order[0]] = true;
    m[order[1]] = true;
    m
}

/// 2:4 semi-structured packed matrix: per 4-column block only the two
/// surviving codes are stored (a contiguous little-endian code stream at
/// `bits` per code, like [`crate::quant::pack::pack_row`]) plus one index
/// nibble `(i1 << 2) | i0` with `i0 < i1` naming the surviving columns.
///
/// Both streams are padded to a whole `u32` word *per group*, so every
/// group starts word-aligned and the kernels never straddle a group
/// boundary mid-word. At 4-bit this stores 12 bits per 4 weights against
/// the dense packed format's 16 — a 1.33× weight-traffic cut on top of
/// halving the multiply count, which is where the batch-1 decode speedup
/// comes from (the matvec is memory-bound; see DESIGN.md §Sparsity).
///
/// Grids (`scales`/`zeros`) are per row × group exactly as in
/// `PackedMatrix`, and `s·(zero − zero) == 0.0` keeps padded survivor
/// slots (blocks with fewer than 2 nonzero codes) exact zeros.
#[derive(Debug, Clone, PartialEq)]
pub struct Sparse24Matrix {
    /// Surviving codes, `drow × (ngroups · pair_wpg)` words.
    pub pair_words: Vec<u32>,
    /// Index nibbles, `drow × (ngroups · idx_wpg)` words (8 nibbles/word).
    pub idx_words: Vec<u32>,
    /// Per row × group scale, `drow × ngroups`.
    pub scales: Vec<f32>,
    /// Per row × group zero point (an integral code), `drow × ngroups`.
    pub zeros: Vec<f32>,
    pub drow: usize,
    pub dcol: usize,
    pub ngroups: usize,
    pub bits: u32,
    /// Pair-code words per group: `ceil((group/2) / (32/bits))`.
    pub pair_wpg: usize,
    /// Index words per group: `ceil((group/4) / 8)`.
    pub idx_wpg: usize,
}

impl Sparse24Matrix {
    /// Pack a solver result whose codes satisfy the 2:4 invariant (at most
    /// 2 non-zero-point codes per aligned 4-block — the output of
    /// `gptq_quantize` with [`Sparsity::TwoOfFour`]). Survivors are the
    /// non-zero-point codes, padded to exactly 2 with the lowest-index
    /// zero-point columns (which dequantize to exactly 0.0, so the padding
    /// is value-neutral). Errors if any block has 3+ nonzero codes.
    pub fn from_result(q: &QuantResult) -> Result<Self, String> {
        let (drow, dcol, ngroups, bits) = (q.drow, q.dcol, q.ngroups, q.bits);
        if dcol % 4 != 0 {
            return Err(format!("sparse24: dcol {dcol} not a multiple of 4"));
        }
        if dcol % ngroups != 0 {
            return Err(format!("sparse24: ngroups {ngroups} does not divide dcol {dcol}"));
        }
        let group = dcol / ngroups;
        if group % 4 != 0 {
            return Err(format!("sparse24: group {group} not a multiple of 4"));
        }
        if !(1..=8).contains(&bits) {
            return Err(format!("sparse24: unsupported bit width {bits}"));
        }
        let cpw = (32 / bits) as usize;
        let nblocks = group / 4;
        let pair_wpg = (group / 2).div_ceil(cpw);
        let idx_wpg = nblocks.div_ceil(8);
        let npw = ngroups * pair_wpg;
        let niw = ngroups * idx_wpg;
        let mut pair_words = vec![0u32; drow * npw];
        let mut idx_words = vec![0u32; drow * niw];
        for r in 0..drow {
            for gi in 0..ngroups {
                let zc = q.zeros[r * ngroups + gi] as u32;
                let pw = &mut pair_words[r * npw + gi * pair_wpg..r * npw + (gi + 1) * pair_wpg];
                let iw = &mut idx_words[r * niw + gi * idx_wpg..r * niw + (gi + 1) * idx_wpg];
                for b in 0..nblocks {
                    let col0 = gi * group + b * 4;
                    // survivors: non-zero-point codes, then zero-point
                    // columns in ascending order as value-neutral padding
                    let mut keep = [0usize; 2];
                    let mut nkeep = 0usize;
                    for c in 0..4 {
                        if q.codes[r * dcol + col0 + c] as u32 != zc {
                            if nkeep == 2 {
                                return Err(format!(
                                    "sparse24: row {r} block at col {col0} has 3+ nonzero codes"
                                ));
                            }
                            keep[nkeep] = c;
                            nkeep += 1;
                        }
                    }
                    for c in 0..4 {
                        if nkeep == 2 {
                            break;
                        }
                        if q.codes[r * dcol + col0 + c] as u32 == zc {
                            // keep `keep` sorted ascending (i0 < i1)
                            if nkeep == 1 && keep[0] > c {
                                keep[1] = keep[0];
                                keep[0] = c;
                            } else {
                                keep[nkeep] = c;
                            }
                            nkeep += 1;
                        }
                    }
                    for (slot, &c) in keep.iter().enumerate() {
                        let k = 2 * b + slot;
                        let code = q.codes[r * dcol + col0 + c] as u32;
                        pw[k / cpw] |= code << ((k % cpw) * bits as usize);
                    }
                    let nib = ((keep[1] as u32) << 2) | keep[0] as u32;
                    iw[b / 8] |= nib << ((b % 8) * 4);
                }
            }
        }
        Ok(Self {
            pair_words,
            idx_words,
            scales: q.scales.clone(),
            zeros: q.zeros.clone(),
            drow,
            dcol,
            ngroups,
            bits,
            pair_wpg,
            idx_wpg,
        })
    }

    /// Words per row in `pair_words`.
    pub fn npair_words(&self) -> usize {
        self.ngroups * self.pair_wpg
    }

    /// Words per row in `idx_words`.
    pub fn nidx_words(&self) -> usize {
        self.ngroups * self.idx_wpg
    }

    /// Dense dequantized matrix (pruned entries exactly 0.0) — the
    /// reference the sparse kernels are tested against.
    pub fn dequantize(&self) -> Vec<f32> {
        let group = self.dcol / self.ngroups;
        let nblocks = group / 4;
        let cpw = (32 / self.bits) as usize;
        let mask = if self.bits == 32 { u32::MAX } else { (1u32 << self.bits) - 1 };
        let (npw, niw) = (self.npair_words(), self.nidx_words());
        let mut out = vec![0.0f32; self.drow * self.dcol];
        for r in 0..self.drow {
            for gi in 0..self.ngroups {
                let s = self.scales[r * self.ngroups + gi];
                let z = self.zeros[r * self.ngroups + gi];
                let pw = &self.pair_words[r * npw + gi * self.pair_wpg..];
                let iw = &self.idx_words[r * niw + gi * self.idx_wpg..];
                for b in 0..nblocks {
                    let nib = (iw[b / 8] >> ((b % 8) * 4)) & 0xF;
                    let (i0, i1) = ((nib & 3) as usize, ((nib >> 2) & 3) as usize);
                    for (slot, idx) in [i0, i1].into_iter().enumerate() {
                        let k = 2 * b + slot;
                        let code = (pw[k / cpw] >> ((k % cpw) * self.bits as usize)) & mask;
                        out[r * self.dcol + gi * group + b * 4 + idx] = s * (code as f32 - z);
                    }
                }
            }
        }
        out
    }

    /// Total resident bytes (codes + indices + grids).
    pub fn storage_bytes(&self) -> usize {
        (self.pair_words.len() + self.idx_words.len()) * 4
            + (self.scales.len() + self.zeros.len()) * 4
    }

    /// Achieved bits per (dense-equivalent) weight including indices and
    /// grids — at 4-bit per-row this approaches `2·4/4 + 1 = 3` bits.
    pub fn effective_bits(&self) -> f64 {
        self.storage_bytes() as f64 * 8.0 / (self.drow * self.dcol) as f64
    }

    /// The 2:4 invariant, checkable on any instance: dequantized blocks
    /// carry at most 2 nonzeros. (`from_result` enforces it on codes; this
    /// re-derives it from values for tests and checkpoint loads.)
    pub fn check_2of4(&self) -> bool {
        let w = self.dequantize();
        w.chunks_exact(4).all(|b| b.iter().filter(|v| **v != 0.0).count() <= 2)
    }
}

/// Magnitude-based 2:4 pruning applied *after* quantization: per aligned
/// 4-block keep the 2 largest `|wq|`, rewriting pruned codes to the
/// zero-point. This is NOT the joint solver (no error compensation) — it
/// exists so kernel tests and benches can produce valid 2:4 operands
/// without a Hessian, and as the naive baseline the joint path beats.
pub fn prune_2of4_by_magnitude(q: &mut QuantResult) {
    assert_eq!(q.dcol % 4, 0, "2:4 pruning needs dcol % 4 == 0");
    let group = q.dcol / q.ngroups;
    assert_eq!(group % 4, 0, "2:4 pruning needs group % 4 == 0");
    for r in 0..q.drow {
        for b in 0..q.dcol / 4 {
            let col0 = b * 4;
            let mut sal = [0.0f64; 4];
            for c in 0..4 {
                let v = q.wq[r * q.dcol + col0 + c] as f64;
                sal[c] = v * v;
            }
            let m = mask_2of4(&sal);
            for c in 0..4 {
                if m[c] {
                    let gi = (col0 + c) / group;
                    q.codes[r * q.dcol + col0 + c] = q.zeros[r * q.ngroups + gi] as u8;
                    q.wq[r * q.dcol + col0 + c] = 0.0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::rand_vec;
    use crate::quant::rtn::rtn_quantize;

    #[test]
    fn parse_and_names_round_trip() {
        for s in [Sparsity::None, Sparsity::Unstructured50, Sparsity::TwoOfFour] {
            assert_eq!(Sparsity::parse(s.name()), Some(s));
        }
        assert_eq!(Sparsity::parse("2:4"), Some(Sparsity::TwoOfFour));
        assert_eq!(Sparsity::parse("bogus"), None);
    }

    #[test]
    fn mask_smallest_k_is_deterministic_on_ties() {
        let sal = [1.0f64, 0.0, 0.0, 0.0, 2.0];
        let mut p = [false; 5];
        mask_smallest_k(&sal, 2, &mut p);
        assert_eq!(p, [false, true, true, false, false]);
    }

    #[test]
    fn mask_2of4_prunes_exactly_two() {
        let m = mask_2of4(&[4.0, 1.0, 3.0, 2.0]);
        assert_eq!(m, [false, true, false, true]);
        let all_equal = mask_2of4(&[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(all_equal.iter().filter(|v| **v).count(), 2);
    }

    #[test]
    fn pack_dequant_round_trips_magnitude_pruned_rtn() {
        for bits in [2u32, 3, 4, 8] {
            for g in [0usize, 16] {
                let (drow, dcol) = (6usize, 48usize);
                let w = rand_vec(drow * dcol, 9 + bits as u64);
                let mut q = rtn_quantize(&w, drow, dcol, bits, g);
                prune_2of4_by_magnitude(&mut q);
                let s = Sparse24Matrix::from_result(&q).unwrap();
                assert!(s.check_2of4());
                let deq = s.dequantize();
                for (i, (a, b)) in deq.iter().zip(&q.wq).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "bits={bits} g={g} i={i}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn from_result_rejects_dense_blocks() {
        let w = rand_vec(4 * 16, 77);
        let q = rtn_quantize(&w, 4, 16, 4, 0);
        // random dense codes essentially surely have a 3+-nonzero block
        assert!(Sparse24Matrix::from_result(&q).is_err());
    }

    #[test]
    fn storage_is_smaller_than_dense_packed() {
        let w = rand_vec(8 * 128, 5);
        let mut q = rtn_quantize(&w, 8, 128, 4, 0);
        prune_2of4_by_magnitude(&mut q);
        let s = Sparse24Matrix::from_result(&q).unwrap();
        let dense = crate::quant::pack::PackedMatrix::from_result(&q);
        assert!(s.storage_bytes() < dense.storage_bytes());
        assert!(s.effective_bits() < 3.5, "{}", s.effective_bits());
    }
}
