//! Minimal f64 dense linear algebra for the GPTQ/OBQ solvers: Cholesky
//! factorization, SPD inverse, and triangular utilities (paper Step 3).
//!
//! Matrices are row-major `Vec<f64>` with explicit dimension — the sizes
//! here (≤ a few thousand) do not justify a BLAS dependency, and keeping
//! the loops visible is what the §Perf pass optimizes.

/// In-place lower Cholesky: `a` (n × n, SPD, row-major) becomes L with
/// `L Lᵀ = A` (upper triangle zeroed). Returns Err on non-SPD input.
pub fn cholesky_lower(a: &mut [f64], n: usize) -> Result<(), String> {
    assert_eq!(a.len(), n * n);
    for j in 0..n {
        let mut d = a[j * n + j];
        for k in 0..j {
            d -= a[j * n + k] * a[j * n + k];
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(format!("matrix not SPD at pivot {j} (d = {d})"));
        }
        let d = d.sqrt();
        a[j * n + j] = d;
        for i in (j + 1)..n {
            let mut s = a[i * n + j];
            // split_at_mut-free dot over previously-computed columns
            for k in 0..j {
                s -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] = s / d;
        }
        for k in (j + 1)..n {
            a[j * n + k] = 0.0;
        }
    }
    Ok(())
}

/// Invert an SPD matrix via its Cholesky factor: returns `A⁻¹`.
///
/// §Perf: solves for ALL right-hand sides at once with row-streaming
/// axpy updates (contiguous row-major access) instead of per-column
/// strided substitution — ~6x faster at n = 1024 (EXPERIMENTS.md §Perf).
pub fn spd_inverse(a: &[f64], n: usize) -> Result<Vec<f64>, String> {
    let mut l = a.to_vec();
    cholesky_lower(&mut l, n)?;
    // forward: Y = L⁻¹ · I, row by row (row i only reads rows k < i)
    let mut y = vec![0.0f64; n * n];
    for i in 0..n {
        y[i * n + i] = 1.0;
        let (head, tail) = y.split_at_mut(i * n);
        let yrow = &mut tail[..n];
        for k in 0..i {
            let lik = l[i * n + k];
            if lik == 0.0 {
                continue;
            }
            let ykrow = &head[k * n..k * n + n];
            // I is lower-triangular along the way: columns > i stay 0
            for (yv, &kv) in yrow[..=i].iter_mut().zip(&ykrow[..=i]) {
                *yv -= lik * kv;
            }
        }
        let d = 1.0 / l[i * n + i];
        for yv in yrow[..=i].iter_mut() {
            *yv *= d;
        }
    }
    // backward: X = L⁻ᵀ · Y, rows from the bottom (row i reads rows k > i)
    let mut inv = y;
    for i in (0..n).rev() {
        let (head, tail) = inv.split_at_mut((i + 1) * n);
        let xrow = &mut head[i * n..];
        for k in (i + 1)..n {
            let lki = l[k * n + i];
            if lki == 0.0 {
                continue;
            }
            let xkrow = &tail[(k - i - 1) * n..(k - i - 1) * n + n];
            for (xv, &kv) in xrow.iter_mut().zip(xkrow) {
                *xv -= lki * kv;
            }
        }
        let d = 1.0 / l[i * n + i];
        for xv in xrow.iter_mut() {
            *xv *= d;
        }
    }
    // exact symmetrization (the solves introduce last-ulp asymmetry)
    for i in 0..n {
        for j in (i + 1)..n {
            let v = 0.5 * (inv[i * n + j] + inv[j * n + i]);
            inv[i * n + j] = v;
            inv[j * n + i] = v;
        }
    }
    Ok(inv)
}

/// Upper Cholesky factor U with `UᵀU = A` (SPD). This is the factor GPTQ
/// consumes: rows of U are the precomputed "remaining Hessian inverse"
/// rows of paper Step 3.
pub fn cholesky_upper(a: &[f64], n: usize) -> Result<Vec<f64>, String> {
    let mut l = a.to_vec();
    cholesky_lower(&mut l, n)?;
    let mut u = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            u[j * n + i] = l[i * n + j];
        }
    }
    Ok(u)
}

/// `C += A · B` for row-major slices: A (m × k), B (k × n), C (m × n),
/// with a scaling factor: `C += alpha * A·B`. ikj loop order (stream B
/// rows) — the cache-friendly form the §Perf pass validated.
pub fn matmul_acc(c: &mut [f64], a: &[f64], b: &[f64], m: usize, k: usize, n: usize, alpha: f64) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            let s = alpha * aik;
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += s * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(n: usize, seed: u64) -> Vec<f64> {
        // A = B Bᵀ + n·I from a deterministic LCG
        let mut state = seed;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f64 / (1u64 << 31) as f64) - 1.0
        };
        let b: Vec<f64> = (0..n * n).map(|_| next()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { n as f64 } else { 0.0 };
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                a[i * n + j] = s;
            }
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let n = 8;
        let a = spd(n, 7);
        let mut l = a.clone();
        cholesky_lower(&mut l, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += l[i * n + k] * l[j * n + k];
                }
                assert!((s - a[i * n + j]).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky_lower(&mut a, 2).is_err());
    }

    #[test]
    fn inverse_is_inverse() {
        let n = 6;
        let a = spd(n, 3);
        let inv = spd_inverse(&a, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += a[i * n + k] * inv[k * n + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-8, "({i},{j}) = {s}");
            }
        }
    }

    #[test]
    fn upper_factor_reconstructs() {
        let n = 5;
        let a = spd(n, 11);
        let u = cholesky_upper(&a, n).unwrap();
        // UᵀU = A and U upper-triangular
        for i in 0..n {
            for j in 0..i {
                assert_eq!(u[i * n + j], 0.0);
            }
        }
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += u[k * n + i] * u[k * n + j];
                }
                assert!((s - a[i * n + j]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn matmul_acc_matches_naive() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let b = [7.0, 8.0, 9.0, 10.0, 11.0, 12.0]; // 3x2
        let mut c = vec![1.0; 4];
        matmul_acc(&mut c, &a, &b, 2, 3, 2, -1.0);
        // naive: A@B = [[58, 64],[139,154]]; C = 1 - that
        assert_eq!(c, vec![1.0 - 58.0, 1.0 - 64.0, 1.0 - 139.0, 1.0 - 154.0]);
    }
}
