//! Bit-packing of quantization codes into `u32` words — the storage format
//! of the paper's inference kernel, shared bit-for-bit with
//! `kernels/ref.py::pack_codes` and the L1 `packmatvec` Pallas kernel.
//!
//! Little-endian field packing, `⌊32/bits⌋` codes per word:
//! 4-bit → 8/word, 3-bit → 10/word (2 pad bits, 3.2 effective bits),
//! 2-bit → 16/word, 8-bit → 4/word (the near-lossless serving baseline).
//!
//! The 2–3-bit widths also back **self-speculative decoding**
//! (`CpuModel::to_draft`, DESIGN.md §Sampling & Speculative decoding):
//! the serving checkpoint's linears are dequantized and RTN-repacked at
//! draft precision, trading accuracy the verify pass will reclaim for
//! the extreme-quant bandwidth win — a 3-bit draft moves ~⅓ the weight
//! bytes of a 4-bit-plus target per proposed token.

use super::gptq::QuantResult;

pub fn codes_per_word(bits: u32) -> usize {
    (32 / bits) as usize
}

pub fn words_per_row(dcol: usize, bits: u32) -> usize {
    dcol.div_ceil(codes_per_word(bits))
}

/// Pack one row of integer codes.
pub fn pack_row(codes: &[u8], bits: u32, out: &mut Vec<u32>) {
    let cpw = codes_per_word(bits);
    for chunk in codes.chunks(cpw) {
        let mut word = 0u32;
        for (i, &c) in chunk.iter().enumerate() {
            debug_assert!((c as u32) < (1 << bits));
            word |= (c as u32) << (bits as usize * i);
        }
        out.push(word);
    }
}

/// Unpack one row back into codes (inverse of [`pack_row`]).
pub fn unpack_row(words: &[u32], bits: u32, dcol: usize, out: &mut Vec<u8>) {
    let cpw = codes_per_word(bits);
    let mask = (1u32 << bits) - 1;
    out.clear();
    'outer: for &w in words {
        for i in 0..cpw {
            if out.len() == dcol {
                break 'outer;
            }
            out.push(((w >> (bits as usize * i)) & mask) as u8);
        }
    }
    assert_eq!(out.len(), dcol);
}

/// A packed quantized weight matrix: codes in u32 words plus the per-group
/// grids — everything the dequantizing matvec needs, and what the packed
/// checkpoint stores. Weight bytes moved per matvec shrink by
/// `32/codes_per_word/bits… ≈ 32/bits / (f32=32)` vs dense f32: 8× at
/// 4-bit, 10× at 3-bit (3.2 eff), 16× at 2-bit — the paper's speedup
/// mechanism.
#[derive(Debug, Clone)]
pub struct PackedMatrix {
    pub words: Vec<u32>,
    pub scales: Vec<f32>,
    pub zeros: Vec<f32>,
    pub drow: usize,
    pub dcol: usize,
    pub nwords: usize,
    pub ngroups: usize,
    pub bits: u32,
}

impl PackedMatrix {
    /// Pack a [`QuantResult`] (codes row-major drow × dcol).
    pub fn from_result(r: &QuantResult) -> Self {
        let nwords = words_per_row(r.dcol, r.bits);
        let mut words = Vec::with_capacity(r.drow * nwords);
        for row in r.codes.chunks_exact(r.dcol) {
            pack_row(row, r.bits, &mut words);
        }
        Self {
            words,
            scales: r.scales.clone(),
            zeros: r.zeros.clone(),
            drow: r.drow,
            dcol: r.dcol,
            nwords,
            ngroups: r.ngroups,
            bits: r.bits,
        }
    }

    /// Dequantize back to a dense row-major f32 matrix.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.drow * self.dcol];
        let g = self.dcol / self.ngroups;
        let mut codes = Vec::with_capacity(self.dcol);
        for r in 0..self.drow {
            unpack_row(&self.words[r * self.nwords..(r + 1) * self.nwords], self.bits, self.dcol, &mut codes);
            for c in 0..self.dcol {
                let gi = c / g;
                let s = self.scales[r * self.ngroups + gi];
                let z = self.zeros[r * self.ngroups + gi];
                out[r * self.dcol + c] = s * (codes[c] as f32 - z);
            }
        }
        out
    }

    /// Bytes of weight storage (words + grids) — the memory-footprint
    /// numbers of Table 5's "GPU reduction" column analog.
    pub fn storage_bytes(&self) -> usize {
        self.words.len() * 4 + (self.scales.len() + self.zeros.len()) * 4
    }

    /// Effective bits per weight including grid overhead.
    pub fn effective_bits(&self) -> f64 {
        self.storage_bytes() as f64 * 8.0 / (self.drow * self.dcol) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::rtn_quantize;

    #[test]
    fn roundtrip_all_bit_widths() {
        for bits in [2u32, 3, 4, 8] {
            let dcol = 37; // deliberately not word-aligned
            let codes: Vec<u8> = (0..dcol).map(|i| (i % (1 << bits)) as u8).collect();
            let mut words = Vec::new();
            pack_row(&codes, bits, &mut words);
            assert_eq!(words.len(), words_per_row(dcol, bits));
            let mut out = Vec::new();
            unpack_row(&words, bits, dcol, &mut out);
            assert_eq!(out, codes);
        }
    }

    #[test]
    fn field_layout_is_little_endian() {
        let mut words = Vec::new();
        pack_row(&[1, 2, 3], 4, &mut words);
        assert_eq!(words, vec![1 | (2 << 4) | (3 << 8)]);
    }

    #[test]
    fn packed_matrix_dequant_matches_quantresult() {
        let w: Vec<f32> = (0..256).map(|i| ((i * 31 % 97) as f32 - 48.0) / 20.0).collect();
        for (bits, g) in [(4u32, 0usize), (3, 8), (2, 16)] {
            let r = rtn_quantize(&w, 8, 32, bits, g);
            let p = PackedMatrix::from_result(&r);
            let dq = p.dequantize();
            for (a, b) in dq.iter().zip(&r.wq) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    /// Property-style check over the full format × kernel surface:
    /// packing RANDOM codes (not RTN-derived ones — every code pattern,
    /// including values the grid would clamp away) then running the
    /// packed matvec must agree with dequantize → dense matvec, across
    /// every bit width, group size, and a non-multiple-of-word dcol.
    #[test]
    fn random_codes_pack_matvec_matches_dense_dequant() {
        use crate::model::matvec::{matvec_f32, matvec_packed};

        let mut state = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            state
        };
        for bits in [2u32, 3, 4, 8] {
            for groupsize in [0usize, 16, 64] {
                // dcol: divisible by the group size, NOT by codes-per-word
                // (37: ragged tail; 112 = 16·7; 192 = 64·3 — 192 is ragged
                // for 3-bit's 10/word, word-aligned for 2/4/8)
                let dcol = match groupsize {
                    0 => 37usize,
                    16 => 112,
                    _ => 192,
                };
                let drow = 9usize;
                let g = if groupsize == 0 { dcol } else { groupsize };
                let ngroups = dcol / g;
                let maxq = ((1u32 << bits) - 1) as f32;
                let codes: Vec<u8> =
                    (0..drow * dcol).map(|_| (next() >> 40) as u8 & maxq as u8).collect();
                // scales sized so each dequantized weight is O(1/dcol):
                // row dots stay O(1) and f32 reorder error ≪ the 1e-5 gate
                let scales: Vec<f32> = (0..drow * ngroups)
                    .map(|_| {
                        let u = ((next() >> 40) % 1000) as f32 / 1000.0;
                        (0.5 + u) / (maxq * dcol as f32)
                    })
                    .collect();
                let zeros: Vec<f32> =
                    (0..drow * ngroups).map(|_| ((next() >> 40) % (1 << bits) as u64) as f32).collect();
                let r = QuantResult {
                    codes,
                    scales,
                    zeros,
                    wq: Vec::new(), // unused by packing
                    drow,
                    dcol,
                    ngroups,
                    bits,
                };
                let p = PackedMatrix::from_result(&r);
                let dense = p.dequantize();
                let x: Vec<f32> =
                    (0..dcol).map(|_| (next() >> 40) as f32 / (1u64 << 23) as f32 - 1.0).collect();
                let mut yp = vec![0.0f32; drow];
                let mut yd = vec![0.0f32; drow];
                matvec_packed(&p, &x, &mut yp);
                matvec_f32(&dense, &x, drow, dcol, &mut yd);
                for (row, (a, b)) in yp.iter().zip(&yd).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-5,
                        "bits={bits} g={groupsize} row={row}: packed {a} vs dense {b}"
                    );
                }
            }
        }
    }

    /// The draft-repack path (`to_draft`) round-trips packed weights
    /// through dequantize → RTN at fewer bits → repack. The second
    /// quantization must stand on its own: strictly smaller storage,
    /// and a dequantized matrix whose codes all fit the narrower grid.
    #[test]
    fn requantizing_packed_weights_to_fewer_bits_shrinks_storage() {
        let w: Vec<f32> = (0..64 * 64).map(|i| ((i * 37 % 113) as f32 - 56.0) / 64.0).collect();
        let four = PackedMatrix::from_result(&rtn_quantize(&w, 64, 64, 4, 0));
        for bits in [3u32, 2] {
            let dense4 = four.dequantize();
            let redone = PackedMatrix::from_result(&rtn_quantize(&dense4, 64, 64, bits, 0));
            assert_eq!(redone.bits, bits);
            assert!(
                redone.storage_bytes() < four.storage_bytes(),
                "{bits}-bit repack must shrink traffic: {} vs {}",
                redone.storage_bytes(),
                four.storage_bytes()
            );
            // the repack is still a faithful quantizer of the 4-bit
            // dense view: error bounded by half a step per weight
            let dq = redone.dequantize();
            let max_scale = redone.scales.iter().cloned().fold(0.0f32, f32::max);
            for (a, b) in dq.iter().zip(&dense4) {
                assert!((a - b).abs() <= max_scale * 0.5 + 1e-6, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn eight_bit_packs_four_per_word() {
        let codes: Vec<u8> = vec![0x11, 0x22, 0x33, 0x44, 0x55];
        let mut words = Vec::new();
        pack_row(&codes, 8, &mut words);
        assert_eq!(words, vec![0x44332211, 0x00000055]);
        assert_eq!(words_per_row(5, 8), 2);
    }

    #[test]
    fn effective_bits_accounting() {
        let w: Vec<f32> = (0..64 * 640).map(|i| (i as f32).sin()).collect();
        let r = rtn_quantize(&w, 64, 640, 3, 0);
        let p = PackedMatrix::from_result(&r);
        // 3-bit fields, 10 per word => 3.2 bits, plus the per-row grid:
        // (scale+zero) = 8 B/row = 64 bits / 640 weights = 0.1 bits
        assert!((p.effective_bits() - 3.3).abs() < 0.02, "{}", p.effective_bits());
    }
}
