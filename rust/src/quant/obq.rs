//! Optimal Brain Quantization (paper §3.2, [Frantar et al. 2022b]) — the
//! accurate greedy method GPTQ derives from and accelerates by
//! Θ(min(drow, dcol)).
//!
//! Per row, OBQ repeatedly (a) picks the unquantized weight with the least
//! quantization impact `(quant(w)−w)²/[H⁻¹_F]_qq` (Eq. 2), (b) compensates
//! all remaining weights, and (c) removes q from the inverse Hessian via
//! one Gaussian-elimination step (Eq. 3). Runtime O(drow · dcol³) — this
//! implementation exists as the Table 1/7 accuracy baseline and the
//! measured base of the Fig. 3 runtime extrapolation, exactly the role the
//! original plays in the paper.

use super::gptq::QuantResult;
use super::grid::{quant_params, quantize_value};
use super::linalg::spd_inverse;

/// OBQ-quantize a (drow × dcol) row-major matrix against the accumulated
/// Hessian `h` (2XᵀX, undamped — dampening is applied internally like the
/// GPTQ path). Per-row grids only (the setting of paper Table 7).
pub fn obq_quantize(
    w: &[f32],
    drow: usize,
    dcol: usize,
    h: &[f64],
    bits: u32,
    percdamp: f64,
) -> Result<QuantResult, String> {
    assert_eq!(w.len(), drow * dcol);
    assert_eq!(h.len(), dcol * dcol);
    let maxq = ((1u32 << bits) - 1) as f64;

    // shared preparation (dead columns + dampening), as in the GPTQ path
    let mut hh = h.to_vec();
    let mut wf: Vec<f64> = w.iter().map(|&v| v as f64).collect();
    let mut diag_mean = 0.0;
    for j in 0..dcol {
        if hh[j * dcol + j] == 0.0 {
            hh[j * dcol + j] = 1.0;
            for r in 0..drow {
                wf[r * dcol + j] = 0.0;
            }
        }
        diag_mean += hh[j * dcol + j];
    }
    for j in 0..dcol {
        hh[j * dcol + j] += percdamp * diag_mean / dcol as f64;
    }
    let hinv0 = spd_inverse(&hh, dcol)?;

    let wf32: Vec<f32> = wf.iter().map(|&v| v as f32).collect();
    let grid = quant_params(&wf32, drow, dcol, bits);

    let mut codes = vec![0u8; drow * dcol];
    let mut wq = vec![0.0f32; drow * dcol];
    let mut hinv = vec![0.0f64; dcol * dcol];

    for r in 0..drow {
        hinv.copy_from_slice(&hinv0);
        let row = &mut wf[r * dcol..(r + 1) * dcol];
        let s = grid.scale[r] as f64;
        let z = grid.zero[r] as f64;
        let mut remaining: Vec<usize> = (0..dcol).collect();

        while !remaining.is_empty() {
            // greedy choice: least (quant error)² / [H⁻¹]_qq   (Eq. 2)
            let mut best = 0usize;
            let mut best_score = f64::INFINITY;
            for (idx, &q) in remaining.iter().enumerate() {
                let (_, dq) = quantize_value(row[q], s, z, maxq);
                let e = row[q] - dq;
                let score = e * e / hinv[q * dcol + q];
                if score < best_score {
                    best_score = score;
                    best = idx;
                }
            }
            let q = remaining.swap_remove(best);
            let (code, dq) = quantize_value(row[q], s, z, maxq);
            codes[r * dcol + q] = code as u8;
            wq[r * dcol + q] = dq as f32;
            let d = hinv[q * dcol + q];
            let e = (row[q] - dq) / d;
            row[q] = dq;
            // compensate remaining weights (Eq. 2 update)
            for &c in &remaining {
                row[c] -= e * hinv[q * dcol + c];
            }
            // remove q from the inverse (Eq. 3)
            if !remaining.is_empty() {
                let hq: Vec<f64> = (0..dcol).map(|c| hinv[q * dcol + c]).collect();
                for i in 0..dcol {
                    let hi = hinv[i * dcol + q];
                    if hi == 0.0 {
                        continue;
                    }
                    let f = hi / d;
                    let hrow = &mut hinv[i * dcol..(i + 1) * dcol];
                    for (hv, &hv2) in hrow.iter_mut().zip(&hq) {
                        *hv -= f * hv2;
                    }
                }
                // keep the eliminated row/col inert
                for c in 0..dcol {
                    hinv[q * dcol + c] = 0.0;
                    hinv[c * dcol + q] = 0.0;
                }
                hinv[q * dcol + q] = 1.0;
            }
        }
    }

    let ngroups = 1;
    Ok(QuantResult {
        codes,
        scales: grid.scale,
        zeros: grid.zero,
        wq,
        drow,
        dcol,
        ngroups,
        bits,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::rtn_quantize;
    use crate::quant::{accumulate_hessian, gptq_quantize, layer_sq_error, GptqConfig};

    fn case(seed: u64, drow: usize, dcol: usize, n: usize) -> (Vec<f32>, Vec<f64>, Vec<f32>) {
        let mut s = seed;
        let mut lcg = move || {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (((s >> 33) as f64 / (1u64 << 31) as f64) - 1.0) as f32
        };
        let w: Vec<f32> = (0..drow * dcol).map(|_| lcg()).collect();
        let mix: Vec<f32> = (0..dcol * dcol).map(|_| lcg() / (dcol as f32).sqrt()).collect();
        let mut x = vec![0.0f32; n * dcol];
        for i in 0..n {
            let raw: Vec<f32> = (0..dcol).map(|_| lcg()).collect();
            for j in 0..dcol {
                x[i * dcol + j] = (0..dcol).map(|k| raw[k] * mix[k * dcol + j]).sum();
            }
        }
        let mut h = vec![0.0f64; dcol * dcol];
        accumulate_hessian(&mut h, &x, n, dcol);
        (w, h, x)
    }

    #[test]
    fn obq_beats_rtn() {
        let (w, h, x) = case(1, 8, 16, 64);
        let o = obq_quantize(&w, 8, 16, &h, 3, 0.01).unwrap();
        let r = rtn_quantize(&w, 8, 16, 3, 0);
        let eo = layer_sq_error(&w, &o.wq, &x, 8, 16);
        let er = layer_sq_error(&w, &r.wq, &x, 8, 16);
        assert!(eo < er, "obq {eo} !< rtn {er}");
    }

    #[test]
    fn obq_and_gptq_comparable() {
        // paper Table 7: GPTQ ≈ OBQ in accuracy. Allow generous slack both
        // ways (greedy order can win or lose on small layers).
        let (w, h, x) = case(2, 8, 24, 96);
        let o = obq_quantize(&w, 8, 24, &h, 4, 0.01).unwrap();
        let g = gptq_quantize(&w, 8, 24, &h, &GptqConfig::new(4)).unwrap();
        let eo = layer_sq_error(&w, &o.wq, &x, 8, 24);
        let eg = layer_sq_error(&w, &g.wq, &x, 8, 24);
        assert!(eg < 3.0 * eo + 1e-9 && eo < 3.0 * eg + 1e-9, "obq {eo} vs gptq {eg}");
    }

    #[test]
    fn all_weights_quantized_once() {
        let (w, h, _) = case(3, 4, 12, 48);
        let o = obq_quantize(&w, 4, 12, &h, 2, 0.01).unwrap();
        assert!(o.codes.iter().all(|&c| c < 4));
        assert!(o.wq.iter().all(|v| v.is_finite()));
    }
}
