//! Round-to-nearest (RTN) quantization — the baseline used by all prior
//! giant-model work the paper compares against (ZeroQuant, LLM.int8(),
//! nuQmm): independent per-row (or per-group) min-max grids, one rounding
//! pass, no error compensation.

use super::gptq::QuantResult;
use super::grid::{quant_params, quantize_value_f32};

/// RTN-quantize a (drow × dcol) row-major matrix. `groupsize == 0` means
/// one grid per row. Output layout matches [`super::gptq::gptq_quantize`].
pub fn rtn_quantize(w: &[f32], drow: usize, dcol: usize, bits: u32, groupsize: usize) -> QuantResult {
    assert_eq!(w.len(), drow * dcol);
    let g = if groupsize == 0 { dcol } else { groupsize };
    assert_eq!(dcol % g, 0, "groupsize must divide dcol");
    let ngroups = dcol / g;
    let maxq = ((1u32 << bits) - 1) as f32;

    let mut codes = vec![0u8; drow * dcol];
    let mut wq = vec![0.0f32; drow * dcol];
    let mut scales = vec![0.0f32; drow * ngroups];
    let mut zeros = vec![0.0f32; drow * ngroups];
    let mut buf = vec![0.0f32; drow * g];

    for gi in 0..ngroups {
        for r in 0..drow {
            buf[r * g..(r + 1) * g].copy_from_slice(&w[r * dcol + gi * g..r * dcol + (gi + 1) * g]);
        }
        let grid = quant_params(&buf, drow, g, bits);
        for r in 0..drow {
            scales[r * ngroups + gi] = grid.scale[r];
            zeros[r * ngroups + gi] = grid.zero[r];
            for c in 0..g {
                let (q, dq) = quantize_value_f32(buf[r * g + c], grid.scale[r], grid.zero[r], maxq);
                codes[r * dcol + gi * g + c] = q as u8;
                wq[r * dcol + gi * g + c] = dq;
            }
        }
    }
    QuantResult { codes, scales, zeros, wq, drow, dcol, ngroups, bits }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reconstruction_bounded_by_half_step() {
        let w: Vec<f32> = (0..64).map(|i| ((i * 37 % 100) as f32 - 50.0) / 25.0).collect();
        let r = rtn_quantize(&w, 4, 16, 4, 0);
        for row in 0..4 {
            let s = r.scales[row];
            for c in 0..16 {
                let err = (w[row * 16 + c] - r.wq[row * 16 + c]).abs();
                assert!(err <= s / 2.0 + 1e-6, "row {row} col {c}: {err} vs step {s}");
            }
        }
    }

    #[test]
    fn grouped_equals_per_row_when_group_is_row() {
        let w: Vec<f32> = (0..48).map(|i| (i as f32).sin()).collect();
        let a = rtn_quantize(&w, 3, 16, 3, 0);
        let b = rtn_quantize(&w, 3, 16, 3, 16);
        assert_eq!(a.codes, b.codes);
        assert_eq!(a.scales, b.scales);
    }

    #[test]
    fn more_bits_less_error() {
        let w: Vec<f32> = (0..128).map(|i| ((i * 17 % 31) as f32 / 7.0) - 2.0).collect();
        let errs: Vec<f32> = [2u32, 3, 4]
            .iter()
            .map(|&b| {
                let r = rtn_quantize(&w, 8, 16, b, 0);
                w.iter().zip(&r.wq).map(|(a, b)| (a - b) * (a - b)).sum::<f32>()
            })
            .collect();
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }

    #[test]
    fn finer_groups_monotone_error() {
        let mut s = 9u64;
        let mut lcg = || {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (((s >> 33) as f64 / (1u64 << 31) as f64) - 1.0) as f32
        };
        let w: Vec<f32> = (0..16 * 64).map(|_| lcg()).collect();
        let mut prev = f32::INFINITY;
        for g in [0usize, 32, 16, 8] {
            let r = rtn_quantize(&w, 16, 64, 2, g);
            let e: f32 = w.iter().zip(&r.wq).map(|(a, b)| (a - b) * (a - b)).sum();
            assert!(e <= prev * 1.05, "g={g}: {e} vs prev {prev}");
            prev = e;
        }
    }
}
