//! Model configuration — mirrors `python/compile/model.py::ModelConfig`
//! and is deserialized from `artifacts/manifest.json`.

use crate::util::Json;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelConfig {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub max_seq: usize,
}

/// The four quantizable linears per block, in pipeline order.
pub const QUANT_LINEARS: [&str; 4] = ["wqkv", "wo", "wup", "wdn"];

impl ModelConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("d_model", Json::Num(self.d_model as f64)),
            ("n_layers", Json::Num(self.n_layers as f64)),
            ("n_heads", Json::Num(self.n_heads as f64)),
            ("d_ff", Json::Num(self.d_ff as f64)),
            ("vocab", Json::Num(self.vocab as f64)),
            ("max_seq", Json::Num(self.max_seq as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<Self> {
        Some(Self {
            d_model: j.get("d_model")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            d_ff: j.get("d_ff")?.as_usize()?,
            vocab: j.get("vocab")?.as_usize()?,
            max_seq: j.get("max_seq")?.as_usize()?,
        })
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// (out, in) shape of each quantizable linear.
    pub fn linear_shape(&self, name: &str) -> (usize, usize) {
        let (d, ff) = (self.d_model, self.d_ff);
        match name {
            "wqkv" => (3 * d, d),
            "wo" => (d, d),
            "wup" => (ff, d),
            "wdn" => (d, ff),
            other => panic!("unknown linear {other}"),
        }
    }

    /// Total parameter count (must equal the python side's n_params()).
    pub fn n_params(&self) -> usize {
        let mut n = 2 * self.vocab * self.d_model + self.max_seq * self.d_model + 2 * self.d_model;
        for _ in 0..self.n_layers {
            n += 4 * self.d_model; // two LayerNorms
            for l in QUANT_LINEARS {
                let (o, i) = self.linear_shape(l);
                n += o * i + o;
            }
        }
        n
    }

    /// f32 bytes of the quantizable weights only (the Table 5 memory story
    /// excludes embeddings, which stay fp).
    pub fn quantizable_bytes_f32(&self) -> usize {
        self.n_layers
            * QUANT_LINEARS
                .iter()
                .map(|l| {
                    let (o, i) = self.linear_shape(l);
                    o * i * 4
                })
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig { d_model: 64, n_layers: 2, n_heads: 2, d_ff: 256, vocab: 256, max_seq: 128 }
    }

    #[test]
    fn shapes() {
        let c = cfg();
        assert_eq!(c.linear_shape("wqkv"), (192, 64));
        assert_eq!(c.linear_shape("wdn"), (64, 256));
        assert_eq!(c.head_dim(), 32);
    }

    #[test]
    fn param_count_formula() {
        let c = cfg();
        // embed+unembed 2*256*64, pos 128*64, lnf 2*64
        let expected_base = 2 * 256 * 64 + 128 * 64 + 2 * 64;
        let per_block = 4 * 64 + (192 * 64 + 192) + (64 * 64 + 64) + (256 * 64 + 256) + (64 * 256 + 64);
        assert_eq!(c.n_params(), expected_base + 2 * per_block);
    }
}
