//! Checkpoint formats.
//!
//! * [`Checkpoint`] — the dense f32 model as trained by the build-time
//!   Python path: raw little-endian f32 blob + the manifest tensor index.
//! * [`QuantizedCheckpoint`] — the pipeline's output: packed b-bit codes +
//!   grids for every quantizable linear, fp tensors for everything else
//!   (embeddings / LayerNorms / biases stay full precision, as in the
//!   paper). Serialized as a JSON header + raw blobs in one file.

use crate::model::config::QUANT_LINEARS;
use crate::model::{ModelConfig, Tensor};
use crate::quant::sparse::Sparse24Matrix;
use crate::quant::PackedMatrix;
use crate::runtime::ModelEntry;
use crate::util::Json;
use crate::Result;
use anyhow::{anyhow, ensure, Context};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

/// Dense f32 checkpoint (name → tensor).
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub config: ModelConfig,
    pub tensors: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    /// Load from the raw weights blob described by a manifest model entry.
    pub fn load(artifacts_dir: &Path, entry: &ModelEntry) -> Result<Self> {
        let blob = std::fs::read(artifacts_dir.join(&entry.weights))?;
        let mut tensors = BTreeMap::new();
        for t in &entry.tensors {
            let bytes = &blob[t.offset..t.offset + t.len * 4];
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            tensors.insert(t.name.clone(), Tensor::new(data, t.shape.clone()));
        }
        Ok(Self { config: entry.config.clone(), tensors })
    }

    pub fn get(&self, name: &str) -> &Tensor {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("tensor {name} missing from checkpoint"))
    }

    pub fn block_tensor(&self, layer: usize, name: &str) -> &Tensor {
        self.get(&format!("blocks.{layer}.{name}"))
    }

    /// Replace a block linear's weights (used by the pipeline to propagate
    /// quantized weights forward).
    pub fn set_block_weight(&mut self, layer: usize, name: &str, data: Vec<f32>) {
        let key = format!("blocks.{layer}.{name}");
        let t = self.tensors.get_mut(&key).unwrap_or_else(|| panic!("{key} missing"));
        assert_eq!(t.data.len(), data.len());
        t.data = data;
    }
}

/// Per-layer quantization statistics recorded by the pipeline (the data
/// behind the Table 1 / ablation rows).
#[derive(Debug, Clone)]
pub struct LayerStats {
    pub layer: usize,
    pub name: String,
    pub sq_error: f64,
    pub quant_ms: f64,
}

impl LayerStats {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("layer", Json::Num(self.layer as f64)),
            ("name", Json::Str(self.name.clone())),
            ("sq_error", Json::Num(self.sq_error)),
            ("quant_ms", Json::Num(self.quant_ms)),
        ])
    }

    fn from_json(j: &Json) -> Option<Self> {
        Some(Self {
            layer: j.get("layer")?.as_usize()?,
            name: j.get("name")?.as_str()?.to_string(),
            sq_error: j.get("sq_error")?.as_f64()?,
            quant_ms: j.get("quant_ms")?.as_f64()?,
        })
    }
}

/// Quantized model: packed linears + the untouched fp tensors.
#[derive(Debug, Clone)]
pub struct QuantizedCheckpoint {
    pub config: ModelConfig,
    pub bits: u32,
    pub groupsize: usize,
    /// `packed["blocks.{l}.{name}"]`
    pub packed: BTreeMap<String, PackedMatrix>,
    /// 2:4 sparse-quantized linears (`--sparsity 2of4`), same key scheme
    /// as `packed`; a linear lives in exactly one of the two maps
    pub sparse: BTreeMap<String, Sparse24Matrix>,
    /// everything that stays fp: embeddings, LN, biases, unembed
    pub fp: BTreeMap<String, Tensor>,
    pub stats: Vec<LayerStats>,
}

struct QHeader {
    config: ModelConfig,
    bits: u32,
    groupsize: usize,
    packed_meta: Vec<(String, usize, usize, usize, usize, u32)>, // name, drow, dcol, nwords, ngroups, bits
    // name, drow, dcol, ngroups, pair_wpg, idx_wpg, bits — absent in
    // pre-sparsity checkpoints (read back as empty)
    sparse_meta: Vec<(String, usize, usize, usize, usize, usize, u32)>,
    fp_meta: Vec<(String, Vec<usize>)>,
    stats: Vec<LayerStats>,
}

impl QHeader {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("config", self.config.to_json()),
            ("bits", Json::Num(self.bits as f64)),
            ("groupsize", Json::Num(self.groupsize as f64)),
            (
                "packed_meta",
                Json::Arr(
                    self.packed_meta
                        .iter()
                        .map(|(n, a, b, c, d, e)| {
                            Json::Arr(vec![
                                Json::Str(n.clone()),
                                Json::Num(*a as f64),
                                Json::Num(*b as f64),
                                Json::Num(*c as f64),
                                Json::Num(*d as f64),
                                Json::Num(*e as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "sparse_meta",
                Json::Arr(
                    self.sparse_meta
                        .iter()
                        .map(|(n, a, b, c, d, e, f)| {
                            Json::Arr(vec![
                                Json::Str(n.clone()),
                                Json::Num(*a as f64),
                                Json::Num(*b as f64),
                                Json::Num(*c as f64),
                                Json::Num(*d as f64),
                                Json::Num(*e as f64),
                                Json::Num(*f as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "fp_meta",
                Json::Arr(
                    self.fp_meta
                        .iter()
                        .map(|(n, s)| Json::Arr(vec![Json::Str(n.clone()), Json::arr_usize(s)]))
                        .collect(),
                ),
            ),
            ("stats", Json::Arr(self.stats.iter().map(|s| s.to_json()).collect())),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        let bad = || anyhow!("malformed checkpoint header");
        let packed_meta = j
            .get("packed_meta")
            .and_then(|p| p.as_arr())
            .ok_or_else(bad)?
            .iter()
            .map(|e| {
                let a = e.as_arr()?;
                Some((
                    a[0].as_str()?.to_string(),
                    a[1].as_usize()?,
                    a[2].as_usize()?,
                    a[3].as_usize()?,
                    a[4].as_usize()?,
                    a[5].as_u32()?,
                ))
            })
            .collect::<Option<Vec<_>>>()
            .ok_or_else(bad)?;
        // absent in checkpoints written before the sparsity PR
        let sparse_meta = match j.get("sparse_meta") {
            None => Vec::new(),
            Some(p) => p
                .as_arr()
                .ok_or_else(bad)?
                .iter()
                .map(|e| {
                    let a = e.as_arr()?;
                    Some((
                        a[0].as_str()?.to_string(),
                        a[1].as_usize()?,
                        a[2].as_usize()?,
                        a[3].as_usize()?,
                        a[4].as_usize()?,
                        a[5].as_usize()?,
                        a[6].as_u32()?,
                    ))
                })
                .collect::<Option<Vec<_>>>()
                .ok_or_else(bad)?,
        };
        let fp_meta = j
            .get("fp_meta")
            .and_then(|p| p.as_arr())
            .ok_or_else(bad)?
            .iter()
            .map(|e| {
                let a = e.as_arr()?;
                Some((a[0].as_str()?.to_string(), a[1].usize_vec()?))
            })
            .collect::<Option<Vec<_>>>()
            .ok_or_else(bad)?;
        let stats = j
            .get("stats")
            .and_then(|p| p.as_arr())
            .ok_or_else(bad)?
            .iter()
            .map(LayerStats::from_json)
            .collect::<Option<Vec<_>>>()
            .ok_or_else(bad)?;
        Ok(Self {
            config: j.get("config").and_then(ModelConfig::from_json).ok_or_else(bad)?,
            bits: j.get("bits").and_then(|b| b.as_u32()).ok_or_else(bad)?,
            groupsize: j.get("groupsize").and_then(|g| g.as_usize()).ok_or_else(bad)?,
            packed_meta,
            sparse_meta,
            fp_meta,
            stats,
        })
    }
}

impl QuantizedCheckpoint {
    /// Build from a dense checkpoint, keeping non-quantized tensors fp.
    pub fn from_parts(
        config: ModelConfig,
        bits: u32,
        groupsize: usize,
        packed: BTreeMap<String, PackedMatrix>,
        source: &Checkpoint,
        stats: Vec<LayerStats>,
    ) -> Self {
        Self::from_parts_sparse(config, bits, groupsize, packed, BTreeMap::new(), source, stats)
    }

    /// [`QuantizedCheckpoint::from_parts`] with a 2:4 sparse map: linears
    /// present in either map are dropped from the fp side.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts_sparse(
        config: ModelConfig,
        bits: u32,
        groupsize: usize,
        packed: BTreeMap<String, PackedMatrix>,
        sparse: BTreeMap<String, Sparse24Matrix>,
        source: &Checkpoint,
        stats: Vec<LayerStats>,
    ) -> Self {
        let mut fp = BTreeMap::new();
        for (name, t) in &source.tensors {
            if !packed.contains_key(name) && !sparse.contains_key(name) {
                fp.insert(name.clone(), t.clone());
            }
        }
        Self { config, bits, groupsize, packed, sparse, fp, stats }
    }

    /// Total bytes of quantized weight storage (codes + grids, dense and
    /// sparse layouts alike), the "memory footprint" column of the Table 5
    /// analog.
    pub fn packed_bytes(&self) -> usize {
        self.packed.values().map(|p| p.storage_bytes()).sum::<usize>()
            + self.sparse.values().map(|m| m.storage_bytes()).sum::<usize>()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let header = QHeader {
            config: self.config.clone(),
            bits: self.bits,
            groupsize: self.groupsize,
            packed_meta: self
                .packed
                .iter()
                .map(|(n, p)| (n.clone(), p.drow, p.dcol, p.nwords, p.ngroups, p.bits))
                .collect(),
            sparse_meta: self
                .sparse
                .iter()
                .map(|(n, m)| {
                    (n.clone(), m.drow, m.dcol, m.ngroups, m.pair_wpg, m.idx_wpg, m.bits)
                })
                .collect(),
            fp_meta: self.fp.iter().map(|(n, t)| (n.clone(), t.shape.clone())).collect(),
            stats: self.stats.clone(),
        };
        let hjson = header.to_json().to_string().into_bytes();
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"GPTQCKPT")?;
        f.write_all(&(hjson.len() as u64).to_le_bytes())?;
        f.write_all(&hjson)?;
        for (_, p) in &self.packed {
            for w in &p.words {
                f.write_all(&w.to_le_bytes())?;
            }
            for s in p.scales.iter().chain(&p.zeros) {
                f.write_all(&s.to_le_bytes())?;
            }
        }
        for (_, m) in &self.sparse {
            for w in m.pair_words.iter().chain(&m.idx_words) {
                f.write_all(&w.to_le_bytes())?;
            }
            for s in m.scales.iter().chain(&m.zeros) {
                f.write_all(&s.to_le_bytes())?;
            }
        }
        for (_, t) in &self.fp {
            for v in &t.data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        ensure!(&magic == b"GPTQCKPT", "bad checkpoint magic");
        let mut lenb = [0u8; 8];
        f.read_exact(&mut lenb)?;
        let hlen = u64::from_le_bytes(lenb) as usize;
        let mut hjson = vec![0u8; hlen];
        f.read_exact(&mut hjson)?;
        let htext = std::str::from_utf8(&hjson).context("checkpoint header utf8")?;
        let header = QHeader::from_json(&Json::parse(htext).map_err(|e| anyhow!("header: {e}"))?)?;

        let read_u32s = |n: usize, f: &mut dyn Read| -> Result<Vec<u32>> {
            let mut buf = vec![0u8; n * 4];
            f.read_exact(&mut buf)?;
            Ok(buf.chunks_exact(4).map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect())
        };
        let mut packed = BTreeMap::new();
        for (name, drow, dcol, nwords, ngroups, bits) in &header.packed_meta {
            let words = read_u32s(drow * nwords, &mut f)?;
            let grids = read_u32s(2 * drow * ngroups, &mut f)?;
            let scales: Vec<f32> = grids[..drow * ngroups].iter().map(|&u| f32::from_bits(u)).collect();
            let zeros: Vec<f32> = grids[drow * ngroups..].iter().map(|&u| f32::from_bits(u)).collect();
            packed.insert(
                name.clone(),
                PackedMatrix {
                    words,
                    scales,
                    zeros,
                    drow: *drow,
                    dcol: *dcol,
                    nwords: *nwords,
                    ngroups: *ngroups,
                    bits: *bits,
                },
            );
        }
        let mut sparse = BTreeMap::new();
        for (name, drow, dcol, ngroups, pair_wpg, idx_wpg, bits) in &header.sparse_meta {
            let pair_words = read_u32s(drow * ngroups * pair_wpg, &mut f)?;
            let idx_words = read_u32s(drow * ngroups * idx_wpg, &mut f)?;
            let grids = read_u32s(2 * drow * ngroups, &mut f)?;
            let scales: Vec<f32> = grids[..drow * ngroups].iter().map(|&u| f32::from_bits(u)).collect();
            let zeros: Vec<f32> = grids[drow * ngroups..].iter().map(|&u| f32::from_bits(u)).collect();
            sparse.insert(
                name.clone(),
                Sparse24Matrix {
                    pair_words,
                    idx_words,
                    scales,
                    zeros,
                    drow: *drow,
                    dcol: *dcol,
                    ngroups: *ngroups,
                    bits: *bits,
                    pair_wpg: *pair_wpg,
                    idx_wpg: *idx_wpg,
                },
            );
        }
        let mut fp = BTreeMap::new();
        for (name, shape) in &header.fp_meta {
            let n: usize = shape.iter().product();
            let raw = read_u32s(n, &mut f)?;
            let data: Vec<f32> = raw.iter().map(|&u| f32::from_bits(u)).collect();
            fp.insert(name.clone(), Tensor::new(data, shape.clone()));
        }
        Ok(Self {
            config: header.config,
            bits: header.bits,
            groupsize: header.groupsize,
            packed,
            sparse,
            fp,
            stats: header.stats,
        })
    }
}

/// Keys of the quantizable linears of a config, in pipeline order.
pub fn quantizable_keys(config: &ModelConfig) -> Vec<String> {
    let mut keys = Vec::new();
    for l in 0..config.n_layers {
        for name in QUANT_LINEARS {
            keys.push(format!("blocks.{l}.{name}"));
        }
    }
    keys
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_quantize;

    fn tiny_config() -> ModelConfig {
        ModelConfig { d_model: 8, n_layers: 1, n_heads: 2, d_ff: 16, vocab: 16, max_seq: 8 }
    }

    #[test]
    fn quantized_checkpoint_roundtrip() {
        let cfg = tiny_config();
        let w: Vec<f32> = (0..24 * 8).map(|i| (i as f32).cos()).collect();
        let r = rtn_quantize(&w, 24, 8, 3, 0);
        let mut packed = BTreeMap::new();
        packed.insert("blocks.0.wqkv".to_string(), PackedMatrix::from_result(&r));
        let mut fp = BTreeMap::new();
        fp.insert("embed".to_string(), Tensor::new(vec![0.5; 16 * 8], vec![16, 8]));
        let q = QuantizedCheckpoint {
            config: cfg,
            bits: 3,
            groupsize: 0,
            packed,
            sparse: BTreeMap::new(),
            fp,
            stats: vec![LayerStats { layer: 0, name: "wqkv".into(), sq_error: 0.1, quant_ms: 1.0 }],
        };
        let tmp = std::env::temp_dir().join("gptq_test_ckpt.bin");
        q.save(&tmp).unwrap();
        let q2 = QuantizedCheckpoint::load(&tmp).unwrap();
        std::fs::remove_file(&tmp).ok();
        assert_eq!(q2.bits, 3);
        assert_eq!(q2.packed["blocks.0.wqkv"].words, q.packed["blocks.0.wqkv"].words);
        assert_eq!(q2.packed["blocks.0.wqkv"].scales, q.packed["blocks.0.wqkv"].scales);
        assert_eq!(q2.fp["embed"].data, q.fp["embed"].data);
        assert_eq!(q2.stats.len(), 1);
        // dequantization identical across the roundtrip
        assert_eq!(q2.packed["blocks.0.wqkv"].dequantize(), q.packed["blocks.0.wqkv"].dequantize());
    }

    #[test]
    fn sparse_checkpoint_roundtrip() {
        use crate::quant::sparse::prune_2of4_by_magnitude;
        let cfg = tiny_config();
        let w: Vec<f32> = (0..24 * 16).map(|i| ((i * 37 + 5) as f32).sin()).collect();
        let mut r = rtn_quantize(&w, 24, 16, 4, 8);
        prune_2of4_by_magnitude(&mut r);
        let sp = Sparse24Matrix::from_result(&r).unwrap();
        let mut sparse = BTreeMap::new();
        sparse.insert("blocks.0.wqkv".to_string(), sp.clone());
        let mut fp = BTreeMap::new();
        fp.insert("embed".to_string(), Tensor::new(vec![0.25; 16 * 8], vec![16, 8]));
        let q = QuantizedCheckpoint {
            config: cfg,
            bits: 4,
            groupsize: 8,
            packed: BTreeMap::new(),
            sparse,
            fp,
            stats: vec![],
        };
        let tmp = std::env::temp_dir().join("gptq_test_sparse_ckpt.bin");
        q.save(&tmp).unwrap();
        let q2 = QuantizedCheckpoint::load(&tmp).unwrap();
        std::fs::remove_file(&tmp).ok();
        // exact struct equality: codes, index nibbles, and grids all
        // round-trip bitwise
        assert_eq!(q2.sparse["blocks.0.wqkv"], sp);
        assert_eq!(q2.fp["embed"].data, q.fp["embed"].data);
        assert!(q2.sparse["blocks.0.wqkv"].check_2of4());
    }

    #[test]
    fn pre_sparsity_header_reads_as_empty_sparse_map() {
        // a header with no "sparse_meta" key (written before the sparsity
        // PR) must load with an empty sparse map, not error
        let cfg = tiny_config();
        let w: Vec<f32> = (0..24 * 8).map(|i| (i as f32).cos()).collect();
        let r = rtn_quantize(&w, 24, 8, 3, 0);
        let mut packed = BTreeMap::new();
        packed.insert("blocks.0.wqkv".to_string(), PackedMatrix::from_result(&r));
        let q = QuantizedCheckpoint {
            config: cfg,
            bits: 3,
            groupsize: 0,
            packed,
            sparse: BTreeMap::new(),
            fp: BTreeMap::new(),
            stats: vec![],
        };
        let tmp = std::env::temp_dir().join("gptq_test_legacy_ckpt.bin");
        q.save(&tmp).unwrap();
        // strip the sparse_meta key from the written header to simulate a
        // legacy file (it serializes as an empty array)
        let bytes = std::fs::read(&tmp).unwrap();
        let hlen = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let htext = std::str::from_utf8(&bytes[16..16 + hlen]).unwrap();
        assert!(htext.contains("\"sparse_meta\""));
        let legacy = htext.replace("\"sparse_meta\":[],", "");
        assert!(!legacy.contains("sparse_meta"));
        let mut out = Vec::new();
        out.extend_from_slice(b"GPTQCKPT");
        out.extend_from_slice(&(legacy.len() as u64).to_le_bytes());
        out.extend_from_slice(legacy.as_bytes());
        out.extend_from_slice(&bytes[16 + hlen..]);
        std::fs::write(&tmp, &out).unwrap();
        let q2 = QuantizedCheckpoint::load(&tmp).unwrap();
        std::fs::remove_file(&tmp).ok();
        assert!(q2.sparse.is_empty());
        assert_eq!(q2.packed["blocks.0.wqkv"].words, q.packed["blocks.0.wqkv"].words);
    }

    #[test]
    fn quantizable_keys_order() {
        let keys = quantizable_keys(&tiny_config());
        assert_eq!(keys, vec!["blocks.0.wqkv", "blocks.0.wo", "blocks.0.wup", "blocks.0.wdn"]);
    }
}
