//! Deterministic tiny fixtures shared by unit tests, integration tests,
//! and benches: a random `tiny` checkpoint and a matching in-memory
//! manifest. With these plus the reference backend, the ENTIRE pipeline
//! (calibrate → Hessian → GPTQ → pack → eval → serve) runs without
//! `make artifacts` — see `tests/reference_backend.rs`.

use crate::model::checkpoint::Checkpoint;
use crate::model::config::QUANT_LINEARS;
use crate::model::{ModelConfig, Tensor};
use crate::runtime::manifest::{Manifest, ModelEntry, QuantDefaults, TensorEntry};
use std::collections::BTreeMap;

/// Manifest model name used by [`tiny_checkpoint`] / [`tiny_manifest`].
pub const TINY_SIZE: &str = "tiny";

/// Seeded uniform(-1, 1) f32 vector — THE shared test-vector generator
/// (the same LCG the tiny checkpoint uses), so kernel/layout test suites
/// don't each carry their own copy.
pub fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut s = seed;
    (0..n)
        .map(|_| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (((s >> 33) as f64 / (1u64 << 31) as f64) - 1.0) as f32
        })
        .collect()
}

/// The tiny config: 2 blocks, d=16, ff=32, vocab 32, max_seq 16.
pub fn tiny_config() -> ModelConfig {
    ModelConfig { d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, vocab: 32, max_seq: 16 }
}

/// A deterministic random tiny checkpoint (seeded LCG weights; LayerNorms
/// at identity, biases zero).
pub fn tiny_checkpoint(seed: u64) -> Checkpoint {
    let cfg = tiny_config();
    let mut s = seed;
    let mut lcg = move || {
        s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (((s >> 33) as f64 / (1u64 << 31) as f64) - 1.0) as f32 * 0.3
    };
    let mut tensors = BTreeMap::new();
    let mut add = |name: &str,
                   shape: Vec<usize>,
                   tensors: &mut BTreeMap<String, Tensor>,
                   f: &mut dyn FnMut() -> f32| {
        let n: usize = shape.iter().product();
        tensors.insert(name.to_string(), Tensor::new((0..n).map(|_| f()).collect(), shape));
    };
    add("embed", vec![32, 16], &mut tensors, &mut lcg);
    add("pos", vec![16, 16], &mut tensors, &mut lcg);
    add("unembed", vec![32, 16], &mut tensors, &mut lcg);
    tensors.insert("lnf_g".into(), Tensor::new(vec![1.0; 16], vec![16]));
    tensors.insert("lnf_b".into(), Tensor::new(vec![0.0; 16], vec![16]));
    for l in 0..2 {
        for nm in ["ln1_g", "ln2_g"] {
            tensors.insert(format!("blocks.{l}.{nm}"), Tensor::new(vec![1.0; 16], vec![16]));
        }
        for nm in ["ln1_b", "ln2_b"] {
            tensors.insert(format!("blocks.{l}.{nm}"), Tensor::new(vec![0.0; 16], vec![16]));
        }
        for nm in QUANT_LINEARS {
            let (o, i) = cfg.linear_shape(nm);
            add(&format!("blocks.{l}.{nm}"), vec![o, i], &mut tensors, &mut lcg);
            tensors.insert(format!("blocks.{l}.{nm}_b"), Tensor::new(vec![0.0; o], vec![o]));
        }
    }
    Checkpoint { config: cfg, tensors }
}

/// The checkpoint tensor order shared with the Python side
/// (`model.py::tensor_index`): head tensors, then per block the LN vectors
/// followed by each linear and its bias.
pub fn tiny_tensor_index() -> Vec<(String, Vec<usize>)> {
    let cfg = tiny_config();
    let d = cfg.d_model;
    let mut idx: Vec<(String, Vec<usize>)> = vec![
        ("embed".into(), vec![cfg.vocab, d]),
        ("pos".into(), vec![cfg.max_seq, d]),
        ("lnf_g".into(), vec![d]),
        ("lnf_b".into(), vec![d]),
        ("unembed".into(), vec![cfg.vocab, d]),
    ];
    for l in 0..cfg.n_layers {
        for nm in ["ln1_g", "ln1_b", "ln2_g", "ln2_b"] {
            idx.push((format!("blocks.{l}.{nm}"), vec![d]));
        }
        for nm in QUANT_LINEARS {
            let (o, i) = cfg.linear_shape(nm);
            idx.push((format!("blocks.{l}.{nm}"), vec![o, i]));
            idx.push((format!("blocks.{l}.{nm}_b"), vec![o]));
        }
    }
    idx
}

/// An in-memory manifest describing the tiny model — enough for the
/// reference backend to run the full pipeline without any artifact tree
/// on disk (artifact map left empty: the reference backend executes
/// contracts by name).
pub fn tiny_manifest(seq_len: usize, eval_batch: usize) -> Manifest {
    let cfg = tiny_config();
    assert!(seq_len < cfg.max_seq, "tiny seq_len must stay below max_seq");
    let mut offset = 0usize;
    let tensors: Vec<TensorEntry> = tiny_tensor_index()
        .into_iter()
        .map(|(name, shape)| {
            let len: usize = shape.iter().product();
            let e = TensorEntry { name, shape, offset, len };
            offset += len * 4;
            e
        })
        .collect();
    let mut models = BTreeMap::new();
    models.insert(
        TINY_SIZE.to_string(),
        ModelEntry {
            n_params: cfg.n_params(),
            config: cfg,
            weights: format!("weights_{TINY_SIZE}.bin"),
            tensors,
        },
    );
    Manifest {
        version: 1,
        seq_len,
        eval_batch,
        calib_tokens: seq_len * eval_batch,
        quant: QuantDefaults { blocksize: 128, percdamp: 0.01, gptq_artifact_bits: vec![3, 4] },
        models,
        artifacts: BTreeMap::new(),
        root: std::path::PathBuf::from("."),
    }
}

/// A deterministic synthetic byte corpus (vocab-32 bytes, mildly
/// structured) for calibration/eval in artifact-free tests.
pub fn tiny_corpus(n_bytes: usize, seed: u64) -> crate::data::CorpusFile {
    let mut rng = crate::data::Rng::new(seed);
    let bytes: Vec<u8> = (0..n_bytes)
        .map(|i| (((i / 3) % 16) as u8 + (rng.below(16) as u8)).min(31))
        .collect();
    crate::data::CorpusFile { bytes, name: "tiny".into() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_index_covers_checkpoint() {
        let ckpt = tiny_checkpoint(1);
        let idx = tiny_tensor_index();
        assert_eq!(idx.len(), ckpt.tensors.len());
        for (name, shape) in &idx {
            assert_eq!(&ckpt.get(name).shape, shape, "{name}");
        }
    }

    #[test]
    fn manifest_is_consistent() {
        let m = tiny_manifest(12, 2);
        let entry = m.model(TINY_SIZE).unwrap();
        assert_eq!(entry.config.d_model, 16);
        assert_eq!(entry.tensors[0].name, "embed");
        assert_eq!(m.calib_tokens, 24);
    }

    #[test]
    fn corpus_in_vocab() {
        let c = tiny_corpus(500, 3);
        assert_eq!(c.len(), 500);
        assert!(c.bytes.iter().all(|&b| b < 32));
    }
}
