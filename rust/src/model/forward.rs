//! Pure-Rust transformer forward — the serving hot path (token-by-token
//! decode with a KV cache) and the reference evaluation path.
//!
//! Mirrors `python/compile/model.py` exactly (pre-norm blocks, fused qkv,
//! GELU-tanh MLP, weights in (out, in) layout applied as W·x); parity with
//! the XLA `lm_fwd_*` artifacts is asserted by the integration tests.
//!
//! Linear weights are either dense f32 (the FP16-baseline analog) or
//! [`PackedMatrix`] (the quantized model) — the ONLY difference between
//! baseline and quantized serving is which matvec kernel runs, exactly the
//! paper's deployment story.

use crate::model::checkpoint::{Checkpoint, QuantizedCheckpoint};
use crate::model::kernels::{self, Sparse24Tiled, TiledPacked};
use crate::model::kvpool::{KvDtype, KvPool, SeqCache};
use crate::model::matvec::{
    matmul_f32_bias, matmul_f32_bias_serial, matmul_packed_bias, matmul_packed_bias_serial,
    matmul_sparse24_bias, matmul_sparse24_bias_serial, matvec_f32_bias, matvec_f32_bias_serial,
    matvec_packed_bias, matvec_packed_bias_serial, matvec_sparse24_bias,
    matvec_sparse24_bias_serial, matvec_sparse24_tiled_bias, matvec_sparse24_tiled_bias_serial,
    matvec_tiled_bias, matvec_tiled_bias_serial, MATVEC_PAR_MIN_ELEMS,
};
use crate::model::ModelConfig;
use crate::quant::sparse::Sparse24Matrix;
use crate::quant::PackedMatrix;
use crate::util::par::{self, Pool};

/// A packed linear's serving form: the canonical [`PackedMatrix`] plus,
/// when the active ISA has a tiled microkernel for this bit width, the
/// register-tiled interleaved copy ([`TiledPacked`], built once here at
/// load time — DESIGN.md §Kernels). The batch-1 decode matvec runs on the
/// tiled layout; the batched matmul and every ragged shape stay on the
/// flat layout (same results — see `matvec::matvec_tiled`).
#[derive(Debug, Clone)]
pub struct PackedLinear {
    pub packed: PackedMatrix,
    pub tiled: Option<TiledPacked>,
}

impl PackedLinear {
    pub fn new(packed: PackedMatrix) -> Self {
        let tiled = if kernels::tiled_supported(kernels::isa(), packed.bits) {
            TiledPacked::from_packed(&packed)
        } else {
            None
        };
        PackedLinear { packed, tiled }
    }
}

/// A 2:4 sparse-quantized linear's serving form: the canonical
/// [`Sparse24Matrix`] plus, when the active ISA has a sparse tiled
/// microkernel for this bit width, the register-tiled interleaved copy
/// ([`Sparse24Tiled`]) — the same two-layout story as [`PackedLinear`],
/// over the sparse pack format (DESIGN.md §Sparsity).
#[derive(Debug, Clone)]
pub struct Sparse24Linear {
    pub flat: Sparse24Matrix,
    pub tiled: Option<Sparse24Tiled>,
}

impl Sparse24Linear {
    pub fn new(flat: Sparse24Matrix) -> Self {
        let tiled = if kernels::sparse24_tiled_supported(kernels::isa(), flat.bits) {
            Some(Sparse24Tiled::from_sparse(&flat))
        } else {
            None
        };
        Sparse24Linear { flat, tiled }
    }
}

/// A linear layer's weights on the decode path.
#[derive(Debug, Clone)]
pub enum LinearWeight {
    Dense { w: Vec<f32>, drow: usize, dcol: usize },
    Packed(PackedLinear),
    Sparse24(Sparse24Linear),
}

impl LinearWeight {
    /// Wrap a packed matrix (builds the tiled layout when the active ISA
    /// can use it).
    pub fn packed(p: PackedMatrix) -> Self {
        LinearWeight::Packed(PackedLinear::new(p))
    }

    /// Wrap a 2:4 sparse matrix (builds the sparse tiled layout when the
    /// active ISA can use it).
    pub fn sparse24(m: Sparse24Matrix) -> Self {
        LinearWeight::Sparse24(Sparse24Linear::new(m))
    }

    pub fn out_dim(&self) -> usize {
        match self {
            LinearWeight::Dense { drow, .. } => *drow,
            LinearWeight::Packed(pl) => pl.packed.drow,
            LinearWeight::Sparse24(sl) => sl.flat.drow,
        }
    }

    /// y = W x + b. With `serial` the never-spawning kernel twins run —
    /// for decode inside already-parallel workers (eval::perplexity).
    pub fn apply_with(&self, x: &[f32], b: &[f32], y: &mut [f32], serial: bool) {
        match self {
            LinearWeight::Dense { w, drow, dcol } => {
                if serial {
                    matvec_f32_bias_serial(w, x, b, *drow, *dcol, y)
                } else {
                    matvec_f32_bias(w, x, b, *drow, *dcol, y)
                }
            }
            LinearWeight::Packed(pl) => {
                // the tiled layout is only entered when the CURRENT ISA
                // has a microkernel for it — if the ISA was flipped after
                // load (tests), fall back to the flat path so
                // `GPTQ_ISA=scalar` always means the historical kernels
                if let Some(t) = &pl.tiled {
                    if kernels::tiled_supported(kernels::isa(), t.bits) {
                        if serial {
                            return matvec_tiled_bias_serial(t, x, b, y);
                        }
                        return matvec_tiled_bias(t, x, b, y);
                    }
                }
                if serial {
                    matvec_packed_bias_serial(&pl.packed, x, b, y)
                } else {
                    matvec_packed_bias(&pl.packed, x, b, y)
                }
            }
            LinearWeight::Sparse24(sl) => {
                // same ISA re-check discipline as the packed tiled path
                if let Some(t) = &sl.tiled {
                    if kernels::sparse24_tiled_supported(kernels::isa(), t.bits) {
                        if serial {
                            return matvec_sparse24_tiled_bias_serial(t, x, b, y);
                        }
                        return matvec_sparse24_tiled_bias(t, x, b, y);
                    }
                }
                if serial {
                    matvec_sparse24_bias_serial(&sl.flat, x, b, y)
                } else {
                    matvec_sparse24_bias(&sl.flat, x, b, y)
                }
            }
        }
    }

    /// y = W x + b (auto-parallel kernels).
    pub fn apply(&self, x: &[f32], b: &[f32], y: &mut [f32]) {
        self.apply_with(x, b, y, false)
    }

    /// Batched Y = W·X + b over `n` stacked activations: `xs` is
    /// sequence-major (n × in), `ys` ROW-major (out × n), so each weight
    /// row — packed or dense — is read once for all n sequences (the
    /// continuous-batching kernel; see `decode_steps`). Per-sequence
    /// arithmetic is bit-identical to [`LinearWeight::apply_with`].
    pub fn apply_batch(&self, xs: &[f32], b: &[f32], n: usize, ys: &mut [f32], serial: bool) {
        match self {
            LinearWeight::Dense { w, drow, dcol } => {
                if serial {
                    matmul_f32_bias_serial(w, xs, b, *drow, *dcol, n, ys)
                } else {
                    matmul_f32_bias(w, xs, b, *drow, *dcol, n, ys)
                }
            }
            LinearWeight::Packed(pl) => {
                if serial {
                    matmul_packed_bias_serial(&pl.packed, xs, b, n, ys)
                } else {
                    matmul_packed_bias(&pl.packed, xs, b, n, ys)
                }
            }
            LinearWeight::Sparse24(sl) => {
                if serial {
                    matmul_sparse24_bias_serial(&sl.flat, xs, b, n, ys)
                } else {
                    matmul_sparse24_bias(&sl.flat, xs, b, n, ys)
                }
            }
        }
    }

    /// Weight bytes touched per matvec (Table 5 traffic accounting; the
    /// tiled layout streams the same bytes, just interleaved).
    pub fn traffic_bytes(&self) -> usize {
        match self {
            LinearWeight::Dense { w, .. } => w.len() * 4,
            LinearWeight::Packed(pl) => pl.packed.storage_bytes(),
            LinearWeight::Sparse24(sl) => sl.flat.storage_bytes(),
        }
    }
}

#[derive(Debug, Clone)]
struct BlockWeights {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    wqkv: LinearWeight,
    wqkv_b: Vec<f32>,
    wo: LinearWeight,
    wo_b: Vec<f32>,
    wup: LinearWeight,
    wup_b: Vec<f32>,
    wdn: LinearWeight,
    wdn_b: Vec<f32>,
}

/// Per-sequence KV cache: `k[layer]`/`v[layer]` hold (max_seq × d_model)
/// rows (head-major within a row), `len` positions filled.
#[derive(Debug, Clone)]
pub struct KvCache {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    pub len: usize,
    max_seq: usize,
    d_model: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> Self {
        Self {
            k: (0..cfg.n_layers).map(|_| vec![0.0; cfg.max_seq * cfg.d_model]).collect(),
            v: (0..cfg.n_layers).map(|_| vec![0.0; cfg.max_seq * cfg.d_model]).collect(),
            len: 0,
            max_seq: cfg.max_seq,
            d_model: cfg.d_model,
        }
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Bytes held (the "+9 GB of keys and values" accounting of §Practical
    /// Speedups, at our scale).
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * self.max_seq * self.d_model * 4
    }
}

/// CPU model instance (dense or packed weights). `Clone` gives each
/// evaluation worker its own decode state (see `eval::perplexity`).
#[derive(Clone)]
pub struct CpuModel {
    pub config: ModelConfig,
    embed: Vec<f32>,   // vocab × d
    pos: Vec<f32>,     // max_seq × d
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
    unembed: Vec<f32>, // vocab × d
    blocks: Vec<BlockWeights>,
    // scratch buffers (decode is single-threaded per model instance)
    scratch: Scratch,
    // batched-decode scratch, grown on demand by `decode_steps`
    bscratch: BatchScratch,
    /// Use the never-spawning matvec twins on the decode path — set by
    /// callers whose workers are already parallel (eval::perplexity), so
    /// matvecs don't nest thread scopes inside every worker.
    serial_kernels: bool,
}

#[derive(Clone)]
struct Scratch {
    x: Vec<f32>,
    x1: Vec<f32>,
    qkv: Vec<f32>,
    attn: Vec<f32>,
    proj: Vec<f32>,
    hidden: Vec<f32>,
    logits: Vec<f32>,
    att_w: Vec<f32>,
}

/// Scratch for the batched decode path (`decode_steps`): per-sequence
/// activations are sequence-major (n × width); `rm` holds each batched
/// matmul's row-major output before it is scattered back.
#[derive(Clone, Default)]
struct BatchScratch {
    cap: usize,
    xs: Vec<f32>,
    x1s: Vec<f32>,
    qkvs: Vec<f32>,
    attns: Vec<f32>,
    hiddens: Vec<f32>,
    rm: Vec<f32>,
}

/// LayerNorm over one row (eps 1e-5, matching the L2 graph). Shared with
/// the reference execution backend.
pub(crate) fn layer_norm(x: &[f32], g: &[f32], b: &[f32], out: &mut [f32]) {
    let n = x.len() as f32;
    let mu: f32 = x.iter().sum::<f32>() / n;
    let var: f32 = x.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mu) * inv * g[i] + b[i];
    }
}

/// jax.nn.gelu default (tanh approximation) — must match the L2 graph.
/// Shared with the reference execution backend.
#[inline]
pub(crate) fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// dst[j·rows + r] = src[r·n + j] — scatter a batched matmul's row-major
/// output (rows × n) back to sequence-major buffers (n × rows).
fn transpose_rows(src: &[f32], rows: usize, n: usize, dst: &mut [f32]) {
    debug_assert!(src.len() >= rows * n && dst.len() >= rows * n);
    for (r, srow) in src.chunks_exact(n).take(rows).enumerate() {
        for (j, &v) in srow.iter().enumerate() {
            dst[j * rows + r] = v;
        }
    }
}

/// K/V row source for one sequence's attention walk: `Pool` borrows f32
/// rows straight out of an F32 pool (the historical zero-copy path —
/// same calls, same arithmetic, bit-identical); `Buf` reads from a
/// per-worker scratch buffer that Q8 pages were dequantized into.
enum KvRows<'a> {
    Pool { pool: &'a KvPool, sc: &'a SeqCache, layer: usize },
    Buf { k: &'a [f32], v: &'a [f32], d: usize },
}

impl KvRows<'_> {
    #[inline]
    fn k(&self, p: usize) -> &[f32] {
        match self {
            KvRows::Pool { pool, sc, layer } => pool.k_row(sc, *layer, p),
            KvRows::Buf { k, d, .. } => &k[p * d..(p + 1) * d],
        }
    }

    #[inline]
    fn v(&self, p: usize) -> &[f32] {
        match self {
            KvRows::Pool { pool, sc, layer } => pool.v_row(sc, *layer, p),
            KvRows::Buf { v, d, .. } => &v[p * d..(p + 1) * d],
        }
    }
}

/// Per-sequence causal attention for one layer of the batched decode:
/// sequence `j` attends over positions `0..=seqs[j].len` of its OWN
/// pages. Parallel ACROSS sequences (each output row is one sequence —
/// disjoint, partition-independent arithmetic, so any thread count is
/// bit-identical); within a sequence the loops match `decode_step`
/// exactly. Q8 pools dequantize each sequence's rows into a per-worker
/// scratch buffer first ([`KvPool::read_k_row`]) — deterministic, so
/// the bitwise parity contracts hold within Q8 too; the matvec kernels
/// never see quantized KV.
#[allow(clippy::too_many_arguments)]
fn batched_attention(
    pool: &KvPool,
    seqs: &[&mut SeqCache],
    qkvs: &[f32],
    d: usize,
    h: usize,
    hd: usize,
    layer: usize,
    attns: &mut [f32],
    serial: bool,
) {
    let n = seqs.len();
    let maxpos = seqs.iter().map(|s| s.len).max().unwrap_or(0) + 1;
    let tp = if serial || n * d * maxpos < MATVEC_PAR_MIN_ELEMS {
        Pool::serial()
    } else {
        Pool::global()
    };
    par::for_rows_mut(&tp, attns, n, d, |range, chunk| {
        // one score buffer per worker chunk (every entry is overwritten
        // before it is read, so reuse across sequences is safe); the
        // dequant scratch (Q8 only) is likewise per worker chunk
        let mut att_buf: Vec<f32> = Vec::new();
        let mut kbuf: Vec<f32> = Vec::new();
        let mut vbuf: Vec<f32> = Vec::new();
        for (jj, out_all) in chunk.chunks_exact_mut(d).enumerate() {
            let j = range.start + jj;
            let sc: &SeqCache = &*seqs[j];
            let pos = sc.len;
            let q = &qkvs[j * 3 * d..j * 3 * d + d];
            let scale = 1.0 / (hd as f32).sqrt();
            if att_buf.len() < pos + 1 {
                att_buf.resize(pos + 1, 0.0);
            }
            let att = &mut att_buf[..pos + 1];
            let rows = match pool.dtype() {
                KvDtype::F32 => KvRows::Pool { pool, sc, layer },
                KvDtype::Q8 => {
                    if kbuf.len() < (pos + 1) * d {
                        kbuf.resize((pos + 1) * d, 0.0);
                        vbuf.resize((pos + 1) * d, 0.0);
                    }
                    for p in 0..=pos {
                        pool.read_k_row(sc, layer, p, &mut kbuf[p * d..(p + 1) * d]);
                        pool.read_v_row(sc, layer, p, &mut vbuf[p * d..(p + 1) * d]);
                    }
                    KvRows::Buf { k: &kbuf, v: &vbuf, d }
                }
            };
            for head in 0..h {
                let qh = &q[head * hd..(head + 1) * hd];
                let mut maxv = f32::NEG_INFINITY;
                for (p, av) in att.iter_mut().enumerate() {
                    let kh = &rows.k(p)[head * hd..(head + 1) * hd];
                    let mut dot = 0.0f32;
                    for i in 0..hd {
                        dot += qh[i] * kh[i];
                    }
                    *av = dot * scale;
                    maxv = maxv.max(*av);
                }
                let mut denom = 0.0f32;
                for av in att.iter_mut() {
                    *av = (*av - maxv).exp();
                    denom += *av;
                }
                let out = &mut out_all[head * hd..(head + 1) * hd];
                out.fill(0.0);
                for (p, &av) in att.iter().enumerate() {
                    let wgt = av / denom;
                    let vh = &rows.v(p)[head * hd..(head + 1) * hd];
                    for i in 0..hd {
                        out[i] += wgt * vh[i];
                    }
                }
            }
        }
    });
}

/// Causal attention for `n_span` consecutive positions of ONE sequence
/// (the speculative-verify pass of `decode_span`): lane `j` sits at
/// position `base + j` and attends rows `0..=base + j` of the
/// sequence's pages — every row it needs, including the span rows below
/// it, was written before this call. The per-lane loops are copied from
/// [`batched_attention`] verbatim (same buffers, same accumulation
/// order), so each lane's output is bit-identical to the sequential
/// single-token step at the same position; Q8 pools take the same
/// dequant-to-scratch path.
#[allow(clippy::too_many_arguments)]
fn span_attention(
    pool: &KvPool,
    seq: &SeqCache,
    base: usize,
    n_span: usize,
    qkvs: &[f32],
    d: usize,
    h: usize,
    hd: usize,
    layer: usize,
    attns: &mut [f32],
    serial: bool,
) {
    let maxpos = base + n_span;
    let tp = if serial || n_span * d * maxpos < MATVEC_PAR_MIN_ELEMS {
        Pool::serial()
    } else {
        Pool::global()
    };
    par::for_rows_mut(&tp, attns, n_span, d, |range, chunk| {
        let mut att_buf: Vec<f32> = Vec::new();
        let mut kbuf: Vec<f32> = Vec::new();
        let mut vbuf: Vec<f32> = Vec::new();
        for (jj, out_all) in chunk.chunks_exact_mut(d).enumerate() {
            let j = range.start + jj;
            let pos = base + j;
            let q = &qkvs[j * 3 * d..j * 3 * d + d];
            let scale = 1.0 / (hd as f32).sqrt();
            if att_buf.len() < pos + 1 {
                att_buf.resize(pos + 1, 0.0);
            }
            let att = &mut att_buf[..pos + 1];
            let rows = match pool.dtype() {
                KvDtype::F32 => KvRows::Pool { pool, sc: seq, layer },
                KvDtype::Q8 => {
                    if kbuf.len() < (pos + 1) * d {
                        kbuf.resize((pos + 1) * d, 0.0);
                        vbuf.resize((pos + 1) * d, 0.0);
                    }
                    for p in 0..=pos {
                        pool.read_k_row(seq, layer, p, &mut kbuf[p * d..(p + 1) * d]);
                        pool.read_v_row(seq, layer, p, &mut vbuf[p * d..(p + 1) * d]);
                    }
                    KvRows::Buf { k: &kbuf, v: &vbuf, d }
                }
            };
            for head in 0..h {
                let qh = &q[head * hd..(head + 1) * hd];
                let mut maxv = f32::NEG_INFINITY;
                for (p, av) in att.iter_mut().enumerate() {
                    let kh = &rows.k(p)[head * hd..(head + 1) * hd];
                    let mut dot = 0.0f32;
                    for i in 0..hd {
                        dot += qh[i] * kh[i];
                    }
                    *av = dot * scale;
                    maxv = maxv.max(*av);
                }
                let mut denom = 0.0f32;
                for av in att.iter_mut() {
                    *av = (*av - maxv).exp();
                    denom += *av;
                }
                let out = &mut out_all[head * hd..(head + 1) * hd];
                out.fill(0.0);
                for (p, &av) in att.iter().enumerate() {
                    let wgt = av / denom;
                    let vh = &rows.v(p)[head * hd..(head + 1) * hd];
                    for i in 0..hd {
                        out[i] += wgt * vh[i];
                    }
                }
            }
        }
    });
}

/// Typed construction failure for [`CpuModel`]. The serving stack hands
/// token ids around as `u8` (KV pages, request prompts, the sampling
/// pick), so a vocab that cannot round-trip through `u8` must be
/// rejected HERE, once — the old failure mode was `argmax` silently
/// truncating `i as u8` per token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelBuildError {
    /// vocab exceeds the u8 token-id domain (max 256)
    VocabTooLarge { vocab: usize },
    /// vocab of zero produces empty logits — nothing to sample
    EmptyVocab,
}

impl std::fmt::Display for ModelBuildError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ModelBuildError::VocabTooLarge { vocab } => write!(
                f,
                "vocab {vocab} exceeds the u8 token-id domain (256): the serving stack would \
                 silently truncate token ids"
            ),
            ModelBuildError::EmptyVocab => write!(f, "vocab 0: the model can emit no tokens"),
        }
    }
}

impl std::error::Error for ModelBuildError {}

impl CpuModel {
    /// Build with dense f32 weights (the FP16-baseline analog). Panics
    /// on an invalid config; [`CpuModel::try_from_checkpoint`] is the
    /// fallible twin.
    pub fn from_checkpoint(ckpt: &Checkpoint) -> Self {
        Self::try_from_checkpoint(ckpt).unwrap_or_else(|e| panic!("from_checkpoint: {e}"))
    }

    /// Fallible build from a dense checkpoint: validates the config
    /// (vocab must fit the u8 token-id domain) before touching weights.
    pub fn try_from_checkpoint(ckpt: &Checkpoint) -> Result<Self, ModelBuildError> {
        let cfg = ckpt.config.clone();
        let blocks = (0..cfg.n_layers)
            .map(|l| {
                let lin = |name: &str| {
                    let t = ckpt.block_tensor(l, name);
                    let (drow, dcol) = t.dims2();
                    LinearWeight::Dense { w: t.data.clone(), drow, dcol }
                };
                BlockWeights {
                    ln1_g: ckpt.block_tensor(l, "ln1_g").data.clone(),
                    ln1_b: ckpt.block_tensor(l, "ln1_b").data.clone(),
                    ln2_g: ckpt.block_tensor(l, "ln2_g").data.clone(),
                    ln2_b: ckpt.block_tensor(l, "ln2_b").data.clone(),
                    wqkv: lin("wqkv"),
                    wqkv_b: ckpt.block_tensor(l, "wqkv_b").data.clone(),
                    wo: lin("wo"),
                    wo_b: ckpt.block_tensor(l, "wo_b").data.clone(),
                    wup: lin("wup"),
                    wup_b: ckpt.block_tensor(l, "wup_b").data.clone(),
                    wdn: lin("wdn"),
                    wdn_b: ckpt.block_tensor(l, "wdn_b").data.clone(),
                }
            })
            .collect();
        Self::assemble(
            cfg,
            ckpt.get("embed").data.clone(),
            ckpt.get("pos").data.clone(),
            ckpt.get("lnf_g").data.clone(),
            ckpt.get("lnf_b").data.clone(),
            ckpt.get("unembed").data.clone(),
            blocks,
        )
    }

    /// Build with packed quantized linears (the GPTQ-deployed model).
    /// Panics on an invalid config; [`CpuModel::try_from_quantized`] is
    /// the fallible twin.
    pub fn from_quantized(q: &QuantizedCheckpoint) -> Self {
        Self::try_from_quantized(q).unwrap_or_else(|e| panic!("from_quantized: {e}"))
    }

    /// Fallible build from a quantized checkpoint (same vocab
    /// validation as [`CpuModel::try_from_checkpoint`]).
    pub fn try_from_quantized(q: &QuantizedCheckpoint) -> Result<Self, ModelBuildError> {
        let cfg = q.config.clone();
        let blocks = (0..cfg.n_layers)
            .map(|l| {
                let lin = |name: &str| {
                    let key = format!("blocks.{l}.{name}");
                    match q.sparse.get(&key) {
                        Some(m) => LinearWeight::sparse24(m.clone()),
                        None => LinearWeight::packed(q.packed[&key].clone()),
                    }
                };
                let fp = |name: &str| q.fp[&format!("blocks.{l}.{name}")].data.clone();
                BlockWeights {
                    ln1_g: fp("ln1_g"),
                    ln1_b: fp("ln1_b"),
                    ln2_g: fp("ln2_g"),
                    ln2_b: fp("ln2_b"),
                    wqkv: lin("wqkv"),
                    wqkv_b: fp("wqkv_b"),
                    wo: lin("wo"),
                    wo_b: fp("wo_b"),
                    wup: lin("wup"),
                    wup_b: fp("wup_b"),
                    wdn: lin("wdn"),
                    wdn_b: fp("wdn_b"),
                }
            })
            .collect();
        Self::assemble(
            cfg,
            q.fp["embed"].data.clone(),
            q.fp["pos"].data.clone(),
            q.fp["lnf_g"].data.clone(),
            q.fp["lnf_b"].data.clone(),
            q.fp["unembed"].data.clone(),
            blocks,
        )
    }

    /// The single construction funnel: every `CpuModel` passes through
    /// here, so the vocab-fits-u8 invariant holds for every instance —
    /// `argmax`'s `i as u8` and the u8 prompt/KV plumbing are safe by
    /// construction afterwards.
    #[allow(clippy::too_many_arguments)]
    fn assemble(
        config: ModelConfig,
        embed: Vec<f32>,
        pos: Vec<f32>,
        lnf_g: Vec<f32>,
        lnf_b: Vec<f32>,
        unembed: Vec<f32>,
        blocks: Vec<BlockWeights>,
    ) -> Result<Self, ModelBuildError> {
        if config.vocab == 0 {
            return Err(ModelBuildError::EmptyVocab);
        }
        if config.vocab > 256 {
            return Err(ModelBuildError::VocabTooLarge { vocab: config.vocab });
        }
        let d = config.d_model;
        let scratch = Scratch {
            x: vec![0.0; d],
            x1: vec![0.0; d],
            qkv: vec![0.0; 3 * d],
            attn: vec![0.0; d],
            proj: vec![0.0; d.max(config.d_ff)],
            hidden: vec![0.0; config.d_ff],
            logits: vec![0.0; config.vocab],
            att_w: vec![0.0; config.max_seq],
        };
        Ok(Self {
            config,
            embed,
            pos,
            lnf_g,
            lnf_b,
            unembed,
            blocks,
            scratch,
            bscratch: BatchScratch::default(),
            serial_kernels: false,
        })
    }

    fn ensure_batch_scratch(&mut self, n: usize) {
        if self.bscratch.cap >= n {
            return;
        }
        let (d, ff, vocab) = (self.config.d_model, self.config.d_ff, self.config.vocab);
        let rm_w = (3 * d).max(ff).max(vocab);
        self.bscratch = BatchScratch {
            cap: n,
            xs: vec![0.0; n * d],
            x1s: vec![0.0; n * d],
            qkvs: vec![0.0; n * 3 * d],
            attns: vec![0.0; n * d],
            hiddens: vec![0.0; n * ff],
            rm: vec![0.0; n * rm_w],
        };
    }

    /// Pin the decode path to the serial matvec kernels (bit-identical to
    /// the auto-parallel ones; see DESIGN.md §Parallelism).
    pub fn set_serial_kernels(&mut self, on: bool) {
        self.serial_kernels = on;
    }

    /// Total weight bytes the decode path touches per token (all linears) —
    /// the bandwidth model behind the paper's Table 5.
    pub fn traffic_bytes_per_token(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| {
                b.wqkv.traffic_bytes() + b.wo.traffic_bytes() + b.wup.traffic_bytes() + b.wdn.traffic_bytes()
            })
            .sum()
    }

    /// One decode step: consume `token` at position `cache.len`, return the
    /// next-token logits. This is the paper's generative-inference loop.
    pub fn decode_step(&mut self, cache: &mut KvCache, token: u8) -> &[f32] {
        let cfg = &self.config;
        let d = cfg.d_model;
        let h = cfg.n_heads;
        let hd = cfg.head_dim();
        let pos = cache.len;
        assert!(pos < cfg.max_seq, "sequence overflow");
        let serial = self.serial_kernels;
        let s = &mut self.scratch;

        // embedding + positional
        for i in 0..d {
            s.x[i] = self.embed[token as usize * d + i] + self.pos[pos * d + i];
        }

        for (l, blk) in self.blocks.iter().enumerate() {
            // attention
            layer_norm(&s.x, &blk.ln1_g, &blk.ln1_b, &mut s.x1);
            blk.wqkv.apply_with(&s.x1, &blk.wqkv_b, &mut s.qkv, serial);
            let (q, kv) = s.qkv.split_at(d);
            let (k_new, v_new) = kv.split_at(d);
            cache.k[l][pos * d..(pos + 1) * d].copy_from_slice(k_new);
            cache.v[l][pos * d..(pos + 1) * d].copy_from_slice(v_new);
            let scale = 1.0 / (hd as f32).sqrt();
            for head in 0..h {
                let qh = &q[head * hd..(head + 1) * hd];
                // scores over positions 0..=pos
                let att = &mut s.att_w[..=pos];
                let mut maxv = f32::NEG_INFINITY;
                for (p, av) in att.iter_mut().enumerate() {
                    let kh = &cache.k[l][p * d + head * hd..p * d + (head + 1) * hd];
                    let mut dot = 0.0f32;
                    for i in 0..hd {
                        dot += qh[i] * kh[i];
                    }
                    *av = dot * scale;
                    maxv = maxv.max(*av);
                }
                let mut denom = 0.0f32;
                for av in att.iter_mut() {
                    *av = (*av - maxv).exp();
                    denom += *av;
                }
                let out = &mut s.attn[head * hd..(head + 1) * hd];
                out.fill(0.0);
                for (p, &av) in att.iter().enumerate() {
                    let wgt = av / denom;
                    let vh = &cache.v[l][p * d + head * hd..p * d + (head + 1) * hd];
                    for i in 0..hd {
                        out[i] += wgt * vh[i];
                    }
                }
            }
            blk.wo.apply_with(&s.attn, &blk.wo_b, &mut s.proj[..d], serial);
            for i in 0..d {
                s.x[i] += s.proj[i];
            }
            // MLP
            layer_norm(&s.x, &blk.ln2_g, &blk.ln2_b, &mut s.x1);
            blk.wup.apply_with(&s.x1, &blk.wup_b, &mut s.hidden, serial);
            for v in s.hidden.iter_mut() {
                *v = gelu(*v);
            }
            blk.wdn.apply_with(&s.hidden, &blk.wdn_b, &mut s.proj[..d], serial);
            for i in 0..d {
                s.x[i] += s.proj[i];
            }
        }

        layer_norm(&s.x, &self.lnf_g, &self.lnf_b, &mut s.x1);
        // unembed: vocab × d
        for v in 0..cfg.vocab {
            let row = &self.unembed[v * d..(v + 1) * d];
            let mut acc = 0.0f32;
            for i in 0..d {
                acc += row[i] * s.x1[i];
            }
            s.logits[v] = acc;
        }
        cache.len += 1;
        &s.logits
    }

    /// Batched decode: advance N sequences one token each through ONE
    /// pass over the weights (the continuous-batching hot path). Every
    /// linear runs as a matmul over the n stacked activations — each
    /// weight row, packed or dense, is read once for the whole batch —
    /// while attention stays per-sequence over that sequence's own pages
    /// in `pool`. Returns the next-token logits, sequence-major
    /// (n × vocab).
    ///
    /// Parity contract (DESIGN.md §Serving, `tests/continuous_batching.rs`):
    /// per sequence this is bit-identical to [`CpuModel::decode_step`] on
    /// dense linears and within 1e-5 on packed ones (in practice also
    /// bit-identical: the batched kernels reuse the single-sequence
    /// accumulation order).
    ///
    /// The caller must have reserved pool capacity for each sequence's
    /// next position ([`KvPool::reserve`]) — admission control and
    /// backpressure live in the scheduler, not here. Sequences may be
    /// forks ([`KvPool::fork`]): attention walks whatever pages the
    /// sequence maps, shared or owned, and `reserve`'s copy-on-write
    /// guarantees this step's `write_row` never lands in a shared page —
    /// so prefix sharing is invisible to the math (same f32 rows read
    /// either way; `tests/prefix_cache.rs` pins this bitwise).
    ///
    /// Q8 pools are a distinct numeric mode (this step's K/V rows are
    /// quantized by `write_row` and read back dequantized, including the
    /// current position), so Q8 logits differ from [`CpuModel::decode_step`]
    /// within the documented drift tolerance (EXPERIMENTS.md §KV capacity)
    /// — but all the WITHIN-mode contracts above stay bitwise, because
    /// quantization happens once at write and dequant is deterministic
    /// (`tests/kv_quant.rs`).
    pub fn decode_steps(
        &mut self,
        pool: &mut KvPool,
        seqs: &mut [&mut SeqCache],
        tokens: &[u8],
    ) -> Vec<f32> {
        let n = seqs.len();
        assert_eq!(n, tokens.len(), "decode_steps: one token per sequence");
        if n == 0 {
            return Vec::new();
        }
        let cfg = &self.config;
        let (d, h, hd, ff, vocab) = (cfg.d_model, cfg.n_heads, cfg.head_dim(), cfg.d_ff, cfg.vocab);
        for sc in seqs.iter() {
            assert!(sc.len < cfg.max_seq, "sequence overflow");
            assert!(pool.capacity_of(sc) > sc.len, "decode_steps: reserve pool pages first");
        }
        self.ensure_batch_scratch(n);
        let serial = self.serial_kernels;
        let s = &mut self.bscratch;

        // embedding + positional, per sequence
        for j in 0..n {
            let (tok, p) = (tokens[j] as usize, seqs[j].len);
            let x = &mut s.xs[j * d..(j + 1) * d];
            for i in 0..d {
                x[i] = self.embed[tok * d + i] + self.pos[p * d + i];
            }
        }

        for (l, blk) in self.blocks.iter().enumerate() {
            // attention: LN, fused qkv over the whole batch
            for j in 0..n {
                layer_norm(
                    &s.xs[j * d..(j + 1) * d],
                    &blk.ln1_g,
                    &blk.ln1_b,
                    &mut s.x1s[j * d..(j + 1) * d],
                );
            }
            let qkv_rm = &mut s.rm[..3 * d * n];
            blk.wqkv.apply_batch(&s.x1s[..n * d], &blk.wqkv_b, n, qkv_rm, serial);
            transpose_rows(qkv_rm, 3 * d, n, &mut s.qkvs[..n * 3 * d]);
            // append this step's K/V rows to each sequence's pages
            for j in 0..n {
                let sc: &SeqCache = &*seqs[j];
                let kv = &s.qkvs[j * 3 * d + d..(j + 1) * 3 * d];
                let (k_new, v_new) = kv.split_at(d);
                pool.write_row(sc, l, sc.len, k_new, v_new);
            }
            // attention stays per-sequence over its own pages (parallel
            // ACROSS sequences; arithmetic identical to decode_step)
            batched_attention(pool, seqs, &s.qkvs[..n * 3 * d], d, h, hd, l, &mut s.attns[..n * d], serial);
            let proj_rm = &mut s.rm[..d * n];
            blk.wo.apply_batch(&s.attns[..n * d], &blk.wo_b, n, proj_rm, serial);
            for j in 0..n {
                for i in 0..d {
                    s.xs[j * d + i] += proj_rm[i * n + j];
                }
            }
            // MLP
            for j in 0..n {
                layer_norm(
                    &s.xs[j * d..(j + 1) * d],
                    &blk.ln2_g,
                    &blk.ln2_b,
                    &mut s.x1s[j * d..(j + 1) * d],
                );
            }
            let up_rm = &mut s.rm[..ff * n];
            blk.wup.apply_batch(&s.x1s[..n * d], &blk.wup_b, n, up_rm, serial);
            for j in 0..n {
                for r in 0..ff {
                    s.hiddens[j * ff + r] = gelu(up_rm[r * n + j]);
                }
            }
            let dn_rm = &mut s.rm[..d * n];
            blk.wdn.apply_batch(&s.hiddens[..n * ff], &blk.wdn_b, n, dn_rm, serial);
            for j in 0..n {
                for i in 0..d {
                    s.xs[j * d + i] += dn_rm[i * n + j];
                }
            }
        }

        for j in 0..n {
            layer_norm(
                &s.xs[j * d..(j + 1) * d],
                &self.lnf_g,
                &self.lnf_b,
                &mut s.x1s[j * d..(j + 1) * d],
            );
        }
        // unembed: each vocab row read once for all n sequences, with the
        // same plain sequential dot as decode_step (bit-parity)
        let head_rm = &mut s.rm[..vocab * n];
        let x1s = &s.x1s[..n * d];
        let tp = if serial || vocab * d < MATVEC_PAR_MIN_ELEMS {
            Pool::serial()
        } else {
            Pool::global()
        };
        par::for_rows_mut(&tp, head_rm, vocab, n, |rows, chunk| {
            for (i, yrow) in chunk.chunks_exact_mut(n).enumerate() {
                let v = rows.start + i;
                let row = &self.unembed[v * d..(v + 1) * d];
                for (j, yv) in yrow.iter_mut().enumerate() {
                    let x1 = &x1s[j * d..(j + 1) * d];
                    let mut acc = 0.0f32;
                    for k in 0..d {
                        acc += row[k] * x1[k];
                    }
                    *yv = acc;
                }
            }
        });
        let mut out = vec![0.0f32; n * vocab];
        transpose_rows(head_rm, vocab, n, &mut out);
        for sc in seqs.iter_mut() {
            sc.len += 1;
        }
        out
    }

    /// Advance ONE sequence by `tokens.len()` consecutive positions in a
    /// single pass over the weights — the speculative-decoding verify
    /// kernel (DESIGN.md §Sampling & Speculative decoding). Lane `j`
    /// consumes `tokens[j]` at position `seq.len + j`; every linear runs
    /// as one batched matmul over the span (the same `apply_batch`
    /// kernels as [`CpuModel::decode_steps`], so each weight row is read
    /// once for all k+1 verify lanes), and each layer writes ALL span
    /// K/V rows before attention so lane `j` attends the rows its own
    /// pass produced for positions below it.
    ///
    /// Parity contract (`decode_span_matches_sequential_decode_bitwise`):
    /// lane `j`'s logits are bit-identical to feeding the same tokens
    /// one at a time through [`CpuModel::decode_steps`] — per-lane
    /// arithmetic never depends on the span width, and the K/V rows a
    /// lane reads are exactly the rows the sequential steps would have
    /// written (Q8 pools quantize once at write either way). This is
    /// what makes greedy spec-on ≡ spec-off bitwise: the scheduler
    /// verifies draft proposals against these logits, keeps the accepted
    /// prefix's rows (they ARE the target's canonical rows), and rolls
    /// `seq.len` back over the rejected tail.
    ///
    /// The caller must have reserved capacity for the whole span
    /// (`pool.reserve(seq, seq.len + tokens.len())`). On return
    /// `seq.len` has advanced by the span; returns sequence-major
    /// (tokens.len() × vocab) logits, one row per consumed token.
    pub fn decode_span(
        &mut self,
        pool: &mut KvPool,
        seq: &mut SeqCache,
        tokens: &[u8],
    ) -> Vec<f32> {
        let n = tokens.len();
        if n == 0 {
            return Vec::new();
        }
        let cfg = &self.config;
        let (d, h, hd, ff, vocab) = (cfg.d_model, cfg.n_heads, cfg.head_dim(), cfg.d_ff, cfg.vocab);
        let base = seq.len;
        assert!(base + n <= cfg.max_seq, "decode_span: sequence overflow");
        assert!(pool.capacity_of(seq) >= base + n, "decode_span: reserve the whole span first");
        self.ensure_batch_scratch(n);
        let serial = self.serial_kernels;
        let s = &mut self.bscratch;

        // embedding + positional, per lane
        for (j, &tok) in tokens.iter().enumerate() {
            let x = &mut s.xs[j * d..(j + 1) * d];
            for i in 0..d {
                x[i] = self.embed[tok as usize * d + i] + self.pos[(base + j) * d + i];
            }
        }

        for (l, blk) in self.blocks.iter().enumerate() {
            for j in 0..n {
                layer_norm(
                    &s.xs[j * d..(j + 1) * d],
                    &blk.ln1_g,
                    &blk.ln1_b,
                    &mut s.x1s[j * d..(j + 1) * d],
                );
            }
            let qkv_rm = &mut s.rm[..3 * d * n];
            blk.wqkv.apply_batch(&s.x1s[..n * d], &blk.wqkv_b, n, qkv_rm, serial);
            transpose_rows(qkv_rm, 3 * d, n, &mut s.qkvs[..n * 3 * d]);
            // ALL span rows land before attention: lane j's walk over
            // positions base..=base+j reads rows this very pass wrote
            for j in 0..n {
                let kv = &s.qkvs[j * 3 * d + d..(j + 1) * 3 * d];
                let (k_new, v_new) = kv.split_at(d);
                pool.write_row(seq, l, base + j, k_new, v_new);
            }
            span_attention(pool, seq, base, n, &s.qkvs[..n * 3 * d], d, h, hd, l, &mut s.attns[..n * d], serial);
            let proj_rm = &mut s.rm[..d * n];
            blk.wo.apply_batch(&s.attns[..n * d], &blk.wo_b, n, proj_rm, serial);
            for j in 0..n {
                for i in 0..d {
                    s.xs[j * d + i] += proj_rm[i * n + j];
                }
            }
            for j in 0..n {
                layer_norm(
                    &s.xs[j * d..(j + 1) * d],
                    &blk.ln2_g,
                    &blk.ln2_b,
                    &mut s.x1s[j * d..(j + 1) * d],
                );
            }
            let up_rm = &mut s.rm[..ff * n];
            blk.wup.apply_batch(&s.x1s[..n * d], &blk.wup_b, n, up_rm, serial);
            for j in 0..n {
                for r in 0..ff {
                    s.hiddens[j * ff + r] = gelu(up_rm[r * n + j]);
                }
            }
            let dn_rm = &mut s.rm[..d * n];
            blk.wdn.apply_batch(&s.hiddens[..n * ff], &blk.wdn_b, n, dn_rm, serial);
            for j in 0..n {
                for i in 0..d {
                    s.xs[j * d + i] += dn_rm[i * n + j];
                }
            }
        }

        for j in 0..n {
            layer_norm(
                &s.xs[j * d..(j + 1) * d],
                &self.lnf_g,
                &self.lnf_b,
                &mut s.x1s[j * d..(j + 1) * d],
            );
        }
        let head_rm = &mut s.rm[..vocab * n];
        let x1s = &s.x1s[..n * d];
        let tp = if serial || vocab * d < MATVEC_PAR_MIN_ELEMS {
            Pool::serial()
        } else {
            Pool::global()
        };
        par::for_rows_mut(&tp, head_rm, vocab, n, |rows, chunk| {
            for (i, yrow) in chunk.chunks_exact_mut(n).enumerate() {
                let v = rows.start + i;
                let row = &self.unembed[v * d..(v + 1) * d];
                for (j, yv) in yrow.iter_mut().enumerate() {
                    let x1 = &x1s[j * d..(j + 1) * d];
                    let mut acc = 0.0f32;
                    for k in 0..d {
                        acc += row[k] * x1[k];
                    }
                    *yv = acc;
                }
            }
        });
        let mut out = vec![0.0f32; n * vocab];
        transpose_rows(head_rm, vocab, n, &mut out);
        seq.len = base + n;
        out
    }

    /// Repack this model's quantizable linears at `bits` with
    /// round-to-nearest over their dequantized weights — the
    /// self-speculative draft (the paper's extreme-quant regime: the
    /// SAME checkpoint at 2–3 bits is cheap enough to propose tokens the
    /// full-precision/4-bit target verifies). Everything else — embed,
    /// positions, norms, biases, the unembed head, the model config and
    /// therefore the KV-page layout — is shared verbatim, so draft and
    /// target decode over the same pool pages interchangeably. 2:4
    /// sparse linears are already in a compressed serving form and are
    /// kept as-is.
    pub fn to_draft(&self, bits: u32) -> CpuModel {
        use crate::quant::rtn_quantize;
        let requant = |w: &LinearWeight| -> LinearWeight {
            let (dense, drow, dcol) = match w {
                LinearWeight::Dense { w, drow, dcol } => (w.clone(), *drow, *dcol),
                LinearWeight::Packed(pl) => {
                    (pl.packed.dequantize(), pl.packed.drow, pl.packed.dcol)
                }
                LinearWeight::Sparse24(sl) => return LinearWeight::Sparse24(sl.clone()),
            };
            let r = rtn_quantize(&dense, drow, dcol, bits, 0);
            LinearWeight::packed(PackedMatrix::from_result(&r))
        };
        let mut m = self.clone();
        for blk in &mut m.blocks {
            blk.wqkv = requant(&blk.wqkv);
            blk.wo = requant(&blk.wo);
            blk.wup = requant(&blk.wup);
            blk.wdn = requant(&blk.wdn);
        }
        m
    }

    /// Next-token logits for every position of `tokens` (teacher-forced) —
    /// the perplexity-evaluation path. Returns (seq × vocab) row-major.
    pub fn logits_all(&mut self, tokens: &[u8]) -> Vec<f32> {
        let vocab = self.config.vocab;
        let mut cache = KvCache::new(&self.config);
        let mut out = Vec::with_capacity(tokens.len() * vocab);
        for &t in tokens {
            let logits = self.decode_step(&mut cache, t);
            out.extend_from_slice(logits);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::tiny_checkpoint;
    use std::collections::BTreeMap;

    #[test]
    fn decode_deterministic_and_finite() {
        let ckpt = tiny_checkpoint(1);
        let mut m = CpuModel::from_checkpoint(&ckpt);
        let mut cache = KvCache::new(&m.config);
        let l1 = m.decode_step(&mut cache, 5).to_vec();
        assert!(l1.iter().all(|v| v.is_finite()));
        let mut m2 = CpuModel::from_checkpoint(&ckpt);
        let mut cache2 = KvCache::new(&m2.config);
        let l2 = m2.decode_step(&mut cache2, 5).to_vec();
        assert_eq!(l1, l2);
    }

    #[test]
    fn kv_cache_consistent_with_fresh_replay() {
        // decode(t0, t1, t2) incrementally == logits_all over the prefix
        let ckpt = tiny_checkpoint(2);
        let mut m = CpuModel::from_checkpoint(&ckpt);
        let tokens = [3u8, 14, 15, 9, 2];
        let all = m.logits_all(&tokens);
        let mut cache = KvCache::new(&m.config);
        for (i, &t) in tokens.iter().enumerate() {
            let step = m.decode_step(&mut cache, t).to_vec();
            let want = &all[i * 32..(i + 1) * 32];
            for (a, b) in step.iter().zip(want) {
                assert!((a - b).abs() < 1e-5, "pos {i}");
            }
        }
    }

    #[test]
    fn causality_past_logits_stable() {
        let ckpt = tiny_checkpoint(3);
        let mut m = CpuModel::from_checkpoint(&ckpt);
        let a = m.logits_all(&[1, 2, 3, 4]);
        let b = m.logits_all(&[1, 2, 3, 31]);
        // positions 0..3 identical (causal); position 3 differs
        for i in 0..3 * 32 {
            assert!((a[i] - b[i]).abs() < 1e-6);
        }
        let last_a = &a[3 * 32..];
        let last_b = &b[3 * 32..];
        assert!(last_a.iter().zip(last_b).any(|(x, y)| (x - y).abs() > 1e-4));
    }

    #[test]
    fn decode_steps_matches_decode_step_bitwise() {
        use crate::model::kvpool::{KvPool, SeqCache};
        let ckpt = tiny_checkpoint(6);
        let mut m = CpuModel::from_checkpoint(&ckpt);
        let streams: [&[u8]; 3] = [&[1, 2, 3, 4, 5], &[9, 8], &[30, 0, 7, 7]];
        // sequential reference: per-stream logits at every step
        let mut want: Vec<Vec<Vec<f32>>> = Vec::new();
        for st in streams {
            let mut cache = KvCache::new(&m.config);
            want.push(st.iter().map(|&t| m.decode_step(&mut cache, t).to_vec()).collect());
        }
        // batched over a paged pool, ragged lengths
        let mut pool = KvPool::new(&m.config, 8, 2);
        let mut seqs: Vec<SeqCache> = (0..streams.len()).map(|_| SeqCache::new()).collect();
        let maxlen = streams.iter().map(|s| s.len()).max().unwrap();
        for t in 0..maxlen {
            let mut refs: Vec<&mut SeqCache> = Vec::new();
            let mut toks = Vec::new();
            let mut live = Vec::new();
            for (j, sc) in seqs.iter_mut().enumerate() {
                if t < streams[j].len() {
                    assert!(pool.reserve(sc, t + 1));
                    refs.push(sc);
                    toks.push(streams[j][t]);
                    live.push(j);
                }
            }
            let logits = m.decode_steps(&mut pool, &mut refs, &toks);
            let vocab = m.config.vocab;
            for (k, &j) in live.iter().enumerate() {
                let got = &logits[k * vocab..(k + 1) * vocab];
                for (a, b) in got.iter().zip(&want[j][t]) {
                    assert_eq!(a.to_bits(), b.to_bits(), "seq {j} step {t}");
                }
            }
        }
        for mut sc in seqs {
            pool.release(&mut sc);
        }
        assert_eq!(pool.free_pages(), 8, "page leak");
    }

    #[test]
    fn decode_over_forked_pages_matches_original_bitwise() {
        use crate::model::kvpool::{KvPool, SeqCache};
        let ckpt = tiny_checkpoint(8);
        let mut m = CpuModel::from_checkpoint(&ckpt);
        let vocab = m.config.vocab;
        let toks: [u8; 7] = [3, 14, 15, 9, 2, 6, 5];
        // drive one sequence to completion, recording per-step logits
        let mut pool = KvPool::new(&m.config, 16, 2);
        let mut a = SeqCache::new();
        let mut want: Vec<Vec<f32>> = Vec::new();
        for (t, &tok) in toks.iter().enumerate() {
            assert!(pool.reserve(&mut a, t + 1));
            let mut refs = vec![&mut a];
            want.push(m.decode_steps(&mut pool, &mut refs, &[tok]));
        }
        // fork mid-page (len 5 with page_size 2: page 2 is a shared tail)
        // and replay the remaining tokens over the forked table
        let parent_row5 = pool.k_row(&a, 0, 5).to_vec();
        let mut b = pool.fork(&a, 5);
        for (t, &tok) in toks.iter().enumerate().skip(5) {
            assert!(pool.reserve(&mut b, t + 1), "CoW + growth must fit");
            let mut refs = vec![&mut b];
            let got = m.decode_steps(&mut pool, &mut refs, &[tok]);
            for (x, y) in got.iter().zip(&want[t][..vocab]) {
                assert_eq!(x.to_bits(), y.to_bits(), "forked decode diverged at step {t}");
            }
        }
        // the fork's position-5 write went to its CoW copy, never into
        // the parent's still-mapped row
        assert_eq!(pool.k_row(&a, 0, 5), parent_row5.as_slice());
        pool.release(&mut a);
        pool.release(&mut b);
        assert_eq!(pool.free_pages(), 16, "page leak after fork");
    }

    #[test]
    fn decode_span_matches_sequential_decode_bitwise() {
        use crate::model::kvpool::{KvPool, SeqCache};
        let ckpt = tiny_checkpoint(11);
        let mut m = CpuModel::from_checkpoint(&ckpt);
        let vocab = m.config.vocab;
        let toks: [u8; 6] = [3, 14, 15, 9, 2, 6];
        // sequential reference: one decode_steps call per token
        let mut pool = KvPool::new(&m.config, 8, 2);
        let mut a = SeqCache::new();
        let mut want: Vec<f32> = Vec::new();
        for (t, &tok) in toks.iter().enumerate() {
            assert!(pool.reserve(&mut a, t + 1));
            let mut refs = vec![&mut a];
            want.extend(m.decode_steps(&mut pool, &mut refs, &[tok]));
        }
        // span path: 2 sequential steps, then the remaining 4 in ONE pass
        let mut b = SeqCache::new();
        for (t, &tok) in toks.iter().enumerate().take(2) {
            assert!(pool.reserve(&mut b, t + 1));
            let mut refs = vec![&mut b];
            m.decode_steps(&mut pool, &mut refs, &[tok]);
        }
        assert!(pool.reserve(&mut b, toks.len()));
        let got = m.decode_span(&mut pool, &mut b, &toks[2..]);
        assert_eq!(b.len, toks.len());
        assert_eq!(got.len(), 4 * vocab);
        for (i, (x, y)) in got.iter().zip(&want[2 * vocab..]).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "span lane {} diverged", i / vocab);
        }
        // rollback contract: truncate len over the span tail, then a
        // plain step overwrites the dead rows and reproduces the
        // sequential logits bitwise — the scheduler's rejection path
        b.len = 3;
        let mut refs = vec![&mut b];
        let redo = m.decode_steps(&mut pool, &mut refs, &[toks[3]]);
        for (x, y) in redo.iter().zip(&want[3 * vocab..4 * vocab]) {
            assert_eq!(x.to_bits(), y.to_bits(), "post-rollback step diverged");
        }
        pool.release(&mut a);
        pool.release(&mut b);
        assert_eq!(pool.free_pages(), 8, "page leak");
    }

    #[test]
    fn draft_repack_shrinks_traffic_and_shares_kv_layout() {
        use crate::model::kvpool::{KvPool, SeqCache};
        let ckpt = tiny_checkpoint(12);
        let mut m = CpuModel::from_checkpoint(&ckpt);
        let mut draft = m.to_draft(3);
        assert_eq!(draft.config, m.config, "draft must share the target's config/KV layout");
        assert!(
            draft.traffic_bytes_per_token() * 3 < m.traffic_bytes_per_token(),
            "3-bit draft should stream >3x fewer weight bytes"
        );
        // draft decodes over the SAME pool/sequence the target uses:
        // propose on shared pages, roll back, target overwrites
        let mut pool = KvPool::new(&m.config, 8, 2);
        let mut s = SeqCache::new();
        assert!(pool.reserve(&mut s, 1));
        let mut refs = vec![&mut s];
        let ld = draft.decode_steps(&mut pool, &mut refs, &[5]);
        assert_eq!(ld.len(), m.config.vocab);
        assert!(ld.iter().all(|v| v.is_finite()));
        s.len = 0; // reject the provisional draft row
        let mut refs = vec![&mut s];
        let lt = m.decode_steps(&mut pool, &mut refs, &[5]);
        assert!(lt.iter().all(|v| v.is_finite()));
        // a 2-bit draft packs too (the extreme end of the regime)
        let d2 = m.to_draft(2);
        assert!(d2.traffic_bytes_per_token() < draft.traffic_bytes_per_token());
        pool.release(&mut s);
        assert_eq!(pool.free_pages(), 8, "page leak");
    }

    #[test]
    fn vocab_validation_rejects_untruncatable_token_ids() {
        let base = tiny_checkpoint(1);
        assert!(CpuModel::try_from_checkpoint(&base).is_ok());
        // the construction funnel rejects vocab > 256 with a typed error
        // (the old argmax truncated `i as u8` silently at serve time)
        let mut cfg = base.config.clone();
        cfg.vocab = 300;
        let err = CpuModel::assemble(cfg, vec![], vec![], vec![], vec![], vec![], Vec::new())
            .unwrap_err();
        assert_eq!(err, ModelBuildError::VocabTooLarge { vocab: 300 });
        assert!(err.to_string().contains("truncate"), "{err}");
        let mut cfg0 = base.config.clone();
        cfg0.vocab = 0;
        let err = CpuModel::assemble(cfg0, vec![], vec![], vec![], vec![], vec![], Vec::new())
            .unwrap_err();
        assert_eq!(err, ModelBuildError::EmptyVocab);
        // 256 exactly still fits the u8 domain
        let mut cfg256 = base.config.clone();
        cfg256.vocab = 256;
        let d = cfg256.d_model;
        assert!(CpuModel::assemble(
            cfg256.clone(),
            vec![0.0; 256 * d],
            vec![0.0; cfg256.max_seq * d],
            vec![1.0; d],
            vec![0.0; d],
            vec![0.0; 256 * d],
            Vec::new(),
        )
        .is_ok());
    }

    #[test]
    fn packed_model_close_to_dense_dequant() {
        use crate::model::checkpoint::{quantizable_keys, QuantizedCheckpoint};
        use crate::quant::{rtn_quantize, PackedMatrix};
        let ckpt = tiny_checkpoint(4);
        let mut packed = BTreeMap::new();
        let mut dense = ckpt.clone();
        for key in quantizable_keys(&ckpt.config) {
            let t = ckpt.get(&key);
            let (o, i) = t.dims2();
            let r = rtn_quantize(&t.data, o, i, 4, 0);
            packed.insert(key.clone(), PackedMatrix::from_result(&r));
            dense.tensors.get_mut(&key).unwrap().data = r.wq;
        }
        let q = QuantizedCheckpoint::from_parts(ckpt.config.clone(), 4, 0, packed, &ckpt, vec![]);
        let mut qm = CpuModel::from_quantized(&q);
        let mut dm = CpuModel::from_checkpoint(&dense);
        let tokens = [7u8, 21, 0, 13];
        let lq = qm.logits_all(&tokens);
        let ld = dm.logits_all(&tokens);
        for (a, b) in lq.iter().zip(&ld) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn sparse_model_matches_dense_pruned_dequant() {
        use crate::model::checkpoint::{quantizable_keys, QuantizedCheckpoint};
        use crate::quant::rtn_quantize;
        use crate::quant::sparse::{prune_2of4_by_magnitude, Sparse24Matrix};
        let ckpt = tiny_checkpoint(9);
        let mut sparse = BTreeMap::new();
        let mut dense = ckpt.clone();
        for key in quantizable_keys(&ckpt.config) {
            let t = ckpt.get(&key);
            let (o, i) = t.dims2();
            let mut r = rtn_quantize(&t.data, o, i, 4, 0);
            prune_2of4_by_magnitude(&mut r);
            sparse.insert(key.clone(), Sparse24Matrix::from_result(&r).unwrap());
            dense.tensors.get_mut(&key).unwrap().data = r.wq;
        }
        let q = QuantizedCheckpoint::from_parts_sparse(
            ckpt.config.clone(),
            4,
            0,
            BTreeMap::new(),
            sparse,
            &ckpt,
            vec![],
        );
        let mut qm = CpuModel::from_quantized(&q);
        let mut dm = CpuModel::from_checkpoint(&dense);
        // every linear rides the sparse decode path and the sparse traffic
        // is below the dense-f32 equivalent
        assert!(qm.traffic_bytes_per_token() * 2 < dm.traffic_bytes_per_token());
        let tokens = [7u8, 21, 0, 13];
        let lq = qm.logits_all(&tokens);
        let ld = dm.logits_all(&tokens);
        for (a, b) in lq.iter().zip(&ld) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn traffic_shrinks_when_packed() {
        use crate::model::checkpoint::{quantizable_keys, QuantizedCheckpoint};
        use crate::quant::{rtn_quantize, PackedMatrix};
        let ckpt = tiny_checkpoint(5);
        let mut m = CpuModel::from_checkpoint(&ckpt);
        let dense_traffic = m.traffic_bytes_per_token();
        let mut packed = BTreeMap::new();
        for key in quantizable_keys(&ckpt.config) {
            let t = ckpt.get(&key);
            let (o, i) = t.dims2();
            packed.insert(key.clone(), PackedMatrix::from_result(&rtn_quantize(&t.data, o, i, 3, 0)));
        }
        let q = QuantizedCheckpoint::from_parts(ckpt.config.clone(), 3, 0, packed, &ckpt, vec![]);
        let mut qm = CpuModel::from_quantized(&q);
        // tiny layers carry proportionally large per-row grid overhead;
        // still expect >3x traffic reduction at 3-bit even here (real
        // model shapes reach ~10x — see the matvec bench)
        let qt = qm.traffic_bytes_per_token();
        assert!(qt * 3 < dense_traffic, "packed {qt} vs dense {dense_traffic}");
        // silence unused-mut warnings via actual decode
        let mut c1 = KvCache::new(&m.config);
        let mut c2 = KvCache::new(&qm.config);
        m.decode_step(&mut c1, 1);
        qm.decode_step(&mut c2, 1);
    }
}
