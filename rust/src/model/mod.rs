//! Model substrate: tensors, configs, checkpoints (dense + packed), and
//! the pure-Rust transformer forward that is the serving hot path.
//!
//! The decode path is matvec-dominated (the paper's observation that
//! generative inference is memory-bandwidth-bound), so [`matvec`] carries
//! both the f32 baseline and the packed dequantizing matvec — the Rust
//! twin of the L1 `packmatvec` Pallas kernel and the analog of the paper's
//! CUDA kernel (§Practical Speedups). The per-row arithmetic behind it
//! lives in [`kernels`]: runtime-dispatched SIMD microkernels
//! (scalar/AVX2+FMA/NEON, `--isa` / `GPTQ_ISA`) with LUT dequant and the
//! register-tiled [`kernels::tiled::TiledPacked`] layout (DESIGN.md
//! §Kernels).

pub mod checkpoint;
pub mod config;
pub mod forward;
pub mod kernels;
pub mod kvpool;
pub mod matvec;
pub mod tensor;
pub mod testkit;

pub use checkpoint::{Checkpoint, QuantizedCheckpoint};
pub use config::ModelConfig;
pub use forward::{CpuModel, KvCache, LinearWeight, ModelBuildError, PackedLinear, Sparse24Linear};
pub use kernels::{Isa, Sparse24Tiled, TiledPacked};
pub use kvpool::{KvDtype, KvPool, SeqCache};
pub use tensor::Tensor;
