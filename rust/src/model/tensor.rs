//! A minimal dense f32 tensor (row-major) — the only tensor type the
//! coordinator needs; heavy math lives either in XLA artifacts or in the
//! specialized matvec kernels.

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Self { data, shape }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { data: vec![0.0; n], shape }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 2);
        let c = self.shape[1];
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert_eq!(self.rank(), 2);
        let c = self.shape[1];
        &mut self.data[i * c..(i + 1) * c]
    }

    /// (rows, cols) of a rank-2 tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.rank(), 2, "expected rank-2, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_rows() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![2, 3]);
        assert_eq!(t.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(t.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(t.dims2(), (2, 3));
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![1.0], vec![2, 3]);
    }

    #[test]
    fn zeros() {
        let t = Tensor::zeros(vec![3, 4]);
        assert_eq!(t.numel(), 12);
        assert!(t.data.iter().all(|&v| v == 0.0));
    }
}
