//! The serving hot path: matrix-vector products.
//!
//! Generative decode at batch 1 reduces to one matvec per linear layer;
//! the paper's observation is that these are memory-bandwidth-bound, so
//! keeping weights packed at 2–8 bits and dequantizing in registers wins
//! roughly (32 / effective-bits)× on weight traffic. [`matvec_f32`] is the
//! FP16-baseline analog, [`matvec_packed`] the CUDA-kernel analog (and the
//! Rust twin of the L1 `packmatvec.py` Pallas kernel).
//!
//! §Perf notes (see EXPERIMENTS.md §Perf for measurements): the packed
//! inner loop decodes one u32 word at a time with compile-time-known field
//! counts (monomorphized per bit width), accumulates `Σ code·x` and `Σ x`
//! separately per group, and applies scale/zero once per group:
//! `y += s·(Σ code·x) − s·z·(Σ x)` — no per-element multiply by the grid.

use crate::quant::pack::PackedMatrix;
use crate::util::par::{self, Pool};

/// Below this many weight elements a matvec stays serial: thread spawn
/// costs tens of µs per region, which only amortises once the matrix is
/// past L2-resident sizes (DESIGN.md §Parallelism, threshold rationale).
pub const MATVEC_PAR_MIN_ELEMS: usize = 1 << 16;

fn pool_for(elems: usize) -> Pool {
    if elems >= MATVEC_PAR_MIN_ELEMS {
        Pool::global()
    } else {
        Pool::serial()
    }
}

/// Rows `row0..row0+y.len()` of y = W x. 4-way unrolled dot; the shared
/// serial core of [`matvec_f32`] — per-row arithmetic is independent of
/// how rows are chunked, which is what makes the parallel wrapper
/// bit-identical at any thread count.
fn matvec_f32_rows(w: &[f32], x: &[f32], dcol: usize, row0: usize, y: &mut [f32]) {
    for (i, yr) in y.iter_mut().enumerate() {
        let r = row0 + i;
        let row = &w[r * dcol..(r + 1) * dcol];
        let mut acc0 = 0.0f32;
        let mut acc1 = 0.0f32;
        let mut acc2 = 0.0f32;
        let mut acc3 = 0.0f32;
        let chunks = dcol / 4;
        for c in 0..chunks {
            let i = c * 4;
            acc0 += row[i] * x[i];
            acc1 += row[i + 1] * x[i + 1];
            acc2 += row[i + 2] * x[i + 2];
            acc3 += row[i + 3] * x[i + 3];
        }
        let mut acc = acc0 + acc1 + acc2 + acc3;
        for i in chunks * 4..dcol {
            acc += row[i] * x[i];
        }
        *yr = acc;
    }
}

/// y = W x for dense row-major W (drow × dcol). Row-range parallel on the
/// global pool above [`MATVEC_PAR_MIN_ELEMS`]; bit-identical to
/// [`matvec_f32_serial`] at every thread count.
pub fn matvec_f32(w: &[f32], x: &[f32], drow: usize, dcol: usize, y: &mut [f32]) {
    assert_eq!(w.len(), drow * dcol);
    assert_eq!(x.len(), dcol);
    assert_eq!(y.len(), drow);
    let pool = pool_for(drow * dcol);
    par::for_rows_mut(&pool, y, drow, 1, |rows, ys| {
        matvec_f32_rows(w, x, dcol, rows.start, ys);
    });
}

/// Serial twin of [`matvec_f32`]: same arithmetic, never spawns. Used
/// inside loops that are already parallel over rows/samples (reference
/// backend) to avoid nested thread scopes.
pub fn matvec_f32_serial(w: &[f32], x: &[f32], drow: usize, dcol: usize, y: &mut [f32]) {
    assert_eq!(w.len(), drow * dcol);
    assert_eq!(x.len(), dcol);
    assert_eq!(y.len(), drow);
    matvec_f32_rows(w, x, dcol, 0, y);
}

/// y = W x + b (dense), the convenience used by the dense forward.
pub fn matvec_f32_bias(w: &[f32], x: &[f32], b: &[f32], drow: usize, dcol: usize, y: &mut [f32]) {
    matvec_f32(w, x, drow, dcol, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

/// Serial twin of [`matvec_f32_bias`] (see [`matvec_f32_serial`]).
pub fn matvec_f32_bias_serial(
    w: &[f32],
    x: &[f32],
    b: &[f32],
    drow: usize,
    dcol: usize,
    y: &mut [f32],
) {
    matvec_f32_serial(w, x, drow, dcol, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

/// General (unaligned) packed row dot — handles any dcol/group layout.
/// The aligned fast path below is what real shapes hit.
#[inline(always)]
fn dot_packed_row_general<const BITS: u32>(
    words: &[u32],
    x: &[f32],
    scales: &[f32],
    zeros: &[f32],
    dcol: usize,
    group: usize,
) -> f32 {
    let cpw = (32 / BITS) as usize;
    let mask = (1u32 << BITS) - 1;
    let mut y = 0.0f32;
    let mut col = 0usize;
    let mut gi = 0usize;
    // per-group partial sums: Σ code·x and Σ x
    let mut acc_cx = 0.0f32;
    let mut acc_x = 0.0f32;
    let mut in_group = 0usize;
    for &w in words {
        let mut wbits = w;
        let fields = cpw.min(dcol - col);
        for _ in 0..fields {
            let code = (wbits & mask) as f32;
            wbits >>= BITS;
            let xv = unsafe { *x.get_unchecked(col) };
            acc_cx += code * xv;
            acc_x += xv;
            col += 1;
            in_group += 1;
            if in_group == group {
                let s = unsafe { *scales.get_unchecked(gi) };
                let z = unsafe { *zeros.get_unchecked(gi) };
                y += s * acc_cx - s * z * acc_x;
                acc_cx = 0.0;
                acc_x = 0.0;
                in_group = 0;
                gi += 1;
            }
        }
        if col == dcol {
            break;
        }
    }
    if in_group > 0 {
        let s = scales[gi];
        let z = zeros[gi];
        y += s * acc_cx - s * z * acc_x;
    }
    y
}

/// Aligned fast path: whole words only, group size a multiple of the
/// codes-per-word. §Perf design (see EXPERIMENTS.md §Perf):
/// * Σx per group is ROW-INDEPENDENT — precomputed once per matvec in
///   `xsum` and folded in as `−s·z·Σx`, halving the per-element FMAs;
/// * each u32 decodes into a fixed-length `[f32; CPW]` array with
///   independent shift/mask lanes — no loop-carried `wbits >>= B`
///   dependency, so LLVM vectorizes the decode + dot;
/// * no per-element group branch: groups advance in whole words.
#[inline(always)]
fn dot_packed_row_aligned<const BITS: u32, const CPW: usize>(
    words: &[u32],
    x: &[f32],
    scales: &[f32],
    zeros: &[f32],
    xsum: &[f32],
    words_per_group: usize,
) -> f32 {
    let mask = (1u32 << BITS) - 1;
    let mut y = 0.0f32;
    for (gi, gwords) in words.chunks_exact(words_per_group).enumerate() {
        // CPW persistent accumulators: lane k always uses shift k·BITS, so
        // the word loop is CPW independent FMA streams (no serial add
        // chain) — measured ~2x over the per-word horizontal sum.
        let mut accs = [0.0f32; CPW];
        let xg = &x[gi * words_per_group * CPW..];
        for (wi, &w) in gwords.iter().enumerate() {
            let xs = &xg[wi * CPW..wi * CPW + CPW];
            for k in 0..CPW {
                accs[k] += ((w >> (BITS as usize * k)) & mask) as f32 * xs[k];
            }
        }
        let acc: f32 = accs.iter().sum();
        let s = unsafe { *scales.get_unchecked(gi) };
        let z = unsafe { *zeros.get_unchecked(gi) };
        y += s * acc - s * z * unsafe { *xsum.get_unchecked(gi) };
    }
    y
}

/// Aligned fast path over rows `row0..row0+y.len()` (serial core).
fn packed_rows_aligned(
    p: &PackedMatrix,
    xeff: &[f32],
    xsum: &[f32],
    wpg: usize,
    row0: usize,
    y: &mut [f32],
) {
    for (i, yr) in y.iter_mut().enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        *yr = match p.bits {
            2 => dot_packed_row_aligned::<2, 16>(words, xeff, scales, zeros, xsum, wpg),
            3 => dot_packed_row_aligned::<3, 10>(words, xeff, scales, zeros, xsum, wpg),
            4 => dot_packed_row_aligned::<4, 8>(words, xeff, scales, zeros, xsum, wpg),
            8 => dot_packed_row_aligned::<8, 4>(words, xeff, scales, zeros, xsum, wpg),
            b => panic!("unsupported bit width {b}"),
        };
    }
}

/// General (ragged) path over rows `row0..row0+y.len()` (serial core).
fn packed_rows_general(p: &PackedMatrix, x: &[f32], group: usize, row0: usize, y: &mut [f32]) {
    for (i, yr) in y.iter_mut().enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        *yr = match p.bits {
            2 => dot_packed_row_general::<2>(words, x, scales, zeros, p.dcol, group),
            3 => dot_packed_row_general::<3>(words, x, scales, zeros, p.dcol, group),
            4 => dot_packed_row_general::<4>(words, x, scales, zeros, p.dcol, group),
            8 => dot_packed_row_general::<8>(words, x, scales, zeros, p.dcol, group),
            b => panic!("unsupported bit width {b}"),
        };
    }
}

/// y = dequant(P) x — the quantized-matrix × fp-vector kernel (the Rust
/// twin of the L1 `packmatvec` Pallas kernel and the paper's CUDA kernel).
/// Row-range parallel above [`MATVEC_PAR_MIN_ELEMS`] logical elements;
/// bit-identical at every thread count (rows are independent).
pub fn matvec_packed(p: &PackedMatrix, x: &[f32], y: &mut [f32]) {
    matvec_packed_with(p, x, y, pool_for(p.drow * p.dcol));
}

/// Serial twin of [`matvec_packed`] (see [`matvec_f32_serial`]).
pub fn matvec_packed_serial(p: &PackedMatrix, x: &[f32], y: &mut [f32]) {
    matvec_packed_with(p, x, y, Pool::serial());
}

fn matvec_packed_with(p: &PackedMatrix, x: &[f32], y: &mut [f32], pool: Pool) {
    assert_eq!(x.len(), p.dcol);
    assert_eq!(y.len(), p.drow);
    let group = p.dcol / p.ngroups;
    let cpw = (32 / p.bits) as usize;
    // Fast path: either one grid per row (pad x so the ragged last word
    // multiplies zeros — packed pad fields are 0 by construction), or
    // grouped with whole-word groups (then dcol is word-aligned too).
    // Real layer shapes always land here; odd shapes use the general path.
    let aligned = p.ngroups == 1 || (group % cpw == 0 && p.nwords * cpw == p.dcol);
    if aligned {
        let padded_len = p.nwords * cpw;
        let mut xpad_store;
        let xeff: &[f32] = if padded_len == p.dcol {
            x
        } else {
            xpad_store = vec![0.0f32; padded_len];
            xpad_store[..p.dcol].copy_from_slice(x);
            &xpad_store
        };
        // per-group Σx, shared by every row (row-independent term);
        // pad zeros don't perturb the sums
        let mut xsum = vec![0.0f32; p.ngroups];
        for (gi, xs) in x.chunks_exact(group).enumerate() {
            xsum[gi] = xs.iter().sum();
        }
        let wpg = p.nwords / p.ngroups;
        par::for_rows_mut(&pool, y, p.drow, 1, |rows, ys| {
            packed_rows_aligned(p, xeff, &xsum, wpg, rows.start, ys);
        });
        return;
    }
    par::for_rows_mut(&pool, y, p.drow, 1, |rows, ys| {
        packed_rows_general(p, x, group, rows.start, ys);
    });
}

/// y = dequant(P) x + b.
pub fn matvec_packed_bias(p: &PackedMatrix, x: &[f32], b: &[f32], y: &mut [f32]) {
    matvec_packed(p, x, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

/// Serial twin of [`matvec_packed_bias`] (see [`matvec_f32_serial`]).
pub fn matvec_packed_bias_serial(p: &PackedMatrix, x: &[f32], b: &[f32], y: &mut [f32]) {
    matvec_packed_serial(p, x, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

/// Weight bytes touched by one matvec — the quantity the paper's speedup
/// model is built on (used by the Table 5 analog to report the traffic
/// reduction alongside measured latency).
pub fn weight_traffic_bytes(p: &PackedMatrix) -> usize {
    p.storage_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_quantize;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (((s >> 33) as f64 / (1u64 << 31) as f64) - 1.0) as f32
            })
            .collect()
    }

    #[test]
    fn f32_matches_naive() {
        let (drow, dcol) = (7, 13);
        let w = rand_vec(drow * dcol, 1);
        let x = rand_vec(dcol, 2);
        let mut y = vec![0.0; drow];
        matvec_f32(&w, &x, drow, dcol, &mut y);
        for r in 0..drow {
            let want: f32 = (0..dcol).map(|c| w[r * dcol + c] * x[c]).sum();
            assert!((y[r] - want).abs() < 1e-4);
        }
    }

    #[test]
    fn packed_matches_dense_dequant() {
        for (bits, g) in
            [(2u32, 0usize), (3, 0), (4, 0), (8, 0), (3, 16), (4, 8), (2, 32), (8, 16)]
        {
            let (drow, dcol) = (16, 64);
            let w = rand_vec(drow * dcol, bits as u64 * 31 + g as u64);
            let r = rtn_quantize(&w, drow, dcol, bits, g);
            let p = PackedMatrix::from_result(&r);
            let dense = p.dequantize();
            let x = rand_vec(dcol, 99);
            let mut yp = vec![0.0; drow];
            let mut yd = vec![0.0; drow];
            matvec_packed(&p, &x, &mut yp);
            matvec_f32(&dense, &x, drow, dcol, &mut yd);
            for (a, b) in yp.iter().zip(&yd) {
                assert!((a - b).abs() < 1e-3, "bits={bits} g={g}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn packed_handles_unaligned_dcol() {
        // dcol not a multiple of codes-per-word exercises the tail path
        let (drow, dcol) = (4, 37);
        let w = rand_vec(drow * dcol, 5);
        let r = rtn_quantize(&w, drow, dcol, 3, 0);
        let p = PackedMatrix::from_result(&r);
        let x = rand_vec(dcol, 6);
        let mut yp = vec![0.0; drow];
        let mut yd = vec![0.0; drow];
        matvec_packed(&p, &x, &mut yp);
        matvec_f32(&p.dequantize(), &x, drow, dcol, &mut yd);
        for (a, b) in yp.iter().zip(&yd) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn bias_variant() {
        let w = rand_vec(6 * 8, 7);
        let x = rand_vec(8, 8);
        let b = rand_vec(6, 9);
        let mut y1 = vec![0.0; 6];
        let mut y2 = vec![0.0; 6];
        matvec_f32(&w, &x, 6, 8, &mut y1);
        matvec_f32_bias(&w, &x, &b, 6, 8, &mut y2);
        for i in 0..6 {
            assert!((y2[i] - y1[i] - b[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn traffic_reduction_ratios() {
        let w = rand_vec(64 * 640, 11);
        let f32_bytes = 64 * 640 * 4;
        for (bits, min_ratio) in [(4u32, 7.0f64), (3, 9.0), (2, 14.0)] {
            let r = rtn_quantize(&w, 64, 640, bits, 0);
            let p = PackedMatrix::from_result(&r);
            let ratio = f32_bytes as f64 / weight_traffic_bytes(&p) as f64;
            assert!(ratio > min_ratio, "bits={bits}: ratio {ratio}");
        }
    }
}
