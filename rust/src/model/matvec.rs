//! The serving hot path: matrix-vector products.
//!
//! Generative decode at batch 1 reduces to one matvec per linear layer;
//! the paper's observation is that these are memory-bandwidth-bound, so
//! keeping weights packed at 2–8 bits and dequantizing in registers wins
//! roughly (32 / effective-bits)× on weight traffic. [`matvec_f32`] is the
//! FP16-baseline analog, [`matvec_packed`] the CUDA-kernel analog (and the
//! Rust twin of the L1 `packmatvec.py` Pallas kernel).
//!
//! This module owns the PUBLIC kernel API: argument checks, the
//! aligned/ragged layout split, the row-independent precomputes (per-group
//! Σx, x padding) and the thread partition. The per-row arithmetic lives
//! in [`crate::model::kernels`] behind runtime ISA dispatch
//! (`Scalar`/`Avx2Fma`/`Neon` — DESIGN.md §Kernels): every entry point
//! reads the process-wide ISA once ([`kernels::isa`]), and the `*_isa`
//! variants pin it explicitly (parity tests, the kernel-sweep bench).
//!
//! §Determinism: for a FIXED ISA every function here is bit-identical at
//! any thread count (rows are partitioned, never the arithmetic), and the
//! batched kernels replay the single-sequence op order per sequence.
//! Changing the ISA may move results within ~1e-5 elementwise.

use crate::model::kernels::{self, Isa, Sparse24Tiled, TiledPacked};
use crate::quant::pack::PackedMatrix;
use crate::quant::sparse::Sparse24Matrix;
use crate::util::par::{self, Pool, SliceParts};

/// Below this many weight elements a matvec stays serial: thread spawn
/// costs tens of µs per region, which only amortises once the matrix is
/// past L2-resident sizes (DESIGN.md §Parallelism, threshold rationale).
pub const MATVEC_PAR_MIN_ELEMS: usize = 1 << 16;

fn pool_for(elems: usize) -> Pool {
    if elems >= MATVEC_PAR_MIN_ELEMS {
        Pool::global()
    } else {
        Pool::serial()
    }
}

/// y = W x for dense row-major W (drow × dcol). Row-range parallel on the
/// global pool above [`MATVEC_PAR_MIN_ELEMS`]; bit-identical to
/// [`matvec_f32_serial`] at every thread count.
pub fn matvec_f32(w: &[f32], x: &[f32], drow: usize, dcol: usize, y: &mut [f32]) {
    matvec_f32_with(w, x, drow, dcol, y, pool_for(drow * dcol), kernels::isa());
}

/// Serial twin of [`matvec_f32`]: same arithmetic, never spawns. Used
/// inside loops that are already parallel over rows/samples (reference
/// backend) to avoid nested thread scopes.
pub fn matvec_f32_serial(w: &[f32], x: &[f32], drow: usize, dcol: usize, y: &mut [f32]) {
    matvec_f32_with(w, x, drow, dcol, y, Pool::serial(), kernels::isa());
}

/// [`matvec_f32`] at an explicit ISA (parity tests, benches).
pub fn matvec_f32_isa(w: &[f32], x: &[f32], drow: usize, dcol: usize, y: &mut [f32], isa: Isa) {
    matvec_f32_with(w, x, drow, dcol, y, pool_for(drow * dcol), isa);
}

fn matvec_f32_with(w: &[f32], x: &[f32], drow: usize, dcol: usize, y: &mut [f32], pool: Pool, isa: Isa) {
    assert_eq!(w.len(), drow * dcol);
    assert_eq!(x.len(), dcol);
    assert_eq!(y.len(), drow);
    let isa = kernels::clamp(isa);
    par::for_rows_mut(&pool, y, drow, 1, |rows, ys| {
        kernels::f32_rows(isa, w, x, dcol, rows.start, ys);
    });
}

/// y = W x + b (dense), the convenience used by the dense forward.
pub fn matvec_f32_bias(w: &[f32], x: &[f32], b: &[f32], drow: usize, dcol: usize, y: &mut [f32]) {
    matvec_f32(w, x, drow, dcol, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

/// Serial twin of [`matvec_f32_bias`] (see [`matvec_f32_serial`]).
pub fn matvec_f32_bias_serial(
    w: &[f32],
    x: &[f32],
    b: &[f32],
    drow: usize,
    dcol: usize,
    y: &mut [f32],
) {
    matvec_f32_serial(w, x, drow, dcol, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

/// Batched Y = W·X: `xs` sequence-major (n × dcol), `ys` row-major
/// (drow × n). Row-range parallel like [`matvec_f32`]; bit-identical to
/// n independent matvecs at every thread count (the per-(row, sequence)
/// dot is the same kernel on every ISA).
pub fn matmul_f32(w: &[f32], xs: &[f32], drow: usize, dcol: usize, n: usize, ys: &mut [f32]) {
    matmul_f32_with(w, xs, drow, dcol, n, ys, pool_for(drow * dcol), kernels::isa());
}

/// Serial twin of [`matmul_f32`] (see [`matvec_f32_serial`]).
pub fn matmul_f32_serial(w: &[f32], xs: &[f32], drow: usize, dcol: usize, n: usize, ys: &mut [f32]) {
    matmul_f32_with(w, xs, drow, dcol, n, ys, Pool::serial(), kernels::isa());
}

/// [`matmul_f32`] at an explicit ISA.
pub fn matmul_f32_isa(
    w: &[f32],
    xs: &[f32],
    drow: usize,
    dcol: usize,
    n: usize,
    ys: &mut [f32],
    isa: Isa,
) {
    matmul_f32_with(w, xs, drow, dcol, n, ys, pool_for(drow * dcol), isa);
}

#[allow(clippy::too_many_arguments)]
fn matmul_f32_with(
    w: &[f32],
    xs: &[f32],
    drow: usize,
    dcol: usize,
    n: usize,
    ys: &mut [f32],
    pool: Pool,
    isa: Isa,
) {
    assert_eq!(w.len(), drow * dcol);
    assert_eq!(xs.len(), n * dcol);
    assert_eq!(ys.len(), drow * n);
    if n == 0 {
        return;
    }
    let isa = kernels::clamp(isa);
    par::for_rows_mut(&pool, ys, drow, n, |rows, chunk| {
        kernels::f32_matmul_rows(isa, w, xs, dcol, n, rows.start, chunk);
    });
}

/// Batched Y = W·X + b (bias broadcast over the n columns of each row).
pub fn matmul_f32_bias(
    w: &[f32],
    xs: &[f32],
    b: &[f32],
    drow: usize,
    dcol: usize,
    n: usize,
    ys: &mut [f32],
) {
    matmul_f32(w, xs, drow, dcol, n, ys);
    add_bias_rows(ys, b, n);
}

/// Serial twin of [`matmul_f32_bias`].
pub fn matmul_f32_bias_serial(
    w: &[f32],
    xs: &[f32],
    b: &[f32],
    drow: usize,
    dcol: usize,
    n: usize,
    ys: &mut [f32],
) {
    matmul_f32_serial(w, xs, drow, dcol, n, ys);
    add_bias_rows(ys, b, n);
}

/// ys[r*n + j] += b[r] — the batched form of the matvec bias pass (one
/// add per element, same arithmetic as the single-sequence path).
fn add_bias_rows(ys: &mut [f32], b: &[f32], n: usize) {
    for (yrow, &bv) in ys.chunks_exact_mut(n).zip(b) {
        for yv in yrow.iter_mut() {
            *yv += bv;
        }
    }
}

/// Batched Y = dequant(P)·X: `xs` sequence-major (n × dcol), `ys`
/// row-major (drow × n). The continuous-batching kernel: packed weight
/// rows are read (and on SIMD ISAs, decoded) once per step for ALL n
/// sequences. Row-range parallel; bit-identical to n independent
/// [`matvec_packed`] calls at every thread count.
pub fn matmul_packed(p: &PackedMatrix, xs: &[f32], n: usize, ys: &mut [f32]) {
    matmul_packed_with(p, xs, n, ys, pool_for(p.drow * p.dcol), kernels::isa());
}

/// Serial twin of [`matmul_packed`] (see [`matvec_f32_serial`]).
pub fn matmul_packed_serial(p: &PackedMatrix, xs: &[f32], n: usize, ys: &mut [f32]) {
    matmul_packed_with(p, xs, n, ys, Pool::serial(), kernels::isa());
}

/// [`matmul_packed`] at an explicit ISA.
pub fn matmul_packed_isa(p: &PackedMatrix, xs: &[f32], n: usize, ys: &mut [f32], isa: Isa) {
    matmul_packed_with(p, xs, n, ys, pool_for(p.drow * p.dcol), isa);
}

fn matmul_packed_with(p: &PackedMatrix, xs: &[f32], n: usize, ys: &mut [f32], pool: Pool, isa: Isa) {
    assert_eq!(xs.len(), n * p.dcol);
    assert_eq!(ys.len(), p.drow * n);
    if n == 0 {
        return;
    }
    let isa = kernels::clamp(isa);
    let group = p.dcol / p.ngroups;
    let cpw = (32 / p.bits) as usize;
    // aligned/general split: the predicate is shared with the tiled
    // builder (kernels::packed_aligned) so both route shapes identically
    if kernels::packed_aligned(p) {
        let padded = p.nwords * cpw;
        let mut xeff_store;
        let xeffs: &[f32] = if padded == p.dcol {
            xs
        } else {
            xeff_store = vec![0.0f32; n * padded];
            for j in 0..n {
                xeff_store[j * padded..j * padded + p.dcol]
                    .copy_from_slice(&xs[j * p.dcol..(j + 1) * p.dcol]);
            }
            &xeff_store
        };
        // per-(sequence, group) Σx — row-independent, computed once for
        // the scalar kernel's factored form; skipped entirely when a SIMD
        // LUT kernel will run (it bakes scale/zero into the table)
        let mut xsums = Vec::new();
        if kernels::packed_aligned_uses_xsum(isa, p.bits) {
            xsums = vec![0.0f32; n * p.ngroups];
            for j in 0..n {
                let x = &xs[j * p.dcol..(j + 1) * p.dcol];
                for (gi, xc) in x.chunks_exact(group).enumerate() {
                    xsums[j * p.ngroups + gi] = xc.iter().sum();
                }
            }
        }
        let wpg = p.nwords / p.ngroups;
        par::for_rows_mut(&pool, ys, p.drow, n, |rows, chunk| {
            kernels::packed_matmul_rows_aligned(isa, p, xeffs, &xsums, wpg, n, rows.start, chunk);
        });
        return;
    }
    par::for_rows_mut(&pool, ys, p.drow, n, |rows, chunk| {
        kernels::packed_matmul_rows_general(p, xs, group, n, rows.start, chunk);
    });
}

/// Batched Y = dequant(P)·X + b.
pub fn matmul_packed_bias(p: &PackedMatrix, xs: &[f32], b: &[f32], n: usize, ys: &mut [f32]) {
    matmul_packed(p, xs, n, ys);
    add_bias_rows(ys, b, n);
}

/// Serial twin of [`matmul_packed_bias`].
pub fn matmul_packed_bias_serial(p: &PackedMatrix, xs: &[f32], b: &[f32], n: usize, ys: &mut [f32]) {
    matmul_packed_serial(p, xs, n, ys);
    add_bias_rows(ys, b, n);
}

/// y = dequant(P) x — the quantized-matrix × fp-vector kernel (the Rust
/// twin of the L1 `packmatvec` Pallas kernel and the paper's CUDA kernel).
/// Row-range parallel above [`MATVEC_PAR_MIN_ELEMS`] logical elements;
/// bit-identical at every thread count (rows are independent).
pub fn matvec_packed(p: &PackedMatrix, x: &[f32], y: &mut [f32]) {
    matvec_packed_with(p, x, y, pool_for(p.drow * p.dcol), kernels::isa());
}

/// Serial twin of [`matvec_packed`] (see [`matvec_f32_serial`]).
pub fn matvec_packed_serial(p: &PackedMatrix, x: &[f32], y: &mut [f32]) {
    matvec_packed_with(p, x, y, Pool::serial(), kernels::isa());
}

/// [`matvec_packed`] at an explicit ISA.
pub fn matvec_packed_isa(p: &PackedMatrix, x: &[f32], y: &mut [f32], isa: Isa) {
    matvec_packed_with(p, x, y, pool_for(p.drow * p.dcol), isa);
}

fn matvec_packed_with(p: &PackedMatrix, x: &[f32], y: &mut [f32], pool: Pool, isa: Isa) {
    assert_eq!(x.len(), p.dcol);
    assert_eq!(y.len(), p.drow);
    let isa = kernels::clamp(isa);
    let group = p.dcol / p.ngroups;
    let cpw = (32 / p.bits) as usize;
    if kernels::packed_aligned(p) {
        let padded_len = p.nwords * cpw;
        let mut xpad_store;
        let xeff: &[f32] = if padded_len == p.dcol {
            x
        } else {
            xpad_store = vec![0.0f32; padded_len];
            xpad_store[..p.dcol].copy_from_slice(x);
            &xpad_store
        };
        // per-group Σx, shared by every row (row-independent term; pad
        // zeros don't perturb the sums) — skipped when a SIMD LUT kernel
        // will run
        let mut xsum = Vec::new();
        if kernels::packed_aligned_uses_xsum(isa, p.bits) {
            xsum = vec![0.0f32; p.ngroups];
            for (gi, xs) in x.chunks_exact(group).enumerate() {
                xsum[gi] = xs.iter().sum();
            }
        }
        let wpg = p.nwords / p.ngroups;
        par::for_rows_mut(&pool, y, p.drow, 1, |rows, ys| {
            kernels::packed_rows_aligned(isa, p, xeff, &xsum, wpg, rows.start, ys);
        });
        return;
    }
    par::for_rows_mut(&pool, y, p.drow, 1, |rows, ys| {
        kernels::packed_rows_general(p, x, group, rows.start, ys);
    });
}

/// y = dequant(P) x + b.
pub fn matvec_packed_bias(p: &PackedMatrix, x: &[f32], b: &[f32], y: &mut [f32]) {
    matvec_packed(p, x, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

/// Serial twin of [`matvec_packed_bias`] (see [`matvec_f32_serial`]).
pub fn matvec_packed_bias_serial(p: &PackedMatrix, x: &[f32], b: &[f32], y: &mut [f32]) {
    matvec_packed_serial(p, x, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

/// y = dequant(T) x over the register-tiled interleaved layout
/// (DESIGN.md §Kernels): one SIMD load of `x` feeds R row accumulators.
/// On an ISA with a tiled microkernel for `t.bits` this is bit-identical
/// per row to [`matvec_packed`] at the same ISA (same op order, different
/// memory walk); otherwise a scalar tiled fallback runs (≤1e-5 from the
/// flat scalar kernel). Tile-range parallel; bit-identical at every
/// thread count.
pub fn matvec_tiled(t: &TiledPacked, x: &[f32], y: &mut [f32]) {
    matvec_tiled_with(t, x, y, pool_for(t.drow * t.dcol), kernels::isa());
}

/// Serial twin of [`matvec_tiled`].
pub fn matvec_tiled_serial(t: &TiledPacked, x: &[f32], y: &mut [f32]) {
    matvec_tiled_with(t, x, y, Pool::serial(), kernels::isa());
}

/// [`matvec_tiled`] at an explicit ISA.
pub fn matvec_tiled_isa(t: &TiledPacked, x: &[f32], y: &mut [f32], isa: Isa) {
    matvec_tiled_with(t, x, y, pool_for(t.drow * t.dcol), isa);
}

fn matvec_tiled_with(t: &TiledPacked, x: &[f32], y: &mut [f32], pool: Pool, isa: Isa) {
    assert_eq!(x.len(), t.dcol);
    assert_eq!(y.len(), t.drow);
    let isa = kernels::clamp(isa);
    let cpw = (32 / t.bits) as usize;
    let padded_len = t.nwords * cpw;
    let mut xpad_store;
    let xeff: &[f32] = if padded_len == t.dcol {
        x
    } else {
        xpad_store = vec![0.0f32; padded_len];
        xpad_store[..t.dcol].copy_from_slice(x);
        &xpad_store
    };
    // one contiguous tile-range job per worker (mirroring for_rows_mut's
    // chunking — per-tile jobs would mean one contended atomic per 4 rows
    // on the batch-1 decode hot path); the last tile's row range is
    // ragged, so partition by hand over SliceParts (disjoint per-tile
    // output ranges — the same soundness argument as for_rows_mut)
    let workers = pool.nthreads().min(t.ntiles.max(1));
    let chunk = t.ntiles.div_ceil(workers.max(1));
    let parts = SliceParts::new(y);
    pool.run_chunks(t.ntiles, chunk, |tr| {
        for ti in tr {
            let lo = ti * t.r;
            let hi = ((ti + 1) * t.r).min(t.drow);
            let ys = unsafe { parts.range(lo..hi) };
            kernels::tiled_rows(isa, t, xeff, ti, ys);
        }
    });
}

/// y = dequant(T) x + b.
pub fn matvec_tiled_bias(t: &TiledPacked, x: &[f32], b: &[f32], y: &mut [f32]) {
    matvec_tiled(t, x, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

/// Serial twin of [`matvec_tiled_bias`].
pub fn matvec_tiled_bias_serial(t: &TiledPacked, x: &[f32], b: &[f32], y: &mut [f32]) {
    matvec_tiled_serial(t, x, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

// ---------------------------------------------------------------------------
// 2:4 sparse entry points — the same API shape as the packed/tiled ones.
// No x padding or Σx precompute is needed: the sparse format gathers x by
// absolute column, and its per-group word padding is never executed.
// ---------------------------------------------------------------------------

/// y = dequant(M) x over the 2:4 sparse layout. Row-range parallel;
/// bit-identical at every thread count. On the scalar ISA this is THE
/// bit-frozen sparse reference (see `kernels::sparse24`).
pub fn matvec_sparse24(m: &Sparse24Matrix, x: &[f32], y: &mut [f32]) {
    matvec_sparse24_with(m, x, y, pool_for(m.drow * m.dcol), kernels::isa());
}

/// Serial twin of [`matvec_sparse24`] (see [`matvec_f32_serial`]).
pub fn matvec_sparse24_serial(m: &Sparse24Matrix, x: &[f32], y: &mut [f32]) {
    matvec_sparse24_with(m, x, y, Pool::serial(), kernels::isa());
}

/// [`matvec_sparse24`] at an explicit ISA (parity tests, benches).
pub fn matvec_sparse24_isa(m: &Sparse24Matrix, x: &[f32], y: &mut [f32], isa: Isa) {
    matvec_sparse24_with(m, x, y, pool_for(m.drow * m.dcol), isa);
}

fn matvec_sparse24_with(m: &Sparse24Matrix, x: &[f32], y: &mut [f32], pool: Pool, isa: Isa) {
    assert_eq!(x.len(), m.dcol);
    assert_eq!(y.len(), m.drow);
    let isa = kernels::clamp(isa);
    par::for_rows_mut(&pool, y, m.drow, 1, |rows, ys| {
        kernels::sparse24_rows(isa, m, x, rows.start, ys);
    });
}

/// y = dequant(M) x + b.
pub fn matvec_sparse24_bias(m: &Sparse24Matrix, x: &[f32], b: &[f32], y: &mut [f32]) {
    matvec_sparse24(m, x, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

/// Serial twin of [`matvec_sparse24_bias`].
pub fn matvec_sparse24_bias_serial(m: &Sparse24Matrix, x: &[f32], b: &[f32], y: &mut [f32]) {
    matvec_sparse24_serial(m, x, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

/// Batched Y = dequant(M)·X over the 2:4 sparse layout: block decodes are
/// shared across the batch and per-sequence op order replays the single
/// matvec — bit-identical to n independent [`matvec_sparse24`] calls.
pub fn matmul_sparse24(m: &Sparse24Matrix, xs: &[f32], n: usize, ys: &mut [f32]) {
    matmul_sparse24_with(m, xs, n, ys, pool_for(m.drow * m.dcol), kernels::isa());
}

/// Serial twin of [`matmul_sparse24`].
pub fn matmul_sparse24_serial(m: &Sparse24Matrix, xs: &[f32], n: usize, ys: &mut [f32]) {
    matmul_sparse24_with(m, xs, n, ys, Pool::serial(), kernels::isa());
}

/// [`matmul_sparse24`] at an explicit ISA.
pub fn matmul_sparse24_isa(m: &Sparse24Matrix, xs: &[f32], n: usize, ys: &mut [f32], isa: Isa) {
    matmul_sparse24_with(m, xs, n, ys, pool_for(m.drow * m.dcol), isa);
}

fn matmul_sparse24_with(
    m: &Sparse24Matrix,
    xs: &[f32],
    n: usize,
    ys: &mut [f32],
    pool: Pool,
    isa: Isa,
) {
    assert_eq!(xs.len(), n * m.dcol);
    assert_eq!(ys.len(), m.drow * n);
    if n == 0 {
        return;
    }
    let isa = kernels::clamp(isa);
    par::for_rows_mut(&pool, ys, m.drow, n, |rows, chunk| {
        kernels::sparse24_matmul_rows(isa, m, xs, n, rows.start, chunk);
    });
}

/// Batched Y = dequant(M)·X + b.
pub fn matmul_sparse24_bias(m: &Sparse24Matrix, xs: &[f32], b: &[f32], n: usize, ys: &mut [f32]) {
    matmul_sparse24(m, xs, n, ys);
    add_bias_rows(ys, b, n);
}

/// Serial twin of [`matmul_sparse24_bias`].
pub fn matmul_sparse24_bias_serial(
    m: &Sparse24Matrix,
    xs: &[f32],
    b: &[f32],
    n: usize,
    ys: &mut [f32],
) {
    matmul_sparse24_serial(m, xs, n, ys);
    add_bias_rows(ys, b, n);
}

/// y = dequant(T) x over the interleaved 2:4 tiled layout — the batch-1
/// decode fast path when the active ISA has a sparse tiled microkernel
/// (`kernels::sparse24_tiled_supported`); the scalar fallback replays the
/// flat op order bitwise. Tile-range parallel; bit-identical at every
/// thread count.
pub fn matvec_sparse24_tiled(t: &Sparse24Tiled, x: &[f32], y: &mut [f32]) {
    matvec_sparse24_tiled_with(t, x, y, pool_for(t.drow * t.dcol), kernels::isa());
}

/// Serial twin of [`matvec_sparse24_tiled`].
pub fn matvec_sparse24_tiled_serial(t: &Sparse24Tiled, x: &[f32], y: &mut [f32]) {
    matvec_sparse24_tiled_with(t, x, y, Pool::serial(), kernels::isa());
}

/// [`matvec_sparse24_tiled`] at an explicit ISA.
pub fn matvec_sparse24_tiled_isa(t: &Sparse24Tiled, x: &[f32], y: &mut [f32], isa: Isa) {
    matvec_sparse24_tiled_with(t, x, y, pool_for(t.drow * t.dcol), isa);
}

fn matvec_sparse24_tiled_with(t: &Sparse24Tiled, x: &[f32], y: &mut [f32], pool: Pool, isa: Isa) {
    assert_eq!(x.len(), t.dcol);
    assert_eq!(y.len(), t.drow);
    let isa = kernels::clamp(isa);
    // same tile-range partition as matvec_tiled_with (see the rationale
    // there); disjoint per-tile output ranges over SliceParts
    let workers = pool.nthreads().min(t.ntiles.max(1));
    let chunk = t.ntiles.div_ceil(workers.max(1));
    let parts = SliceParts::new(y);
    pool.run_chunks(t.ntiles, chunk, |tr| {
        for ti in tr {
            let lo = ti * t.r;
            let hi = ((ti + 1) * t.r).min(t.drow);
            let ys = unsafe { parts.range(lo..hi) };
            kernels::sparse24_tiled_rows(isa, t, x, ti, ys);
        }
    });
}

/// y = dequant(T) x + b over the 2:4 tiled layout.
pub fn matvec_sparse24_tiled_bias(t: &Sparse24Tiled, x: &[f32], b: &[f32], y: &mut [f32]) {
    matvec_sparse24_tiled(t, x, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

/// Serial twin of [`matvec_sparse24_tiled_bias`].
pub fn matvec_sparse24_tiled_bias_serial(t: &Sparse24Tiled, x: &[f32], b: &[f32], y: &mut [f32]) {
    matvec_sparse24_tiled_serial(t, x, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

/// Weight bytes touched by one matvec — the quantity the paper's speedup
/// model is built on (used by the Table 5 analog and the roofline helper
/// `util::bench::achieved_gbps` to report the traffic reduction alongside
/// measured latency).
pub fn weight_traffic_bytes(p: &PackedMatrix) -> usize {
    p.storage_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::rand_vec;
    use crate::quant::rtn_quantize;

    #[test]
    fn f32_matches_naive() {
        let (drow, dcol) = (7, 13);
        let w = rand_vec(drow * dcol, 1);
        let x = rand_vec(dcol, 2);
        let mut y = vec![0.0; drow];
        matvec_f32(&w, &x, drow, dcol, &mut y);
        for r in 0..drow {
            let want: f32 = (0..dcol).map(|c| w[r * dcol + c] * x[c]).sum();
            assert!((y[r] - want).abs() < 1e-4);
        }
    }

    #[test]
    fn packed_matches_dense_dequant() {
        for (bits, g) in
            [(2u32, 0usize), (3, 0), (4, 0), (8, 0), (3, 16), (4, 8), (2, 32), (8, 16)]
        {
            let (drow, dcol) = (16, 64);
            let w = rand_vec(drow * dcol, bits as u64 * 31 + g as u64);
            let r = rtn_quantize(&w, drow, dcol, bits, g);
            let p = PackedMatrix::from_result(&r);
            let dense = p.dequantize();
            let x = rand_vec(dcol, 99);
            let mut yp = vec![0.0; drow];
            let mut yd = vec![0.0; drow];
            matvec_packed(&p, &x, &mut yp);
            matvec_f32(&dense, &x, drow, dcol, &mut yd);
            for (a, b) in yp.iter().zip(&yd) {
                assert!((a - b).abs() < 1e-3, "bits={bits} g={g}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn packed_handles_unaligned_dcol() {
        // dcol not a multiple of codes-per-word exercises the tail path
        let (drow, dcol) = (4, 37);
        let w = rand_vec(drow * dcol, 5);
        let r = rtn_quantize(&w, drow, dcol, 3, 0);
        let p = PackedMatrix::from_result(&r);
        let x = rand_vec(dcol, 6);
        let mut yp = vec![0.0; drow];
        let mut yd = vec![0.0; drow];
        matvec_packed(&p, &x, &mut yp);
        matvec_f32(&p.dequantize(), &x, drow, dcol, &mut yd);
        for (a, b) in yp.iter().zip(&yd) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn bias_variant() {
        let w = rand_vec(6 * 8, 7);
        let x = rand_vec(8, 8);
        let b = rand_vec(6, 9);
        let mut y1 = vec![0.0; 6];
        let mut y2 = vec![0.0; 6];
        matvec_f32(&w, &x, 6, 8, &mut y1);
        matvec_f32_bias(&w, &x, &b, 6, 8, &mut y2);
        for i in 0..6 {
            assert!((y2[i] - y1[i] - b[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_f32_bitwise_equals_stacked_matvecs() {
        // includes dcol not divisible by the unroll and n > drow
        for (drow, dcol, n) in [(7usize, 13usize, 3usize), (16, 33, 5), (3, 64, 9)] {
            let w = rand_vec(drow * dcol, 21 + n as u64);
            let xs = rand_vec(n * dcol, 22 + drow as u64);
            let b = rand_vec(drow, 23);
            let mut ys = vec![0.0f32; drow * n];
            matmul_f32_bias(&w, &xs, &b, drow, dcol, n, &mut ys);
            for j in 0..n {
                let mut y = vec![0.0f32; drow];
                matvec_f32_bias(&w, &xs[j * dcol..(j + 1) * dcol], &b, drow, dcol, &mut y);
                for r in 0..drow {
                    assert_eq!(
                        ys[r * n + j].to_bits(),
                        y[r].to_bits(),
                        "drow={drow} dcol={dcol} n={n} r={r} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn matmul_packed_bitwise_equals_stacked_matvecs() {
        // aligned (1024), ragged tail (37), and grouped layouts
        for (bits, g) in [(2u32, 0usize), (3, 0), (4, 16), (8, 0), (3, 37)] {
            let (drow, dcol, n) = (12usize, if g == 37 { 37 } else { 1024 }, 4usize);
            let g = if g == 37 { 0 } else { g };
            let w = rand_vec(drow * dcol, bits as u64 * 17 + g as u64);
            let r = rtn_quantize(&w, drow, dcol, bits, g);
            let p = PackedMatrix::from_result(&r);
            let xs = rand_vec(n * dcol, 31 + bits as u64);
            let b = rand_vec(drow, 32);
            let mut ys = vec![0.0f32; drow * n];
            matmul_packed_bias(&p, &xs, &b, n, &mut ys);
            for j in 0..n {
                let mut y = vec![0.0f32; drow];
                matvec_packed_bias(&p, &xs[j * dcol..(j + 1) * dcol], &b, &mut y);
                for row in 0..drow {
                    assert_eq!(
                        ys[row * n + j].to_bits(),
                        y[row].to_bits(),
                        "bits={bits} g={g} row={row} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn matmul_serial_twins_match() {
        let (drow, dcol, n) = (9usize, 64usize, 3usize);
        let w = rand_vec(drow * dcol, 41);
        let xs = rand_vec(n * dcol, 42);
        let (mut a, mut b) = (vec![0.0f32; drow * n], vec![0.0f32; drow * n]);
        matmul_f32(&w, &xs, drow, dcol, n, &mut a);
        matmul_f32_serial(&w, &xs, drow, dcol, n, &mut b);
        assert_eq!(a, b);
        let q = rtn_quantize(&w, drow, dcol, 4, 0);
        let p = PackedMatrix::from_result(&q);
        matmul_packed(&p, &xs, n, &mut a);
        matmul_packed_serial(&p, &xs, n, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn every_available_isa_agrees_with_scalar() {
        // quick in-module parity smoke (the full property sweep lives in
        // tests/kernel_parity.rs): weights scaled so row dots stay O(1)
        let (drow, dcol) = (13usize, 128usize);
        let w: Vec<f32> = rand_vec(drow * dcol, 51).iter().map(|v| v / dcol as f32).collect();
        let x = rand_vec(dcol, 52);
        for bits in [2u32, 3, 4, 8] {
            let q = rtn_quantize(&w, drow, dcol, bits, 0);
            let p = PackedMatrix::from_result(&q);
            let mut want = vec![0.0f32; drow];
            matvec_packed_isa(&p, &x, &mut want, Isa::Scalar);
            for isa in kernels::available() {
                let mut got = vec![0.0f32; drow];
                matvec_packed_isa(&p, &x, &mut got, isa);
                for (a, b) in got.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-5, "bits={bits} isa={isa}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn tiled_matches_flat_packed() {
        for (bits, g) in [(2u32, 0usize), (3, 0), (4, 16), (8, 0)] {
            // drow 11: two full tiles + a ragged one
            let (drow, dcol) = (11usize, 320usize);
            let w: Vec<f32> =
                rand_vec(drow * dcol, 61 + bits as u64).iter().map(|v| v / dcol as f32).collect();
            let q = rtn_quantize(&w, drow, dcol, bits, g);
            let p = PackedMatrix::from_result(&q);
            let t = TiledPacked::from_packed(&p).expect("aligned shape tiles");
            let x = rand_vec(dcol, 62);
            for isa in kernels::available() {
                let mut yt = vec![0.0f32; drow];
                let mut yp = vec![0.0f32; drow];
                matvec_tiled_isa(&t, &x, &mut yt, isa);
                matvec_packed_isa(&p, &x, &mut yp, isa);
                for (row, (a, b)) in yt.iter().zip(&yp).enumerate() {
                    if kernels::tiled_supported(isa, bits) {
                        // same op order, different memory walk: bit-equal
                        assert_eq!(a.to_bits(), b.to_bits(), "bits={bits} isa={isa} row={row}");
                    } else {
                        assert!((a - b).abs() < 1e-5, "bits={bits} isa={isa} row={row}");
                    }
                }
            }
        }
    }

    #[test]
    fn sparse24_paths_agree_across_isas() {
        // quick smoke (the full sparse sweep lives in tests/sparsity.rs)
        use crate::quant::sparse::{prune_2of4_by_magnitude, Sparse24Matrix};
        let (drow, dcol) = (11usize, 128usize);
        let w: Vec<f32> = rand_vec(drow * dcol, 71).iter().map(|v| v / dcol as f32).collect();
        let mut q = rtn_quantize(&w, drow, dcol, 4, 16);
        prune_2of4_by_magnitude(&mut q);
        let m = Sparse24Matrix::from_result(&q).unwrap();
        let t = Sparse24Tiled::from_sparse(&m);
        let x = rand_vec(dcol, 72);
        let n = 3usize;
        let xs = rand_vec(n * dcol, 73);
        let mut want = vec![0.0f32; drow];
        matvec_sparse24_isa(&m, &x, &mut want, Isa::Scalar);
        for isa in kernels::available() {
            let (mut yf, mut yt) = (vec![0.0f32; drow], vec![0.0f32; drow]);
            matvec_sparse24_isa(&m, &x, &mut yf, isa);
            matvec_sparse24_tiled_isa(&t, &x, &mut yt, isa);
            for r in 0..drow {
                assert!((yf[r] - want[r]).abs() < 1e-5, "flat isa={isa} r={r}");
                assert!((yt[r] - want[r]).abs() < 1e-5, "tiled isa={isa} r={r}");
            }
            // batched replays the single-sequence op order bitwise
            let mut ys = vec![0.0f32; drow * n];
            matmul_sparse24_isa(&m, &xs, n, &mut ys, isa);
            for j in 0..n {
                let mut y = vec![0.0f32; drow];
                matvec_sparse24_isa(&m, &xs[j * dcol..(j + 1) * dcol], &mut y, isa);
                for r in 0..drow {
                    assert_eq!(ys[r * n + j].to_bits(), y[r].to_bits(), "isa={isa} r={r} j={j}");
                }
            }
        }
    }

    #[test]
    fn traffic_reduction_ratios() {
        let w = rand_vec(64 * 640, 11);
        let f32_bytes = 64 * 640 * 4;
        for (bits, min_ratio) in [(4u32, 7.0f64), (3, 9.0), (2, 14.0)] {
            let r = rtn_quantize(&w, 64, 640, bits, 0);
            let p = PackedMatrix::from_result(&r);
            let ratio = f32_bytes as f64 / weight_traffic_bytes(&p) as f64;
            assert!(ratio > min_ratio, "bits={bits}: ratio {ratio}");
        }
    }
}
