//! The serving hot path: matrix-vector products.
//!
//! Generative decode at batch 1 reduces to one matvec per linear layer;
//! the paper's observation is that these are memory-bandwidth-bound, so
//! keeping weights packed at 2–8 bits and dequantizing in registers wins
//! roughly (32 / effective-bits)× on weight traffic. [`matvec_f32`] is the
//! FP16-baseline analog, [`matvec_packed`] the CUDA-kernel analog (and the
//! Rust twin of the L1 `packmatvec.py` Pallas kernel).
//!
//! §Perf notes (see EXPERIMENTS.md §Perf for measurements): the packed
//! inner loop decodes one u32 word at a time with compile-time-known field
//! counts (monomorphized per bit width), accumulates `Σ code·x` and `Σ x`
//! separately per group, and applies scale/zero once per group:
//! `y += s·(Σ code·x) − s·z·(Σ x)` — no per-element multiply by the grid.

use crate::quant::pack::PackedMatrix;
use crate::util::par::{self, Pool};

/// Below this many weight elements a matvec stays serial: thread spawn
/// costs tens of µs per region, which only amortises once the matrix is
/// past L2-resident sizes (DESIGN.md §Parallelism, threshold rationale).
pub const MATVEC_PAR_MIN_ELEMS: usize = 1 << 16;

fn pool_for(elems: usize) -> Pool {
    if elems >= MATVEC_PAR_MIN_ELEMS {
        Pool::global()
    } else {
        Pool::serial()
    }
}

/// The 4-way unrolled row dot shared by the matvec and the batched
/// matmul: one code path means the batched decode is bit-identical to
/// the single-sequence decode on dense linears (the continuous-batching
/// parity contract, DESIGN.md §Serving).
#[inline(always)]
fn dot4(row: &[f32], x: &[f32], dcol: usize) -> f32 {
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let chunks = dcol / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc0 += row[i] * x[i];
        acc1 += row[i + 1] * x[i + 1];
        acc2 += row[i + 2] * x[i + 2];
        acc3 += row[i + 3] * x[i + 3];
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for i in chunks * 4..dcol {
        acc += row[i] * x[i];
    }
    acc
}

/// Rows `row0..row0+y.len()` of y = W x. The shared serial core of
/// [`matvec_f32`] — per-row arithmetic is independent of how rows are
/// chunked, which is what makes the parallel wrapper bit-identical at
/// any thread count.
fn matvec_f32_rows(w: &[f32], x: &[f32], dcol: usize, row0: usize, y: &mut [f32]) {
    for (i, yr) in y.iter_mut().enumerate() {
        let r = row0 + i;
        *yr = dot4(&w[r * dcol..(r + 1) * dcol], x, dcol);
    }
}

/// y = W x for dense row-major W (drow × dcol). Row-range parallel on the
/// global pool above [`MATVEC_PAR_MIN_ELEMS`]; bit-identical to
/// [`matvec_f32_serial`] at every thread count.
pub fn matvec_f32(w: &[f32], x: &[f32], drow: usize, dcol: usize, y: &mut [f32]) {
    assert_eq!(w.len(), drow * dcol);
    assert_eq!(x.len(), dcol);
    assert_eq!(y.len(), drow);
    let pool = pool_for(drow * dcol);
    par::for_rows_mut(&pool, y, drow, 1, |rows, ys| {
        matvec_f32_rows(w, x, dcol, rows.start, ys);
    });
}

/// Serial twin of [`matvec_f32`]: same arithmetic, never spawns. Used
/// inside loops that are already parallel over rows/samples (reference
/// backend) to avoid nested thread scopes.
pub fn matvec_f32_serial(w: &[f32], x: &[f32], drow: usize, dcol: usize, y: &mut [f32]) {
    assert_eq!(w.len(), drow * dcol);
    assert_eq!(x.len(), dcol);
    assert_eq!(y.len(), drow);
    matvec_f32_rows(w, x, dcol, 0, y);
}

/// y = W x + b (dense), the convenience used by the dense forward.
pub fn matvec_f32_bias(w: &[f32], x: &[f32], b: &[f32], drow: usize, dcol: usize, y: &mut [f32]) {
    matvec_f32(w, x, drow, dcol, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

/// Serial twin of [`matvec_f32_bias`] (see [`matvec_f32_serial`]).
pub fn matvec_f32_bias_serial(
    w: &[f32],
    x: &[f32],
    b: &[f32],
    drow: usize,
    dcol: usize,
    y: &mut [f32],
) {
    matvec_f32_serial(w, x, drow, dcol, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

/// Serial core of [`matmul_f32`]: rows `row0..` of Y = W·X over `n`
/// stacked activations. `xs` is sequence-major (n × dcol); `ys` is
/// ROW-major (rows × n) so a row-range parallel partition writes
/// contiguous chunks. Each weight row is read once for all n columns —
/// the continuous-batching win: N sequences advance per pass over the
/// weights. Per-(row, sequence) arithmetic is exactly [`dot4`], i.e.
/// bit-identical to n separate [`matvec_f32`] calls.
fn matmul_f32_rows(w: &[f32], xs: &[f32], dcol: usize, n: usize, row0: usize, ys: &mut [f32]) {
    for (i, yrow) in ys.chunks_exact_mut(n).enumerate() {
        let r = row0 + i;
        let row = &w[r * dcol..(r + 1) * dcol];
        for (j, yv) in yrow.iter_mut().enumerate() {
            *yv = dot4(row, &xs[j * dcol..(j + 1) * dcol], dcol);
        }
    }
}

/// Batched Y = W·X: `xs` sequence-major (n × dcol), `ys` row-major
/// (drow × n). Row-range parallel like [`matvec_f32`]; bit-identical to
/// n independent matvecs at every thread count.
pub fn matmul_f32(w: &[f32], xs: &[f32], drow: usize, dcol: usize, n: usize, ys: &mut [f32]) {
    assert_eq!(w.len(), drow * dcol);
    assert_eq!(xs.len(), n * dcol);
    assert_eq!(ys.len(), drow * n);
    if n == 0 {
        return;
    }
    let pool = pool_for(drow * dcol);
    par::for_rows_mut(&pool, ys, drow, n, |rows, chunk| {
        matmul_f32_rows(w, xs, dcol, n, rows.start, chunk);
    });
}

/// Serial twin of [`matmul_f32`] (see [`matvec_f32_serial`]).
pub fn matmul_f32_serial(w: &[f32], xs: &[f32], drow: usize, dcol: usize, n: usize, ys: &mut [f32]) {
    assert_eq!(w.len(), drow * dcol);
    assert_eq!(xs.len(), n * dcol);
    assert_eq!(ys.len(), drow * n);
    if n == 0 {
        return;
    }
    matmul_f32_rows(w, xs, dcol, n, 0, ys);
}

/// Batched Y = W·X + b (bias broadcast over the n columns of each row).
pub fn matmul_f32_bias(
    w: &[f32],
    xs: &[f32],
    b: &[f32],
    drow: usize,
    dcol: usize,
    n: usize,
    ys: &mut [f32],
) {
    matmul_f32(w, xs, drow, dcol, n, ys);
    add_bias_rows(ys, b, n);
}

/// Serial twin of [`matmul_f32_bias`].
pub fn matmul_f32_bias_serial(
    w: &[f32],
    xs: &[f32],
    b: &[f32],
    drow: usize,
    dcol: usize,
    n: usize,
    ys: &mut [f32],
) {
    matmul_f32_serial(w, xs, drow, dcol, n, ys);
    add_bias_rows(ys, b, n);
}

/// ys[r*n + j] += b[r] — the batched form of the matvec bias pass (one
/// add per element, same arithmetic as the single-sequence path).
fn add_bias_rows(ys: &mut [f32], b: &[f32], n: usize) {
    for (yrow, &bv) in ys.chunks_exact_mut(n).zip(b) {
        for yv in yrow.iter_mut() {
            *yv += bv;
        }
    }
}

/// General (unaligned) packed row dot — handles any dcol/group layout.
/// The aligned fast path below is what real shapes hit.
#[inline(always)]
fn dot_packed_row_general<const BITS: u32>(
    words: &[u32],
    x: &[f32],
    scales: &[f32],
    zeros: &[f32],
    dcol: usize,
    group: usize,
) -> f32 {
    let cpw = (32 / BITS) as usize;
    let mask = (1u32 << BITS) - 1;
    let mut y = 0.0f32;
    let mut col = 0usize;
    let mut gi = 0usize;
    // per-group partial sums: Σ code·x and Σ x
    let mut acc_cx = 0.0f32;
    let mut acc_x = 0.0f32;
    let mut in_group = 0usize;
    for &w in words {
        let mut wbits = w;
        let fields = cpw.min(dcol - col);
        for _ in 0..fields {
            let code = (wbits & mask) as f32;
            wbits >>= BITS;
            let xv = unsafe { *x.get_unchecked(col) };
            acc_cx += code * xv;
            acc_x += xv;
            col += 1;
            in_group += 1;
            if in_group == group {
                let s = unsafe { *scales.get_unchecked(gi) };
                let z = unsafe { *zeros.get_unchecked(gi) };
                y += s * acc_cx - s * z * acc_x;
                acc_cx = 0.0;
                acc_x = 0.0;
                in_group = 0;
                gi += 1;
            }
        }
        if col == dcol {
            break;
        }
    }
    if in_group > 0 {
        let s = scales[gi];
        let z = zeros[gi];
        y += s * acc_cx - s * z * acc_x;
    }
    y
}

/// Aligned fast path: whole words only, group size a multiple of the
/// codes-per-word. §Perf design (see EXPERIMENTS.md §Perf):
/// * Σx per group is ROW-INDEPENDENT — precomputed once per matvec in
///   `xsum` and folded in as `−s·z·Σx`, halving the per-element FMAs;
/// * each u32 decodes into a fixed-length `[f32; CPW]` array with
///   independent shift/mask lanes — no loop-carried `wbits >>= B`
///   dependency, so LLVM vectorizes the decode + dot;
/// * no per-element group branch: groups advance in whole words.
#[inline(always)]
fn dot_packed_row_aligned<const BITS: u32, const CPW: usize>(
    words: &[u32],
    x: &[f32],
    scales: &[f32],
    zeros: &[f32],
    xsum: &[f32],
    words_per_group: usize,
) -> f32 {
    let mask = (1u32 << BITS) - 1;
    let mut y = 0.0f32;
    for (gi, gwords) in words.chunks_exact(words_per_group).enumerate() {
        // CPW persistent accumulators: lane k always uses shift k·BITS, so
        // the word loop is CPW independent FMA streams (no serial add
        // chain) — measured ~2x over the per-word horizontal sum.
        let mut accs = [0.0f32; CPW];
        let xg = &x[gi * words_per_group * CPW..];
        for (wi, &w) in gwords.iter().enumerate() {
            let xs = &xg[wi * CPW..wi * CPW + CPW];
            for k in 0..CPW {
                accs[k] += ((w >> (BITS as usize * k)) & mask) as f32 * xs[k];
            }
        }
        let acc: f32 = accs.iter().sum();
        let s = unsafe { *scales.get_unchecked(gi) };
        let z = unsafe { *zeros.get_unchecked(gi) };
        y += s * acc - s * z * unsafe { *xsum.get_unchecked(gi) };
    }
    y
}

/// Aligned fast path over rows `row0..row0+y.len()` (serial core).
fn packed_rows_aligned(
    p: &PackedMatrix,
    xeff: &[f32],
    xsum: &[f32],
    wpg: usize,
    row0: usize,
    y: &mut [f32],
) {
    for (i, yr) in y.iter_mut().enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        *yr = match p.bits {
            2 => dot_packed_row_aligned::<2, 16>(words, xeff, scales, zeros, xsum, wpg),
            3 => dot_packed_row_aligned::<3, 10>(words, xeff, scales, zeros, xsum, wpg),
            4 => dot_packed_row_aligned::<4, 8>(words, xeff, scales, zeros, xsum, wpg),
            8 => dot_packed_row_aligned::<8, 4>(words, xeff, scales, zeros, xsum, wpg),
            b => panic!("unsupported bit width {b}"),
        };
    }
}

/// General (ragged) path over rows `row0..row0+y.len()` (serial core).
fn packed_rows_general(p: &PackedMatrix, x: &[f32], group: usize, row0: usize, y: &mut [f32]) {
    for (i, yr) in y.iter_mut().enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        *yr = match p.bits {
            2 => dot_packed_row_general::<2>(words, x, scales, zeros, p.dcol, group),
            3 => dot_packed_row_general::<3>(words, x, scales, zeros, p.dcol, group),
            4 => dot_packed_row_general::<4>(words, x, scales, zeros, p.dcol, group),
            8 => dot_packed_row_general::<8>(words, x, scales, zeros, p.dcol, group),
            b => panic!("unsupported bit width {b}"),
        };
    }
}

/// Aligned batched core: rows `row0..` of Y = dequant(P)·X for `n`
/// stacked activations. Each packed u32 word is decoded ONCE into its
/// `[f32; CPW]` lane array and FMA'd into every sequence's lane
/// accumulators — the packed-weight read (the §Practical Speedups
/// bottleneck) is amortized over the whole batch. Per-sequence
/// accumulation order (lanes within words, words within groups, groups
/// within the row) is identical to [`dot_packed_row_aligned`], so the
/// batched result is bit-identical to n independent packed matvecs.
fn matmul_rows_packed_aligned<const BITS: u32, const CPW: usize>(
    p: &PackedMatrix,
    xeffs: &[f32],
    xsums: &[f32],
    wpg: usize,
    n: usize,
    row0: usize,
    ys: &mut [f32],
) {
    let mask = (1u32 << BITS) - 1;
    let padded = p.nwords * CPW;
    // per-sequence lane accumulators, reset per group
    let mut accs = vec![0.0f32; n * CPW];
    for (i, yrow) in ys.chunks_exact_mut(n).enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        yrow.fill(0.0);
        for (gi, gwords) in words.chunks_exact(wpg).enumerate() {
            accs.fill(0.0);
            let gbase = gi * wpg * CPW;
            for (wi, &w) in gwords.iter().enumerate() {
                let mut dec = [0.0f32; CPW];
                for k in 0..CPW {
                    dec[k] = ((w >> (BITS as usize * k)) & mask) as f32;
                }
                let off = gbase + wi * CPW;
                for j in 0..n {
                    let xg = &xeffs[j * padded + off..j * padded + off + CPW];
                    let a = &mut accs[j * CPW..(j + 1) * CPW];
                    for k in 0..CPW {
                        a[k] += dec[k] * xg[k];
                    }
                }
            }
            let s = scales[gi];
            let z = zeros[gi];
            for (j, yv) in yrow.iter_mut().enumerate() {
                let acc: f32 = accs[j * CPW..(j + 1) * CPW].iter().sum();
                *yv += s * acc - s * z * xsums[j * p.ngroups + gi];
            }
        }
    }
}

/// General (ragged) batched core: falls back to the per-sequence general
/// dot (each row re-read per sequence — only odd test shapes land here).
fn matmul_rows_packed_general(
    p: &PackedMatrix,
    xs: &[f32],
    group: usize,
    n: usize,
    row0: usize,
    ys: &mut [f32],
) {
    for (i, yrow) in ys.chunks_exact_mut(n).enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        for (j, yv) in yrow.iter_mut().enumerate() {
            let x = &xs[j * p.dcol..(j + 1) * p.dcol];
            *yv = match p.bits {
                2 => dot_packed_row_general::<2>(words, x, scales, zeros, p.dcol, group),
                3 => dot_packed_row_general::<3>(words, x, scales, zeros, p.dcol, group),
                4 => dot_packed_row_general::<4>(words, x, scales, zeros, p.dcol, group),
                8 => dot_packed_row_general::<8>(words, x, scales, zeros, p.dcol, group),
                b => panic!("unsupported bit width {b}"),
            };
        }
    }
}

/// Batched Y = dequant(P)·X: `xs` sequence-major (n × dcol), `ys`
/// row-major (drow × n). The continuous-batching kernel: packed weight
/// rows are read once per step for ALL n sequences. Row-range parallel;
/// bit-identical to n independent [`matvec_packed`] calls at every
/// thread count.
pub fn matmul_packed(p: &PackedMatrix, xs: &[f32], n: usize, ys: &mut [f32]) {
    matmul_packed_with(p, xs, n, ys, pool_for(p.drow * p.dcol));
}

/// Serial twin of [`matmul_packed`] (see [`matvec_f32_serial`]).
pub fn matmul_packed_serial(p: &PackedMatrix, xs: &[f32], n: usize, ys: &mut [f32]) {
    matmul_packed_with(p, xs, n, ys, Pool::serial());
}

fn matmul_packed_with(p: &PackedMatrix, xs: &[f32], n: usize, ys: &mut [f32], pool: Pool) {
    assert_eq!(xs.len(), n * p.dcol);
    assert_eq!(ys.len(), p.drow * n);
    if n == 0 {
        return;
    }
    let group = p.dcol / p.ngroups;
    let cpw = (32 / p.bits) as usize;
    // same aligned/ragged split as matvec_packed_with
    let aligned = p.ngroups == 1 || (group % cpw == 0 && p.nwords * cpw == p.dcol);
    if aligned {
        let padded = p.nwords * cpw;
        let mut xeff_store;
        let xeffs: &[f32] = if padded == p.dcol {
            xs
        } else {
            xeff_store = vec![0.0f32; n * padded];
            for j in 0..n {
                xeff_store[j * padded..j * padded + p.dcol]
                    .copy_from_slice(&xs[j * p.dcol..(j + 1) * p.dcol]);
            }
            &xeff_store
        };
        // per-(sequence, group) Σx — row-independent, computed once
        let mut xsums = vec![0.0f32; n * p.ngroups];
        for j in 0..n {
            let x = &xs[j * p.dcol..(j + 1) * p.dcol];
            for (gi, xc) in x.chunks_exact(group).enumerate() {
                xsums[j * p.ngroups + gi] = xc.iter().sum();
            }
        }
        let wpg = p.nwords / p.ngroups;
        par::for_rows_mut(&pool, ys, p.drow, n, |rows, chunk| match p.bits {
            2 => matmul_rows_packed_aligned::<2, 16>(p, xeffs, &xsums, wpg, n, rows.start, chunk),
            3 => matmul_rows_packed_aligned::<3, 10>(p, xeffs, &xsums, wpg, n, rows.start, chunk),
            4 => matmul_rows_packed_aligned::<4, 8>(p, xeffs, &xsums, wpg, n, rows.start, chunk),
            8 => matmul_rows_packed_aligned::<8, 4>(p, xeffs, &xsums, wpg, n, rows.start, chunk),
            b => panic!("unsupported bit width {b}"),
        });
        return;
    }
    par::for_rows_mut(&pool, ys, p.drow, n, |rows, chunk| {
        matmul_rows_packed_general(p, xs, group, n, rows.start, chunk);
    });
}

/// Batched Y = dequant(P)·X + b.
pub fn matmul_packed_bias(p: &PackedMatrix, xs: &[f32], b: &[f32], n: usize, ys: &mut [f32]) {
    matmul_packed(p, xs, n, ys);
    add_bias_rows(ys, b, n);
}

/// Serial twin of [`matmul_packed_bias`].
pub fn matmul_packed_bias_serial(p: &PackedMatrix, xs: &[f32], b: &[f32], n: usize, ys: &mut [f32]) {
    matmul_packed_serial(p, xs, n, ys);
    add_bias_rows(ys, b, n);
}

/// y = dequant(P) x — the quantized-matrix × fp-vector kernel (the Rust
/// twin of the L1 `packmatvec` Pallas kernel and the paper's CUDA kernel).
/// Row-range parallel above [`MATVEC_PAR_MIN_ELEMS`] logical elements;
/// bit-identical at every thread count (rows are independent).
pub fn matvec_packed(p: &PackedMatrix, x: &[f32], y: &mut [f32]) {
    matvec_packed_with(p, x, y, pool_for(p.drow * p.dcol));
}

/// Serial twin of [`matvec_packed`] (see [`matvec_f32_serial`]).
pub fn matvec_packed_serial(p: &PackedMatrix, x: &[f32], y: &mut [f32]) {
    matvec_packed_with(p, x, y, Pool::serial());
}

fn matvec_packed_with(p: &PackedMatrix, x: &[f32], y: &mut [f32], pool: Pool) {
    assert_eq!(x.len(), p.dcol);
    assert_eq!(y.len(), p.drow);
    let group = p.dcol / p.ngroups;
    let cpw = (32 / p.bits) as usize;
    // Fast path: either one grid per row (pad x so the ragged last word
    // multiplies zeros — packed pad fields are 0 by construction), or
    // grouped with whole-word groups (then dcol is word-aligned too).
    // Real layer shapes always land here; odd shapes use the general path.
    let aligned = p.ngroups == 1 || (group % cpw == 0 && p.nwords * cpw == p.dcol);
    if aligned {
        let padded_len = p.nwords * cpw;
        let mut xpad_store;
        let xeff: &[f32] = if padded_len == p.dcol {
            x
        } else {
            xpad_store = vec![0.0f32; padded_len];
            xpad_store[..p.dcol].copy_from_slice(x);
            &xpad_store
        };
        // per-group Σx, shared by every row (row-independent term);
        // pad zeros don't perturb the sums
        let mut xsum = vec![0.0f32; p.ngroups];
        for (gi, xs) in x.chunks_exact(group).enumerate() {
            xsum[gi] = xs.iter().sum();
        }
        let wpg = p.nwords / p.ngroups;
        par::for_rows_mut(&pool, y, p.drow, 1, |rows, ys| {
            packed_rows_aligned(p, xeff, &xsum, wpg, rows.start, ys);
        });
        return;
    }
    par::for_rows_mut(&pool, y, p.drow, 1, |rows, ys| {
        packed_rows_general(p, x, group, rows.start, ys);
    });
}

/// y = dequant(P) x + b.
pub fn matvec_packed_bias(p: &PackedMatrix, x: &[f32], b: &[f32], y: &mut [f32]) {
    matvec_packed(p, x, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

/// Serial twin of [`matvec_packed_bias`] (see [`matvec_f32_serial`]).
pub fn matvec_packed_bias_serial(p: &PackedMatrix, x: &[f32], b: &[f32], y: &mut [f32]) {
    matvec_packed_serial(p, x, y);
    for (yv, &bv) in y.iter_mut().zip(b) {
        *yv += bv;
    }
}

/// Weight bytes touched by one matvec — the quantity the paper's speedup
/// model is built on (used by the Table 5 analog to report the traffic
/// reduction alongside measured latency).
pub fn weight_traffic_bytes(p: &PackedMatrix) -> usize {
    p.storage_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_quantize;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (((s >> 33) as f64 / (1u64 << 31) as f64) - 1.0) as f32
            })
            .collect()
    }

    #[test]
    fn f32_matches_naive() {
        let (drow, dcol) = (7, 13);
        let w = rand_vec(drow * dcol, 1);
        let x = rand_vec(dcol, 2);
        let mut y = vec![0.0; drow];
        matvec_f32(&w, &x, drow, dcol, &mut y);
        for r in 0..drow {
            let want: f32 = (0..dcol).map(|c| w[r * dcol + c] * x[c]).sum();
            assert!((y[r] - want).abs() < 1e-4);
        }
    }

    #[test]
    fn packed_matches_dense_dequant() {
        for (bits, g) in
            [(2u32, 0usize), (3, 0), (4, 0), (8, 0), (3, 16), (4, 8), (2, 32), (8, 16)]
        {
            let (drow, dcol) = (16, 64);
            let w = rand_vec(drow * dcol, bits as u64 * 31 + g as u64);
            let r = rtn_quantize(&w, drow, dcol, bits, g);
            let p = PackedMatrix::from_result(&r);
            let dense = p.dequantize();
            let x = rand_vec(dcol, 99);
            let mut yp = vec![0.0; drow];
            let mut yd = vec![0.0; drow];
            matvec_packed(&p, &x, &mut yp);
            matvec_f32(&dense, &x, drow, dcol, &mut yd);
            for (a, b) in yp.iter().zip(&yd) {
                assert!((a - b).abs() < 1e-3, "bits={bits} g={g}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn packed_handles_unaligned_dcol() {
        // dcol not a multiple of codes-per-word exercises the tail path
        let (drow, dcol) = (4, 37);
        let w = rand_vec(drow * dcol, 5);
        let r = rtn_quantize(&w, drow, dcol, 3, 0);
        let p = PackedMatrix::from_result(&r);
        let x = rand_vec(dcol, 6);
        let mut yp = vec![0.0; drow];
        let mut yd = vec![0.0; drow];
        matvec_packed(&p, &x, &mut yp);
        matvec_f32(&p.dequantize(), &x, drow, dcol, &mut yd);
        for (a, b) in yp.iter().zip(&yd) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn bias_variant() {
        let w = rand_vec(6 * 8, 7);
        let x = rand_vec(8, 8);
        let b = rand_vec(6, 9);
        let mut y1 = vec![0.0; 6];
        let mut y2 = vec![0.0; 6];
        matvec_f32(&w, &x, 6, 8, &mut y1);
        matvec_f32_bias(&w, &x, &b, 6, 8, &mut y2);
        for i in 0..6 {
            assert!((y2[i] - y1[i] - b[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_f32_bitwise_equals_stacked_matvecs() {
        // includes dcol not divisible by the unroll and n > drow
        for (drow, dcol, n) in [(7usize, 13usize, 3usize), (16, 33, 5), (3, 64, 9)] {
            let w = rand_vec(drow * dcol, 21 + n as u64);
            let xs = rand_vec(n * dcol, 22 + drow as u64);
            let b = rand_vec(drow, 23);
            let mut ys = vec![0.0f32; drow * n];
            matmul_f32_bias(&w, &xs, &b, drow, dcol, n, &mut ys);
            for j in 0..n {
                let mut y = vec![0.0f32; drow];
                matvec_f32_bias(&w, &xs[j * dcol..(j + 1) * dcol], &b, drow, dcol, &mut y);
                for r in 0..drow {
                    assert_eq!(
                        ys[r * n + j].to_bits(),
                        y[r].to_bits(),
                        "drow={drow} dcol={dcol} n={n} r={r} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn matmul_packed_bitwise_equals_stacked_matvecs() {
        // aligned (1024), ragged tail (37), and grouped layouts
        for (bits, g) in [(2u32, 0usize), (3, 0), (4, 16), (8, 0), (3, 37)] {
            let (drow, dcol, n) = (12usize, if g == 37 { 37 } else { 1024 }, 4usize);
            let g = if g == 37 { 0 } else { g };
            let w = rand_vec(drow * dcol, bits as u64 * 17 + g as u64);
            let r = rtn_quantize(&w, drow, dcol, bits, g);
            let p = PackedMatrix::from_result(&r);
            let xs = rand_vec(n * dcol, 31 + bits as u64);
            let b = rand_vec(drow, 32);
            let mut ys = vec![0.0f32; drow * n];
            matmul_packed_bias(&p, &xs, &b, n, &mut ys);
            for j in 0..n {
                let mut y = vec![0.0f32; drow];
                matvec_packed_bias(&p, &xs[j * dcol..(j + 1) * dcol], &b, &mut y);
                for row in 0..drow {
                    assert_eq!(
                        ys[row * n + j].to_bits(),
                        y[row].to_bits(),
                        "bits={bits} g={g} row={row} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn matmul_serial_twins_match() {
        let (drow, dcol, n) = (9usize, 64usize, 3usize);
        let w = rand_vec(drow * dcol, 41);
        let xs = rand_vec(n * dcol, 42);
        let (mut a, mut b) = (vec![0.0f32; drow * n], vec![0.0f32; drow * n]);
        matmul_f32(&w, &xs, drow, dcol, n, &mut a);
        matmul_f32_serial(&w, &xs, drow, dcol, n, &mut b);
        assert_eq!(a, b);
        let q = rtn_quantize(&w, drow, dcol, 4, 0);
        let p = PackedMatrix::from_result(&q);
        matmul_packed(&p, &xs, n, &mut a);
        matmul_packed_serial(&p, &xs, n, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn traffic_reduction_ratios() {
        let w = rand_vec(64 * 640, 11);
        let f32_bytes = 64 * 640 * 4;
        for (bits, min_ratio) in [(4u32, 7.0f64), (3, 9.0), (2, 14.0)] {
            let r = rtn_quantize(&w, 64, 640, bits, 0);
            let p = PackedMatrix::from_result(&r);
            let ratio = f32_bytes as f64 / weight_traffic_bytes(&p) as f64;
            assert!(ratio > min_ratio, "bits={bits}: ratio {ratio}");
        }
    }
}
