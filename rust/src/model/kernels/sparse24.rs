//! 2:4 semi-structured kernels over [`Sparse24Matrix`] — the execution
//! side of the joint sparsify+quantize engine (`quant::sparse`).
//!
//! The format stores, per aligned 4-column block, only the two surviving
//! codes (a contiguous code stream at `bits` per code) plus one index
//! nibble `(i1 << 2) | i0`; both streams are word-padded per group. The
//! kernels therefore touch 2 of every 4 weights: half the FMAs and, at
//! 4-bit, 12 bits of weight traffic per 4 columns against the dense
//! packed path's 16 — which is where the batch-1 speedup comes from on
//! the memory-bound decode matvec (DESIGN.md §Sparsity).
//!
//! §Determinism, mirroring the dense kernels:
//! * the scalar kernels here are THE bit-frozen reference: per group one
//!   f32 accumulator, blocks in order, survivor `i0` before `i1`. Because
//!   pruned entries dequantize to exactly ±0.0 and a (+0-initialised) f32
//!   accumulator is bit-invariant under adding ±0.0, the scalar sparse
//!   dot is bit-identical to the groupwise single-accumulator dense dot
//!   over the dequantized matrix — the property `tests/sparsity.rs` pins.
//! * the batched kernel replays the single-sequence op order per
//!   sequence (batched ≡ single bitwise), and the tiled scalar fallback
//!   replays the flat per-row op order (tiled ≡ flat bitwise).
//! * SIMD variants (AVX2/NEON, 4-bit) reassociate lanes and agree with
//!   scalar within the usual ~1e-5 cross-ISA band.

use super::fill_lut;
use crate::quant::sparse::Sparse24Matrix;

/// Rows per tile — same R as the dense [`super::tiled::TiledPacked`].
pub const TILE_ROWS: usize = 4;

/// Register-tiled interleaved form of a [`Sparse24Matrix`]: words and
/// grids of R=4 consecutive rows interleaved index-major, so the batch-1
/// decode streams one cache line of 4 rows' pair words at a time. Same
/// codes/indices/grids as the flat form — only the memory order changes.
///
/// Unlike the dense `TiledPacked` there is no alignment predicate: the
/// sparse format is word-padded per group by construction, so every
/// instance tiles. The last tile is zero-padded (code 0, scale 0 → every
/// phantom lane dequantizes to 0); kernels don't write the phantom rows.
#[derive(Debug, Clone)]
pub struct Sparse24Tiled {
    /// pair words, tile-major: `pair_words[(tile * npw + wi) * r + rr]`
    pub pair_words: Vec<u32>,
    /// index words, tile-major: `idx_words[(tile * niw + wi) * r + rr]`
    pub idx_words: Vec<u32>,
    /// scales, tile-major: `scales[(tile * ngroups + gi) * r + rr]`
    pub scales: Vec<f32>,
    pub zeros: Vec<f32>,
    /// rows per tile (R)
    pub r: usize,
    /// number of tiles (`ceil(drow / r)`; last tile zero-padded)
    pub ntiles: usize,
    pub drow: usize,
    pub dcol: usize,
    pub ngroups: usize,
    /// pair words per row (`ngroups · pair_wpg`)
    pub npw: usize,
    /// index words per row (`ngroups · idx_wpg`)
    pub niw: usize,
    pub pair_wpg: usize,
    pub idx_wpg: usize,
    pub bits: u32,
}

impl Sparse24Tiled {
    /// Interleave `m` into R-row tiles.
    pub fn from_sparse(m: &Sparse24Matrix) -> Sparse24Tiled {
        let r = TILE_ROWS;
        let ntiles = m.drow.div_ceil(r);
        let (npw, niw) = (m.npair_words(), m.nidx_words());
        let mut pair_words = vec![0u32; ntiles * npw * r];
        let mut idx_words = vec![0u32; ntiles * niw * r];
        let mut scales = vec![0.0f32; ntiles * m.ngroups * r];
        let mut zeros = vec![0.0f32; ntiles * m.ngroups * r];
        for t in 0..ntiles {
            for rr in 0..r {
                let row = t * r + rr;
                if row >= m.drow {
                    break; // phantom rows stay all-zero
                }
                for wi in 0..npw {
                    pair_words[(t * npw + wi) * r + rr] = m.pair_words[row * npw + wi];
                }
                for wi in 0..niw {
                    idx_words[(t * niw + wi) * r + rr] = m.idx_words[row * niw + wi];
                }
                for gi in 0..m.ngroups {
                    scales[(t * m.ngroups + gi) * r + rr] = m.scales[row * m.ngroups + gi];
                    zeros[(t * m.ngroups + gi) * r + rr] = m.zeros[row * m.ngroups + gi];
                }
            }
        }
        Sparse24Tiled {
            pair_words,
            idx_words,
            scales,
            zeros,
            r,
            ntiles,
            drow: m.drow,
            dcol: m.dcol,
            ngroups: m.ngroups,
            npw,
            niw,
            pair_wpg: m.pair_wpg,
            idx_wpg: m.idx_wpg,
            bits: m.bits,
        }
    }

    /// Bytes of weight storage in this layout (what one tiled matvec
    /// streams, including last-tile padding).
    pub fn storage_bytes(&self) -> usize {
        (self.pair_words.len() + self.idx_words.len()) * 4
            + (self.scales.len() + self.zeros.len()) * 4
    }
}

/// One row's sparse dot — THE reference op order every other variant
/// (batched, tiled, SIMD) is measured against. Per group: fill the
/// dequant LUT, one f32 accumulator, blocks in order, `i0` before `i1`.
#[inline(always)]
fn dot_row(m: &Sparse24Matrix, r: usize, x: &[f32], lut: &mut [f32; 256]) -> f32 {
    let group = m.dcol / m.ngroups;
    let nblocks = group / 4;
    let cpw = (32 / m.bits) as usize;
    let bits = m.bits as usize;
    let mask = (1u32 << m.bits) - 1;
    let (npw, niw) = (m.npair_words(), m.nidx_words());
    let mut acc_row = 0.0f32;
    for gi in 0..m.ngroups {
        fill_lut(m.bits, m.scales[r * m.ngroups + gi], m.zeros[r * m.ngroups + gi], lut);
        let pw = &m.pair_words[r * npw + gi * m.pair_wpg..];
        let iw = &m.idx_words[r * niw + gi * m.idx_wpg..];
        let xg = &x[gi * group..];
        let mut acc = 0.0f32;
        for b in 0..nblocks {
            let nib = (iw[b / 8] >> ((b % 8) * 4)) & 0xF;
            let k = 2 * b;
            let c0 = (pw[k / cpw] >> ((k % cpw) * bits)) & mask;
            let c1 = (pw[(k + 1) / cpw] >> (((k + 1) % cpw) * bits)) & mask;
            acc += lut[c0 as usize] * xg[b * 4 + (nib & 3) as usize];
            acc += lut[c1 as usize] * xg[b * 4 + ((nib >> 2) & 3) as usize];
        }
        acc_row += acc;
    }
    acc_row
}

/// Rows `row0..row0+y.len()` of y = dequant(M) x — the scalar flat
/// matvec (per-row arithmetic independent of the thread partition).
pub(crate) fn rows(m: &Sparse24Matrix, x: &[f32], row0: usize, y: &mut [f32]) {
    let mut lut = [0.0f32; 256];
    for (i, yr) in y.iter_mut().enumerate() {
        *yr = dot_row(m, row0 + i, x, &mut lut);
    }
}

/// Batched rows `row0..` of Y = dequant(M)·X over `n` stacked
/// activations: each block's codes/indices are decoded ONCE and FMA'd
/// into every sequence's group accumulator; per-sequence op order is
/// exactly [`dot_row`], so batched ≡ n single matvecs bitwise.
pub(crate) fn matmul_rows(
    m: &Sparse24Matrix,
    xs: &[f32],
    n: usize,
    row0: usize,
    ys: &mut [f32],
) {
    let group = m.dcol / m.ngroups;
    let nblocks = group / 4;
    let cpw = (32 / m.bits) as usize;
    let bits = m.bits as usize;
    let mask = (1u32 << m.bits) - 1;
    let (npw, niw) = (m.npair_words(), m.nidx_words());
    let mut lut = [0.0f32; 256];
    let mut accs = vec![0.0f32; n];
    for (i, yrow) in ys.chunks_exact_mut(n).enumerate() {
        let r = row0 + i;
        yrow.fill(0.0);
        for gi in 0..m.ngroups {
            fill_lut(m.bits, m.scales[r * m.ngroups + gi], m.zeros[r * m.ngroups + gi], &mut lut);
            let pw = &m.pair_words[r * npw + gi * m.pair_wpg..];
            let iw = &m.idx_words[r * niw + gi * m.idx_wpg..];
            accs.fill(0.0);
            for b in 0..nblocks {
                let nib = (iw[b / 8] >> ((b % 8) * 4)) & 0xF;
                let k = 2 * b;
                let l0 = lut[((pw[k / cpw] >> ((k % cpw) * bits)) & mask) as usize];
                let l1 = lut[((pw[(k + 1) / cpw] >> (((k + 1) % cpw) * bits)) & mask) as usize];
                let col0 = gi * group + b * 4 + (nib & 3) as usize;
                let col1 = gi * group + b * 4 + ((nib >> 2) & 3) as usize;
                for (j, a) in accs.iter_mut().enumerate() {
                    *a += l0 * xs[j * m.dcol + col0];
                    *a += l1 * xs[j * m.dcol + col1];
                }
            }
            for (j, yv) in yrow.iter_mut().enumerate() {
                *yv += accs[j];
            }
        }
    }
}

/// One tile of y = dequant(T) x — the scalar fallback when the active
/// ISA has no sparse tiled microkernel. Per-row op order replays
/// [`dot_row`] exactly (same group accumulator, same block order), so
/// tiled ≡ flat bitwise on the scalar ISA.
pub(crate) fn tiled_rows(t: &Sparse24Tiled, x: &[f32], tile: usize, ys: &mut [f32]) {
    let group = t.dcol / t.ngroups;
    let nblocks = group / 4;
    let cpw = (32 / t.bits) as usize;
    let bits = t.bits as usize;
    let mask = (1u32 << t.bits) - 1;
    let r = t.r;
    let mut lut = [0.0f32; 256];
    ys.fill(0.0);
    for gi in 0..t.ngroups {
        let gbase = (tile * t.ngroups + gi) * r;
        let xg = &x[gi * group..];
        for (rr, yv) in ys.iter_mut().enumerate() {
            fill_lut(t.bits, t.scales[gbase + rr], t.zeros[gbase + rr], &mut lut);
            let mut acc = 0.0f32;
            for b in 0..nblocks {
                let iwi = (tile * t.niw + gi * t.idx_wpg + b / 8) * r + rr;
                let nib = (t.idx_words[iwi] >> ((b % 8) * 4)) & 0xF;
                let k = 2 * b;
                let w0 = t.pair_words[(tile * t.npw + gi * t.pair_wpg + k / cpw) * r + rr];
                let w1 = t.pair_words[(tile * t.npw + gi * t.pair_wpg + (k + 1) / cpw) * r + rr];
                let c0 = (w0 >> ((k % cpw) * bits)) & mask;
                let c1 = (w1 >> (((k + 1) % cpw) * bits)) & mask;
                acc += lut[c0 as usize] * xg[b * 4 + (nib & 3) as usize];
                acc += lut[c1 as usize] * xg[b * 4 + ((nib >> 2) & 3) as usize];
            }
            *yv += acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::rand_vec;
    use crate::quant::rtn_quantize;
    use crate::quant::sparse::prune_2of4_by_magnitude;

    fn sample(bits: u32, g: usize, drow: usize, dcol: usize, seed: u64) -> Sparse24Matrix {
        let w = rand_vec(drow * dcol, seed);
        let mut q = rtn_quantize(&w, drow, dcol, bits, g);
        prune_2of4_by_magnitude(&mut q);
        Sparse24Matrix::from_result(&q).unwrap()
    }

    #[test]
    fn tiled_interleave_roundtrips() {
        let m = sample(4, 16, 10, 64, 11); // 2 full tiles + ragged
        let t = Sparse24Tiled::from_sparse(&m);
        assert_eq!(t.ntiles, 3);
        for row in 0..m.drow {
            let (tile, rr) = (row / t.r, row % t.r);
            for wi in 0..t.npw {
                assert_eq!(
                    t.pair_words[(tile * t.npw + wi) * t.r + rr],
                    m.pair_words[row * t.npw + wi]
                );
            }
            for wi in 0..t.niw {
                assert_eq!(
                    t.idx_words[(tile * t.niw + wi) * t.r + rr],
                    m.idx_words[row * t.niw + wi]
                );
            }
            for gi in 0..t.ngroups {
                assert_eq!(
                    t.scales[(tile * t.ngroups + gi) * t.r + rr],
                    m.scales[row * t.ngroups + gi]
                );
            }
        }
        // phantom rows of the last tile stay zero
        for wi in 0..t.npw {
            for rr in 2..t.r {
                assert_eq!(t.pair_words[(2 * t.npw + wi) * t.r + rr], 0);
            }
        }
    }

    #[test]
    fn scalar_matches_groupwise_dense_dot_bitwise() {
        for bits in [2u32, 3, 4, 8] {
            for g in [0usize, 16] {
                let (drow, dcol) = (7usize, 48usize);
                let m = sample(bits, g, drow, dcol, 21 + bits as u64);
                let x = rand_vec(dcol, 31);
                let wdeq = m.dequantize();
                let group = dcol / m.ngroups;
                let mut y = vec![0.0f32; drow];
                rows(&m, &x, 0, &mut y);
                for r in 0..drow {
                    // groupwise single-accumulator dense reference
                    let mut want = 0.0f32;
                    for gi in 0..m.ngroups {
                        let mut acc = 0.0f32;
                        for c in 0..group {
                            acc += wdeq[r * dcol + gi * group + c] * x[gi * group + c];
                        }
                        want += acc;
                    }
                    assert_eq!(y[r].to_bits(), want.to_bits(), "bits={bits} g={g} r={r}");
                }
            }
        }
    }

    #[test]
    fn batched_replays_single_bitwise() {
        let m = sample(4, 16, 9, 64, 3);
        let n = 3usize;
        let xs = rand_vec(n * 64, 5);
        let mut ys = vec![0.0f32; 9 * n];
        matmul_rows(&m, &xs, n, 0, &mut ys);
        let mut lut = [0.0f32; 256];
        for j in 0..n {
            for r in 0..9 {
                let want = dot_row(&m, r, &xs[j * 64..(j + 1) * 64], &mut lut);
                assert_eq!(ys[r * n + j].to_bits(), want.to_bits(), "r={r} j={j}");
            }
        }
    }

    #[test]
    fn tiled_scalar_matches_flat_bitwise() {
        for (drow, dcol, g) in [(10usize, 64usize, 16usize), (5, 48, 0), (4, 32, 8)] {
            let m = sample(4, g, drow, dcol, 40 + drow as u64);
            let t = Sparse24Tiled::from_sparse(&m);
            let x = rand_vec(dcol, 7);
            let mut flat = vec![0.0f32; drow];
            rows(&m, &x, 0, &mut flat);
            for tile in 0..t.ntiles {
                let rows_here = t.r.min(drow - tile * t.r);
                let mut ys = vec![0.0f32; rows_here];
                tiled_rows(&t, &x, tile, &mut ys);
                for rr in 0..rows_here {
                    assert_eq!(
                        ys[rr].to_bits(),
                        flat[tile * t.r + rr].to_bits(),
                        "tile={tile} rr={rr}"
                    );
                }
            }
        }
    }
}
