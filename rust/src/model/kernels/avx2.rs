//! AVX2+FMA microkernels (x86_64) — `Isa::Avx2Fma`.
//!
//! Every function here carries `#[target_feature(enable = "avx2", "fma")]`
//! and is only reached through the dispatch table after
//! `is_x86_feature_detected!` confirmed both features (kernels::clamp), so
//! the binary stays portable with `RUSTFLAGS` unset — dispatch, not
//! compile flags, provides the ISA.
//!
//! Packed dequant goes through the per-group 2^bits LUT
//! (`lut[code] = s·(code − zero)`, `kernels::fill_lut`):
//! * 2-bit — 4-entry LUT, one `vpermps` per 8 codes (indices 0..3);
//! * 3-bit — 8-entry LUT, one `vpermps`; codes 8/9 of each 10-code word
//!   are folded through the same LUT scalar-side;
//! * 4-bit — 16-entry LUT as two ymm halves: two `vpermps` (vpermps reads
//!   only the low 3 index bits) blended on code bit 3;
//! * 8-bit — a 256-entry table would thrash; dequant is the affine
//!   `fma(code, s, −s·z)` instead, which computes the same value.
//!
//! §Determinism: lane order is fixed (one accumulator vector per group,
//! horizontal sum in a fixed tree), and the batched kernels replay the
//! exact per-sequence op order of the single-sequence kernels — so for
//! this ISA, batched ≡ single bitwise and any thread count is
//! bit-identical (the partition only moves whole rows).

use super::fill_lut;
use super::sparse24::Sparse24Tiled;
use super::tiled::TiledPacked;
use crate::quant::pack::PackedMatrix;
use crate::quant::sparse::Sparse24Matrix;
use core::arch::x86_64::*;

/// Horizontal sum in a fixed association tree — shared by every kernel so
/// batched/single and tiled/flat results are bit-identical per row.
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn hsum8(v: __m256) -> f32 {
    let mut t = [0.0f32; 8];
    _mm256_storeu_ps(t.as_mut_ptr(), v);
    ((t[0] + t[1]) + (t[2] + t[3])) + ((t[4] + t[5]) + (t[6] + t[7]))
}

#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn hsum4(v: __m128) -> f32 {
    let mut t = [0.0f32; 4];
    _mm_storeu_ps(t.as_mut_ptr(), v);
    (t[0] + t[1]) + (t[2] + t[3])
}

// -------------------------------------------------------------------------
// Dense f32
// -------------------------------------------------------------------------

/// 8-lane×2 FMA row dot. The single dot shared by the dense matvec AND
/// the batched dense matmul (bit-parity between them, per sequence).
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_f32(row: &[f32], x: &[f32], dcol: usize) -> f32 {
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let chunks = dcol / 16;
    for c in 0..chunks {
        let i = c * 16;
        acc0 = _mm256_fmadd_ps(
            _mm256_loadu_ps(row.as_ptr().add(i)),
            _mm256_loadu_ps(x.as_ptr().add(i)),
            acc0,
        );
        acc1 = _mm256_fmadd_ps(
            _mm256_loadu_ps(row.as_ptr().add(i + 8)),
            _mm256_loadu_ps(x.as_ptr().add(i + 8)),
            acc1,
        );
    }
    let mut acc = hsum8(_mm256_add_ps(acc0, acc1));
    for i in chunks * 16..dcol {
        acc += row[i] * x[i];
    }
    acc
}

#[target_feature(enable = "avx2", enable = "fma")]
pub(crate) unsafe fn f32_rows(w: &[f32], x: &[f32], dcol: usize, row0: usize, y: &mut [f32]) {
    for (i, yr) in y.iter_mut().enumerate() {
        let r = row0 + i;
        *yr = dot_f32(&w[r * dcol..(r + 1) * dcol], x, dcol);
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
pub(crate) unsafe fn f32_matmul_rows(
    w: &[f32],
    xs: &[f32],
    dcol: usize,
    n: usize,
    row0: usize,
    ys: &mut [f32],
) {
    for (i, yrow) in ys.chunks_exact_mut(n).enumerate() {
        let r = row0 + i;
        let row = &w[r * dcol..(r + 1) * dcol];
        for (j, yv) in yrow.iter_mut().enumerate() {
            *yv = dot_f32(row, &xs[j * dcol..(j + 1) * dcol], dcol);
        }
    }
}

// -------------------------------------------------------------------------
// Packed dequant helpers: one u32 word -> dequantized f32 lanes
// -------------------------------------------------------------------------

/// 4-bit: 8 codes -> 8 lanes. 16-entry LUT lives in (lo, hi) ymm halves.
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dequant8_b4(w: u32, lo: __m256, hi: __m256) -> __m256 {
    let shifts = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
    let codes = _mm256_and_si256(
        _mm256_srlv_epi32(_mm256_set1_epi32(w as i32), shifts),
        _mm256_set1_epi32(15),
    );
    // vpermps reads only idx[2:0], so no pre-masking of the low half
    let vlo = _mm256_permutevar8x32_ps(lo, codes);
    let vhi = _mm256_permutevar8x32_ps(hi, codes);
    let m = _mm256_castsi256_ps(_mm256_cmpgt_epi32(codes, _mm256_set1_epi32(7)));
    _mm256_blendv_ps(vlo, vhi, m)
}

/// 3-bit: lanes 0..7 of a 10-code word (codes 8/9 are handled scalar by
/// the caller through the same LUT).
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dequant8_b3(w: u32, lut: __m256) -> __m256 {
    let shifts = _mm256_setr_epi32(0, 3, 6, 9, 12, 15, 18, 21);
    let codes = _mm256_and_si256(
        _mm256_srlv_epi32(_mm256_set1_epi32(w as i32), shifts),
        _mm256_set1_epi32(7),
    );
    _mm256_permutevar8x32_ps(lut, codes)
}

/// 2-bit: 16 codes -> two 8-lane vectors. 4-entry LUT in lanes 0..3.
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dequant16_b2(w: u32, lut: __m256) -> (__m256, __m256) {
    let v = _mm256_set1_epi32(w as i32);
    let m = _mm256_set1_epi32(3);
    let s0 = _mm256_setr_epi32(0, 2, 4, 6, 8, 10, 12, 14);
    let s1 = _mm256_setr_epi32(16, 18, 20, 22, 24, 26, 28, 30);
    let c0 = _mm256_and_si256(_mm256_srlv_epi32(v, s0), m);
    let c1 = _mm256_and_si256(_mm256_srlv_epi32(v, s1), m);
    (_mm256_permutevar8x32_ps(lut, c0), _mm256_permutevar8x32_ps(lut, c1))
}

/// 8-bit: 4 codes -> 4 lanes, affine dequant `fma(code, s, −s·z)`.
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dequant4_b8(w: u32, s: __m128, nsz: __m128) -> __m128 {
    let shifts = _mm_setr_epi32(0, 8, 16, 24);
    let codes = _mm_and_si128(
        _mm_srlv_epi32(_mm_set1_epi32(w as i32), shifts),
        _mm_set1_epi32(255),
    );
    _mm_fmadd_ps(_mm_cvtepi32_ps(codes), s, nsz)
}

// -------------------------------------------------------------------------
// Packed matvec, aligned fast path (single sequence)
// -------------------------------------------------------------------------

#[target_feature(enable = "avx2", enable = "fma")]
pub(crate) unsafe fn packed_rows_aligned(
    p: &PackedMatrix,
    xeff: &[f32],
    wpg: usize,
    row0: usize,
    y: &mut [f32],
) {
    match p.bits {
        2 => rows_b2(p, xeff, wpg, row0, y),
        3 => rows_b3(p, xeff, wpg, row0, y),
        4 => rows_b4(p, xeff, wpg, row0, y),
        8 => rows_b8(p, xeff, wpg, row0, y),
        b => panic!("unsupported bit width {b}"),
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn rows_b4(p: &PackedMatrix, xeff: &[f32], wpg: usize, row0: usize, y: &mut [f32]) {
    let mut lut = [0.0f32; 16];
    for (i, yr) in y.iter_mut().enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        let mut acc_row = 0.0f32;
        for gi in 0..p.ngroups {
            fill_lut(4, scales[gi], zeros[gi], &mut lut);
            let lo = _mm256_loadu_ps(lut.as_ptr());
            let hi = _mm256_loadu_ps(lut.as_ptr().add(8));
            let mut acc = _mm256_setzero_ps();
            for wi in 0..wpg {
                let w = words[gi * wpg + wi];
                let xv = _mm256_loadu_ps(xeff.as_ptr().add((gi * wpg + wi) * 8));
                acc = _mm256_fmadd_ps(dequant8_b4(w, lo, hi), xv, acc);
            }
            acc_row += hsum8(acc);
        }
        *yr = acc_row;
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn rows_b3(p: &PackedMatrix, xeff: &[f32], wpg: usize, row0: usize, y: &mut [f32]) {
    let mut lut = [0.0f32; 8];
    for (i, yr) in y.iter_mut().enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        let mut acc_row = 0.0f32;
        for gi in 0..p.ngroups {
            fill_lut(3, scales[gi], zeros[gi], &mut lut);
            let l = _mm256_loadu_ps(lut.as_ptr());
            let mut acc = _mm256_setzero_ps();
            let mut tacc = 0.0f32;
            for wi in 0..wpg {
                let w = words[gi * wpg + wi];
                let off = (gi * wpg + wi) * 10;
                let xv = _mm256_loadu_ps(xeff.as_ptr().add(off));
                acc = _mm256_fmadd_ps(dequant8_b3(w, l), xv, acc);
                tacc += lut[((w >> 24) & 7) as usize] * xeff[off + 8];
                tacc += lut[((w >> 27) & 7) as usize] * xeff[off + 9];
            }
            acc_row += hsum8(acc) + tacc;
        }
        *yr = acc_row;
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn rows_b2(p: &PackedMatrix, xeff: &[f32], wpg: usize, row0: usize, y: &mut [f32]) {
    let mut lut = [0.0f32; 8];
    for (i, yr) in y.iter_mut().enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        let mut acc_row = 0.0f32;
        for gi in 0..p.ngroups {
            fill_lut(2, scales[gi], zeros[gi], &mut lut);
            let l = _mm256_loadu_ps(lut.as_ptr());
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            for wi in 0..wpg {
                let w = words[gi * wpg + wi];
                let off = (gi * wpg + wi) * 16;
                let (d0, d1) = dequant16_b2(w, l);
                acc0 = _mm256_fmadd_ps(d0, _mm256_loadu_ps(xeff.as_ptr().add(off)), acc0);
                acc1 = _mm256_fmadd_ps(d1, _mm256_loadu_ps(xeff.as_ptr().add(off + 8)), acc1);
            }
            acc_row += hsum8(_mm256_add_ps(acc0, acc1));
        }
        *yr = acc_row;
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn rows_b8(p: &PackedMatrix, xeff: &[f32], wpg: usize, row0: usize, y: &mut [f32]) {
    for (i, yr) in y.iter_mut().enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        let mut acc_row = 0.0f32;
        for gi in 0..p.ngroups {
            let s = _mm_set1_ps(scales[gi]);
            let nsz = _mm_set1_ps(-(scales[gi] * zeros[gi]));
            let mut acc = _mm_setzero_ps();
            for wi in 0..wpg {
                let w = words[gi * wpg + wi];
                let xv = _mm_loadu_ps(xeff.as_ptr().add((gi * wpg + wi) * 4));
                acc = _mm_fmadd_ps(dequant4_b8(w, s, nsz), xv, acc);
            }
            acc_row += hsum4(acc);
        }
        *yr = acc_row;
    }
}

// -------------------------------------------------------------------------
// Packed matmul, aligned fast path (batched): each word decoded ONCE and
// FMA'd into every sequence's accumulator. Per-sequence op order replays
// the single-sequence kernels above exactly -> bitwise batched parity.
// -------------------------------------------------------------------------

#[target_feature(enable = "avx2", enable = "fma")]
pub(crate) unsafe fn packed_matmul_rows_aligned(
    p: &PackedMatrix,
    xeffs: &[f32],
    wpg: usize,
    n: usize,
    row0: usize,
    ys: &mut [f32],
) {
    match p.bits {
        2 => matmul_b2(p, xeffs, wpg, n, row0, ys),
        3 => matmul_b3(p, xeffs, wpg, n, row0, ys),
        4 => matmul_b4(p, xeffs, wpg, n, row0, ys),
        8 => matmul_b8(p, xeffs, wpg, n, row0, ys),
        b => panic!("unsupported bit width {b}"),
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn matmul_b4(p: &PackedMatrix, xeffs: &[f32], wpg: usize, n: usize, row0: usize, ys: &mut [f32]) {
    let padded = p.nwords * 8;
    let mut lut = [0.0f32; 16];
    let mut accs: Vec<__m256> = vec![_mm256_setzero_ps(); n];
    for (i, yrow) in ys.chunks_exact_mut(n).enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        yrow.fill(0.0);
        for gi in 0..p.ngroups {
            fill_lut(4, scales[gi], zeros[gi], &mut lut);
            let lo = _mm256_loadu_ps(lut.as_ptr());
            let hi = _mm256_loadu_ps(lut.as_ptr().add(8));
            for a in accs.iter_mut() {
                *a = _mm256_setzero_ps();
            }
            for wi in 0..wpg {
                let w = words[gi * wpg + wi];
                let off = (gi * wpg + wi) * 8;
                let deq = dequant8_b4(w, lo, hi);
                for (j, a) in accs.iter_mut().enumerate() {
                    let xv = _mm256_loadu_ps(xeffs.as_ptr().add(j * padded + off));
                    *a = _mm256_fmadd_ps(deq, xv, *a);
                }
            }
            for (j, yv) in yrow.iter_mut().enumerate() {
                *yv += hsum8(accs[j]);
            }
        }
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn matmul_b3(p: &PackedMatrix, xeffs: &[f32], wpg: usize, n: usize, row0: usize, ys: &mut [f32]) {
    let padded = p.nwords * 10;
    let mut lut = [0.0f32; 8];
    let mut accs: Vec<__m256> = vec![_mm256_setzero_ps(); n];
    let mut taccs = vec![0.0f32; n];
    for (i, yrow) in ys.chunks_exact_mut(n).enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        yrow.fill(0.0);
        for gi in 0..p.ngroups {
            fill_lut(3, scales[gi], zeros[gi], &mut lut);
            let l = _mm256_loadu_ps(lut.as_ptr());
            for a in accs.iter_mut() {
                *a = _mm256_setzero_ps();
            }
            taccs.fill(0.0);
            for wi in 0..wpg {
                let w = words[gi * wpg + wi];
                let off = (gi * wpg + wi) * 10;
                let deq = dequant8_b3(w, l);
                let l8 = lut[((w >> 24) & 7) as usize];
                let l9 = lut[((w >> 27) & 7) as usize];
                for j in 0..n {
                    let xv = _mm256_loadu_ps(xeffs.as_ptr().add(j * padded + off));
                    accs[j] = _mm256_fmadd_ps(deq, xv, accs[j]);
                    taccs[j] += l8 * xeffs[j * padded + off + 8];
                    taccs[j] += l9 * xeffs[j * padded + off + 9];
                }
            }
            for (j, yv) in yrow.iter_mut().enumerate() {
                *yv += hsum8(accs[j]) + taccs[j];
            }
        }
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn matmul_b2(p: &PackedMatrix, xeffs: &[f32], wpg: usize, n: usize, row0: usize, ys: &mut [f32]) {
    let padded = p.nwords * 16;
    let mut lut = [0.0f32; 8];
    let mut accs0: Vec<__m256> = vec![_mm256_setzero_ps(); n];
    let mut accs1: Vec<__m256> = vec![_mm256_setzero_ps(); n];
    for (i, yrow) in ys.chunks_exact_mut(n).enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        yrow.fill(0.0);
        for gi in 0..p.ngroups {
            fill_lut(2, scales[gi], zeros[gi], &mut lut);
            let l = _mm256_loadu_ps(lut.as_ptr());
            for a in accs0.iter_mut() {
                *a = _mm256_setzero_ps();
            }
            for a in accs1.iter_mut() {
                *a = _mm256_setzero_ps();
            }
            for wi in 0..wpg {
                let w = words[gi * wpg + wi];
                let off = (gi * wpg + wi) * 16;
                let (d0, d1) = dequant16_b2(w, l);
                for j in 0..n {
                    accs0[j] = _mm256_fmadd_ps(
                        d0,
                        _mm256_loadu_ps(xeffs.as_ptr().add(j * padded + off)),
                        accs0[j],
                    );
                    accs1[j] = _mm256_fmadd_ps(
                        d1,
                        _mm256_loadu_ps(xeffs.as_ptr().add(j * padded + off + 8)),
                        accs1[j],
                    );
                }
            }
            for (j, yv) in yrow.iter_mut().enumerate() {
                *yv += hsum8(_mm256_add_ps(accs0[j], accs1[j]));
            }
        }
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn matmul_b8(p: &PackedMatrix, xeffs: &[f32], wpg: usize, n: usize, row0: usize, ys: &mut [f32]) {
    let padded = p.nwords * 4;
    let mut accs: Vec<__m128> = vec![_mm_setzero_ps(); n];
    for (i, yrow) in ys.chunks_exact_mut(n).enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        yrow.fill(0.0);
        for gi in 0..p.ngroups {
            let s = _mm_set1_ps(scales[gi]);
            let nsz = _mm_set1_ps(-(scales[gi] * zeros[gi]));
            for a in accs.iter_mut() {
                *a = _mm_setzero_ps();
            }
            for wi in 0..wpg {
                let w = words[gi * wpg + wi];
                let off = (gi * wpg + wi) * 4;
                let deq = dequant4_b8(w, s, nsz);
                for (j, a) in accs.iter_mut().enumerate() {
                    let xv = _mm_loadu_ps(xeffs.as_ptr().add(j * padded + off));
                    *a = _mm_fmadd_ps(deq, xv, *a);
                }
            }
            for (j, yv) in yrow.iter_mut().enumerate() {
                *yv += hsum4(accs[j]);
            }
        }
    }
}

// -------------------------------------------------------------------------
// Tiled matvec: R=4 interleaved rows, one x load feeds 4 accumulators.
// Per-row op order matches the flat aligned kernels above exactly, so the
// tiled and flat AVX2 paths are bit-identical per row.
// -------------------------------------------------------------------------

#[target_feature(enable = "avx2", enable = "fma")]
pub(crate) unsafe fn tiled_rows(t: &TiledPacked, xeff: &[f32], tile: usize, ys: &mut [f32]) {
    debug_assert_eq!(t.r, 4, "AVX2 tiled kernels assume R=4");
    match t.bits {
        2 => tiled_b2(t, xeff, tile, ys),
        3 => tiled_b3(t, xeff, tile, ys),
        4 => tiled_b4(t, xeff, tile, ys),
        8 => tiled_b8(t, xeff, tile, ys),
        b => panic!("unsupported bit width {b}"),
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn tiled_b4(t: &TiledPacked, xeff: &[f32], tile: usize, ys: &mut [f32]) {
    let mut lut = [0.0f32; 16];
    let mut los = [_mm256_setzero_ps(); 4];
    let mut his = [_mm256_setzero_ps(); 4];
    ys.fill(0.0);
    for gi in 0..t.ngroups {
        let gbase = (tile * t.ngroups + gi) * 4;
        for rr in 0..4 {
            fill_lut(4, t.scales[gbase + rr], t.zeros[gbase + rr], &mut lut);
            los[rr] = _mm256_loadu_ps(lut.as_ptr());
            his[rr] = _mm256_loadu_ps(lut.as_ptr().add(8));
        }
        let mut accs = [_mm256_setzero_ps(); 4];
        for wi in 0..t.wpg {
            let wbase = (tile * t.nwords + gi * t.wpg + wi) * 4;
            let xv = _mm256_loadu_ps(xeff.as_ptr().add((gi * t.wpg + wi) * 8));
            for rr in 0..4 {
                let w = t.words[wbase + rr];
                accs[rr] = _mm256_fmadd_ps(dequant8_b4(w, los[rr], his[rr]), xv, accs[rr]);
            }
        }
        for (rr, yv) in ys.iter_mut().enumerate() {
            *yv += hsum8(accs[rr]);
        }
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn tiled_b3(t: &TiledPacked, xeff: &[f32], tile: usize, ys: &mut [f32]) {
    let mut luts = [[0.0f32; 8]; 4];
    let mut ls = [_mm256_setzero_ps(); 4];
    ys.fill(0.0);
    for gi in 0..t.ngroups {
        let gbase = (tile * t.ngroups + gi) * 4;
        for rr in 0..4 {
            fill_lut(3, t.scales[gbase + rr], t.zeros[gbase + rr], &mut luts[rr]);
            ls[rr] = _mm256_loadu_ps(luts[rr].as_ptr());
        }
        let mut accs = [_mm256_setzero_ps(); 4];
        let mut taccs = [0.0f32; 4];
        for wi in 0..t.wpg {
            let wbase = (tile * t.nwords + gi * t.wpg + wi) * 4;
            let off = (gi * t.wpg + wi) * 10;
            let xv = _mm256_loadu_ps(xeff.as_ptr().add(off));
            let x8 = xeff[off + 8];
            let x9 = xeff[off + 9];
            for rr in 0..4 {
                let w = t.words[wbase + rr];
                accs[rr] = _mm256_fmadd_ps(dequant8_b3(w, ls[rr]), xv, accs[rr]);
                taccs[rr] += luts[rr][((w >> 24) & 7) as usize] * x8;
                taccs[rr] += luts[rr][((w >> 27) & 7) as usize] * x9;
            }
        }
        for (rr, yv) in ys.iter_mut().enumerate() {
            *yv += hsum8(accs[rr]) + taccs[rr];
        }
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn tiled_b2(t: &TiledPacked, xeff: &[f32], tile: usize, ys: &mut [f32]) {
    let mut lut = [0.0f32; 8];
    let mut ls = [_mm256_setzero_ps(); 4];
    ys.fill(0.0);
    for gi in 0..t.ngroups {
        let gbase = (tile * t.ngroups + gi) * 4;
        for rr in 0..4 {
            fill_lut(2, t.scales[gbase + rr], t.zeros[gbase + rr], &mut lut);
            ls[rr] = _mm256_loadu_ps(lut.as_ptr());
        }
        let mut accs0 = [_mm256_setzero_ps(); 4];
        let mut accs1 = [_mm256_setzero_ps(); 4];
        for wi in 0..t.wpg {
            let wbase = (tile * t.nwords + gi * t.wpg + wi) * 4;
            let off = (gi * t.wpg + wi) * 16;
            let xv0 = _mm256_loadu_ps(xeff.as_ptr().add(off));
            let xv1 = _mm256_loadu_ps(xeff.as_ptr().add(off + 8));
            for rr in 0..4 {
                let (d0, d1) = dequant16_b2(t.words[wbase + rr], ls[rr]);
                accs0[rr] = _mm256_fmadd_ps(d0, xv0, accs0[rr]);
                accs1[rr] = _mm256_fmadd_ps(d1, xv1, accs1[rr]);
            }
        }
        for (rr, yv) in ys.iter_mut().enumerate() {
            *yv += hsum8(_mm256_add_ps(accs0[rr], accs1[rr]));
        }
    }
}

// -------------------------------------------------------------------------
// 2:4 sparse kernels (4-bit): one pair-code word = 8 surviving codes = 4
// blocks. The index nibbles steer a scalar gather of the 8 surviving x
// values into a stack buffer; the codes dequantize through the same
// (lo, hi) vpermps LUT as the dense b4 kernels. Half the FMAs of dense,
// and 12 bits of weight traffic per 4 columns instead of 16.
// -------------------------------------------------------------------------

#[target_feature(enable = "avx2", enable = "fma")]
pub(crate) unsafe fn sparse24_tiled_rows_b4(
    t: &Sparse24Tiled,
    x: &[f32],
    tile: usize,
    ys: &mut [f32],
) {
    debug_assert_eq!(t.bits, 4, "AVX2 sparse24 kernel is 4-bit only");
    debug_assert_eq!(t.r, 4, "AVX2 tiled kernels assume R=4");
    let group = t.dcol / t.ngroups;
    let nblocks = group / 4;
    let nfull = nblocks / 4; // fully-populated pair words (8 codes each)
    let mut luts = [[0.0f32; 16]; 4];
    let mut los = [_mm256_setzero_ps(); 4];
    let mut his = [_mm256_setzero_ps(); 4];
    let mut xbuf = [0.0f32; 8];
    ys.fill(0.0);
    for gi in 0..t.ngroups {
        let gbase = (tile * t.ngroups + gi) * 4;
        for rr in 0..4 {
            fill_lut(4, t.scales[gbase + rr], t.zeros[gbase + rr], &mut luts[rr]);
            los[rr] = _mm256_loadu_ps(luts[rr].as_ptr());
            his[rr] = _mm256_loadu_ps(luts[rr].as_ptr().add(8));
        }
        let xg = &x[gi * group..];
        let mut accs = [_mm256_setzero_ps(); 4];
        let mut taccs = [0.0f32; 4];
        for wi in 0..nfull {
            let wbase = (tile * t.npw + gi * t.pair_wpg + wi) * 4;
            // 4 blocks per word -> 4 nibbles, a contiguous 16-bit field
            let ibase = (tile * t.niw + gi * t.idx_wpg + wi / 2) * 4;
            for rr in 0..4 {
                let w = t.pair_words[wbase + rr];
                let nib16 = (t.idx_words[ibase + rr] >> ((wi % 2) * 16)) & 0xFFFF;
                for bb in 0..4 {
                    let nib = (nib16 >> (bb * 4)) & 0xF;
                    let base = (wi * 4 + bb) * 4;
                    xbuf[2 * bb] = xg[base + (nib & 3) as usize];
                    xbuf[2 * bb + 1] = xg[base + ((nib >> 2) & 3) as usize];
                }
                accs[rr] = _mm256_fmadd_ps(
                    dequant8_b4(w, los[rr], his[rr]),
                    _mm256_loadu_ps(xbuf.as_ptr()),
                    accs[rr],
                );
            }
        }
        // tail blocks of a partial last word (group % 16 != 0): scalar
        // through the same LUT arrays
        for b in nfull * 4..nblocks {
            let k = 2 * b;
            let wbase = (tile * t.npw + gi * t.pair_wpg + k / 8) * 4;
            let ibase = (tile * t.niw + gi * t.idx_wpg + b / 8) * 4;
            for rr in 0..4 {
                let w = t.pair_words[wbase + rr];
                let nib = (t.idx_words[ibase + rr] >> ((b % 8) * 4)) & 0xF;
                let c0 = ((w >> ((k % 8) * 4)) & 15) as usize;
                let c1 = ((w >> (((k + 1) % 8) * 4)) & 15) as usize;
                taccs[rr] += luts[rr][c0] * xg[b * 4 + (nib & 3) as usize];
                taccs[rr] += luts[rr][c1] * xg[b * 4 + ((nib >> 2) & 3) as usize];
            }
        }
        for (rr, yv) in ys.iter_mut().enumerate() {
            *yv += hsum8(accs[rr]) + taccs[rr];
        }
    }
}

/// Flat 2:4 rows (single sequence). Per-group op order is replayed
/// exactly by the batched kernel below (per sequence) and the tiled
/// kernel above (per row), so all three agree bitwise on this ISA.
#[target_feature(enable = "avx2", enable = "fma")]
pub(crate) unsafe fn sparse24_rows_b4(
    m: &Sparse24Matrix,
    x: &[f32],
    row0: usize,
    y: &mut [f32],
) {
    debug_assert_eq!(m.bits, 4, "AVX2 sparse24 kernel is 4-bit only");
    let group = m.dcol / m.ngroups;
    let nblocks = group / 4;
    let nfull = nblocks / 4;
    let (npw, niw) = (m.npair_words(), m.nidx_words());
    let mut lut = [0.0f32; 16];
    let mut xbuf = [0.0f32; 8];
    for (i, yr) in y.iter_mut().enumerate() {
        let r = row0 + i;
        let scales = &m.scales[r * m.ngroups..(r + 1) * m.ngroups];
        let zeros = &m.zeros[r * m.ngroups..(r + 1) * m.ngroups];
        let mut acc_row = 0.0f32;
        for gi in 0..m.ngroups {
            fill_lut(4, scales[gi], zeros[gi], &mut lut);
            let lo = _mm256_loadu_ps(lut.as_ptr());
            let hi = _mm256_loadu_ps(lut.as_ptr().add(8));
            let pw = &m.pair_words[r * npw + gi * m.pair_wpg..];
            let iw = &m.idx_words[r * niw + gi * m.idx_wpg..];
            let xg = &x[gi * group..];
            let mut acc = _mm256_setzero_ps();
            let mut tacc = 0.0f32;
            for wi in 0..nfull {
                let w = pw[wi];
                let nib16 = (iw[wi / 2] >> ((wi % 2) * 16)) & 0xFFFF;
                for bb in 0..4 {
                    let nib = (nib16 >> (bb * 4)) & 0xF;
                    let base = (wi * 4 + bb) * 4;
                    xbuf[2 * bb] = xg[base + (nib & 3) as usize];
                    xbuf[2 * bb + 1] = xg[base + ((nib >> 2) & 3) as usize];
                }
                acc = _mm256_fmadd_ps(dequant8_b4(w, lo, hi), _mm256_loadu_ps(xbuf.as_ptr()), acc);
            }
            for b in nfull * 4..nblocks {
                let k = 2 * b;
                let w = pw[k / 8];
                let nib = (iw[b / 8] >> ((b % 8) * 4)) & 0xF;
                tacc += lut[((w >> ((k % 8) * 4)) & 15) as usize] * xg[b * 4 + (nib & 3) as usize];
                tacc += lut[((w >> (((k + 1) % 8) * 4)) & 15) as usize]
                    * xg[b * 4 + ((nib >> 2) & 3) as usize];
            }
            acc_row += hsum8(acc) + tacc;
        }
        *yr = acc_row;
    }
}

/// Batched 2:4 rows: each pair word is decoded ONCE (and its gather
/// columns computed once) and FMA'd into every sequence's accumulator.
/// Per-sequence op order replays [`sparse24_rows_b4`] exactly.
#[target_feature(enable = "avx2", enable = "fma")]
pub(crate) unsafe fn sparse24_matmul_rows_b4(
    m: &Sparse24Matrix,
    xs: &[f32],
    n: usize,
    row0: usize,
    ys: &mut [f32],
) {
    debug_assert_eq!(m.bits, 4, "AVX2 sparse24 kernel is 4-bit only");
    let group = m.dcol / m.ngroups;
    let nblocks = group / 4;
    let nfull = nblocks / 4;
    let (npw, niw) = (m.npair_words(), m.nidx_words());
    let mut lut = [0.0f32; 16];
    let mut xbuf = [0.0f32; 8];
    let mut cols = [0usize; 8];
    let mut accs: Vec<__m256> = vec![_mm256_setzero_ps(); n];
    let mut taccs = vec![0.0f32; n];
    for (i, yrow) in ys.chunks_exact_mut(n).enumerate() {
        let r = row0 + i;
        let scales = &m.scales[r * m.ngroups..(r + 1) * m.ngroups];
        let zeros = &m.zeros[r * m.ngroups..(r + 1) * m.ngroups];
        yrow.fill(0.0);
        for gi in 0..m.ngroups {
            fill_lut(4, scales[gi], zeros[gi], &mut lut);
            let lo = _mm256_loadu_ps(lut.as_ptr());
            let hi = _mm256_loadu_ps(lut.as_ptr().add(8));
            let pw = &m.pair_words[r * npw + gi * m.pair_wpg..];
            let iw = &m.idx_words[r * niw + gi * m.idx_wpg..];
            for a in accs.iter_mut() {
                *a = _mm256_setzero_ps();
            }
            taccs.fill(0.0);
            for wi in 0..nfull {
                let w = pw[wi];
                let nib16 = (iw[wi / 2] >> ((wi % 2) * 16)) & 0xFFFF;
                for bb in 0..4 {
                    let nib = (nib16 >> (bb * 4)) & 0xF;
                    let base = gi * group + (wi * 4 + bb) * 4;
                    cols[2 * bb] = base + (nib & 3) as usize;
                    cols[2 * bb + 1] = base + ((nib >> 2) & 3) as usize;
                }
                let deq = dequant8_b4(w, lo, hi);
                for (j, a) in accs.iter_mut().enumerate() {
                    let xrow = &xs[j * m.dcol..];
                    for (slot, &c) in xbuf.iter_mut().zip(cols.iter()) {
                        *slot = xrow[c];
                    }
                    *a = _mm256_fmadd_ps(deq, _mm256_loadu_ps(xbuf.as_ptr()), *a);
                }
            }
            for b in nfull * 4..nblocks {
                let k = 2 * b;
                let w = pw[k / 8];
                let nib = (iw[b / 8] >> ((b % 8) * 4)) & 0xF;
                let l0 = lut[((w >> ((k % 8) * 4)) & 15) as usize];
                let l1 = lut[((w >> (((k + 1) % 8) * 4)) & 15) as usize];
                let col0 = gi * group + b * 4 + (nib & 3) as usize;
                let col1 = gi * group + b * 4 + ((nib >> 2) & 3) as usize;
                for (j, ta) in taccs.iter_mut().enumerate() {
                    *ta += l0 * xs[j * m.dcol + col0];
                    *ta += l1 * xs[j * m.dcol + col1];
                }
            }
            for (j, yv) in yrow.iter_mut().enumerate() {
                *yv += hsum8(accs[j]) + taccs[j];
            }
        }
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn tiled_b8(t: &TiledPacked, xeff: &[f32], tile: usize, ys: &mut [f32]) {
    ys.fill(0.0);
    for gi in 0..t.ngroups {
        let gbase = (tile * t.ngroups + gi) * 4;
        let mut svec = [_mm_setzero_ps(); 4];
        let mut nszvec = [_mm_setzero_ps(); 4];
        for rr in 0..4 {
            let s = t.scales[gbase + rr];
            svec[rr] = _mm_set1_ps(s);
            nszvec[rr] = _mm_set1_ps(-(s * t.zeros[gbase + rr]));
        }
        let mut accs = [_mm_setzero_ps(); 4];
        for wi in 0..t.wpg {
            let wbase = (tile * t.nwords + gi * t.wpg + wi) * 4;
            let xv = _mm_loadu_ps(xeff.as_ptr().add((gi * t.wpg + wi) * 4));
            for rr in 0..4 {
                let w = t.words[wbase + rr];
                accs[rr] = _mm_fmadd_ps(dequant4_b8(w, svec[rr], nszvec[rr]), xv, accs[rr]);
            }
        }
        for (rr, yv) in ys.iter_mut().enumerate() {
            *yv += hsum4(accs[rr]);
        }
    }
}
