//! [`TiledPacked`] — a register-tiled, row-interleaved packed layout.
//!
//! The plain `PackedMatrix` streams one row's words at a time: at batch 1
//! every element of `x` is re-loaded for every row. The tiled layout
//! interleaves the words of R=4 consecutive rows word-index-major
//! (`words[(tile·nwords + wi)·R + rr]`), so the SIMD matvec loads each
//! 8-lane chunk of `x` ONCE and FMAs it into R row accumulators while the
//! R weight words stream from one contiguous cache line — the
//! register-tiling of the paper's fused dequant kernels, applied to the
//! batch-1 decode path (the per-token latency path of Table 5).
//!
//! Built once at pack/load time next to the `PackedMatrix`
//! (`model::forward::PackedLinear`), only when the active ISA has a tiled
//! microkernel for the bit width (`kernels::tiled_supported`) — it is a
//! second copy of the weights, so scalar-only deployments skip it.
//!
//! The last tile is zero-padded to R rows (code 0, scale 0 → every padded
//! lane dequantizes to 0); kernels simply don't write the phantom rows.

use crate::quant::pack::PackedMatrix;

/// Rows per tile. 4 keeps the working set at R accumulator vectors plus
/// R LUT registers on both AVX2 (16 ymm) and NEON (32 q-regs).
pub const TILE_ROWS: usize = 4;

/// The interleaved tiled form of a `PackedMatrix` (same codes, scales,
/// zeros — only the memory order changes, so dequant semantics and the
/// quantization format are untouched).
#[derive(Debug, Clone)]
pub struct TiledPacked {
    /// words, tile-major: `words[(tile * nwords + wi) * r + rr]` is word
    /// `wi` of row `tile * r + rr`
    pub words: Vec<u32>,
    /// scales, tile-major: `scales[(tile * ngroups + gi) * r + rr]`
    pub scales: Vec<f32>,
    pub zeros: Vec<f32>,
    /// rows per tile (R)
    pub r: usize,
    /// number of tiles (`ceil(drow / r)`; last tile zero-padded)
    pub ntiles: usize,
    pub drow: usize,
    pub dcol: usize,
    /// words per row (same as the source `PackedMatrix`)
    pub nwords: usize,
    pub ngroups: usize,
    /// words per group (`nwords / ngroups`)
    pub wpg: usize,
    pub bits: u32,
}

impl TiledPacked {
    /// Interleave `p` into R-row tiles. Returns `None` for layouts the
    /// aligned kernels can't walk in whole words — the SAME predicate
    /// (`kernels::packed_aligned`) the flat matvec uses for its fast
    /// path, so tiled and flat always route a shape the same way; those
    /// shapes stay on the general packed path.
    pub fn from_packed(p: &PackedMatrix) -> Option<TiledPacked> {
        if !matches!(p.bits, 2 | 3 | 4 | 8) || !super::packed_aligned(p) {
            return None;
        }
        let r = TILE_ROWS;
        let ntiles = p.drow.div_ceil(r);
        let mut words = vec![0u32; ntiles * p.nwords * r];
        let mut scales = vec![0.0f32; ntiles * p.ngroups * r];
        let mut zeros = vec![0.0f32; ntiles * p.ngroups * r];
        for t in 0..ntiles {
            for rr in 0..r {
                let row = t * r + rr;
                if row >= p.drow {
                    break; // phantom rows stay all-zero
                }
                for wi in 0..p.nwords {
                    words[(t * p.nwords + wi) * r + rr] = p.words[row * p.nwords + wi];
                }
                for gi in 0..p.ngroups {
                    scales[(t * p.ngroups + gi) * r + rr] = p.scales[row * p.ngroups + gi];
                    zeros[(t * p.ngroups + gi) * r + rr] = p.zeros[row * p.ngroups + gi];
                }
            }
        }
        Some(TiledPacked {
            words,
            scales,
            zeros,
            r,
            ntiles,
            drow: p.drow,
            dcol: p.dcol,
            nwords: p.nwords,
            ngroups: p.ngroups,
            wpg: p.nwords / p.ngroups,
            bits: p.bits,
        })
    }

    /// Bytes of weight storage in this layout (the traffic one tiled
    /// matvec streams — same accounting as `PackedMatrix::storage_bytes`,
    /// plus the zero padding of the last tile).
    pub fn storage_bytes(&self) -> usize {
        self.words.len() * 4 + (self.scales.len() + self.zeros.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::rand_vec;
    use crate::quant::rtn_quantize;

    #[test]
    fn interleave_roundtrips_words_and_grids() {
        // drow 10 = 2 full tiles + a ragged one (2 real rows)
        let (drow, dcol) = (10usize, 64usize);
        let w = rand_vec(drow * dcol, 3);
        let q = rtn_quantize(&w, drow, dcol, 4, 16);
        let p = PackedMatrix::from_result(&q);
        let t = TiledPacked::from_packed(&p).expect("aligned shape tiles");
        assert_eq!(t.ntiles, 3);
        assert_eq!(t.wpg, p.nwords / p.ngroups);
        for row in 0..drow {
            let (tile, rr) = (row / t.r, row % t.r);
            for wi in 0..p.nwords {
                assert_eq!(t.words[(tile * t.nwords + wi) * t.r + rr], p.words[row * p.nwords + wi]);
            }
            for gi in 0..p.ngroups {
                assert_eq!(t.scales[(tile * t.ngroups + gi) * t.r + rr], p.scales[row * p.ngroups + gi]);
                assert_eq!(t.zeros[(tile * t.ngroups + gi) * t.r + rr], p.zeros[row * p.ngroups + gi]);
            }
        }
        // phantom rows of the last tile dequantize to zero
        for wi in 0..p.nwords {
            for rr in 2..t.r {
                assert_eq!(t.words[(2 * t.nwords + wi) * t.r + rr], 0);
            }
        }
        for gi in 0..p.ngroups {
            for rr in 2..t.r {
                assert_eq!(t.scales[(2 * t.ngroups + gi) * t.r + rr], 0.0);
            }
        }
    }

    #[test]
    fn ragged_layouts_do_not_tile() {
        // dcol 37 with 3-bit (10/word) leaves a ragged last word per group
        let w = rand_vec(4 * 37, 5);
        let q = rtn_quantize(&w, 4, 37, 3, 0);
        let p = PackedMatrix::from_result(&q);
        // ngroups == 1 ragged shapes DO tile (x is padded like the aligned
        // matvec path) …
        assert!(TiledPacked::from_packed(&p).is_some());
        // … but grouped-with-ragged-words shapes do not
        let w2 = rand_vec(4 * 48, 6);
        let q2 = rtn_quantize(&w2, 4, 48, 3, 16); // 16 % 10 != 0
        let p2 = PackedMatrix::from_result(&q2);
        assert!(TiledPacked::from_packed(&p2).is_none());
    }
}
