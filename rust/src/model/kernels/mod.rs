//! SIMD kernel subsystem: runtime-dispatched fused-dequant microkernels.
//!
//! The paper's end-to-end inference wins (§Practical Speedups, 3.25–4.5×
//! over FP16) come from fused dequantize-and-multiply kernels that read
//! the packed weights once and decode them in registers. This module is
//! the CPU analog: explicit SIMD microkernels behind *runtime* ISA
//! dispatch, so one portable binary (no `-C target-cpu` required) picks
//! the fastest kernel the hardware supports at startup.
//!
//! Structure:
//! * [`Isa`] — the dispatch key: `Scalar` (the pre-SIMD code paths,
//!   bit-exact with history), `Avx2Fma` (`std::arch::x86_64`, selected
//!   when `is_x86_feature_detected!("avx2"/"fma")`), `Neon`
//!   (`std::arch::aarch64`).
//! * [`scalar`] — the portable kernels, moved verbatim from
//!   `model::matvec` so `GPTQ_ISA=scalar` reproduces today's bit-exact
//!   arithmetic on the aligned fast paths.
//! * [`avx2`] / [`neon`] — the SIMD microkernels. Packed weights are
//!   dequantized through a per-group 2^bits-entry LUT
//!   (`scale * (code − zero)`) instead of per-element shift/mask/scale
//!   arithmetic; on AVX2 the LUT lookup is one or two `vpermps`.
//! * [`tiled`] — [`TiledPacked`], a register-tiled interleaved layout
//!   (row tiles of R=4) built once at pack/load time next to
//!   `PackedMatrix`, so one SIMD load of `x` feeds R row accumulators.
//!
//! §Determinism contract (DESIGN.md §Kernels): for any FIXED ISA, lane
//! order inside every kernel is fixed and per-row arithmetic is
//! independent of the thread partition, so `threads=N` stays bit-identical
//! to `threads=1`. Only changing the ISA may shift results, and then only
//! within ~1e-5 elementwise (each ISA computes the same dequant values in
//! a different association order).
//!
//! Selection: once at startup from, in priority order, the last
//! [`set_isa`]/[`set_isa_name`] call (the `--isa` CLI flag), the
//! `GPTQ_ISA` env var, else auto-detection ([`detect_best`]). A requested
//! ISA the hardware lacks clamps to `Scalar` (never UB: the
//! `#[target_feature]` kernels are only entered for detected features).

pub mod scalar;
pub mod sparse24;
pub mod tiled;

#[cfg(target_arch = "x86_64")]
pub mod avx2;

#[cfg(target_arch = "aarch64")]
pub mod neon;

pub use sparse24::Sparse24Tiled;
pub use tiled::TiledPacked;

use crate::quant::pack::PackedMatrix;
use crate::quant::sparse::Sparse24Matrix;
use std::sync::atomic::{AtomicUsize, Ordering};

/// The runtime-dispatch key. Every kernel family (dense matvec/matmul,
/// packed matvec/matmul, tiled matvec) has an implementation per variant;
/// unsupported (isa, bits) combinations fall back to [`Isa::Scalar`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// The portable kernels — the pre-SIMD code paths, bit-exact on the
    /// aligned layouts real layer shapes hit (the ragged fallback now
    /// shares the LUT dequant; see the module docs).
    Scalar,
    /// AVX2 + FMA (x86_64), 8-lane f32 vectors.
    Avx2Fma,
    /// NEON (aarch64), 4-lane f32 vectors.
    Neon,
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2Fma => "avx2",
            Isa::Neon => "neon",
        }
    }

    fn code(self) -> usize {
        match self {
            Isa::Scalar => 0,
            Isa::Avx2Fma => 1,
            Isa::Neon => 2,
        }
    }

    fn from_code(c: usize) -> Isa {
        match c {
            1 => Isa::Avx2Fma,
            2 => Isa::Neon,
            _ => Isa::Scalar,
        }
    }
}

impl std::fmt::Display for Isa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_fma_detected() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_fma_detected() -> bool {
    false
}

#[cfg(target_arch = "aarch64")]
fn neon_detected() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

#[cfg(not(target_arch = "aarch64"))]
fn neon_detected() -> bool {
    false
}

/// Is `isa` executable on this machine? (`Scalar` always is.)
pub fn supported(isa: Isa) -> bool {
    match isa {
        Isa::Scalar => true,
        Isa::Avx2Fma => avx2_fma_detected(),
        Isa::Neon => neon_detected(),
    }
}

/// The best ISA this machine supports (the `GPTQ_ISA=auto` choice).
pub fn detect_best() -> Isa {
    if supported(Isa::Avx2Fma) {
        return Isa::Avx2Fma;
    }
    if supported(Isa::Neon) {
        return Isa::Neon;
    }
    Isa::Scalar
}

/// Every ISA runnable on this machine, `Scalar` first — what the parity
/// tests and the kernel-sweep bench iterate over.
pub fn available() -> Vec<Isa> {
    let mut out = vec![Isa::Scalar];
    for isa in [Isa::Avx2Fma, Isa::Neon] {
        if supported(isa) {
            out.push(isa);
        }
    }
    out
}

/// Clamp to something runnable: an unsupported request degrades to
/// `Scalar` (the dispatch entry points call this, which is what keeps the
/// `#[target_feature]` kernels sound even if a caller hands us a foreign
/// [`Isa`] value).
pub fn clamp(isa: Isa) -> Isa {
    if supported(isa) {
        isa
    } else {
        Isa::Scalar
    }
}

const UNSET: usize = usize::MAX;
static GLOBAL_ISA: AtomicUsize = AtomicUsize::new(UNSET);

/// [`clamp`] plus the one warning policy for explicit requests (`--isa`,
/// `GPTQ_ISA`): serving at silent-scalar throughput while the operator
/// believes SIMD is pinned is worse than a stderr line.
fn clamp_or_warn(requested: Isa) -> Isa {
    let resolved = clamp(requested);
    if resolved != requested {
        eprintln!("isa {requested} not supported on this machine; falling back to {resolved}");
    }
    resolved
}

fn env_isa() -> Isa {
    match std::env::var("GPTQ_ISA") {
        Ok(v) => match parse_isa(v.trim()) {
            Ok(Some(requested)) => clamp_or_warn(requested),
            Ok(None) => detect_best(),
            Err(_) => {
                eprintln!("GPTQ_ISA={v:?} not recognized (auto|scalar|avx2|neon); using auto");
                detect_best()
            }
        },
        Err(_) => detect_best(),
    }
}

/// Parse an ISA name. `Ok(None)` means `auto`.
pub fn parse_isa(name: &str) -> crate::Result<Option<Isa>> {
    Ok(match name {
        "auto" => None,
        "scalar" => Some(Isa::Scalar),
        "avx2" | "avx2fma" | "avx2-fma" => Some(Isa::Avx2Fma),
        "neon" => Some(Isa::Neon),
        other => anyhow::bail!("unknown ISA {other:?} (auto|scalar|avx2|neon)"),
    })
}

/// The process-wide kernel ISA (lazily initialised from `GPTQ_ISA`,
/// default auto-detect).
pub fn isa() -> Isa {
    let c = GLOBAL_ISA.load(Ordering::Relaxed);
    if c != UNSET {
        return Isa::from_code(c);
    }
    let resolved = env_isa();
    GLOBAL_ISA.store(resolved.code(), Ordering::Relaxed);
    resolved
}

/// Override the process-wide ISA (clamped to what the hardware supports,
/// with the shared [`clamp_or_warn`] warning on downgrade); returns the
/// ISA actually installed.
pub fn set_isa(requested: Isa) -> Isa {
    let resolved = clamp_or_warn(requested);
    GLOBAL_ISA.store(resolved.code(), Ordering::Relaxed);
    resolved
}

/// [`set_isa`] from a CLI name (`--isa`); `"auto"` re-runs detection.
pub fn set_isa_name(name: &str) -> crate::Result<Isa> {
    Ok(match parse_isa(name)? {
        Some(requested) => set_isa(requested),
        None => set_isa(detect_best()),
    })
}

/// Reset the process-wide ISA to the `GPTQ_ISA` default (used by benches
/// and tests that temporarily pin it).
pub fn set_isa_env() {
    GLOBAL_ISA.store(env_isa().code(), Ordering::Relaxed);
}

/// Does `isa` have a tiled-layout kernel for this bit width? Gates both
/// building [`TiledPacked`] at load time and entering the tiled matvec.
pub fn tiled_supported(isa: Isa, bits: u32) -> bool {
    match isa {
        Isa::Scalar => false,
        Isa::Avx2Fma => matches!(bits, 2 | 3 | 4 | 8),
        Isa::Neon => bits == 4,
    }
}

/// Does `isa` have a 2:4-sparse tiled microkernel for this bit width?
/// Gates building [`Sparse24Tiled`] at load time and entering the sparse
/// tiled matvec (the scalar ISA runs the flat sparse kernels directly).
pub fn sparse24_tiled_supported(isa: Isa, bits: u32) -> bool {
    match isa {
        Isa::Scalar => false,
        Isa::Avx2Fma | Isa::Neon => bits == 4,
    }
}

/// The aligned-layout predicate — THE single definition shared by the
/// flat packed entry points (`model::matvec`) and the tiled builder
/// ([`TiledPacked::from_packed`]), so both always route a given shape the
/// same way (the tiled≡flat bitwise guarantee depends on it): either one
/// grid per row (pad `x` so the ragged last word multiplies zeros —
/// packed pad fields are 0 by construction), or grouped with whole-word
/// groups (then dcol is word-aligned too). Real layer shapes always land
/// aligned; odd shapes use the general path.
pub fn packed_aligned(p: &PackedMatrix) -> bool {
    if p.ngroups == 0 {
        return false;
    }
    let cpw = (32 / p.bits) as usize;
    let group = p.dcol / p.ngroups;
    p.ngroups == 1 || (group % cpw == 0 && p.nwords * cpw == p.dcol)
}

/// Build the per-group dequant LUT `lut[code] = scale * (code − zero)` —
/// the §Practical-Speedups trick of decoding through a table instead of
/// per-element scale arithmetic. `lut.len()` must be ≥ `1 << bits`.
#[inline]
pub(crate) fn fill_lut(bits: u32, s: f32, z: f32, lut: &mut [f32]) {
    for (k, slot) in lut.iter_mut().enumerate().take(1usize << bits) {
        *slot = s * (k as f32 - z);
    }
}

// ---------------------------------------------------------------------------
// Dispatch table: row-range kernels. `model::matvec` owns the public API
// (argument checks, thread partitioning, Σx / padding precomputes) and
// funnels every row range through these. All `isa` arguments are expected
// pre-clamped (see `clamp`); unsupported (isa, bits) pairs fall back to
// the scalar kernel, never to UB.
// ---------------------------------------------------------------------------

/// Rows `row0..row0+y.len()` of y = W x (dense).
pub(crate) fn f32_rows(isa: Isa, w: &[f32], x: &[f32], dcol: usize, row0: usize, y: &mut [f32]) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { avx2::f32_rows(w, x, dcol, row0, y) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::f32_rows(w, x, dcol, row0, y) },
        _ => scalar::f32_rows(w, x, dcol, row0, y),
    }
}

/// Rows `row0..` of the batched dense Y = W·X (`ys` row-major rows × n).
/// Per (row, sequence) arithmetic is the same dot as [`f32_rows`] on every
/// ISA — the batched/single bit-parity contract.
pub(crate) fn f32_matmul_rows(
    isa: Isa,
    w: &[f32],
    xs: &[f32],
    dcol: usize,
    n: usize,
    row0: usize,
    ys: &mut [f32],
) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { avx2::f32_matmul_rows(w, xs, dcol, n, row0, ys) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::f32_matmul_rows(w, xs, dcol, n, row0, ys) },
        _ => scalar::f32_matmul_rows(w, xs, dcol, n, row0, ys),
    }
}

/// Will the aligned packed dispatch for (isa, bits) land on the scalar
/// factored kernel, which needs the per-group Σx precompute? MUST mirror
/// the match arms of [`packed_rows_aligned`] / [`packed_matmul_rows_aligned`]
/// exactly — `model::matvec` uses it to skip computing Σx when a SIMD LUT
/// kernel (which bakes scale/zero into the table) will run; the scalar
/// kernels debug-assert the Σx length so any drift fails tests loudly
/// instead of reading out of bounds.
pub(crate) fn packed_aligned_uses_xsum(isa: Isa, bits: u32) -> bool {
    let _ = bits; // only consulted on aarch64
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => false,
        #[cfg(target_arch = "aarch64")]
        Isa::Neon if bits == 4 => false,
        _ => true,
    }
}

/// Aligned packed rows: `xeff` is `x` padded to `nwords·cpw`, `xsum` the
/// per-group Σx (used by the scalar kernel's factored form; the SIMD LUT
/// kernels bake scale/zero into the table and ignore it — callers may
/// pass it empty when [`packed_aligned_uses_xsum`] says so).
pub(crate) fn packed_rows_aligned(
    isa: Isa,
    p: &PackedMatrix,
    xeff: &[f32],
    xsum: &[f32],
    wpg: usize,
    row0: usize,
    y: &mut [f32],
) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { avx2::packed_rows_aligned(p, xeff, wpg, row0, y) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon if p.bits == 4 => unsafe { neon::packed_rows_aligned_b4(p, xeff, wpg, row0, y) },
        _ => scalar::packed_rows_aligned(p, xeff, xsum, wpg, row0, y),
    }
}

/// General (ragged) packed rows — scalar on every ISA (only odd test
/// shapes land here; real layer shapes hit the aligned path).
pub(crate) fn packed_rows_general(
    p: &PackedMatrix,
    x: &[f32],
    group: usize,
    row0: usize,
    y: &mut [f32],
) {
    scalar::packed_rows_general(p, x, group, row0, y);
}

/// Aligned batched packed rows: each u32 word is decoded ONCE and FMA'd
/// into every sequence's accumulators (the continuous-batching kernel).
#[allow(clippy::too_many_arguments)]
pub(crate) fn packed_matmul_rows_aligned(
    isa: Isa,
    p: &PackedMatrix,
    xeffs: &[f32],
    xsums: &[f32],
    wpg: usize,
    n: usize,
    row0: usize,
    ys: &mut [f32],
) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { avx2::packed_matmul_rows_aligned(p, xeffs, wpg, n, row0, ys) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon if p.bits == 4 => unsafe {
            neon::packed_matmul_rows_aligned_b4(p, xeffs, wpg, n, row0, ys)
        },
        _ => scalar::packed_matmul_rows_aligned(p, xeffs, xsums, wpg, n, row0, ys),
    }
}

/// General (ragged) batched packed rows — scalar on every ISA, with the
/// per-row group grids hoisted out of the per-sequence loop.
pub(crate) fn packed_matmul_rows_general(
    p: &PackedMatrix,
    xs: &[f32],
    group: usize,
    n: usize,
    row0: usize,
    ys: &mut [f32],
) {
    scalar::packed_matmul_rows_general(p, xs, group, n, row0, ys);
}

/// One tile (rows `tile·R..tile·R+ys.len()`) of y = dequant(T) x over the
/// interleaved tiled layout. `xeff` is padded like the aligned path.
pub(crate) fn tiled_rows(isa: Isa, t: &TiledPacked, xeff: &[f32], tile: usize, ys: &mut [f32]) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { avx2::tiled_rows(t, xeff, tile, ys) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon if t.bits == 4 => unsafe { neon::tiled_rows_b4(t, xeff, tile, ys) },
        _ => scalar::tiled_rows(t, xeff, tile, ys),
    }
}

/// Rows of y = dequant(M)·x over the 2:4 sparse layout. The scalar
/// kernel is the bit-frozen sparse reference; AVX2 has a 4-bit fast path
/// whose op order the batched and tiled AVX2 kernels replay. NEON runs
/// scalar here (its only sparse microkernel is the tiled one, which
/// therefore agrees with this path within the cross-ISA ~1e-5 band
/// rather than bitwise).
pub(crate) fn sparse24_rows(isa: Isa, m: &Sparse24Matrix, x: &[f32], row0: usize, y: &mut [f32]) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma if m.bits == 4 => unsafe { avx2::sparse24_rows_b4(m, x, row0, y) },
        _ => sparse24::rows(m, x, row0, y),
    }
}

/// Batched 2:4 sparse rows: each pair word decoded once per row and
/// replayed across the batch. AVX2 has a 4-bit fast path; NEON stays on
/// the scalar kernel (the batched path is bandwidth-bound and the sparse
/// format already halves traffic).
pub(crate) fn sparse24_matmul_rows(
    isa: Isa,
    m: &Sparse24Matrix,
    xs: &[f32],
    n: usize,
    row0: usize,
    ys: &mut [f32],
) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma if m.bits == 4 => unsafe { avx2::sparse24_matmul_rows_b4(m, xs, n, row0, ys) },
        _ => sparse24::matmul_rows(m, xs, n, row0, ys),
    }
}

/// One tile of y = dequant(T)·x over the interleaved 2:4 sparse layout.
pub(crate) fn sparse24_tiled_rows(
    isa: Isa,
    t: &Sparse24Tiled,
    x: &[f32],
    tile: usize,
    ys: &mut [f32],
) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma if t.bits == 4 => unsafe { avx2::sparse24_tiled_rows_b4(t, x, tile, ys) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon if t.bits == 4 => unsafe { neon::sparse24_tiled_rows_b4(t, x, tile, ys) },
        _ => sparse24::tiled_rows(t, x, tile, ys),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_always_available() {
        assert!(supported(Isa::Scalar));
        let avail = available();
        assert_eq!(avail[0], Isa::Scalar);
        assert!(avail.contains(&detect_best()));
    }

    #[test]
    fn clamp_unsupported_degrades_to_scalar() {
        for isa in [Isa::Scalar, Isa::Avx2Fma, Isa::Neon] {
            let c = clamp(isa);
            assert!(supported(c));
            if supported(isa) {
                assert_eq!(c, isa);
            } else {
                assert_eq!(c, Isa::Scalar);
            }
        }
    }

    #[test]
    fn parse_isa_names() {
        assert_eq!(parse_isa("auto").unwrap(), None);
        assert_eq!(parse_isa("scalar").unwrap(), Some(Isa::Scalar));
        assert_eq!(parse_isa("avx2").unwrap(), Some(Isa::Avx2Fma));
        assert_eq!(parse_isa("neon").unwrap(), Some(Isa::Neon));
        assert!(parse_isa("sse9").is_err());
    }

    #[test]
    fn lut_matches_dequant_formula() {
        let mut lut = [0.0f32; 16];
        fill_lut(4, 0.25, 7.0, &mut lut);
        for (k, &v) in lut.iter().enumerate() {
            assert_eq!(v.to_bits(), (0.25f32 * (k as f32 - 7.0)).to_bits());
        }
    }
}
