//! Portable scalar kernels — the `Isa::Scalar` implementations.
//!
//! The dense dot and the ALIGNED packed kernels are moved verbatim from
//! the pre-dispatch `model::matvec`, so `GPTQ_ISA=scalar` is bit-identical
//! to the historical code paths (the determinism-suite contract). The
//! general (ragged) packed path was re-based on the per-group dequant LUT
//! (`lut[code] = s·(code − zero)`, shared with the SIMD kernels) for bits
//! ≤ 4 — it no longer re-derives scale arithmetic per element, stays
//! within f32-reassociation distance of the old factored form, and gives
//! the batched general kernel per-row grids it can hoist across the
//! sequence loop. 8-bit keeps the factored form (a 256-entry LUT per
//! group would cost more than it saves) with the `s·z` product hoisted
//! per row.

use super::fill_lut;
use super::tiled::TiledPacked;
use crate::quant::pack::PackedMatrix;

/// The 4-way unrolled row dot shared by the matvec and the batched
/// matmul: one code path means the batched decode is bit-identical to
/// the single-sequence decode on dense linears (the continuous-batching
/// parity contract, DESIGN.md §Serving).
#[inline(always)]
pub(crate) fn dot4(row: &[f32], x: &[f32], dcol: usize) -> f32 {
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let chunks = dcol / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc0 += row[i] * x[i];
        acc1 += row[i + 1] * x[i + 1];
        acc2 += row[i + 2] * x[i + 2];
        acc3 += row[i + 3] * x[i + 3];
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for i in chunks * 4..dcol {
        acc += row[i] * x[i];
    }
    acc
}

/// Rows `row0..row0+y.len()` of y = W x — per-row arithmetic independent
/// of how rows are chunked (the parallel bit-identity contract).
pub(crate) fn f32_rows(w: &[f32], x: &[f32], dcol: usize, row0: usize, y: &mut [f32]) {
    for (i, yr) in y.iter_mut().enumerate() {
        let r = row0 + i;
        *yr = dot4(&w[r * dcol..(r + 1) * dcol], x, dcol);
    }
}

/// Rows `row0..` of the batched Y = W·X over `n` stacked activations
/// (`ys` row-major rows × n). Per-(row, sequence) arithmetic is exactly
/// [`dot4`], i.e. bit-identical to n separate single-sequence dots.
pub(crate) fn f32_matmul_rows(
    w: &[f32],
    xs: &[f32],
    dcol: usize,
    n: usize,
    row0: usize,
    ys: &mut [f32],
) {
    for (i, yrow) in ys.chunks_exact_mut(n).enumerate() {
        let r = row0 + i;
        let row = &w[r * dcol..(r + 1) * dcol];
        for (j, yv) in yrow.iter_mut().enumerate() {
            *yv = dot4(row, &xs[j * dcol..(j + 1) * dcol], dcol);
        }
    }
}

/// General (unaligned) packed row dot for bits ≤ 4, decoding through the
/// per-group LUT (`luts` holds `ngroups` tables of `1 << BITS` entries).
/// Handles any dcol/group layout; group boundaries may fall mid-word.
#[inline(always)]
fn dot_packed_general_lut<const BITS: u32>(
    words: &[u32],
    x: &[f32],
    luts: &[f32],
    dcol: usize,
    group: usize,
) -> f32 {
    let cpw = (32 / BITS) as usize;
    let mask = (1u32 << BITS) - 1;
    let lsize = 1usize << BITS;
    let mut y = 0.0f32;
    let mut col = 0usize;
    let mut gi = 0usize;
    let mut in_group = 0usize;
    for &w in words {
        let mut wbits = w;
        let fields = cpw.min(dcol - col);
        for _ in 0..fields {
            let code = (wbits & mask) as usize;
            wbits >>= BITS;
            let xv = unsafe { *x.get_unchecked(col) };
            y += unsafe { *luts.get_unchecked(gi * lsize + code) } * xv;
            col += 1;
            in_group += 1;
            if in_group == group {
                in_group = 0;
                gi += 1;
            }
        }
        if col == dcol {
            break;
        }
    }
    y
}

/// General (unaligned) packed row dot, factored form (8-bit): per-group
/// Σ code·x and Σ x folded as `s·Σcx − (s·z)·Σx`, with the `(s, s·z)`
/// pairs precomputed per row — bit-identical to the historical kernel
/// (`s * z * acc_x` always evaluated `(s·z)·acc_x`).
#[inline(always)]
fn dot_packed_general_fac<const BITS: u32>(
    words: &[u32],
    x: &[f32],
    szs: &[(f32, f32)],
    dcol: usize,
    group: usize,
) -> f32 {
    let cpw = (32 / BITS) as usize;
    let mask = (1u32 << BITS) - 1;
    let mut y = 0.0f32;
    let mut col = 0usize;
    let mut gi = 0usize;
    let mut acc_cx = 0.0f32;
    let mut acc_x = 0.0f32;
    let mut in_group = 0usize;
    for &w in words {
        let mut wbits = w;
        let fields = cpw.min(dcol - col);
        for _ in 0..fields {
            let code = (wbits & mask) as f32;
            wbits >>= BITS;
            let xv = unsafe { *x.get_unchecked(col) };
            acc_cx += code * xv;
            acc_x += xv;
            col += 1;
            in_group += 1;
            if in_group == group {
                let (s, sz) = unsafe { *szs.get_unchecked(gi) };
                y += s * acc_cx - sz * acc_x;
                acc_cx = 0.0;
                acc_x = 0.0;
                in_group = 0;
                gi += 1;
            }
        }
        if col == dcol {
            break;
        }
    }
    if in_group > 0 {
        let (s, sz) = szs[gi];
        y += s * acc_cx - sz * acc_x;
    }
    y
}

/// Per-row grids for the general path: LUTs for bits ≤ 4, `(s, s·z)`
/// pairs for 8-bit. Reused across rows (and, in the batched kernel,
/// across all n sequences of a row — the hoist that was previously redone
/// per (row, sequence)).
struct GeneralGrids {
    luts: Vec<f32>,
    szs: Vec<(f32, f32)>,
}

impl GeneralGrids {
    fn new(p: &PackedMatrix) -> Self {
        if p.bits < 8 {
            GeneralGrids { luts: vec![0.0; p.ngroups << p.bits], szs: Vec::new() }
        } else {
            GeneralGrids { luts: Vec::new(), szs: vec![(0.0, 0.0); p.ngroups] }
        }
    }

    fn fill(&mut self, p: &PackedMatrix, r: usize) {
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        if p.bits < 8 {
            let lsize = 1usize << p.bits;
            for gi in 0..p.ngroups {
                fill_lut(p.bits, scales[gi], zeros[gi], &mut self.luts[gi * lsize..(gi + 1) * lsize]);
            }
        } else {
            for gi in 0..p.ngroups {
                self.szs[gi] = (scales[gi], scales[gi] * zeros[gi]);
            }
        }
    }

    fn dot(&self, p: &PackedMatrix, words: &[u32], x: &[f32], group: usize) -> f32 {
        match p.bits {
            2 => dot_packed_general_lut::<2>(words, x, &self.luts, p.dcol, group),
            3 => dot_packed_general_lut::<3>(words, x, &self.luts, p.dcol, group),
            4 => dot_packed_general_lut::<4>(words, x, &self.luts, p.dcol, group),
            8 => dot_packed_general_fac::<8>(words, x, &self.szs, p.dcol, group),
            b => panic!("unsupported bit width {b}"),
        }
    }
}

/// General (ragged) path over rows `row0..row0+y.len()`.
pub(crate) fn packed_rows_general(
    p: &PackedMatrix,
    x: &[f32],
    group: usize,
    row0: usize,
    y: &mut [f32],
) {
    let mut grids = GeneralGrids::new(p);
    for (i, yr) in y.iter_mut().enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        grids.fill(p, r);
        *yr = grids.dot(p, words, x, group);
    }
}

/// General (ragged) batched path: the per-row grids (LUT / s·z) are built
/// once per row and shared by all n sequences — the only thing re-read
/// per sequence is the activation vector.
pub(crate) fn packed_matmul_rows_general(
    p: &PackedMatrix,
    xs: &[f32],
    group: usize,
    n: usize,
    row0: usize,
    ys: &mut [f32],
) {
    let mut grids = GeneralGrids::new(p);
    for (i, yrow) in ys.chunks_exact_mut(n).enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        grids.fill(p, r);
        for (j, yv) in yrow.iter_mut().enumerate() {
            let x = &xs[j * p.dcol..(j + 1) * p.dcol];
            *yv = grids.dot(p, words, x, group);
        }
    }
}

/// Aligned fast path: whole words only, group size a multiple of the
/// codes-per-word. §Perf design (see EXPERIMENTS.md §Perf):
/// * Σx per group is ROW-INDEPENDENT — precomputed once per matvec in
///   `xsum` and folded in as `−s·z·Σx`, halving the per-element FMAs;
/// * each u32 decodes into a fixed-length `[f32; CPW]` array with
///   independent shift/mask lanes — no loop-carried `wbits >>= B`
///   dependency, so LLVM vectorizes the decode + dot;
/// * no per-element group branch: groups advance in whole words.
///
/// Kept verbatim from the pre-dispatch kernel: this is the path real
/// layer shapes hit, and `GPTQ_ISA=scalar` must stay bit-exact with it.
#[inline(always)]
fn dot_packed_row_aligned<const BITS: u32, const CPW: usize>(
    words: &[u32],
    x: &[f32],
    scales: &[f32],
    zeros: &[f32],
    xsum: &[f32],
    words_per_group: usize,
) -> f32 {
    let mask = (1u32 << BITS) - 1;
    let mut y = 0.0f32;
    for (gi, gwords) in words.chunks_exact(words_per_group).enumerate() {
        // CPW persistent accumulators: lane k always uses shift k·BITS, so
        // the word loop is CPW independent FMA streams (no serial add
        // chain) — measured ~2x over the per-word horizontal sum.
        let mut accs = [0.0f32; CPW];
        let xg = &x[gi * words_per_group * CPW..];
        for (wi, &w) in gwords.iter().enumerate() {
            let xs = &xg[wi * CPW..wi * CPW + CPW];
            for k in 0..CPW {
                accs[k] += ((w >> (BITS as usize * k)) & mask) as f32 * xs[k];
            }
        }
        let acc: f32 = accs.iter().sum();
        let s = unsafe { *scales.get_unchecked(gi) };
        let z = unsafe { *zeros.get_unchecked(gi) };
        y += s * acc - s * z * unsafe { *xsum.get_unchecked(gi) };
    }
    y
}

/// Aligned fast path over rows `row0..row0+y.len()` (serial core).
pub(crate) fn packed_rows_aligned(
    p: &PackedMatrix,
    xeff: &[f32],
    xsum: &[f32],
    wpg: usize,
    row0: usize,
    y: &mut [f32],
) {
    // callers skip the Σx precompute when a SIMD kernel will run
    // (kernels::packed_aligned_uses_xsum) — a HARD assert (one branch per
    // row-range call, negligible vs the row loop) so any drift between
    // that predicate and the dispatch table fails loudly in release too,
    // never reaching the unchecked reads below
    assert_eq!(xsum.len(), p.ngroups, "scalar aligned kernel needs per-group Σx");
    for (i, yr) in y.iter_mut().enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        *yr = match p.bits {
            2 => dot_packed_row_aligned::<2, 16>(words, xeff, scales, zeros, xsum, wpg),
            3 => dot_packed_row_aligned::<3, 10>(words, xeff, scales, zeros, xsum, wpg),
            4 => dot_packed_row_aligned::<4, 8>(words, xeff, scales, zeros, xsum, wpg),
            8 => dot_packed_row_aligned::<8, 4>(words, xeff, scales, zeros, xsum, wpg),
            b => panic!("unsupported bit width {b}"),
        };
    }
}

/// Aligned batched core: rows `row0..` of Y = dequant(P)·X for `n`
/// stacked activations. Each packed u32 word is decoded ONCE into its
/// `[f32; CPW]` lane array and FMA'd into every sequence's lane
/// accumulators — the packed-weight read (the §Practical Speedups
/// bottleneck) is amortized over the whole batch. Per-sequence
/// accumulation order (lanes within words, words within groups, groups
/// within the row) is identical to [`dot_packed_row_aligned`], so the
/// batched result is bit-identical to n independent packed matvecs.
/// Kept verbatim from the pre-dispatch kernel.
fn matmul_rows_packed_aligned<const BITS: u32, const CPW: usize>(
    p: &PackedMatrix,
    xeffs: &[f32],
    xsums: &[f32],
    wpg: usize,
    n: usize,
    row0: usize,
    ys: &mut [f32],
) {
    let mask = (1u32 << BITS) - 1;
    let padded = p.nwords * CPW;
    // hard assert for the same reason as packed_rows_aligned's
    assert_eq!(xsums.len(), n * p.ngroups, "scalar aligned kernel needs per-group Σx");
    // per-sequence lane accumulators, reset per group
    let mut accs = vec![0.0f32; n * CPW];
    for (i, yrow) in ys.chunks_exact_mut(n).enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        yrow.fill(0.0);
        for (gi, gwords) in words.chunks_exact(wpg).enumerate() {
            accs.fill(0.0);
            let gbase = gi * wpg * CPW;
            for (wi, &w) in gwords.iter().enumerate() {
                let mut dec = [0.0f32; CPW];
                for k in 0..CPW {
                    dec[k] = ((w >> (BITS as usize * k)) & mask) as f32;
                }
                let off = gbase + wi * CPW;
                for j in 0..n {
                    let xg = &xeffs[j * padded + off..j * padded + off + CPW];
                    let a = &mut accs[j * CPW..(j + 1) * CPW];
                    for k in 0..CPW {
                        a[k] += dec[k] * xg[k];
                    }
                }
            }
            let s = scales[gi];
            let z = zeros[gi];
            for (j, yv) in yrow.iter_mut().enumerate() {
                let acc: f32 = accs[j * CPW..(j + 1) * CPW].iter().sum();
                *yv += s * acc - s * z * xsums[j * p.ngroups + gi];
            }
        }
    }
}

/// Bits dispatch for the aligned batched core.
#[allow(clippy::too_many_arguments)]
pub(crate) fn packed_matmul_rows_aligned(
    p: &PackedMatrix,
    xeffs: &[f32],
    xsums: &[f32],
    wpg: usize,
    n: usize,
    row0: usize,
    ys: &mut [f32],
) {
    match p.bits {
        2 => matmul_rows_packed_aligned::<2, 16>(p, xeffs, xsums, wpg, n, row0, ys),
        3 => matmul_rows_packed_aligned::<3, 10>(p, xeffs, xsums, wpg, n, row0, ys),
        4 => matmul_rows_packed_aligned::<4, 8>(p, xeffs, xsums, wpg, n, row0, ys),
        8 => matmul_rows_packed_aligned::<8, 4>(p, xeffs, xsums, wpg, n, row0, ys),
        b => panic!("unsupported bit width {b}"),
    }
}

/// Scalar tiled kernel — the fallback when a [`TiledPacked`] exists but
/// the active ISA has no tiled microkernel for its width (also what the
/// layout tests exercise on machines without SIMD). Decodes through the
/// same per-group LUT semantics as the SIMD tiled kernels (8-bit: affine
/// `code·s − s·z`), so results agree within f32 reassociation.
pub(crate) fn tiled_rows(t: &TiledPacked, xeff: &[f32], tile: usize, ys: &mut [f32]) {
    let r = t.r;
    let cpw = (32 / t.bits) as usize;
    let mask = (1u32 << t.bits) - 1;
    let lsize = 1usize << t.bits.min(4);
    let mut luts = vec![0.0f32; if t.bits < 8 { r * lsize } else { 0 }];
    let mut szs = vec![(0.0f32, 0.0f32); if t.bits == 8 { r } else { 0 }];
    ys.fill(0.0);
    for gi in 0..t.ngroups {
        let gbase = (tile * t.ngroups + gi) * r;
        if t.bits < 8 {
            for rr in 0..r {
                fill_lut(
                    t.bits,
                    t.scales[gbase + rr],
                    t.zeros[gbase + rr],
                    &mut luts[rr * lsize..(rr + 1) * lsize],
                );
            }
        } else {
            for (rr, slot) in szs.iter_mut().enumerate() {
                let s = t.scales[gbase + rr];
                *slot = (s, s * t.zeros[gbase + rr]);
            }
        }
        for wi in 0..t.wpg {
            let wbase = (tile * t.nwords + gi * t.wpg + wi) * r;
            let xw = &xeff[(gi * t.wpg + wi) * cpw..(gi * t.wpg + wi) * cpw + cpw];
            for (rr, yv) in ys.iter_mut().enumerate() {
                let w = t.words[wbase + rr];
                let mut acc = 0.0f32;
                if t.bits < 8 {
                    let lut = &luts[rr * lsize..(rr + 1) * lsize];
                    for (k, &xv) in xw.iter().enumerate() {
                        let code = ((w >> (t.bits as usize * k)) & mask) as usize;
                        acc += lut[code] * xv;
                    }
                } else {
                    let (s, sz) = szs[rr];
                    for (k, &xv) in xw.iter().enumerate() {
                        let code = ((w >> (8 * k)) & mask) as f32;
                        acc += (code * s - sz) * xv;
                    }
                }
                *yv += acc;
            }
        }
    }
}
