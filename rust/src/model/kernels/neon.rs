//! NEON microkernels (aarch64) — `Isa::Neon`.
//!
//! Initial port: the dense f32 dot and the headline 4-bit packed kernels
//! (single-sequence, batched, tiled). Other bit widths fall back to the
//! scalar kernels through the dispatch table (`kernels::tiled_supported`
//! gates the tiled layout accordingly).
//!
//! Dequant computes the same per-element value as the LUT kernels
//! (`s·(code − zero)`) as the affine `fma(code, s, −s·z)` — a
//! tbl-based f32 LUT would need four table registers per group and isn't
//! worth it at 4 lanes. Lane order is fixed (per-group accumulator
//! vectors, `vaddvq` horizontal sums), and the batched kernel replays the
//! single-sequence op order per sequence, so the PR-2/PR-3 determinism
//! contracts hold at this ISA exactly as on AVX2.

use super::sparse24::Sparse24Tiled;
use super::tiled::TiledPacked;
use crate::quant::pack::PackedMatrix;
use core::arch::aarch64::*;

/// One word (8 codes) -> two dequantized 4-lane vectors.
/// `sh_lo`/`sh_hi` are the negative shift vectors {0,-4,-8,-12} /
/// {-16,-20,-24,-28} (NEON `ushl` with a negative count shifts right).
#[inline]
#[target_feature(enable = "neon")]
unsafe fn dequant8_b4(
    w: u32,
    sh_lo: int32x4_t,
    sh_hi: int32x4_t,
    s: float32x4_t,
    nsz: float32x4_t,
) -> (float32x4_t, float32x4_t) {
    let v = vdupq_n_u32(w);
    let mask = vdupq_n_u32(15);
    let c_lo = vandq_u32(vshlq_u32(v, sh_lo), mask);
    let c_hi = vandq_u32(vshlq_u32(v, sh_hi), mask);
    (
        vfmaq_f32(nsz, vcvtq_f32_u32(c_lo), s),
        vfmaq_f32(nsz, vcvtq_f32_u32(c_hi), s),
    )
}

#[inline]
#[target_feature(enable = "neon")]
unsafe fn shift_vectors() -> (int32x4_t, int32x4_t) {
    let lo = [0i32, -4, -8, -12];
    let hi = [-16i32, -20, -24, -28];
    (vld1q_s32(lo.as_ptr()), vld1q_s32(hi.as_ptr()))
}

/// 4-lane×2 FMA row dot, shared by matvec and batched matmul (bit-parity).
#[inline]
#[target_feature(enable = "neon")]
unsafe fn dot_f32(row: &[f32], x: &[f32], dcol: usize) -> f32 {
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let chunks = dcol / 8;
    for c in 0..chunks {
        let i = c * 8;
        acc0 = vfmaq_f32(acc0, vld1q_f32(row.as_ptr().add(i)), vld1q_f32(x.as_ptr().add(i)));
        acc1 = vfmaq_f32(acc1, vld1q_f32(row.as_ptr().add(i + 4)), vld1q_f32(x.as_ptr().add(i + 4)));
    }
    let mut acc = vaddvq_f32(vaddq_f32(acc0, acc1));
    for i in chunks * 8..dcol {
        acc += row[i] * x[i];
    }
    acc
}

#[target_feature(enable = "neon")]
pub(crate) unsafe fn f32_rows(w: &[f32], x: &[f32], dcol: usize, row0: usize, y: &mut [f32]) {
    for (i, yr) in y.iter_mut().enumerate() {
        let r = row0 + i;
        *yr = dot_f32(&w[r * dcol..(r + 1) * dcol], x, dcol);
    }
}

#[target_feature(enable = "neon")]
pub(crate) unsafe fn f32_matmul_rows(
    w: &[f32],
    xs: &[f32],
    dcol: usize,
    n: usize,
    row0: usize,
    ys: &mut [f32],
) {
    for (i, yrow) in ys.chunks_exact_mut(n).enumerate() {
        let r = row0 + i;
        let row = &w[r * dcol..(r + 1) * dcol];
        for (j, yv) in yrow.iter_mut().enumerate() {
            *yv = dot_f32(row, &xs[j * dcol..(j + 1) * dcol], dcol);
        }
    }
}

#[target_feature(enable = "neon")]
pub(crate) unsafe fn packed_rows_aligned_b4(
    p: &PackedMatrix,
    xeff: &[f32],
    wpg: usize,
    row0: usize,
    y: &mut [f32],
) {
    let (sh_lo, sh_hi) = shift_vectors();
    for (i, yr) in y.iter_mut().enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        let mut acc_row = 0.0f32;
        for gi in 0..p.ngroups {
            let s = vdupq_n_f32(scales[gi]);
            let nsz = vdupq_n_f32(-(scales[gi] * zeros[gi]));
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            for wi in 0..wpg {
                let w = words[gi * wpg + wi];
                let off = (gi * wpg + wi) * 8;
                let (d0, d1) = dequant8_b4(w, sh_lo, sh_hi, s, nsz);
                acc0 = vfmaq_f32(acc0, d0, vld1q_f32(xeff.as_ptr().add(off)));
                acc1 = vfmaq_f32(acc1, d1, vld1q_f32(xeff.as_ptr().add(off + 4)));
            }
            acc_row += vaddvq_f32(vaddq_f32(acc0, acc1));
        }
        *yr = acc_row;
    }
}

#[target_feature(enable = "neon")]
pub(crate) unsafe fn packed_matmul_rows_aligned_b4(
    p: &PackedMatrix,
    xeffs: &[f32],
    wpg: usize,
    n: usize,
    row0: usize,
    ys: &mut [f32],
) {
    let padded = p.nwords * 8;
    let (sh_lo, sh_hi) = shift_vectors();
    let mut accs0: Vec<float32x4_t> = vec![vdupq_n_f32(0.0); n];
    let mut accs1: Vec<float32x4_t> = vec![vdupq_n_f32(0.0); n];
    for (i, yrow) in ys.chunks_exact_mut(n).enumerate() {
        let r = row0 + i;
        let words = &p.words[r * p.nwords..(r + 1) * p.nwords];
        let scales = &p.scales[r * p.ngroups..(r + 1) * p.ngroups];
        let zeros = &p.zeros[r * p.ngroups..(r + 1) * p.ngroups];
        yrow.fill(0.0);
        for gi in 0..p.ngroups {
            let s = vdupq_n_f32(scales[gi]);
            let nsz = vdupq_n_f32(-(scales[gi] * zeros[gi]));
            for a in accs0.iter_mut() {
                *a = vdupq_n_f32(0.0);
            }
            for a in accs1.iter_mut() {
                *a = vdupq_n_f32(0.0);
            }
            for wi in 0..wpg {
                let w = words[gi * wpg + wi];
                let off = (gi * wpg + wi) * 8;
                let (d0, d1) = dequant8_b4(w, sh_lo, sh_hi, s, nsz);
                for j in 0..n {
                    accs0[j] = vfmaq_f32(accs0[j], d0, vld1q_f32(xeffs.as_ptr().add(j * padded + off)));
                    accs1[j] =
                        vfmaq_f32(accs1[j], d1, vld1q_f32(xeffs.as_ptr().add(j * padded + off + 4)));
                }
            }
            for (j, yv) in yrow.iter_mut().enumerate() {
                *yv += vaddvq_f32(vaddq_f32(accs0[j], accs1[j]));
            }
        }
    }
}

/// 2:4 sparse tiled rows (4-bit): the index nibbles steer a scalar
/// gather of the 8 surviving x values per pair word; codes dequantize
/// through the same affine `fma(code, s, −s·z)` as the dense b4 kernels.
/// Batched sparse matmul stays scalar on NEON (dispatch table).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn sparse24_tiled_rows_b4(
    t: &Sparse24Tiled,
    x: &[f32],
    tile: usize,
    ys: &mut [f32],
) {
    debug_assert_eq!(t.bits, 4, "NEON sparse24 kernel is 4-bit only");
    debug_assert_eq!(t.r, 4, "NEON tiled kernels assume R=4");
    let (sh_lo, sh_hi) = shift_vectors();
    let group = t.dcol / t.ngroups;
    let nblocks = group / 4;
    let nfull = nblocks / 4; // fully-populated pair words (8 codes each)
    let mut xbuf = [0.0f32; 8];
    ys.fill(0.0);
    for gi in 0..t.ngroups {
        let gbase = (tile * t.ngroups + gi) * 4;
        let mut svec = [vdupq_n_f32(0.0); 4];
        let mut nszvec = [vdupq_n_f32(0.0); 4];
        let mut ss = [0.0f32; 4];
        let mut szs = [0.0f32; 4];
        for rr in 0..4 {
            let s = t.scales[gbase + rr];
            let sz = s * t.zeros[gbase + rr];
            svec[rr] = vdupq_n_f32(s);
            nszvec[rr] = vdupq_n_f32(-sz);
            ss[rr] = s;
            szs[rr] = sz;
        }
        let xg = &x[gi * group..];
        let mut accs0 = [vdupq_n_f32(0.0); 4];
        let mut accs1 = [vdupq_n_f32(0.0); 4];
        let mut taccs = [0.0f32; 4];
        for wi in 0..nfull {
            let wbase = (tile * t.npw + gi * t.pair_wpg + wi) * 4;
            let ibase = (tile * t.niw + gi * t.idx_wpg + wi / 2) * 4;
            for rr in 0..4 {
                let w = t.pair_words[wbase + rr];
                let nib16 = (t.idx_words[ibase + rr] >> ((wi % 2) * 16)) & 0xFFFF;
                for bb in 0..4 {
                    let nib = (nib16 >> (bb * 4)) & 0xF;
                    let base = (wi * 4 + bb) * 4;
                    xbuf[2 * bb] = xg[base + (nib & 3) as usize];
                    xbuf[2 * bb + 1] = xg[base + ((nib >> 2) & 3) as usize];
                }
                let (d0, d1) = dequant8_b4(w, sh_lo, sh_hi, svec[rr], nszvec[rr]);
                accs0[rr] = vfmaq_f32(accs0[rr], d0, vld1q_f32(xbuf.as_ptr()));
                accs1[rr] = vfmaq_f32(accs1[rr], d1, vld1q_f32(xbuf.as_ptr().add(4)));
            }
        }
        // tail blocks of a partial last word (group % 16 != 0)
        for b in nfull * 4..nblocks {
            let k = 2 * b;
            let wbase = (tile * t.npw + gi * t.pair_wpg + k / 8) * 4;
            let ibase = (tile * t.niw + gi * t.idx_wpg + b / 8) * 4;
            for rr in 0..4 {
                let w = t.pair_words[wbase + rr];
                let nib = (t.idx_words[ibase + rr] >> ((b % 8) * 4)) & 0xF;
                let c0 = ((w >> ((k % 8) * 4)) & 15) as f32;
                let c1 = ((w >> (((k + 1) % 8) * 4)) & 15) as f32;
                taccs[rr] += (c0 * ss[rr] - szs[rr]) * xg[b * 4 + (nib & 3) as usize];
                taccs[rr] += (c1 * ss[rr] - szs[rr]) * xg[b * 4 + ((nib >> 2) & 3) as usize];
            }
        }
        for (rr, yv) in ys.iter_mut().enumerate() {
            *yv += vaddvq_f32(vaddq_f32(accs0[rr], accs1[rr])) + taccs[rr];
        }
    }
}

#[target_feature(enable = "neon")]
pub(crate) unsafe fn tiled_rows_b4(t: &TiledPacked, xeff: &[f32], tile: usize, ys: &mut [f32]) {
    debug_assert_eq!(t.r, 4, "NEON tiled kernels assume R=4");
    let (sh_lo, sh_hi) = shift_vectors();
    ys.fill(0.0);
    for gi in 0..t.ngroups {
        let gbase = (tile * t.ngroups + gi) * 4;
        let mut svec = [vdupq_n_f32(0.0); 4];
        let mut nszvec = [vdupq_n_f32(0.0); 4];
        for rr in 0..4 {
            let s = t.scales[gbase + rr];
            svec[rr] = vdupq_n_f32(s);
            nszvec[rr] = vdupq_n_f32(-(s * t.zeros[gbase + rr]));
        }
        let mut accs0 = [vdupq_n_f32(0.0); 4];
        let mut accs1 = [vdupq_n_f32(0.0); 4];
        for wi in 0..t.wpg {
            let wbase = (tile * t.nwords + gi * t.wpg + wi) * 4;
            let off = (gi * t.wpg + wi) * 8;
            let xv0 = vld1q_f32(xeff.as_ptr().add(off));
            let xv1 = vld1q_f32(xeff.as_ptr().add(off + 4));
            for rr in 0..4 {
                let w = t.words[wbase + rr];
                let (d0, d1) = dequant8_b4(w, sh_lo, sh_hi, svec[rr], nszvec[rr]);
                accs0[rr] = vfmaq_f32(accs0[rr], d0, xv0);
                accs1[rr] = vfmaq_f32(accs1[rr], d1, xv1);
            }
        }
        for (rr, yv) in ys.iter_mut().enumerate() {
            *yv += vaddvq_f32(vaddq_f32(accs0[rr], accs1[rr]));
        }
    }
}
