//! Paged KV-cache pool — the memory subsystem behind continuous batching.
//!
//! The per-request [`KvCache`](crate::model::KvCache) of the single-stream
//! decode path reserves `max_seq × d_model` rows per layer up front, so a
//! worker serving B concurrent requests would pin `B × max_seq` positions
//! of KV memory regardless of how many tokens are actually cached. This
//! pool instead hands out fixed-size **pages** (`page_size` consecutive
//! positions, all layers at once) from a bounded budget: memory scales
//! with live tokens, many short sequences pack tightly, and exhaustion is
//! an explicit signal ([`KvPool::reserve`] returning `false`) that the
//! scheduler turns into backpressure (preempt + FIFO re-queue) instead of
//! an allocation failure.
//!
//! Layout: one page id addresses every layer simultaneously — layer `l`'s
//! K rows for page `p` live at `k[l][(p·page_size + off)·d_model ..]` —
//! so allocation and reclaim are per-sequence-chunk, never per-layer. A
//! sequence's [`SeqCache`] is just its page table plus the filled length;
//! attention walks positions through [`KvPool::k_row`]/[`KvPool::v_row`].
//! Pages are recycled through a LIFO free list; rows are always written
//! (`write_row` at position `len`) before they are read, so stale data
//! from a previous owner is never observed.

use crate::model::ModelConfig;

/// A sequence's view into the pool: the page table (indices into the
/// pool's page array, one entry per `page_size` positions) and the number
/// of positions filled so far. Deliberately not `Clone` — two live copies
/// of a page table would double-free pages on release.
#[derive(Debug, Default)]
pub struct SeqCache {
    pages: Vec<u32>,
    /// positions filled (the next decode step consumes position `len`)
    pub len: usize,
}

impl SeqCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pages currently held (capacity = `n_pages() × pool.page_size()`).
    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }
}

/// Bounded paged KV memory shared by every in-flight sequence of one
/// worker (see module docs).
#[derive(Debug)]
pub struct KvPool {
    n_layers: usize,
    d_model: usize,
    page_size: usize,
    n_pages: usize,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    free: Vec<u32>,
}

impl KvPool {
    /// A pool of `n_pages` pages of `page_size` positions each.
    pub fn new(cfg: &ModelConfig, n_pages: usize, page_size: usize) -> Self {
        assert!(n_pages > 0, "KvPool needs at least one page");
        assert!(page_size > 0, "KvPool page_size must be positive");
        let floats = n_pages * page_size * cfg.d_model;
        Self {
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            page_size,
            n_pages,
            k: (0..cfg.n_layers).map(|_| vec![0.0; floats]).collect(),
            v: (0..cfg.n_layers).map(|_| vec![0.0; floats]).collect(),
            // reversed so fresh pools allocate page 0 first (deterministic)
            free: (0..n_pages as u32).rev().collect(),
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn total_pages(&self) -> usize {
        self.n_pages
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Pages needed to hold `len` positions.
    pub fn pages_for(&self, len: usize) -> usize {
        len.div_ceil(self.page_size)
    }

    /// Positions `seq` can hold without another reserve.
    pub fn capacity_of(&self, seq: &SeqCache) -> usize {
        seq.pages.len() * self.page_size
    }

    /// Total KV bytes held by the pool (the bounded analog of
    /// `KvCache::bytes` — the "+9 GB of keys and values" accounting of
    /// §Practical Speedups, now a budget instead of a per-request cost).
    pub fn bytes(&self) -> usize {
        2 * self.n_layers * self.n_pages * self.page_size * self.d_model * 4
    }

    /// Grow `seq`'s page table until it can hold `len` positions. Returns
    /// `false` — the pool-exhausted backpressure signal — when the free
    /// list runs out. Pages granted before exhaustion stay with the
    /// sequence (reclaimed by [`KvPool::release`]), so a failed reserve
    /// never leaks and a later retry continues where it stopped.
    #[must_use]
    pub fn reserve(&mut self, seq: &mut SeqCache, len: usize) -> bool {
        while seq.pages.len() * self.page_size < len {
            match self.free.pop() {
                Some(p) => seq.pages.push(p),
                None => return false,
            }
        }
        true
    }

    /// Return every page of `seq` to the free list and reset it.
    pub fn release(&mut self, seq: &mut SeqCache) {
        self.free.extend(seq.pages.drain(..));
        seq.len = 0;
    }

    fn base(&self, seq: &SeqCache, pos: usize) -> usize {
        let page = seq.pages[pos / self.page_size] as usize;
        (page * self.page_size + pos % self.page_size) * self.d_model
    }

    /// Layer `layer`'s K row (d_model floats) for position `pos` of `seq`.
    pub fn k_row(&self, seq: &SeqCache, layer: usize, pos: usize) -> &[f32] {
        let b = self.base(seq, pos);
        &self.k[layer][b..b + self.d_model]
    }

    /// Layer `layer`'s V row for position `pos` of `seq`.
    pub fn v_row(&self, seq: &SeqCache, layer: usize, pos: usize) -> &[f32] {
        let b = self.base(seq, pos);
        &self.v[layer][b..b + self.d_model]
    }

    /// Store the K and V rows for position `pos` of `seq` at layer
    /// `layer` (the caller must have reserved capacity past `pos`).
    pub fn write_row(&mut self, seq: &SeqCache, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        debug_assert!(pos < self.capacity_of(seq), "write past reserved pages");
        let b = self.base(seq, pos);
        self.k[layer][b..b + self.d_model].copy_from_slice(k);
        self.v[layer][b..b + self.d_model].copy_from_slice(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::tiny_config;

    fn pool(n_pages: usize, page_size: usize) -> KvPool {
        KvPool::new(&tiny_config(), n_pages, page_size)
    }

    #[test]
    fn reserve_grows_in_page_units() {
        let mut p = pool(4, 4);
        let mut s = SeqCache::new();
        assert!(p.reserve(&mut s, 1));
        assert_eq!(s.n_pages(), 1);
        assert_eq!(p.capacity_of(&s), 4);
        assert!(p.reserve(&mut s, 4)); // still fits the first page
        assert_eq!(s.n_pages(), 1);
        assert!(p.reserve(&mut s, 5));
        assert_eq!(s.n_pages(), 2);
        assert_eq!(p.free_pages(), 2);
    }

    #[test]
    fn exhaustion_signals_and_release_restores() {
        let mut p = pool(3, 2);
        let mut a = SeqCache::new();
        let mut b = SeqCache::new();
        assert!(p.reserve(&mut a, 4)); // 2 pages
        assert!(p.reserve(&mut b, 2)); // 1 page
        assert_eq!(p.free_pages(), 0);
        // pool exhausted: explicit backpressure signal, no panic
        assert!(!p.reserve(&mut b, 3));
        p.release(&mut a);
        assert_eq!(p.free_pages(), 2);
        assert_eq!(a.n_pages(), 0);
        assert_eq!(a.len, 0);
        // the failed reserve kept b's existing page; retry succeeds now
        assert!(p.reserve(&mut b, 3));
        p.release(&mut b);
        assert_eq!(p.free_pages(), 3, "page leak");
    }

    #[test]
    fn rows_round_trip_across_page_boundaries() {
        let d = tiny_config().d_model;
        let mut p = pool(4, 2); // 2 positions per page -> pos 2 is page 1
        let mut s = SeqCache::new();
        assert!(p.reserve(&mut s, 5));
        for pos in 0..5 {
            let k: Vec<f32> = (0..d).map(|i| (pos * d + i) as f32).collect();
            let v: Vec<f32> = k.iter().map(|x| -x).collect();
            for l in 0..2 {
                p.write_row(&s, l, pos, &k, &v);
            }
        }
        for pos in 0..5 {
            for l in 0..2 {
                assert_eq!(p.k_row(&s, l, pos)[1], (pos * d + 1) as f32);
                assert_eq!(p.v_row(&s, l, pos)[1], -((pos * d + 1) as f32));
            }
        }
    }

    #[test]
    fn recycled_pages_are_rewritten_not_reread() {
        let d = tiny_config().d_model;
        let mut p = pool(1, 2);
        let mut a = SeqCache::new();
        assert!(p.reserve(&mut a, 1));
        p.write_row(&a, 0, 0, &vec![7.0; d], &vec![7.0; d]);
        p.release(&mut a);
        // new owner of the same page writes before reading
        let mut b = SeqCache::new();
        assert!(p.reserve(&mut b, 1));
        p.write_row(&b, 0, 0, &vec![3.0; d], &vec![3.0; d]);
        assert_eq!(p.k_row(&b, 0, 0)[0], 3.0);
    }

    #[test]
    fn bytes_accounting() {
        let cfg = tiny_config();
        let p = KvPool::new(&cfg, 8, 4);
        assert_eq!(p.bytes(), 2 * cfg.n_layers * 8 * 4 * cfg.d_model * 4);
    }
}
