//! Paged KV-cache pool — the memory subsystem behind continuous batching
//! and cross-request prefix sharing.
//!
//! The per-request [`KvCache`](crate::model::KvCache) of the single-stream
//! decode path reserves `max_seq × d_model` rows per layer up front, so a
//! worker serving B concurrent requests would pin `B × max_seq` positions
//! of KV memory regardless of how many tokens are actually cached. This
//! pool instead hands out fixed-size **pages** (`page_size` consecutive
//! positions, all layers at once) from a bounded budget: memory scales
//! with live tokens, many short sequences pack tightly, and exhaustion is
//! an explicit signal ([`KvPool::reserve`] returning `false`) that the
//! scheduler turns into backpressure (preempt + FIFO re-queue) instead of
//! an allocation failure.
//!
//! Layout: one page id addresses every layer simultaneously — layer `l`'s
//! K rows for page `p` live at `k[l][(p·page_size + off)·d_model ..]` —
//! so allocation and reclaim are per-sequence-chunk, never per-layer. A
//! sequence's [`SeqCache`] is just its page table plus the filled length;
//! attention walks positions through [`KvPool::k_row`]/[`KvPool::v_row`].
//! Pages are recycled through a LIFO free list; rows are always written
//! (`write_row` at position `len`) before they are read, so stale data
//! from a previous owner is never observed.
//!
//! **Prefix sharing.** Every page carries a reference count. A page is
//! *owned* (refcount 1) or *shared* (refcount > 1): [`KvPool::fork`]
//! maps a parent's prefix pages into a new [`SeqCache`] by incrementing
//! their counts — no KV floats are copied — and [`KvPool::release`]
//! decrements, returning a page to the free list only when the last
//! holder drops it. Sequences are append-only (the only write is
//! `write_row` at position `len`), so at most ONE mapped page can ever
//! be written while shared: the partially-filled tail page of a fork.
//! [`KvPool::reserve`] therefore performs copy-on-write at the moment it
//! guarantees capacity for the next position: if the page holding the
//! next write position is shared, a fresh page is popped from the free
//! list, the filled prefix rows are copied across all layers, and the
//! sequence's table entry is swapped — the other holders keep reading
//! the original rows, bit-for-bit unchanged. `write_row` asserts (debug)
//! that it only ever mutates owned pages, which is the invariant the
//! `tests/kvpool_refcount.rs` property suite fuzzes.
//!
//! **Speculative rollback.** Self-speculative decoding leans on the
//! page-granular layout for free rollback: the draft model writes
//! provisional rows at positions `len..len+k`, and discarding them is
//! just truncating `SeqCache::len` back — the pages stay reserved and
//! the target's verify pass overwrites the same positions with its own
//! canonical rows. This re-write-after-rollback is safe because rows
//! past a fork's shared prefix were written (and CoW'd if needed) by
//! this sequence, so their pages are owned, and readers only ever
//! touch positions `< len`, so a provisional row is never observed
//! once the rollback lands. Under Q8 the roll-forward rewrite
//! re-quantizes at the same position; all subsequent reads see only
//! the final (target) write, so the once-per-surviving-row error
//! argument is unchanged.
//!
//! **KV precision.** Pages store rows in one of two dtypes
//! ([`KvDtype`], fixed at pool construction): `F32` keeps today's exact
//! f32 rows, `Q8` stores u8 codes plus per-position **per-head**
//! (scale, zero) f32 pairs — asymmetric affine over each head's
//! `head_dim` slice, computed once at [`KvPool::write_row`] time. A Q8
//! page costs `d_model + 8·n_heads` bytes per position per layer per
//! {K,V} instead of `4·d_model`, an ≈4× capacity win for realistic
//! `head_dim`. Reads go through [`KvPool::read_k_row`] /
//! [`KvPool::read_v_row`], which dequantize into a caller scratch
//! buffer; quantization error is incurred exactly once (at write), so
//! every holder of a shared page — and every re-read of the same
//! position — sees bit-identical floats. CoW copies codes and scales
//! verbatim and NEVER re-quantizes, so the prefix-cache
//! bit-reproducibility argument survives unchanged under Q8
//! (DESIGN.md §KV precision).

use crate::model::ModelConfig;

/// Storage precision of a [`KvPool`]'s pages. `F32` is the default and
/// bit-identical to the pre-dtype pool; `Q8` trades ≈4× KV memory for a
/// deterministic per-head affine quantization error (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvDtype {
    #[default]
    F32,
    Q8,
}

impl KvDtype {
    /// Parse a CLI/env spelling (`"f32"` / `"q8"`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(Self::F32),
            "q8" => Some(Self::Q8),
            _ => None,
        }
    }

    /// Dtype selected by `GPTQ_KV_DTYPE` (unset or empty → `F32`; any
    /// other unrecognized value panics — a silent fallback would quietly
    /// un-test the q8 rows of the determinism matrix).
    pub fn from_env() -> Self {
        match std::env::var("GPTQ_KV_DTYPE") {
            Ok(s) if s.is_empty() => Self::F32,
            Ok(s) => Self::parse(&s)
                .unwrap_or_else(|| panic!("GPTQ_KV_DTYPE must be f32 or q8, got {s:?}")),
            Err(_) => Self::F32,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::F32 => "f32",
            Self::Q8 => "q8",
        }
    }
}

/// Per-head asymmetric affine encode of one `d_model` row:
/// `code = round((x − zero) / scale)` with `scale = (max − min)/255`,
/// `zero = min`, per `head_dim` slice. A flat head (`max == min`) gets
/// `scale = 0` and code 0, which round-trips exactly through
/// `zero + code·scale` — constant rows survive Q8 bit-for-bit.
fn q8_encode(row: &[f32], head_dim: usize, codes: &mut [u8], scales: &mut [f32]) {
    for h in 0..row.len() / head_dim {
        let seg = &row[h * head_dim..(h + 1) * head_dim];
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in seg {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        let scale = (hi - lo) / 255.0;
        let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
        scales[2 * h] = scale;
        scales[2 * h + 1] = lo;
        for (c, &x) in codes[h * head_dim..(h + 1) * head_dim].iter_mut().zip(seg) {
            *c = ((x - lo) * inv).round().clamp(0.0, 255.0) as u8;
        }
    }
}

/// Inverse of [`q8_encode`]: `x̂ = zero + code·scale` per head. Pure
/// f32 arithmetic in a fixed order — deterministic across threads,
/// batch shapes, and cache on/off, which is what lets the serving
/// parity contracts stay bitwise within Q8.
fn q8_decode(codes: &[u8], scales: &[f32], head_dim: usize, out: &mut [f32]) {
    for h in 0..codes.len() / head_dim {
        let (s, z) = (scales[2 * h], scales[2 * h + 1]);
        let seg = &codes[h * head_dim..(h + 1) * head_dim];
        for (o, &c) in out[h * head_dim..(h + 1) * head_dim].iter_mut().zip(seg) {
            *o = z + c as f32 * s;
        }
    }
}

/// A sequence's view into the pool: the page table (indices into the
/// pool's page array, one entry per `page_size` positions) and the number
/// of positions filled so far. Deliberately not `Clone` — two live copies
/// of a page table would double-release pages; sharing goes through
/// [`KvPool::fork`], which accounts every holder in the page refcounts.
#[derive(Debug, Default)]
pub struct SeqCache {
    pages: Vec<u32>,
    /// positions filled (the next decode step consumes position `len`)
    pub len: usize,
}

impl SeqCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pages currently held (capacity = `n_pages() × pool.page_size()`).
    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    /// The page table (pool page ids, one per `page_size` positions) —
    /// read-only: the prefix cache indexes full prompt pages by token
    /// key, and the property tests audit refcounts against it.
    pub fn pages(&self) -> &[u32] {
        &self.pages
    }
}

/// Bounded paged KV memory shared by every in-flight sequence of one
/// worker (see module docs).
#[derive(Debug)]
pub struct KvPool {
    n_layers: usize,
    d_model: usize,
    n_heads: usize,
    page_size: usize,
    n_pages: usize,
    dtype: KvDtype,
    /// F32 rows per layer (empty when dtype is Q8)
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Q8 codes per layer, `n_pages·page_size·d_model` u8 each (empty
    /// when dtype is F32)
    kq: Vec<Vec<u8>>,
    vq: Vec<Vec<u8>>,
    /// Q8 (scale, zero) pairs per layer, `n_pages·page_size·n_heads·2`
    /// f32 each (empty when dtype is F32)
    ksz: Vec<Vec<f32>>,
    vsz: Vec<Vec<f32>>,
    free: Vec<u32>,
    /// per-page holder count: 0 = on the free list, 1 = owned by exactly
    /// one holder (a sequence or the prefix cache), >1 = shared
    refs: Vec<u32>,
}

impl KvPool {
    /// A pool of `n_pages` pages of `page_size` positions each, storing
    /// exact f32 rows ([`KvDtype::F32`] — bit-identical to the
    /// pre-dtype pool; every pre-existing caller goes through here).
    pub fn new(cfg: &ModelConfig, n_pages: usize, page_size: usize) -> Self {
        Self::new_with_dtype(cfg, n_pages, page_size, KvDtype::F32)
    }

    /// A pool with an explicit page storage dtype (see module docs §KV
    /// precision).
    pub fn new_with_dtype(
        cfg: &ModelConfig,
        n_pages: usize,
        page_size: usize,
        dtype: KvDtype,
    ) -> Self {
        assert!(n_pages > 0, "KvPool needs at least one page");
        assert!(page_size > 0, "KvPool page_size must be positive");
        assert_eq!(cfg.d_model % cfg.n_heads, 0, "d_model must split into heads");
        let floats = n_pages * page_size * cfg.d_model;
        let nsz = n_pages * page_size * cfg.n_heads * 2;
        let (f32_layers, q8_layers) = match dtype {
            KvDtype::F32 => (cfg.n_layers, 0),
            KvDtype::Q8 => (0, cfg.n_layers),
        };
        Self {
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            n_heads: cfg.n_heads,
            page_size,
            n_pages,
            dtype,
            k: (0..f32_layers).map(|_| vec![0.0; floats]).collect(),
            v: (0..f32_layers).map(|_| vec![0.0; floats]).collect(),
            kq: (0..q8_layers).map(|_| vec![0; floats]).collect(),
            vq: (0..q8_layers).map(|_| vec![0; floats]).collect(),
            ksz: (0..q8_layers).map(|_| vec![0.0; nsz]).collect(),
            vsz: (0..q8_layers).map(|_| vec![0.0; nsz]).collect(),
            // reversed so fresh pools allocate page 0 first (deterministic)
            free: (0..n_pages as u32).rev().collect(),
            refs: vec![0; n_pages],
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Storage precision of this pool's pages.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    pub fn total_pages(&self) -> usize {
        self.n_pages
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Fraction of pages in use (live sequences + cache holds), in
    /// [0, 1] — the saturation signal the serving overload bench and the
    /// SLO docs report. A zero-page pool reads as fully utilized.
    pub fn utilization(&self) -> f64 {
        if self.n_pages == 0 {
            return 1.0;
        }
        1.0 - self.free.len() as f64 / self.n_pages as f64
    }

    /// Holder count of `page` (0 = free). Exposed for the prefix cache's
    /// eviction policy and the refcount property tests.
    pub fn refcount(&self, page: u32) -> u32 {
        self.refs[page as usize]
    }

    /// Pages needed to hold `len` positions.
    pub fn pages_for(&self, len: usize) -> usize {
        len.div_ceil(self.page_size)
    }

    /// Positions `seq` can hold without another reserve.
    pub fn capacity_of(&self, seq: &SeqCache) -> usize {
        seq.pages.len() * self.page_size
    }

    /// True when `seq`'s next `write_row` (position `seq.len`) lands in a
    /// page it maps but does not own — i.e. the next [`KvPool::reserve`]
    /// past `seq.len` will consume one extra free page for the
    /// copy-on-write. The scheduler's admission gate counts this.
    pub fn cow_pending(&self, seq: &SeqCache) -> bool {
        seq.len < self.capacity_of(seq) && self.refs[seq.pages[seq.len / self.page_size] as usize] > 1
    }

    /// Bytes one {K or V} position costs at one layer under `dtype`:
    /// `4·d_model` for f32 rows, `d_model` codes + `n_heads` (scale,
    /// zero) f32 pairs for q8.
    fn pos_bytes(d_model: usize, n_heads: usize, dtype: KvDtype) -> usize {
        match dtype {
            KvDtype::F32 => d_model * 4,
            KvDtype::Q8 => d_model + n_heads * 2 * 4,
        }
    }

    /// Bytes one page (all layers, K and V) costs under `dtype` — the
    /// unit the scheduler's fixed-byte budget and `serve_sweep`'s
    /// fixed-pool-bytes phase divide by to size dtype-fair pools.
    pub fn page_bytes(cfg: &ModelConfig, page_size: usize, dtype: KvDtype) -> usize {
        2 * cfg.n_layers * page_size * Self::pos_bytes(cfg.d_model, cfg.n_heads, dtype)
    }

    /// Total KV bytes held by the pool (the bounded analog of
    /// `KvCache::bytes` — the "+9 GB of keys and values" accounting of
    /// §Practical Speedups, now a budget instead of a per-request cost),
    /// derived from the page dtype: q8 pools report their smaller
    /// footprint, which is the whole capacity story.
    pub fn bytes(&self) -> usize {
        2 * self.n_layers
            * self.n_pages
            * self.page_size
            * Self::pos_bytes(self.d_model, self.n_heads, self.dtype)
    }

    fn alloc(&mut self) -> Option<u32> {
        let p = self.free.pop()?;
        debug_assert_eq!(self.refs[p as usize], 0, "free page with holders");
        self.refs[p as usize] = 1;
        Some(p)
    }

    /// Grow `seq`'s page table until it can hold `len` positions, and —
    /// when growth implies upcoming writes (`len > seq.len`) — make the
    /// page holding the next write position exclusively owned
    /// (copy-on-write of a shared fork tail). Returns `false` — the
    /// pool-exhausted backpressure signal — when the free list runs out
    /// at either step. Pages granted before exhaustion stay with the
    /// sequence (reclaimed by [`KvPool::release`]), so a failed reserve
    /// never leaks and a later retry continues where it stopped.
    #[must_use]
    pub fn reserve(&mut self, seq: &mut SeqCache, len: usize) -> bool {
        if len > seq.len && !self.make_tail_owned(seq) {
            return false;
        }
        while seq.pages.len() * self.page_size < len {
            match self.alloc() {
                Some(p) => seq.pages.push(p),
                None => return false,
            }
        }
        true
    }

    /// Copy-on-write: if position `seq.len` falls inside a mapped page
    /// that other holders share, give `seq` its own copy of that page's
    /// filled rows. Append-only writes mean this is the only page that
    /// can ever be both mapped-ahead-of-`len` and shared (fork grants
    /// exactly `pages_for(len)` pages), so one copy per fork suffices.
    fn make_tail_owned(&mut self, seq: &mut SeqCache) -> bool {
        if seq.len >= self.capacity_of(seq) {
            return true; // next write goes to a page alloc() will own
        }
        let pi = seq.len / self.page_size;
        let old = seq.pages[pi] as usize;
        if self.refs[old] == 1 {
            return true;
        }
        let Some(new) = self.alloc() else { return false };
        let filled = seq.len - pi * self.page_size;
        let src = old * self.page_size * self.d_model;
        let dst = new as usize * self.page_size * self.d_model;
        match self.dtype {
            KvDtype::F32 => {
                for l in 0..self.n_layers {
                    self.k[l].copy_within(src..src + filled * self.d_model, dst);
                    self.v[l].copy_within(src..src + filled * self.d_model, dst);
                }
            }
            KvDtype::Q8 => {
                // Copy codes AND scales verbatim — never re-quantize:
                // the copy must be byte-identical to the shared original
                // so the other holders and the new owner keep reading
                // the same dequantized floats (module docs).
                let ssrc = old * self.page_size * self.n_heads * 2;
                let sdst = new as usize * self.page_size * self.n_heads * 2;
                for l in 0..self.n_layers {
                    self.kq[l].copy_within(src..src + filled * self.d_model, dst);
                    self.vq[l].copy_within(src..src + filled * self.d_model, dst);
                    self.ksz[l].copy_within(ssrc..ssrc + filled * self.n_heads * 2, sdst);
                    self.vsz[l].copy_within(ssrc..ssrc + filled * self.n_heads * 2, sdst);
                }
            }
        }
        self.refs[old] -= 1;
        seq.pages[pi] = new;
        true
    }

    /// Map the first `pages_for(len)` pages of `parent` into a new
    /// sequence holding `len` positions — no KV data moves, the shared
    /// pages' refcounts go up by one. `len` must not exceed the parent's
    /// filled length (a fork may only see rows that were written).
    pub fn fork(&mut self, parent: &SeqCache, len: usize) -> SeqCache {
        assert!(len <= parent.len, "fork past the parent's filled length");
        self.fork_pages(&parent.pages, len)
    }

    /// [`KvPool::fork`] from a bare page list (the prefix cache stores
    /// matched prefixes as page ids, not `SeqCache`s). The caller asserts
    /// the first `len` positions of `pages` hold valid rows.
    pub fn fork_pages(&mut self, pages: &[u32], len: usize) -> SeqCache {
        let need = self.pages_for(len);
        assert!(need <= pages.len(), "fork needs {need} pages, got {}", pages.len());
        let mapped = pages[..need].to_vec();
        for &p in &mapped {
            debug_assert!(self.refs[p as usize] > 0, "fork of a free page");
            self.refs[p as usize] += 1;
        }
        SeqCache { pages: mapped, len }
    }

    /// Take one extra hold on `page` (the prefix cache pinning a prompt
    /// page it indexed). Balanced by [`KvPool::release_page`].
    pub fn retain_page(&mut self, page: u32) {
        debug_assert!(self.refs[page as usize] > 0, "retain of a free page");
        self.refs[page as usize] += 1;
    }

    /// Drop one hold on `page`; the last drop returns it to the free
    /// list. Releasing a free page is a double-free — asserted.
    pub fn release_page(&mut self, page: u32) {
        let r = &mut self.refs[page as usize];
        assert!(*r > 0, "double free of page {page}");
        *r -= 1;
        if *r == 0 {
            self.free.push(page);
        }
    }

    /// Drop `seq`'s hold on every page it maps and reset it. Pages whose
    /// last holder this was return to the free list; pages shared with
    /// other sequences or the prefix cache stay resident.
    pub fn release(&mut self, seq: &mut SeqCache) {
        for p in seq.pages.drain(..) {
            self.release_page(p);
        }
        seq.len = 0;
    }

    /// Flat slot index of position `pos` of `seq` (× d_model for
    /// row/code offsets, × n_heads·2 for scale offsets).
    fn slot(&self, seq: &SeqCache, pos: usize) -> usize {
        let page = seq.pages[pos / self.page_size] as usize;
        page * self.page_size + pos % self.page_size
    }

    fn base(&self, seq: &SeqCache, pos: usize) -> usize {
        self.slot(seq, pos) * self.d_model
    }

    /// Layer `layer`'s K row (d_model floats) for position `pos` of
    /// `seq`. F32 pools only — the zero-copy fast path the f32
    /// attention loop borrows from; Q8 readers go through
    /// [`KvPool::read_k_row`].
    pub fn k_row(&self, seq: &SeqCache, layer: usize, pos: usize) -> &[f32] {
        debug_assert_eq!(self.dtype, KvDtype::F32, "k_row on a {} pool", self.dtype.name());
        let b = self.base(seq, pos);
        &self.k[layer][b..b + self.d_model]
    }

    /// Layer `layer`'s V row for position `pos` of `seq` (F32 pools
    /// only, see [`KvPool::k_row`]).
    pub fn v_row(&self, seq: &SeqCache, layer: usize, pos: usize) -> &[f32] {
        debug_assert_eq!(self.dtype, KvDtype::F32, "v_row on a {} pool", self.dtype.name());
        let b = self.base(seq, pos);
        &self.v[layer][b..b + self.d_model]
    }

    /// Materialize layer `layer`'s K row for position `pos` of `seq`
    /// into `out` (d_model floats) — copy for F32, per-head dequant for
    /// Q8. Works for both dtypes; the attention loops use this to fill
    /// their per-thread scratch buffers under Q8.
    pub fn read_k_row(&self, seq: &SeqCache, layer: usize, pos: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.d_model);
        match self.dtype {
            KvDtype::F32 => out.copy_from_slice(self.k_row(seq, layer, pos)),
            KvDtype::Q8 => {
                let b = self.slot(seq, pos) * self.d_model;
                let s = self.slot(seq, pos) * self.n_heads * 2;
                q8_decode(
                    &self.kq[layer][b..b + self.d_model],
                    &self.ksz[layer][s..s + self.n_heads * 2],
                    self.d_model / self.n_heads,
                    out,
                );
            }
        }
    }

    /// [`KvPool::read_k_row`] for the V row.
    pub fn read_v_row(&self, seq: &SeqCache, layer: usize, pos: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.d_model);
        match self.dtype {
            KvDtype::F32 => out.copy_from_slice(self.v_row(seq, layer, pos)),
            KvDtype::Q8 => {
                let b = self.slot(seq, pos) * self.d_model;
                let s = self.slot(seq, pos) * self.n_heads * 2;
                q8_decode(
                    &self.vq[layer][b..b + self.d_model],
                    &self.vsz[layer][s..s + self.n_heads * 2],
                    self.d_model / self.n_heads,
                    out,
                );
            }
        }
    }

    /// Raw Q8 K codes for one position (Q8 pools only) — exposed so the
    /// refcount property suite can assert CoW copies are byte-identical.
    pub fn k_codes(&self, seq: &SeqCache, layer: usize, pos: usize) -> &[u8] {
        assert_eq!(self.dtype, KvDtype::Q8, "k_codes on a {} pool", self.dtype.name());
        let b = self.slot(seq, pos) * self.d_model;
        &self.kq[layer][b..b + self.d_model]
    }

    /// Raw Q8 V codes for one position (Q8 pools only).
    pub fn v_codes(&self, seq: &SeqCache, layer: usize, pos: usize) -> &[u8] {
        assert_eq!(self.dtype, KvDtype::Q8, "v_codes on a {} pool", self.dtype.name());
        let b = self.slot(seq, pos) * self.d_model;
        &self.vq[layer][b..b + self.d_model]
    }

    /// Raw Q8 K (scale, zero) pairs for one position, `n_heads·2` f32
    /// (Q8 pools only).
    pub fn k_scales(&self, seq: &SeqCache, layer: usize, pos: usize) -> &[f32] {
        assert_eq!(self.dtype, KvDtype::Q8, "k_scales on a {} pool", self.dtype.name());
        let s = self.slot(seq, pos) * self.n_heads * 2;
        &self.ksz[layer][s..s + self.n_heads * 2]
    }

    /// Raw Q8 V (scale, zero) pairs for one position (Q8 pools only).
    pub fn v_scales(&self, seq: &SeqCache, layer: usize, pos: usize) -> &[f32] {
        assert_eq!(self.dtype, KvDtype::Q8, "v_scales on a {} pool", self.dtype.name());
        let s = self.slot(seq, pos) * self.n_heads * 2;
        &self.vsz[layer][s..s + self.n_heads * 2]
    }

    /// Store the K and V rows for position `pos` of `seq` at layer
    /// `layer` (the caller must have reserved capacity past `pos`, which
    /// also guarantees — via copy-on-write — that the target page is
    /// exclusively owned: a write can never leak into rows another live
    /// sequence or the prefix cache reads). Under Q8 this is where the
    /// one-and-only quantization happens (per-head affine, see module
    /// docs); every later read dequantizes the same stored codes.
    pub fn write_row(&mut self, seq: &SeqCache, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        debug_assert!(pos < self.capacity_of(seq), "write past reserved pages");
        debug_assert_eq!(
            self.refs[seq.pages[pos / self.page_size] as usize],
            1,
            "write into a shared page (reserve skipped copy-on-write?)"
        );
        let b = self.base(seq, pos);
        match self.dtype {
            KvDtype::F32 => {
                self.k[layer][b..b + self.d_model].copy_from_slice(k);
                self.v[layer][b..b + self.d_model].copy_from_slice(v);
            }
            KvDtype::Q8 => {
                let hd = self.d_model / self.n_heads;
                let s = self.slot(seq, pos) * self.n_heads * 2;
                q8_encode(
                    k,
                    hd,
                    &mut self.kq[layer][b..b + self.d_model],
                    &mut self.ksz[layer][s..s + self.n_heads * 2],
                );
                q8_encode(
                    v,
                    hd,
                    &mut self.vq[layer][b..b + self.d_model],
                    &mut self.vsz[layer][s..s + self.n_heads * 2],
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::tiny_config;

    fn pool(n_pages: usize, page_size: usize) -> KvPool {
        KvPool::new(&tiny_config(), n_pages, page_size)
    }

    #[test]
    fn reserve_grows_in_page_units() {
        let mut p = pool(4, 4);
        let mut s = SeqCache::new();
        assert!(p.reserve(&mut s, 1));
        assert_eq!(s.n_pages(), 1);
        assert_eq!(p.capacity_of(&s), 4);
        assert!(p.reserve(&mut s, 4)); // still fits the first page
        assert_eq!(s.n_pages(), 1);
        assert!(p.reserve(&mut s, 5));
        assert_eq!(s.n_pages(), 2);
        assert_eq!(p.free_pages(), 2);
        assert!(s.pages().iter().all(|&pg| p.refcount(pg) == 1));
    }

    #[test]
    fn exhaustion_signals_and_release_restores() {
        let mut p = pool(3, 2);
        let mut a = SeqCache::new();
        let mut b = SeqCache::new();
        assert!(p.reserve(&mut a, 4)); // 2 pages
        assert!(p.reserve(&mut b, 2)); // 1 page
        assert_eq!(p.free_pages(), 0);
        // pool exhausted: explicit backpressure signal, no panic
        assert!(!p.reserve(&mut b, 3));
        p.release(&mut a);
        assert_eq!(p.free_pages(), 2);
        assert_eq!(a.n_pages(), 0);
        assert_eq!(a.len, 0);
        // the failed reserve kept b's existing page; retry succeeds now
        assert!(p.reserve(&mut b, 3));
        p.release(&mut b);
        assert_eq!(p.free_pages(), 3, "page leak");
    }

    #[test]
    fn utilization_tracks_reserve_and_release() {
        let mut p = pool(4, 2);
        assert_eq!(p.utilization(), 0.0);
        let mut s = SeqCache::new();
        assert!(p.reserve(&mut s, 3)); // 2 of 4 pages
        assert!((p.utilization() - 0.5).abs() < 1e-12);
        assert!(p.reserve(&mut s, 8)); // all 4
        assert_eq!(p.utilization(), 1.0);
        p.release(&mut s);
        assert_eq!(p.utilization(), 0.0);
    }

    #[test]
    fn rows_round_trip_across_page_boundaries() {
        let d = tiny_config().d_model;
        let mut p = pool(4, 2); // 2 positions per page -> pos 2 is page 1
        let mut s = SeqCache::new();
        assert!(p.reserve(&mut s, 5));
        for pos in 0..5 {
            let k: Vec<f32> = (0..d).map(|i| (pos * d + i) as f32).collect();
            let v: Vec<f32> = k.iter().map(|x| -x).collect();
            for l in 0..2 {
                p.write_row(&s, l, pos, &k, &v);
            }
        }
        for pos in 0..5 {
            for l in 0..2 {
                assert_eq!(p.k_row(&s, l, pos)[1], (pos * d + 1) as f32);
                assert_eq!(p.v_row(&s, l, pos)[1], -((pos * d + 1) as f32));
            }
        }
    }

    #[test]
    fn recycled_pages_are_rewritten_not_reread() {
        let d = tiny_config().d_model;
        let mut p = pool(1, 2);
        let mut a = SeqCache::new();
        assert!(p.reserve(&mut a, 1));
        p.write_row(&a, 0, 0, &vec![7.0; d], &vec![7.0; d]);
        p.release(&mut a);
        // new owner of the same page writes before reading
        let mut b = SeqCache::new();
        assert!(p.reserve(&mut b, 1));
        p.write_row(&b, 0, 0, &vec![3.0; d], &vec![3.0; d]);
        assert_eq!(p.k_row(&b, 0, 0)[0], 3.0);
    }

    #[test]
    fn bytes_accounting() {
        let cfg = tiny_config();
        let p = KvPool::new(&cfg, 8, 4);
        assert_eq!(p.bytes(), 2 * cfg.n_layers * 8 * 4 * cfg.d_model * 4);
        assert_eq!(p.bytes(), 8 * KvPool::page_bytes(&cfg, 4, KvDtype::F32));
    }

    #[test]
    fn bytes_accounting_q8() {
        // q8: d_model code bytes + n_heads (scale, zero) f32 pairs per
        // position per layer per {K,V}. tiny config (d=16, h=2):
        // 16 + 2·2·4 = 32 bytes vs f32's 64 — exactly 2× smaller.
        let cfg = tiny_config();
        let q = KvPool::new_with_dtype(&cfg, 8, 4, KvDtype::Q8);
        let per_pos = cfg.d_model + cfg.n_heads * 2 * 4;
        assert_eq!(q.bytes(), 2 * cfg.n_layers * 8 * 4 * per_pos);
        assert_eq!(q.bytes(), 8 * KvPool::page_bytes(&cfg, 4, KvDtype::Q8));
        let f = KvPool::new(&cfg, 8, 4);
        assert_eq!(f.bytes(), 2 * q.bytes());
    }

    #[test]
    fn dtype_parse_and_default() {
        assert_eq!(KvDtype::parse("f32"), Some(KvDtype::F32));
        assert_eq!(KvDtype::parse("q8"), Some(KvDtype::Q8));
        assert_eq!(KvDtype::parse("fp16"), None);
        assert_eq!(KvDtype::default(), KvDtype::F32);
        assert_eq!(KvDtype::F32.name(), "f32");
        assert_eq!(KvDtype::Q8.name(), "q8");
    }

    #[test]
    fn q8_rows_round_trip_within_step() {
        // Reading back a q8 row lands within one quantization step
        // (scale/2 per element) of the written floats, and re-reads are
        // bit-identical (quantize once at write, dequant is pure).
        let cfg = tiny_config();
        let d = cfg.d_model;
        let mut p = KvPool::new_with_dtype(&cfg, 4, 2, KvDtype::Q8);
        let mut s = SeqCache::new();
        assert!(p.reserve(&mut s, 5));
        for pos in 0..5 {
            let k: Vec<f32> = (0..d).map(|i| ((pos * d + i) as f32).sin()).collect();
            let v: Vec<f32> = k.iter().map(|x| -x * 0.5).collect();
            for l in 0..cfg.n_layers {
                p.write_row(&s, l, pos, &k, &v);
            }
            s.len = pos + 1;
            let mut kd = vec![0.0; d];
            let mut kd2 = vec![0.0; d];
            let mut vd = vec![0.0; d];
            p.read_k_row(&s, 0, pos, &mut kd);
            p.read_k_row(&s, 0, pos, &mut kd2);
            p.read_v_row(&s, 0, pos, &mut vd);
            assert_eq!(kd, kd2, "dequant must be deterministic");
            let scales = p.k_scales(&s, 0, pos);
            let hd = d / cfg.n_heads;
            for (i, (&x, &x_hat)) in k.iter().zip(&kd).enumerate() {
                let step = scales[2 * (i / hd)];
                assert!((x - x_hat).abs() <= step * 0.5 + 1e-6, "elem {i}: {x} vs {x_hat}");
            }
            assert!(vd.iter().zip(&v).all(|(a, b)| (a - b).abs() < 0.05));
        }
    }

    #[test]
    fn q8_constant_rows_are_exact() {
        // Flat heads get scale 0 / zero = value: constant rows survive
        // q8 bit-for-bit — the property the refcount fuzz tags rely on.
        let cfg = tiny_config();
        let d = cfg.d_model;
        let mut p = KvPool::new_with_dtype(&cfg, 2, 4, KvDtype::Q8);
        let mut s = SeqCache::new();
        assert!(p.reserve(&mut s, 1));
        p.write_row(&s, 0, 0, &vec![3.25; d], &vec![-7.5; d]);
        let (mut k, mut v) = (vec![0.0; d], vec![0.0; d]);
        p.read_k_row(&s, 0, 0, &mut k);
        p.read_v_row(&s, 0, 0, &mut v);
        assert_eq!(k, vec![3.25; d]);
        assert_eq!(v, vec![-7.5; d]);
    }

    #[test]
    fn q8_cow_copies_codes_and_scales_byte_identically() {
        let cfg = tiny_config();
        let d = cfg.d_model;
        let mut p = KvPool::new_with_dtype(&cfg, 8, 4, KvDtype::Q8);
        let mut a = SeqCache::new();
        assert!(p.reserve(&mut a, 6));
        for pos in 0..6 {
            // varied (non-flat) rows so scales are nontrivial
            let k: Vec<f32> = (0..d).map(|i| ((pos * 31 + i * 7) % 13) as f32 * 0.3 - 1.0).collect();
            let v: Vec<f32> = k.iter().map(|x| x * -1.7 + 0.2).collect();
            for l in 0..cfg.n_layers {
                p.write_row(&a, l, pos, &k, &v);
            }
        }
        a.len = 6;
        let parent_codes: Vec<Vec<u8>> = (0..6).map(|pos| p.k_codes(&a, 1, pos).to_vec()).collect();
        let parent_scales: Vec<Vec<f32>> =
            (0..6).map(|pos| p.k_scales(&a, 1, pos).to_vec()).collect();
        // fork mid-page: position 5 sits in a's second page (shared tail)
        let mut b = p.fork(&a, 5);
        assert!(p.reserve(&mut b, 6)); // triggers CoW of the tail page
        assert_ne!(b.pages()[1], a.pages()[1], "tail page must be copied");
        for pos in 0..5 {
            assert_eq!(p.k_codes(&b, 1, pos), &parent_codes[pos][..], "pos {pos} codes");
            assert_eq!(p.k_scales(&b, 1, pos), &parent_scales[pos][..], "pos {pos} scales");
            assert_eq!(p.v_codes(&b, 1, pos), p.v_codes(&a, 1, pos));
            assert_eq!(p.v_scales(&b, 1, pos), p.v_scales(&a, 1, pos));
        }
        // writing the child's tail leaves the parent's rows untouched
        for l in 0..cfg.n_layers {
            p.write_row(&b, l, 5, &vec![1.0; d], &vec![1.0; d]);
        }
        b.len = 6;
        assert_eq!(p.k_codes(&a, 1, 5), &parent_codes[5][..]);
        assert_eq!(p.k_scales(&a, 1, 5), &parent_scales[5][..]);
        p.release(&mut a);
        p.release(&mut b);
        assert_eq!(p.free_pages(), 8, "page leak after q8 CoW");
    }

    fn fill(p: &mut KvPool, s: &SeqCache, from: usize, to: usize, tag: f32) {
        let d = tiny_config().d_model;
        for pos in from..to {
            let row = vec![tag + pos as f32; d];
            for l in 0..2 {
                p.write_row(s, l, pos, &row, &row);
            }
        }
    }

    #[test]
    fn fork_shares_pages_without_copying() {
        let mut p = pool(8, 2);
        let mut a = SeqCache::new();
        assert!(p.reserve(&mut a, 6));
        fill(&mut p, &a, 0, 6, 100.0);
        a.len = 6;
        // fork 4 positions: maps the first 2 pages, refcounts go to 2
        let b = p.fork(&a, 4);
        assert_eq!(b.len, 4);
        assert_eq!(b.n_pages(), 2);
        assert_eq!(b.pages()[..2], a.pages()[..2]);
        assert_eq!(p.refcount(a.pages()[0]), 2);
        assert_eq!(p.refcount(a.pages()[1]), 2);
        assert_eq!(p.refcount(a.pages()[2]), 1);
        // no pages were consumed by the fork itself
        assert_eq!(p.free_pages(), 5);
        // forked view reads the parent's rows
        assert_eq!(p.k_row(&b, 0, 3)[0], 103.0);
    }

    #[test]
    fn cow_write_leaves_parent_rows_untouched() {
        let mut p = pool(8, 4);
        let mut a = SeqCache::new();
        assert!(p.reserve(&mut a, 6));
        fill(&mut p, &a, 0, 6, 100.0);
        a.len = 6;
        // fork mid-page: position 5 sits in a's second page (shared tail)
        let mut b = p.fork(&a, 5);
        assert!(p.cow_pending(&b));
        // reserve for the next write copies the shared tail page
        assert!(p.reserve(&mut b, 6));
        assert!(!p.cow_pending(&b));
        assert_ne!(b.pages()[1], a.pages()[1], "tail page must be copied");
        assert_eq!(p.refcount(a.pages()[1]), 1);
        // the copy carried the filled prefix row (position 4)
        assert_eq!(p.k_row(&b, 0, 4)[0], 104.0);
        let d = tiny_config().d_model;
        for l in 0..2 {
            p.write_row(&b, l, 5, &vec![-1.0; d], &vec![-1.0; d]);
        }
        b.len = 6;
        // parent still reads its own position-5 row
        assert_eq!(p.k_row(&a, 0, 5)[0], 105.0);
        assert_eq!(p.k_row(&b, 0, 5)[0], -1.0);
        p.release(&mut a);
        p.release(&mut b);
        assert_eq!(p.free_pages(), 8, "page leak after CoW");
    }

    #[test]
    fn page_aligned_fork_needs_no_cow() {
        let mut p = pool(8, 2);
        let mut a = SeqCache::new();
        assert!(p.reserve(&mut a, 4));
        fill(&mut p, &a, 0, 4, 10.0);
        a.len = 4;
        let mut b = p.fork(&a, 4); // exactly 2 full pages
        assert!(!p.cow_pending(&b));
        let free_before = p.free_pages();
        // growth allocates a fresh page; no CoW copy happens
        assert!(p.reserve(&mut b, 5));
        assert_eq!(p.free_pages(), free_before - 1);
        assert_eq!(b.pages()[..2], a.pages()[..2]);
        p.release(&mut a);
        p.release(&mut b);
        assert_eq!(p.free_pages(), 8);
    }

    #[test]
    fn release_frees_only_last_holder() {
        let mut p = pool(4, 2);
        let mut a = SeqCache::new();
        assert!(p.reserve(&mut a, 4));
        fill(&mut p, &a, 0, 4, 0.0);
        a.len = 4;
        let mut b = p.fork(&a, 4);
        p.release(&mut a);
        // b still holds both pages: nothing returned yet
        assert_eq!(p.free_pages(), 2);
        assert_eq!(p.refcount(b.pages()[0]), 1);
        p.release(&mut b);
        assert_eq!(p.free_pages(), 4);
    }

    #[test]
    fn retain_release_page_pins_like_a_holder() {
        let mut p = pool(4, 2);
        let mut a = SeqCache::new();
        assert!(p.reserve(&mut a, 2));
        let page = a.pages()[0];
        p.retain_page(page); // e.g. the prefix cache indexing this page
        p.release(&mut a);
        assert_eq!(p.free_pages(), 3, "cache hold must keep the page resident");
        assert_eq!(p.refcount(page), 1);
        p.release_page(page);
        assert_eq!(p.free_pages(), 4);
        assert_eq!(p.refcount(page), 0);
    }

    #[test]
    fn cow_respects_pool_exhaustion() {
        let mut p = pool(2, 2);
        let mut a = SeqCache::new();
        assert!(p.reserve(&mut a, 3));
        fill(&mut p, &a, 0, 3, 0.0);
        a.len = 3;
        let mut b = p.fork(&a, 3); // shares both pages; free list empty
        assert!(p.cow_pending(&b));
        // CoW needs a free page: exhaustion signals instead of corrupting
        assert!(!p.reserve(&mut b, 4));
        p.release(&mut a);
        // parent released its tail page hold; CoW can now proceed...
        // (page came back to the free list because b maps it too? no —
        // b still holds it, so refcount is 1 and no copy is needed)
        assert!(p.reserve(&mut b, 4));
        p.release(&mut b);
        assert_eq!(p.free_pages(), 2);
    }
}
