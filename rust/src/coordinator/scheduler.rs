//! Iteration-level (continuous-batching) scheduler — the multi-user
//! serving loop that replaces drain-then-run batching.
//!
//! The old worker loop served requests to completion one at a time, so
//! aggregate throughput under concurrent load was the single-stream
//! number. This scheduler advances EVERY in-flight sequence one token per
//! iteration through one batched [`CpuModel::decode_steps`] pass — N
//! sequences share each read of the (packed) weights, which is where
//! multi-user throughput comes from in the paper's bandwidth-bound
//! regime — with KV state in pages from a bounded [`KvPool`].
//!
//! One `step()` (a *tick*):
//! 1. **Shed/timeout**: queued requests past their TTFT or total
//!    deadline are shed (`TimedOut`, never admitted — the pool is not
//!    spent on an answer nobody is waiting for); running sequences past
//!    their total deadline are stopped, their pages reclaimed, and their
//!    partial tokens returned.
//! 2. **Admit** queued requests while slots (`max_batch`) and pool pages
//!    allow, in strict priority order: every queued `Interactive`
//!    request goes before any `Batch` one (within a class, FIFO). A
//!    class head that does not fit blocks lower classes too — skipping
//!    ahead would let Batch work starve the very Interactive request the
//!    classes exist to protect. Admission first consults the
//!    [`PrefixCache`]: the longest cached page-granular prefix of the
//!    prompt (capped at `plen − 1`, so the last prompt position is
//!    always recomputed — its logits pick the first token) is FORKED
//!    into the new sequence ([`KvPool::fork_pages`], a refcount bump)
//!    and only the uncached suffix is enqueued as chunked prefill. A
//!    request is admitted only when the pool can hold its remaining
//!    prompt + first token on top of what already-running sequences
//!    still need through their own prompts (including any pending
//!    copy-on-write page), so admission bursts don't overcommit the pool
//!    against prefill work (decode-phase growth is not reserved —
//!    preemption handles it).
//! 3. **Advance**: one batched decode sub-step over all running
//!    sequences — each consumes its next prompt token (chunked prefill)
//!    or its last generated token (decode) — then up to
//!    `prefill_chunk − 1` extra sub-steps for sequences still in
//!    prefill, so long prompts ramp quickly without stalling decoders
//!    for more than one token. A sequence finishing prefill indexes its
//!    full prompt pages into the prefix cache.
//! 4. **Reclaim**: finished sequences (max tokens, `max_seq`/pool length
//!    cap, the optional EOS byte, a deadline, or a cancellation) release
//!    their pages (shared pages stay resident for the cache and other
//!    forks) and emit a [`GenResponse`] tagged with its terminal
//!    [`GenOutcome`].
//!
//! **Lifecycle (DESIGN.md §Robustness).** Every `submit` leads to
//! exactly one terminal response. Validation is immediate:
//! `max_new_tokens == 0` is vacuously `Completed` (no compute spent),
//! an empty prompt is `Rejected` (no logits exist to pick a token
//! from). Overload is shed at submit by per-class queue bounds
//! (`max_queue_interactive` / `max_queue_batch`) — sizing the Batch
//! bound smaller makes overload reject Batch before it delays
//! Interactive. [`Scheduler::cancel`] resolves a queued or running
//! request to `Cancelled` (partial tokens returned); cancelling an
//! already-finished id is a no-op, preserving exactly-one-terminal.
//!
//! **Backpressure.** When [`KvPool::reserve`] fails, cold prefix-cache
//! pages are evicted first (LRU entries whose pages no live sequence
//! maps — DESIGN.md §Prefix cache); only if nothing is evictable is a
//! running sequence preempted — the youngest-admitted `Batch` sequence
//! if any is running, else the youngest overall (priority-then-youngest)
//! — its pages are reclaimed and its request goes back to the FRONT of
//! its class queue (original submit time kept, so queue-wait stays
//! honest) for a rerun — on re-admission it re-forks whatever prefix is
//! cached (often its own, indexed when its first run finished prefill),
//! so preempted work is largely recovered. Greedy decode is
//! deterministic, and seeded sampling draws every token from a
//! counter-based RNG keyed by `(seed, position)` (`sampling::uniform`),
//! so a rerun reproduces the same tokens either way. A lone
//! sequence can always finish: per-request length is capped at
//! admission to what the whole pool can hold, and every cache-only page
//! is eventually evictable, which keeps the loop deadlock-free.
//!
//! **Sampling & speculative decoding (DESIGN.md §Sampling &
//! Speculative decoding).** Token selection is per-request
//! [`sampling::SamplingParams`]: the default (temperature 0) routes through the
//! frozen `sampling::argmax` pick; anything else draws from the
//! filtered softmax with the counter-based RNG above. With
//! `cfg.spec` enabled (`--spec-decode` / `GPTQ_SPEC`), each decode
//! lane runs a speculative round per tick instead of a single step:
//! the SAME checkpoint repacked at 2–3 bits ([`CpuModel::to_draft`])
//! proposes up to `k` tokens on the lane's own KV pages (shared-KV
//! self-speculation: the draft attends the target's canonical rows,
//! writes provisional rows, and is rolled back), then ONE batched
//! [`CpuModel::decode_span`] pass through the target verifies the
//! whole span. Greedy acceptance is accept-iff-equal, so spec-on is
//! bit-identical to spec-off; sampled acceptance is standard rejection
//! sampling (accept with min(1, P/Q), resample rejections from
//! max(P − Q, 0)), which preserves the target distribution exactly.
//! Accepted rows ARE the target's rows — nothing is recomputed — and a
//! rejected tail is discarded by rolling `seq.len` back, which the
//! page-granular pool supports for free.
//!
//! **Fault injection.** `cfg.faults` (default: parsed from
//! `GPTQ_FAULTS`, i.e. off unless asked) arms the deterministic chaos
//! hooks (`util::faultinject`): a tick-boundary hook that can delay or
//! panic the worker BEFORE any state changes, and a reserve-site hook
//! that forces `KvPool::reserve` failures on a seeded counter schedule
//! to exercise eviction/preemption without real pool pressure. All
//! hooks are zero-cost when off, and the default config injects
//! nothing, so every determinism contract below is unchanged.
//!
//! **Parity contract.** Per sequence, scheduler output is identical to
//! the sequential single-stream decode — WITH OR WITHOUT the prefix
//! cache: a fork maps the very pages an identical earlier prefill
//! wrote, so attention reads the same f32 rows either way (dense
//! bit-identical, packed within 1e-5 — in practice also bit-identical),
//! and token selection is a pure function of `(logits, SamplingParams,
//! position)` (`sampling::sample`; greedy = the frozen `argmax`).
//! Speculative decoding preserves the contract: greedy accept-iff-equal
//! makes spec-on bit-identical to spec-off, so the same oracle covers
//! both. `tests/continuous_batching.rs` and `tests/prefix_cache.rs`
//! enforce this under `GPTQ_ISA={scalar,auto} × GPTQ_THREADS={1,4} ×
//! GPTQ_SPEC={off,k4}`.

use crate::coordinator::metrics::ServeMetrics;
use crate::coordinator::prefixcache::PrefixCache;
use crate::coordinator::sampling::{self, sample, SpecConfig};
use crate::coordinator::serve::{Class, GenOutcome, GenRequest, GenResponse};
use crate::model::{CpuModel, KvDtype, KvPool, SeqCache};
use crate::util::faultinject::{FaultConfig, FaultInjector};
use std::collections::VecDeque;
use std::time::Instant;

/// Knobs for one worker's scheduler (embedded in `ServerConfig`).
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// slot budget: max sequences in flight per worker
    pub max_batch: usize,
    /// KV pool budget, in pages
    pub pool_pages: usize,
    /// positions per page
    pub page_size: usize,
    /// max prompt tokens a prefilling sequence consumes per tick
    pub prefill_chunk: usize,
    /// optional stop byte: generation ends when it would be emitted
    pub eos: Option<u8>,
    /// share prompt-prefix KV across requests (the radix prompt cache);
    /// off = every request prefills from scratch (pre-prefix-cache
    /// behavior, bit-identical outputs either way)
    pub prefix_cache: bool,
    /// KV page storage precision (`--kv-dtype` / `GPTQ_KV_DTYPE`):
    /// `F32` is today's exact rows, `Q8` fits ≈4× the positions in the
    /// same bytes at a documented logit-drift cost (DESIGN.md §KV
    /// precision). Within either dtype the scheduler's parity contracts
    /// hold bitwise.
    pub kv_dtype: KvDtype,
    /// admission bound on the Interactive queue: a submit past it is
    /// answered `Rejected` immediately (default: unbounded)
    pub max_queue_interactive: usize,
    /// admission bound on the Batch queue — size it smaller than the
    /// Interactive bound so overload sheds Batch first
    pub max_queue_batch: usize,
    /// deterministic fault-injection schedule (chaos testing); default
    /// is `GPTQ_FAULTS` from the environment, i.e. no faults unless
    /// explicitly armed
    pub faults: FaultConfig,
    /// self-speculative decoding (`--spec-decode` / `GPTQ_SPEC`):
    /// disabled by default; when enabled each decode lane drafts up to
    /// `spec.k` tokens with the same checkpoint repacked at
    /// `spec.draft_bits` bits and verifies them in one batched target
    /// pass. Greedy output is bit-identical to spec-off.
    pub spec: SpecConfig,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            pool_pages: 64,
            page_size: 16,
            prefill_chunk: 4,
            eos: None,
            prefix_cache: true,
            // env-derived so the determinism suites (and anything else
            // built on the default config) flip to q8 pages under
            // GPTQ_KV_DTYPE=q8 without code changes; unset env = F32 =
            // bit-identical to the pre-dtype default
            kv_dtype: KvDtype::from_env(),
            max_queue_interactive: usize::MAX,
            max_queue_batch: usize::MAX,
            faults: FaultConfig::from_env(),
            // env-derived for the same reason as kv_dtype: the
            // determinism suites flip speculation on with GPTQ_SPEC=k4
            // and must see bit-identical token streams
            spec: SpecConfig::from_env(),
        }
    }
}

/// One in-flight sequence (admission order is preserved in
/// `Scheduler::running`; preemption picks the last `Batch` entry, else
/// the last entry).
struct Running {
    req: GenRequest,
    seq: SeqCache,
    /// prompt tokens consumed so far (prefill while `consumed < plen`);
    /// starts at the forked cached-prefix length, not 0
    consumed: usize,
    /// effective prompt length after the length cap
    plen: usize,
    /// hard length cap: min(max_seq, pool capacity) — guarantees a lone
    /// sequence always fits the pool
    limit: usize,
    /// prompt tokens whose KV was forked from the prefix cache at the
    /// last admission (prefill skipped for them)
    cached_prefix_len: usize,
    /// generated token awaiting its decode step
    next: Option<u8>,
    out: Vec<u8>,
    per_token_ms: Vec<f64>,
    prefill_ms: f64,
    submitted: Instant,
    admitted: Instant,
    ttft_ms: Option<f64>,
    /// how this sequence will be reported once `done` (deadline/cancel
    /// paths overwrite the `Completed` default before setting `done`)
    outcome: GenOutcome,
    done: bool,
}

/// Terminal response for a request that never reached a slot (validated
/// away at submit, shed from the queue, or cancelled while queued).
fn unadmitted_response(
    req: &GenRequest,
    queue_wait_ms: f64,
    outcome: GenOutcome,
    wid: usize,
) -> GenResponse {
    GenResponse {
        id: req.id,
        tokens: Vec::new(),
        per_token_ms: Vec::new(),
        prefill_ms: 0.0,
        queue_wait_ms,
        ttft_ms: None,
        cached_prefix_len: 0,
        outcome,
        worker: wid,
    }
}

/// Continuous-batching scheduler for one worker (see module docs).
pub struct Scheduler {
    wid: usize,
    model: CpuModel,
    /// low-bit repack of `model` used to propose speculative tokens;
    /// `Some` iff `cfg.spec.enabled()`
    draft: Option<CpuModel>,
    pool: KvPool,
    cache: PrefixCache,
    cfg: SchedulerConfig,
    /// one FIFO queue per [`Class`], indexed by `Class::idx()`;
    /// admission drains lower indices (higher priority) first
    queues: [VecDeque<(GenRequest, Instant)>; Class::COUNT],
    running: Vec<Running>,
    /// terminal responses produced outside a sub-step (submit-time
    /// validation, queue sheds, cancellations) — drained by `step()`
    done_buf: Vec<GenResponse>,
    metrics: ServeMetrics,
    preemptions: usize,
    faults: FaultInjector,
}

impl Scheduler {
    pub fn new(wid: usize, model: CpuModel, cfg: SchedulerConfig) -> Self {
        assert!(cfg.max_batch >= 1, "max_batch must be at least 1");
        let pool = KvPool::new_with_dtype(&model.config, cfg.pool_pages, cfg.page_size, cfg.kv_dtype);
        let cache = PrefixCache::new(cfg.page_size);
        let faults = FaultInjector::new(cfg.faults.clone(), wid);
        // the draft shares config/embeddings/KV layout with the target
        // by construction (same checkpoint, linear weights requantized)
        let draft = if cfg.spec.enabled() {
            Some(model.to_draft(cfg.spec.draft_bits))
        } else {
            None
        };
        Self {
            wid,
            model,
            draft,
            pool,
            cache,
            cfg,
            queues: [VecDeque::new(), VecDeque::new()],
            running: Vec::new(),
            done_buf: Vec::new(),
            metrics: ServeMetrics::new(),
            preemptions: 0,
            faults,
        }
    }

    /// Enqueue a request (FIFO within its class; queue-wait starts now).
    /// Degenerate requests resolve immediately (`max_new_tokens == 0` →
    /// `Completed`, empty prompt → `Rejected`), as does a submit past
    /// the class queue bound (`Rejected` — admission-time load
    /// shedding); their terminal responses surface from the next
    /// `step()`.
    pub fn submit(&mut self, req: GenRequest) {
        if req.max_new_tokens == 0 {
            // zero tokens requested: vacuously complete, zero compute
            self.finish_unadmitted(req, GenOutcome::Completed);
            return;
        }
        if req.prompt.is_empty() {
            // no prompt position exists to produce first-token logits
            self.finish_unadmitted(req, GenOutcome::Rejected);
            return;
        }
        let bound = match req.priority {
            Class::Interactive => self.cfg.max_queue_interactive,
            Class::Batch => self.cfg.max_queue_batch,
        };
        let q = req.priority.idx();
        if self.queues[q].len() >= bound {
            self.finish_unadmitted(req, GenOutcome::Rejected);
            return;
        }
        self.queues[q].push_back((req, Instant::now()));
    }

    fn finish_unadmitted(&mut self, req: GenRequest, outcome: GenOutcome) {
        self.metrics.record_outcome(outcome);
        if outcome == GenOutcome::Completed {
            self.metrics.no_token_requests += 1;
        }
        self.done_buf.push(unadmitted_response(&req, 0.0, outcome, self.wid));
    }

    /// Cooperatively cancel request `id`. Queued → resolved `Cancelled`
    /// immediately; running → stopped at the current token (partial
    /// output returned as `Cancelled`); unknown/finished id → `false`
    /// (its terminal response already exists — never a second one).
    pub fn cancel(&mut self, id: u64) -> bool {
        for q in 0..self.queues.len() {
            if let Some(i) = self.queues[q].iter().position(|(r, _)| r.id == id) {
                let (req, submitted) = self.queues[q].remove(i).unwrap();
                self.metrics.record_outcome(GenOutcome::Cancelled);
                self.done_buf.push(unadmitted_response(
                    &req,
                    ms_since(submitted),
                    GenOutcome::Cancelled,
                    self.wid,
                ));
                return true;
            }
        }
        if let Some(r) = self.running.iter_mut().find(|r| r.req.id == id && !r.done) {
            r.done = true;
            r.outcome = GenOutcome::Cancelled;
            return true;
        }
        false
    }

    /// Nothing queued, nothing in flight, no terminal response pending.
    pub fn is_idle(&self) -> bool {
        self.queues.iter().all(|q| q.is_empty())
            && self.running.is_empty()
            && self.done_buf.is_empty()
    }

    /// Queued requests across every class.
    pub fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    pub fn in_flight(&self) -> usize {
        self.running.len()
    }

    pub fn free_pages(&self) -> usize {
        self.pool.free_pages()
    }

    pub fn total_pages(&self) -> usize {
        self.pool.total_pages()
    }

    /// Fraction of the KV pool currently in use (live sequences plus
    /// prefix-cache holds) — the saturation signal the overload bench
    /// reports.
    pub fn pool_utilization(&self) -> f64 {
        self.pool.utilization()
    }

    /// Pages currently pinned by the prefix cache alone. At idle,
    /// `free_pages() + cached_pages() == total_pages()` — the pool-leak
    /// invariant with prefix sharing on.
    pub fn cached_pages(&self) -> usize {
        self.cache.pages_held()
    }

    /// Drop every prefix-cache hold (tests; also proves the cache is the
    /// only thing between `free_pages` and `total_pages` at idle).
    pub fn clear_prefix_cache(&mut self) {
        self.cache.clear(&mut self.pool);
    }

    /// Test/teardown assertion of the idle-pool invariant: every page is
    /// either free or pinned by the prefix cache, and dropping the cache
    /// returns all of them. DESTRUCTIVE — empties the prefix cache; the
    /// single copy of the leak check every suite tears down with.
    pub fn assert_no_page_leak(&mut self) {
        assert!(self.is_idle(), "leak check requires an idle scheduler");
        assert_eq!(
            self.free_pages() + self.cached_pages(),
            self.total_pages(),
            "page leak (free {} + cached {} != total {})",
            self.free_pages(),
            self.cached_pages(),
            self.total_pages()
        );
        self.clear_prefix_cache();
        assert_eq!(self.free_pages(), self.total_pages(), "page leak after cache clear");
    }

    /// Pool-exhaustion preemptions so far (backpressure events).
    pub fn preemptions(&self) -> usize {
        self.preemptions
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    pub fn into_metrics(self) -> ServeMetrics {
        self.metrics
    }

    /// One scheduler iteration; returns the requests that reached a
    /// terminal state during it (completions, sheds, timeouts,
    /// cancellations, submit-time validations).
    pub fn step(&mut self) -> Vec<GenResponse> {
        // fault hook first, BEFORE any state changes: an injected panic
        // here leaves a clean slate for the server's replay
        self.faults.on_tick();
        let mut done = std::mem::take(&mut self.done_buf);
        self.shed_expired(&mut done);
        self.timeout_running();
        // reclaim timed-out sequences before admitting against the pool
        self.harvest(&mut done);
        self.admit();
        done.append(&mut self.done_buf); // degenerate admissions
        for substep in 0..self.cfg.prefill_chunk.max(1) {
            let idx = self.reserve_active(substep);
            if idx.is_empty() {
                break;
            }
            self.advance(&idx);
            self.harvest(&mut done);
        }
        done
    }

    /// Drive until queue and batch are empty; returns every response.
    pub fn run_until_idle(&mut self) -> Vec<GenResponse> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step());
        }
        out
    }

    /// Shed queued requests whose TTFT (or total) deadline has already
    /// passed: they are answered `TimedOut` without ever taking a slot
    /// or pool pages — by the time they would run, nobody is waiting.
    fn shed_expired(&mut self, done: &mut Vec<GenResponse>) {
        for q in 0..self.queues.len() {
            let mut i = 0;
            while i < self.queues[q].len() {
                let (req, submitted) = &self.queues[q][i];
                let waited = ms_since(*submitted);
                let expired = req.ttft_deadline_ms.map_or(false, |d| waited >= d)
                    || req.deadline_ms.map_or(false, |d| waited >= d);
                if expired {
                    let (req, _) = self.queues[q].remove(i).unwrap();
                    self.metrics.record_outcome(GenOutcome::TimedOut);
                    done.push(unadmitted_response(&req, waited, GenOutcome::TimedOut, self.wid));
                } else {
                    i += 1;
                }
            }
        }
    }

    /// Stop running sequences past their total deadline: marked done as
    /// `TimedOut`, pages reclaimed by the next harvest, partial tokens
    /// returned.
    fn timeout_running(&mut self) {
        for r in &mut self.running {
            if !r.done && r.req.deadline_ms.map_or(false, |d| ms_since(r.submitted) >= d) {
                r.done = true;
                r.outcome = GenOutcome::TimedOut;
            }
        }
    }

    /// Admission control: strict priority across class queues, FIFO
    /// within one, while a slot is free and the pool can hold the
    /// prompt's uncached remainder plus the first generated token. On a
    /// gate shortfall the candidate's fork is released before cache
    /// eviction runs (see the comment at the gate: holding it could pin
    /// the shortfall forever), then the request is retried from scratch
    /// if eviction reclaimed anything.
    fn admit(&mut self) {
        // shortfall at the last gate failure for the current queue head
        // (usize::MAX = fresh candidate): eviction retries must shrink
        // it or stop — see the progress check at the gate
        let mut prev_short = usize::MAX;
        while self.running.len() < self.cfg.max_batch {
            // highest-priority non-empty queue; its head is THE next
            // admission — a head that doesn't fit blocks lower classes
            // (skipping ahead would starve the class we protect)
            let Some(qi) = (0..self.queues.len()).find(|&q| !self.queues[q].is_empty()) else {
                break;
            };
            let Some(&(ref req, _)) = self.queues[qi].front() else { break };
            let limit = self
                .model
                .config
                .max_seq
                .min(self.pool.total_pages() * self.pool.page_size());
            let plen = req.prompt.len().min(limit.saturating_sub(1));
            // longest cached prefix, capped at plen − 1: the final prompt
            // position is always recomputed because its logits choose the
            // first generated token
            let (seq, cached) = if self.cfg.prefix_cache && plen > 1 {
                let pages = self.cache.lookup(&req.prompt[..plen]);
                let cached = (pages.len() * self.pool.page_size()).min(plen - 1);
                if cached > 0 {
                    (self.pool.fork_pages(&pages, cached), cached)
                } else {
                    (SeqCache::new(), 0)
                }
            } else {
                (SeqCache::new(), 0)
            };
            // pool gate: room for the uncached prompt remainder + first
            // token AFTER the pages already-running sequences still need
            // to finish their own prompts (+ next position once decoding,
            // + a copy-on-write page where a fork tail is still shared) —
            // so a burst of admissions can't overcommit the pool against
            // prefill work. Decode-phase growth past the first token is
            // not reserved; that is what preemption is for.
            let committed: usize = self
                .running
                .iter()
                .filter(|r| !r.done)
                .map(|r| {
                    let target = (r.plen + 1).min(r.limit).max(r.seq.len + 1);
                    self.pool.pages_for(target).saturating_sub(r.seq.n_pages())
                        + self.pool.cow_pending(&r.seq) as usize
                })
                .sum();
            let fresh = self.pool.pages_for(plen + 1).saturating_sub(seq.n_pages())
                + self.pool.cow_pending(&seq) as usize;
            let need = committed + fresh;
            if self.pool.free_pages() < need {
                // Pool pressure. Drop the fork's holds BEFORE evicting:
                // a fork pins its pages at refcount ≥ 2, so a shortfall
                // pinned by our own fork would survive eviction and this
                // admit would repeat identically every tick (livelock —
                // e.g. a near-pool-sized cached prefix plus its CoW
                // page). Un-forked, every cold cache page is evictable;
                // the lookup just bumped this prefix's LRU stamps, so
                // its pages go last and a retry usually re-forks them.
                let mut seq = seq;
                self.pool.release(&mut seq);
                let short = need - self.pool.free_pages();
                // Progress check: evicting a page of this request's OWN
                // matched prefix frees one page but raises `fresh` by
                // one — shortfall unchanged — so when the pressure comes
                // from running sequences' reservations, retrying would
                // churn away the whole cached prefix for nothing. Stop
                // as soon as a retry fails to shrink the shortfall.
                if short >= prev_short {
                    break;
                }
                if self.cfg.prefix_cache && self.cache.evict(&mut self.pool, short) > 0 {
                    // pages reclaimed: retry this request from scratch
                    // (fresh lookup — the prefix may be partly gone)
                    prev_short = short;
                    continue;
                }
                break; // nothing reclaimable: wait for running sequences
            }
            prev_short = usize::MAX; // next queue head starts fresh
            let (req, submitted) = self.queues[qi].pop_front().unwrap();
            let admitted = Instant::now();
            if self.cfg.prefix_cache && plen > 1 {
                self.metrics.prefix_lookups += 1;
                if cached > 0 {
                    self.metrics.prefix_hits += 1;
                    self.metrics.prefill_tokens_saved += cached;
                }
            }
            if plen == 0 {
                // defensive: submit-level validation rejects empty
                // prompts, so plen == 0 here means the length cap ate the
                // whole prompt (a pool smaller than one position) —
                // nothing can run, reject rather than fabricate tokens
                let mut seq = seq;
                self.pool.release(&mut seq);
                self.metrics.record_outcome(GenOutcome::Rejected);
                self.done_buf.push(unadmitted_response(
                    &req,
                    (admitted - submitted).as_secs_f64() * 1e3,
                    GenOutcome::Rejected,
                    self.wid,
                ));
                continue;
            }
            self.running.push(Running {
                req,
                seq,
                consumed: cached,
                plen,
                limit,
                cached_prefix_len: cached,
                next: None,
                out: Vec::new(),
                per_token_ms: Vec::new(),
                prefill_ms: 0.0,
                submitted,
                admitted,
                ttft_ms: None,
                outcome: GenOutcome::Completed,
                done: false,
            });
        }
    }

    /// The indices (into `running`, ascending) active in `substep`, with
    /// pool pages reserved for each one's next position (the reserve
    /// also performs copy-on-write when a fork's tail page is shared).
    /// Pool exhaustion evicts cold prefix-cache pages first, then
    /// preempts priority-then-youngest: the youngest-admitted `Batch`
    /// sequence if one is running, else the youngest overall (FIFO
    /// re-queue at the front of its class, original submit time kept).
    /// An injected reserve failure (`cfg.faults`) takes the same
    /// preemption path, minus real eviction — that is the point: chaos
    /// runs exercise backpressure without needing a truly full pool.
    fn reserve_active(&mut self, substep: usize) -> Vec<usize> {
        'retry: loop {
            let idx: Vec<usize> = self
                .running
                .iter()
                .enumerate()
                .filter(|(_, r)| !r.done && (substep == 0 || r.consumed < r.plen))
                .map(|(i, _)| i)
                .collect();
            for &i in &idx {
                let need = self.running[i].seq.len + 1;
                let injected = self.faults.inject_reserve_failure();
                if !injected && self.pool.reserve(&mut self.running[i].seq, need) {
                    continue;
                }
                // cold cache pages go before live work does (a forced
                // failure skips eviction — the pool isn't actually full)
                if !injected && self.cfg.prefix_cache && self.cache.evict(&mut self.pool, 1) > 0 {
                    continue 'retry;
                }
                if self.running.len() <= 1 {
                    if injected {
                        // forced failure on a lone sequence: nothing to
                        // preempt, so just stall this tick and retry —
                        // the counter advances, so a p < 1 schedule
                        // eventually lets it through
                        return Vec::new();
                    }
                    // unreachable: a lone sequence's length is capped
                    // to the pool at admission and every cache-only
                    // page is evictable — defensive truncation
                    debug_assert!(false, "lone sequence exhausted the pool");
                    self.running[i].done = true;
                    return Vec::new();
                }
                let vi = self
                    .running
                    .iter()
                    .rposition(|r| r.req.priority == Class::Batch && !r.done)
                    .unwrap_or(self.running.len() - 1);
                let mut victim = self.running.remove(vi);
                self.pool.release(&mut victim.seq);
                self.queues[victim.req.priority.idx()]
                    .push_front((victim.req, victim.submitted));
                self.preemptions += 1;
                continue 'retry;
            }
            return idx;
        }
    }

    /// One sub-step over the sequences at `idx`. Without speculation
    /// everything runs through the batched step; with a draft model,
    /// prefilling lanes still batch together and each decode lane runs
    /// one speculative round instead (decode lanes only appear at
    /// `substep == 0`, so a lane gets exactly one round per tick).
    fn advance(&mut self, idx: &[usize]) {
        if self.draft.is_none() {
            self.advance_batched(idx);
            return;
        }
        let (prefill, decode): (Vec<usize>, Vec<usize>) = idx
            .iter()
            .copied()
            .partition(|&i| self.running[i].consumed < self.running[i].plen);
        if !prefill.is_empty() {
            self.advance_batched(&prefill);
        }
        for &i in &decode {
            self.spec_round(i);
        }
    }

    /// One batched decode sub-step over the sequences at `idx`.
    fn advance_batched(&mut self, idx: &[usize]) {
        let toks: Vec<u8> = idx
            .iter()
            .map(|&i| {
                let r = &self.running[i];
                if r.consumed < r.plen {
                    r.req.prompt[r.consumed]
                } else {
                    r.next.expect("decoding sequence without a pending token")
                }
            })
            .collect();
        let mut want = idx.iter().copied().peekable();
        let mut seqs: Vec<&mut SeqCache> = Vec::with_capacity(idx.len());
        for (i, r) in self.running.iter_mut().enumerate() {
            if want.peek() == Some(&i) {
                want.next();
                seqs.push(&mut r.seq);
            }
        }
        let t0 = Instant::now();
        let logits = self.model.decode_steps(&mut self.pool, &mut seqs, &toks);
        drop(seqs);
        let ms = t0.elapsed().as_secs_f64() * 1e3;

        let vocab = self.model.config.vocab;
        for (k, &i) in idx.iter().enumerate() {
            let lg = &logits[k * vocab..(k + 1) * vocab];
            let r = &mut self.running[i];
            if r.consumed < r.plen {
                // prefill step
                r.consumed += 1;
                r.prefill_ms += ms;
                if r.consumed == r.plen {
                    // prompt done — index its full KV pages so later
                    // requests (and this one, if preempted) skip the
                    // shared prefix, then pick the first token from
                    // these logits
                    if self.cfg.prefix_cache {
                        self.cache.insert(&mut self.pool, &r.req.prompt[..r.plen], &r.seq);
                    }
                    // position key = seq.len AFTER the step = where the
                    // picked token will be consumed — replay-stable
                    // across preemption because it only depends on how
                    // far the sequence has progressed
                    let t = sample(lg, &r.req.sampling, r.seq.len);
                    if self.cfg.eos == Some(t) {
                        r.done = true;
                    } else {
                        // a token will actually be emitted: TTFT
                        r.ttft_ms = Some(ms_since(r.submitted));
                        r.next = Some(t);
                    }
                }
            } else {
                // decode step: consumed the pending generated token
                let tok = r.next.take().expect("decode step without pending token");
                r.out.push(tok);
                r.per_token_ms.push(ms);
                if r.out.len() >= r.req.max_new_tokens || r.seq.len >= r.limit {
                    r.done = true;
                } else {
                    let t = sample(lg, &r.req.sampling, r.seq.len);
                    if self.cfg.eos == Some(t) {
                        r.done = true;
                    } else {
                        r.next = Some(t);
                    }
                }
            }
        }
    }

    /// One speculative round for the decode lane at `i`: the draft
    /// proposes up to `cfg.spec.k` tokens on the lane's own KV pages,
    /// the target verifies the whole span (pending token + proposals)
    /// in ONE batched `decode_span` pass, and a unified acceptance loop
    /// replays the sequential decode arm exactly — same pick function,
    /// same position keys, same done/EOS checks in the same order — so
    /// greedy output is bit-identical to the non-speculative path and
    /// sampled output follows the exact target distribution (rejection
    /// sampling). Any shortfall (no token budget, no pages) falls back
    /// to one plain batched step.
    fn spec_round(&mut self, i: usize) {
        let (n, limit, budget) = {
            let r = &self.running[i];
            (r.seq.len, r.limit, r.req.max_new_tokens - r.out.len())
        };
        // proposals past the length cap or the remaining token budget
        // are dead work; the -1s leave room for the bonus/final token
        let k_eff = self
            .cfg
            .spec
            .k
            .min(limit.saturating_sub(n + 1))
            .min(budget.saturating_sub(1));
        if k_eff == 0 {
            self.advance_batched(&[i]);
            return;
        }
        // extend the lane's single-token reservation (already made by
        // reserve_active) to the span + bonus token. A shortfall is not
        // worth evicting or preempting over — speculation is optional
        // work — so it degrades to the plain step. This reserve also
        // deliberately bypasses the fault-injection hook: injected
        // failures police the mandatory reserve in reserve_active.
        if !self.pool.reserve(&mut self.running[i].seq, n + k_eff + 1) {
            self.advance_batched(&[i]);
            return;
        }
        let t0 = Instant::now();
        let params = self.running[i].req.sampling;
        let t_first = self.running[i]
            .next
            .expect("speculative round without a pending token");

        // --- draft phase: propose k_eff tokens on the SHARED pool.
        // The draft reads the target's canonical rows 0..n and writes
        // provisional rows n..n+k_eff-1, which the rollback below
        // discards (the verify pass overwrites them with target rows).
        let mut toks: Vec<u8> = Vec::with_capacity(k_eff + 1);
        toks.push(t_first);
        // per-proposal draft distribution Q (empty when greedy: the
        // accept rule there is token equality, no densities needed)
        let mut draft_q: Vec<Vec<f64>> = Vec::with_capacity(k_eff);
        for j in 0..k_eff {
            let fed = toks[j];
            let draft = self.draft.as_mut().expect("spec_round without draft");
            let lg = {
                let mut seqs = [&mut self.running[i].seq];
                draft.decode_steps(&mut self.pool, &mut seqs[..], &[fed])
            };
            // consume position of this proposal — the SAME key the
            // sequential pick would use, so a greedy draft proposes
            // exactly what the target would pick whenever their logits
            // agree on the argmax
            let pos = self.running[i].seq.len;
            if params.is_greedy() {
                toks.push(sampling::argmax(&lg));
                draft_q.push(Vec::new());
            } else {
                let q = sampling::distribution(&lg, &params);
                let u = sampling::uniform(params.seed, pos, sampling::STREAM_PICK);
                toks.push(sampling::pick(&q, u));
                draft_q.push(q);
            }
        }
        // roll back the draft's provisional rows (page-granular pool:
        // truncating len is free and keeps the pages reserved)
        self.running[i].seq.len = n;

        // --- verify phase: one batched pass through the TARGET kernels
        // over the whole span. Row j's logits are the target's logits
        // after consuming toks[..=j] — bitwise equal to j sequential
        // decode steps (per-lane batch-size independence).
        let logits = self
            .model
            .decode_span(&mut self.pool, &mut self.running[i].seq, &toks);
        let ms = t0.elapsed().as_secs_f64() * 1e3;

        // --- acceptance: replay the sequential decode arm per span row
        let vocab = self.model.config.vocab;
        let eos = self.cfg.eos;
        let r = &mut self.running[i];
        let mut final_len = n;
        let mut accepted = 0usize;
        let mut emitted = 0usize;
        // at entry r.next = Some(toks[0]); each accepted iteration
        // conceptually takes it and re-arms it with the next proposal
        r.next = None;
        'accept: for j in 0..=k_eff {
            // the sequential arm would consume toks[j] now
            r.out.push(toks[j]);
            emitted += 1;
            let vlen = n + j + 1; // seq.len after that sequential step
            final_len = vlen;
            if r.out.len() >= r.req.max_new_tokens || vlen >= r.limit {
                r.done = true;
                break 'accept;
            }
            let lg = &logits[j * vocab..(j + 1) * vocab];
            let t = if params.is_greedy() {
                // accept-iff-equal: the target's frozen pick either
                // confirms the proposal (continue down the span) or
                // replaces it (truncate here) — indistinguishable from
                // never having speculated
                let t = sampling::argmax(lg);
                if j < k_eff && eos != Some(t) && toks[j + 1] == t {
                    accepted += 1;
                    continue 'accept;
                }
                t
            } else if j < k_eff {
                // rejection sampling: accept proposal d with
                // min(1, P(d)/Q(d)), else resample from max(P-Q, 0)+
                let p = sampling::distribution(lg, &params);
                let d = toks[j + 1] as usize;
                let q = &draft_q[j];
                let ratio = if q[d] > 0.0 { (p[d] / q[d]).min(1.0) } else { 0.0 };
                let u = sampling::uniform(params.seed, vlen, sampling::STREAM_ACCEPT);
                if u < ratio {
                    if eos == Some(d as u8) {
                        r.done = true;
                        break 'accept;
                    }
                    accepted += 1;
                    continue 'accept;
                }
                let mut resid: Vec<f64> =
                    p.iter().zip(q.iter()).map(|(&pv, &qv)| (pv - qv).max(0.0)).collect();
                let mass: f64 = resid.iter().sum();
                if mass > 0.0 {
                    for v in &mut resid {
                        *v /= mass;
                    }
                } else {
                    // P == Q pointwise: the residual is empty only when
                    // the distributions coincide, so any P-draw is fine
                    resid = p;
                }
                sampling::pick(
                    &resid,
                    sampling::uniform(params.seed, vlen, sampling::STREAM_RESIDUAL),
                )
            } else {
                // bonus position past the last proposal: a fresh pick,
                // exactly what the sequential arm does at this position
                sampling::pick(
                    &sampling::distribution(lg, &params),
                    sampling::uniform(params.seed, vlen, sampling::STREAM_PICK),
                )
            };
            if eos == Some(t) {
                r.done = true;
            } else {
                r.next = Some(t);
            }
            break 'accept;
        }
        // keep exactly the rows whose tokens were emitted; the pool
        // reclaims the rejected tail implicitly (len rollback)
        r.seq.len = final_len;
        // one round produced `emitted` tokens in `ms` — amortize so
        // per-token latency metrics stay comparable with spec off
        let per = ms / emitted as f64;
        for _ in 0..emitted {
            r.per_token_ms.push(per);
        }
        self.metrics.spec_rounds += 1;
        self.metrics.spec_proposed += k_eff;
        self.metrics.spec_accepted += accepted;
    }

    /// Move finished sequences out of the batch: release pages (shared
    /// ones stay resident for the cache/other forks), record metrics,
    /// emit outcome-tagged responses (admission order preserved for the
    /// rest).
    fn harvest(&mut self, done: &mut Vec<GenResponse>) {
        let mut i = 0;
        while i < self.running.len() {
            if !self.running[i].done {
                i += 1;
                continue;
            }
            let mut r = self.running.remove(i);
            self.pool.release(&mut r.seq);
            let queue_wait_ms = (r.admitted - r.submitted).as_secs_f64() * 1e3;
            for &ms in &r.per_token_ms {
                self.metrics.per_token.record_ms(ms);
            }
            self.metrics.prefill.record_ms(r.prefill_ms);
            // requests that emit no token have no first-token time — the
            // old code recorded a 0.0 sentinel here, dragging TTFT p50
            // down; legit empty completions (EOS-first) are counted
            // separately instead
            match r.ttft_ms {
                Some(t) => {
                    self.metrics.ttft.record_ms(t);
                    self.metrics.ttft_class_mut(r.req.priority).record_ms(t);
                }
                None => {
                    if r.outcome == GenOutcome::Completed {
                        self.metrics.no_token_requests += 1;
                    }
                }
            }
            self.metrics.queue_wait.record_ms(queue_wait_ms);
            self.metrics.record_outcome(r.outcome);
            done.push(GenResponse {
                id: r.req.id,
                tokens: r.out,
                per_token_ms: r.per_token_ms,
                prefill_ms: r.prefill_ms,
                queue_wait_ms,
                ttft_ms: r.ttft_ms,
                cached_prefix_len: r.cached_prefix_len,
                outcome: r.outcome,
                worker: self.wid,
            });
        }
    }
}

fn ms_since(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sampling::SamplingParams;
    use crate::model::testkit::tiny_checkpoint;

    fn sched(cfg: SchedulerConfig) -> Scheduler {
        Scheduler::new(0, CpuModel::from_checkpoint(&tiny_checkpoint(7)), cfg)
    }

    fn req(id: u64, prompt: Vec<u8>, max_new: usize) -> GenRequest {
        GenRequest::new(id, prompt, max_new)
    }

    /// Shorthand for the shared idle-pool invariant check.
    fn assert_no_leak(s: &mut Scheduler) {
        s.assert_no_page_leak();
    }

    #[test]
    fn completes_one_request() {
        let mut s = sched(SchedulerConfig::default());
        s.submit(req(1, vec![1, 2, 3], 4));
        let rs = s.run_until_idle();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].tokens.len(), 4);
        assert_eq!(rs[0].per_token_ms.len(), 4);
        assert_eq!(rs[0].outcome, GenOutcome::Completed);
        assert!(rs[0].ttft_ms.unwrap() >= rs[0].queue_wait_ms);
        assert_eq!(rs[0].cached_prefix_len, 0, "cold cache cannot hit");
        assert_no_leak(&mut s);
        assert_eq!(s.metrics().requests(), 1);
        assert_eq!(s.metrics().per_token.count(), 4);
        assert_eq!(s.metrics().completed, 1);
        assert_eq!(s.metrics().terminals(), 1);
    }

    #[test]
    fn batch_advances_together_and_all_complete() {
        let mut s = sched(SchedulerConfig { max_batch: 4, ..Default::default() });
        for i in 0..6 {
            s.submit(req(i, vec![(i % 16) as u8; (i as usize % 5) + 1], 3));
        }
        let rs = s.run_until_idle();
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..6).collect::<Vec<_>>());
        assert!(rs.iter().all(|r| r.tokens.len() == 3));
        assert_no_leak(&mut s);
    }

    #[test]
    fn tiny_pool_backpressures_but_completes() {
        // 4 pages × 2 positions = 8 cached positions shared by 4 slots:
        // forces preemption with 6-long sequences
        let cfg = SchedulerConfig {
            max_batch: 4,
            pool_pages: 4,
            page_size: 2,
            prefill_chunk: 2,
            ..Default::default()
        };
        let mut s = sched(cfg);
        for i in 0..8 {
            s.submit(req(i, vec![3, 1, 4], 3));
        }
        let mut steps = 0;
        let mut rs = Vec::new();
        while !s.is_idle() {
            rs.extend(s.step());
            steps += 1;
            assert!(steps < 10_000, "scheduler deadlocked");
        }
        assert_eq!(rs.len(), 8);
        assert!(rs.iter().all(|r| r.tokens.len() == 3));
        assert!(rs.iter().all(|r| r.outcome == GenOutcome::Completed));
        assert_no_leak(&mut s);
    }

    #[test]
    fn identical_prompts_share_their_prefix_pages() {
        // page_size 2, prompt of 5 tokens → 2 full pages cacheable; the
        // second request should fork 4 tokens and prefill only the rest
        let cfg = SchedulerConfig {
            max_batch: 1, // serialize so the first request is indexed first
            pool_pages: 16,
            page_size: 2,
            ..Default::default()
        };
        let mut s = sched(cfg);
        s.submit(req(0, vec![5, 6, 7, 8, 9], 2));
        s.submit(req(1, vec![5, 6, 7, 8, 9], 2));
        let rs = s.run_until_idle();
        let by_id = |id: u64| rs.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(0).cached_prefix_len, 0);
        assert_eq!(by_id(1).cached_prefix_len, 4);
        // identical prompt → identical greedy continuation, shared pages
        // or not (the parity contract)
        assert_eq!(by_id(0).tokens, by_id(1).tokens);
        let m = s.metrics();
        assert_eq!(m.prefix_lookups, 2);
        assert_eq!(m.prefix_hits, 1);
        assert_eq!(m.prefill_tokens_saved, 4);
        assert!((m.cache_hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(s.cached_pages(), 2, "two full prompt pages indexed");
        assert_no_leak(&mut s);
    }

    #[test]
    fn prefix_cache_off_never_shares() {
        let cfg = SchedulerConfig {
            max_batch: 1,
            pool_pages: 16,
            page_size: 2,
            prefix_cache: false,
            ..Default::default()
        };
        let mut s = sched(cfg);
        s.submit(req(0, vec![5, 6, 7, 8, 9], 2));
        s.submit(req(1, vec![5, 6, 7, 8, 9], 2));
        let rs = s.run_until_idle();
        assert!(rs.iter().all(|r| r.cached_prefix_len == 0));
        assert_eq!(s.metrics().prefix_lookups, 0);
        assert_eq!(s.metrics().prefill_tokens_saved, 0);
        assert_eq!(s.cached_pages(), 0);
        assert_eq!(s.free_pages(), s.total_pages());
    }

    #[test]
    fn eos_stops_generation_early() {
        // find the first greedy token, then rerun with it as EOS
        let mut probe = sched(SchedulerConfig::default());
        probe.submit(req(0, vec![5, 6], 4));
        let first = probe.run_until_idle()[0].tokens[0];
        let mut s = sched(SchedulerConfig { eos: Some(first), ..Default::default() });
        s.submit(req(0, vec![5, 6], 4));
        let rs = s.run_until_idle();
        assert!(rs[0].tokens.is_empty(), "EOS should suppress generation");
        assert_eq!(rs[0].outcome, GenOutcome::Completed, "EOS-first is a legit completion");
        assert_eq!(rs[0].ttft_ms, None, "no token, no TTFT sample");
        assert_eq!(s.metrics().ttft.count(), 0);
        assert_eq!(s.metrics().no_token_requests, 1);
        assert_no_leak(&mut s);
    }

    #[test]
    fn zero_max_tokens_and_empty_prompt_get_immediate_outcomes() {
        // satellite: validation at submit, with documented semantics —
        // neither request takes a slot, pool pages, or a prefill pass
        let mut s = sched(SchedulerConfig::default());
        s.submit(req(0, vec![1, 2], 0));
        s.submit(req(1, vec![], 2));
        assert!(!s.is_idle(), "pending terminal responses keep the scheduler live");
        let rs = s.run_until_idle();
        assert_eq!(rs.len(), 2);
        let by_id = |id: u64| rs.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(0).outcome, GenOutcome::Completed, "zero tokens = vacuously done");
        assert_eq!(by_id(1).outcome, GenOutcome::Rejected, "empty prompt has no logits");
        assert!(by_id(0).tokens.is_empty() && by_id(1).tokens.is_empty());
        assert_eq!(by_id(0).ttft_ms, None);
        assert_eq!(s.metrics().requests(), 0, "neither request was admitted");
        assert_eq!(s.metrics().ttft.count(), 0, "no 0.0 sentinel in TTFT");
        assert_eq!(s.metrics().completed, 1);
        assert_eq!(s.metrics().rejected, 1);
        assert_eq!(s.metrics().no_token_requests, 1);
        assert_eq!(s.metrics().terminals(), 2);
        assert_no_leak(&mut s);
    }

    #[test]
    fn long_prompt_truncates_to_limit() {
        let mut s = sched(SchedulerConfig::default());
        // tiny max_seq = 16: prompt 30 truncates to 15, one token fits
        s.submit(req(0, vec![1; 30], 30));
        let rs = s.run_until_idle();
        assert_eq!(rs[0].tokens.len(), 1);
        assert_eq!(rs[0].outcome, GenOutcome::Completed);
    }

    #[test]
    fn full_prefix_hit_still_recomputes_last_prompt_token() {
        // prompt length = 3 pages exactly; a full-trie hit must be capped
        // at plen − 1 so the last position's logits are recomputed and
        // TTFT/prefill metrics stay well-defined (≥ one prefill step)
        let cfg = SchedulerConfig {
            max_batch: 1,
            pool_pages: 16,
            page_size: 2,
            ..Default::default()
        };
        let mut s = sched(cfg);
        let prompt = vec![4u8, 5, 6, 7, 8, 9]; // 6 tokens = 3 full pages
        s.submit(req(0, prompt.clone(), 2));
        s.submit(req(1, prompt.clone(), 2));
        let rs = s.run_until_idle();
        let by_id = |id: u64| rs.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(1).cached_prefix_len, 5, "capped at plen − 1");
        assert_eq!(by_id(0).tokens, by_id(1).tokens);
        assert!(by_id(1).ttft_ms.unwrap() > 0.0);
        assert_eq!(s.metrics().ttft.count(), 2);
        assert_eq!(s.metrics().queue_wait.count(), 2);
        assert_eq!(s.metrics().prefill.count(), 2, "prefill recorded even when mostly skipped");
        assert_no_leak(&mut s);
    }

    #[test]
    fn preemption_with_prefix_cache_matches_cache_off() {
        // tight pool forces preemption/re-admission churn; a preempted
        // request's rerun re-forks whatever prefix is cached (its own
        // pages if its first prefill finished). Whatever the interleaving,
        // per-request token streams must be identical to a cache-off run
        // — the parity contract under backpressure.
        let run = |prefix_cache: bool| {
            let cfg = SchedulerConfig {
                max_batch: 4,
                pool_pages: 6,
                page_size: 2,
                prefill_chunk: 2,
                prefix_cache,
                ..Default::default()
            };
            let mut s = sched(cfg);
            for i in 0..6 {
                // distinct 4-token prompts → 2 full cacheable pages each
                s.submit(req(i, vec![(i as u8) * 2, 1, (i as u8) * 2 + 1, 3], 4));
            }
            let mut steps = 0;
            let mut rs = Vec::new();
            while !s.is_idle() {
                rs.extend(s.step());
                steps += 1;
                assert!(steps < 100_000, "deadlock under preemption (cache={prefix_cache})");
            }
            rs.sort_by_key(|r| r.id);
            assert_eq!(rs.len(), 6);
            assert!(rs.iter().all(|r| r.tokens.len() == 4));
            assert_no_leak(&mut s);
            rs.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(true), run(false), "prefix cache changed generated tokens");
    }

    #[test]
    fn interactive_admitted_before_earlier_batch() {
        // Batch arrives FIRST, but with one slot the Interactive request
        // must still be admitted (and finish) first — strict priority
        let mut s = sched(SchedulerConfig { max_batch: 1, ..Default::default() });
        s.submit(req(0, vec![1, 2], 3).with_priority(Class::Batch));
        s.submit(req(1, vec![3, 4], 3).with_priority(Class::Interactive));
        let rs = s.run_until_idle();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].id, 1, "interactive must finish before the earlier batch request");
        assert!(rs.iter().all(|r| r.outcome == GenOutcome::Completed));
        assert_no_leak(&mut s);
    }

    #[test]
    fn preemption_prefers_batch_victim() {
        // both classes running concurrently in a pool too small for both:
        // the Batch sequence must be the one preempted, so Interactive
        // finishes first even though Batch was submitted first
        let cfg = SchedulerConfig {
            max_batch: 2,
            pool_pages: 4,
            page_size: 2,
            prefill_chunk: 2,
            prefix_cache: false,
            ..Default::default()
        };
        let mut s = sched(cfg);
        s.submit(req(0, vec![2, 7, 1], 4).with_priority(Class::Batch));
        s.submit(req(1, vec![3, 1, 4], 4).with_priority(Class::Interactive));
        let mut steps = 0;
        let mut rs = Vec::new();
        while !s.is_idle() {
            rs.extend(s.step());
            steps += 1;
            assert!(steps < 10_000, "deadlock under priority preemption");
        }
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].id, 1, "batch should have been the preemption victim");
        assert!(s.preemptions() > 0, "the tiny pool must have forced preemption");
        assert!(rs.iter().all(|r| r.tokens.len() == 4 && r.outcome == GenOutcome::Completed));
        assert_no_leak(&mut s);
    }

    #[test]
    fn queue_bound_sheds_batch_at_submit() {
        let cfg = SchedulerConfig { max_queue_batch: 1, ..Default::default() };
        let mut s = sched(cfg);
        s.submit(req(0, vec![1, 2], 2).with_priority(Class::Batch));
        s.submit(req(1, vec![3, 4], 2).with_priority(Class::Batch)); // over the bound
        s.submit(req(2, vec![5, 6], 2).with_priority(Class::Interactive)); // unaffected
        let rs = s.run_until_idle();
        assert_eq!(rs.len(), 3);
        let by_id = |id: u64| rs.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(0).outcome, GenOutcome::Completed);
        assert_eq!(by_id(1).outcome, GenOutcome::Rejected, "second batch submit is over the bound");
        assert_eq!(by_id(2).outcome, GenOutcome::Completed, "interactive bound is separate");
        assert_eq!(s.metrics().rejected, 1);
        assert!((s.metrics().shed_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert_no_leak(&mut s);
    }

    #[test]
    fn expired_ttft_deadline_sheds_from_queue() {
        let mut s = sched(SchedulerConfig::default());
        // a deadline of 0 ms has always already passed: shed on the
        // first tick, before any pool pages are touched
        s.submit(req(0, vec![1, 2, 3], 4).with_ttft_deadline_ms(0.0));
        s.submit(req(1, vec![1, 2, 3], 4)); // no deadline: completes
        let rs = s.run_until_idle();
        let by_id = |id: u64| rs.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(0).outcome, GenOutcome::TimedOut);
        assert!(by_id(0).tokens.is_empty());
        assert_eq!(by_id(0).ttft_ms, None);
        assert_eq!(by_id(1).outcome, GenOutcome::Completed);
        assert_eq!(by_id(1).tokens.len(), 4);
        assert_eq!(s.metrics().timed_out, 1);
        assert_eq!(s.metrics().ttft.count(), 1, "shed request contributes no TTFT sample");
        assert_no_leak(&mut s);
    }

    #[test]
    fn running_past_total_deadline_times_out_with_partial_tokens() {
        // admit first (no deadline check passes yet — 1 hour), then use
        // the injected per-tick delay to blow a deadline we shrink by
        // hand: simplest deterministic path is a 0 ms deadline submitted
        // AFTER one step has already admitted... instead, use the delay
        // fault so wall-clock reliably crosses a small real deadline.
        let cfg = SchedulerConfig {
            prefill_chunk: 1,
            faults: FaultConfig { step_delay: Some((1, 4)), ..FaultConfig::off() },
            ..Default::default()
        };
        let mut s = sched(cfg);
        // 4 ms sleep per tick vs a 2 ms total budget: admitted on tick 1
        // (0 ms elapsed at the shed check of a fresh submit is < 2 only
        // if the clock hasn't moved — either way the OUTCOME must be
        // TimedOut, from the queue or mid-run; both paths reclaim pages)
        s.submit(req(0, vec![1, 2, 3, 4], 64).with_deadline_ms(2.0));
        let mut steps = 0;
        let mut rs = Vec::new();
        while !s.is_idle() {
            rs.extend(s.step());
            steps += 1;
            assert!(steps < 1_000, "timeout failed to terminate the request");
        }
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].outcome, GenOutcome::TimedOut);
        assert!(rs[0].tokens.len() < 64, "deadline must cut generation short");
        assert_eq!(s.metrics().timed_out, 1);
        assert_no_leak(&mut s);
    }

    #[test]
    fn cancel_queued_and_running() {
        // queued cancel: max_batch 1 keeps id 1 in the queue
        let mut s = sched(SchedulerConfig { max_batch: 1, ..Default::default() });
        s.submit(req(0, vec![1, 2], 6));
        s.submit(req(1, vec![3, 4], 6));
        assert!(s.cancel(1), "queued request must be cancellable");
        assert!(!s.cancel(99), "unknown id is a no-op");
        let rs = s.run_until_idle();
        let by_id = |id: u64| rs.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(0).outcome, GenOutcome::Completed);
        assert_eq!(by_id(1).outcome, GenOutcome::Cancelled);
        assert!(by_id(1).tokens.is_empty());
        assert!(!s.cancel(1), "a finished id must never get a second terminal response");
        assert_eq!(s.metrics().cancelled, 1);
        assert_no_leak(&mut s);

        // running cancel: step a few times, then cancel mid-generation
        let mut s = sched(SchedulerConfig { prefill_chunk: 1, ..Default::default() });
        s.submit(req(7, vec![1, 2], 64));
        for _ in 0..6 {
            s.step();
        }
        assert_eq!(s.in_flight(), 1);
        assert!(s.cancel(7));
        let rs = s.run_until_idle();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].outcome, GenOutcome::Cancelled);
        assert!(rs[0].tokens.len() < 64, "cancel must stop generation early");
        assert_no_leak(&mut s);
    }

    #[test]
    fn injected_reserve_failures_keep_token_parity() {
        // forced reserve failures churn preemption without real pool
        // pressure; greedy decode must still produce the exact tokens of
        // a fault-free run, and nothing may leak
        let run = |faults: FaultConfig| {
            let cfg = SchedulerConfig {
                max_batch: 4,
                pool_pages: 16,
                page_size: 2,
                prefill_chunk: 2,
                faults,
                ..Default::default()
            };
            let mut s = sched(cfg);
            for i in 0..6 {
                s.submit(req(i, vec![(i as u8) + 1, 2, 5], 3));
            }
            let mut steps = 0;
            let mut rs = Vec::new();
            while !s.is_idle() {
                rs.extend(s.step());
                steps += 1;
                assert!(steps < 100_000, "injected failures deadlocked the scheduler");
            }
            rs.sort_by_key(|r| r.id);
            assert!(rs.iter().all(|r| r.outcome == GenOutcome::Completed));
            assert_no_leak(&mut s);
            rs.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        let clean = run(FaultConfig::off());
        let faulty = run(FaultConfig { seed: 11, reserve_fail_p: 0.25, ..FaultConfig::off() });
        assert_eq!(clean, faulty, "injected backpressure changed generated tokens");
    }

    #[test]
    fn spec_on_matches_spec_off_greedy_bitwise() {
        // the tentpole determinism contract: greedy accept-iff-equal
        // makes speculative decoding indistinguishable from the plain
        // path, token for token — in a roomy pool AND under the tight-
        // pool fallback (span reserve fails → plain step)
        let run = |spec: SpecConfig, pool_pages: usize| {
            let cfg = SchedulerConfig {
                max_batch: 4,
                pool_pages,
                page_size: 2,
                prefill_chunk: 2,
                spec,
                ..Default::default()
            };
            let mut s = sched(cfg);
            for i in 0..6 {
                s.submit(req(i, vec![(i as u8) * 3 % 16, 2, 5], 6));
            }
            let mut steps = 0;
            let mut rs = Vec::new();
            while !s.is_idle() {
                rs.extend(s.step());
                steps += 1;
                assert!(steps < 100_000, "spec run deadlocked (pages={pool_pages})");
            }
            rs.sort_by_key(|r| r.id);
            assert!(rs.iter().all(|r| r.outcome == GenOutcome::Completed));
            let m = s.metrics().clone();
            assert_no_leak(&mut s);
            (rs.into_iter().map(|r| r.tokens).collect::<Vec<_>>(), m)
        };
        for pages in [64, 6] {
            let (off, m_off) = run(SpecConfig::off(), pages);
            let (on, m_on) = run(SpecConfig { k: 4, draft_bits: 3 }, pages);
            assert_eq!(off, on, "speculation changed greedy tokens (pages={pages})");
            assert_eq!(m_off.spec_rounds, 0, "spec-off must never run a round");
            assert!(m_on.spec_accepted <= m_on.spec_proposed);
            if pages == 64 {
                assert!(m_on.spec_rounds > 0, "roomy pool must exercise spec rounds");
                assert!(m_on.spec_proposed > 0);
            }
            // per-token accounting stays one sample per emitted token
            assert_eq!(m_on.per_token.count(), m_off.per_token.count());
        }
    }

    #[test]
    fn seeded_sampling_replays_after_preemption_bitwise() {
        // sampled picks are pure functions of (seed, position, stream),
        // so a preempt-and-rerun interleaving must replay the exact
        // same tokens a roomy no-preemption run produces
        let params = SamplingParams { temperature: 1.5, top_k: 0, top_p: 0.9, seed: 0xC0FFEE };
        let run = |pool_pages: usize| {
            let cfg = SchedulerConfig {
                max_batch: 4,
                pool_pages,
                page_size: 2,
                prefill_chunk: 2,
                ..Default::default()
            };
            let mut s = sched(cfg);
            for i in 0..6 {
                s.submit(
                    req(i, vec![(i as u8) * 2, 1, (i as u8) * 2 + 1, 3], 4)
                        .with_sampling(SamplingParams { seed: params.seed + i, ..params }),
                );
            }
            let mut steps = 0;
            let mut rs = Vec::new();
            while !s.is_idle() {
                rs.extend(s.step());
                steps += 1;
                assert!(steps < 100_000, "sampled run deadlocked (pages={pool_pages})");
            }
            rs.sort_by_key(|r| r.id);
            assert!(rs.iter().all(|r| r.tokens.len() == 4));
            let preemptions = s.preemptions();
            assert_no_leak(&mut s);
            (rs.into_iter().map(|r| r.tokens).collect::<Vec<_>>(), preemptions)
        };
        let (roomy, p0) = run(64);
        let (tight, p1) = run(6);
        assert_eq!(p0, 0, "roomy pool must not preempt");
        assert!(p1 > 0, "tight pool must force preemption to make the replay meaningful");
        assert_eq!(roomy, tight, "preemption changed a seeded-sampling token stream");
        // sanity: the sampled streams actually diverge from greedy —
        // 24 picks at temperature 1.5 all landing on the argmax would
        // mean sampling never engaged
        let greedy = {
            let mut s = sched(SchedulerConfig { max_batch: 4, ..Default::default() });
            for i in 0..6 {
                s.submit(req(i, vec![(i as u8) * 2, 1, (i as u8) * 2 + 1, 3], 4));
            }
            let mut rs = s.run_until_idle();
            rs.sort_by_key(|r| r.id);
            rs.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_ne!(roomy, greedy, "temperature-1.5 sampling reproduced greedy exactly");
    }

    #[test]
    fn spec_with_sampling_completes_and_counts_acceptance() {
        // rejection sampling path: requests finish, acceptance counters
        // are coherent, and replaying the identical config replays the
        // identical tokens (the determinism contract also holds for
        // sampled speculation — same config, same stream)
        let run = || {
            let cfg = SchedulerConfig {
                max_batch: 2,
                spec: SpecConfig { k: 3, draft_bits: 3 },
                ..Default::default()
            };
            let mut s = sched(cfg);
            for i in 0..4 {
                s.submit(req(i, vec![(i as u8) + 1, 6, 2], 5).with_sampling(SamplingParams {
                    temperature: 1.0,
                    top_k: 0,
                    top_p: 1.0,
                    seed: 42 + i,
                }));
            }
            let mut rs = s.run_until_idle();
            rs.sort_by_key(|r| r.id);
            assert!(rs.iter().all(|r| r.tokens.len() == 5));
            assert!(rs.iter().all(|r| r.outcome == GenOutcome::Completed));
            let m = s.metrics().clone();
            assert!(m.spec_rounds > 0);
            assert!(m.spec_proposed > 0);
            assert!(m.spec_accepted <= m.spec_proposed);
            let rate = m.spec_accept_rate();
            assert!((0.0..=1.0).contains(&rate), "accept rate {rate} out of range");
            assert_no_leak(&mut s);
            rs.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(), run(), "sampled speculation is not replay-deterministic");
    }

    #[test]
    fn spec_single_token_budget_falls_back_to_plain_step() {
        // budget - 1 == 0 proposals: the round must degrade to one
        // plain batched step, not stall or over-generate
        let cfg = SchedulerConfig {
            spec: SpecConfig { k: 4, draft_bits: 3 },
            ..Default::default()
        };
        let mut s = sched(cfg);
        s.submit(req(0, vec![1, 2, 3], 1));
        let rs = s.run_until_idle();
        assert_eq!(rs[0].tokens.len(), 1);
        assert_eq!(rs[0].outcome, GenOutcome::Completed);
        assert_eq!(s.metrics().spec_rounds, 0, "no room to propose, no round");
        assert_no_leak(&mut s);
    }
}
