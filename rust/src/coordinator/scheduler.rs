//! Iteration-level (continuous-batching) scheduler — the multi-user
//! serving loop that replaces drain-then-run batching.
//!
//! The old worker loop served requests to completion one at a time, so
//! aggregate throughput under concurrent load was the single-stream
//! number. This scheduler advances EVERY in-flight sequence one token per
//! iteration through one batched [`CpuModel::decode_steps`] pass — N
//! sequences share each read of the (packed) weights, which is where
//! multi-user throughput comes from in the paper's bandwidth-bound
//! regime — with KV state in pages from a bounded [`KvPool`].
//!
//! One `step()` (a *tick*):
//! 1. **Admit** queued requests while slots (`max_batch`) and pool pages
//!    allow. Admission first consults the [`PrefixCache`]: the longest
//!    cached page-granular prefix of the prompt (capped at `plen − 1`,
//!    so the last prompt position is always recomputed — its logits
//!    pick the first token) is FORKED into the new sequence
//!    ([`KvPool::fork_pages`], a refcount bump) and only the uncached
//!    suffix is enqueued as chunked prefill. A request is admitted only
//!    when the pool can hold its remaining prompt + first token on top
//!    of what already-running sequences still need through their own
//!    prompts (including any pending copy-on-write page), so admission
//!    bursts don't overcommit the pool against prefill work
//!    (decode-phase growth is not reserved — preemption handles it).
//! 2. **Advance**: one batched decode sub-step over all running
//!    sequences — each consumes its next prompt token (chunked prefill)
//!    or its last generated token (decode) — then up to
//!    `prefill_chunk − 1` extra sub-steps for sequences still in
//!    prefill, so long prompts ramp quickly without stalling decoders
//!    for more than one token. A sequence finishing prefill indexes its
//!    full prompt pages into the prefix cache.
//! 3. **Reclaim**: finished sequences (max tokens, `max_seq`/pool length
//!    cap, or the optional EOS byte) release their pages (shared pages
//!    stay resident for the cache and other forks) and emit a
//!    [`GenResponse`] with queue-wait, TTFT, and cached-prefix length.
//!
//! **Backpressure.** When [`KvPool::reserve`] fails, cold prefix-cache
//! pages are evicted first (LRU entries whose pages no live sequence
//! maps — DESIGN.md §Prefix cache); only if nothing is evictable is the
//! youngest-admitted sequence preempted: its pages are reclaimed and its
//! request goes back to the FRONT of the queue (original submit time
//! kept, so queue-wait stays honest) for a rerun — on re-admission it
//! re-forks whatever prefix is cached (often its own, indexed when its
//! first run finished prefill), so preempted work is largely recovered.
//! Greedy decode is deterministic, so a rerun reproduces the same
//! tokens. A lone sequence can always finish: per-request length is
//! capped at admission to what the whole pool can hold, and every
//! cache-only page is eventually evictable, which keeps the loop
//! deadlock-free.
//!
//! **Parity contract.** Per sequence, scheduler output is identical to
//! the sequential single-stream decode — WITH OR WITHOUT the prefix
//! cache: a fork maps the very pages an identical earlier prefill
//! wrote, so attention reads the same f32 rows either way (dense
//! bit-identical, packed within 1e-5 — in practice also bit-identical),
//! and token selection copies `argmax` exactly.
//! `tests/continuous_batching.rs` and `tests/prefix_cache.rs` enforce
//! this under `GPTQ_ISA={scalar,auto} × GPTQ_THREADS={1,4}`.

use crate::coordinator::metrics::ServeMetrics;
use crate::coordinator::prefixcache::PrefixCache;
use crate::coordinator::serve::{GenRequest, GenResponse};
use crate::model::{CpuModel, KvDtype, KvPool, SeqCache};
use std::collections::VecDeque;
use std::time::Instant;

/// Knobs for one worker's scheduler (embedded in `ServerConfig`).
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// slot budget: max sequences in flight per worker
    pub max_batch: usize,
    /// KV pool budget, in pages
    pub pool_pages: usize,
    /// positions per page
    pub page_size: usize,
    /// max prompt tokens a prefilling sequence consumes per tick
    pub prefill_chunk: usize,
    /// optional stop byte: generation ends when it would be emitted
    pub eos: Option<u8>,
    /// share prompt-prefix KV across requests (the radix prompt cache);
    /// off = every request prefills from scratch (pre-prefix-cache
    /// behavior, bit-identical outputs either way)
    pub prefix_cache: bool,
    /// KV page storage precision (`--kv-dtype` / `GPTQ_KV_DTYPE`):
    /// `F32` is today's exact rows, `Q8` fits ≈4× the positions in the
    /// same bytes at a documented logit-drift cost (DESIGN.md §KV
    /// precision). Within either dtype the scheduler's parity contracts
    /// hold bitwise.
    pub kv_dtype: KvDtype,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            pool_pages: 64,
            page_size: 16,
            prefill_chunk: 4,
            eos: None,
            prefix_cache: true,
            // env-derived so the determinism suites (and anything else
            // built on the default config) flip to q8 pages under
            // GPTQ_KV_DTYPE=q8 without code changes; unset env = F32 =
            // bit-identical to the pre-dtype default
            kv_dtype: KvDtype::from_env(),
        }
    }
}

/// One in-flight sequence (admission order is preserved in
/// `Scheduler::running`; the LAST entry is the preemption victim).
struct Running {
    req: GenRequest,
    seq: SeqCache,
    /// prompt tokens consumed so far (prefill while `consumed < plen`);
    /// starts at the forked cached-prefix length, not 0
    consumed: usize,
    /// effective prompt length after the length cap
    plen: usize,
    /// hard length cap: min(max_seq, pool capacity) — guarantees a lone
    /// sequence always fits the pool
    limit: usize,
    /// prompt tokens whose KV was forked from the prefix cache at the
    /// last admission (prefill skipped for them)
    cached_prefix_len: usize,
    /// generated token awaiting its decode step
    next: Option<u8>,
    out: Vec<u8>,
    per_token_ms: Vec<f64>,
    prefill_ms: f64,
    submitted: Instant,
    admitted: Instant,
    ttft_ms: Option<f64>,
    done: bool,
}

/// The greedy pick (last max wins on ties, NaN panics — the historical
/// serving semantics). This is the single production copy; the
/// sequential oracle in `tests/continuous_batching.rs` replicates it
/// deliberately so the parity tests stay independent of this code.
fn argmax(logits: &[f32]) -> u8 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as u8)
        .unwrap_or(0)
}

/// Continuous-batching scheduler for one worker (see module docs).
pub struct Scheduler {
    wid: usize,
    model: CpuModel,
    pool: KvPool,
    cache: PrefixCache,
    cfg: SchedulerConfig,
    queue: VecDeque<(GenRequest, Instant)>,
    running: Vec<Running>,
    metrics: ServeMetrics,
    preemptions: usize,
}

impl Scheduler {
    pub fn new(wid: usize, model: CpuModel, cfg: SchedulerConfig) -> Self {
        assert!(cfg.max_batch >= 1, "max_batch must be at least 1");
        let pool = KvPool::new_with_dtype(&model.config, cfg.pool_pages, cfg.page_size, cfg.kv_dtype);
        let cache = PrefixCache::new(cfg.page_size);
        Self {
            wid,
            model,
            pool,
            cache,
            cfg,
            queue: VecDeque::new(),
            running: Vec::new(),
            metrics: ServeMetrics::new(),
            preemptions: 0,
        }
    }

    /// Enqueue a request (FIFO; queue-wait starts now).
    pub fn submit(&mut self, req: GenRequest) {
        self.queue.push_back((req, Instant::now()));
    }

    /// Nothing queued and nothing in flight.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.running.is_empty()
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn in_flight(&self) -> usize {
        self.running.len()
    }

    pub fn free_pages(&self) -> usize {
        self.pool.free_pages()
    }

    pub fn total_pages(&self) -> usize {
        self.pool.total_pages()
    }

    /// Pages currently pinned by the prefix cache alone. At idle,
    /// `free_pages() + cached_pages() == total_pages()` — the pool-leak
    /// invariant with prefix sharing on.
    pub fn cached_pages(&self) -> usize {
        self.cache.pages_held()
    }

    /// Drop every prefix-cache hold (tests; also proves the cache is the
    /// only thing between `free_pages` and `total_pages` at idle).
    pub fn clear_prefix_cache(&mut self) {
        self.cache.clear(&mut self.pool);
    }

    /// Test/teardown assertion of the idle-pool invariant: every page is
    /// either free or pinned by the prefix cache, and dropping the cache
    /// returns all of them. DESTRUCTIVE — empties the prefix cache; the
    /// single copy of the leak check every suite tears down with.
    pub fn assert_no_page_leak(&mut self) {
        assert!(self.is_idle(), "leak check requires an idle scheduler");
        assert_eq!(
            self.free_pages() + self.cached_pages(),
            self.total_pages(),
            "page leak (free {} + cached {} != total {})",
            self.free_pages(),
            self.cached_pages(),
            self.total_pages()
        );
        self.clear_prefix_cache();
        assert_eq!(self.free_pages(), self.total_pages(), "page leak after cache clear");
    }

    /// Pool-exhaustion preemptions so far (backpressure events).
    pub fn preemptions(&self) -> usize {
        self.preemptions
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    pub fn into_metrics(self) -> ServeMetrics {
        self.metrics
    }

    /// One scheduler iteration; returns the requests completed by it.
    pub fn step(&mut self) -> Vec<GenResponse> {
        self.admit();
        let mut done = Vec::new();
        // requests that complete AT admission (empty prompt, zero tokens)
        // never enter a sub-step — reclaim them here
        self.harvest(&mut done);
        for substep in 0..self.cfg.prefill_chunk.max(1) {
            let idx = self.reserve_active(substep);
            if idx.is_empty() {
                break;
            }
            self.advance(&idx);
            self.harvest(&mut done);
        }
        done
    }

    /// Drive until queue and batch are empty; returns every response.
    pub fn run_until_idle(&mut self) -> Vec<GenResponse> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step());
        }
        out
    }

    /// Admission control: FIFO from the queue while a slot is free and
    /// the pool can hold the prompt's uncached remainder plus the first
    /// generated token. On a gate shortfall the candidate's fork is
    /// released before cache eviction runs (see the comment at the gate:
    /// holding it could pin the shortfall forever), then the request is
    /// retried from scratch if eviction reclaimed anything.
    fn admit(&mut self) {
        // shortfall at the last gate failure for the current queue head
        // (usize::MAX = fresh candidate): eviction retries must shrink
        // it or stop — see the progress check at the gate
        let mut prev_short = usize::MAX;
        while self.running.len() < self.cfg.max_batch {
            let Some(&(ref req, _)) = self.queue.front() else { break };
            let limit = self
                .model
                .config
                .max_seq
                .min(self.pool.total_pages() * self.pool.page_size());
            let plen = req.prompt.len().min(limit.saturating_sub(1));
            // longest cached prefix, capped at plen − 1: the final prompt
            // position is always recomputed because its logits choose the
            // first generated token
            let (seq, cached) = if self.cfg.prefix_cache && plen > 1 {
                let pages = self.cache.lookup(&req.prompt[..plen]);
                let cached = (pages.len() * self.pool.page_size()).min(plen - 1);
                if cached > 0 {
                    (self.pool.fork_pages(&pages, cached), cached)
                } else {
                    (SeqCache::new(), 0)
                }
            } else {
                (SeqCache::new(), 0)
            };
            // pool gate: room for the uncached prompt remainder + first
            // token AFTER the pages already-running sequences still need
            // to finish their own prompts (+ next position once decoding,
            // + a copy-on-write page where a fork tail is still shared) —
            // so a burst of admissions can't overcommit the pool against
            // prefill work. Decode-phase growth past the first token is
            // not reserved; that is what preemption is for.
            let committed: usize = self
                .running
                .iter()
                .filter(|r| !r.done)
                .map(|r| {
                    let target = (r.plen + 1).min(r.limit).max(r.seq.len + 1);
                    self.pool.pages_for(target).saturating_sub(r.seq.n_pages())
                        + self.pool.cow_pending(&r.seq) as usize
                })
                .sum();
            let fresh = self.pool.pages_for(plen + 1).saturating_sub(seq.n_pages())
                + self.pool.cow_pending(&seq) as usize;
            let need = committed + fresh;
            if self.pool.free_pages() < need {
                // Pool pressure. Drop the fork's holds BEFORE evicting:
                // a fork pins its pages at refcount ≥ 2, so a shortfall
                // pinned by our own fork would survive eviction and this
                // admit would repeat identically every tick (livelock —
                // e.g. a near-pool-sized cached prefix plus its CoW
                // page). Un-forked, every cold cache page is evictable;
                // the lookup just bumped this prefix's LRU stamps, so
                // its pages go last and a retry usually re-forks them.
                let mut seq = seq;
                self.pool.release(&mut seq);
                let short = need - self.pool.free_pages();
                // Progress check: evicting a page of this request's OWN
                // matched prefix frees one page but raises `fresh` by
                // one — shortfall unchanged — so when the pressure comes
                // from running sequences' reservations, retrying would
                // churn away the whole cached prefix for nothing. Stop
                // as soon as a retry fails to shrink the shortfall.
                if short >= prev_short {
                    break;
                }
                if self.cfg.prefix_cache && self.cache.evict(&mut self.pool, short) > 0 {
                    // pages reclaimed: retry this request from scratch
                    // (fresh lookup — the prefix may be partly gone)
                    prev_short = short;
                    continue;
                }
                break; // nothing reclaimable: wait for running sequences
            }
            prev_short = usize::MAX; // next queue head starts fresh
            let (req, submitted) = self.queue.pop_front().unwrap();
            let admitted = Instant::now();
            if self.cfg.prefix_cache && plen > 1 {
                self.metrics.prefix_lookups += 1;
                if cached > 0 {
                    self.metrics.prefix_hits += 1;
                    self.metrics.prefill_tokens_saved += cached;
                }
            }
            let mut r = Running {
                req,
                seq,
                consumed: cached,
                plen,
                limit,
                cached_prefix_len: cached,
                next: None,
                out: Vec::new(),
                per_token_ms: Vec::new(),
                prefill_ms: 0.0,
                submitted,
                admitted,
                ttft_ms: None,
                done: false,
            };
            if plen == 0 {
                // empty prompt: the sequential path feeds token 0 with no
                // logits to pick from — mirror it (but EOS is still never
                // emitted)
                if r.req.max_new_tokens == 0 || self.cfg.eos == Some(0) {
                    r.done = true;
                } else {
                    r.ttft_ms = Some(ms_since(submitted));
                    r.next = Some(0);
                }
            }
            self.running.push(r);
        }
    }

    /// The indices (into `running`, ascending) active in `substep`, with
    /// pool pages reserved for each one's next position (the reserve
    /// also performs copy-on-write when a fork's tail page is shared).
    /// Pool exhaustion evicts cold prefix-cache pages first, then
    /// preempts the youngest-admitted sequence (FIFO re-queue at the
    /// front, original submit time kept) and retries.
    fn reserve_active(&mut self, substep: usize) -> Vec<usize> {
        'retry: loop {
            let idx: Vec<usize> = self
                .running
                .iter()
                .enumerate()
                .filter(|(_, r)| !r.done && (substep == 0 || r.consumed < r.plen))
                .map(|(i, _)| i)
                .collect();
            for &i in &idx {
                let need = self.running[i].seq.len + 1;
                if !self.pool.reserve(&mut self.running[i].seq, need) {
                    // cold cache pages go before live work does
                    if self.cfg.prefix_cache && self.cache.evict(&mut self.pool, 1) > 0 {
                        continue 'retry;
                    }
                    if self.running.len() <= 1 {
                        // unreachable: a lone sequence's length is capped
                        // to the pool at admission and every cache-only
                        // page is evictable — defensive truncation
                        debug_assert!(false, "lone sequence exhausted the pool");
                        self.running[i].done = true;
                        return Vec::new();
                    }
                    let mut victim = self.running.pop().unwrap();
                    self.pool.release(&mut victim.seq);
                    self.queue.push_front((victim.req, victim.submitted));
                    self.preemptions += 1;
                    continue 'retry;
                }
            }
            return idx;
        }
    }

    /// One batched decode sub-step over the sequences at `idx`.
    fn advance(&mut self, idx: &[usize]) {
        let toks: Vec<u8> = idx
            .iter()
            .map(|&i| {
                let r = &self.running[i];
                if r.consumed < r.plen {
                    r.req.prompt[r.consumed]
                } else {
                    r.next.expect("decoding sequence without a pending token")
                }
            })
            .collect();
        let mut want = idx.iter().copied().peekable();
        let mut seqs: Vec<&mut SeqCache> = Vec::with_capacity(idx.len());
        for (i, r) in self.running.iter_mut().enumerate() {
            if want.peek() == Some(&i) {
                want.next();
                seqs.push(&mut r.seq);
            }
        }
        let t0 = Instant::now();
        let logits = self.model.decode_steps(&mut self.pool, &mut seqs, &toks);
        drop(seqs);
        let ms = t0.elapsed().as_secs_f64() * 1e3;

        let vocab = self.model.config.vocab;
        for (k, &i) in idx.iter().enumerate() {
            let lg = &logits[k * vocab..(k + 1) * vocab];
            let r = &mut self.running[i];
            if r.consumed < r.plen {
                // prefill step
                r.consumed += 1;
                r.prefill_ms += ms;
                if r.consumed == r.plen {
                    // prompt done — index its full KV pages so later
                    // requests (and this one, if preempted) skip the
                    // shared prefix, then pick the first token from
                    // these logits
                    if self.cfg.prefix_cache {
                        self.cache.insert(&mut self.pool, &r.req.prompt[..r.plen], &r.seq);
                    }
                    if r.req.max_new_tokens == 0 {
                        r.done = true;
                    } else {
                        let t = argmax(lg);
                        if self.cfg.eos == Some(t) {
                            r.done = true;
                        } else {
                            // a token will actually be emitted: TTFT
                            r.ttft_ms = Some(ms_since(r.submitted));
                            r.next = Some(t);
                        }
                    }
                }
            } else {
                // decode step: consumed the pending generated token
                let tok = r.next.take().expect("decode step without pending token");
                r.out.push(tok);
                r.per_token_ms.push(ms);
                if r.out.len() >= r.req.max_new_tokens || r.seq.len >= r.limit {
                    r.done = true;
                } else {
                    let t = argmax(lg);
                    if self.cfg.eos == Some(t) {
                        r.done = true;
                    } else {
                        r.next = Some(t);
                    }
                }
            }
        }
    }

    /// Move finished sequences out of the batch: release pages (shared
    /// ones stay resident for the cache/other forks), record metrics,
    /// emit responses (admission order preserved for the rest).
    fn harvest(&mut self, done: &mut Vec<GenResponse>) {
        let mut i = 0;
        while i < self.running.len() {
            if !self.running[i].done {
                i += 1;
                continue;
            }
            let mut r = self.running.remove(i);
            self.pool.release(&mut r.seq);
            let queue_wait_ms = (r.admitted - r.submitted).as_secs_f64() * 1e3;
            for &ms in &r.per_token_ms {
                self.metrics.per_token.record_ms(ms);
            }
            self.metrics.prefill.record_ms(r.prefill_ms);
            // requests that emit no token (max_new 0, EOS-first) have no
            // first-token time — skip the sample rather than skew TTFT
            // with prompt-processing-only measurements
            if let Some(t) = r.ttft_ms {
                self.metrics.ttft.record_ms(t);
            }
            self.metrics.queue_wait.record_ms(queue_wait_ms);
            let ttft_ms = r.ttft_ms.unwrap_or(0.0);
            done.push(GenResponse {
                id: r.req.id,
                tokens: r.out,
                per_token_ms: r.per_token_ms,
                prefill_ms: r.prefill_ms,
                queue_wait_ms,
                ttft_ms,
                cached_prefix_len: r.cached_prefix_len,
                worker: self.wid,
            });
        }
    }
}

fn ms_since(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::tiny_checkpoint;

    fn sched(cfg: SchedulerConfig) -> Scheduler {
        Scheduler::new(0, CpuModel::from_checkpoint(&tiny_checkpoint(7)), cfg)
    }

    fn req(id: u64, prompt: Vec<u8>, max_new: usize) -> GenRequest {
        GenRequest { id, prompt, max_new_tokens: max_new }
    }

    /// Shorthand for the shared idle-pool invariant check.
    fn assert_no_leak(s: &mut Scheduler) {
        s.assert_no_page_leak();
    }

    #[test]
    fn completes_one_request() {
        let mut s = sched(SchedulerConfig::default());
        s.submit(req(1, vec![1, 2, 3], 4));
        let rs = s.run_until_idle();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].tokens.len(), 4);
        assert_eq!(rs[0].per_token_ms.len(), 4);
        assert!(rs[0].ttft_ms >= rs[0].queue_wait_ms);
        assert_eq!(rs[0].cached_prefix_len, 0, "cold cache cannot hit");
        assert_no_leak(&mut s);
        assert_eq!(s.metrics().requests(), 1);
        assert_eq!(s.metrics().per_token.count(), 4);
    }

    #[test]
    fn batch_advances_together_and_all_complete() {
        let mut s = sched(SchedulerConfig { max_batch: 4, ..Default::default() });
        for i in 0..6 {
            s.submit(req(i, vec![(i % 16) as u8; (i as usize % 5) + 1], 3));
        }
        let rs = s.run_until_idle();
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..6).collect::<Vec<_>>());
        assert!(rs.iter().all(|r| r.tokens.len() == 3));
        assert_no_leak(&mut s);
    }

    #[test]
    fn tiny_pool_backpressures_but_completes() {
        // 4 pages × 2 positions = 8 cached positions shared by 4 slots:
        // forces preemption with 6-long sequences
        let cfg = SchedulerConfig {
            max_batch: 4,
            pool_pages: 4,
            page_size: 2,
            prefill_chunk: 2,
            ..Default::default()
        };
        let mut s = sched(cfg);
        for i in 0..8 {
            s.submit(req(i, vec![3, 1, 4], 3));
        }
        let mut steps = 0;
        let mut rs = Vec::new();
        while !s.is_idle() {
            rs.extend(s.step());
            steps += 1;
            assert!(steps < 10_000, "scheduler deadlocked");
        }
        assert_eq!(rs.len(), 8);
        assert!(rs.iter().all(|r| r.tokens.len() == 3));
        assert_no_leak(&mut s);
    }

    #[test]
    fn identical_prompts_share_their_prefix_pages() {
        // page_size 2, prompt of 5 tokens → 2 full pages cacheable; the
        // second request should fork 4 tokens and prefill only the rest
        let cfg = SchedulerConfig {
            max_batch: 1, // serialize so the first request is indexed first
            pool_pages: 16,
            page_size: 2,
            ..Default::default()
        };
        let mut s = sched(cfg);
        s.submit(req(0, vec![5, 6, 7, 8, 9], 2));
        s.submit(req(1, vec![5, 6, 7, 8, 9], 2));
        let rs = s.run_until_idle();
        let by_id = |id: u64| rs.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(0).cached_prefix_len, 0);
        assert_eq!(by_id(1).cached_prefix_len, 4);
        // identical prompt → identical greedy continuation, shared pages
        // or not (the parity contract)
        assert_eq!(by_id(0).tokens, by_id(1).tokens);
        let m = s.metrics();
        assert_eq!(m.prefix_lookups, 2);
        assert_eq!(m.prefix_hits, 1);
        assert_eq!(m.prefill_tokens_saved, 4);
        assert!((m.cache_hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(s.cached_pages(), 2, "two full prompt pages indexed");
        assert_no_leak(&mut s);
    }

    #[test]
    fn prefix_cache_off_never_shares() {
        let cfg = SchedulerConfig {
            max_batch: 1,
            pool_pages: 16,
            page_size: 2,
            prefix_cache: false,
            ..Default::default()
        };
        let mut s = sched(cfg);
        s.submit(req(0, vec![5, 6, 7, 8, 9], 2));
        s.submit(req(1, vec![5, 6, 7, 8, 9], 2));
        let rs = s.run_until_idle();
        assert!(rs.iter().all(|r| r.cached_prefix_len == 0));
        assert_eq!(s.metrics().prefix_lookups, 0);
        assert_eq!(s.metrics().prefill_tokens_saved, 0);
        assert_eq!(s.cached_pages(), 0);
        assert_eq!(s.free_pages(), s.total_pages());
    }

    #[test]
    fn eos_stops_generation_early() {
        // find the first greedy token, then rerun with it as EOS
        let mut probe = sched(SchedulerConfig::default());
        probe.submit(req(0, vec![5, 6], 4));
        let first = probe.run_until_idle()[0].tokens[0];
        let mut s = sched(SchedulerConfig { eos: Some(first), ..Default::default() });
        s.submit(req(0, vec![5, 6], 4));
        let rs = s.run_until_idle();
        assert!(rs[0].tokens.is_empty(), "EOS should suppress generation");
        assert_no_leak(&mut s);
    }

    #[test]
    fn zero_max_tokens_and_empty_prompt_complete() {
        let mut s = sched(SchedulerConfig::default());
        s.submit(req(0, vec![1, 2], 0));
        s.submit(req(1, vec![], 2));
        let rs = s.run_until_idle();
        assert_eq!(rs.len(), 2);
        let by_id = |id: u64| rs.iter().find(|r| r.id == id).unwrap();
        assert!(by_id(0).tokens.is_empty());
        assert_eq!(by_id(1).tokens.len(), 2);
        // the sequential path's empty-prompt behavior: first token is 0
        assert_eq!(by_id(1).tokens[0], 0);
        // 0-token prefill: queue-wait and TTFT accounting must survive
        // a request that never enters the prefill loop
        assert_eq!(by_id(1).cached_prefix_len, 0);
        assert!(by_id(1).ttft_ms >= by_id(1).queue_wait_ms);
        assert_eq!(s.metrics().requests(), 2);
        assert_eq!(s.metrics().ttft.count(), 1, "only the emitting request samples TTFT");
        assert_no_leak(&mut s);
    }

    #[test]
    fn long_prompt_truncates_to_limit() {
        let mut s = sched(SchedulerConfig::default());
        // tiny max_seq = 16: prompt 30 truncates to 15, one token fits
        s.submit(req(0, vec![1; 30], 30));
        let rs = s.run_until_idle();
        assert_eq!(rs[0].tokens.len(), 1);
    }

    #[test]
    fn full_prefix_hit_still_recomputes_last_prompt_token() {
        // prompt length = 3 pages exactly; a full-trie hit must be capped
        // at plen − 1 so the last position's logits are recomputed and
        // TTFT/prefill metrics stay well-defined (≥ one prefill step)
        let cfg = SchedulerConfig {
            max_batch: 1,
            pool_pages: 16,
            page_size: 2,
            ..Default::default()
        };
        let mut s = sched(cfg);
        let prompt = vec![4u8, 5, 6, 7, 8, 9]; // 6 tokens = 3 full pages
        s.submit(req(0, prompt.clone(), 2));
        s.submit(req(1, prompt.clone(), 2));
        let rs = s.run_until_idle();
        let by_id = |id: u64| rs.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(1).cached_prefix_len, 5, "capped at plen − 1");
        assert_eq!(by_id(0).tokens, by_id(1).tokens);
        assert!(by_id(1).ttft_ms > 0.0);
        assert_eq!(s.metrics().ttft.count(), 2);
        assert_eq!(s.metrics().queue_wait.count(), 2);
        assert_eq!(s.metrics().prefill.count(), 2, "prefill recorded even when mostly skipped");
        assert_no_leak(&mut s);
    }

    #[test]
    fn preemption_with_prefix_cache_matches_cache_off() {
        // tight pool forces preemption/re-admission churn; a preempted
        // request's rerun re-forks whatever prefix is cached (its own
        // pages if its first prefill finished). Whatever the interleaving,
        // per-request token streams must be identical to a cache-off run
        // — the parity contract under backpressure.
        let run = |prefix_cache: bool| {
            let cfg = SchedulerConfig {
                max_batch: 4,
                pool_pages: 6,
                page_size: 2,
                prefill_chunk: 2,
                prefix_cache,
                ..Default::default()
            };
            let mut s = sched(cfg);
            for i in 0..6 {
                // distinct 4-token prompts → 2 full cacheable pages each
                s.submit(req(i, vec![(i as u8) * 2, 1, (i as u8) * 2 + 1, 3], 4));
            }
            let mut steps = 0;
            let mut rs = Vec::new();
            while !s.is_idle() {
                rs.extend(s.step());
                steps += 1;
                assert!(steps < 100_000, "deadlock under preemption (cache={prefix_cache})");
            }
            rs.sort_by_key(|r| r.id);
            assert_eq!(rs.len(), 6);
            assert!(rs.iter().all(|r| r.tokens.len() == 4));
            assert_no_leak(&mut s);
            rs.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(true), run(false), "prefix cache changed generated tokens");
    }
}
