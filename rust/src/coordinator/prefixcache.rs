//! Radix prompt cache — the cross-request prefix-sharing index over the
//! paged KV pool.
//!
//! Real serving traffic shares long prompt prefixes (system prompts,
//! few-shot preambles), and once per-token compute is kernel-bound the
//! dominant redundant cost under concurrent load is re-running prefill
//! for KV rows an earlier request already produced. This cache indexes
//! those rows by the token ids that generated them: a trie whose edges
//! are **exactly `page_size` tokens** and whose nodes each pin one pool
//! page. Admission walks the trie with a new prompt, forks the matched
//! pages into the request's `SeqCache` ([`KvPool::fork_pages`] — a
//! refcount bump, no float moves), and enqueues only the uncached suffix
//! as chunked prefill.
//!
//! **Why page-granular keys.** A KV page holds `page_size` positions and
//! is the pool's unit of sharing — a fork maps whole pages or nothing.
//! Causality makes page `i`'s rows a pure function of tokens
//! `0..(i+1)·page_size`, so keying edge `i` by exactly that token chunk
//! means a trie match IS a valid KV match: no sub-page bookkeeping, no
//! partial-page copies at lookup time, and the index stays proportional
//! to cached pages rather than cached tokens.
//!
//! **Invariants** (fuzzed by `tests/kvpool_refcount.rs`, spelled out in
//! DESIGN.md §Prefix cache):
//! * every node holds exactly one refcount on its page
//!   ([`KvPool::retain_page`] on insert, [`KvPool::release_page`] on
//!   evict) — a page appears in at most one node;
//! * eviction only ever drops pages whose refcount is 1, i.e. pages no
//!   live sequence maps — shared pages are unevictable until the last
//!   sequence releases them, so a hit can never dangle;
//! * eviction removes leaves first (LRU by last-touched lookup/insert),
//!   so every root-to-node path always remains a complete prefix.

use crate::model::{KvPool, SeqCache};

/// One trie node: the `page_size`-token edge key that leads to it, the
/// pool page holding that chunk's KV rows, an LRU stamp, and children.
/// Children are a Vec scanned linearly — fan-out is small (distinct
/// prompt continuations at one depth) and iteration order deterministic.
#[derive(Debug)]
struct Node {
    key: Vec<u8>,
    page: u32,
    last_use: u64,
    children: Vec<Node>,
}

/// Token-prefix → KV-page index for one worker's pool (see module docs).
#[derive(Debug, Default)]
pub struct PrefixCache {
    page_size: usize,
    roots: Vec<Node>,
    clock: u64,
    pages_held: usize,
}

impl PrefixCache {
    pub fn new(page_size: usize) -> Self {
        assert!(page_size > 0, "PrefixCache page_size must be positive");
        Self { page_size, roots: Vec::new(), clock: 0, pages_held: 0 }
    }

    /// Pages currently pinned by the cache (each holds one refcount).
    pub fn pages_held(&self) -> usize {
        self.pages_held
    }

    /// Longest cached prefix of `tokens`, as the pool pages holding its
    /// KV rows — `pages.len() × page_size` tokens are covered. Touches
    /// the matched path's LRU stamps.
    pub fn lookup(&mut self, tokens: &[u8]) -> Vec<u32> {
        self.clock += 1;
        let clock = self.clock;
        let mut pages = Vec::new();
        let mut level = &mut self.roots;
        for chunk in tokens.chunks_exact(self.page_size) {
            match level.iter_mut().position(|n| n.key == chunk) {
                Some(i) => {
                    let node = &mut level[i];
                    node.last_use = clock;
                    pages.push(node.page);
                    level = &mut node.children;
                }
                None => break,
            }
        }
        pages
    }

    /// Index the full prompt pages of `seq` under the token chunks of
    /// `tokens` (the prompt as actually prefilled — `seq` must have at
    /// least `tokens.len()` filled positions). Chunks already present
    /// keep their existing page (first writer wins — both pages hold
    /// bit-identical rows, greedy prefill being deterministic); new
    /// chunks pin `seq`'s page with an extra refcount. Only whole pages
    /// are indexed; a trailing partial page is ignored.
    pub fn insert(&mut self, pool: &mut KvPool, tokens: &[u8], seq: &SeqCache) {
        debug_assert!(seq.len >= tokens.len() - tokens.len() % self.page_size);
        self.clock += 1;
        let clock = self.clock;
        let mut level = &mut self.roots;
        for (i, chunk) in tokens.chunks_exact(self.page_size).enumerate() {
            let pos = match level.iter_mut().position(|n| n.key == chunk) {
                Some(p) => p,
                None => {
                    let page = seq.pages()[i];
                    pool.retain_page(page);
                    self.pages_held += 1;
                    level.push(Node {
                        key: chunk.to_vec(),
                        page,
                        last_use: clock,
                        children: Vec::new(),
                    });
                    level.len() - 1
                }
            };
            let node = &mut level[pos];
            node.last_use = clock;
            level = &mut node.children;
        }
    }

    /// Free up to `want` pages by evicting least-recently-used **leaf**
    /// entries whose page has no other holder (refcount 1 — dropping the
    /// hold actually returns memory; pages live sequences map are never
    /// freed from under them, and evicting their entries would reclaim
    /// nothing). Inner nodes become evictable as their subtrees drain.
    /// Returns the number of pages actually freed.
    pub fn evict(&mut self, pool: &mut KvPool, want: usize) -> usize {
        let mut freed = 0;
        while freed < want {
            match Self::evict_lru_leaf(&mut self.roots, pool) {
                Some(page) => {
                    pool.release_page(page);
                    self.pages_held -= 1;
                    freed += 1;
                }
                None => break,
            }
        }
        freed
    }

    /// Remove the LRU leaf with a refcount-1 page from the forest rooted
    /// at `level`; returns its page (not yet released).
    fn evict_lru_leaf(level: &mut Vec<Node>, pool: &KvPool) -> Option<u32> {
        fn find(level: &[Node], pool: &KvPool, best: &mut Option<(u64, Vec<usize>)>, path: &mut Vec<usize>) {
            for (i, n) in level.iter().enumerate() {
                path.push(i);
                if n.children.is_empty() {
                    if pool.refcount(n.page) == 1
                        && best.as_ref().map(|(t, _)| n.last_use < *t).unwrap_or(true)
                    {
                        *best = Some((n.last_use, path.clone()));
                    }
                } else {
                    find(&n.children, pool, best, path);
                }
                path.pop();
            }
        }
        let mut best = None;
        find(level, pool, &mut best, &mut Vec::new());
        let (_, path) = best?;
        let mut level = level;
        for &i in &path[..path.len() - 1] {
            level = &mut level[i].children;
        }
        Some(level.remove(path[path.len() - 1]).page)
    }

    /// Drop every hold and empty the index (worker teardown, tests).
    pub fn clear(&mut self, pool: &mut KvPool) {
        fn drop_subtree(n: Node, pool: &mut KvPool) {
            pool.release_page(n.page);
            for c in n.children {
                drop_subtree(c, pool);
            }
        }
        for n in self.roots.drain(..) {
            drop_subtree(n, pool);
        }
        self.pages_held = 0;
    }

    /// Every page the cache holds (test/debug audit of refcounts).
    pub fn held_pages(&self) -> Vec<u32> {
        fn walk(level: &[Node], out: &mut Vec<u32>) {
            for n in level {
                out.push(n.page);
                walk(&n.children, out);
            }
        }
        let mut out = Vec::new();
        walk(&self.roots, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::tiny_config;

    /// A pool plus a sequence whose first `len` positions are "filled"
    /// (rows written so the refcount discipline is exercised for real).
    fn pool_with_seq(n_pages: usize, ps: usize, len: usize) -> (KvPool, SeqCache) {
        let cfg = tiny_config();
        let mut pool = KvPool::new(&cfg, n_pages, ps);
        let mut seq = SeqCache::new();
        assert!(pool.reserve(&mut seq, len));
        let row = vec![0.5; cfg.d_model];
        for pos in 0..len {
            for l in 0..cfg.n_layers {
                pool.write_row(&seq, l, pos, &row, &row);
            }
        }
        seq.len = len;
        (pool, seq)
    }

    #[test]
    fn miss_then_insert_then_hit() {
        let (mut pool, mut seq) = pool_with_seq(8, 2, 6);
        let mut c = PrefixCache::new(2);
        let prompt = [1u8, 2, 3, 4, 5, 6];
        assert!(c.lookup(&prompt).is_empty());
        c.insert(&mut pool, &prompt, &seq);
        assert_eq!(c.pages_held(), 3);
        // full hit: all 3 pages, in order
        assert_eq!(c.lookup(&prompt), seq.pages()[..3].to_vec());
        // longest-prefix hit for a diverging prompt
        assert_eq!(c.lookup(&[1, 2, 3, 4, 9, 9]), seq.pages()[..2].to_vec());
        assert_eq!(c.lookup(&[9, 9]), Vec::<u32>::new());
        // partial trailing chunk is not indexed and not matched
        assert_eq!(c.lookup(&[1, 2, 3]), seq.pages()[..1].to_vec());
        // cache holds survive the sequence releasing
        pool.release(&mut seq);
        assert_eq!(pool.free_pages(), 5);
        c.clear(&mut pool);
        assert_eq!(pool.free_pages(), 8);
    }

    #[test]
    fn insert_is_idempotent_first_writer_wins() {
        let (mut pool, seq) = pool_with_seq(8, 2, 4);
        let mut c = PrefixCache::new(2);
        c.insert(&mut pool, &[1, 2, 3, 4], &seq);
        let held = c.held_pages();
        // a second sequence with the same prompt re-inserts: no-op
        let (_, seq2) = {
            let mut s2 = SeqCache::new();
            assert!(pool.reserve(&mut s2, 4));
            let row = vec![0.25; tiny_config().d_model];
            for pos in 0..4 {
                for l in 0..tiny_config().n_layers {
                    pool.write_row(&s2, l, pos, &row, &row);
                }
            }
            s2.len = 4;
            ((), s2)
        };
        c.insert(&mut pool, &[1, 2, 3, 4], &seq2);
        assert_eq!(c.held_pages(), held, "existing chunks must keep their page");
        assert_eq!(c.pages_held(), 2);
        let mut s2 = seq2;
        pool.release(&mut s2);
        let mut s = seq;
        pool.release(&mut s);
        c.clear(&mut pool);
        assert_eq!(pool.free_pages(), 8);
    }

    #[test]
    fn evict_lru_leaves_only_and_never_shared_pages() {
        let (mut pool, mut seq) = pool_with_seq(16, 2, 6);
        let mut c = PrefixCache::new(2);
        c.insert(&mut pool, &[1, 2, 3, 4, 5, 6], &seq);
        // a live fork maps the first 2 pages (refcount 3: seq + cache + fork)
        let mut live = pool.fork(&seq, 4);
        pool.release(&mut seq);
        // leaf (page 2) has refcount 1 → evictable; pages 0/1 are mapped
        // by `live` → not evictable even after the leaf goes
        assert_eq!(c.evict(&mut pool, 10), 1, "only the unshared leaf frees a page");
        assert_eq!(c.pages_held(), 2);
        assert_eq!(c.lookup(&[1, 2, 3, 4]).len(), 2, "shared prefix must survive");
        // once the live sequence drops, the remaining chain becomes
        // evictable leaf-by-leaf
        pool.release(&mut live);
        assert_eq!(c.evict(&mut pool, 10), 2);
        assert_eq!(c.pages_held(), 0);
        assert_eq!(pool.free_pages(), 16);
    }

    #[test]
    fn evict_order_is_lru() {
        let (mut pool, mut a) = pool_with_seq(16, 2, 2);
        // two independent single-page entries
        let mut b = SeqCache::new();
        assert!(pool.reserve(&mut b, 2));
        let row = vec![1.0; tiny_config().d_model];
        for l in 0..2 {
            pool.write_row(&b, l, 0, &row, &row);
            pool.write_row(&b, l, 1, &row, &row);
        }
        b.len = 2;
        let mut c = PrefixCache::new(2);
        c.insert(&mut pool, &[1, 1], &a);
        c.insert(&mut pool, &[2, 2], &b);
        let page_a = a.pages()[0];
        let page_b = b.pages()[0];
        pool.release(&mut a);
        pool.release(&mut b);
        // touch [1,1]: [2,2] becomes the LRU entry
        assert_eq!(c.lookup(&[1, 1]).len(), 1);
        assert_eq!(c.evict(&mut pool, 1), 1);
        assert_eq!(pool.refcount(page_b), 0, "LRU entry should go first");
        assert_eq!(pool.refcount(page_a), 1);
        c.clear(&mut pool);
        assert_eq!(pool.free_pages(), 16);
    }

    #[test]
    fn branching_prefixes_share_the_trunk() {
        let (mut pool, mut a) = pool_with_seq(16, 2, 4);
        let mut c = PrefixCache::new(2);
        c.insert(&mut pool, &[7, 7, 1, 1], &a);
        // second prompt shares page 0's chunk, diverges at chunk 1
        let mut b = SeqCache::new();
        assert!(pool.reserve(&mut b, 4));
        let row = vec![2.0; tiny_config().d_model];
        for pos in 0..4 {
            for l in 0..2 {
                pool.write_row(&b, l, pos, &row, &row);
            }
        }
        b.len = 4;
        c.insert(&mut pool, &[7, 7, 2, 2], &b);
        // trunk chunk [7,7] was NOT re-pinned: 3 pages held, not 4
        assert_eq!(c.pages_held(), 3);
        assert_eq!(c.lookup(&[7, 7, 1, 1]).len(), 2);
        assert_eq!(c.lookup(&[7, 7, 2, 2]).len(), 2);
        // both hits route through the SAME trunk page
        assert_eq!(c.lookup(&[7, 7, 1, 1])[0], c.lookup(&[7, 7, 2, 2])[0]);
        pool.release(&mut a);
        pool.release(&mut b);
        c.clear(&mut pool);
        assert_eq!(pool.free_pages(), 16);
    }
}
