//! Generation server — the paper's "execution harness which allows us to
//! execute the resulting compressed models efficiently for generative
//! tasks", grown into a multi-user tier: a request router over worker
//! replicas, each worker running the continuous-batching [`Scheduler`]
//! (iteration-level batching over a paged [`KvPool`](crate::model::KvPool)
//! — see `coordinator::scheduler`), with per-request latency metrics.
//!
//! Each worker owns one [`CpuModel`] instance (dense = the FP16-baseline
//! analog, packed = the GPTQ-deployed model). Generation follows each
//! request's [`SamplingParams`] — greedy by default, seeded sampling
//! otherwise, both replay-deterministic (`coordinator::sampling`), and
//! optionally accelerated by self-speculative decoding
//! (`scheduler.spec`); N in-flight sequences advance one token per scheduler
//! iteration against shared weight reads — the multi-user form of the
//! autoregressive, matvec-bound regime the paper targets (§Practical
//! Speedups). Each worker additionally shares prompt-prefix KV across
//! its requests through a radix prefix cache over its paged pool
//! (`coordinator::prefixcache`, `scheduler.prefix_cache` knob): repeated
//! system/few-shot prefixes are forked, not re-prefilled, and
//! `ServeMetrics` reports the hit rate and prefill tokens saved. Every linear in that step runs on the runtime-dispatched
//! SIMD kernels (`model::kernels`, `--isa` / `GPTQ_ISA`): the batched
//! sub-step decodes each packed word once per batch on the active ISA,
//! and batch-1 decode uses the register-tiled layout when the model was
//! loaded under a SIMD ISA (DESIGN.md §Kernels).
//!
//! **Request lifecycle (DESIGN.md §Robustness).** Every submitted
//! request gets EXACTLY ONE terminal [`GenResponse`], tagged with a
//! [`GenOutcome`]: `Completed` (possibly with zero tokens), `Rejected`
//! (validation or admission-time load shedding), `TimedOut` (TTFT or
//! total deadline missed), `Cancelled` (cooperative [`Server::cancel`]),
//! or `Failed` (the request exhausted its worker-crash retry budget).
//! Requests carry a priority [`Class`] and optional deadlines; the
//! scheduler sheds by class bound and deadline (see
//! `coordinator::scheduler`).
//!
//! **Fault isolation.** Worker loops wrap every scheduler tick in
//! `catch_unwind`; a panicking worker reports itself dead and exits with
//! its metrics intact. The server reaps the thread and re-routes that
//! worker's outstanding requests to survivors with a bounded retry
//! budget ([`MAX_WORKER_DEATHS`]): token selection is deterministic for
//! greedy AND seeded sampling (picks are pure functions of
//! `(seed, position)`), so a replayed request reproduces its tokens,
//! and a request that has killed two workers is answered `Failed`
//! instead of being retried forever.
//! [`Server::submit`]/[`Server::recv`] return typed [`ServeError`]s
//! instead of panicking when no worker is left; a submit reusing an
//! in-flight id is rejected as [`ServeError::DuplicateId`] (the
//! outstanding table is keyed by id, so a silent overwrite would leak
//! the first request's terminal response).

use crate::coordinator::metrics::ServeMetrics;
use crate::coordinator::sampling::SamplingParams;
use crate::coordinator::scheduler::{Scheduler, SchedulerConfig};
use crate::data::CorpusFile;
use crate::eval::{perplexity, perplexity_artifact};
use crate::model::{Checkpoint, CpuModel};
use crate::runtime::Runtime;
use crate::Result;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Request priority class. `Interactive` is admitted first and is the
/// last to be preempted or shed; `Batch` absorbs overload (its queue
/// bound is meant to be the smaller one, and it is the preferred
/// preemption victim).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Class {
    #[default]
    Interactive,
    Batch,
}

impl Class {
    pub const COUNT: usize = 2;

    /// Dense index for per-class tables (queues, counters).
    pub fn idx(self) -> usize {
        match self {
            Class::Interactive => 0,
            Class::Batch => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Class::Interactive => "interactive",
            Class::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Option<Class> {
        match s {
            "interactive" => Some(Class::Interactive),
            "batch" => Some(Class::Batch),
            _ => None,
        }
    }
}

/// The one terminal state every submitted request reaches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GenOutcome {
    /// ran to its stop condition (max tokens, EOS, length cap) — the
    /// token stream may legitimately be empty (EOS as the first pick,
    /// or `max_new_tokens == 0`)
    Completed,
    /// never admitted: failed validation (empty prompt) or shed at
    /// admission by a full per-class queue bound
    Rejected,
    /// missed a deadline: shed from the queue past its TTFT/total
    /// deadline, or stopped mid-generation past its total deadline
    /// (partial tokens are returned)
    TimedOut,
    /// cooperatively cancelled by id (partial tokens are returned)
    Cancelled,
    /// exhausted the worker-crash retry budget (killed two workers) or
    /// no worker was left to retry on
    Failed,
}

impl GenOutcome {
    pub fn name(self) -> &'static str {
        match self {
            GenOutcome::Completed => "completed",
            GenOutcome::Rejected => "rejected",
            GenOutcome::TimedOut => "timed_out",
            GenOutcome::Cancelled => "cancelled",
            GenOutcome::Failed => "failed",
        }
    }
}

/// A generation request. Construct with [`GenRequest::new`] + the
/// builder methods — new lifecycle fields default to "no constraint"
/// (`Interactive`, no deadlines), which reproduces the pre-lifecycle
/// behavior exactly.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    /// admission/preemption/shedding class (default `Interactive`)
    pub priority: Class,
    /// submit → first token budget, ms: a queued request that can no
    /// longer meet it is shed as `TimedOut` instead of occupying pool
    /// pages for an answer nobody is waiting for
    pub ttft_deadline_ms: Option<f64>,
    /// submit → last token budget, ms: checked per tick; a running
    /// request past it is stopped (`TimedOut`), its pages reclaimed,
    /// and its partial tokens returned
    pub deadline_ms: Option<f64>,
    /// token-selection parameters (default: greedy, temperature 0 —
    /// bitwise the pre-sampling behavior); seeded sampling draws from a
    /// counter-based RNG keyed by `(seed, position)` so preemption and
    /// worker-crash replays reproduce the same tokens
    pub sampling: SamplingParams,
}

impl GenRequest {
    pub fn new(id: u64, prompt: Vec<u8>, max_new_tokens: usize) -> Self {
        Self {
            id,
            prompt,
            max_new_tokens,
            priority: Class::Interactive,
            ttft_deadline_ms: None,
            deadline_ms: None,
            sampling: SamplingParams::greedy(),
        }
    }

    pub fn with_priority(mut self, priority: Class) -> Self {
        self.priority = priority;
        self
    }

    pub fn with_ttft_deadline_ms(mut self, ms: f64) -> Self {
        self.ttft_deadline_ms = Some(ms);
        self
    }

    pub fn with_deadline_ms(mut self, ms: f64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    pub fn with_sampling(mut self, sampling: SamplingParams) -> Self {
        self.sampling = sampling;
        self
    }
}

/// A terminal response (exactly one per submitted request).
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<u8>,
    /// per-token decode latencies, ms: each sample is the batched step
    /// that consumed the token (prefill excluded — the paper's per-token
    /// generation metric)
    pub per_token_ms: Vec<f64>,
    pub prefill_ms: f64,
    /// submit → admitted to a scheduler slot, ms (for a request shed
    /// from the queue: submit → shed)
    pub queue_wait_ms: f64,
    /// submit → first generated token available, ms; `None` when the
    /// request emitted no token (`max_new_tokens` 0, EOS as the first
    /// pick, or a pre-first-token shed) — the old API reported a 0.0
    /// sentinel here, which polluted TTFT percentiles downstream
    pub ttft_ms: Option<f64>,
    /// prompt tokens whose KV was forked from the worker's prefix cache
    /// at admission instead of being prefilled (0 = fully cold prompt,
    /// or `scheduler.prefix_cache` disabled)
    pub cached_prefix_len: usize,
    /// how this request terminated (see [`GenOutcome`])
    pub outcome: GenOutcome,
    pub worker: usize,
}

/// Typed server errors — the old API called `.expect("worker died")`
/// here and took the whole process down with the first worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeError {
    /// every worker thread has died; the server cannot accept new work
    NoWorkers,
    /// all workers have exited and no response is pending — nothing
    /// will ever arrive
    Disconnected,
    /// the submitted id is already in flight: the outstanding table is
    /// keyed by id, so accepting the duplicate would silently overwrite
    /// the first request's replay copy and leak its terminal response
    /// (the old code did exactly that)
    DuplicateId(u64),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::NoWorkers => write!(f, "no live workers: cannot accept new requests"),
            ServeError::Disconnected => {
                write!(f, "all workers exited and no response is pending")
            }
            ServeError::DuplicateId(id) => {
                write!(f, "request id {id} is already in flight: ids must be unique until answered")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Worker-crash retry budget: a request that has been on this many dead
/// workers is answered `Failed` instead of being retried forever (it is
/// probably what is killing them).
pub const MAX_WORKER_DEATHS: u32 = 2;

/// Server shape: worker count plus each worker's scheduler knobs
/// (`scheduler.max_batch`, `scheduler.pool_pages`, … — see
/// [`SchedulerConfig`]).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub n_workers: usize,
    /// per-worker continuous-batching knobs (slot budget, KV pool, …)
    pub scheduler: SchedulerConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { n_workers: 1, scheduler: SchedulerConfig::default() }
    }
}

enum Job {
    Gen(GenRequest),
    Cancel(u64),
    Stop,
}

/// What workers stream back on the shared response channel. mpsc
/// preserves per-sender order, so a worker's `Done`s are always
/// processed before its own `Died` — a completed request is never
/// double-answered by the re-route path.
enum Event {
    Done(GenResponse),
    /// the worker's scheduler panicked mid-tick; the thread is exiting
    /// (its metrics are recovered by joining the handle)
    Died { wid: usize },
}

/// Multi-worker generation server with least-loaded routing, worker
/// fault isolation, and bounded crash retries (see the module docs).
pub struct Server {
    /// per-worker job channels; `None` = reaped (dead) worker
    senders: Vec<Option<Sender<Job>>>,
    resp_rx: Receiver<Event>,
    inflight: Vec<Arc<AtomicUsize>>,
    handles: Vec<Option<JoinHandle<ServeMetrics>>>,
    /// submitted-but-unanswered requests: id → (request copy for
    /// replay, worker it is currently routed to)
    outstanding: HashMap<u64, (GenRequest, usize)>,
    /// worker deaths each outstanding request has survived (the retry
    /// budget, [`MAX_WORKER_DEATHS`])
    deaths: HashMap<u64, u32>,
    /// responses ready to hand out: drained worker completions plus
    /// synthesized `Failed` answers
    ready: VecDeque<GenResponse>,
    /// metrics recovered from reaped (panicked) workers
    reaped: ServeMetrics,
}

impl Server {
    /// `make_model` builds one model replica per worker (each worker owns
    /// its weights — the "model parallel replicas" shape of a router tier).
    pub fn start<F>(cfg: ServerConfig, make_model: F) -> Self
    where
        F: Fn(usize) -> CpuModel,
    {
        let (resp_tx, resp_rx) = channel::<Event>();
        let mut senders = Vec::new();
        let mut inflight = Vec::new();
        let mut handles = Vec::new();
        for wid in 0..cfg.n_workers {
            let (tx, rx) = channel::<Job>();
            let model = make_model(wid);
            let resp_tx = resp_tx.clone();
            let count = Arc::new(AtomicUsize::new(0));
            let count_w = count.clone();
            let scfg = cfg.scheduler.clone();
            handles.push(Some(std::thread::spawn(move || {
                worker_loop(wid, model, rx, resp_tx, count_w, scfg)
            })));
            senders.push(Some(tx));
            inflight.push(count);
        }
        // the original `resp_tx` drops here: a disconnect on `resp_rx`
        // then means every worker has exited
        Self {
            senders,
            resp_rx,
            inflight,
            handles,
            outstanding: HashMap::new(),
            deaths: HashMap::new(),
            ready: VecDeque::new(),
            reaped: ServeMetrics::new(),
        }
    }

    /// Workers still accepting jobs.
    pub fn live_workers(&self) -> usize {
        self.senders.iter().filter(|s| s.is_some()).count()
    }

    /// Route a request to the least-loaded live worker. Returns the
    /// worker id, [`ServeError::NoWorkers`] when every worker has died
    /// (the old API panicked here), or [`ServeError::DuplicateId`] when
    /// `req.id` is still in flight — an id is reusable only after its
    /// terminal response has been issued.
    pub fn submit(&mut self, req: GenRequest) -> std::result::Result<usize, ServeError> {
        self.drain_events();
        if self.outstanding.contains_key(&req.id) {
            return Err(ServeError::DuplicateId(req.id));
        }
        let wid = self.least_loaded().ok_or(ServeError::NoWorkers)?;
        self.route(req, wid);
        Ok(wid)
    }

    fn least_loaded(&self) -> Option<usize> {
        self.senders
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_some())
            .min_by_key(|&(i, _)| self.inflight[i].load(Ordering::Relaxed))
            .map(|(i, _)| i)
    }

    fn route(&mut self, req: GenRequest, wid: usize) {
        self.inflight[wid].fetch_add(1, Ordering::Relaxed);
        self.outstanding.insert(req.id, (req.clone(), wid));
        if let Some(tx) = &self.senders[wid] {
            // a send error means the worker died after `least_loaded`
            // looked: its `Died` event is already in flight and will
            // re-route this request when processed
            let _ = tx.send(Job::Gen(req));
        }
    }

    /// Request cooperative cancellation of `id` (best-effort: a request
    /// that already completed is unaffected; a cancelled one is answered
    /// `Cancelled` with whatever tokens it had generated).
    pub fn cancel(&mut self, id: u64) {
        self.drain_events();
        if let Some((_, wid)) = self.outstanding.get(&id) {
            let wid = *wid;
            if let Some(tx) = &self.senders[wid] {
                let _ = tx.send(Job::Cancel(id));
            }
        }
    }

    /// Block for the next terminal response. `Err(Disconnected)` only
    /// when every worker has exited and nothing is pending — the old
    /// API panicked ("all workers died") instead.
    pub fn recv(&mut self) -> std::result::Result<GenResponse, ServeError> {
        loop {
            if let Some(r) = self.ready.pop_front() {
                return Ok(r);
            }
            match self.resp_rx.recv() {
                Ok(ev) => self.handle_event(ev),
                Err(_) => {
                    // every worker exited (each held a resp_tx clone).
                    // Reaching here with requests still outstanding means
                    // they died with their workers before a Died event
                    // could be sent — answer them Failed, never hang.
                    if self.outstanding.is_empty() {
                        return Err(ServeError::Disconnected);
                    }
                    let mut ids: Vec<u64> = self.outstanding.keys().copied().collect();
                    ids.sort_unstable();
                    for id in ids {
                        // tolerant remove: the id came from the table one
                        // statement ago, but a missing entry must degrade
                        // to a skipped replay, not a router panic — the
                        // old `.unwrap()` here could take down the whole
                        // server over one bookkeeping miss
                        let Some((req, wid)) = self.outstanding.remove(&id) else {
                            eprintln!(
                                "serve: request {id} vanished from the outstanding table \
                                 during the final drain — skipping"
                            );
                            continue;
                        };
                        self.reaped.record_outcome(GenOutcome::Failed);
                        self.ready.push_back(failed_response(&req, wid));
                    }
                }
            }
        }
    }

    /// Drain exactly `n` responses.
    pub fn collect(&mut self, n: usize) -> std::result::Result<Vec<GenResponse>, ServeError> {
        (0..n).map(|_| self.recv()).collect()
    }

    fn drain_events(&mut self) {
        while let Ok(ev) = self.resp_rx.try_recv() {
            self.handle_event(ev);
        }
    }

    fn handle_event(&mut self, ev: Event) {
        match ev {
            Event::Done(resp) => {
                self.outstanding.remove(&resp.id);
                self.deaths.remove(&resp.id);
                self.ready.push_back(resp);
            }
            Event::Died { wid } => self.reap(wid),
        }
    }

    /// A worker panicked: reap its thread (recovering its metrics), then
    /// re-route everything still routed to it. Each orphan's death count
    /// is bumped; one that has now killed [`MAX_WORKER_DEATHS`] workers
    /// — or has no survivor to run on — is answered `Failed`.
    fn reap(&mut self, wid: usize) {
        self.senders[wid] = None;
        if let Some(h) = self.handles[wid].take() {
            if let Ok(m) = h.join() {
                self.reaped.merge(&m);
            }
        }
        let mut orphans: Vec<u64> = self
            .outstanding
            .iter()
            .filter(|(_, (_, w))| *w == wid)
            .map(|(id, _)| *id)
            .collect();
        orphans.sort_unstable();
        for id in orphans {
            // tolerant remove, same rationale as the final-drain path:
            // losing one replay beats panicking the router that every
            // other request depends on
            let Some((req, _)) = self.outstanding.remove(&id) else {
                eprintln!(
                    "serve: orphan {id} of dead worker {wid} vanished from the \
                     outstanding table — skipping replay"
                );
                continue;
            };
            let survived = self.deaths.entry(id).or_insert(0);
            *survived += 1;
            let over_budget = *survived >= MAX_WORKER_DEATHS;
            match (over_budget, self.least_loaded()) {
                (false, Some(next)) => self.route(req, next),
                _ => {
                    self.deaths.remove(&id);
                    self.reaped.record_outcome(GenOutcome::Failed);
                    self.ready.push_back(failed_response(&req, wid));
                }
            }
        }
    }

    /// Stop workers and return their merged serving metrics (including
    /// metrics recovered from workers that crashed earlier).
    pub fn shutdown(mut self) -> ServeMetrics {
        for tx in self.senders.iter().flatten() {
            let _ = tx.send(Job::Stop);
        }
        let mut metrics = std::mem::take(&mut self.reaped);
        for h in &mut self.handles {
            if let Some(h) = h.take() {
                if let Ok(m) = h.join() {
                    metrics.merge(&m);
                }
            }
        }
        metrics
    }
}

fn failed_response(req: &GenRequest, wid: usize) -> GenResponse {
    GenResponse {
        id: req.id,
        tokens: Vec::new(),
        per_token_ms: Vec::new(),
        prefill_ms: 0.0,
        queue_wait_ms: 0.0,
        ttft_ms: None,
        cached_prefix_len: 0,
        outcome: GenOutcome::Failed,
        worker: wid,
    }
}

/// Pre-flight deployment check: evaluate a few segments through BOTH the
/// serving decode path (`CpuModel`, KV-cached) and the runtime's execution
/// backend (`lm_fwd_<size>` artifact contract), and return the relative
/// perplexity difference. A healthy deployment is ≈0 on the reference
/// backend and <2% against the lowered XLA graph; anything larger means
/// the checkpoint and the artifact tree disagree (stale `make artifacts`,
/// wrong size flag, corrupted weights).
///
/// `segments` should be a multiple of the manifest's `eval_batch`.
pub fn verify_parity(
    rt: &mut Runtime,
    size: &str,
    ckpt: &Checkpoint,
    corpus: &CorpusFile,
    segments: usize,
) -> Result<f64> {
    let seq = rt.manifest.seq_len;
    let batch = rt.manifest.eval_batch;
    let batches = (segments / batch).max(1);
    let mut cpu = CpuModel::from_checkpoint(ckpt);
    let ppl_cpu = perplexity(&mut cpu, corpus, seq, batches * batch);
    let ppl_art = perplexity_artifact(rt, size, ckpt, corpus, batches)?;
    Ok((ppl_cpu - ppl_art).abs() / ppl_art.max(1e-12))
}

/// Worker: admit jobs into the continuous-batching scheduler (blocking
/// only when idle), run one scheduler iteration per loop, stream
/// completions back. On `Stop`, everything already submitted drains to
/// completion before the worker exits (the channel is FIFO, so every
/// `Gen` sent before the `Stop` has been admitted by then).
///
/// Every tick runs under `catch_unwind`: a panic (a real bug, or an
/// injected `GPTQ_FAULTS` panic) reports `Died` on the response channel
/// and exits with the scheduler's metrics — the process, the other
/// workers, and the panicking worker's requests (replayed elsewhere by
/// the server) all survive. Injected panics fire at the tick boundary
/// before any state changes, so a replay starts from a clean slate.
fn worker_loop(
    wid: usize,
    model: CpuModel,
    rx: Receiver<Job>,
    resp_tx: Sender<Event>,
    inflight: Arc<AtomicUsize>,
    scfg: SchedulerConfig,
) -> ServeMetrics {
    let mut sched = Scheduler::new(wid, model, scfg);
    let mut stopping = false;
    loop {
        // block for work only when there is nothing to advance
        if !stopping && sched.is_idle() {
            match rx.recv() {
                Ok(Job::Gen(r)) => sched.submit(r),
                Ok(Job::Cancel(id)) => {
                    sched.cancel(id);
                }
                Ok(Job::Stop) | Err(_) => stopping = true,
            }
        }
        // then drain whatever else is already queued, without blocking —
        // new arrivals join the batch at the next iteration's admission
        if !stopping {
            loop {
                match rx.try_recv() {
                    Ok(Job::Gen(r)) => sched.submit(r),
                    Ok(Job::Cancel(id)) => {
                        sched.cancel(id);
                    }
                    Ok(Job::Stop) => {
                        stopping = true;
                        break;
                    }
                    Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                }
            }
        }
        if sched.is_idle() {
            if stopping {
                break;
            }
            continue;
        }
        match catch_unwind(AssertUnwindSafe(|| sched.step())) {
            Ok(responses) => {
                for resp in responses {
                    inflight.fetch_sub(1, Ordering::Relaxed);
                    let _ = resp_tx.send(Event::Done(resp));
                }
            }
            Err(_) => {
                // the tick panicked: report the death (the server
                // re-routes everything still routed here) and exit with
                // whatever metrics the scheduler had accumulated
                let _ = resp_tx.send(Event::Died { wid });
                return sched.into_metrics();
            }
        }
    }
    sched.into_metrics()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::tiny_checkpoint;
    use crate::util::faultinject::FaultConfig;

    fn server(n_workers: usize) -> Server {
        let cfg = ServerConfig {
            n_workers,
            scheduler: SchedulerConfig { max_batch: 2, ..Default::default() },
        };
        Server::start(cfg, |_| CpuModel::from_checkpoint(&tiny_checkpoint(7)))
    }

    #[test]
    fn serves_one_request() {
        let mut s = server(1);
        s.submit(GenRequest::new(1, vec![1, 2, 3], 4)).unwrap();
        let r = s.recv().unwrap();
        assert_eq!(r.id, 1);
        assert_eq!(r.tokens.len(), 4);
        assert_eq!(r.per_token_ms.len(), 4);
        assert_eq!(r.outcome, GenOutcome::Completed);
        assert!(r.ttft_ms.unwrap() >= 0.0 && r.queue_wait_ms >= 0.0);
        let m = s.shutdown();
        assert_eq!(m.per_token.count(), 4);
        assert_eq!(m.requests(), 1);
        assert_eq!(m.ttft.count(), 1);
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn no_request_lost_across_workers() {
        let mut s = server(3);
        let n = 20;
        for i in 0..n {
            s.submit(GenRequest::new(i, vec![(i % 16) as u8], 2)).unwrap();
        }
        let rs = s.collect(n as usize).unwrap();
        assert!(rs.iter().all(|r| r.outcome == GenOutcome::Completed));
        let mut ids: Vec<u64> = rs.into_iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n).collect::<Vec<_>>());
        s.shutdown();
    }

    #[test]
    fn routing_spreads_load() {
        let mut s = server(2);
        let n = 8;
        for i in 0..n {
            s.submit(GenRequest::new(i, vec![0], 1)).unwrap();
        }
        let workers: std::collections::HashSet<usize> =
            s.collect(n as usize).unwrap().into_iter().map(|r| r.worker).collect();
        assert!(workers.len() >= 2, "all requests went to one worker");
        s.shutdown();
    }

    #[test]
    fn generation_deterministic() {
        let mut s1 = server(1);
        s1.submit(GenRequest::new(0, vec![5, 6], 6)).unwrap();
        let r1 = s1.recv().unwrap();
        s1.shutdown();
        let mut s2 = server(1);
        s2.submit(GenRequest::new(0, vec![5, 6], 6)).unwrap();
        let r2 = s2.recv().unwrap();
        s2.shutdown();
        assert_eq!(r1.tokens, r2.tokens);
    }

    #[test]
    fn respects_max_seq() {
        let mut s = server(1);
        // prompt + generation longer than max_seq (16) must truncate safely
        s.submit(GenRequest::new(9, vec![1; 30], 30)).unwrap();
        let r = s.recv().unwrap();
        assert!(r.tokens.len() < 16);
        assert_eq!(r.outcome, GenOutcome::Completed);
        s.shutdown();
    }

    #[test]
    fn validation_outcomes_at_submit() {
        // satellite: empty prompt and max_new_tokens == 0 get explicit
        // immediate outcomes instead of implicit scheduler behavior
        let mut s = server(1);
        s.submit(GenRequest::new(0, vec![1, 2], 0)).unwrap();
        s.submit(GenRequest::new(1, vec![], 3)).unwrap();
        let rs = s.collect(2).unwrap();
        let by_id = |id: u64| rs.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(0).outcome, GenOutcome::Completed, "empty generation is vacuously done");
        assert_eq!(by_id(1).outcome, GenOutcome::Rejected, "no logits exist for an empty prompt");
        assert!(by_id(0).tokens.is_empty() && by_id(1).tokens.is_empty());
        assert_eq!(by_id(0).ttft_ms, None);
        let m = s.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.rejected, 1);
        assert_eq!(m.ttft.count(), 0, "no 0.0 TTFT sentinel from token-less requests");
        assert_eq!(m.no_token_requests, 1);
    }

    #[test]
    fn pool_limited_server_completes_all_requests() {
        // a pool far smaller than the offered load: backpressure (preempt
        // + re-queue) must still complete everything
        let cfg = ServerConfig {
            n_workers: 1,
            scheduler: SchedulerConfig {
                max_batch: 4,
                pool_pages: 4,
                page_size: 2,
                ..Default::default()
            },
        };
        let mut s =
            Server::start(cfg, |_| CpuModel::from_checkpoint(&tiny_checkpoint(7)));
        let n = 10;
        for i in 0..n {
            s.submit(GenRequest::new(i, vec![2, 7, 1], 3)).unwrap();
        }
        let rs = s.collect(n as usize).unwrap();
        assert!(rs.iter().all(|r| r.tokens.len() == 3));
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n).collect::<Vec<_>>());
        s.shutdown();
    }

    #[test]
    fn server_reports_prefix_cache_savings() {
        let cfg = ServerConfig {
            n_workers: 1,
            scheduler: SchedulerConfig { max_batch: 2, page_size: 2, ..Default::default() },
        };
        let mut s =
            Server::start(cfg, |_| CpuModel::from_checkpoint(&tiny_checkpoint(7)));
        // sequential same-prompt requests: the second must fork the
        // first's pages (prompt 6 tokens = 3 full pages, capped to 5)
        s.submit(GenRequest::new(0, vec![4, 5, 6, 7, 8, 9], 2)).unwrap();
        let r0 = s.recv().unwrap();
        s.submit(GenRequest::new(1, vec![4, 5, 6, 7, 8, 9], 2)).unwrap();
        let r1 = s.recv().unwrap();
        assert_eq!(r0.cached_prefix_len, 0);
        assert_eq!(r1.cached_prefix_len, 5);
        assert_eq!(r0.tokens, r1.tokens, "prefix sharing changed greedy decode");
        let m = s.shutdown();
        assert_eq!(m.prefix_lookups, 2);
        assert_eq!(m.prefix_hits, 1);
        assert_eq!(m.prefill_tokens_saved, 5);
        assert!((m.cache_hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn worker_panic_loses_no_requests() {
        // worker 0 panics at its 2nd tick; every request routed to it
        // must be replayed on worker 1 and complete with full output
        let cfg = ServerConfig {
            n_workers: 2,
            scheduler: SchedulerConfig {
                max_batch: 2,
                faults: FaultConfig { panic_at: vec![(0, 2)], ..FaultConfig::off() },
                ..Default::default()
            },
        };
        let mut s = Server::start(cfg, |_| CpuModel::from_checkpoint(&tiny_checkpoint(7)));
        let n = 20u64;
        for i in 0..n {
            s.submit(GenRequest::new(i, vec![(i % 16) as u8, 3], 4)).unwrap();
        }
        let rs = s.collect(n as usize).unwrap();
        assert!(rs.iter().all(|r| r.outcome == GenOutcome::Completed), "a worker panic must not fail requests");
        assert!(rs.iter().all(|r| r.tokens.len() == 4), "replayed requests must produce full output");
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n).collect::<Vec<_>>(), "worker panic lost or duplicated requests");
        assert_eq!(s.live_workers(), 1, "the panicked worker must be reaped");
        s.shutdown();
    }

    #[test]
    fn all_workers_dead_fails_requests_and_errors_typed() {
        // both workers panic on their first tick: every request exhausts
        // the retry budget (or has no survivor) and is answered Failed;
        // submit/recv then return typed errors instead of panicking
        let cfg = ServerConfig {
            n_workers: 2,
            scheduler: SchedulerConfig {
                max_batch: 2,
                faults: FaultConfig { panic_at: vec![(0, 1), (1, 1)], ..FaultConfig::off() },
                ..Default::default()
            },
        };
        let mut s = Server::start(cfg, |_| CpuModel::from_checkpoint(&tiny_checkpoint(7)));
        let n = 6u64;
        for i in 0..n {
            s.submit(GenRequest::new(i, vec![1, 2], 3)).unwrap();
        }
        let rs = s.collect(n as usize).unwrap();
        assert!(rs.iter().all(|r| r.outcome == GenOutcome::Failed));
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n).collect::<Vec<_>>(), "every request still got a terminal answer");
        assert_eq!(s.live_workers(), 0);
        assert_eq!(
            s.submit(GenRequest::new(99, vec![1], 1)).unwrap_err(),
            ServeError::NoWorkers
        );
        assert_eq!(s.recv().unwrap_err(), ServeError::Disconnected);
        let m = s.shutdown();
        assert_eq!(m.failed, n as usize);
    }

    #[test]
    fn duplicate_in_flight_id_rejected_typed() {
        // satellite bugfix: reusing an in-flight id used to silently
        // overwrite the outstanding entry (leaking the first request's
        // terminal response); now it is a typed error and the original
        // request is unaffected
        let mut s = server(1);
        s.submit(GenRequest::new(7, vec![1, 2, 3], 4)).unwrap();
        let err = s.submit(GenRequest::new(7, vec![9, 9], 1)).unwrap_err();
        assert_eq!(err, ServeError::DuplicateId(7));
        assert!(err.to_string().contains("already in flight"), "{err}");
        let r = s.recv().unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.tokens.len(), 4, "original request must complete untouched");
        // the id is free again once answered: resubmitting is legal
        s.submit(GenRequest::new(7, vec![1, 2, 3], 2)).unwrap();
        assert_eq!(s.recv().unwrap().tokens.len(), 2);
        let m = s.shutdown();
        assert_eq!(m.completed, 2, "the duplicate must not produce a terminal outcome");
    }

    #[test]
    fn seeded_sampling_survives_worker_crash_replay() {
        // a sampled request replayed on a surviving worker must
        // reproduce the exact tokens of a crash-free run — picks are
        // pure functions of (seed, position), not of which worker runs
        let run = |faults: FaultConfig| {
            let cfg = ServerConfig {
                n_workers: 2,
                scheduler: SchedulerConfig { max_batch: 2, faults, ..Default::default() },
            };
            let mut s = Server::start(cfg, |_| CpuModel::from_checkpoint(&tiny_checkpoint(7)));
            let n = 8u64;
            for i in 0..n {
                s.submit(
                    GenRequest::new(i, vec![(i % 16) as u8, 3], 4).with_sampling(
                        SamplingParams { temperature: 1.2, top_k: 0, top_p: 0.95, seed: 100 + i },
                    ),
                )
                .unwrap();
            }
            let mut rs = s.collect(n as usize).unwrap();
            assert!(rs.iter().all(|r| r.outcome == GenOutcome::Completed));
            s.shutdown();
            rs.sort_by_key(|r| r.id);
            rs.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        let clean = run(FaultConfig::off());
        let crashy = run(FaultConfig { panic_at: vec![(0, 2)], ..FaultConfig::off() });
        assert_eq!(clean, crashy, "worker-crash replay changed sampled tokens");
    }

    #[test]
    fn cancel_is_terminal_exactly_once() {
        let mut s = server(1);
        s.submit(GenRequest::new(5, vec![1, 2, 3], 12)).unwrap();
        s.cancel(5);
        let r = s.recv().unwrap();
        assert_eq!(r.id, 5);
        // the race between completion and cancellation is inherent; both
        // are valid single terminal outcomes
        assert!(
            r.outcome == GenOutcome::Cancelled || r.outcome == GenOutcome::Completed,
            "{:?}",
            r.outcome
        );
        s.shutdown();
    }

    #[test]
    fn parity_check_passes_on_reference_backend() {
        use crate::model::testkit::{tiny_corpus, tiny_manifest, TINY_SIZE};
        let (seq, batch) = (12usize, 2usize);
        let mut rt = crate::runtime::Runtime::new(tiny_manifest(seq, batch)).unwrap();
        let ckpt = tiny_checkpoint(11);
        let corpus = tiny_corpus(1024, 7);
        let rel = verify_parity(&mut rt, TINY_SIZE, &ckpt, &corpus, 4).unwrap();
        assert!(rel < 1e-3, "decode path vs reference backend: rel {rel}");
    }
}
