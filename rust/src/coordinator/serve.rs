//! Generation server — the paper's "execution harness which allows us to
//! execute the resulting compressed models efficiently for generative
//! tasks": a request router over worker replicas, a dynamic batcher with a
//! linger window, per-worker KV caches, and per-token latency metrics.
//!
//! Each worker owns one [`CpuModel`] instance (dense = the FP16-baseline
//! analog, packed = the GPTQ-deployed model); generation is token-by-token
//! greedy decode at batch size 1 per request — the autoregressive,
//! matvec-bound regime the paper targets (§Practical Speedups).

use crate::coordinator::metrics::LatencyStats;
use crate::data::CorpusFile;
use crate::eval::{perplexity, perplexity_artifact};
use crate::model::{Checkpoint, CpuModel, KvCache};
use crate::runtime::Runtime;
use crate::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<u8>,
    /// per-token decode latencies, ms (prefill excluded — the paper's
    /// per-token generation metric)
    pub per_token_ms: Vec<f64>,
    pub prefill_ms: f64,
    pub worker: usize,
}

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub n_workers: usize,
    /// max requests a worker drains per batching round
    pub max_batch: usize,
    /// how long the batcher lingers for stragglers
    pub linger: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { n_workers: 1, max_batch: 4, linger: Duration::from_millis(2) }
    }
}

enum Job {
    Gen(GenRequest),
    Stop,
}

/// Multi-worker generation server with least-loaded routing.
pub struct Server {
    senders: Vec<Sender<Job>>,
    resp_rx: Receiver<GenResponse>,
    inflight: Vec<Arc<AtomicUsize>>,
    handles: Vec<JoinHandle<LatencyStats>>,
    submitted: u64,
}

impl Server {
    /// `make_model` builds one model replica per worker (each worker owns
    /// its weights — the "model parallel replicas" shape of a router tier).
    pub fn start<F>(cfg: ServerConfig, make_model: F) -> Self
    where
        F: Fn(usize) -> CpuModel,
    {
        let (resp_tx, resp_rx) = channel::<GenResponse>();
        let mut senders = Vec::new();
        let mut inflight = Vec::new();
        let mut handles = Vec::new();
        for wid in 0..cfg.n_workers {
            let (tx, rx) = channel::<Job>();
            let model = make_model(wid);
            let resp_tx = resp_tx.clone();
            let count = Arc::new(AtomicUsize::new(0));
            let count_w = count.clone();
            let max_batch = cfg.max_batch;
            let linger = cfg.linger;
            handles.push(std::thread::spawn(move || {
                worker_loop(wid, model, rx, resp_tx, count_w, max_batch, linger)
            }));
            senders.push(tx);
            inflight.push(count);
        }
        Self { senders, resp_rx, inflight, handles, submitted: 0 }
    }

    /// Route a request to the least-loaded worker. Returns the worker id.
    pub fn submit(&mut self, req: GenRequest) -> usize {
        let wid = self
            .inflight
            .iter()
            .enumerate()
            .min_by_key(|(_, c)| c.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .unwrap();
        self.inflight[wid].fetch_add(1, Ordering::Relaxed);
        self.submitted += 1;
        self.senders[wid].send(Job::Gen(req)).expect("worker died");
        wid
    }

    /// Block for the next completed response.
    pub fn recv(&self) -> GenResponse {
        self.resp_rx.recv().expect("all workers died")
    }

    /// Drain exactly `n` responses.
    pub fn collect(&self, n: usize) -> Vec<GenResponse> {
        (0..n).map(|_| self.recv()).collect()
    }

    /// Stop workers and return their merged per-token latency stats.
    pub fn shutdown(self) -> LatencyStats {
        for tx in &self.senders {
            let _ = tx.send(Job::Stop);
        }
        let mut stats = LatencyStats::new();
        for h in self.handles {
            if let Ok(s) = h.join() {
                stats.merge(&s);
            }
        }
        stats
    }
}

/// Pre-flight deployment check: evaluate a few segments through BOTH the
/// serving decode path (`CpuModel`, KV-cached) and the runtime's execution
/// backend (`lm_fwd_<size>` artifact contract), and return the relative
/// perplexity difference. A healthy deployment is ≈0 on the reference
/// backend and <2% against the lowered XLA graph; anything larger means
/// the checkpoint and the artifact tree disagree (stale `make artifacts`,
/// wrong size flag, corrupted weights).
///
/// `segments` should be a multiple of the manifest's `eval_batch`.
pub fn verify_parity(
    rt: &mut Runtime,
    size: &str,
    ckpt: &Checkpoint,
    corpus: &CorpusFile,
    segments: usize,
) -> Result<f64> {
    let seq = rt.manifest.seq_len;
    let batch = rt.manifest.eval_batch;
    let batches = (segments / batch).max(1);
    let mut cpu = CpuModel::from_checkpoint(ckpt);
    let ppl_cpu = perplexity(&mut cpu, corpus, seq, batches * batch);
    let ppl_art = perplexity_artifact(rt, size, ckpt, corpus, batches)?;
    Ok((ppl_cpu - ppl_art).abs() / ppl_art.max(1e-12))
}

fn worker_loop(
    wid: usize,
    mut model: CpuModel,
    rx: Receiver<Job>,
    resp_tx: Sender<GenResponse>,
    inflight: Arc<AtomicUsize>,
    max_batch: usize,
    linger: Duration,
) -> LatencyStats {
    let mut stats = LatencyStats::new();
    let mut cache = KvCache::new(&model.config);
    'outer: loop {
        // dynamic batching: block for one job, linger for stragglers
        let first = match rx.recv() {
            Ok(Job::Gen(r)) => r,
            _ => break 'outer,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + linger;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Job::Gen(r)) => batch.push(r),
                Ok(Job::Stop) => {
                    process_batch(wid, &mut model, &mut cache, &batch, &resp_tx, &inflight, &mut stats);
                    break 'outer;
                }
                Err(_) => break,
            }
        }
        process_batch(wid, &mut model, &mut cache, &batch, &resp_tx, &inflight, &mut stats);
    }
    stats
}

fn process_batch(
    wid: usize,
    model: &mut CpuModel,
    cache: &mut KvCache,
    batch: &[GenRequest],
    resp_tx: &Sender<GenResponse>,
    inflight: &Arc<AtomicUsize>,
    stats: &mut LatencyStats,
) {
    for req in batch {
        let resp = generate(wid, model, cache, req, stats);
        inflight.fetch_sub(1, Ordering::Relaxed);
        let _ = resp_tx.send(resp);
    }
}

/// Greedy generation for one request (batch-1 decode, the Table 5 setup).
fn generate(
    wid: usize,
    model: &mut CpuModel,
    cache: &mut KvCache,
    req: &GenRequest,
    stats: &mut LatencyStats,
) -> GenResponse {
    cache.reset();
    let max_seq = model.config.max_seq;
    let t0 = Instant::now();
    let mut logits: Vec<f32> = Vec::new();
    for &b in req.prompt.iter().take(max_seq.saturating_sub(1)) {
        logits = model.decode_step(cache, b).to_vec();
    }
    let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut tokens = Vec::with_capacity(req.max_new_tokens);
    let mut per_token_ms = Vec::with_capacity(req.max_new_tokens);
    for _ in 0..req.max_new_tokens {
        if cache.len >= max_seq {
            break;
        }
        let next = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as u8)
            .unwrap_or(0);
        let t = Instant::now();
        logits = model.decode_step(cache, next).to_vec();
        let ms = t.elapsed().as_secs_f64() * 1e3;
        per_token_ms.push(ms);
        stats.record_ms(ms);
        tokens.push(next);
    }
    GenResponse { id: req.id, tokens, per_token_ms, prefill_ms, worker: wid }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::tiny_checkpoint;

    fn server(n_workers: usize) -> Server {
        let cfg = ServerConfig { n_workers, max_batch: 2, linger: Duration::from_millis(1) };
        Server::start(cfg, |_| CpuModel::from_checkpoint(&tiny_checkpoint(7)))
    }

    #[test]
    fn serves_one_request() {
        let mut s = server(1);
        s.submit(GenRequest { id: 1, prompt: vec![1, 2, 3], max_new_tokens: 4 });
        let r = s.recv();
        assert_eq!(r.id, 1);
        assert_eq!(r.tokens.len(), 4);
        assert_eq!(r.per_token_ms.len(), 4);
        let stats = s.shutdown();
        assert_eq!(stats.count(), 4);
    }

    #[test]
    fn no_request_lost_across_workers() {
        let mut s = server(3);
        let n = 20;
        for i in 0..n {
            s.submit(GenRequest { id: i, prompt: vec![(i % 16) as u8], max_new_tokens: 2 });
        }
        let mut ids: Vec<u64> = s.collect(n as usize).into_iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n).collect::<Vec<_>>());
        s.shutdown();
    }

    #[test]
    fn routing_spreads_load() {
        let mut s = server(2);
        let n = 8;
        for i in 0..n {
            s.submit(GenRequest { id: i, prompt: vec![0], max_new_tokens: 1 });
        }
        let workers: std::collections::HashSet<usize> =
            s.collect(n as usize).into_iter().map(|r| r.worker).collect();
        assert!(workers.len() >= 2, "all requests went to one worker");
        s.shutdown();
    }

    #[test]
    fn generation_deterministic() {
        let mut s1 = server(1);
        s1.submit(GenRequest { id: 0, prompt: vec![5, 6], max_new_tokens: 6 });
        let r1 = s1.recv();
        s1.shutdown();
        let mut s2 = server(1);
        s2.submit(GenRequest { id: 0, prompt: vec![5, 6], max_new_tokens: 6 });
        let r2 = s2.recv();
        s2.shutdown();
        assert_eq!(r1.tokens, r2.tokens);
    }

    #[test]
    fn respects_max_seq() {
        let mut s = server(1);
        // prompt + generation longer than max_seq (16) must truncate safely
        s.submit(GenRequest { id: 9, prompt: vec![1; 30], max_new_tokens: 30 });
        let r = s.recv();
        assert!(r.tokens.len() < 16);
        s.shutdown();
    }

    #[test]
    fn parity_check_passes_on_reference_backend() {
        use crate::model::testkit::{tiny_corpus, tiny_manifest, TINY_SIZE};
        let (seq, batch) = (12usize, 2usize);
        let mut rt = crate::runtime::Runtime::new(tiny_manifest(seq, batch)).unwrap();
        let ckpt = tiny_checkpoint(11);
        let corpus = tiny_corpus(1024, 7);
        let rel = verify_parity(&mut rt, TINY_SIZE, &ckpt, &corpus, 4).unwrap();
        assert!(rel < 1e-3, "decode path vs reference backend: rel {rel}");
    }
}
