//! Generation server — the paper's "execution harness which allows us to
//! execute the resulting compressed models efficiently for generative
//! tasks", grown into a multi-user tier: a request router over worker
//! replicas, each worker running the continuous-batching [`Scheduler`]
//! (iteration-level batching over a paged [`KvPool`](crate::model::KvPool)
//! — see `coordinator::scheduler`), with per-request latency metrics.
//!
//! Each worker owns one [`CpuModel`] instance (dense = the FP16-baseline
//! analog, packed = the GPTQ-deployed model). Generation is greedy
//! decode; N in-flight sequences advance one token per scheduler
//! iteration against shared weight reads — the multi-user form of the
//! autoregressive, matvec-bound regime the paper targets (§Practical
//! Speedups). Each worker additionally shares prompt-prefix KV across
//! its requests through a radix prefix cache over its paged pool
//! (`coordinator::prefixcache`, `scheduler.prefix_cache` knob): repeated
//! system/few-shot prefixes are forked, not re-prefilled, and
//! `ServeMetrics` reports the hit rate and prefill tokens saved. Every linear in that step runs on the runtime-dispatched
//! SIMD kernels (`model::kernels`, `--isa` / `GPTQ_ISA`): the batched
//! sub-step decodes each packed word once per batch on the active ISA,
//! and batch-1 decode uses the register-tiled layout when the model was
//! loaded under a SIMD ISA (DESIGN.md §Kernels).

use crate::coordinator::metrics::ServeMetrics;
use crate::coordinator::scheduler::{Scheduler, SchedulerConfig};
use crate::data::CorpusFile;
use crate::eval::{perplexity, perplexity_artifact};
use crate::model::{Checkpoint, CpuModel};
use crate::runtime::Runtime;
use crate::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

/// A generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<u8>,
    /// per-token decode latencies, ms: each sample is the batched step
    /// that consumed the token (prefill excluded — the paper's per-token
    /// generation metric)
    pub per_token_ms: Vec<f64>,
    pub prefill_ms: f64,
    /// submit → admitted to a scheduler slot, ms
    pub queue_wait_ms: f64,
    /// submit → first generated token available, ms (0 when the request
    /// emitted no token: `max_new_tokens` 0 or EOS as the first pick)
    pub ttft_ms: f64,
    /// prompt tokens whose KV was forked from the worker's prefix cache
    /// at admission instead of being prefilled (0 = fully cold prompt,
    /// or `scheduler.prefix_cache` disabled)
    pub cached_prefix_len: usize,
    pub worker: usize,
}

/// Server shape: worker count plus each worker's scheduler knobs
/// (`scheduler.max_batch`, `scheduler.pool_pages`, … — see
/// [`SchedulerConfig`]).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub n_workers: usize,
    /// per-worker continuous-batching knobs (slot budget, KV pool, …)
    pub scheduler: SchedulerConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { n_workers: 1, scheduler: SchedulerConfig::default() }
    }
}

enum Job {
    Gen(GenRequest),
    Stop,
}

/// Multi-worker generation server with least-loaded routing.
pub struct Server {
    senders: Vec<Sender<Job>>,
    resp_rx: Receiver<GenResponse>,
    inflight: Vec<Arc<AtomicUsize>>,
    handles: Vec<JoinHandle<ServeMetrics>>,
    submitted: u64,
}

impl Server {
    /// `make_model` builds one model replica per worker (each worker owns
    /// its weights — the "model parallel replicas" shape of a router tier).
    pub fn start<F>(cfg: ServerConfig, make_model: F) -> Self
    where
        F: Fn(usize) -> CpuModel,
    {
        let (resp_tx, resp_rx) = channel::<GenResponse>();
        let mut senders = Vec::new();
        let mut inflight = Vec::new();
        let mut handles = Vec::new();
        for wid in 0..cfg.n_workers {
            let (tx, rx) = channel::<Job>();
            let model = make_model(wid);
            let resp_tx = resp_tx.clone();
            let count = Arc::new(AtomicUsize::new(0));
            let count_w = count.clone();
            let scfg = cfg.scheduler.clone();
            handles.push(std::thread::spawn(move || {
                worker_loop(wid, model, rx, resp_tx, count_w, scfg)
            }));
            senders.push(tx);
            inflight.push(count);
        }
        Self { senders, resp_rx, inflight, handles, submitted: 0 }
    }

    /// Route a request to the least-loaded worker. Returns the worker id.
    pub fn submit(&mut self, req: GenRequest) -> usize {
        let wid = self
            .inflight
            .iter()
            .enumerate()
            .min_by_key(|(_, c)| c.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .unwrap();
        self.inflight[wid].fetch_add(1, Ordering::Relaxed);
        self.submitted += 1;
        self.senders[wid].send(Job::Gen(req)).expect("worker died");
        wid
    }

    /// Block for the next completed response.
    pub fn recv(&self) -> GenResponse {
        self.resp_rx.recv().expect("all workers died")
    }

    /// Drain exactly `n` responses.
    pub fn collect(&self, n: usize) -> Vec<GenResponse> {
        (0..n).map(|_| self.recv()).collect()
    }

    /// Stop workers and return their merged serving metrics.
    pub fn shutdown(self) -> ServeMetrics {
        for tx in &self.senders {
            let _ = tx.send(Job::Stop);
        }
        let mut metrics = ServeMetrics::new();
        for h in self.handles {
            if let Ok(m) = h.join() {
                metrics.merge(&m);
            }
        }
        metrics
    }
}

/// Pre-flight deployment check: evaluate a few segments through BOTH the
/// serving decode path (`CpuModel`, KV-cached) and the runtime's execution
/// backend (`lm_fwd_<size>` artifact contract), and return the relative
/// perplexity difference. A healthy deployment is ≈0 on the reference
/// backend and <2% against the lowered XLA graph; anything larger means
/// the checkpoint and the artifact tree disagree (stale `make artifacts`,
/// wrong size flag, corrupted weights).
///
/// `segments` should be a multiple of the manifest's `eval_batch`.
pub fn verify_parity(
    rt: &mut Runtime,
    size: &str,
    ckpt: &Checkpoint,
    corpus: &CorpusFile,
    segments: usize,
) -> Result<f64> {
    let seq = rt.manifest.seq_len;
    let batch = rt.manifest.eval_batch;
    let batches = (segments / batch).max(1);
    let mut cpu = CpuModel::from_checkpoint(ckpt);
    let ppl_cpu = perplexity(&mut cpu, corpus, seq, batches * batch);
    let ppl_art = perplexity_artifact(rt, size, ckpt, corpus, batches)?;
    Ok((ppl_cpu - ppl_art).abs() / ppl_art.max(1e-12))
}

/// Worker: admit jobs into the continuous-batching scheduler (blocking
/// only when idle), run one scheduler iteration per loop, stream
/// completions back. On `Stop`, everything already submitted drains to
/// completion before the worker exits (the channel is FIFO, so every
/// `Gen` sent before the `Stop` has been admitted by then).
fn worker_loop(
    wid: usize,
    model: CpuModel,
    rx: Receiver<Job>,
    resp_tx: Sender<GenResponse>,
    inflight: Arc<AtomicUsize>,
    scfg: SchedulerConfig,
) -> ServeMetrics {
    let mut sched = Scheduler::new(wid, model, scfg);
    let mut stopping = false;
    loop {
        // block for work only when there is nothing to advance
        if !stopping && sched.is_idle() {
            match rx.recv() {
                Ok(Job::Gen(r)) => sched.submit(r),
                Ok(Job::Stop) | Err(_) => stopping = true,
            }
        }
        // then drain whatever else is already queued, without blocking —
        // new arrivals join the batch at the next iteration's admission
        if !stopping {
            loop {
                match rx.try_recv() {
                    Ok(Job::Gen(r)) => sched.submit(r),
                    Ok(Job::Stop) => {
                        stopping = true;
                        break;
                    }
                    Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                }
            }
        }
        if sched.is_idle() {
            if stopping {
                break;
            }
            continue;
        }
        for resp in sched.step() {
            inflight.fetch_sub(1, Ordering::Relaxed);
            let _ = resp_tx.send(resp);
        }
    }
    sched.into_metrics()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::tiny_checkpoint;

    fn server(n_workers: usize) -> Server {
        let cfg = ServerConfig {
            n_workers,
            scheduler: SchedulerConfig { max_batch: 2, ..Default::default() },
        };
        Server::start(cfg, |_| CpuModel::from_checkpoint(&tiny_checkpoint(7)))
    }

    #[test]
    fn serves_one_request() {
        let mut s = server(1);
        s.submit(GenRequest { id: 1, prompt: vec![1, 2, 3], max_new_tokens: 4 });
        let r = s.recv();
        assert_eq!(r.id, 1);
        assert_eq!(r.tokens.len(), 4);
        assert_eq!(r.per_token_ms.len(), 4);
        assert!(r.ttft_ms >= 0.0 && r.queue_wait_ms >= 0.0);
        let m = s.shutdown();
        assert_eq!(m.per_token.count(), 4);
        assert_eq!(m.requests(), 1);
        assert_eq!(m.ttft.count(), 1);
    }

    #[test]
    fn no_request_lost_across_workers() {
        let mut s = server(3);
        let n = 20;
        for i in 0..n {
            s.submit(GenRequest { id: i, prompt: vec![(i % 16) as u8], max_new_tokens: 2 });
        }
        let mut ids: Vec<u64> = s.collect(n as usize).into_iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n).collect::<Vec<_>>());
        s.shutdown();
    }

    #[test]
    fn routing_spreads_load() {
        let mut s = server(2);
        let n = 8;
        for i in 0..n {
            s.submit(GenRequest { id: i, prompt: vec![0], max_new_tokens: 1 });
        }
        let workers: std::collections::HashSet<usize> =
            s.collect(n as usize).into_iter().map(|r| r.worker).collect();
        assert!(workers.len() >= 2, "all requests went to one worker");
        s.shutdown();
    }

    #[test]
    fn generation_deterministic() {
        let mut s1 = server(1);
        s1.submit(GenRequest { id: 0, prompt: vec![5, 6], max_new_tokens: 6 });
        let r1 = s1.recv();
        s1.shutdown();
        let mut s2 = server(1);
        s2.submit(GenRequest { id: 0, prompt: vec![5, 6], max_new_tokens: 6 });
        let r2 = s2.recv();
        s2.shutdown();
        assert_eq!(r1.tokens, r2.tokens);
    }

    #[test]
    fn respects_max_seq() {
        let mut s = server(1);
        // prompt + generation longer than max_seq (16) must truncate safely
        s.submit(GenRequest { id: 9, prompt: vec![1; 30], max_new_tokens: 30 });
        let r = s.recv();
        assert!(r.tokens.len() < 16);
        s.shutdown();
    }

    #[test]
    fn pool_limited_server_completes_all_requests() {
        // a pool far smaller than the offered load: backpressure (preempt
        // + re-queue) must still complete everything
        let cfg = ServerConfig {
            n_workers: 1,
            scheduler: SchedulerConfig {
                max_batch: 4,
                pool_pages: 4,
                page_size: 2,
                ..Default::default()
            },
        };
        let mut s =
            Server::start(cfg, |_| CpuModel::from_checkpoint(&tiny_checkpoint(7)));
        let n = 10;
        for i in 0..n {
            s.submit(GenRequest { id: i, prompt: vec![2, 7, 1], max_new_tokens: 3 });
        }
        let rs = s.collect(n as usize);
        assert!(rs.iter().all(|r| r.tokens.len() == 3));
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n).collect::<Vec<_>>());
        s.shutdown();
    }

    #[test]
    fn server_reports_prefix_cache_savings() {
        let cfg = ServerConfig {
            n_workers: 1,
            scheduler: SchedulerConfig { max_batch: 2, page_size: 2, ..Default::default() },
        };
        let mut s =
            Server::start(cfg, |_| CpuModel::from_checkpoint(&tiny_checkpoint(7)));
        // sequential same-prompt requests: the second must fork the
        // first's pages (prompt 6 tokens = 3 full pages, capped to 5)
        s.submit(GenRequest { id: 0, prompt: vec![4, 5, 6, 7, 8, 9], max_new_tokens: 2 });
        let r0 = s.recv();
        s.submit(GenRequest { id: 1, prompt: vec![4, 5, 6, 7, 8, 9], max_new_tokens: 2 });
        let r1 = s.recv();
        assert_eq!(r0.cached_prefix_len, 0);
        assert_eq!(r1.cached_prefix_len, 5);
        assert_eq!(r0.tokens, r1.tokens, "prefix sharing changed greedy decode");
        let m = s.shutdown();
        assert_eq!(m.prefix_lookups, 2);
        assert_eq!(m.prefix_hits, 1);
        assert_eq!(m.prefill_tokens_saved, 5);
        assert!((m.cache_hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn parity_check_passes_on_reference_backend() {
        use crate::model::testkit::{tiny_corpus, tiny_manifest, TINY_SIZE};
        let (seq, batch) = (12usize, 2usize);
        let mut rt = crate::runtime::Runtime::new(tiny_manifest(seq, batch)).unwrap();
        let ckpt = tiny_checkpoint(11);
        let corpus = tiny_corpus(1024, 7);
        let rel = verify_parity(&mut rt, TINY_SIZE, &ckpt, &corpus, 4).unwrap();
        assert!(rel < 1e-3, "decode path vs reference backend: rel {rel}");
    }
}
