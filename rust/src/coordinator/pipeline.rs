//! The quantization pipeline (paper §4 Setup):
//!
//! > "we always load one Transformer block ... at a time into GPU memory
//! > and then accumulate the layer-Hessians and perform quantization.
//! > Finally, the current block inputs are sent through the fully
//! > quantized block again to produce the new inputs for the quantization
//! > of the next block."
//!
//! Stages per block (all shapes come from the manifest; the forward passes
//! run through the [`Runtime`]'s execution backend — the pure-Rust
//! reference engine by default, the AOT XLA artifacts under
//! `--features pjrt` — and the solver either in pure Rust or through the
//! `gptq_layer_*` artifact contract; all paths produce matching results,
//! see the integration tests):
//!
//!   x ── block_capture ──► per-linear inputs ──► H += 2XᵀX per linear
//!     └─ after quantizing all 4 linears: re-run the block with Ŵ to get
//!        the next block's x.
//!
//! The embedding / head stay fp, exactly as in the paper.

use crate::data::{batch_segments, sample_calibration, CorpusFile};
use crate::model::checkpoint::{LayerStats, QuantizedCheckpoint};
use crate::model::config::QUANT_LINEARS;
use crate::model::{Checkpoint, ModelConfig};
use crate::quant::{
    self, gptq_quantize, rtn_quantize, GptqConfig, PackedMatrix, QuantResult, Sparse24Matrix,
    Sparsity,
};
use crate::runtime::{Runtime, Value, BLOCK_TENSORS};
use crate::util::par::{self, Pool};
use crate::Result;
use std::collections::BTreeMap;
use std::time::Instant;

/// Which solver quantizes each layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantEngine {
    /// Pure-Rust GPTQ (f64 Cholesky) — the default.
    GptqRust,
    /// The `gptq_layer_<shape>_b<bits>` artifact contract, executed through
    /// the runtime's backend (the L2 graph under PJRT, the reference solver
    /// otherwise) — available where the backend supports the artifact.
    GptqArtifact,
    /// Round-to-nearest baseline.
    Rtn,
    /// Full greedy OBQ (slow; Table 1/7 baseline).
    Obq,
}

#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub bits: u32,
    pub groupsize: usize,
    pub engine: QuantEngine,
    pub n_calib_segments: usize,
    pub seed: u64,
    pub gptq: GptqConfig,
    /// propagate quantized outputs to the next block (paper default: true)
    pub propagate_quantized: bool,
    /// joint sparsify+quantize mode (SparseGPT-style; DESIGN.md §Sparsity)
    pub sparsity: Sparsity,
}

impl PipelineConfig {
    pub fn new(bits: u32, engine: QuantEngine) -> Self {
        Self {
            bits,
            groupsize: 0,
            engine,
            n_calib_segments: 64,
            seed: 1234,
            gptq: GptqConfig::new(bits),
            propagate_quantized: true,
            sparsity: Sparsity::None,
        }
    }

    pub fn with_groupsize(mut self, g: usize) -> Self {
        self.groupsize = g;
        self.gptq.groupsize = g;
        self
    }

    pub fn with_sparsity(mut self, s: Sparsity) -> Self {
        self.sparsity = s;
        self.gptq.sparsity = s;
        self
    }
}

/// Outcome of a pipeline run.
pub struct PipelineReport {
    pub checkpoint: QuantizedCheckpoint,
    pub stats: Vec<LayerStats>,
    pub total_s: f64,
    pub mean_layer_error: f64,
}

/// Engine dispatch for the solvers that are pure functions of
/// `(w, H, cfg)` — everything except the artifact contract, which needs
/// the runtime. Shared by the serial and the fan-out paths.
fn solve_pure(
    cfg: &PipelineConfig,
    w: &[f32],
    drow: usize,
    dcol: usize,
    h: &[f64],
) -> std::result::Result<QuantResult, String> {
    match cfg.engine {
        QuantEngine::Rtn => Ok(rtn_quantize(w, drow, dcol, cfg.bits, cfg.groupsize)),
        QuantEngine::GptqRust => gptq_quantize(w, drow, dcol, h, &cfg.gptq),
        QuantEngine::Obq => {
            crate::quant::obq_quantize(w, drow, dcol, h, cfg.bits, cfg.gptq.percdamp)
        }
        QuantEngine::GptqArtifact => Err("artifact engine is not a pure solver".into()),
    }
}

/// The block-streaming quantization pipeline.
pub struct QuantPipeline<'rt> {
    rt: &'rt mut Runtime,
    size: String,
    cfg: PipelineConfig,
}

impl<'rt> QuantPipeline<'rt> {
    pub fn new(rt: &'rt mut Runtime, size: &str, cfg: PipelineConfig) -> Self {
        Self { rt, size: size.to_string(), cfg }
    }

    /// Run the full pipeline over `ckpt` (which is consumed as the working
    /// copy — quantized weights are written back for propagation).
    pub fn run(&mut self, ckpt: &mut Checkpoint, calib: &CorpusFile) -> Result<PipelineReport> {
        let t0 = Instant::now();
        anyhow::ensure!(
            self.cfg.sparsity == Sparsity::None || self.cfg.engine == QuantEngine::GptqRust,
            "--sparsity requires the rust GPTQ engine (joint mask selection runs inside the \
             Cholesky solver)"
        );
        anyhow::ensure!(
            self.cfg.sparsity == self.cfg.gptq.sparsity,
            "PipelineConfig.sparsity and gptq.sparsity diverged; use with_sparsity()"
        );
        let config = ckpt.config.clone();
        let seq = self.rt.manifest.seq_len;
        let batch = self.rt.manifest.eval_batch;

        // 1. calibration batches (the paper's 128 random segments)
        let segments = sample_calibration(calib, self.cfg.n_calib_segments, seq, self.cfg.seed);
        let token_batches = batch_segments(&segments, batch);
        anyhow::ensure!(!token_batches.is_empty(), "not enough calibration segments");

        // 2. embed: token batches -> activations (embed/pos marshalled
        // once; only the tokens slot changes per batch)
        let mut xs: Vec<Vec<f32>> = Vec::with_capacity(token_batches.len());
        let mut inputs = vec![
            Value::i32(vec![0; batch * seq], &[batch, seq])?,
            Value::f32(ckpt.get("embed").data.clone(), &ckpt.get("embed").shape)?,
            Value::f32(ckpt.get("pos").data.clone(), &ckpt.get("pos").shape)?,
        ];
        let embed_name = format!("embed_{}", self.size);
        for tokens in &token_batches {
            inputs[0] = Value::i32(tokens.clone(), &[batch, seq])?;
            let out = self.rt.execute(&embed_name, &inputs)?;
            anyhow::ensure!(!out.is_empty(), "embed returned no outputs");
            xs.push(out.into_iter().next().unwrap().into_f32()?);
        }

        // 3. per block: capture -> hessians -> quantize -> propagate
        let mut packed: BTreeMap<String, PackedMatrix> = BTreeMap::new();
        let mut sparse: BTreeMap<String, Sparse24Matrix> = BTreeMap::new();
        let mut stats: Vec<LayerStats> = Vec::new();
        for layer in 0..config.n_layers {
            let (hessians, captures) = self.capture_block(ckpt, layer, &xs, &config)?;
            // solve the block's four linears — independently, so the pure
            // engines run them in parallel (layer-level parallelism).
            // `jobs` holds the ORIGINAL weights, which the no-propagation
            // ablation also reuses below.
            let jobs: Vec<(Vec<f32>, usize, usize)> = QUANT_LINEARS
                .iter()
                .map(|lin| {
                    let t = ckpt.block_tensor(layer, lin);
                    let (drow, dcol) = t.dims2();
                    (t.data.clone(), drow, dcol)
                })
                .collect();
            let solved = self.solve_linears(&jobs, &hessians)?;
            for (li, ((w, drow, dcol), (result, quant_ms))) in
                jobs.iter().zip(solved.into_iter()).enumerate()
            {
                let lin = QUANT_LINEARS[li];
                let sq_error =
                    quant::layer_sq_error(w, &result.wq, &captures[li], *drow, *dcol);
                stats.push(LayerStats { layer, name: lin.to_string(), sq_error, quant_ms });
                let key = format!("blocks.{layer}.{lin}");
                // 2:4 masks pack into the index-skipping sparse layout;
                // unstructured masks stay on the dense pack (zeros encode
                // as the zero-point code — no layout change needed)
                if self.cfg.sparsity == Sparsity::TwoOfFour {
                    sparse.insert(
                        key,
                        Sparse24Matrix::from_result(&result).map_err(|e| anyhow::anyhow!(e))?,
                    );
                } else {
                    packed.insert(key, PackedMatrix::from_result(&result));
                }
                // write back Ŵ so the propagation pass (and later layers'
                // Hessians within this block, via re-capture) see it
                ckpt.set_block_weight(layer, lin, result.wq);
            }

            // 4. propagate: re-run the block — with the quantized weights
            // (paper default) or, for the ablation, with the originals
            // (next block calibrates on full-precision activations).
            if !self.cfg.propagate_quantized {
                let quantized: Vec<Vec<f32>> = QUANT_LINEARS
                    .iter()
                    .map(|lin| ckpt.block_tensor(layer, lin).data.clone())
                    .collect();
                for (lin, (orig, _, _)) in QUANT_LINEARS.iter().zip(&jobs) {
                    ckpt.set_block_weight(layer, lin, orig.clone());
                }
                for x in xs.iter_mut() {
                    *x = self.block_forward(ckpt, layer, x, &config, batch, seq)?.0;
                }
                for (lin, q) in QUANT_LINEARS.iter().zip(quantized) {
                    ckpt.set_block_weight(layer, lin, q);
                }
            } else {
                for x in xs.iter_mut() {
                    *x = self.block_forward(ckpt, layer, x, &config, batch, seq)?.0;
                }
            }
        }

        let mean_layer_error =
            stats.iter().map(|s| s.sq_error).sum::<f64>() / stats.len().max(1) as f64;
        // rebuild a pristine fp checkpoint view for the non-quantized
        // tensors (ckpt weights were overwritten with Ŵ — that is fine:
        // packed codes are the source of truth for the linears)
        let qc = QuantizedCheckpoint::from_parts_sparse(
            config,
            self.cfg.bits,
            self.cfg.groupsize,
            packed,
            sparse,
            ckpt,
            stats.clone(),
        );
        Ok(PipelineReport {
            checkpoint: qc,
            stats,
            total_s: t0.elapsed().as_secs_f64(),
            mean_layer_error,
        })
    }

    /// Run block_capture over every calibration batch; accumulate the four
    /// per-linear Hessians and keep one batch of captures for error
    /// reporting. Returns (hessians, sample captures).
    #[allow(clippy::type_complexity)]
    fn capture_block(
        &mut self,
        ckpt: &Checkpoint,
        layer: usize,
        xs: &[Vec<f32>],
        config: &ModelConfig,
    ) -> Result<([Vec<f64>; 4], [Vec<f32>; 4])> {
        let batch = self.rt.manifest.eval_batch;
        let seq = self.rt.manifest.seq_len;
        let n = batch * seq;
        let dims: [usize; 4] = [config.d_model, config.d_model, config.d_model, config.d_ff];
        let mut hessians: [Vec<f64>; 4] =
            std::array::from_fn(|i| vec![0.0f64; dims[i] * dims[i]]);
        let mut sample: [Vec<f32>; 4] = std::array::from_fn(|_| Vec::new());

        for (bi, x) in xs.iter().enumerate() {
            let (_, caps) = self.block_forward(ckpt, layer, x, config, batch, seq)?;
            for (li, cap) in caps.iter().enumerate() {
                quant::accumulate_hessian(&mut hessians[li], cap, n, dims[li]);
                if bi == 0 {
                    sample[li] = cap.clone();
                }
            }
        }
        Ok((hessians, sample))
    }

    /// One block forward through the `block_capture_<size>` artifact.
    /// Returns (y, [four capture tensors]).
    fn block_forward(
        &mut self,
        ckpt: &Checkpoint,
        layer: usize,
        x: &[f32],
        config: &ModelConfig,
        batch: usize,
        seq: usize,
    ) -> Result<(Vec<f32>, [Vec<f32>; 4])> {
        let mut inputs = vec![Value::f32(x.to_vec(), &[batch, seq, config.d_model])?];
        for name in BLOCK_TENSORS {
            let t = ckpt.block_tensor(layer, name);
            inputs.push(Value::f32(t.data.clone(), &t.shape)?);
        }
        let out = self.rt.execute(&format!("block_capture_{}", self.size), &inputs)?;
        anyhow::ensure!(out.len() == 5, "block_capture returned {} outputs", out.len());
        let mut it = out.into_iter();
        let y = it.next().unwrap().into_f32()?;
        let caps = [
            it.next().unwrap().into_f32()?,
            it.next().unwrap().into_f32()?,
            it.next().unwrap().into_f32()?,
            it.next().unwrap().into_f32()?,
        ];
        Ok((y, caps))
    }

    /// Solve a block's linears, returning `(result, quant_ms)` per linear
    /// in input order. The pure engines (rust / rtn / obq) fan the four
    /// solves out across the global pool — each solve is a pure function
    /// of `(w, H, cfg)`, so results are position-stable and bit-identical
    /// to the serial loop. The artifact engine drives `&mut Runtime` and
    /// stays serial.
    fn solve_linears(
        &mut self,
        jobs: &[(Vec<f32>, usize, usize)],
        hessians: &[Vec<f64>; 4],
    ) -> Result<Vec<(QuantResult, f64)>> {
        let pool = Pool::global();
        let pure = !matches!(self.cfg.engine, QuantEngine::GptqArtifact);
        if pure && pool.nthreads() > 1 && jobs.len() > 1 {
            let cfg = self.cfg.clone();
            let mut slots: Vec<Option<std::result::Result<(QuantResult, f64), String>>> =
                vec![None; jobs.len()];
            {
                let parts = par::SliceParts::new(&mut slots);
                pool.run(jobs.len(), |li| {
                    let (w, drow, dcol) = &jobs[li];
                    let t = Instant::now();
                    let r = solve_pure(&cfg, w, *drow, *dcol, &hessians[li])
                        .map(|q| (q, t.elapsed().as_secs_f64() * 1e3));
                    // SAFETY: each job owns exactly slot li
                    unsafe { parts.range(li..li + 1)[0] = Some(r) };
                });
            }
            slots
                .into_iter()
                .map(|s| s.expect("solver job did not run").map_err(|e| anyhow::anyhow!(e)))
                .collect()
        } else {
            let mut out = Vec::with_capacity(jobs.len());
            for (li, (w, drow, dcol)) in jobs.iter().enumerate() {
                let t = Instant::now();
                let r = self.quantize_layer(w, *drow, *dcol, &hessians[li])?;
                out.push((r, t.elapsed().as_secs_f64() * 1e3));
            }
            Ok(out)
        }
    }

    /// Solve one layer with the configured engine.
    fn quantize_layer(
        &mut self,
        w: &[f32],
        drow: usize,
        dcol: usize,
        h: &[f64],
    ) -> Result<QuantResult> {
        match self.cfg.engine {
            // one dispatch table for the pure engines — shared with the
            // parallel fan-out so the two paths can never drift
            QuantEngine::Rtn | QuantEngine::GptqRust | QuantEngine::Obq => {
                solve_pure(&self.cfg, w, drow, dcol, h).map_err(|e| anyhow::anyhow!(e))
            }
            QuantEngine::GptqArtifact => {
                // the gptq_layer contract takes only (W, H): per-row grids
                anyhow::ensure!(
                    self.cfg.groupsize == 0,
                    "the artifact engine quantizes per-row (the gptq_layer contract carries no \
                     group size); use --engine rust for grouped grids"
                );
                let name = format!("gptq_layer_{drow}x{dcol}_b{}", self.cfg.bits);
                anyhow::ensure!(
                    self.rt.supports(&name),
                    "backend {} cannot execute {name}; use the rust engine or re-run aot.py",
                    self.rt.backend_name()
                );
                let hf: Vec<f32> = h.iter().map(|&v| v as f32).collect();
                let inputs =
                    vec![Value::f32(w.to_vec(), &[drow, dcol])?, Value::f32(hf, &[dcol, dcol])?];
                let out = self.rt.execute(&name, &inputs)?;
                anyhow::ensure!(out.len() == 4, "gptq_layer returned {} outputs", out.len());
                let mut it = out.into_iter();
                let codes_f = it.next().unwrap().into_f32()?;
                let scales = it.next().unwrap().into_f32()?;
                let zeros = it.next().unwrap().into_f32()?;
                let wq = it.next().unwrap().into_f32()?;
                let ngroups = scales.len() / drow;
                Ok(QuantResult {
                    codes: codes_f.iter().map(|&c| c as u8).collect(),
                    scales,
                    zeros,
                    wq,
                    drow,
                    dcol,
                    ngroups,
                    bits: self.cfg.bits,
                })
            }
        }
    }
}
