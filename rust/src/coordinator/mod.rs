//! The L3 coordinator: the paper's quantization pipeline (§4 Setup) and
//! its "execution harness" for generative inference (§Practical Speedups).
//!
//! * [`pipeline`] — block-by-block quantization: stream calibration text
//!   through the model (XLA artifacts), accumulate per-linear Hessians,
//!   solve each layer with GPTQ (Rust solver or the AOT `gptq_layer_*`
//!   graph), and propagate the **quantized** block's outputs to the next
//!   block's calibration inputs — the paper's "actual layer inputs in the
//!   already partially quantized" trick.
//! * [`serve`] — token-by-token generation server: request router,
//!   dynamic batcher, KV-cache pool, per-token latency metrics (the
//!   Table 5 measurement harness), plus the [`serve::verify_parity`]
//!   pre-flight check that compares the serving decode path against the
//!   runtime's execution backend before workers start.
//! * [`metrics`] — latency/throughput accounting.

pub mod metrics;
pub mod pipeline;
pub mod serve;

pub use metrics::LatencyStats;
pub use pipeline::{QuantEngine, QuantPipeline, PipelineConfig, PipelineReport};
pub use serve::{verify_parity, GenRequest, GenResponse, Server, ServerConfig};
