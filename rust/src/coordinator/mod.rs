//! The L3 coordinator: the paper's quantization pipeline (§4 Setup) and
//! its "execution harness" for generative inference (§Practical Speedups).
//!
//! * [`pipeline`] — block-by-block quantization: stream calibration text
//!   through the model (XLA artifacts), accumulate per-linear Hessians,
//!   solve each layer with GPTQ (Rust solver or the AOT `gptq_layer_*`
//!   graph), and propagate the **quantized** block's outputs to the next
//!   block's calibration inputs — the paper's "actual layer inputs in the
//!   already partially quantized" trick.
//! * [`serve`] — the generation server: request router over worker
//!   replicas with fault isolation (a panicking worker is reaped and its
//!   requests replayed on survivors with a bounded retry budget),
//!   per-request/per-token latency metrics (the Table 5 measurement
//!   harness), plus the [`serve::verify_parity`] pre-flight check that
//!   compares the serving decode path against the runtime's execution
//!   backend before workers start.
//! * [`scheduler`] — the continuous-batching loop each worker runs:
//!   iteration-level admission/eviction over a paged KV pool, one
//!   batched decode step per iteration for all in-flight sequences,
//!   preempt + FIFO re-queue backpressure when the pool is exhausted,
//!   SLO enforcement (priority classes, per-class queue bounds, TTFT and
//!   total deadlines, cooperative cancellation — DESIGN.md §Robustness).
//! * [`prefixcache`] — the radix prompt cache admission consults: a
//!   page-granular token-prefix trie over the KV pool, so requests
//!   sharing a system/few-shot prefix fork already-computed pages
//!   instead of re-running prefill (DESIGN.md §Prefix cache).
//! * [`sampling`] — per-request seeded sampling (counter-based RNG so
//!   preempt-and-rerun replays bitwise; greedy stays frozen through
//!   `argmax`) and the self-speculative decoding config: the same
//!   checkpoint repacked at 2–3 bits drafts k tokens the target model
//!   verifies in one batched pass (DESIGN.md §Sampling & Speculative
//!   decoding).
//! * [`metrics`] — latency/throughput accounting (per-token, TTFT,
//!   queue wait, prefix-cache hit rate and prefill tokens saved,
//!   speculative proposal/accept counters).

pub mod metrics;
pub mod pipeline;
pub mod prefixcache;
pub mod sampling;
pub mod scheduler;
pub mod serve;

pub use metrics::{LatencyStats, ServeMetrics};
pub use sampling::{SamplingParams, SpecConfig};
pub use pipeline::{QuantEngine, QuantPipeline, PipelineConfig, PipelineReport};
pub use prefixcache::PrefixCache;
pub use scheduler::{Scheduler, SchedulerConfig};
pub use serve::{
    verify_parity, Class, GenOutcome, GenRequest, GenResponse, ServeError, Server, ServerConfig,
};
