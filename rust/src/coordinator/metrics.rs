//! Latency/throughput metrics for the serving harness — the measurement
//! side of the Table 5 analog ("average per-token latency, batch size 1,
//! generating sequences of length 128"), extended with the multi-user
//! serving dimensions (queue wait, time-to-first-token, per-class TTFT,
//! terminal-outcome counts) the continuous-batching scheduler reports
//! per request.

use crate::coordinator::serve::{Class, GenOutcome};

/// Online latency statistics over recorded samples (milliseconds).
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples: Vec<f64>,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_ms(&mut self, ms: f64) {
        self.samples.push(ms);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Linear interpolation between ranks of an already-sorted sample
    /// view (numpy's default convention): p50 of [1, 2] is 1.5 — the old
    /// nearest-rank rounding returned 2.0.
    fn interp(sorted: &[f64], p: f64) -> f64 {
        let last = sorted.len() - 1;
        let rank = (p / 100.0).clamp(0.0, 1.0) * last as f64;
        let lo = rank.floor() as usize;
        let hi = (lo + 1).min(last);
        let frac = rank - lo as f64;
        sorted[lo] + (sorted[hi] - sorted[lo]) * frac
    }

    /// p-th percentile (0–100), interpolated (see [`LatencyStats::interp`]).
    /// One-off convenience; a caller that needs several should use
    /// [`LatencyStats::percentiles`], which sorts once.
    pub fn percentile(&self, p: f64) -> f64 {
        self.percentiles(&[p])[0]
    }

    /// Batch percentile query: clone + sort the samples ONCE, then
    /// interpolate each requested p — the summary paths ask for four
    /// percentiles per dimension, and the per-call sort was O(n log n)
    /// × 4 at every shutdown/merge report. Values are identical to
    /// calling [`LatencyStats::percentile`] per entry (empty stats →
    /// all zeros).
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        if self.samples.is_empty() {
            return vec![0.0; ps.len()];
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ps.iter().map(|&p| Self::interp(&sorted, p)).collect()
    }

    /// Smallest sample, 0.0 on empty stats — matching `mean`/`max`/
    /// `percentile`, so an idle worker's merged summary never prints
    /// `inf` (the old fold-from-+∞ identity leaked through).
    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample, 0.0 on empty stats (explicit guard — the old
    /// fold from 0.0 silently clamped negative samples and made an
    /// all-negative population indistinguishable from empty).
    pub fn max(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples.extend_from_slice(&other.samples);
    }

    pub fn summary(&self) -> String {
        let ps = self.percentiles(&[50.0, 95.0]);
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms max={:.3}ms",
            self.count(),
            self.mean(),
            ps[0],
            ps[1],
            self.max()
        )
    }
}

/// Per-worker serving metrics, one [`LatencyStats`] per dimension plus
/// the prefix-cache counters. The scheduler records each completed
/// request's samples; workers' metrics merge at shutdown
/// (`Server::shutdown`).
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    /// one sample per generated token: the batched decode step that
    /// consumed it (the paper's per-token generation metric)
    pub per_token: LatencyStats,
    /// one sample per request: wall-clock spent consuming its prompt
    pub prefill: LatencyStats,
    /// one sample per request: submit → first generated token available.
    /// Requests that never emit a token (zero-token completions, sheds)
    /// contribute NO sample — the old 0.0 sentinel dragged p50 down and
    /// polluted the perfgate TTFT keys
    pub ttft: LatencyStats,
    /// TTFT restricted to `Interactive` requests (the per-class SLO view
    /// the overload bench gates on)
    pub ttft_interactive: LatencyStats,
    /// TTFT restricted to `Batch` requests
    pub ttft_batch: LatencyStats,
    /// one sample per request: submit → admitted to a scheduler slot
    pub queue_wait: LatencyStats,
    /// terminal outcomes (exactly one per submitted request — see
    /// `GenOutcome`); `completed` includes zero-token completions
    pub completed: usize,
    pub rejected: usize,
    pub timed_out: usize,
    pub cancelled: usize,
    pub failed: usize,
    /// `Completed` requests that emitted no token (`max_new_tokens` 0,
    /// EOS as the first pick) — counted here instead of as a 0.0 TTFT
    /// sample
    pub no_token_requests: usize,
    /// admissions that consulted the prefix cache (cache enabled and a
    /// shareable prompt, i.e. ≥ 2 tokens — the cap at plen − 1 makes a
    /// 1-token prompt structurally unshareable; re-admissions after
    /// preemption consult again)
    pub prefix_lookups: usize,
    /// consultations that matched at least one cached page
    pub prefix_hits: usize,
    /// prompt tokens whose prefill was skipped by forking cached KV
    /// pages — the cross-request work the prefix cache saved
    pub prefill_tokens_saved: usize,
    /// speculative rounds run (draft propose + one batched target
    /// verify); 0 whenever `--spec-decode` / `GPTQ_SPEC` is off
    pub spec_rounds: usize,
    /// draft tokens proposed across all rounds (≤ k per round)
    pub spec_proposed: usize,
    /// proposals the target accepted — `spec_accepted / spec_proposed`
    /// is the acceptance rate the speedup model hinges on
    pub spec_accepted: usize,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Requests that reached a slot (every admitted request records
    /// exactly one queue-wait sample). Requests resolved without
    /// admission — validation rejects, queue-bound sheds, deadline sheds
    /// — appear in [`ServeMetrics::terminals`] but not here.
    pub fn requests(&self) -> usize {
        self.queue_wait.count()
    }

    /// Count one terminal outcome (called exactly once per request).
    pub fn record_outcome(&mut self, outcome: GenOutcome) {
        match outcome {
            GenOutcome::Completed => self.completed += 1,
            GenOutcome::Rejected => self.rejected += 1,
            GenOutcome::TimedOut => self.timed_out += 1,
            GenOutcome::Cancelled => self.cancelled += 1,
            GenOutcome::Failed => self.failed += 1,
        }
    }

    /// Total terminal responses issued — with exactly-one-terminal
    /// semantics, this equals the number of submitted requests.
    pub fn terminals(&self) -> usize {
        self.completed + self.rejected + self.timed_out + self.cancelled + self.failed
    }

    /// Fraction of terminals shed by admission control or deadlines
    /// (`Rejected` + `TimedOut`); 0.0 before any terminal.
    pub fn shed_rate(&self) -> f64 {
        let t = self.terminals();
        if t == 0 {
            return 0.0;
        }
        (self.rejected + self.timed_out) as f64 / t as f64
    }

    /// Per-class TTFT view.
    pub fn ttft_class(&self, class: Class) -> &LatencyStats {
        match class {
            Class::Interactive => &self.ttft_interactive,
            Class::Batch => &self.ttft_batch,
        }
    }

    pub fn ttft_class_mut(&mut self, class: Class) -> &mut LatencyStats {
        match class {
            Class::Interactive => &mut self.ttft_interactive,
            Class::Batch => &mut self.ttft_batch,
        }
    }

    /// Fraction of prefix-cache consultations that hit (0.0 when the
    /// cache was never consulted).
    pub fn cache_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / self.prefix_lookups as f64
    }

    /// Fraction of draft proposals the target accepted (0.0 before any
    /// proposal, i.e. whenever speculation is off).
    pub fn spec_accept_rate(&self) -> f64 {
        if self.spec_proposed == 0 {
            return 0.0;
        }
        self.spec_accepted as f64 / self.spec_proposed as f64
    }

    pub fn merge(&mut self, other: &ServeMetrics) {
        self.per_token.merge(&other.per_token);
        self.prefill.merge(&other.prefill);
        self.ttft.merge(&other.ttft);
        self.ttft_interactive.merge(&other.ttft_interactive);
        self.ttft_batch.merge(&other.ttft_batch);
        self.queue_wait.merge(&other.queue_wait);
        self.completed += other.completed;
        self.rejected += other.rejected;
        self.timed_out += other.timed_out;
        self.cancelled += other.cancelled;
        self.failed += other.failed;
        self.no_token_requests += other.no_token_requests;
        self.prefix_lookups += other.prefix_lookups;
        self.prefix_hits += other.prefix_hits;
        self.prefill_tokens_saved += other.prefill_tokens_saved;
        self.spec_rounds += other.spec_rounds;
        self.spec_proposed += other.spec_proposed;
        self.spec_accepted += other.spec_accepted;
    }

    pub fn summary(&self) -> String {
        // one sort per dimension (LatencyStats::percentiles), not one
        // per percentile
        let ttft = self.ttft.percentiles(&[50.0, 99.0]);
        let queue = self.queue_wait.percentiles(&[50.0, 99.0]);
        format!(
            "per-token {} | ttft p50={:.3}ms p99={:.3}ms | queue-wait p50={:.3}ms p99={:.3}ms | \
             prefix-cache hit-rate={:.2} saved={} tokens | spec rounds={} accept-rate={:.2} | \
             outcomes completed={} rejected={} \
             timed-out={} cancelled={} failed={} (shed-rate={:.2}, no-token={})",
            self.per_token.summary(),
            ttft[0],
            ttft[1],
            queue[0],
            queue[1],
            self.cache_hit_rate(),
            self.prefill_tokens_saved,
            self.spec_rounds,
            self.spec_accept_rate(),
            self.completed,
            self.rejected,
            self.timed_out,
            self.cancelled,
            self.failed,
            self.shed_rate(),
            self.no_token_requests,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let mut s = LatencyStats::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.record_ms(v);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert_eq!(s.percentile(50.0), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn empty_stats_safe() {
        let s = LatencyStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        // the motivating bugs: min() folded from +inf (an idle worker's
        // summary printed "inf"), max() from 0.0 (empty vs all-negative
        // indistinguishable) — both must report 0.0 on empty, finitely
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert!(s.min().is_finite() && s.max().is_finite());
        assert_eq!(s.percentiles(&[50.0, 99.0]), vec![0.0, 0.0]);
        assert!(!s.summary().contains("inf"), "{}", s.summary());
    }

    #[test]
    fn negative_samples_min_max_exact() {
        // negative latencies shouldn't occur, but clock skew can produce
        // them and the stats must report, not clamp: the old max() fold
        // from 0.0 turned an all-negative population into 0.0
        let mut s = LatencyStats::new();
        for v in [-5.0, -1.0, -3.0] {
            s.record_ms(v);
        }
        assert_eq!(s.min(), -5.0);
        assert_eq!(s.max(), -1.0, "max must not clamp negatives to 0.0");
        assert_eq!(s.percentile(100.0), -1.0);
    }

    #[test]
    fn percentiles_batch_matches_individual_calls() {
        let mut s = LatencyStats::new();
        for v in [4.0, 1.0, 3.0, 2.0, 8.0, 0.5, 2.5] {
            s.record_ms(v);
        }
        let ps = [0.0, 25.0, 50.0, 95.0, 99.0, 100.0];
        let batch = s.percentiles(&ps);
        for (i, &p) in ps.iter().enumerate() {
            assert_eq!(batch[i], s.percentile(p), "p{p}");
        }
    }

    #[test]
    fn single_sample_every_percentile() {
        let mut s = LatencyStats::new();
        s.record_ms(7.5);
        for p in [0.0, 25.0, 50.0, 99.0, 100.0] {
            assert_eq!(s.percentile(p), 7.5, "p{p}");
        }
    }

    #[test]
    fn two_samples_interpolate() {
        // the motivating bug: nearest-rank made p50 of [1, 2] return 2.0
        let mut s = LatencyStats::new();
        s.record_ms(2.0);
        s.record_ms(1.0);
        assert!((s.percentile(50.0) - 1.5).abs() < 1e-12);
        assert!((s.percentile(25.0) - 1.25).abs() < 1e-12);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 2.0);
    }

    #[test]
    fn even_length_interpolates_between_middle_ranks() {
        let mut s = LatencyStats::new();
        for v in [4.0, 1.0, 3.0, 2.0] {
            s.record_ms(v);
        }
        // rank = 0.5 * 3 = 1.5 → halfway between 2.0 and 3.0
        assert!((s.percentile(50.0) - 2.5).abs() < 1e-12);
        // rank = 0.25 * 3 = 0.75 → 1.0 + 0.75
        assert!((s.percentile(25.0) - 1.75).abs() < 1e-12);
        // out-of-range p clamps rather than panicking
        assert_eq!(s.percentile(-5.0), 1.0);
        assert_eq!(s.percentile(150.0), 4.0);
    }

    #[test]
    fn merge_combines() {
        let mut a = LatencyStats::new();
        a.record_ms(1.0);
        let mut b = LatencyStats::new();
        b.record_ms(3.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn serve_metrics_tracks_all_dimensions() {
        let mut m = ServeMetrics::new();
        m.per_token.record_ms(1.0);
        m.per_token.record_ms(2.0);
        m.prefill.record_ms(5.0);
        m.ttft.record_ms(6.0);
        m.queue_wait.record_ms(0.5);
        assert_eq!(m.requests(), 1);
        assert_eq!(m.per_token.count(), 2);
        let s = m.summary();
        assert!(s.contains("ttft"), "{s}");
        assert!(s.contains("queue-wait"), "{s}");
    }

    #[test]
    fn serve_metrics_merge_merges_every_dimension() {
        let mut a = ServeMetrics::new();
        a.per_token.record_ms(1.0);
        a.ttft.record_ms(10.0);
        a.queue_wait.record_ms(1.0);
        a.prefill.record_ms(4.0);
        a.prefix_lookups = 4;
        a.prefix_hits = 1;
        a.prefill_tokens_saved = 32;
        a.spec_rounds = 3;
        a.spec_proposed = 12;
        a.spec_accepted = 9;
        let mut b = ServeMetrics::new();
        b.per_token.record_ms(3.0);
        b.ttft.record_ms(20.0);
        b.queue_wait.record_ms(2.0);
        b.prefill.record_ms(6.0);
        b.prefix_lookups = 2;
        b.prefix_hits = 2;
        b.prefill_tokens_saved = 10;
        b.spec_rounds = 1;
        b.spec_proposed = 4;
        b.spec_accepted = 3;
        a.merge(&b);
        assert_eq!(a.per_token.count(), 2);
        assert_eq!(a.requests(), 2);
        assert!((a.ttft.mean() - 15.0).abs() < 1e-12);
        assert!((a.prefill.mean() - 5.0).abs() < 1e-12);
        assert!((a.queue_wait.mean() - 1.5).abs() < 1e-12);
        assert_eq!(a.prefix_lookups, 6);
        assert_eq!(a.prefix_hits, 3);
        assert_eq!(a.prefill_tokens_saved, 42);
        assert!((a.cache_hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(a.spec_rounds, 4);
        assert_eq!(a.spec_proposed, 16);
        assert_eq!(a.spec_accepted, 12);
        assert!((a.spec_accept_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn spec_accept_rate_safe_when_spec_off() {
        let m = ServeMetrics::new();
        assert_eq!(m.spec_accept_rate(), 0.0);
        let s = m.summary();
        assert!(s.contains("spec rounds=0"), "{s}");
    }

    #[test]
    fn outcome_counters_and_shed_rate() {
        let mut m = ServeMetrics::new();
        assert_eq!(m.shed_rate(), 0.0, "no terminals yet");
        for o in [
            GenOutcome::Completed,
            GenOutcome::Completed,
            GenOutcome::Rejected,
            GenOutcome::TimedOut,
            GenOutcome::Cancelled,
            GenOutcome::Failed,
        ] {
            m.record_outcome(o);
        }
        assert_eq!(m.completed, 2);
        assert_eq!(m.rejected, 1);
        assert_eq!(m.timed_out, 1);
        assert_eq!(m.cancelled, 1);
        assert_eq!(m.failed, 1);
        assert_eq!(m.terminals(), 6);
        assert!((m.shed_rate() - 2.0 / 6.0).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("completed=2"), "{s}");
        assert!(s.contains("failed=1"), "{s}");
    }

    #[test]
    fn per_class_ttft_and_outcomes_merge() {
        let mut a = ServeMetrics::new();
        a.ttft_class_mut(Class::Interactive).record_ms(5.0);
        a.record_outcome(GenOutcome::Completed);
        a.no_token_requests = 1;
        let mut b = ServeMetrics::new();
        b.ttft_class_mut(Class::Batch).record_ms(50.0);
        b.record_outcome(GenOutcome::TimedOut);
        b.record_outcome(GenOutcome::Failed);
        a.merge(&b);
        assert_eq!(a.ttft_class(Class::Interactive).count(), 1);
        assert_eq!(a.ttft_class(Class::Batch).count(), 1);
        assert!((a.ttft_batch.mean() - 50.0).abs() < 1e-12);
        assert_eq!(a.terminals(), 3);
        assert_eq!(a.timed_out, 1);
        assert_eq!(a.failed, 1);
        assert_eq!(a.no_token_requests, 1);
    }

    #[test]
    fn cache_hit_rate_safe_when_never_consulted() {
        let m = ServeMetrics::new();
        assert_eq!(m.cache_hit_rate(), 0.0);
        assert_eq!(m.prefill_tokens_saved, 0);
        let s = m.summary();
        assert!(s.contains("prefix-cache"), "{s}");
    }

    #[test]
    fn zero_token_prefill_keeps_request_accounting_consistent() {
        // a request admitted with its whole (empty or fully-cached-but-
        // capped) prompt already in KV still records queue-wait and — if
        // it emits a token — TTFT, while prefill may be a 0 ms sample.
        // requests() keys off queue_wait, so it must not drift from the
        // other per-request dimensions.
        let mut m = ServeMetrics::new();
        m.queue_wait.record_ms(0.2);
        m.prefill.record_ms(0.0);
        m.ttft.record_ms(0.4);
        assert_eq!(m.requests(), 1);
        assert_eq!(m.prefill.count(), 1);
        assert_eq!(m.ttft.count(), 1);
        assert_eq!(m.prefill.mean(), 0.0);
        assert!(m.ttft.percentile(50.0) > 0.0);
        // a no-token request (max_new 0): queue-wait yes, TTFT no
        m.queue_wait.record_ms(0.1);
        m.prefill.record_ms(0.0);
        assert_eq!(m.requests(), 2);
        assert_eq!(m.ttft.count(), 1, "no-token requests must not skew TTFT");
    }
}
