//! Seeded sampling + self-speculative decoding policy (ROADMAP item 4).
//!
//! Two contracts live here, both load-bearing for the scheduler's
//! preempt-and-rerun guarantee (DESIGN.md §Sampling & Speculative
//! decoding):
//!
//! **Counter-based RNG.** Every random draw is a pure function of
//! `(seed, position, stream)` — no mutable generator state anywhere in
//! the serving stack. `position` is the KV row the drawn token will be
//! consumed at (`seq.len` at pick time), so a preempted request that is
//! re-admitted and re-prefilled replays the exact draw sequence
//! bit-identically: the draws never depend on batch composition, pool
//! state, or how many times the request was rerun. `stream` separates
//! the independent draws speculative decoding needs at one position
//! (proposal pick / accept test / residual resample).
//!
//! **Greedy is frozen.** `temperature == 0.0` routes through [`argmax`]
//! — the same tie-breaking comparison the pre-sampling scheduler used —
//! and never touches the RNG, so every pre-existing bitwise parity
//! contract (batched vs sequential, prefix cache on/off, preemption
//! replay) is untouched by default.
//!
//! [`SpecConfig`] is the knob for self-speculative decoding: the SAME
//! checkpoint repacked at 2–3 bits proposes `k` tokens per round and the
//! target verifies them in one batched pass (scheduler::spec_round). In
//! greedy mode acceptance is accept-iff-equal, so spec-on ≡ spec-off
//! bit-identically; in sampled mode standard rejection sampling keeps
//! the output distribution exactly the target's.

/// Stream id for the token pick at a position (also the draft's
/// proposal pick in speculative mode — the draft reuses the stream the
/// target would have drawn from).
pub const STREAM_PICK: u64 = 0;
/// Stream id for the speculative accept test at a position.
pub const STREAM_ACCEPT: u64 = 1;
/// Stream id for the residual resample after a speculative rejection.
pub const STREAM_RESIDUAL: u64 = 2;

/// Per-request sampling policy, carried on `GenRequest`. The default is
/// greedy (`temperature` 0), which is bitwise-frozen: it routes through
/// [`argmax`] and draws nothing from the RNG.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingParams {
    /// 0.0 = greedy argmax (the frozen default); > 0 divides the logits
    /// before the softmax
    pub temperature: f32,
    /// keep only the `top_k` highest-probability tokens (0 = no cap)
    pub top_k: usize,
    /// nucleus: keep the smallest prefix of probability-sorted tokens
    /// whose mass reaches `top_p` (1.0 = no cap)
    pub top_p: f32,
    /// RNG seed; draws are pure functions of (seed, position, stream)
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { temperature: 0.0, top_k: 0, top_p: 1.0, seed: 0 }
    }
}

impl SamplingParams {
    pub fn greedy() -> Self {
        Self::default()
    }

    pub fn is_greedy(&self) -> bool {
        self.temperature == 0.0
    }

    /// Parse `"greedy"` or a comma list of `key=value` pairs:
    /// `"temp=0.8,top_k=40,top_p=0.95,seed=7"` (`temperature` is an
    /// accepted alias for `temp`). Returns `None` on unknown keys or
    /// out-of-range values.
    pub fn parse(s: &str) -> Option<Self> {
        let mut p = Self::default();
        let s = s.trim();
        if s.is_empty() || s == "greedy" {
            return Some(p);
        }
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part.split_once('=')?;
            let v = v.trim();
            match k.trim() {
                "temp" | "temperature" => p.temperature = v.parse().ok()?,
                "top_k" => p.top_k = v.parse().ok()?,
                "top_p" => p.top_p = v.parse().ok()?,
                "seed" => p.seed = v.parse().ok()?,
                _ => return None,
            }
        }
        if !p.temperature.is_finite() || p.temperature < 0.0 {
            return None;
        }
        if !p.top_p.is_finite() || p.top_p <= 0.0 || p.top_p > 1.0 {
            return None;
        }
        Some(p)
    }
}

/// splitmix64 finalizer (same avalanche the fault-injection harness
/// uses): full 64-bit diffusion, so adjacent (position, stream) keys
/// decorrelate completely.
fn avalanche(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Counter-based uniform draw in [0, 1): a pure function of
/// `(seed, position, stream)`. The top 53 bits of the avalanche become
/// the mantissa, so the value is exact in f64 and identical on every
/// ISA/thread configuration.
pub fn uniform(seed: u64, position: usize, stream: u64) -> f64 {
    let key = seed
        .wrapping_add((position as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add(stream.wrapping_mul(0xBF58_476D_1CE4_E5B9));
    (avalanche(key) >> 11) as f64 / (1u64 << 53) as f64
}

/// Deterministic argmax over the vocab logits — the single production
/// copy of the greedy pick (the sequential oracle in
/// tests/continuous_batching.rs replicates it deliberately). Ties break
/// to the HIGHEST index (`max_by` keeps the last maximum), exactly as
/// the pre-sampling scheduler did, so greedy streams stay bitwise
/// frozen.
///
/// Panics on an empty slice: the old `unwrap_or(0)` silently emitted
/// token 0, which is indistinguishable from a real pick. `i as u8` is
/// safe because model construction validates `vocab <= 256`
/// (`ModelBuildError::VocabTooLarge`).
pub fn argmax(logits: &[f32]) -> u8 {
    let (i, _) = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap_or_else(|| {
            panic!(
                "argmax: empty logits slice — the model produced no vocab scores; \
                 refusing to silently emit token 0 (check vocab/model wiring)"
            )
        });
    debug_assert!(
        i <= u8::MAX as usize,
        "argmax: token id {i} does not fit u8 — vocab > 256 must be rejected at model construction"
    );
    i as u8
}

/// The full post-filter token distribution (dense over the vocab,
/// zeros outside the temperature/top-k/top-p nucleus, sums to 1).
/// Speculative decoding needs the whole distribution — the accept test
/// compares target P against draft Q per token and the residual
/// resample draws from `max(P − Q, 0)` — so this is the one shared
/// softmax/filter implementation. Greedy params yield a point mass at
/// the argmax.
///
/// All arithmetic is sequential f64 in a fixed order: bit-identical
/// across threads and ISAs by construction.
pub fn distribution(logits: &[f32], p: &SamplingParams) -> Vec<f64> {
    assert!(!logits.is_empty(), "distribution: empty logits slice");
    let n = logits.len();
    if p.is_greedy() {
        let mut d = vec![0.0; n];
        d[argmax(logits) as usize] = 1.0;
        return d;
    }
    // probability order with index-ascending tie-break: deterministic
    // under equal logits, NaN-total ordering so sort never panics
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]).then(a.cmp(&b)));
    let keep = if p.top_k > 0 { p.top_k.min(n) } else { n };
    let t = p.temperature as f64;
    let mx = logits[order[0]] as f64 / t;
    let mut w = vec![0.0f64; n];
    let mut total = 0.0;
    for &i in &order[..keep] {
        let e = (logits[i] as f64 / t - mx).exp();
        w[i] = e;
        total += e;
    }
    if p.top_p < 1.0 {
        // nucleus cut in probability order; always keeps >= 1 token
        let target = p.top_p as f64 * total;
        let mut cum = 0.0;
        let mut cut = keep;
        for (rank, &i) in order[..keep].iter().enumerate() {
            cum += w[i];
            if cum >= target {
                cut = rank + 1;
                break;
            }
        }
        total = 0.0;
        for (rank, &i) in order[..keep].iter().enumerate() {
            if rank >= cut {
                w[i] = 0.0;
            } else {
                total += w[i];
            }
        }
    }
    for v in &mut w {
        *v /= total;
    }
    w
}

/// Invert the CDF of a dense distribution at `u ∈ [0, 1)`: the first
/// token whose cumulative mass exceeds `u`, walking in index order.
/// Round-off that leaves `u` past the final cumulative sum clamps to
/// the last positive-mass token.
pub fn pick(dist: &[f64], u: f64) -> u8 {
    let mut cum = 0.0;
    let mut last = 0usize;
    for (i, &w) in dist.iter().enumerate() {
        if w <= 0.0 {
            continue;
        }
        cum += w;
        last = i;
        if u < cum {
            return i as u8;
        }
    }
    last as u8
}

/// The scheduler's token pick for the token to be consumed at
/// `position`: greedy routes through [`argmax`] (no RNG), anything else
/// draws `uniform(seed, position, STREAM_PICK)` against the filtered
/// distribution.
pub fn sample(logits: &[f32], p: &SamplingParams, position: usize) -> u8 {
    if p.is_greedy() {
        return argmax(logits);
    }
    let d = distribution(logits, p);
    pick(&d, uniform(p.seed, position, STREAM_PICK))
}

/// Self-speculative decoding config: `k` draft proposals per round from
/// the SAME checkpoint repacked at `draft_bits` (2–3 bits is the
/// paper's extreme-quant regime — cheap enough to be a draft, accurate
/// enough to agree with the target most steps). `k == 0` disables
/// speculation entirely (the scheduler never builds a draft model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecConfig {
    /// draft proposals per round; 0 = off
    pub k: usize,
    /// bit width the draft repack uses (2..=8)
    pub draft_bits: u32,
}

impl Default for SpecConfig {
    fn default() -> Self {
        Self::off()
    }
}

impl SpecConfig {
    pub const fn off() -> Self {
        Self { k: 0, draft_bits: 3 }
    }

    pub fn enabled(&self) -> bool {
        self.k > 0
    }

    /// Parse `"off"`, `"kN"` (3-bit draft), or `"kNbB"` (explicit draft
    /// bits), e.g. `"k4"`, `"k4b2"`; a bare `"N"` is accepted as `"kN"`.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim();
        if s == "off" || s == "0" {
            return Some(Self::off());
        }
        let body = s.strip_prefix('k').unwrap_or(s);
        let (ks, bits) = match body.split_once('b') {
            Some((ks, bs)) => (ks, bs.parse::<u32>().ok()?),
            None => (body, 3),
        };
        let k = ks.parse::<usize>().ok()?;
        if k == 0 {
            return Some(Self::off());
        }
        if !(2..=8).contains(&bits) {
            return None;
        }
        Some(Self { k, draft_bits: bits })
    }

    /// `GPTQ_SPEC` env knob (the determinism matrix's `off`/`k4` rows);
    /// unset = off, unrecognized values panic loudly like
    /// `KvDtype::from_env`.
    pub fn from_env() -> Self {
        match std::env::var("GPTQ_SPEC") {
            Ok(s) => Self::parse(&s)
                .unwrap_or_else(|| panic!("GPTQ_SPEC={s:?} unrecognized (off|kN|kNbB)")),
            Err(_) => Self::off(),
        }
    }

    pub fn name(&self) -> String {
        if self.enabled() {
            format!("k{}b{}", self.k, self.draft_bits)
        } else {
            "off".into()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_greedy() {
        let p = SamplingParams::default();
        assert!(p.is_greedy());
        assert_eq!(p, SamplingParams::greedy());
    }

    #[test]
    fn parse_roundtrips() {
        let p = SamplingParams::parse("temp=0.8,top_k=40,top_p=0.95,seed=7").unwrap();
        assert_eq!(p.temperature, 0.8);
        assert_eq!(p.top_k, 40);
        assert_eq!(p.top_p, 0.95);
        assert_eq!(p.seed, 7);
        assert!(SamplingParams::parse("greedy").unwrap().is_greedy());
        assert!(SamplingParams::parse("temperature=1.0").is_some());
        assert!(SamplingParams::parse("bogus=1").is_none());
        assert!(SamplingParams::parse("temp=-1").is_none());
        assert!(SamplingParams::parse("top_p=0").is_none());
        assert!(SamplingParams::parse("top_p=1.5").is_none());
    }

    #[test]
    fn uniform_is_a_pure_function_of_its_key() {
        // same key → same draw (the replay contract), distinct keys →
        // distinct draws, everything in [0, 1)
        let a = uniform(7, 3, STREAM_PICK);
        assert_eq!(a, uniform(7, 3, STREAM_PICK));
        assert_ne!(a, uniform(7, 4, STREAM_PICK));
        assert_ne!(a, uniform(8, 3, STREAM_PICK));
        assert_ne!(a, uniform(7, 3, STREAM_ACCEPT));
        for pos in 0..100 {
            for stream in [STREAM_PICK, STREAM_ACCEPT, STREAM_RESIDUAL] {
                let u = uniform(42, pos, stream);
                assert!((0.0..1.0).contains(&u), "u={u}");
            }
        }
    }

    #[test]
    fn argmax_matches_frozen_tie_break() {
        assert_eq!(argmax(&[0.0, 3.0, 1.0]), 1);
        // ties break to the highest index — max_by keeps the last max
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    #[should_panic(expected = "empty logits")]
    fn argmax_panics_on_empty_slice() {
        // the old code returned token 0 via unwrap_or(0) — silently wrong
        argmax(&[]);
    }

    #[test]
    fn greedy_sample_never_draws() {
        // greedy must equal argmax regardless of seed/position
        let logits = [0.1, 2.0, -1.0, 1.9];
        for pos in 0..10 {
            assert_eq!(sample(&logits, &SamplingParams::greedy(), pos), 1);
        }
    }

    #[test]
    fn distribution_sums_to_one_and_respects_filters() {
        let logits = [1.0, 3.0, 2.0, 0.5, -1.0];
        let p = SamplingParams { temperature: 1.0, top_k: 0, top_p: 1.0, seed: 0 };
        let d = distribution(&logits, &p);
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(d.iter().all(|&w| w > 0.0));
        // top_k=2 keeps exactly the two highest logits (indices 1, 2)
        let d = distribution(&logits, &SamplingParams { top_k: 2, ..p });
        assert!(d[1] > 0.0 && d[2] > 0.0);
        assert_eq!(d[0], 0.0);
        assert_eq!(d[3], 0.0);
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // tight top_p keeps only the single highest
        let d = distribution(&logits, &SamplingParams { top_p: 0.1, ..p });
        assert_eq!(d[1], 1.0);
        assert_eq!(d.iter().filter(|&&w| w > 0.0).count(), 1);
        // greedy params → point mass at argmax
        let d = distribution(&logits, &SamplingParams::greedy());
        assert_eq!(d[1], 1.0);
    }

    #[test]
    fn temperature_sharpens_and_flattens() {
        let logits = [1.0, 2.0];
        let base = SamplingParams { temperature: 1.0, top_k: 0, top_p: 1.0, seed: 0 };
        let hot = distribution(&logits, &SamplingParams { temperature: 4.0, ..base });
        let cold = distribution(&logits, &SamplingParams { temperature: 0.25, ..base });
        let mid = distribution(&logits, &base);
        assert!(cold[1] > mid[1] && mid[1] > hot[1]);
        assert!(hot[1] > 0.5, "winner stays the winner at any temperature");
    }

    #[test]
    fn pick_inverts_the_cdf() {
        let d = [0.25, 0.0, 0.5, 0.25];
        assert_eq!(pick(&d, 0.0), 0);
        assert_eq!(pick(&d, 0.24), 0);
        assert_eq!(pick(&d, 0.26), 2);
        assert_eq!(pick(&d, 0.74), 2);
        assert_eq!(pick(&d, 0.76), 3);
        // u at/past the total mass clamps to the last positive token
        assert_eq!(pick(&d, 1.0), 3);
    }

    #[test]
    fn sampled_pick_is_deterministic_and_seed_sensitive() {
        let logits: Vec<f32> = (0..32).map(|i| ((i * 37) % 11) as f32 * 0.3).collect();
        let p = SamplingParams { temperature: 0.9, top_k: 8, top_p: 0.95, seed: 1 };
        let a: Vec<u8> = (0..64).map(|pos| sample(&logits, &p, pos)).collect();
        let b: Vec<u8> = (0..64).map(|pos| sample(&logits, &p, pos)).collect();
        assert_eq!(a, b, "same (seed, position) must replay bitwise");
        let other: Vec<u8> =
            (0..64).map(|pos| sample(&logits, &SamplingParams { seed: 2, ..p }, pos)).collect();
        assert_ne!(a, other, "different seeds must diverge somewhere");
        // every pick lands inside the top_k nucleus
        let d = distribution(&logits, &p);
        for &t in &a {
            assert!(d[t as usize] > 0.0, "token {t} picked outside the nucleus");
        }
    }

    #[test]
    fn spec_config_parses_and_gates() {
        assert_eq!(SpecConfig::parse("off"), Some(SpecConfig::off()));
        assert_eq!(SpecConfig::parse("0"), Some(SpecConfig::off()));
        assert_eq!(SpecConfig::parse("k4"), Some(SpecConfig { k: 4, draft_bits: 3 }));
        assert_eq!(SpecConfig::parse("4"), Some(SpecConfig { k: 4, draft_bits: 3 }));
        assert_eq!(SpecConfig::parse("k2b2"), Some(SpecConfig { k: 2, draft_bits: 2 }));
        assert_eq!(SpecConfig::parse("k4b1"), None, "1-bit draft rejected");
        assert_eq!(SpecConfig::parse("nope"), None);
        assert!(!SpecConfig::off().enabled());
        assert!(SpecConfig { k: 4, draft_bits: 3 }.enabled());
        assert_eq!(SpecConfig { k: 4, draft_bits: 3 }.name(), "k4b3");
        assert_eq!(SpecConfig::off().name(), "off");
    }
}
