//! Tiny CLI argument parser (`--flag value` / `--flag` / positionals) —
//! the clap stand-in for the offline environment.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `std::env::args().skip(1)`-style iterators. `--key value`
    /// pairs become flags; `--key` followed by another `--…` (or nothing)
    /// becomes a boolean flag with value "true".
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                let takes_value = iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                let value = if takes_value { iter.next().unwrap() } else { "true".to_string() };
                out.flags.insert(key.to_string(), value);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u32_or(&self, key: &str, default: u32) -> u32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse("quantize extra --bits 3 --size small --force");
        assert_eq!(a.positional, vec!["quantize", "extra"]);
        assert_eq!(a.u32_or("bits", 4), 3);
        assert_eq!(a.str_or("size", "nano"), "small");
        assert!(a.flag("force"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn boolean_flag_before_flag() {
        let a = parse("--verbose --bits 2");
        assert!(a.flag("verbose"));
        assert_eq!(a.u32_or("bits", 0), 2);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.str_or("x", "d"), "d");
    }
}
