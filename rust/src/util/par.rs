//! Vendored scoped thread pool — the rayon stand-in for the offline
//! environment (std-only: `std::thread::scope` workers pulling chunk
//! indices off a shared atomic counter).
//!
//! §Determinism contract (DESIGN.md §Parallelism): every parallel hot
//! path in this crate partitions work so that each output element is
//! produced by exactly one job with arithmetic that does not depend on
//! the partition — per-row matvecs, per-H-row Hessian folds, per-row
//! GPTQ solves, per-segment NLL subtotals. The thread count therefore
//! only changes *which worker* owns a range, never the numbers:
//! `threads=N` is bit-identical to `threads=1`
//! (`tests/parallel_determinism.rs` enforces this).
//!
//! The global thread count comes from, in priority order: the last
//! [`set_threads`] call (the `--threads` CLI flag), the `GPTQ_THREADS`
//! env var, else 1 (serial — exactly the pre-parallel code). A value of
//! 0 means "all cores" ([`auto_threads`]).

use std::marker::PhantomData;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of hardware threads (the `--threads 0` / `GPTQ_THREADS=0` value).
pub fn auto_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

const UNSET: usize = usize::MAX;
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(UNSET);

fn env_threads() -> usize {
    match std::env::var("GPTQ_THREADS").ok().and_then(|v| v.trim().parse::<usize>().ok()) {
        Some(0) => auto_threads(),
        Some(n) => n,
        None => 1,
    }
}

/// The process-wide thread count (lazily initialised from `GPTQ_THREADS`).
pub fn threads() -> usize {
    let t = GLOBAL_THREADS.load(Ordering::Relaxed);
    if t != UNSET {
        return t;
    }
    let t = env_threads();
    GLOBAL_THREADS.store(t, Ordering::Relaxed);
    t
}

/// Override the process-wide thread count (0 = all cores).
pub fn set_threads(n: usize) {
    let t = if n == 0 { auto_threads() } else { n };
    GLOBAL_THREADS.store(t, Ordering::Relaxed);
}

/// Reset the process-wide thread count to the `GPTQ_THREADS` default
/// (used by tests that temporarily pin the count).
pub fn set_threads_env() {
    GLOBAL_THREADS.store(env_threads(), Ordering::Relaxed);
}

/// A scoped "pool": carries only a worker count — threads are spawned per
/// parallel region via `std::thread::scope`, so there is no persistent
/// state and nothing to shut down. Spawn cost is tens of µs per region;
/// callers gate on a work threshold and fall back to [`Pool::serial`].
#[derive(Debug, Clone, Copy)]
pub struct Pool {
    nthreads: usize,
}

impl Pool {
    /// A pool with `nthreads` workers (0 = all cores).
    pub fn new(nthreads: usize) -> Self {
        Pool { nthreads: if nthreads == 0 { auto_threads() } else { nthreads } }
    }

    /// The pool at the process-wide thread count.
    pub fn global() -> Self {
        Self::new(threads())
    }

    /// The single-worker pool: runs every job inline on the caller, in
    /// order — exactly the serial code.
    pub fn serial() -> Self {
        Pool { nthreads: 1 }
    }

    pub fn nthreads(&self) -> usize {
        self.nthreads
    }

    /// Execute `f(0), …, f(njobs-1)`, work-stealing job indices off a
    /// shared counter. With one worker (or one job) everything runs
    /// inline in index order.
    pub fn run<F>(&self, njobs: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.run_with(njobs, || (), |_, j| f(j));
    }

    /// [`Pool::run`] with per-worker state: each worker calls `init()`
    /// once and threads the value through its jobs (e.g. a cloned model,
    /// a scratch buffer). Job→worker assignment is work-stealing, so
    /// `init` must produce interchangeable states.
    pub fn run_with<S, I, F>(&self, njobs: usize, init: I, f: F)
    where
        I: Fn() -> S + Sync,
        F: Fn(&mut S, usize) + Sync,
    {
        if njobs == 0 {
            return;
        }
        let workers = self.nthreads.min(njobs);
        if workers <= 1 {
            let mut state = init();
            for j in 0..njobs {
                f(&mut state, j);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..workers - 1 {
                scope.spawn(|| {
                    let mut state = init();
                    loop {
                        let j = next.fetch_add(1, Ordering::Relaxed);
                        if j >= njobs {
                            break;
                        }
                        f(&mut state, j);
                    }
                });
            }
            // the caller participates as the last worker
            let mut state = init();
            loop {
                let j = next.fetch_add(1, Ordering::Relaxed);
                if j >= njobs {
                    break;
                }
                f(&mut state, j);
            }
        });
    }

    /// Execute `f` over `0..n` split into `chunk`-sized index ranges
    /// (last range ragged). Chunk geometry depends only on `(n, chunk)`,
    /// never on the worker count.
    pub fn run_chunks<F>(&self, n: usize, chunk: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        let chunk = chunk.max(1);
        let njobs = n.div_ceil(chunk);
        self.run(njobs, |j| {
            let start = j * chunk;
            f(start..(start + chunk).min(n));
        });
    }
}

/// Split `0..n` into `parts` contiguous balanced ranges (first `n % parts`
/// ranges one longer). `parts` is clamped to `1..=max(n,1)`.
pub fn split_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.clamp(1, n.max(1));
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// A raw, shareable view of a mutable slice for disjoint-range parallel
/// writes (the sound core under every parallel output in this crate).
pub struct SliceParts<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

unsafe impl<'a, T: Send> Send for SliceParts<'a, T> {}
unsafe impl<'a, T: Send> Sync for SliceParts<'a, T> {}

impl<'a, T> SliceParts<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        Self { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    /// Reborrow `range` of the underlying slice.
    ///
    /// # Safety
    /// Concurrent callers must use pairwise-disjoint ranges; the range
    /// must lie within the original slice (debug-asserted).
    pub unsafe fn range(&self, r: Range<usize>) -> &mut [T] {
        debug_assert!(r.start <= r.end && r.end <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(r.start), r.end - r.start)
    }
}

/// Run `f(row_range, rows_chunk)` over `out` viewed as `rows` rows of
/// `stride` elements, one contiguous chunk per worker. The serial pool
/// calls `f(0..rows, out)` once — callers keep per-row arithmetic
/// independent of the chunking, which makes every thread count
/// bit-identical (the determinism contract).
pub fn for_rows_mut<T, F>(pool: &Pool, out: &mut [T], rows: usize, stride: usize, f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    assert_eq!(out.len(), rows * stride, "for_rows_mut: len != rows*stride");
    let workers = pool.nthreads.min(rows.max(1));
    if workers <= 1 {
        f(0..rows, out);
        return;
    }
    let chunk = rows.div_ceil(workers);
    let parts = SliceParts::new(out);
    pool.run_chunks(rows, chunk, |r| {
        let s = unsafe { parts.range(r.start * stride..r.end * stride) };
        f(r, s);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_covers_every_job_exactly_once() {
        for nthreads in [1usize, 4] {
            let pool = Pool::new(nthreads);
            let hits: Vec<AtomicU64> = (0..37).map(|_| AtomicU64::new(0)).collect();
            pool.run(37, |j| {
                hits[j].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "nthreads={nthreads}");
        }
    }

    #[test]
    fn run_chunks_tiles_the_range() {
        let pool = Pool::new(3);
        let hits: Vec<AtomicU64> = (0..25).map(|_| AtomicU64::new(0)).collect();
        pool.run_chunks(25, 4, |r| {
            assert!(r.len() <= 4 && !r.is_empty());
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn split_ranges_balanced_and_contiguous() {
        for (n, parts) in [(10usize, 3usize), (3, 8), (0, 4), (16, 4), (7, 7)] {
            let rs = split_ranges(n, parts);
            let mut next = 0usize;
            for r in &rs {
                assert_eq!(r.start, next);
                next = r.end;
            }
            assert_eq!(next, n, "n={n} parts={parts}");
            if n > 0 {
                let lens: Vec<usize> = rs.iter().map(|r| r.len()).collect();
                let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                assert!(mx - mn <= 1, "unbalanced {lens:?}");
            }
        }
    }

    #[test]
    fn for_rows_mut_writes_disjoint_rows() {
        for nthreads in [1usize, 4] {
            let pool = Pool::new(nthreads);
            let (rows, stride) = (13usize, 5usize);
            let mut out = vec![0u32; rows * stride];
            for_rows_mut(&pool, &mut out, rows, stride, |rr, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    let row = rr.start + i / stride;
                    *v = (row * stride + i % stride) as u32 + 1;
                }
            });
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i as u32 + 1, "nthreads={nthreads}");
            }
        }
    }

    #[test]
    fn run_with_builds_state_per_worker() {
        let pool = Pool::new(4);
        let total = AtomicU64::new(0);
        pool.run_with(
            64,
            || 0u64,
            |acc, j| {
                *acc += j as u64;
                // fold local state in at the last moment (order-free sum)
                total.fetch_add(j as u64, Ordering::Relaxed);
                let _ = acc;
            },
        );
        assert_eq!(total.load(Ordering::Relaxed), (0..64u64).sum());
    }

    #[test]
    fn thread_count_knobs() {
        // set_threads(0) resolves to all cores; explicit values stick
        set_threads(3);
        assert_eq!(threads(), 3);
        set_threads(0);
        assert_eq!(threads(), auto_threads());
        set_threads_env(); // restore the env default for other tests
    }
}
