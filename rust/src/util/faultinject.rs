//! Deterministic fault injection for the serving tier (chaos testing).
//!
//! Production serving must survive pool exhaustion, stuck ticks, and
//! worker crashes — but those conditions are rare and timing-dependent,
//! so tests that wait for them organically are flaky and slow. This
//! module makes faults *schedulable*: a seeded [`FaultConfig`] names the
//! injection points (forced `KvPool::reserve` failure, worker panic at
//! tick N, artificial per-tick delay) and a per-scheduler
//! [`FaultInjector`] fires them from counters, not wall-clock, so the
//! same seed always produces the same injected schedule and a chaos
//! trace is exactly replayable (`tests/chaos.rs`).
//!
//! Off by default and zero-cost when off: every hook early-returns on a
//! disabled config, and `FaultConfig::off()` is what
//! `SchedulerConfig::default()` carries unless `GPTQ_FAULTS` is set —
//! the determinism contracts (threads=N ≡ 1, cache-on ≡ off, f32
//! bit-identity) are untouched when no faults are injected.
//!
//! `GPTQ_FAULTS` grammar (comma-separated `key=value`, `panic`
//! repeatable):
//!
//! ```text
//! GPTQ_FAULTS="seed=7,reserve=0.1,panic=0@5,panic=1@9,delay=3@2"
//!              |      |           |                   +- sleep 2 ms before every 3rd tick
//!              |      |           +- worker 0 panics at its 5th tick (and worker 1 at its 9th)
//!              |      +- each reserve attempt fails with probability 0.1
//!              +- seed for the counter-based reserve-failure schedule
//! ```

use std::time::Duration;

/// Which faults to inject, and where. `Default`/[`FaultConfig::off`] is
/// the no-faults configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultConfig {
    /// seed for the counter-based reserve-failure schedule: same seed ⇒
    /// same injected schedule (per worker id)
    pub seed: u64,
    /// probability in [0, 1] that any one `KvPool::reserve` attempt is
    /// forced to fail (exercises eviction/preemption without real pool
    /// pressure); 0.0 = never
    pub reserve_fail_p: f64,
    /// (worker id, tick) pairs: that worker's scheduler panics at the
    /// top of its tick-th `step()` call (1-based), before touching any
    /// state — so a re-routed request replays from a clean slate
    pub panic_at: Vec<(usize, u64)>,
    /// (every_n, ms): sleep `ms` milliseconds before every `every_n`-th
    /// tick — an artificial slow step, for exercising deadline timeouts
    pub step_delay: Option<(u64, u64)>,
}

impl FaultConfig {
    /// No faults (the production configuration).
    pub fn off() -> Self {
        Self::default()
    }

    /// Whether any injection point is armed.
    pub fn enabled(&self) -> bool {
        self.reserve_fail_p > 0.0 || !self.panic_at.is_empty() || self.step_delay.is_some()
    }

    /// Read `GPTQ_FAULTS` (see the module docs for the grammar). Unset
    /// or empty = no faults. A malformed spec panics: silently dropping
    /// faults would make a chaos run vacuously green.
    pub fn from_env() -> Self {
        match std::env::var("GPTQ_FAULTS") {
            Ok(s) if !s.trim().is_empty() => {
                Self::parse(&s).unwrap_or_else(|e| panic!("GPTQ_FAULTS: {e}"))
            }
            _ => Self::off(),
        }
    }

    /// Parse the `GPTQ_FAULTS` grammar.
    pub fn parse(s: &str) -> Result<Self, String> {
        let mut cfg = Self::off();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| format!("expected key=value, got {part:?}"))?;
            match k.trim() {
                "seed" => {
                    cfg.seed = v.parse().map_err(|_| format!("bad seed {v:?}"))?;
                }
                "reserve" => {
                    let p: f64 = v.parse().map_err(|_| format!("bad reserve probability {v:?}"))?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(format!("reserve probability {p} outside [0, 1]"));
                    }
                    cfg.reserve_fail_p = p;
                }
                "panic" => {
                    let (w, t) = v
                        .split_once('@')
                        .ok_or_else(|| format!("panic wants WID@TICK, got {v:?}"))?;
                    let wid = w.parse().map_err(|_| format!("bad panic worker id {w:?}"))?;
                    let tick = t.parse().map_err(|_| format!("bad panic tick {t:?}"))?;
                    cfg.panic_at.push((wid, tick));
                }
                "delay" => {
                    let (n, ms) = v
                        .split_once('@')
                        .ok_or_else(|| format!("delay wants EVERY_N@MS, got {v:?}"))?;
                    let every: u64 = n.parse().map_err(|_| format!("bad delay period {n:?}"))?;
                    if every == 0 {
                        return Err("delay period must be >= 1".into());
                    }
                    let ms = ms.parse().map_err(|_| format!("bad delay ms {ms:?}"))?;
                    cfg.step_delay = Some((every, ms));
                }
                other => {
                    return Err(format!("unknown fault key {other:?} (seed|reserve|panic|delay)"));
                }
            }
        }
        Ok(cfg)
    }
}

/// Per-scheduler fault state: counters (tick, reserve attempts) that the
/// injection decisions hash from. Same `FaultConfig` + same worker id +
/// same call sequence ⇒ same injected schedule.
#[derive(Debug)]
pub struct FaultInjector {
    cfg: FaultConfig,
    wid: usize,
    ticks: u64,
    reserves: u64,
}

impl FaultInjector {
    pub fn new(cfg: FaultConfig, wid: usize) -> Self {
        Self { cfg, wid, ticks: 0, reserves: 0 }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled()
    }

    /// Ticks observed so far (1-based after the first `on_tick`).
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Tick-boundary hook, called at the top of every `Scheduler::step`
    /// BEFORE any state changes: fires the artificial delay and the
    /// scheduled worker panic. Zero-cost when no faults are armed.
    pub fn on_tick(&mut self) {
        if !self.enabled() {
            return;
        }
        self.ticks += 1;
        if let Some((every, ms)) = self.cfg.step_delay {
            if self.ticks % every == 0 {
                std::thread::sleep(Duration::from_millis(ms));
            }
        }
        if self.cfg.panic_at.iter().any(|&(w, t)| w == self.wid && t == self.ticks) {
            panic!("injected worker panic (wid {}, tick {})", self.wid, self.ticks);
        }
    }

    /// Reserve-site hook: whether THIS reserve attempt is forced to
    /// fail. Counter-based (splitmix64 over seed ⊕ wid ⊕ attempt
    /// counter), so the failure schedule is a pure function of the
    /// config and the call sequence — never of wall-clock.
    pub fn inject_reserve_failure(&mut self) -> bool {
        if self.cfg.reserve_fail_p <= 0.0 {
            return false;
        }
        self.reserves += 1;
        let h = splitmix64(
            self.cfg
                .seed
                .wrapping_add((self.wid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
                .wrapping_add(self.reserves.wrapping_mul(0xBF58_476D_1CE4_E5B9)),
        );
        // top 53 bits as a uniform fraction in [0, 1)
        (h >> 11) as f64 / (1u64 << 53) as f64 < self.cfg.reserve_fail_p
    }
}

/// SplitMix64 finalizer — the standard 64-bit avalanche mix.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_is_disabled_and_injects_nothing() {
        let mut inj = FaultInjector::new(FaultConfig::off(), 0);
        assert!(!inj.enabled());
        inj.on_tick(); // must not count, sleep, or panic
        assert_eq!(inj.ticks(), 0);
        for _ in 0..1000 {
            assert!(!inj.inject_reserve_failure());
        }
    }

    #[test]
    fn parse_full_grammar() {
        let cfg = FaultConfig::parse("seed=7, reserve=0.1, panic=0@5, panic=1@9, delay=3@2").unwrap();
        assert_eq!(cfg.seed, 7);
        assert!((cfg.reserve_fail_p - 0.1).abs() < 1e-12);
        assert_eq!(cfg.panic_at, vec![(0, 5), (1, 9)]);
        assert_eq!(cfg.step_delay, Some((3, 2)));
        assert!(cfg.enabled());
        // empty / missing spec is the off config
        assert_eq!(FaultConfig::parse("").unwrap(), FaultConfig::off());
        assert!(!FaultConfig::off().enabled());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(FaultConfig::parse("reserve").is_err());
        assert!(FaultConfig::parse("reserve=1.5").is_err());
        assert!(FaultConfig::parse("panic=3").is_err());
        assert!(FaultConfig::parse("delay=0@5").is_err());
        assert!(FaultConfig::parse("bogus=1").is_err());
    }

    #[test]
    fn reserve_schedule_is_seed_deterministic() {
        let cfg = FaultConfig { seed: 42, reserve_fail_p: 0.3, ..FaultConfig::off() };
        let run = |cfg: &FaultConfig, wid: usize| -> Vec<bool> {
            let mut inj = FaultInjector::new(cfg.clone(), wid);
            (0..200).map(|_| inj.inject_reserve_failure()).collect()
        };
        let a = run(&cfg, 0);
        assert_eq!(a, run(&cfg, 0), "same seed+wid must replay identically");
        assert_ne!(a, run(&cfg, 1), "worker id must decorrelate the schedules");
        let other = FaultConfig { seed: 43, ..cfg.clone() };
        assert_ne!(a, run(&other, 0), "seed must change the schedule");
        // the empirical rate is in the right ballpark for p=0.3
        let hits = a.iter().filter(|&&b| b).count();
        assert!((30..=90).contains(&hits), "200 draws at p=0.3 hit {hits} times");
    }

    #[test]
    fn panic_fires_at_the_scheduled_tick_only() {
        let cfg = FaultConfig { panic_at: vec![(2, 3)], ..FaultConfig::off() };
        let mut inj = FaultInjector::new(cfg.clone(), 2);
        inj.on_tick();
        inj.on_tick();
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| inj.on_tick()));
        assert!(boom.is_err(), "tick 3 must panic for wid 2");
        // a different worker never fires
        let mut other = FaultInjector::new(cfg, 0);
        for _ in 0..10 {
            other.on_tick();
        }
        assert_eq!(other.ticks(), 10);
    }

    #[test]
    fn delay_ticks_without_panicking() {
        let cfg = FaultConfig { step_delay: Some((2, 1)), ..FaultConfig::off() };
        let mut inj = FaultInjector::new(cfg, 0);
        let t0 = std::time::Instant::now();
        for _ in 0..4 {
            inj.on_tick(); // sleeps 1 ms on ticks 2 and 4
        }
        assert!(t0.elapsed() >= Duration::from_millis(2));
        assert_eq!(inj.ticks(), 4);
    }
}
