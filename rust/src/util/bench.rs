//! Micro-benchmark harness (the criterion stand-in): warmup, repeated
//! timed runs, mean / stddev / min, aligned table printing for the
//! paper-table benches, and JSON recording (`BENCH_*.json`,
//! EXPERIMENTS.md §Benches) so the perf trajectory is tracked in-repo.

use crate::util::json::Json;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10.4} ms ±{:>8.4}  (min {:>10.4}, n={})",
            self.name, self.mean_ms, self.std_ms, self.min_ms, self.iters
        )
    }

    /// JSON form for the `BENCH_*.json` perf-trajectory records.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("mean_ms", Json::Num(self.mean_ms)),
            ("std_ms", Json::Num(self.std_ms)),
            ("min_ms", Json::Num(self.min_ms)),
            ("iters", Json::Num(self.iters as f64)),
        ])
    }
}

/// Write a bench record (`{bench, results: […], summary: {…}}`) to
/// `path`. The `make bench` targets use this to produce
/// `BENCH_decode.json` / `BENCH_quantize.json` (EXPERIMENTS.md §Benches).
pub fn write_bench_json(
    path: &str,
    bench: &str,
    results: Vec<Json>,
    summary: Vec<(&str, Json)>,
) -> std::io::Result<()> {
    let doc = Json::obj(vec![
        ("bench", Json::Str(bench.to_string())),
        ("results", Json::Arr(results)),
        ("summary", Json::obj(summary)),
    ]);
    std::fs::write(path, doc.to_string())
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64() * 1e3);
    }
    summarize(name, &times)
}

/// Auto-calibrating variant: picks an iteration count so total measured
/// time is ≈ `budget_ms` (criterion-style), with at least `min_iters`.
pub fn bench_auto<F: FnMut()>(name: &str, budget_ms: f64, min_iters: usize, mut f: F) -> BenchResult {
    let t = Instant::now();
    f();
    let probe_ms = (t.elapsed().as_secs_f64() * 1e3).max(1e-6);
    let iters = ((budget_ms / probe_ms) as usize).clamp(min_iters, 10_000);
    bench(name, (iters / 10).max(1), iters, f)
}

fn summarize(name: &str, times: &[f64]) -> BenchResult {
    let n = times.len() as f64;
    let mean = times.iter().sum::<f64>() / n;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
    BenchResult {
        name: name.to_string(),
        mean_ms: mean,
        std_ms: var.sqrt(),
        min_ms: times.iter().cloned().fold(f64::INFINITY, f64::min),
        iters: times.len(),
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let r = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..50_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            black_box(acc);
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_ms > 0.0);
        assert!(r.min_ms <= r.mean_ms);
    }

    #[test]
    fn bench_auto_scales_iters() {
        let r = bench_auto("noop", 5.0, 3, || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 3);
    }

    #[test]
    fn bench_json_roundtrips() {
        let r = bench("probe", 0, 2, || {
            black_box(1 + 1);
        });
        let path = std::env::temp_dir().join("gptq_bench_json_test.json");
        let path_s = path.to_string_lossy().into_owned();
        write_bench_json(
            &path_s,
            "decode",
            vec![r.to_json()],
            vec![("speedup", Json::Num(2.0))],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("decode"));
        assert_eq!(doc.get("results").and_then(Json::as_arr).map(|a| a.len()), Some(1));
        let summary = doc.get("summary").unwrap();
        assert_eq!(summary.get("speedup").and_then(Json::as_f64), Some(2.0));
        let first = &doc.get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(first.get("name").and_then(Json::as_str), Some("probe"));
        assert_eq!(first.get("iters").and_then(Json::as_usize), Some(2));
    }
}
