//! Micro-benchmark harness (the criterion stand-in): warmup, repeated
//! timed runs, mean / stddev / min, aligned table printing for the
//! paper-table benches, and JSON recording (`BENCH_*.json`,
//! EXPERIMENTS.md §Benches) so the perf trajectory is tracked in-repo.

use crate::util::json::Json;
use std::fmt;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10.4} ms ±{:>8.4}  (min {:>10.4}, n={})",
            self.name, self.mean_ms, self.std_ms, self.min_ms, self.iters
        )
    }

    /// JSON form for the `BENCH_*.json` perf-trajectory records.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("mean_ms", Json::Num(self.mean_ms)),
            ("std_ms", Json::Num(self.std_ms)),
            ("min_ms", Json::Num(self.min_ms)),
            ("iters", Json::Num(self.iters as f64)),
        ])
    }
}

/// The machine-class key recorded in every `BENCH_*.json` header so the
/// perf gate never diffs runs from incomparable hardware: a NEON laptop
/// must not be judged against an AVX2 server baseline, and a
/// `GPTQ_ISA=scalar` run must not be judged against an `avx2` one.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MachineClass {
    /// `std::env::consts::ARCH` — "x86_64", "aarch64", …
    pub arch: String,
    /// effective kernel dispatch ISA (`model::kernels::isa().name()`)
    pub isa: String,
    /// hardware parallelism (`par::auto_threads()`), NOT the current
    /// `GPTQ_THREADS` setting — thread sweeps key on capability
    pub cores: usize,
}

impl MachineClass {
    pub fn detect() -> MachineClass {
        MachineClass {
            arch: std::env::consts::ARCH.to_string(),
            isa: crate::model::kernels::isa().name().to_string(),
            cores: crate::util::par::auto_threads(),
        }
    }

    /// The comparison key, e.g. `x86_64/avx2/8`.
    pub fn key(&self) -> String {
        format!("{}/{}/{}", self.arch, self.isa, self.cores)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arch", Json::Str(self.arch.clone())),
            ("isa", Json::Str(self.isa.clone())),
            ("cores", Json::Num(self.cores as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<MachineClass> {
        Some(MachineClass {
            arch: j.get("arch")?.as_str()?.to_string(),
            isa: j.get("isa")?.as_str()?.to_string(),
            cores: j.get("cores")?.as_usize()?,
        })
    }
}

impl fmt::Display for MachineClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.key())
    }
}

/// Write a bench record (`{bench, machine, results: […], summary: {…}}`)
/// to `path`. The `make bench` targets use this to produce
/// `BENCH_decode.json` / `BENCH_quantize.json` (EXPERIMENTS.md §Benches);
/// `perfgate` diffs the summary block against a committed baseline with
/// the same machine class.
pub fn write_bench_json(
    path: &str,
    bench: &str,
    machine: &MachineClass,
    results: Vec<Json>,
    summary: Vec<(&str, Json)>,
) -> std::io::Result<()> {
    let doc = Json::obj(vec![
        ("bench", Json::Str(bench.to_string())),
        ("machine", machine.to_json()),
        ("results", Json::Arr(results)),
        ("summary", Json::obj(summary)),
    ]);
    std::fs::write(path, doc.to_string())
}

/// A parsed `BENCH_*.json` as the perf gate sees it: the bench name, the
/// machine class, and the NUMERIC summary metrics in file order
/// (non-numeric summary entries like kernel_sweep's `isas` string are
/// informational and skipped).
#[derive(Debug, Clone)]
pub struct BenchDoc {
    pub bench: String,
    pub machine: Option<MachineClass>,
    /// optional top-level `provenance` marker: `"modeled"` rows were
    /// estimated (never measured on this machine class) — the gate still
    /// runs but the report flags them so a green gate is not mistaken for
    /// a measured baseline
    pub provenance: Option<String>,
    pub metrics: Vec<(String, f64)>,
}

impl BenchDoc {
    pub fn parse(text: &str) -> Result<BenchDoc, String> {
        let doc = Json::parse(text)?;
        let bench = doc
            .get("bench")
            .and_then(Json::as_str)
            .ok_or_else(|| "missing `bench` header".to_string())?
            .to_string();
        let machine = doc.get("machine").and_then(MachineClass::from_json);
        let provenance = doc.get("provenance").and_then(Json::as_str).map(String::from);
        let summary = doc.get("summary").ok_or_else(|| "missing `summary` block".to_string())?;
        let pairs = match summary {
            Json::Obj(pairs) => pairs,
            _ => return Err("`summary` is not an object".to_string()),
        };
        let metrics = pairs
            .iter()
            .filter_map(|(k, v)| v.as_f64().map(|n| (k.clone(), n)))
            .collect();
        Ok(BenchDoc { bench, machine, provenance, metrics })
    }

    pub fn load(path: &str) -> Result<BenchDoc, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::parse(&text).map_err(|e| format!("{path}: {e}"))
    }

    pub fn metric(&self, name: &str) -> Option<f64> {
        self.metrics.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }
}

/// Which way a metric is allowed to drift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// throughput-like: tokens/s, GB/s, speedups, tokens saved
    HigherIsBetter,
    /// latency-like: ms/layer, TTFT percentiles
    LowerIsBetter,
}

/// A tolerance band for every summary metric matching `pattern`
/// (`*` wildcards). First matching spec wins; metrics matching no spec
/// are reported but not gated.
#[derive(Debug, Clone)]
pub struct MetricSpec {
    pub pattern: String,
    pub direction: Direction,
    /// relative tolerance: a 0.15 band fails a >15% move in the bad
    /// direction (and flags a >15% move in the good one as improvement)
    pub rel_tol: f64,
}

impl MetricSpec {
    pub fn new(pattern: &str, direction: Direction, rel_tol: f64) -> MetricSpec {
        MetricSpec { pattern: pattern.to_string(), direction, rel_tol }
    }

    pub fn matches(&self, name: &str) -> bool {
        glob_match(&self.pattern, name)
    }
}

/// `*`-wildcard match (any number of stars, each matching any substring).
fn glob_match(pattern: &str, name: &str) -> bool {
    let (p, n): (Vec<char>, Vec<char>) = (pattern.chars().collect(), name.chars().collect());
    // classic iterative glob with single-level backtracking to the last *
    let (mut pi, mut ni) = (0usize, 0usize);
    let (mut star, mut mark) = (None::<usize>, 0usize);
    while ni < n.len() {
        if pi < p.len() && (p[pi] == n[ni]) {
            pi += 1;
            ni += 1;
        } else if pi < p.len() && p[pi] == '*' {
            star = Some(pi);
            mark = ni;
            pi += 1;
        } else if let Some(s) = star {
            pi = s + 1;
            mark += 1;
            ni = mark;
        } else {
            return false;
        }
    }
    while pi < p.len() && p[pi] == '*' {
        pi += 1;
    }
    pi == p.len()
}

/// The default tolerance bands for each recorded bench, keyed by the
/// `bench` header. Patterns cover every numeric summary key the four
/// harnesses emit; the bands are wide enough for shared-CI timing noise
/// but far inside the ≥20% regression the gate exists to catch.
/// Deterministic counters (`prefill_tokens_saved`) get a zero band.
pub fn default_specs(bench: &str) -> Vec<MetricSpec> {
    use Direction::{HigherIsBetter as Higher, LowerIsBetter as Lower};
    match bench {
        "kernels" => vec![
            MetricSpec::new("speedup_4bit_b16_*_over_scalar", Higher, 0.15),
            // 2:4 sparse vs dense-packed, batch-1: the modeled baseline is
            // 1.6x, so a 0.19 band gates at >=1.3x (the acceptance floor)
            MetricSpec::new("sparse24_speedup_4bit_b1_*_over_dense", Higher, 0.19),
            MetricSpec::new("sparse24_gbps_4bit_b1_*", Higher, 0.25),
            MetricSpec::new("peak_gbps*", Higher, 0.25),
        ],
        "decode" => vec![
            MetricSpec::new("peak_gbps*", Higher, 0.25),
            MetricSpec::new("ms_per_layer_*", Lower, 0.15),
            MetricSpec::new("tokens_per_s_*", Higher, 0.15),
            MetricSpec::new("decode_speedup_*", Higher, 0.15),
        ],
        "quantize" => vec![
            MetricSpec::new("quantize_speedup_*", Higher, 0.15),
            MetricSpec::new("ms_per_layer_*", Lower, 0.20),
        ],
        "serve" => vec![
            MetricSpec::new("serve_speedup_*", Higher, 0.20),
            MetricSpec::new("ttft_p50_ms_*", Lower, 0.25),
            MetricSpec::new("ttft_p99_ms_*", Lower, 0.35),
            MetricSpec::new("*_prefill_tokens_saved", Higher, 0.0),
            MetricSpec::new("*_ttft_p50_speedup", Higher, 0.25),
            // fixed-byte-budget q8 KV phase: capacity and agreement are
            // deterministic (scheduler driven synchronously), tail TTFT
            // is wall-clock; preemption counts stay ungated/informational
            MetricSpec::new("kv_fixed_bytes_peak_seqs_*", Higher, 0.10),
            MetricSpec::new("kv_q8_capacity_ratio", Higher, 0.20),
            MetricSpec::new("kv_q8_ttft_p99_speedup", Higher, 0.25),
            MetricSpec::new("kv_q8_token_agreement", Higher, 0.05),
            // overload phase: TTFT tails are wall-clock (wide band);
            // shed and completed rates come from deterministic admission
            // decisions but shift with machine speed, so they get
            // moderate bands rather than zero
            MetricSpec::new("overload*_ttft_p99_ms_*", Lower, 0.35),
            MetricSpec::new("overload*_shed_rate_*", Lower, 0.15),
            MetricSpec::new("overload*_completed_rate", Higher, 0.10),
            // spec-decode phase: tokens/s is wall-clock (wide band); the
            // modeled speedup baseline is 1.35x, so a 0.11 band gates at
            // >=1.2x (the acceptance floor); acceptance rate is a
            // draft-quality signal, not timing, so it gets a tight band
            MetricSpec::new("spec_k4_tokens_per_s", Higher, 0.25),
            MetricSpec::new("spec_k4_speedup_vs_greedy", Higher, 0.11),
            MetricSpec::new("spec_k4_accept_rate", Higher, 0.15),
        ],
        _ => Vec::new(),
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricStatus {
    Pass,
    Improved,
    Regressed,
    /// no spec matched — informational only
    Skipped,
}

/// One row of the per-metric report.
#[derive(Debug, Clone)]
pub struct MetricLine {
    pub name: String,
    pub baseline: f64,
    pub current: f64,
    /// signed relative change, +0.20 = 20% higher than baseline
    pub delta: f64,
    pub rel_tol: f64,
    pub status: MetricStatus,
}

/// The outcome of diffing one current bench doc against its baseline.
#[derive(Debug, Clone)]
pub struct GateReport {
    pub bench: String,
    pub lines: Vec<MetricLine>,
    /// structural problems: machine-class mismatch, missing/extra
    /// metric keys, bench-name mismatch — never panics
    pub errors: Vec<String>,
    /// advisories that do not fail the gate: e.g. the baseline carries a
    /// `provenance: "modeled"` marker, so its gated rows were estimated
    /// rather than measured
    pub warnings: Vec<String>,
}

impl GateReport {
    pub fn regressions(&self) -> usize {
        self.lines.iter().filter(|l| l.status == MetricStatus::Regressed).count()
    }

    pub fn passed(&self) -> bool {
        self.errors.is_empty() && self.regressions() == 0
    }

    /// Human-readable per-metric report (the thing CI prints on red).
    pub fn render(&self) -> String {
        let mut out = format!("== perfgate: bench `{}` ==\n", self.bench);
        for e in &self.errors {
            out.push_str(&format!("  ERROR      {e}\n"));
        }
        for w in &self.warnings {
            out.push_str(&format!("  WARN       {w}\n"));
        }
        for l in &self.lines {
            let tag = match l.status {
                MetricStatus::Pass => "ok       ",
                MetricStatus::Improved => "IMPROVED ",
                MetricStatus::Regressed => "REGRESSED",
                MetricStatus::Skipped => "(no spec)",
            };
            out.push_str(&format!(
                "  {tag}  {:<44} base {:>12.4}  now {:>12.4}  {:>+7.1}% (tol ±{:.0}%)\n",
                l.name,
                l.baseline,
                l.current,
                l.delta * 100.0,
                l.rel_tol * 100.0
            ));
        }
        out.push_str(&format!(
            "  => {} metrics, {} regressed, {} errors: {}\n",
            self.lines.len(),
            self.regressions(),
            self.errors.len(),
            if self.passed() { "PASS" } else { "FAIL" }
        ));
        out
    }
}

/// Diff `current` against `baseline` under `specs`. Every baseline
/// metric must exist in the current run and vice versa (a vanished or
/// novel summary key means the bench changed shape and the baseline
/// must be regenerated — reported as an error, not a panic). Machine
/// classes must match exactly; regressions are moves beyond `rel_tol`
/// in the spec's bad direction.
pub fn compare(baseline: &BenchDoc, current: &BenchDoc, specs: &[MetricSpec]) -> GateReport {
    let mut report = GateReport {
        bench: baseline.bench.clone(),
        lines: Vec::new(),
        errors: Vec::new(),
        warnings: Vec::new(),
    };
    if baseline.bench != current.bench {
        report.errors.push(format!(
            "bench mismatch: baseline `{}` vs current `{}`",
            baseline.bench, current.bench
        ));
    }
    match (&baseline.machine, &current.machine) {
        (Some(b), Some(c)) if b.key() != c.key() => report.errors.push(format!(
            "machine-class mismatch: baseline {} vs current {} — not comparable; \
             re-baseline on this machine class",
            b.key(),
            c.key()
        )),
        (None, _) => report.errors.push("baseline has no machine-class header".to_string()),
        (_, None) => report.errors.push("current run has no machine-class header".to_string()),
        _ => {}
    }
    for (name, base) in &baseline.metrics {
        let Some(cur) = current.metric(name) else {
            report.errors.push(format!("metric `{name}` is in the baseline but missing from the current run"));
            continue;
        };
        let Some(spec) = specs.iter().find(|s| s.matches(name)) else {
            report.lines.push(MetricLine {
                name: name.clone(),
                baseline: *base,
                current: cur,
                delta: (cur - base) / base.abs().max(1e-12),
                rel_tol: 0.0,
                status: MetricStatus::Skipped,
            });
            continue;
        };
        let delta = (cur - base) / base.abs().max(1e-12);
        let (bad, good) = match spec.direction {
            Direction::HigherIsBetter => (delta < -spec.rel_tol - 1e-12, delta > spec.rel_tol + 1e-12),
            Direction::LowerIsBetter => (delta > spec.rel_tol + 1e-12, delta < -spec.rel_tol - 1e-12),
        };
        let status = if bad {
            MetricStatus::Regressed
        } else if good {
            MetricStatus::Improved
        } else {
            MetricStatus::Pass
        };
        report.lines.push(MetricLine {
            name: name.clone(),
            baseline: *base,
            current: cur,
            delta,
            rel_tol: spec.rel_tol,
            status,
        });
    }
    for (name, _) in &current.metrics {
        if baseline.metric(name).is_none() {
            report.errors.push(format!(
                "metric `{name}` appeared in the current run but is not in the baseline"
            ));
        }
    }
    // modeled baselines still gate, but the report must say so: list the
    // gated (specced) keys whose reference numbers were estimated
    if let Some(p) = &baseline.provenance {
        if p.contains("modeled") {
            let gated: Vec<&str> = report
                .lines
                .iter()
                .filter(|l| l.status != MetricStatus::Skipped)
                .map(|l| l.name.as_str())
                .collect();
            if !gated.is_empty() {
                report.warnings.push(format!(
                    "baseline provenance is `{p}`: gated metrics [{}] are compared against \
                     modeled (not measured) reference values — re-record the baseline on this \
                     machine class to make the gate authoritative",
                    gated.join(", ")
                ));
            }
        }
    }
    report
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64() * 1e3);
    }
    summarize(name, &times)
}

/// Auto-calibrating variant: picks an iteration count so total measured
/// time is ≈ `budget_ms` (criterion-style), with at least `min_iters`.
pub fn bench_auto<F: FnMut()>(name: &str, budget_ms: f64, min_iters: usize, mut f: F) -> BenchResult {
    let t = Instant::now();
    f();
    let probe_ms = (t.elapsed().as_secs_f64() * 1e3).max(1e-6);
    let iters = ((budget_ms / probe_ms) as usize).clamp(min_iters, 10_000);
    bench(name, (iters / 10).max(1), iters, f)
}

fn summarize(name: &str, times: &[f64]) -> BenchResult {
    let n = times.len() as f64;
    let mean = times.iter().sum::<f64>() / n;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
    BenchResult {
        name: name.to_string(),
        mean_ms: mean,
        std_ms: var.sqrt(),
        min_ms: times.iter().cloned().fold(f64::INFINITY, f64::min),
        iters: times.len(),
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Achieved bandwidth for a kernel that moved `bytes` in `mean_ms`
/// milliseconds — the roofline companion to
/// `model::matvec::weight_traffic_bytes`: memory-bound kernels are judged
/// against GB/s, not just speedup (a 4-bit kernel at the same GB/s as the
/// f32 kernel IS the paper's ~8× win; a faster-than-f32 kernel that is
/// far below peak bandwidth still has headroom).
pub fn achieved_gbps(bytes: usize, mean_ms: f64) -> f64 {
    bytes as f64 / (mean_ms.max(1e-12) * 1e-3) / 1e9
}

/// A measured streaming-bandwidth ceiling for roofline reporting.
#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    /// best observed read bandwidth of a cache-busting sequential sweep
    pub peak_gbps: f64,
}

impl Roofline {
    /// Measure single-thread streaming read bandwidth: sum-reduce a
    /// 64 MiB f32 buffer (far past LLC) with 8 independent accumulators,
    /// best of 3 sweeps. This is the per-core roofline the decode-path
    /// kernels are bounded by; it is a measurement, so only benches call
    /// it (never tests).
    pub fn measure() -> Roofline {
        const N: usize = 16 << 20; // 16 Mi f32 = 64 MiB
        let buf = vec![1.0f32; N];
        let mut best_s = f64::INFINITY;
        for _ in 0..3 {
            let t = Instant::now();
            let mut acc = [0.0f32; 8];
            for chunk in buf.chunks_exact(8) {
                for (a, &v) in acc.iter_mut().zip(chunk) {
                    *a += v;
                }
            }
            black_box(acc);
            best_s = best_s.min(t.elapsed().as_secs_f64());
        }
        Roofline { peak_gbps: (N * 4) as f64 / best_s.max(1e-12) / 1e9 }
    }

    /// Fraction of the measured peak an achieved bandwidth reaches
    /// (>1.0 means the working set was cache-resident).
    pub fn fraction(&self, gbps: f64) -> f64 {
        gbps / self.peak_gbps.max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let r = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..50_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            black_box(acc);
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_ms > 0.0);
        assert!(r.min_ms <= r.mean_ms);
    }

    #[test]
    fn bench_auto_scales_iters() {
        let r = bench_auto("noop", 5.0, 3, || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 3);
    }

    #[test]
    fn achieved_gbps_math() {
        // 1 GB in 1 s = 1 GB/s; 8 bytes in 1 µs (0.001 ms) = 8 MB/ms = 0.008 GB/s
        assert!((achieved_gbps(1_000_000_000, 1000.0) - 1.0).abs() < 1e-9);
        assert!((achieved_gbps(8, 0.001) - 0.008).abs() < 1e-9);
        let r = Roofline { peak_gbps: 10.0 };
        assert!((r.fraction(5.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn bench_json_roundtrips() {
        let r = bench("probe", 0, 2, || {
            black_box(1 + 1);
        });
        let path = std::env::temp_dir().join("gptq_bench_json_test.json");
        let path_s = path.to_string_lossy().into_owned();
        let machine = MachineClass::detect();
        write_bench_json(
            &path_s,
            "decode",
            &machine,
            vec![r.to_json()],
            vec![("speedup", Json::Num(2.0))],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("decode"));
        assert_eq!(doc.get("results").and_then(Json::as_arr).map(|a| a.len()), Some(1));
        let summary = doc.get("summary").unwrap();
        assert_eq!(summary.get("speedup").and_then(Json::as_f64), Some(2.0));
        let first = &doc.get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(first.get("name").and_then(Json::as_str), Some("probe"));
        assert_eq!(first.get("iters").and_then(Json::as_usize), Some(2));
        // and the perfgate view of the same file
        let bd = BenchDoc::parse(&text).unwrap();
        assert_eq!(bd.bench, "decode");
        assert_eq!(bd.machine.as_ref().map(|m| m.key()), Some(machine.key()));
        assert_eq!(bd.metric("speedup"), Some(2.0));
    }

    #[test]
    fn machine_class_json_roundtrip() {
        let m = MachineClass { arch: "x86_64".into(), isa: "avx2".into(), cores: 8 };
        assert_eq!(m.key(), "x86_64/avx2/8");
        assert_eq!(MachineClass::from_json(&m.to_json()), Some(m.clone()));
        assert_eq!(format!("{m}"), "x86_64/avx2/8");
        // detect() must yield a non-empty class on any machine
        let d = MachineClass::detect();
        assert!(!d.arch.is_empty() && !d.isa.is_empty() && d.cores >= 1);
    }

    #[test]
    fn glob_patterns() {
        assert!(glob_match("tokens_per_s_*", "tokens_per_s_3bit_t1"));
        assert!(glob_match("speedup_4bit_b16_*_over_scalar", "speedup_4bit_b16_avx2_over_scalar"));
        assert!(!glob_match("speedup_4bit_b16_*_over_scalar", "speedup_4bit_b16_avx2"));
        assert!(glob_match("*_prefill_tokens_saved", "shared_prefix_k4_prefill_tokens_saved"));
        assert!(glob_match("peak_gbps*", "peak_gbps"));
        assert!(glob_match("peak_gbps*", "peak_gbps_t1"));
        assert!(!glob_match("ms_per_layer_*", "tokens_per_s_f32_t1"));
        assert!(glob_match("*", "anything"));
        assert!(!glob_match("", "x") && glob_match("", ""));
    }

    fn doc(bench: &str, isa: &str, metrics: &[(&str, f64)]) -> BenchDoc {
        BenchDoc {
            bench: bench.to_string(),
            machine: Some(MachineClass { arch: "x86_64".into(), isa: isa.into(), cores: 4 }),
            provenance: None,
            metrics: metrics.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        }
    }

    #[test]
    fn provenance_parses_and_modeled_baseline_warns() {
        let text = r#"{"bench":"kernels","provenance":"modeled (sparse24 rows)","machine":{"arch":"x86_64","isa":"avx2","cores":4},"results":[],"summary":{"sparse24_speedup_4bit_b1_avx2_over_dense":1.6,"some_counter":5}}"#;
        let base = BenchDoc::parse(text).unwrap();
        assert_eq!(base.provenance.as_deref(), Some("modeled (sparse24 rows)"));
        let mut cur = base.clone();
        cur.provenance = None;
        let specs = default_specs("kernels");
        let r = compare(&base, &cur, &specs);
        // warning lists the gated key, skips the unspecced counter, and
        // does NOT fail the gate
        assert!(r.passed(), "{}", r.render());
        assert_eq!(r.warnings.len(), 1);
        assert!(r.warnings[0].contains("sparse24_speedup_4bit_b1_avx2_over_dense"));
        assert!(!r.warnings[0].contains("some_counter"));
        assert!(r.render().contains("WARN"));
        // a measured baseline produces no warning
        let r2 = compare(&cur, &cur, &specs);
        assert!(r2.warnings.is_empty());
    }

    #[test]
    fn sparse24_specs_gate_the_13x_floor() {
        let specs = default_specs("kernels");
        let base = doc("kernels", "avx2", &[("sparse24_speedup_4bit_b1_avx2_over_dense", 1.6)]);
        // 1.35x is within the 0.19 band of the 1.6 modeled baseline
        let ok = doc("kernels", "avx2", &[("sparse24_speedup_4bit_b1_avx2_over_dense", 1.35)]);
        assert!(compare(&base, &ok, &specs).passed());
        // 1.25x is below the ~1.3x floor -> regression
        let slow = doc("kernels", "avx2", &[("sparse24_speedup_4bit_b1_avx2_over_dense", 1.25)]);
        assert_eq!(compare(&base, &slow, &specs).regressions(), 1);
    }

    #[test]
    fn spec_decode_specs_gate_the_12x_floor() {
        let specs = default_specs("serve");
        let base = doc("serve", "avx2", &[("spec_k4_speedup_vs_greedy", 1.35)]);
        // 1.21x is within the 0.11 band of the 1.35 modeled baseline
        let ok = doc("serve", "avx2", &[("spec_k4_speedup_vs_greedy", 1.21)]);
        assert!(compare(&base, &ok, &specs).passed());
        // 1.15x is below the ~1.2x acceptance floor -> regression
        let slow = doc("serve", "avx2", &[("spec_k4_speedup_vs_greedy", 1.15)]);
        assert_eq!(compare(&base, &slow, &specs).regressions(), 1);
    }

    #[test]
    fn compare_flags_20pct_regression_and_passes_noise() {
        let specs = default_specs("decode");
        let base = doc("decode", "avx2", &[("tokens_per_s_4bit_t1", 1000.0), ("ms_per_layer_4bit_t1", 1.0)]);
        // 20% tokens/s drop: beyond the 15% band -> regression, nonzero report
        let bad = doc("decode", "avx2", &[("tokens_per_s_4bit_t1", 800.0), ("ms_per_layer_4bit_t1", 1.0)]);
        let r = compare(&base, &bad, &specs);
        assert!(!r.passed());
        assert_eq!(r.regressions(), 1);
        assert!(r.render().contains("REGRESSED") && r.render().contains("tokens_per_s_4bit_t1"));
        // 3% noise either way stays inside the band
        let noisy = doc("decode", "avx2", &[("tokens_per_s_4bit_t1", 970.0), ("ms_per_layer_4bit_t1", 1.03)]);
        let r = compare(&base, &noisy, &specs);
        assert!(r.passed(), "{}", r.render());
        assert!(r.lines.iter().all(|l| l.status == MetricStatus::Pass));
        // a 30% improvement passes (and is labeled as such)
        let better = doc("decode", "avx2", &[("tokens_per_s_4bit_t1", 1300.0), ("ms_per_layer_4bit_t1", 0.7)]);
        let r = compare(&base, &better, &specs);
        assert!(r.passed());
        assert!(r.lines.iter().all(|l| l.status == MetricStatus::Improved));
    }

    #[test]
    fn compare_latency_direction() {
        // lower-is-better: a 20% ms/layer INCREASE is the regression
        let specs = default_specs("decode");
        let base = doc("decode", "avx2", &[("ms_per_layer_3bit_t1", 1.0)]);
        let slow = doc("decode", "avx2", &[("ms_per_layer_3bit_t1", 1.2)]);
        assert_eq!(compare(&base, &slow, &specs).regressions(), 1);
        let fast = doc("decode", "avx2", &[("ms_per_layer_3bit_t1", 0.8)]);
        assert!(compare(&base, &fast, &specs).passed());
    }

    #[test]
    fn compare_key_mismatches_are_errors_not_panics() {
        let specs = default_specs("decode");
        let base = doc("decode", "avx2", &[("tokens_per_s_4bit_t1", 1000.0), ("peak_gbps_t1", 10.0)]);
        // missing key in current
        let missing = doc("decode", "avx2", &[("tokens_per_s_4bit_t1", 1000.0)]);
        let r = compare(&base, &missing, &specs);
        assert!(!r.passed() && r.errors.iter().any(|e| e.contains("peak_gbps_t1")));
        // extra key in current
        let extra = doc(
            "decode",
            "avx2",
            &[("tokens_per_s_4bit_t1", 1000.0), ("peak_gbps_t1", 10.0), ("novel_metric", 1.0)],
        );
        let r = compare(&base, &extra, &specs);
        assert!(!r.passed() && r.errors.iter().any(|e| e.contains("novel_metric")));
        // machine-class mismatch
        let other_isa = doc("decode", "neon", &[("tokens_per_s_4bit_t1", 1000.0), ("peak_gbps_t1", 10.0)]);
        let r = compare(&base, &other_isa, &specs);
        assert!(!r.passed() && r.errors.iter().any(|e| e.contains("machine-class mismatch")));
        // absent machine header
        let mut no_machine = base.clone();
        no_machine.machine = None;
        let r = compare(&base, &no_machine, &specs);
        assert!(!r.passed() && r.errors.iter().any(|e| e.contains("machine-class")));
    }

    #[test]
    fn compare_unspecced_metric_is_reported_not_gated() {
        let specs = default_specs("kernels");
        let base = doc("kernels", "avx2", &[("some_unknown_counter", 5.0)]);
        let cur = doc("kernels", "avx2", &[("some_unknown_counter", 1.0)]);
        let r = compare(&base, &cur, &specs);
        assert!(r.passed());
        assert_eq!(r.lines[0].status, MetricStatus::Skipped);
    }

    #[test]
    fn deterministic_counters_have_zero_band() {
        let specs = default_specs("serve");
        let base = doc("serve", "avx2", &[("shared_prefix_k4_prefill_tokens_saved", 1344.0)]);
        let same = doc("serve", "avx2", &[("shared_prefix_k4_prefill_tokens_saved", 1344.0)]);
        assert!(compare(&base, &same, &specs).passed());
        let fewer = doc("serve", "avx2", &[("shared_prefix_k4_prefill_tokens_saved", 1200.0)]);
        assert_eq!(compare(&base, &fewer, &specs).regressions(), 1);
    }

    #[test]
    fn kv_capacity_metrics_are_gated() {
        let specs = default_specs("serve");
        let base = doc(
            "serve",
            "avx2",
            &[
                ("kv_fixed_bytes_peak_seqs_q8", 21.0),
                ("kv_q8_capacity_ratio", 2.6),
                ("kv_q8_token_agreement", 0.98),
                ("kv_fixed_bytes_preemptions_f32", 9.0),
            ],
        );
        // losing >5% of greedy agreement is a quantization-quality bug
        let drifted = doc(
            "serve",
            "avx2",
            &[
                ("kv_fixed_bytes_peak_seqs_q8", 21.0),
                ("kv_q8_capacity_ratio", 2.6),
                ("kv_q8_token_agreement", 0.90),
                ("kv_fixed_bytes_preemptions_f32", 9.0),
            ],
        );
        let r = compare(&base, &drifted, &specs);
        assert_eq!(r.regressions(), 1);
        assert!(r.render().contains("kv_q8_token_agreement"));
        // capacity halving back toward f32 fails the ratio gate; raw
        // preemption counts are informational only
        let shrunk = doc(
            "serve",
            "avx2",
            &[
                ("kv_fixed_bytes_peak_seqs_q8", 9.0),
                ("kv_q8_capacity_ratio", 1.1),
                ("kv_q8_token_agreement", 0.98),
                ("kv_fixed_bytes_preemptions_f32", 40.0),
            ],
        );
        let r = compare(&base, &shrunk, &specs);
        assert_eq!(r.regressions(), 2);
        let preempt_line = r
            .lines
            .iter()
            .find(|l| l.name == "kv_fixed_bytes_preemptions_f32")
            .unwrap();
        assert_eq!(preempt_line.status, MetricStatus::Skipped);
    }
}
