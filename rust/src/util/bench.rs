//! Micro-benchmark harness (the criterion stand-in): warmup, repeated
//! timed runs, mean / stddev / min, aligned table printing for the
//! paper-table benches, and JSON recording (`BENCH_*.json`,
//! EXPERIMENTS.md §Benches) so the perf trajectory is tracked in-repo.

use crate::util::json::Json;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10.4} ms ±{:>8.4}  (min {:>10.4}, n={})",
            self.name, self.mean_ms, self.std_ms, self.min_ms, self.iters
        )
    }

    /// JSON form for the `BENCH_*.json` perf-trajectory records.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("mean_ms", Json::Num(self.mean_ms)),
            ("std_ms", Json::Num(self.std_ms)),
            ("min_ms", Json::Num(self.min_ms)),
            ("iters", Json::Num(self.iters as f64)),
        ])
    }
}

/// Write a bench record (`{bench, results: […], summary: {…}}`) to
/// `path`. The `make bench` targets use this to produce
/// `BENCH_decode.json` / `BENCH_quantize.json` (EXPERIMENTS.md §Benches).
pub fn write_bench_json(
    path: &str,
    bench: &str,
    results: Vec<Json>,
    summary: Vec<(&str, Json)>,
) -> std::io::Result<()> {
    let doc = Json::obj(vec![
        ("bench", Json::Str(bench.to_string())),
        ("results", Json::Arr(results)),
        ("summary", Json::obj(summary)),
    ]);
    std::fs::write(path, doc.to_string())
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64() * 1e3);
    }
    summarize(name, &times)
}

/// Auto-calibrating variant: picks an iteration count so total measured
/// time is ≈ `budget_ms` (criterion-style), with at least `min_iters`.
pub fn bench_auto<F: FnMut()>(name: &str, budget_ms: f64, min_iters: usize, mut f: F) -> BenchResult {
    let t = Instant::now();
    f();
    let probe_ms = (t.elapsed().as_secs_f64() * 1e3).max(1e-6);
    let iters = ((budget_ms / probe_ms) as usize).clamp(min_iters, 10_000);
    bench(name, (iters / 10).max(1), iters, f)
}

fn summarize(name: &str, times: &[f64]) -> BenchResult {
    let n = times.len() as f64;
    let mean = times.iter().sum::<f64>() / n;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
    BenchResult {
        name: name.to_string(),
        mean_ms: mean,
        std_ms: var.sqrt(),
        min_ms: times.iter().cloned().fold(f64::INFINITY, f64::min),
        iters: times.len(),
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Achieved bandwidth for a kernel that moved `bytes` in `mean_ms`
/// milliseconds — the roofline companion to
/// `model::matvec::weight_traffic_bytes`: memory-bound kernels are judged
/// against GB/s, not just speedup (a 4-bit kernel at the same GB/s as the
/// f32 kernel IS the paper's ~8× win; a faster-than-f32 kernel that is
/// far below peak bandwidth still has headroom).
pub fn achieved_gbps(bytes: usize, mean_ms: f64) -> f64 {
    bytes as f64 / (mean_ms.max(1e-12) * 1e-3) / 1e9
}

/// A measured streaming-bandwidth ceiling for roofline reporting.
#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    /// best observed read bandwidth of a cache-busting sequential sweep
    pub peak_gbps: f64,
}

impl Roofline {
    /// Measure single-thread streaming read bandwidth: sum-reduce a
    /// 64 MiB f32 buffer (far past LLC) with 8 independent accumulators,
    /// best of 3 sweeps. This is the per-core roofline the decode-path
    /// kernels are bounded by; it is a measurement, so only benches call
    /// it (never tests).
    pub fn measure() -> Roofline {
        const N: usize = 16 << 20; // 16 Mi f32 = 64 MiB
        let buf = vec![1.0f32; N];
        let mut best_s = f64::INFINITY;
        for _ in 0..3 {
            let t = Instant::now();
            let mut acc = [0.0f32; 8];
            for chunk in buf.chunks_exact(8) {
                for (a, &v) in acc.iter_mut().zip(chunk) {
                    *a += v;
                }
            }
            black_box(acc);
            best_s = best_s.min(t.elapsed().as_secs_f64());
        }
        Roofline { peak_gbps: (N * 4) as f64 / best_s.max(1e-12) / 1e9 }
    }

    /// Fraction of the measured peak an achieved bandwidth reaches
    /// (>1.0 means the working set was cache-resident).
    pub fn fraction(&self, gbps: f64) -> f64 {
        gbps / self.peak_gbps.max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let r = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..50_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            black_box(acc);
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_ms > 0.0);
        assert!(r.min_ms <= r.mean_ms);
    }

    #[test]
    fn bench_auto_scales_iters() {
        let r = bench_auto("noop", 5.0, 3, || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 3);
    }

    #[test]
    fn achieved_gbps_math() {
        // 1 GB in 1 s = 1 GB/s; 8 bytes in 1 µs (0.001 ms) = 8 MB/ms = 0.008 GB/s
        assert!((achieved_gbps(1_000_000_000, 1000.0) - 1.0).abs() < 1e-9);
        assert!((achieved_gbps(8, 0.001) - 0.008).abs() < 1e-9);
        let r = Roofline { peak_gbps: 10.0 };
        assert!((r.fraction(5.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn bench_json_roundtrips() {
        let r = bench("probe", 0, 2, || {
            black_box(1 + 1);
        });
        let path = std::env::temp_dir().join("gptq_bench_json_test.json");
        let path_s = path.to_string_lossy().into_owned();
        write_bench_json(
            &path_s,
            "decode",
            vec![r.to_json()],
            vec![("speedup", Json::Num(2.0))],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("decode"));
        assert_eq!(doc.get("results").and_then(Json::as_arr).map(|a| a.len()), Some(1));
        let summary = doc.get("summary").unwrap();
        assert_eq!(summary.get("speedup").and_then(Json::as_f64), Some(2.0));
        let first = &doc.get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(first.get("name").and_then(Json::as_str), Some("probe"));
        assert_eq!(first.get("iters").and_then(Json::as_usize), Some(2));
    }
}
