//! Micro-benchmark harness (the criterion stand-in): warmup, repeated
//! timed runs, mean / stddev / min, and aligned table printing for the
//! paper-table benches.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10.4} ms ±{:>8.4}  (min {:>10.4}, n={})",
            self.name, self.mean_ms, self.std_ms, self.min_ms, self.iters
        )
    }
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64() * 1e3);
    }
    summarize(name, &times)
}

/// Auto-calibrating variant: picks an iteration count so total measured
/// time is ≈ `budget_ms` (criterion-style), with at least `min_iters`.
pub fn bench_auto<F: FnMut()>(name: &str, budget_ms: f64, min_iters: usize, mut f: F) -> BenchResult {
    let t = Instant::now();
    f();
    let probe_ms = (t.elapsed().as_secs_f64() * 1e3).max(1e-6);
    let iters = ((budget_ms / probe_ms) as usize).clamp(min_iters, 10_000);
    bench(name, (iters / 10).max(1), iters, f)
}

fn summarize(name: &str, times: &[f64]) -> BenchResult {
    let n = times.len() as f64;
    let mean = times.iter().sum::<f64>() / n;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
    BenchResult {
        name: name.to_string(),
        mean_ms: mean,
        std_ms: var.sqrt(),
        min_ms: times.iter().cloned().fold(f64::INFINITY, f64::min),
        iters: times.len(),
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let r = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..50_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            black_box(acc);
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_ms > 0.0);
        assert!(r.min_ms <= r.mean_ms);
    }

    #[test]
    fn bench_auto_scales_iters() {
        let r = bench_auto("noop", 5.0, 3, || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 3);
    }
}
