//! Minimal JSON: a recursive-descent parser and a writer. Covers the full
//! JSON grammar (objects, arrays, strings with escapes, numbers, bools,
//! null) — enough for the artifact manifest, task files, and checkpoint
//! headers. Object key order is preserved (Vec-backed) so serialization
//! is deterministic.

use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // -- accessors -----------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest ergonomics.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_u32(&self) -> Option<u32> {
        self.as_f64().map(|f| f as u32)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Convenience: array of usize.
    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    pub fn f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?.iter().map(|j| j.as_f64().map(|f| f as f32)).collect()
    }

    // -- construction helpers -------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_str(v: &[String]) -> Json {
        Json::Arr(v.iter().map(|s| Json::Str(s.clone())).collect())
    }

    // -- parse ----------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(v)
    }

    // -- write ----------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_string(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("invalid literal at byte {pos}", pos = *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut s = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(s);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                match b[*pos] {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err("truncated \\u escape".into());
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape")?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    c => return Err(format!("bad escape \\{}", c as char)),
                }
                *pos += 1;
            }
            _ => {
                // consume one UTF-8 scalar
                let rest = std::str::from_utf8(&b[*pos..]).map_err(|_| "invalid utf8")?;
                let c = rest.chars().next().unwrap();
                s.push(c);
                *pos += c.len_utf8();
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // [
    let mut items = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(format!("expected , or ] at byte {pos}", pos = *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // {
    let mut pairs = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(pairs));
    }
    loop {
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            return Err(format!("expected key at byte {pos}", pos = *pos));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected : at byte {pos}", pos = *pos));
        }
        *pos += 1;
        pairs.push((key, parse_value(b, pos)?));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(pairs));
            }
            _ => return Err(format!("expected , or }} at byte {pos}", pos = *pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true, "e": null}}"#)
            .unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(j.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(j.get("b").unwrap().get("d").unwrap().as_bool(), Some(true));
        assert_eq!(*j.get("b").unwrap().get("e").unwrap(), Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = Json::obj(vec![
            ("name", Json::Str("quoted \"str\"\twith escapes".into())),
            ("vals", Json::arr_usize(&[1, 2, 3])),
            ("pi", Json::Num(3.25)),
            ("ok", Json::Bool(false)),
        ]);
        let text = src.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(src, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""éA""#).unwrap();
        assert_eq!(j.as_str(), Some("éA"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let j = Json::parse(
            r#"{"version": 1, "models": {"nano": {"config": {"d_model": 64}}},
                "artifacts": {"lm_fwd_nano": {"file": "hlo/x.txt", "params": [[8, 128]]}}}"#,
        )
        .unwrap();
        let p = j.get("artifacts").unwrap().get("lm_fwd_nano").unwrap();
        assert_eq!(p.get("params").unwrap().as_arr().unwrap()[0].usize_vec().unwrap(), vec![8, 128]);
    }
}
