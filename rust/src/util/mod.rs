//! Self-contained substrate the offline environment forces us to carry:
//! a JSON parser/writer ([`json`]), a small CLI argument parser ([`cli`]),
//! a criterion-style micro-benchmark harness ([`bench`]), and a scoped
//! thread pool ([`par`], the rayon stand-in).

pub mod bench;
pub mod cli;
pub mod json;
pub mod par;

pub use json::Json;
