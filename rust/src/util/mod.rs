//! Self-contained substrate the offline environment forces us to carry:
//! a JSON parser/writer ([`json`]), a small CLI argument parser ([`cli`]),
//! and a criterion-style micro-benchmark harness ([`bench`]).

pub mod bench;
pub mod cli;
pub mod json;

pub use json::Json;
