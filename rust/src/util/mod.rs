//! Self-contained substrate the offline environment forces us to carry:
//! a JSON parser/writer ([`json`]), a small CLI argument parser ([`cli`]),
//! a criterion-style micro-benchmark harness ([`bench`]), a scoped
//! thread pool ([`par`], the rayon stand-in), and the deterministic
//! fault-injection harness for chaos testing ([`faultinject`]).

pub mod bench;
pub mod cli;
pub mod faultinject;
pub mod json;
pub mod par;

pub use json::Json;
