//! Byte-level corpus files written by `python/compile/corpus.py`.

use crate::Result;
use std::path::Path;

/// An in-memory byte corpus (vocab = 256, bytes are tokens).
#[derive(Debug, Clone)]
pub struct CorpusFile {
    pub bytes: Vec<u8>,
    pub name: String,
}

/// The three evaluation styles, mirroring the paper's WikiText2/PTB/C4.
pub const EVAL_STYLES: [&str; 3] = ["narrative", "markup", "crawl"];

impl CorpusFile {
    pub fn load(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("corpus {} missing (run `make artifacts`): {e}", path.display()))?;
        anyhow::ensure!(!bytes.is_empty(), "empty corpus {}", path.display());
        Ok(Self {
            bytes,
            name: path.file_stem().unwrap().to_string_lossy().into_owned(),
        })
    }

    /// Non-overlapping evaluation segments of `seq_len + 1` bytes (inputs
    /// plus next-byte targets), like the paper's stride-2048 perplexity
    /// protocol.
    pub fn eval_segments(&self, seq_len: usize, max_segments: usize) -> Vec<&[u8]> {
        self.bytes
            .chunks_exact(seq_len + 1)
            .take(max_segments)
            .collect()
    }

    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_segments_shapes() {
        let c = CorpusFile { bytes: (0..=255u8).cycle().take(1000).collect(), name: "t".into() };
        let segs = c.eval_segments(99, 100);
        assert_eq!(segs.len(), 10);
        assert!(segs.iter().all(|s| s.len() == 100));
        // non-overlapping
        assert_eq!(segs[1][0], c.bytes[100]);
    }

    #[test]
    fn eval_segments_capped() {
        let c = CorpusFile { bytes: vec![0; 1000], name: "t".into() };
        assert_eq!(c.eval_segments(9, 3).len(), 3);
    }
}
