//! Data substrate: corpus access, calibration sampling (the paper's "128
//! random 2048-token segments from C4" at our scale), and the zero-shot
//! task files produced by the build-time generator.

pub mod calib;
pub mod corpus;
pub mod tasks;

pub use calib::{batch_segments, sample_calibration};
pub use corpus::CorpusFile;
pub use tasks::{load_tasks, TaskItem};

/// Deterministic xoshiro-ish RNG used for all sampling in this crate —
/// no external randomness so every table regenerates identically.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self(seed.max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        // splitmix64
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// uniform integer in [0, bound)
    pub fn below(&mut self, bound: usize) -> usize {
        (self.next_u64() % bound as u64) as usize
    }

    /// uniform f32 in [-1, 1)
    pub fn unit(&mut self) -> f32 {
        // top 24 bits -> [0, 1) -> [-1, 1)
        ((self.next_u64() >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn rng_unit_in_range() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let vals: Vec<f32> = (0..n).map(|_| r.unit()).collect();
        assert!(vals.iter().all(|&v| (-1.0..1.0).contains(&v)));
        let mean: f32 = vals.iter().sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
    }
}
