//! Calibration sampling — the paper's §4 Setup: "128 random 2048-token
//! segments" of generic crawl text; at our scale, `n_segments` random
//! `seq_len`-byte windows of `calib.bin`.

use super::corpus::CorpusFile;
use super::Rng;

/// Draw `n_segments` random windows of `seq_len` bytes. Deterministic in
/// `seed` (the whole pipeline is reproducible end-to-end).
pub fn sample_calibration(
    corpus: &CorpusFile,
    n_segments: usize,
    seq_len: usize,
    seed: u64,
) -> Vec<Vec<u8>> {
    assert!(corpus.len() > seq_len, "calibration corpus shorter than seq_len");
    let mut rng = Rng::new(seed);
    (0..n_segments)
        .map(|_| {
            let start = rng.below(corpus.len() - seq_len);
            corpus.bytes[start..start + seq_len].to_vec()
        })
        .collect()
}

/// Group segments into batches of `batch` (the shape of the capture/
/// hessian artifacts: (batch × seq_len) token blocks).
pub fn batch_segments(segments: &[Vec<u8>], batch: usize) -> Vec<Vec<i32>> {
    segments
        .chunks(batch)
        .filter(|c| c.len() == batch)
        .map(|chunk| {
            chunk
                .iter()
                .flat_map(|seg| seg.iter().map(|&b| b as i32))
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> CorpusFile {
        CorpusFile { bytes: (0..10_000).map(|i| (i % 251) as u8).collect(), name: "c".into() }
    }

    #[test]
    fn sampling_deterministic() {
        let c = corpus();
        let a = sample_calibration(&c, 8, 128, 42);
        let b = sample_calibration(&c, 8, 128, 42);
        assert_eq!(a, b);
        let c2 = sample_calibration(&c, 8, 128, 43);
        assert_ne!(a, c2);
    }

    #[test]
    fn segments_have_requested_length() {
        let c = corpus();
        for seg in sample_calibration(&c, 16, 64, 1) {
            assert_eq!(seg.len(), 64);
        }
    }

    #[test]
    fn batching_drops_ragged_tail() {
        let c = corpus();
        let segs = sample_calibration(&c, 10, 32, 1);
        let batches = batch_segments(&segs, 4);
        assert_eq!(batches.len(), 2); // 10/4 -> 2 full batches
        assert_eq!(batches[0].len(), 4 * 32);
    }
}
