//! Zero-shot task files (`artifacts/corpus/tasks/*.jsonl`) — the LAMBADA /
//! ARC / PIQA / StoryCloze analogs produced by the build-time generator.

use crate::util::Json;
use crate::Result;
use anyhow::{anyhow, ensure, Context};
use std::path::Path;

/// One task item. `cloze` items carry a `target`; choice items carry
/// `choices` + `answer`.
#[derive(Debug, Clone)]
pub struct TaskItem {
    pub context: String,
    pub target: Option<String>,
    pub choices: Vec<String>,
    pub answer: usize,
}

impl TaskItem {
    fn from_json(j: &Json) -> Option<Self> {
        Some(Self {
            context: j.get("context")?.as_str()?.to_string(),
            target: j.get("target").and_then(|t| t.as_str()).map(String::from),
            choices: j
                .get("choices")
                .and_then(|c| c.as_arr())
                .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                .unwrap_or_default(),
            answer: j.get("answer").and_then(|a| a.as_usize()).unwrap_or(0),
        })
    }
}

pub fn load_tasks(path: &Path) -> Result<Vec<TaskItem>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("task file {} missing", path.display()))?;
    let mut items = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| anyhow!("{}:{}: {e}", path.display(), i + 1))?;
        items.push(
            TaskItem::from_json(&j).ok_or_else(|| anyhow!("{}:{}: bad item", path.display(), i + 1))?,
        );
    }
    ensure!(!items.is_empty(), "no tasks in {}", path.display());
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_jsonl() {
        let tmp = std::env::temp_dir().join("gptq_tasks_test.jsonl");
        std::fs::write(
            &tmp,
            r#"{"context": "abc", "target": " d"}
{"context": "xyz", "choices": [" a", " b"], "answer": 1}
"#,
        )
        .unwrap();
        let items = load_tasks(&tmp).unwrap();
        std::fs::remove_file(&tmp).ok();
        assert_eq!(items.len(), 2);
        assert_eq!(items[0].target.as_deref(), Some(" d"));
        assert_eq!(items[1].answer, 1);
        assert_eq!(items[1].choices.len(), 2);
    }

    #[test]
    fn missing_file_is_error() {
        assert!(load_tasks(Path::new("/nonexistent/t.jsonl")).is_err());
    }
}
