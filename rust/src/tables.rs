//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md experiment index maps each to its paper counterpart).
//!
//! ```text
//! tables table1     RTN/OBQ/GPTQ accuracy comparison      (paper Tables 1 & 7)
//! tables fig1       PPL vs model size, GPTQ vs RTN        (paper Figure 1)
//! tables table2     PPL on the PTB-analog corpus          (paper Tables 2–3)
//! tables fig3       quantization runtime scaling          (paper Figure 3, Tables 8–9)
//! tables table4     largest-model summary                 (paper Table 4)
//! tables table5     per-token latency + memory            (paper Table 5)
//! tables table6     2-bit group-size sweep                (paper Table 6)
//! tables fig4       zero-shot accuracy                    (paper Figure 4, Tables 14–23)
//! tables ablations  order/Cholesky/damping/propagation    (paper §3.3 design choices)
//! tables sparse     joint sparsify+quantize comparison    (SparseGPT-style follow-up)
//! tables all        everything above
//! ```
//!
//! Flags: `--sizes nano,micro,small` `--segments N` `--calib N`
//! `--sparsity none|unstructured50|2of4` (or `GPTQ_SPARSITY`; applies the
//! regime to every GPTQ solve — RTN/OBQ baselines stay dense).
//! Absolute numbers are testbed-specific; the *shape* (who wins, by what
//! factor, where RTN collapses) is the reproduction target.

use crate::coordinator::{PipelineConfig, QuantEngine, QuantPipeline};
use crate::data::{load_tasks, CorpusFile};
use crate::eval::{eval_choice, eval_cloze, perplexity};
use crate::model::{Checkpoint, CpuModel, KvCache, QuantizedCheckpoint};
use crate::quant::{self, gptq_quantize, obq_quantize, GptqConfig, Order, Sparsity};
use crate::runtime::Runtime;
use crate::util::cli::Args;
use crate::Result;
use std::collections::HashMap;
use std::time::Instant;

pub struct Ctx {
    rt: Runtime,
    sizes: Vec<String>,
    segments: usize,
    calib_segments: usize,
    /// sparsity regime for GPTQ solves (`--sparsity` / `GPTQ_SPARSITY`)
    sparsity: Sparsity,
    /// (size, bits, groupsize, engine-tag, sparsity-tag) -> quantized
    /// checkpoint + runtime
    cache: HashMap<(String, u32, usize, &'static str, &'static str), (QuantizedCheckpoint, f64)>,
}

impl Ctx {
    fn new(args: &Args) -> Result<Self> {
        let rt = Runtime::from_artifacts_dir(&crate::artifacts_dir())?;
        let all: Vec<String> = rt.manifest.models.keys().cloned().collect();
        let sizes: Vec<String> = match args.get("sizes") {
            Some(s) => s.split(',').map(String::from).filter(|s| !s.is_empty()).collect(),
            None => all,
        };
        let sparsity = match args.get("sparsity") {
            Some(s) => Sparsity::parse(s).ok_or_else(|| {
                anyhow::anyhow!("unknown --sparsity {s:?} (none|unstructured50|2of4)")
            })?,
            None => Sparsity::from_env(),
        };
        Ok(Self {
            rt,
            sizes,
            segments: args.usize_or("segments", 16),
            calib_segments: args.usize_or("calib", 32),
            sparsity,
            cache: HashMap::new(),
        })
    }

    fn fp_model(&self, size: &str) -> Result<CpuModel> {
        let entry = self.rt.manifest.model(size)?.clone();
        Ok(CpuModel::from_checkpoint(&Checkpoint::load(&crate::artifacts_dir(), &entry)?))
    }

    fn engine_tag(e: QuantEngine) -> &'static str {
        match e {
            QuantEngine::GptqRust => "gptq",
            QuantEngine::GptqArtifact => "gptq-artifact",
            QuantEngine::Rtn => "rtn",
            QuantEngine::Obq => "obq",
        }
    }

    /// Quantize (cached) and return (checkpoint, pipeline seconds). The
    /// `--sparsity` regime applies to GPTQ solves; RTN/OBQ rows stay dense
    /// (the joint mask selection lives in the Cholesky solver).
    fn quantized(
        &mut self,
        size: &str,
        bits: u32,
        groupsize: usize,
        engine: QuantEngine,
    ) -> Result<(QuantizedCheckpoint, f64)> {
        let sp = if engine == QuantEngine::GptqRust { self.sparsity } else { Sparsity::None };
        self.quantized_sparse(size, bits, groupsize, engine, sp)
    }

    fn quantized_sparse(
        &mut self,
        size: &str,
        bits: u32,
        groupsize: usize,
        engine: QuantEngine,
        sparsity: Sparsity,
    ) -> Result<(QuantizedCheckpoint, f64)> {
        let key = (size.to_string(), bits, groupsize, Self::engine_tag(engine), sparsity.name());
        if let Some(v) = self.cache.get(&key) {
            return Ok(v.clone_pair());
        }
        let entry = self.rt.manifest.model(size)?.clone();
        let mut ckpt = Checkpoint::load(&crate::artifacts_dir(), &entry)?;
        let calib = CorpusFile::load(&self.rt.manifest.corpus_path("calib.bin"))?;
        let mut cfg =
            PipelineConfig::new(bits, engine).with_groupsize(groupsize).with_sparsity(sparsity);
        cfg.n_calib_segments = self.calib_segments;
        let report = QuantPipeline::new(&mut self.rt, size, cfg).run(&mut ckpt, &calib)?;
        let out = (report.checkpoint, report.total_s);
        self.cache.insert(key, out.clone_pair());
        Ok(out)
    }

    fn ppl(&self, model: &mut CpuModel, style: &str) -> Result<f64> {
        let corpus = CorpusFile::load(&self.rt.manifest.corpus_path(&format!("{style}_test.bin")))?;
        Ok(perplexity(model, &corpus, self.rt.manifest.seq_len, self.segments))
    }

    fn ppl_quantized(&mut self, size: &str, bits: u32, g: usize, e: QuantEngine, style: &str) -> Result<f64> {
        let (qc, _) = self.quantized(size, bits, g, e)?;
        let mut m = CpuModel::from_quantized(&qc);
        self.ppl(&mut m, style)
    }

    fn zeroshot(&self, model: &mut CpuModel) -> Result<(f64, f64, f64, f64)> {
        let cloze = load_tasks(&self.rt.manifest.corpus_path("tasks/cloze.jsonl"))?;
        let mcq = load_tasks(&self.rt.manifest.corpus_path("tasks/mcq.jsonl"))?;
        let binary = load_tasks(&self.rt.manifest.corpus_path("tasks/binary.jsonl"))?;
        let n = 120;
        Ok((
            eval_cloze(model, &cloze, n),
            eval_choice(model, &cloze, n),
            eval_choice(model, &mcq, n),
            eval_choice(model, &binary, n),
        ))
    }
}

trait ClonePair {
    fn clone_pair(&self) -> (QuantizedCheckpoint, f64);
}
impl ClonePair for (QuantizedCheckpoint, f64) {
    fn clone_pair(&self) -> (QuantizedCheckpoint, f64) {
        (self.0.clone(), self.1)
    }
}

fn hline(w: usize) {
    println!("{}", "-".repeat(w));
}

// ---------------------------------------------------------------------------
// Table 1 / Table 7 — method comparison
// ---------------------------------------------------------------------------

pub fn table1(ctx: &mut Ctx) -> Result<()> {
    println!("\n== Table 1/7 analog: PTQ method comparison (RTN vs OBQ vs GPTQ) ==");
    println!("paper: GPTQ ≈ accurate-but-slow methods, ≫ fast RTN; ~60x faster than OBQ");
    let size = ctx.sizes.first().cloned().unwrap_or_else(|| "nano".into());
    println!("model {size}; per-method: mean layer ‖WX−ŴX‖²/n, total solver ms, val PPL (narrative)");
    hline(74);
    println!("{:<8} {:>4} {:>14} {:>12} {:>10}", "method", "bits", "mean sq-err", "solver ms", "ppl");
    hline(74);
    for bits in [4u32, 3] {
        for engine in [QuantEngine::Rtn, QuantEngine::Obq, QuantEngine::GptqRust] {
            let t0 = Instant::now();
            let (qc, _) = ctx.quantized(&size, bits, 0, engine)?;
            let _elapsed = t0.elapsed();
            let solver_ms: f64 = qc.stats.iter().map(|s| s.quant_ms).sum();
            let err = qc.stats.iter().map(|s| s.sq_error).sum::<f64>() / qc.stats.len() as f64;
            let mut m = CpuModel::from_quantized(&qc);
            let ppl = ctx.ppl(&mut m, "narrative")?;
            println!(
                "{:<8} {:>4} {:>14.4e} {:>12.1} {:>10.3}",
                Ctx::engine_tag(engine),
                bits,
                err,
                solver_ms,
                ppl
            );
        }
    }
    let mut fp = ctx.fp_model(&size)?;
    println!("{:<8} {:>4} {:>14} {:>12} {:>10.3}", "fp32", 32, "-", "-", ctx.ppl(&mut fp, "narrative")?);
    Ok(())
}

// ---------------------------------------------------------------------------
// Figure 1 + Tables 2/3 + appendix tables — PPL grids
// ---------------------------------------------------------------------------

fn ppl_grid(ctx: &mut Ctx, style: &str, paper_ref: &str) -> Result<()> {
    println!("\n== {paper_ref}: perplexity on `{style}` ==");
    println!("paper shape: GPTQ ≈ fp at 4-bit; RTN degrades at 4-bit and collapses at 3-bit;");
    println!("gaps shrink with model size (larger models quantize more easily)");
    hline(78);
    println!(
        "{:<8} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "model", "fp32", "RTN-4", "GPTQ-4", "RTN-3", "GPTQ-3"
    );
    hline(78);
    for size in ctx.sizes.clone() {
        let mut fp = ctx.fp_model(&size)?;
        let p_fp = ctx.ppl(&mut fp, style)?;
        let r4 = ctx.ppl_quantized(&size, 4, 0, QuantEngine::Rtn, style)?;
        let g4 = ctx.ppl_quantized(&size, 4, 0, QuantEngine::GptqRust, style)?;
        let r3 = ctx.ppl_quantized(&size, 3, 0, QuantEngine::Rtn, style)?;
        let g3 = ctx.ppl_quantized(&size, 3, 0, QuantEngine::GptqRust, style)?;
        println!(
            "{size:<8} {p_fp:>10.3} {r4:>10.3} {g4:>10.3} {r3:>10.3} {g3:>10.3}"
        );
    }
    Ok(())
}

pub fn fig1(ctx: &mut Ctx) -> Result<()> {
    ppl_grid(ctx, "narrative", "Figure 1 / Tables 10–11 analog (WikiText2 stand-in)")
}

pub fn table2(ctx: &mut Ctx) -> Result<()> {
    ppl_grid(ctx, "markup", "Tables 2–3 analog (PTB stand-in)")?;
    ppl_grid(ctx, "crawl", "Tables 12–13 analog (C4 stand-in; calibration domain)")
}

// ---------------------------------------------------------------------------
// Figure 3 / Tables 8–9 — runtime scaling
// ---------------------------------------------------------------------------

pub fn fig3(ctx: &mut Ctx) -> Result<()> {
    println!("\n== Figure 3 / Tables 8–9 analog: quantization runtime scaling ==");
    println!("paper shape: GPTQ full-model minutes–hours; OBQ infeasible (extrapolated)");
    hline(70);
    println!("{:<8} {:>12} {:>16} {:>18}", "model", "params", "GPTQ (s)", "OBQ est. (s)");
    hline(70);
    for size in ctx.sizes.clone() {
        let entry = ctx.rt.manifest.model(&size)?.clone();
        let (_, gptq_s) = ctx.quantized(&size, 4, 0, QuantEngine::GptqRust)?;
        // OBQ measured on the smallest layer, extrapolated by the paper's
        // complexity ratio O(drow·dcol³) vs O(dcol²·max(drow,dcol))
        let obq_est = estimate_obq_seconds(&entry.config);
        println!("{:<8} {:>12} {:>16.2} {:>18.1}", size, entry.n_params, gptq_s, obq_est);
    }

    println!("\nsynthetic single-layer sweep (square drow=dcol layers):");
    hline(70);
    println!("{:<8} {:>14} {:>14} {:>14}", "dcol", "GPTQ ms", "OBQ ms", "speedup");
    hline(70);
    let mut obq_ms_by_d: Vec<(usize, f64)> = Vec::new();
    for d in [64usize, 128, 256, 512] {
        let (w, h) = synthetic_layer(d, d);
        let t0 = Instant::now();
        let _ = gptq_quantize(&w, d, d, &h, &GptqConfig::new(4)).unwrap();
        let gptq_ms = t0.elapsed().as_secs_f64() * 1e3;
        let obq_ms = if d <= 256 {
            let t1 = Instant::now();
            let _ = obq_quantize(&w, d, d, &h, 4, 0.01).unwrap();
            t1.elapsed().as_secs_f64() * 1e3
        } else {
            // extrapolate cubically from the last measured point
            let (d0, ms0) = *obq_ms_by_d.last().unwrap();
            ms0 * ((d as f64 / d0 as f64).powi(4))
        };
        obq_ms_by_d.push((d, obq_ms));
        println!(
            "{:<8} {:>14.1} {:>14.1}{} {:>13.1}x",
            d,
            gptq_ms,
            obq_ms,
            if d > 256 { "*" } else { " " },
            obq_ms / gptq_ms
        );
    }
    println!("(* extrapolated, O(drow·dcol³); paper estimates OBQ at months for 175B)");
    Ok(())
}

fn estimate_obq_seconds(cfg: &crate::model::ModelConfig) -> f64 {
    // measured OBQ throughput on this machine: ~calibrated from the 128-dim
    // layer at startup, then complexity-scaled per layer
    let (w, h) = synthetic_layer(64, 64);
    let t0 = Instant::now();
    let _ = obq_quantize(&w, 64, 64, &h, 4, 0.01).unwrap();
    let per_unit = t0.elapsed().as_secs_f64() / (64.0 * 64f64.powi(3));
    let mut total = 0.0;
    for l in crate::model::config::QUANT_LINEARS {
        let (o, i) = cfg.linear_shape(l);
        total += per_unit * o as f64 * (i as f64).powi(3);
    }
    total * cfg.n_layers as f64
}

fn synthetic_layer(drow: usize, dcol: usize) -> (Vec<f32>, Vec<f64>) {
    let mut rng = crate::data::Rng::new(drow as u64 * 31 + dcol as u64);
    let w: Vec<f32> = (0..drow * dcol).map(|_| rng.unit()).collect();
    let n = 2 * dcol;
    let mut x = vec![0.0f32; n * dcol];
    for v in x.iter_mut() {
        *v = rng.unit();
    }
    // correlate adjacent features (cheap stand-in for real activations)
    for r in 0..n {
        for c in 1..dcol {
            x[r * dcol + c] = 0.6 * x[r * dcol + c - 1] + 0.4 * x[r * dcol + c];
        }
    }
    let mut h = vec![0.0f64; dcol * dcol];
    quant::accumulate_hessian(&mut h, &x, n, dcol);
    (w, h)
}

// ---------------------------------------------------------------------------
// Table 4 — largest-model summary
// ---------------------------------------------------------------------------

pub fn table4(ctx: &mut Ctx) -> Result<()> {
    let size = ctx.sizes.last().cloned().unwrap_or_else(|| "small".into());
    println!("\n== Table 4 analog: {size} summary across all corpora + cloze ==");
    println!("paper shape: 4-bit GPTQ within ~0.2 ppl of fp; 3-bit RTN collapses, GPTQ holds;");
    println!("grouping (3G row) recovers most of the remaining 3-bit gap");
    hline(86);
    println!(
        "{:<10} {:>5} {:>10} {:>10} {:>10} {:>10}",
        "method", "bits", "narrative", "markup", "crawl", "cloze%"
    );
    hline(86);
    let rows: Vec<(&str, u32, usize, Option<QuantEngine>)> = vec![
        ("baseline", 32, 0, None),
        ("RTN", 4, 0, Some(QuantEngine::Rtn)),
        ("GPTQ", 4, 0, Some(QuantEngine::GptqRust)),
        ("RTN", 3, 0, Some(QuantEngine::Rtn)),
        ("GPTQ", 3, 0, Some(QuantEngine::GptqRust)),
        ("GPTQ-3G", 3, 32, Some(QuantEngine::GptqRust)),
    ];
    for (name, bits, g, engine) in rows {
        let mut model = match engine {
            None => ctx.fp_model(&size)?,
            Some(e) => {
                let (qc, _) = ctx.quantized(&size, bits, g, e)?;
                CpuModel::from_quantized(&qc)
            }
        };
        let p1 = ctx.ppl(&mut model, "narrative")?;
        let p2 = ctx.ppl(&mut model, "markup")?;
        let p3 = ctx.ppl(&mut model, "crawl")?;
        let (_, cloze_choice, _, _) = ctx.zeroshot(&mut model)?;
        println!(
            "{:<10} {:>5} {:>10.3} {:>10.3} {:>10.3} {:>10.1}",
            name,
            bits,
            p1,
            p2,
            p3,
            cloze_choice * 100.0
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 5 — per-token latency + memory
// ---------------------------------------------------------------------------

pub fn table5(ctx: &mut Ctx) -> Result<()> {
    let size = ctx.sizes.last().cloned().unwrap_or_else(|| "small".into());
    println!("\n== Table 5 analog: per-token generation latency, batch 1 ({size}) ==");
    println!("paper: 3-bit OPT-175B 1.9–4.5x faster per token than FP16 (bandwidth-bound);");
    println!("'GPU reduction' column becomes quantizable-weight memory reduction");
    let gen_tokens = 96usize;
    hline(86);
    println!(
        "{:<10} {:>12} {:>12} {:>10} {:>14} {:>10}",
        "weights", "ms/token", "tokens/s", "speedup", "weight bytes", "mem red."
    );
    hline(86);
    let mut fp = ctx.fp_model(&size)?;
    let (fp_ms, fp_bytes) = decode_latency(&mut fp, gen_tokens);
    println!(
        "{:<10} {:>12.3} {:>12.1} {:>10} {:>14} {:>10}",
        "fp32", fp_ms, 1e3 / fp_ms, "1.00x", fp_bytes, "1.0x"
    );
    for bits in [4u32, 3, 2] {
        let (qc, _) = ctx.quantized(&size, bits, 0, QuantEngine::GptqRust)?;
        let mut qm = CpuModel::from_quantized(&qc);
        let (ms, bytes) = decode_latency(&mut qm, gen_tokens);
        println!(
            "{:<10} {:>12.3} {:>12.1} {:>9.2}x {:>14} {:>9.1}x",
            format!("{bits}-bit"),
            ms,
            1e3 / ms,
            fp_ms / ms,
            bytes,
            fp_bytes as f64 / bytes as f64
        );
    }
    Ok(())
}

fn decode_latency(model: &mut CpuModel, gen_tokens: usize) -> (f64, usize) {
    let mut cache = KvCache::new(&model.config);
    // warm prefill
    for b in [10u8, 32, 97, 101] {
        model.decode_step(&mut cache, b);
    }
    let t0 = Instant::now();
    let mut tok = 101u8;
    for _ in 0..gen_tokens.min(model.config.max_seq - cache.len) {
        let logits = model.decode_step(&mut cache, tok);
        // greedy argmax to keep the loop honest
        let mut best = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        tok = best as u8;
    }
    let ms = t0.elapsed().as_secs_f64() * 1e3 / gen_tokens.min(model.config.max_seq) as f64;
    (ms, model.traffic_bytes_per_token())
}

// ---------------------------------------------------------------------------
// Table 6 — 2-bit group sweep
// ---------------------------------------------------------------------------

pub fn table6(ctx: &mut Ctx) -> Result<()> {
    let size = ctx.sizes.last().cloned().unwrap_or_else(|| "small".into());
    println!("\n== Table 6 analog: 2-bit GPTQ with varying group size ({size}, narrative) ==");
    println!("paper shape: 2-bit collapses per-row; smaller groups recover monotonically,");
    println!("g=32 at 2-bit ≈ vanilla 3-bit");
    let mut fp = ctx.fp_model(&size)?;
    let p_fp = ctx.ppl(&mut fp, "narrative")?;
    hline(52);
    println!("{:<12} {:>12} {:>14}", "group", "ppl", "eff. bits");
    hline(52);
    println!("{:<12} {:>12.3} {:>14}", "fp32", p_fp, "32");
    for g in [0usize, 128, 64, 32, 16] {
        let (qc, _) = ctx.quantized(&size, 2, g, QuantEngine::GptqRust)?;
        let mut m = CpuModel::from_quantized(&qc);
        let ppl = ctx.ppl(&mut m, "narrative")?;
        let n_weights: usize = qc.packed.values().map(|p| p.drow * p.dcol).sum();
        let eff = qc.packed_bytes() as f64 * 8.0 / n_weights as f64;
        let label = if g == 0 { "per-row".to_string() } else { format!("g={g}") };
        println!("{:<12} {:>12.3} {:>14.2}", label, ppl, eff);
    }
    let g3 = ctx.ppl_quantized(&size, 3, 0, QuantEngine::GptqRust, "narrative")?;
    println!("{:<12} {:>12.3} {:>14.2}", "3-bit row", g3, 3.2);
    Ok(())
}

// ---------------------------------------------------------------------------
// Figure 4 / Tables 14–23 — zero-shot
// ---------------------------------------------------------------------------

pub fn fig4(ctx: &mut Ctx) -> Result<()> {
    println!("\n== Figure 4 / Tables 14–23 analog: zero-shot accuracy ==");
    println!("tasks: cloze-exact & cloze-choice (LAMBADA), mcq (ARC), binary (PIQA/StoryCloze)");
    println!("paper shape: 4-bit near-fp even for RTN; at 3-bit RTN breaks, GPTQ holds");
    hline(96);
    println!(
        "{:<8} {:<8} {:>5} {:>12} {:>13} {:>10} {:>10}",
        "model", "method", "bits", "cloze-exact%", "cloze-choice%", "mcq%", "binary%"
    );
    hline(96);
    for size in ctx.sizes.clone() {
        let rows: Vec<(&str, u32, Option<QuantEngine>)> = vec![
            ("fp32", 32, None),
            ("RTN", 4, Some(QuantEngine::Rtn)),
            ("GPTQ", 4, Some(QuantEngine::GptqRust)),
            ("RTN", 3, Some(QuantEngine::Rtn)),
            ("GPTQ", 3, Some(QuantEngine::GptqRust)),
        ];
        for (name, bits, engine) in rows {
            let mut model = match engine {
                None => ctx.fp_model(&size)?,
                Some(e) => {
                    let (qc, _) = ctx.quantized(&size, bits, 0, e)?;
                    CpuModel::from_quantized(&qc)
                }
            };
            let (ce, cc, mcq, bin) = ctx.zeroshot(&mut model)?;
            println!(
                "{:<8} {:<8} {:>5} {:>12.1} {:>13.1} {:>10.1} {:>10.1}",
                size,
                name,
                bits,
                ce * 100.0,
                cc * 100.0,
                mcq * 100.0,
                bin * 100.0
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Ablations — §3.3 design choices
// ---------------------------------------------------------------------------

pub fn ablations(ctx: &mut Ctx) -> Result<()> {
    println!("\n== Ablations: the paper's §3.3 design choices, measured ==");
    let size = ctx.sizes.first().cloned().unwrap_or_else(|| "nano".into());
    let entry = ctx.rt.manifest.model(&size)?.clone();
    let dir = crate::artifacts_dir();
    let calib = CorpusFile::load(&ctx.rt.manifest.corpus_path("calib.bin"))?;

    let run = |label: &str, cfg: PipelineConfig, ctx: &mut Ctx| -> Result<()> {
        let mut ckpt = Checkpoint::load(&dir, &entry)?;
        let report = QuantPipeline::new(&mut ctx.rt, &size, cfg).run(&mut ckpt, &calib)?;
        let mut m = CpuModel::from_quantized(&report.checkpoint);
        let ppl = ctx.ppl(&mut m, "narrative")?;
        println!(
            "{:<34} ppl {:>8.3}  mean-err {:>10.4e}  {:>7.2}s",
            label, ppl, report.mean_layer_error, report.total_s
        );
        Ok(())
    };

    let calib_segments = ctx.calib_segments;
    let base = move |bits| {
        let mut c = PipelineConfig::new(bits, QuantEngine::GptqRust);
        c.n_calib_segments = calib_segments;
        c
    };

    println!("--- column order (paper Step 1: fixed order loses little) ---");
    run("natural order (GPTQ)", base(3), ctx)?;
    let mut act = base(3);
    act.gptq.order = Order::ActOrder;
    run("act-order (greedy-ish)", act, ctx)?;

    println!("--- inverse maintenance (paper Step 3: Cholesky) ---");
    run("cholesky (GPTQ)", base(3), ctx)?;
    let mut naive = base(3);
    naive.gptq.use_cholesky = false;
    run("naive Eq.(3) updates", naive, ctx)?;

    println!("--- dampening (paper: 1% of mean diag) ---");
    run("damp 1% (GPTQ)", base(3), ctx)?;
    let mut nodamp = base(3);
    nodamp.gptq.percdamp = 1e-8;
    run("damp ~0", nodamp, ctx)?;

    println!("--- quantized-input propagation (paper §4 Setup trick) ---");
    run("propagate quantized (GPTQ)", base(3), ctx)?;
    let mut noprop = base(3);
    noprop.propagate_quantized = false;
    run("propagate full-precision", noprop, ctx)?;

    println!("--- lazy batching (paper Step 2: blocking changes speed, not result) ---");
    let (w, h) = synthetic_layer(512, 512);
    for bs in [1usize, 16, 128, 512] {
        let cfg = GptqConfig { blocksize: bs, ..GptqConfig::new(4) };
        let t0 = Instant::now();
        let r = gptq_quantize(&w, 512, 512, &h, &cfg).unwrap();
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let checksum: f64 = r.wq.iter().map(|&v| v as f64).sum();
        println!("blocksize {bs:>4}: {ms:>9.1} ms   (wq checksum {checksum:+.4} — identical across rows)");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Sparsity — the SparseGPT-style follow-up experiment
// ---------------------------------------------------------------------------

pub fn sparse(ctx: &mut Ctx) -> Result<()> {
    let size = ctx.sizes.last().cloned().unwrap_or_else(|| "small".into());
    println!("\n== Sparsity: joint sparsify+quantize at 4-bit ({size}, narrative) ==");
    println!("SparseGPT-style: masks chosen inside the GPTQ solver by OBS saliency w²/[H⁻¹]ⱼⱼ,");
    println!("pruning error propagated through the same Cholesky path; 2:4 packs into the");
    println!("index-skipping sparse layout (DESIGN.md §Sparsity)");
    let mut fp = ctx.fp_model(&size)?;
    let p_fp = ctx.ppl(&mut fp, "narrative")?;
    hline(80);
    println!(
        "{:<16} {:>10} {:>14} {:>14} {:>12}",
        "mode", "ppl", "mean sq-err", "weight bytes", "eff. bits"
    );
    hline(80);
    let fp_bytes: usize = {
        let entry = ctx.rt.manifest.model(&size)?.clone();
        entry.config.quantizable_bytes_f32()
    };
    println!("{:<16} {:>10.3} {:>14} {:>14} {:>12}", "fp32", p_fp, "-", fp_bytes, "32.00");
    for sp in [Sparsity::None, Sparsity::Unstructured50, Sparsity::TwoOfFour] {
        let (qc, _) = ctx.quantized_sparse(&size, 4, 0, QuantEngine::GptqRust, sp)?;
        let mut m = CpuModel::from_quantized(&qc);
        let ppl = ctx.ppl(&mut m, "narrative")?;
        let err = qc.stats.iter().map(|s| s.sq_error).sum::<f64>() / qc.stats.len().max(1) as f64;
        let n_weights: usize = qc.packed.values().map(|p| p.drow * p.dcol).sum::<usize>()
            + qc.sparse.values().map(|s| s.drow * s.dcol).sum::<usize>();
        let bytes = qc.packed_bytes();
        let eff = bytes as f64 * 8.0 / n_weights as f64;
        println!("{:<16} {:>10.3} {:>14.4e} {:>14} {:>12.2}", sp.name(), ppl, err, bytes, eff);
    }
    println!("shape: unstructured50 ≈ dense ppl at the same stored bits; 2of4 trades a small");
    println!("ppl gap for the structured layout the batch-1 kernels exploit (kernel_sweep)");
    Ok(())
}

// ---------------------------------------------------------------------------

pub fn main_cli() -> Result<()> {
    let args = Args::from_env();
    let which = args.positional.first().map(String::as_str).unwrap_or("all").to_string();
    let mut ctx = Ctx::new(&args)?;
    let t0 = Instant::now();
    match which.as_str() {
        "table1" => table1(&mut ctx)?,
        "fig1" => fig1(&mut ctx)?,
        "table2" => table2(&mut ctx)?,
        "fig3" => fig3(&mut ctx)?,
        "table4" => table4(&mut ctx)?,
        "table5" => table5(&mut ctx)?,
        "table6" => table6(&mut ctx)?,
        "fig4" => fig4(&mut ctx)?,
        "ablations" => ablations(&mut ctx)?,
        "sparse" => sparse(&mut ctx)?,
        "all" => {
            table1(&mut ctx)?;
            fig1(&mut ctx)?;
            table2(&mut ctx)?;
            fig3(&mut ctx)?;
            table4(&mut ctx)?;
            table5(&mut ctx)?;
            table6(&mut ctx)?;
            fig4(&mut ctx)?;
            ablations(&mut ctx)?;
            sparse(&mut ctx)?;
        }
        other => anyhow::bail!(
            "unknown table {other}; one of table1|fig1|table2|fig3|table4|table5|table6|fig4|ablations|sparse|all"
        ),
    }
    eprintln!("\n[{which} done in {:.1}s]", t0.elapsed().as_secs_f64());
    Ok(())
}
