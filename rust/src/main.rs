//! `gptq` — the L3 coordinator CLI.
//!
//! ```text
//! gptq quantize --size small --bits 3 [--groupsize 64] [--engine rust|artifact|rtn|obq] [--out f.ckpt]
//! gptq eval     --size small [--quantized f.ckpt] [--segments 24] [--via cpu|artifact]
//! gptq serve    --size small [--quantized f.ckpt] [--workers 2] [--requests 32] [--gen-tokens 64]
//! gptq info
//! ```
//!
//! Every subcommand accepts `--backend reference|pjrt` to pick the
//! execution engine behind the artifact contracts (default: the pure-Rust
//! reference backend, which runs everywhere; `pjrt` needs
//! `--features pjrt` and the XLA toolchain). Everything runs against the
//! AOT artifact tree (`make artifacts`); Python never executes here.

use gptq_rs::coordinator::{
    verify_parity, Class, GenOutcome, GenRequest, PipelineConfig, QuantEngine, QuantPipeline,
    SamplingParams, SchedulerConfig, Server, ServerConfig, SpecConfig,
};
use gptq_rs::data::{load_tasks, CorpusFile};
use gptq_rs::eval::{eval_choice, eval_cloze, perplexity, perplexity_artifact};
use gptq_rs::model::{Checkpoint, CpuModel, QuantizedCheckpoint};
use gptq_rs::runtime::{Manifest, Runtime};
use gptq_rs::util::cli::Args;
use gptq_rs::Result;
use std::path::{Path, PathBuf};
use std::time::Instant;

const USAGE: &str = "usage: gptq [--artifacts DIR] [--backend reference|pjrt] [--threads N] [--isa auto|scalar|avx2|neon] <info|quantize|eval|serve> [flags]
  quantize --size S --bits B [--groupsize G] [--engine rust|artifact|rtn|obq]
           [--sparsity none|unstructured50|2of4] [--calib-segments N] [--out F]
  eval     --size S [--quantized F] [--segments N] [--via cpu|artifact]
  serve    --size S [--quantized F] [--workers N] [--requests N] [--gen-tokens N]
           [--max-batch N] [--pool-pages N] [--page-size N] [--prefill-chunk N]
           [--kv-dtype f32|q8] [--skip-parity]
           [--priority interactive|batch] [--ttft-deadline-ms MS] [--deadline-ms MS]
           [--max-queue-interactive N] [--max-queue-batch N]
           [--sampling greedy|temp=T,top_k=K,top_p=P,seed=S]
           [--spec-decode off|K|KbB]  (e.g. 4 or k4b3: draft K tokens at B bits)
           (GPTQ_FAULTS arms the fault-injection harness, GPTQ_SPEC speculation; see DESIGN.md)";

fn parse_engine(s: &str) -> Result<QuantEngine> {
    Ok(match s {
        "rust" => QuantEngine::GptqRust,
        // "xla" kept as an alias from the pre-backend CLI
        "artifact" | "xla" => QuantEngine::GptqArtifact,
        "rtn" => QuantEngine::Rtn,
        "obq" => QuantEngine::Obq,
        other => anyhow::bail!("unknown engine {other} (rust|artifact|rtn|obq)"),
    })
}

fn main() -> Result<()> {
    let args = Args::from_env();
    // global intra-op thread count: --threads beats GPTQ_THREADS; 0 = all
    // cores; unset/1 = serial (exactly the single-threaded code paths)
    if let Some(t) = args.get("threads").and_then(|v| v.parse::<usize>().ok()) {
        gptq_rs::util::par::set_threads(t);
    }
    // global kernel ISA: --isa beats GPTQ_ISA; default auto-detect, and an
    // unsupported request clamps to scalar (DESIGN.md §Kernels)
    if let Some(s) = args.get("isa") {
        gptq_rs::model::kernels::set_isa_name(s)?;
    }
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let backend = args.str_or("backend", "reference");
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "info" => info(&artifacts, &backend),
        "quantize" => quantize(&artifacts, &backend, &args),
        "eval" => eval(&artifacts, &backend, &args),
        "serve" => serve(&artifacts, &backend, &args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn info(artifacts: &Path, backend: &str) -> Result<()> {
    let rt = Runtime::from_artifacts_dir_with(artifacts, backend)?;
    let m = &rt.manifest;
    println!(
        "manifest v{} — seq_len {}, eval_batch {}, backend {}",
        m.version,
        m.seq_len,
        m.eval_batch,
        rt.backend_name()
    );
    for (name, entry) in &m.models {
        println!(
            "  model {name:8} d={:4} L={} heads={} ff={:4}  {:>10} params",
            entry.config.d_model, entry.config.n_layers, entry.config.n_heads, entry.config.d_ff, entry.n_params
        );
    }
    println!("  {} HLO artifacts", m.artifacts.len());
    Ok(())
}

fn quantize(artifacts: &Path, backend: &str, args: &Args) -> Result<()> {
    let size = args.str_or("size", "small");
    let bits = args.u32_or("bits", 4);
    let groupsize = args.usize_or("groupsize", 0);
    let engine_s = args.str_or("engine", "rust");
    // joint sparsify+quantize: --sparsity beats GPTQ_SPARSITY; default
    // dense (DESIGN.md §Sparsity)
    let sparsity = match args.get("sparsity") {
        Some(s) => gptq_rs::quant::Sparsity::parse(s).ok_or_else(|| {
            anyhow::anyhow!("unknown --sparsity {s:?} (none|unstructured50|2of4)")
        })?,
        None => gptq_rs::quant::Sparsity::from_env(),
    };
    let mut rt = Runtime::from_artifacts_dir_with(artifacts, backend)?;
    let entry = rt.manifest.model(&size)?.clone();
    let mut ckpt = Checkpoint::load(artifacts, &entry)?;
    let calib = CorpusFile::load(&rt.manifest.corpus_path("calib.bin"))?;
    let mut cfg = PipelineConfig::new(bits, parse_engine(&engine_s)?)
        .with_groupsize(groupsize)
        .with_sparsity(sparsity);
    cfg.n_calib_segments = args.usize_or("calib-segments", 64);
    let mut pipeline = QuantPipeline::new(&mut rt, &size, cfg);
    let report = pipeline.run(&mut ckpt, &calib)?;
    println!(
        "quantized {size} to {bits}-bit (g={groupsize}, engine {engine_s}, sparsity {sparsity}, backend {backend}, threads {}, isa {}) in {:.2}s; mean layer sq-err {:.4e}",
        gptq_rs::util::par::threads(),
        gptq_rs::model::kernels::isa(),
        report.total_s,
        report.mean_layer_error
    );
    for s in &report.stats {
        println!("  layer {:2} {:5}  err {:.4e}  {:.1} ms", s.layer, s.name, s.sq_error, s.quant_ms);
    }
    let out = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(format!("{size}_{bits}bit.ckpt")));
    report.checkpoint.save(&out)?;
    let n_weights = entry.config.quantizable_bytes_f32() / 4;
    let eff_bits = report.checkpoint.packed_bytes() as f64 * 8.0 / n_weights as f64;
    println!(
        "saved {} ({} packed bytes, {eff_bits:.2} effective bits/weight)",
        out.display(),
        report.checkpoint.packed_bytes(),
    );
    Ok(())
}

fn eval(artifacts: &Path, backend: &str, args: &Args) -> Result<()> {
    let size = args.str_or("size", "small");
    let segments = args.usize_or("segments", 24);
    let via = args.str_or("via", "cpu");
    let m = Manifest::load(artifacts)?;
    let entry = m.model(&size)?.clone();
    match via.as_str() {
        "cpu" => {
            let mut model = build_model(artifacts, &entry, args.get("quantized").map(Path::new))?;
            for style in ["narrative", "markup", "crawl"] {
                let corpus = CorpusFile::load(&m.corpus_path(&format!("{style}_test.bin")))?;
                let ppl = perplexity(&mut model, &corpus, m.seq_len, segments);
                println!("{style:10} ppl {ppl:8.3}");
            }
            for (task, kind) in [("cloze", "cloze"), ("mcq", "choice"), ("binary", "choice")] {
                let items = load_tasks(&m.corpus_path(&format!("tasks/{task}.jsonl")))?;
                let acc = if kind == "cloze" {
                    eval_cloze(&mut model, &items, 200)
                } else {
                    eval_choice(&mut model, &items, 200)
                };
                println!("{task:10} acc {:6.2}%", acc * 100.0);
            }
        }
        "artifact" => {
            // batched dense evaluation through the execution backend's
            // lm_fwd contract (no KV cache; the graph-parity path)
            anyhow::ensure!(
                args.get("quantized").is_none(),
                "--via artifact evaluates the dense checkpoint (lm_fwd takes fp weights)"
            );
            let mut rt = Runtime::with_backend(m, gptq_rs::runtime::backend_by_name(backend)?);
            let ckpt = Checkpoint::load(artifacts, &entry)?;
            let batches = segments.div_ceil(rt.manifest.eval_batch).max(1);
            for style in ["narrative", "markup", "crawl"] {
                let corpus = CorpusFile::load(&rt.manifest.corpus_path(&format!("{style}_test.bin")))?;
                let ppl = perplexity_artifact(&mut rt, &size, &ckpt, &corpus, batches)?;
                println!("{style:10} ppl {ppl:8.3}  (backend {})", rt.backend_name());
            }
        }
        other => anyhow::bail!("unknown eval path {other:?} (cpu|artifact)"),
    }
    Ok(())
}

fn serve(artifacts: &Path, backend: &str, args: &Args) -> Result<()> {
    let size = args.str_or("size", "small");
    let workers = args.usize_or("workers", 1);
    let requests = args.usize_or("requests", 32);
    let gen_tokens = args.usize_or("gen-tokens", 64);
    let mut rt = Runtime::from_artifacts_dir_with(artifacts, backend)?;
    let entry = rt.manifest.model(&size)?.clone();
    let corpus = CorpusFile::load(&rt.manifest.corpus_path("crawl_test.bin"))?;
    let quantized = args.get("quantized").map(PathBuf::from);

    // pre-flight: the serving hot path must agree with the execution
    // backend before taking traffic (dense deployments only — lm_fwd
    // takes fp weights)
    if quantized.is_none() && !args.flag("skip-parity") {
        let ckpt = Checkpoint::load(artifacts, &entry)?;
        let parity_segments = rt.manifest.eval_batch;
        let rel = verify_parity(&mut rt, &size, &ckpt, &corpus, parity_segments)?;
        anyhow::ensure!(
            rel < 0.02,
            "serving parity check failed: decode path vs {} backend differ by {rel:.4} rel ppl",
            rt.backend_name()
        );
        println!("parity check vs {} backend: rel ppl diff {rel:.2e}", rt.backend_name());
    }

    // KV page precision: --kv-dtype beats GPTQ_KV_DTYPE; default f32
    // (DESIGN.md §KV precision)
    let kv_dtype = match args.get("kv-dtype") {
        Some(s) => gptq_rs::model::KvDtype::parse(s)
            .ok_or_else(|| anyhow::anyhow!("unknown --kv-dtype {s:?} (f32|q8)"))?,
        None => gptq_rs::model::KvDtype::from_env(),
    };
    // request lifecycle knobs (DESIGN.md §Robustness): class + deadlines
    // apply to every request this CLI run submits
    let priority = match args.get("priority") {
        Some(s) => Class::parse(s)
            .ok_or_else(|| anyhow::anyhow!("unknown --priority {s:?} (interactive|batch)"))?,
        None => Class::Interactive,
    };
    let ttft_deadline_ms = parse_ms(args.get("ttft-deadline-ms"), "--ttft-deadline-ms")?;
    let deadline_ms = parse_ms(args.get("deadline-ms"), "--deadline-ms")?;
    // per-request token selection: greedy (temperature 0) unless asked
    // otherwise; seeded sampling replays bit-identically (DESIGN.md
    // §Sampling & Speculative decoding)
    let sampling = match args.get("sampling") {
        Some(s) => SamplingParams::parse(s).ok_or_else(|| {
            anyhow::anyhow!("unknown --sampling {s:?} (greedy|temp=T,top_k=K,top_p=P,seed=S)")
        })?,
        None => SamplingParams::greedy(),
    };
    // self-speculative decoding: --spec-decode beats GPTQ_SPEC; off by
    // default, and greedy output is bit-identical either way
    let spec = match args.get("spec-decode") {
        Some(s) => SpecConfig::parse(s)
            .ok_or_else(|| anyhow::anyhow!("unknown --spec-decode {s:?} (off|K|kKbB)"))?,
        None => SpecConfig::from_env(),
    };
    let artifacts = artifacts.to_path_buf();
    let cfg = ServerConfig {
        n_workers: workers,
        scheduler: SchedulerConfig {
            max_batch: args.usize_or("max-batch", 8),
            pool_pages: args.usize_or("pool-pages", 64),
            page_size: args.usize_or("page-size", 16),
            prefill_chunk: args.usize_or("prefill-chunk", 4),
            eos: None,
            // cross-request prompt-prefix sharing (DESIGN.md §Prefix
            // cache); bit-identical outputs either way under greedy decode
            prefix_cache: !args.flag("no-prefix-cache"),
            kv_dtype,
            // per-class admission bounds: overload sheds (Rejected) at
            // submit instead of queueing unboundedly
            max_queue_interactive: args.usize_or("max-queue-interactive", usize::MAX),
            max_queue_batch: args.usize_or("max-queue-batch", usize::MAX),
            // deterministic chaos hooks; off unless GPTQ_FAULTS is set
            faults: gptq_rs::util::faultinject::FaultConfig::from_env(),
            spec,
        },
    };
    println!(
        "kernel ISA: {} (threads {}, kv-dtype {}, spec {})",
        gptq_rs::model::kernels::isa(),
        gptq_rs::util::par::threads(),
        kv_dtype.name(),
        spec.name()
    );
    let mut server = Server::start(cfg, |_| {
        build_model(&artifacts, &entry, quantized.as_deref()).expect("model build")
    });
    let t0 = Instant::now();
    for i in 0..requests {
        let start = (i * 131) % (corpus.len() - 32);
        let mut req = GenRequest::new(
            i as u64,
            corpus.bytes[start..start + 16].to_vec(),
            gen_tokens,
        )
        .with_priority(priority)
        .with_sampling(sampling);
        if let Some(ms) = ttft_deadline_ms {
            req = req.with_ttft_deadline_ms(ms);
        }
        if let Some(ms) = deadline_ms {
            req = req.with_deadline_ms(ms);
        }
        server.submit(req)?;
    }
    let responses = server.collect(requests)?;
    let wall_s = t0.elapsed().as_secs_f64();
    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let ok = responses.iter().filter(|r| r.outcome == GenOutcome::Completed).count();
    let metrics = server.shutdown();
    println!(
        "served {requests} requests ({ok} completed, {} shed/failed) / {total_tokens} tokens on \
         {workers} worker(s) in {wall_s:.2}s ({:.1} tokens/s aggregate, wall-clock)",
        requests - ok,
        total_tokens as f64 / wall_s.max(1e-9)
    );
    println!("{}", metrics.summary());
    Ok(())
}

/// Parse an optional millisecond flag value.
fn parse_ms(v: Option<&str>, flag: &str) -> Result<Option<f64>> {
    match v {
        Some(s) => {
            let ms: f64 =
                s.parse().map_err(|_| anyhow::anyhow!("{flag} wants milliseconds, got {s:?}"))?;
            anyhow::ensure!(ms >= 0.0 && ms.is_finite(), "{flag} must be a finite, non-negative number");
            Ok(Some(ms))
        }
        None => Ok(None),
    }
}

fn build_model(
    artifacts: &Path,
    entry: &gptq_rs::runtime::ModelEntry,
    quantized: Option<&Path>,
) -> Result<CpuModel> {
    match quantized {
        Some(path) => Ok(CpuModel::from_quantized(&QuantizedCheckpoint::load(path)?)),
        None => Ok(CpuModel::from_checkpoint(&Checkpoint::load(artifacts, entry)?)),
    }
}
