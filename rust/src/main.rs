//! `gptq` — the L3 coordinator CLI.
//!
//! ```text
//! gptq quantize --size small --bits 3 [--groupsize 64] [--engine rust|xla|rtn|obq] [--out f.ckpt]
//! gptq eval     --size small [--quantized f.ckpt] [--segments 24]
//! gptq serve    --size small [--quantized f.ckpt] [--workers 2] [--requests 32] [--gen-tokens 64]
//! gptq info
//! ```
//!
//! Everything runs against the AOT artifact tree (`make artifacts`);
//! Python never executes here.

use gptq_rs::coordinator::{GenRequest, PipelineConfig, QuantEngine, QuantPipeline, Server, ServerConfig};
use gptq_rs::data::{load_tasks, CorpusFile};
use gptq_rs::eval::{eval_choice, eval_cloze, perplexity};
use gptq_rs::model::{Checkpoint, CpuModel, QuantizedCheckpoint};
use gptq_rs::runtime::{Manifest, Runtime};
use gptq_rs::util::cli::Args;
use gptq_rs::Result;
use std::path::{Path, PathBuf};
use std::time::Duration;

const USAGE: &str = "usage: gptq [--artifacts DIR] <info|quantize|eval|serve> [flags]
  quantize --size S --bits B [--groupsize G] [--engine rust|xla|rtn|obq] [--calib-segments N] [--out F]
  eval     --size S [--quantized F] [--segments N]
  serve    --size S [--quantized F] [--workers N] [--requests N] [--gen-tokens N]";

fn parse_engine(s: &str) -> Result<QuantEngine> {
    Ok(match s {
        "rust" => QuantEngine::GptqRust,
        "xla" => QuantEngine::GptqXla,
        "rtn" => QuantEngine::Rtn,
        "obq" => QuantEngine::Obq,
        other => anyhow::bail!("unknown engine {other} (rust|xla|rtn|obq)"),
    })
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "info" => info(&artifacts),
        "quantize" => quantize(&artifacts, &args),
        "eval" => eval(&artifacts, &args),
        "serve" => serve(&artifacts, &args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn info(artifacts: &Path) -> Result<()> {
    let m = Manifest::load(artifacts)?;
    println!("manifest v{} — seq_len {}, eval_batch {}", m.version, m.seq_len, m.eval_batch);
    for (name, entry) in &m.models {
        println!(
            "  model {name:8} d={:4} L={} heads={} ff={:4}  {:>10} params",
            entry.config.d_model, entry.config.n_layers, entry.config.n_heads, entry.config.d_ff, entry.n_params
        );
    }
    println!("  {} HLO artifacts", m.artifacts.len());
    Ok(())
}

fn quantize(artifacts: &Path, args: &Args) -> Result<()> {
    let size = args.str_or("size", "small");
    let bits = args.u32_or("bits", 4);
    let groupsize = args.usize_or("groupsize", 0);
    let engine_s = args.str_or("engine", "rust");
    let mut rt = Runtime::from_artifacts_dir(artifacts)?;
    let entry = rt.manifest.model(&size)?.clone();
    let mut ckpt = Checkpoint::load(artifacts, &entry)?;
    let calib = CorpusFile::load(&rt.manifest.corpus_path("calib.bin"))?;
    let mut cfg = PipelineConfig::new(bits, parse_engine(&engine_s)?).with_groupsize(groupsize);
    cfg.n_calib_segments = args.usize_or("calib-segments", 64);
    let mut pipeline = QuantPipeline::new(&mut rt, &size, cfg);
    let report = pipeline.run(&mut ckpt, &calib)?;
    println!(
        "quantized {size} to {bits}-bit (g={groupsize}, engine {engine_s}) in {:.2}s; mean layer sq-err {:.4e}",
        report.total_s, report.mean_layer_error
    );
    for s in &report.stats {
        println!("  layer {:2} {:5}  err {:.4e}  {:.1} ms", s.layer, s.name, s.sq_error, s.quant_ms);
    }
    let out = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(format!("{size}_{bits}bit.ckpt")));
    report.checkpoint.save(&out)?;
    let n_weights = entry.config.quantizable_bytes_f32() / 4;
    let eff_bits = report.checkpoint.packed_bytes() as f64 * 8.0 / n_weights as f64;
    println!(
        "saved {} ({} packed bytes, {eff_bits:.2} effective bits/weight)",
        out.display(),
        report.checkpoint.packed_bytes(),
    );
    Ok(())
}

fn eval(artifacts: &Path, args: &Args) -> Result<()> {
    let size = args.str_or("size", "small");
    let segments = args.usize_or("segments", 24);
    let m = Manifest::load(artifacts)?;
    let entry = m.model(&size)?.clone();
    let mut model = build_model(artifacts, &entry, args.get("quantized").map(Path::new))?;
    for style in ["narrative", "markup", "crawl"] {
        let corpus = CorpusFile::load(&m.corpus_path(&format!("{style}_test.bin")))?;
        let ppl = perplexity(&mut model, &corpus, m.seq_len, segments);
        println!("{style:10} ppl {ppl:8.3}");
    }
    for (task, kind) in [("cloze", "cloze"), ("mcq", "choice"), ("binary", "choice")] {
        let items = load_tasks(&m.corpus_path(&format!("tasks/{task}.jsonl")))?;
        let acc = if kind == "cloze" {
            eval_cloze(&mut model, &items, 200)
        } else {
            eval_choice(&mut model, &items, 200)
        };
        println!("{task:10} acc {:6.2}%", acc * 100.0);
    }
    Ok(())
}

fn serve(artifacts: &Path, args: &Args) -> Result<()> {
    let size = args.str_or("size", "small");
    let workers = args.usize_or("workers", 1);
    let requests = args.usize_or("requests", 32);
    let gen_tokens = args.usize_or("gen-tokens", 64);
    let m = Manifest::load(artifacts)?;
    let entry = m.model(&size)?.clone();
    let corpus = CorpusFile::load(&m.corpus_path("crawl_test.bin"))?;
    let quantized = args.get("quantized").map(PathBuf::from);
    let artifacts = artifacts.to_path_buf();
    let cfg = ServerConfig { n_workers: workers, max_batch: 4, linger: Duration::from_millis(1) };
    let mut server = Server::start(cfg, |_| {
        build_model(&artifacts, &entry, quantized.as_deref()).expect("model build")
    });
    for i in 0..requests {
        let start = (i * 131) % (corpus.len() - 32);
        server.submit(GenRequest {
            id: i as u64,
            prompt: corpus.bytes[start..start + 16].to_vec(),
            max_new_tokens: gen_tokens,
        });
    }
    let responses = server.collect(requests);
    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let stats = server.shutdown();
    println!("served {requests} requests / {total_tokens} tokens on {workers} worker(s)");
    println!("per-token latency: {}", stats.summary());
    Ok(())
}

fn build_model(
    artifacts: &Path,
    entry: &gptq_rs::runtime::ModelEntry,
    quantized: Option<&Path>,
) -> Result<CpuModel> {
    match quantized {
        Some(path) => Ok(CpuModel::from_quantized(&QuantizedCheckpoint::load(path)?)),
        None => Ok(CpuModel::from_checkpoint(&Checkpoint::load(artifacts, entry)?)),
    }
}
