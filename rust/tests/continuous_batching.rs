//! Continuous-batching suite: the serving parity contract (DESIGN.md
//! §Serving) and the scheduler's backpressure/accounting invariants.
//!
//! * Batched `decode_steps` over the paged pool must equal sequential
//!   `decode_step` per sequence: dense linears BIT-identical, packed
//!   within 1e-5 (the batched kernels keep the single-sequence
//!   accumulation order, so packed is bit-identical too in practice).
//! * Pool exhaustion must backpressure (evict cold prefix-cache pages,
//!   then preempt + FIFO re-queue), never deadlock, and never leak
//!   pages: every page is free or pinned by the prefix cache at idle,
//!   and dropping the cache returns the free count to initial.
//! * `make -C rust check` runs this suite under `GPTQ_THREADS=1` and
//!   `=4`; the thread-flip test additionally pins bit-identity of the
//!   batched kernels across pool sizes in-process.
//! * The determinism matrix additionally runs the suite under
//!   `GPTQ_KV_DTYPE=q8`: the scheduler's default pool flips to q8 pages
//!   and the `generate_sequential` oracle follows it (batch-1
//!   `decode_steps` over a q8 pool), pinning scheduler ≡ sequential
//!   WITHIN the q8 numeric mode. The explicit f32 pools built by the
//!   parity tests are deliberately env-independent.
//! * The matrix also runs the suite under `GPTQ_SPEC=k4`: the
//!   scheduler's default config flips self-speculative decoding on,
//!   and because greedy acceptance is accept-iff-equal, every
//!   scheduler-vs-oracle assertion in this file must keep passing
//!   BIT-IDENTICALLY with the spec-free oracle. The explicit-config
//!   tests below additionally pin spec-on ≡ oracle and seeded-sampling
//!   replay without needing the env var.
//! * Soak coverage: a seeded, bounded 60-request trace runs in the
//!   default suite (`make -C rust check`); the long 500-request trace
//!   and a shared-prefix variant (prefix-cache churn under a tight
//!   pool) stay `#[ignore]`d behind `make -C rust soak`. All assert
//!   zero dropped/duplicated responses and zero leaked pages.

use gptq_rs::coordinator::{GenRequest, SamplingParams, Scheduler, SchedulerConfig, SpecConfig};
use gptq_rs::coordinator::sampling::sample;
use gptq_rs::data::Rng;
use gptq_rs::model::checkpoint::quantizable_keys;
use gptq_rs::model::testkit::tiny_checkpoint;
use gptq_rs::model::{CpuModel, KvCache, KvDtype, KvPool, QuantizedCheckpoint, SeqCache};
use gptq_rs::quant::{rtn_quantize, PackedMatrix};
use gptq_rs::util::par;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// The global thread count is process state; tests that flip it
/// serialize through this lock (ignoring poisoning).
static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn packed_tiny_model(seed: u64) -> CpuModel {
    let ckpt = tiny_checkpoint(seed);
    let mut packed = BTreeMap::new();
    for key in quantizable_keys(&ckpt.config) {
        let t = ckpt.get(&key);
        let (o, i) = t.dims2();
        packed.insert(key.clone(), PackedMatrix::from_result(&rtn_quantize(&t.data, o, i, 4, 16)));
    }
    let q = QuantizedCheckpoint::from_parts(ckpt.config.clone(), 4, 16, packed, &ckpt, vec![]);
    CpuModel::from_quantized(&q)
}

/// Ragged deterministic token streams (vocab 32, lengths 2..=15).
fn ragged_streams(n: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let len = 2 + rng.below(14);
            (0..len).map(|_| rng.below(32) as u8).collect()
        })
        .collect()
}

/// Per-stream logits from the sequential single-sequence decode path.
fn sequential_logits(model: &mut CpuModel, streams: &[Vec<u8>]) -> Vec<Vec<Vec<f32>>> {
    streams
        .iter()
        .map(|st| {
            let mut cache = KvCache::new(&model.config);
            st.iter().map(|&t| model.decode_step(&mut cache, t).to_vec()).collect()
        })
        .collect()
}

/// Per-stream logits from batched `decode_steps` over a paged pool;
/// asserts no page leak on the way out.
fn batched_logits(
    model: &mut CpuModel,
    streams: &[Vec<u8>],
    pool_pages: usize,
    page_size: usize,
) -> Vec<Vec<Vec<f32>>> {
    let mut pool = KvPool::new(&model.config, pool_pages, page_size);
    let mut seqs: Vec<SeqCache> = (0..streams.len()).map(|_| SeqCache::new()).collect();
    let mut out: Vec<Vec<Vec<f32>>> = streams.iter().map(|_| Vec::new()).collect();
    let vocab = model.config.vocab;
    let maxlen = streams.iter().map(Vec::len).max().unwrap_or(0);
    for t in 0..maxlen {
        let mut refs: Vec<&mut SeqCache> = Vec::new();
        let mut toks = Vec::new();
        let mut live = Vec::new();
        for (j, sc) in seqs.iter_mut().enumerate() {
            if t < streams[j].len() {
                assert!(pool.reserve(sc, t + 1), "test pool sized too small");
                refs.push(sc);
                toks.push(streams[j][t]);
                live.push(j);
            }
        }
        let logits = model.decode_steps(&mut pool, &mut refs, &toks);
        for (k, &j) in live.iter().enumerate() {
            out[j].push(logits[k * vocab..(k + 1) * vocab].to_vec());
        }
    }
    for sc in seqs.iter_mut() {
        pool.release(sc);
    }
    assert_eq!(pool.free_pages(), pool.total_pages(), "page leak");
    out
}

#[test]
fn batched_equals_sequential_dense_bitwise() {
    let ckpt = tiny_checkpoint(41);
    let mut m = CpuModel::from_checkpoint(&ckpt);
    let streams = ragged_streams(8, 43);
    let want = sequential_logits(&mut m, &streams);
    let got = batched_logits(&mut m, &streams, 64, 4);
    for j in 0..streams.len() {
        assert_eq!(want[j].len(), got[j].len());
        for t in 0..want[j].len() {
            for (a, b) in got[j][t].iter().zip(&want[j][t]) {
                assert_eq!(a.to_bits(), b.to_bits(), "dense seq {j} step {t}");
            }
        }
    }
}

#[test]
fn batched_equals_sequential_packed_within_tolerance() {
    let mut m = packed_tiny_model(47);
    let streams = ragged_streams(8, 53);
    let want = sequential_logits(&mut m, &streams);
    let got = batched_logits(&mut m, &streams, 64, 4);
    for j in 0..streams.len() {
        for t in 0..want[j].len() {
            for (a, b) in got[j][t].iter().zip(&want[j][t]) {
                assert!(
                    (a - b).abs() < 1e-5,
                    "packed seq {j} step {t}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn batched_decode_thread_count_bit_identical() {
    // batched kernels partition output rows; thread count must never
    // move a bit (the PR-2 determinism contract extended to serving)
    let guard = THREADS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let streams = ragged_streams(6, 61);
    let run = |threads: usize| {
        par::set_threads(threads);
        let mut m = CpuModel::from_checkpoint(&tiny_checkpoint(59));
        let dense = batched_logits(&mut m, &streams, 32, 8);
        let mut q = packed_tiny_model(59);
        let packed = batched_logits(&mut q, &streams, 32, 8);
        let bits = |l: Vec<Vec<Vec<f32>>>| -> Vec<u32> {
            l.into_iter().flatten().flatten().map(f32::to_bits).collect()
        };
        (bits(dense), bits(packed))
    };
    let a = run(1);
    let b = run(4);
    par::set_threads_env();
    drop(guard);
    assert_eq!(a, b);
}

/// The sequential single-stream generation loop (what `serve.rs` ran
/// before continuous batching) — the scheduler's parity oracle.
///
/// Dtype-aware so the suite can run under `GPTQ_KV_DTYPE=q8`: the
/// scheduler's default pool follows the env, so the oracle must speak
/// the same numeric mode. For f32 it stays the INDEPENDENT dense
/// `KvCache`/`decode_step` path (a stronger oracle: different storage,
/// bit-identical math). For q8 there is no dense equivalent — the
/// contract is scheduler ≡ batch-1 sequential WITHIN the mode — so the
/// oracle replays the same loop through batch-1 `decode_steps` over its
/// own q8 pool.
fn generate_sequential(model: &mut CpuModel, prompt: &[u8], max_new: usize) -> Vec<u8> {
    let max_seq = model.config.max_seq;
    let dtype = KvDtype::from_env();
    let mut pool = KvPool::new_with_dtype(&model.config, (max_seq + 1) / 2, 2, dtype);
    let mut seq = SeqCache::new();
    let mut cache = KvCache::new(&model.config);
    // One decode step in the oracle's numeric mode.
    let mut step = |model: &mut CpuModel, pool: &mut KvPool, seq: &mut SeqCache, b: u8| {
        match dtype {
            KvDtype::F32 => model.decode_step(&mut cache, b).to_vec(),
            KvDtype::Q8 => {
                assert!(pool.reserve(seq, seq.len + 1), "oracle pool sized too small");
                let mut refs = [&mut *seq];
                model.decode_steps(pool, &mut refs, &[b])
            }
        }
    };
    let mut len = 0usize;
    let mut logits: Vec<f32> = Vec::new();
    for &b in prompt.iter().take(max_seq.saturating_sub(1)) {
        logits = step(model, &mut pool, &mut seq, b);
        len += 1;
    }
    let mut tokens = Vec::new();
    for _ in 0..max_new {
        if len >= max_seq {
            break;
        }
        let next = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as u8)
            .unwrap_or(0);
        logits = step(model, &mut pool, &mut seq, next);
        len += 1;
        tokens.push(next);
    }
    pool.release(&mut seq);
    tokens
}

fn requests(n: usize, seed: u64) -> Vec<GenRequest> {
    ragged_streams(n, seed)
        .into_iter()
        .enumerate()
        .map(|(i, prompt)| GenRequest::new(i as u64, prompt, 1 + i % 5))
        .collect()
}

/// The pool-leak invariant with prefix sharing on: at idle every page is
/// either free or pinned by the prefix cache, and dropping the cache
/// returns all of them (single copy: `Scheduler::assert_no_page_leak`).
fn assert_no_leak(sched: &mut Scheduler) {
    sched.assert_no_page_leak();
}

#[test]
fn scheduler_n8_matches_sequential_generate_dense_and_packed() {
    for packed in [false, true] {
        let mut model = if packed {
            packed_tiny_model(67)
        } else {
            CpuModel::from_checkpoint(&tiny_checkpoint(67))
        };
        let reqs = requests(8, 71);
        let want: Vec<Vec<u8>> = reqs
            .iter()
            .map(|r| generate_sequential(&mut model, &r.prompt, r.max_new_tokens))
            .collect();
        let cfg = SchedulerConfig { max_batch: 8, ..Default::default() };
        let mut sched = Scheduler::new(0, model, cfg);
        for r in &reqs {
            sched.submit(r.clone());
        }
        let mut got = sched.run_until_idle();
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), 8);
        for (r, w) in got.iter().zip(&want) {
            assert_eq!(&r.tokens, w, "packed={packed} id={}", r.id);
            assert_eq!(r.per_token_ms.len(), r.tokens.len());
        }
        assert_eq!(sched.free_pages(), sched.total_pages(), "page leak (packed={packed})");
    }
}

/// The sampled-decode oracle: the same sequential loop as
/// [`generate_sequential`], but picking through the production
/// `sampling::sample` with the position key the scheduler uses (the
/// sequence length AFTER the step that produced the logits). Pins the
/// scheduler's sampling WIRING — position keys, replay across
/// preemption — while `sampling`'s own unit tests pin the math.
fn generate_sequential_sampled(
    model: &mut CpuModel,
    prompt: &[u8],
    max_new: usize,
    params: &SamplingParams,
) -> Vec<u8> {
    let max_seq = model.config.max_seq;
    let dtype = KvDtype::from_env();
    let mut pool = KvPool::new_with_dtype(&model.config, (max_seq + 1) / 2, 2, dtype);
    let mut seq = SeqCache::new();
    let mut cache = KvCache::new(&model.config);
    let mut step = |model: &mut CpuModel, pool: &mut KvPool, seq: &mut SeqCache, b: u8| {
        match dtype {
            KvDtype::F32 => model.decode_step(&mut cache, b).to_vec(),
            KvDtype::Q8 => {
                assert!(pool.reserve(seq, seq.len + 1), "oracle pool sized too small");
                let mut refs = [&mut *seq];
                model.decode_steps(pool, &mut refs, &[b])
            }
        }
    };
    let mut len = 0usize;
    let mut logits: Vec<f32> = Vec::new();
    for &b in prompt.iter().take(max_seq.saturating_sub(1)) {
        logits = step(model, &mut pool, &mut seq, b);
        len += 1;
    }
    let mut tokens = Vec::new();
    for _ in 0..max_new {
        if len >= max_seq {
            break;
        }
        let next = sample(&logits, params, len);
        logits = step(model, &mut pool, &mut seq, next);
        len += 1;
        tokens.push(next);
    }
    pool.release(&mut seq);
    tokens
}

#[test]
fn scheduler_spec_on_matches_sequential_oracle_explicitly() {
    // env-independent version of the GPTQ_SPEC=k4 matrix rows: with
    // speculation explicitly on, greedy accept-iff-equal must keep the
    // scheduler bit-identical to the SPEC-FREE sequential oracle, for
    // both draft precisions and under a tight pool
    for spec in [SpecConfig { k: 4, draft_bits: 3 }, SpecConfig { k: 2, draft_bits: 2 }] {
        let mut model = CpuModel::from_checkpoint(&tiny_checkpoint(67));
        let reqs = requests(8, 71);
        let want: Vec<Vec<u8>> = reqs
            .iter()
            .map(|r| generate_sequential(&mut model, &r.prompt, r.max_new_tokens))
            .collect();
        let cfg = SchedulerConfig { max_batch: 8, spec, ..Default::default() };
        let mut sched = Scheduler::new(0, model, cfg);
        for r in &reqs {
            sched.submit(r.clone());
        }
        let mut got = sched.run_until_idle();
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), 8);
        for (r, w) in got.iter().zip(&want) {
            assert_eq!(&r.tokens, w, "spec={spec:?} id={}", r.id);
        }
        assert_no_leak(&mut sched);
    }
}

#[test]
fn seeded_sampling_matches_sequential_oracle_under_preemption() {
    // the tentpole replay contract, end to end: sampled picks are keyed
    // by (seed, position), so a tight pool full of preempt-and-rerun
    // churn must emit the exact tokens of the undisturbed sequential
    // loop. Speculation is explicitly OFF: sampled spec draws from
    // different RNG streams by design, so its contract is replay
    // determinism (scheduler unit tests), not oracle equality.
    let params =
        SamplingParams { temperature: 1.3, top_k: 0, top_p: 0.9, seed: 0 };
    let mut model = CpuModel::from_checkpoint(&tiny_checkpoint(73));
    let reqs: Vec<GenRequest> = (0..16u64)
        .map(|i| {
            GenRequest::new(i, vec![(i % 32) as u8, (i * 7 % 32) as u8, (i * 13 % 32) as u8], 5)
                .with_sampling(SamplingParams { seed: 1000 + i, ..params })
        })
        .collect();
    let want: Vec<Vec<u8>> = reqs
        .iter()
        .map(|r| generate_sequential_sampled(&mut model, &r.prompt, r.max_new_tokens, &r.sampling))
        .collect();
    let cfg = SchedulerConfig {
        max_batch: 8,
        pool_pages: 6,
        page_size: 2,
        prefill_chunk: 3,
        spec: SpecConfig::off(),
        ..Default::default()
    };
    let mut sched = Scheduler::new(0, model, cfg);
    for r in &reqs {
        sched.submit(r.clone());
    }
    let mut steps = 0;
    let mut got = Vec::new();
    while !sched.is_idle() {
        got.extend(sched.step());
        steps += 1;
        assert!(steps < 100_000, "sampled run deadlocked under pool exhaustion");
    }
    assert!(sched.preemptions() > 0, "pool never backpressured — replay path unexercised");
    got.sort_by_key(|r| r.id);
    assert_eq!(got.len(), 16, "dropped responses");
    for (r, w) in got.iter().zip(&want) {
        assert_eq!(&r.tokens, w, "id={}: preemption replay changed sampled tokens", r.id);
    }
    assert_no_leak(&mut sched);
}

#[test]
fn pool_exhaustion_backpressures_and_completes() {
    // 6 pages × 2 positions = 12 cached positions. Admission reserves
    // prompt+1 (2 pages per request), so 3 sequences co-admit; each then
    // grows to 8 positions (4 pages) during decode — 12 pages of demand
    // against 6 — which forces preemption deterministically.
    let cfg = SchedulerConfig {
        max_batch: 8,
        pool_pages: 6,
        page_size: 2,
        prefill_chunk: 3,
        ..Default::default()
    };
    let mut model = CpuModel::from_checkpoint(&tiny_checkpoint(73));
    let reqs: Vec<GenRequest> = (0..16u64)
        .map(|i| {
            GenRequest::new(i, vec![(i % 32) as u8, (i * 7 % 32) as u8, (i * 13 % 32) as u8], 5)
        })
        .collect();
    let want: Vec<Vec<u8>> = reqs
        .iter()
        .map(|r| generate_sequential(&mut model, &r.prompt, r.max_new_tokens))
        .collect();
    let mut sched = Scheduler::new(0, model, cfg);
    for r in &reqs {
        sched.submit(r.clone());
    }
    let mut steps = 0;
    let mut got = Vec::new();
    while !sched.is_idle() {
        got.extend(sched.step());
        steps += 1;
        assert!(steps < 100_000, "scheduler deadlocked under pool exhaustion");
    }
    assert!(sched.preemptions() > 0, "pool never backpressured — test not exercising eviction");
    got.sort_by_key(|r| r.id);
    assert_eq!(got.len(), 16, "dropped responses");
    for (r, w) in got.iter().zip(&want) {
        assert_eq!(&r.tokens, w, "id={} (restart must reproduce greedy decode)", r.id);
    }
    assert_no_leak(&mut sched);
}

#[test]
fn interleaved_admit_and_evict_with_ragged_prompts() {
    let cfg = SchedulerConfig {
        max_batch: 4,
        pool_pages: 8,
        page_size: 2,
        prefill_chunk: 2,
        ..Default::default()
    };
    let mut sched = Scheduler::new(0, CpuModel::from_checkpoint(&tiny_checkpoint(83)), cfg);
    let reqs = requests(12, 89);
    let mut submitted = 0usize;
    let mut got = Vec::new();
    let mut rng = Rng::new(97);
    let mut steps = 0;
    // trickle submissions between iterations so admission interleaves
    // with in-flight decode and completions
    while submitted < reqs.len() || !sched.is_idle() {
        for _ in 0..rng.below(3) {
            if submitted < reqs.len() {
                sched.submit(reqs[submitted].clone());
                submitted += 1;
            }
        }
        got.extend(sched.step());
        steps += 1;
        assert!(steps < 100_000, "interleaved run deadlocked");
    }
    let mut ids: Vec<u64> = got.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..12).collect::<Vec<u64>>(), "dropped or duplicated responses");
    assert!(got.iter().all(|r| !r.tokens.is_empty()));
    assert_no_leak(&mut sched);
}

/// Seeded soak driver: bursty arrivals of random requests against a
/// deliberately tight pool (prefix-cache churn included — random 1..=14
/// token prompts over vocab 32 produce full-page collisions at
/// page_size 4). Asserts zero dropped/duplicated responses and zero
/// leaked pages; everything is derived from `seed`, so a trace is
/// exactly reproducible.
fn soak_trace(name: &str, total: usize, seed: u64, shared_prefixes: usize) {
    let cfg = SchedulerConfig {
        max_batch: 8,
        pool_pages: 12,
        page_size: 4,
        prefill_chunk: 4,
        ..Default::default()
    };
    let mut sched = Scheduler::new(0, CpuModel::from_checkpoint(&tiny_checkpoint(101)), cfg);
    // the shared-prefix variant draws every prompt's head from a small
    // set of 8-token system prefixes (2 full pages each)
    let mut rng = Rng::new(seed);
    let prefixes: Vec<Vec<u8>> = (0..shared_prefixes)
        .map(|_| (0..8).map(|_| rng.below(32) as u8).collect())
        .collect();
    let mut submitted = 0usize;
    let mut got = Vec::new();
    let mut steps = 0usize;
    while submitted < total || !sched.is_idle() {
        // bursty arrivals: 0..=4 new requests per iteration
        for _ in 0..rng.below(5) {
            if submitted < total {
                let prompt: Vec<u8> = if prefixes.is_empty() {
                    let plen = 1 + rng.below(14);
                    (0..plen).map(|_| rng.below(32) as u8).collect()
                } else {
                    let mut p = prefixes[rng.below(prefixes.len())].clone();
                    for _ in 0..rng.below(6) {
                        p.push(rng.below(32) as u8);
                    }
                    p
                };
                // max_new_tokens can be 0: those resolve immediately as
                // zero-token Completed responses and must still show up
                // exactly once in the id census below
                sched.submit(GenRequest::new(submitted as u64, prompt, rng.below(9)));
                submitted += 1;
            }
        }
        got.extend(sched.step());
        steps += 1;
        assert!(steps < 1_000_000, "{name} deadlocked");
    }
    let mut ids: Vec<u64> = got.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..total as u64).collect::<Vec<u64>>(), "{name}: dropped/duplicated responses");
    if !prefixes.is_empty() {
        assert!(
            sched.metrics().prefill_tokens_saved > 0,
            "{name}: shared prefixes never forked"
        );
    }
    println!(
        "{name}: {} responses over {} iterations, {} preemptions, {} cached pages, metrics: {}",
        got.len(),
        steps,
        sched.preemptions(),
        sched.cached_pages(),
        sched.metrics().summary()
    );
    assert_no_leak(&mut sched);
}

/// The bounded soak that runs in `make -C rust check`: same generator
/// and pool shape as the 500-request trace, cut to 60 requests so the
/// default suite stays fast while still crossing preemption, prefix
/// reuse, and cache eviction many times over.
#[test]
fn soak_60_request_trace_bounded() {
    soak_trace("soak-60", 60, 103, 0);
}

/// `make -C rust soak`: the long trace.
#[test]
#[ignore]
fn soak_500_request_trace() {
    soak_trace("soak-500", 500, 103, 0);
}

/// `make -C rust soak`: the shared-prefix long trace — every prompt
/// starts with one of 4 system prefixes, so the prefix cache is hot and
/// constantly fought over by the tight pool.
#[test]
#[ignore]
fn soak_500_shared_prefix_trace() {
    soak_trace("soak-500-shared", 500, 107, 4);
}
