//! Property/fuzz suite for the refcounted KV pool (DESIGN.md §Prefix
//! cache): seeded random interleavings of admit / grow-write / fork /
//! cache-hold / release over a deliberately small pool, with a shadow
//! model of every live sequence's expected rows and every page's
//! expected holder count. Asserts, continuously and at the end:
//!
//! * **no leak** — every page is free or accounted to a holder, and the
//!   free count returns to `total_pages` once all holders drop;
//! * **no double-free** — `KvPool::release`/`release_page` panic on a
//!   zero-refcount page, so survival of thousands of random release
//!   interleavings is the property;
//! * **CoW isolation** — a write into a forked sequence never mutates a
//!   row any other live holder maps: every live sequence's rows always
//!   match its shadow, no matter how forks/releases interleave.
//!
//! The whole suite runs under BOTH page dtypes (DESIGN.md §KV
//! precision): refcount/fork/CoW machinery is dtype-agnostic, and the
//! shadow rows are constant per position, which q8's per-head affine
//! encodes exactly (flat head → scale 0, zero = value) — so the
//! equality audits hold bitwise under q8 too. Byte-identity of q8 CoW
//! copies on NON-flat rows is pinned by the `kvpool` unit test
//! `q8_cow_copies_codes_and_scales_byte_identically`.

use gptq_rs::data::Rng;
use gptq_rs::model::testkit::tiny_config;
use gptq_rs::model::{KvDtype, KvPool, SeqCache};

const POOL_PAGES: usize = 12;
const PAGE_SIZE: usize = 4;
const MAX_LEN: usize = 32; // < POOL_PAGES × PAGE_SIZE so growth can succeed
const MAX_LIVE: usize = 6;

/// A live sequence plus the rows it must observe (tag per position).
struct Sim {
    seq: SeqCache,
    rows: Vec<f32>,
}

/// First element of the K row at `pos` — the shadow-checked cell.
/// Reads through the dtype-generic accessor so the same audit runs over
/// f32 and q8 pages.
fn cell(pool: &KvPool, seq: &SeqCache, pos: usize) -> f32 {
    let mut row = vec![0.0f32; tiny_config().d_model];
    pool.read_k_row(seq, 0, pos, &mut row);
    row[0]
}

fn write_tagged(pool: &mut KvPool, sim: &mut Sim, tag: f32, n_layers: usize, d: usize) {
    let pos = sim.seq.len;
    let row = vec![tag; d];
    for l in 0..n_layers {
        pool.write_row(&sim.seq, l, pos, &row, &row);
    }
    sim.seq.len += 1;
    sim.rows.push(tag);
}

/// Audit refcounts against the ground truth: holders = live page tables
/// plus explicit cache holds. Duplicates (a page forked into several
/// sequences, or held twice) must each count.
fn audit_refcounts(pool: &KvPool, sims: &[Sim], holds: &[u32]) {
    let mut counts = vec![0u32; pool.total_pages()];
    for sim in sims {
        for &p in sim.seq.pages() {
            counts[p as usize] += 1;
        }
    }
    for &p in holds {
        counts[p as usize] += 1;
    }
    let mut held_pages = 0;
    for (p, &want) in counts.iter().enumerate() {
        assert_eq!(
            pool.refcount(p as u32),
            want,
            "page {p}: refcount drifted from the holder ground truth"
        );
        if want > 0 {
            held_pages += 1;
        }
    }
    assert_eq!(
        pool.free_pages(),
        pool.total_pages() - held_pages,
        "free-list size disagrees with held-page count"
    );
}

/// Every live sequence still reads exactly the rows it wrote or forked —
/// the CoW-isolation property.
fn audit_rows(pool: &KvPool, sims: &[Sim]) {
    for (i, sim) in sims.iter().enumerate() {
        for pos in 0..sim.seq.len {
            assert_eq!(
                cell(pool, &sim.seq, pos),
                sim.rows[pos],
                "sim {i} pos {pos}: a write leaked into a shared page"
            );
        }
    }
}

fn fuzz(seed: u64, iters: usize, dtype: KvDtype) {
    let cfg = tiny_config();
    let (n_layers, d) = (cfg.n_layers, cfg.d_model);
    let mut pool = KvPool::new_with_dtype(&cfg, POOL_PAGES, PAGE_SIZE, dtype);
    let mut rng = Rng::new(seed);
    let mut sims: Vec<Sim> = Vec::new();
    let mut holds: Vec<u32> = Vec::new();
    let mut next_tag = 1.0f32;
    let (mut grows, mut forks, mut cows, mut oom) = (0usize, 0usize, 0usize, 0usize);

    for it in 0..iters {
        match rng.below(10) {
            // admit a fresh sequence
            0 if sims.len() < MAX_LIVE => {
                sims.push(Sim { seq: SeqCache::new(), rows: Vec::new() });
            }
            // fork a random live sequence at a random (often mid-page)
            // cut — the child shares full pages and the partial tail
            1 | 2 if !sims.is_empty() => {
                let j = rng.below(sims.len());
                if sims[j].seq.len > 0 && sims.len() < MAX_LIVE {
                    let cut = 1 + rng.below(sims[j].seq.len);
                    let child = pool.fork(&sims[j].seq, cut);
                    let rows = sims[j].rows[..cut].to_vec();
                    sims.push(Sim { seq: child, rows });
                    forks += 1;
                }
            }
            // cache-style hold on a random mapped page
            3 if !sims.is_empty() => {
                let j = rng.below(sims.len());
                if sims[j].seq.n_pages() > 0 && holds.len() < POOL_PAGES {
                    let p = sims[j].seq.pages()[rng.below(sims[j].seq.n_pages())];
                    pool.retain_page(p);
                    holds.push(p);
                }
            }
            // drop a random hold
            4 if !holds.is_empty() => {
                let p = holds.swap_remove(rng.below(holds.len()));
                pool.release_page(p);
            }
            // release (preempt/finish) a random sequence
            5 if sims.len() > 1 || (sims.len() == 1 && rng.below(4) == 0) => {
                let j = rng.below(sims.len());
                let mut sim = sims.swap_remove(j);
                pool.release(&mut sim.seq);
            }
            // grow + tagged write (reserve performs CoW when the tail
            // page is shared — the hot property)
            _ if !sims.is_empty() => {
                let j = rng.below(sims.len());
                if sims[j].seq.len < MAX_LEN {
                    let was_shared = pool.cow_pending(&sims[j].seq);
                    let need = sims[j].seq.len + 1;
                    if pool.reserve(&mut sims[j].seq, need) {
                        if was_shared {
                            cows += 1;
                        }
                        write_tagged(&mut pool, &mut sims[j], next_tag, n_layers, d);
                        next_tag += 1.0;
                        grows += 1;
                    } else {
                        // pool exhausted: legal backpressure — free room
                        oom += 1;
                        if !holds.is_empty() {
                            let p = holds.swap_remove(rng.below(holds.len()));
                            pool.release_page(p);
                        } else if sims.len() > 1 {
                            let k = rng.below(sims.len());
                            let mut sim = sims.swap_remove(k);
                            pool.release(&mut sim.seq);
                        }
                    }
                }
            }
            _ => {}
        }
        audit_refcounts(&pool, &sims, &holds);
        if it % 7 == 0 {
            audit_rows(&pool, &sims);
        }
    }
    audit_rows(&pool, &sims);

    // teardown in random order: children before parents, holds last,
    // whatever the dice say — the free count must still come back whole
    while !sims.is_empty() {
        let j = rng.below(sims.len());
        let mut sim = sims.swap_remove(j);
        pool.release(&mut sim.seq);
        audit_refcounts(&pool, &sims, &holds);
    }
    while !holds.is_empty() {
        let p = holds.swap_remove(rng.below(holds.len()));
        pool.release_page(p);
    }
    assert_eq!(pool.free_pages(), pool.total_pages(), "page leak (seed {seed})");
    for p in 0..pool.total_pages() {
        assert_eq!(pool.refcount(p as u32), 0, "page {p} refcount stuck (seed {seed})");
    }
    assert!(grows > 0 && forks > 0, "seed {seed} never exercised grow/fork");
    // the interesting interleavings actually happened under this seed mix
    println!("seed {seed}: {grows} writes, {forks} forks, {cows} CoW copies, {oom} OOM events");
}

#[test]
fn refcount_fuzz_seed_1() {
    fuzz(0xA11CE, 3000, KvDtype::F32);
}

#[test]
fn refcount_fuzz_seed_2() {
    fuzz(0xB0B, 3000, KvDtype::F32);
}

#[test]
fn refcount_fuzz_seed_3() {
    fuzz(0xC0FFEE, 3000, KvDtype::F32);
}

#[test]
fn refcount_fuzz_seed_1_q8() {
    fuzz(0xA11CE, 3000, KvDtype::Q8);
}

#[test]
fn refcount_fuzz_seed_2_q8() {
    fuzz(0xB0B, 3000, KvDtype::Q8);
}

#[test]
fn refcount_fuzz_seed_3_q8() {
    fuzz(0xC0FFEE, 3000, KvDtype::Q8);
}

/// Deterministic micro-interleaving: the exact sequence the scheduler
/// produces under preemption — prefill, index (hold), fork, CoW write,
/// release parent, release child — with the shadow checked at each step.
#[test]
fn scripted_preemption_interleaving() {
    scripted_preemption(KvDtype::F32);
}

#[test]
fn scripted_preemption_interleaving_q8() {
    scripted_preemption(KvDtype::Q8);
}

fn scripted_preemption(dtype: KvDtype) {
    let cfg = tiny_config();
    let d = cfg.d_model;
    let mut pool = KvPool::new_with_dtype(&cfg, 6, 2, dtype);
    // parent prefills 5 positions (2 full pages + tail)
    let mut parent = Sim { seq: SeqCache::new(), rows: Vec::new() };
    for t in 0..5 {
        assert!(pool.reserve(&mut parent.seq, t + 1));
        write_tagged(&mut pool, &mut parent, 10.0 + t as f32, cfg.n_layers, d);
    }
    // "prefix cache" indexes the 2 full pages
    let holds: Vec<u32> = parent.seq.pages()[..2].to_vec();
    for &p in &holds {
        pool.retain_page(p);
    }
    // a second request forks 4 tokens, then appends its own rows
    let mut child = Sim { seq: pool.fork(&parent.seq, 4), rows: parent.rows[..4].to_vec() };
    assert!(pool.reserve(&mut child.seq, 5));
    write_tagged(&mut pool, &mut child, 99.0, cfg.n_layers, d);
    // parent's position-4 row must be untouched by the child's write
    assert_eq!(cell(&pool, &parent.seq, 4), 14.0);
    assert_eq!(cell(&pool, &child.seq, 4), 99.0);
    // preempt the parent (release); cached pages stay for the child+holds
    pool.release(&mut parent.seq);
    assert_eq!(cell(&pool, &child.seq, 1), 11.0, "release freed a page the child maps");
    // parent re-admitted as a fork of the cached prefix
    let mut parent2 = Sim { seq: pool.fork_pages(&holds, 4), rows: vec![10.0, 11.0, 12.0, 13.0] };
    assert!(pool.reserve(&mut parent2.seq, 5));
    write_tagged(&mut pool, &mut parent2, 14.0, cfg.n_layers, d);
    audit_rows(&pool, &[child, parent2]);
}
