//! End-to-end pipeline tests on the real artifacts: quantize the nano
//! model through the full block-streaming pipeline, evaluate, and verify
//! the paper's qualitative claims hold at this scale:
//!   * 4-bit GPTQ ppl ≈ fp32 ppl (small gap);
//!   * GPTQ ≤ RTN ppl at every bit width;
//!   * the checkpoint round-trips through disk.

use gptq_rs::coordinator::{PipelineConfig, QuantEngine, QuantPipeline};
use gptq_rs::data::CorpusFile;
use gptq_rs::eval::perplexity;
use gptq_rs::model::{Checkpoint, CpuModel, QuantizedCheckpoint};
use gptq_rs::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    let dir = gptq_rs::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return None;
    }
    Some(Runtime::from_artifacts_dir(&dir).expect("runtime"))
}

fn quantized_ppl(rt: &mut Runtime, size: &str, cfg: PipelineConfig) -> (f64, QuantizedCheckpoint) {
    let dir = gptq_rs::artifacts_dir();
    let entry = rt.manifest.model(size).unwrap().clone();
    let mut ckpt = Checkpoint::load(&dir, &entry).unwrap();
    let calib = CorpusFile::load(&rt.manifest.corpus_path("calib.bin")).unwrap();
    let report = QuantPipeline::new(rt, size, cfg).run(&mut ckpt, &calib).unwrap();
    let corpus = CorpusFile::load(&rt.manifest.corpus_path("narrative_test.bin")).unwrap();
    let mut m = CpuModel::from_quantized(&report.checkpoint);
    let seq = rt.manifest.seq_len;
    (perplexity(&mut m, &corpus, seq, 8), report.checkpoint)
}

#[test]
fn gptq4_close_to_fp_and_beats_rtn() {
    let Some(mut rt) = runtime() else { return };
    let size = "nano";
    let dir = gptq_rs::artifacts_dir();
    let entry = rt.manifest.model(size).unwrap().clone();
    let ckpt = Checkpoint::load(&dir, &entry).unwrap();
    let corpus = CorpusFile::load(&rt.manifest.corpus_path("narrative_test.bin")).unwrap();
    let mut fp = CpuModel::from_checkpoint(&ckpt);
    let ppl_fp = perplexity(&mut fp, &corpus, rt.manifest.seq_len, 8);

    let mut cfg = PipelineConfig::new(4, QuantEngine::GptqRust);
    cfg.n_calib_segments = 32;
    let (ppl_gptq, qc) = quantized_ppl(&mut rt, size, cfg);

    let mut cfg_rtn = PipelineConfig::new(4, QuantEngine::Rtn);
    cfg_rtn.n_calib_segments = 32;
    let (ppl_rtn, _) = quantized_ppl(&mut rt, size, cfg_rtn);

    eprintln!("nano 4-bit: fp {ppl_fp:.3}  gptq {ppl_gptq:.3}  rtn {ppl_rtn:.3}");
    assert!(ppl_gptq < ppl_rtn * 1.02, "GPTQ {ppl_gptq} should beat/match RTN {ppl_rtn}");
    assert!(
        ppl_gptq < ppl_fp * 1.5,
        "4-bit GPTQ ppl {ppl_gptq} too far above fp {ppl_fp}"
    );

    // checkpoint round-trip preserves the model
    let tmp = std::env::temp_dir().join("gptq_e2e_nano4.ckpt");
    qc.save(&tmp).unwrap();
    let qc2 = QuantizedCheckpoint::load(&tmp).unwrap();
    std::fs::remove_file(&tmp).ok();
    let mut m2 = CpuModel::from_quantized(&qc2);
    let ppl2 = perplexity(&mut m2, &corpus, rt.manifest.seq_len, 8);
    assert!((ppl2 - ppl_gptq).abs() < 1e-6 * ppl_gptq.max(1.0));
}

#[test]
fn gptq_beats_rtn_at_3bit_by_larger_margin() {
    // The paper's headline: the GPTQ/RTN gap WIDENS as bits shrink.
    let Some(mut rt) = runtime() else { return };
    let size = "nano";
    let mut g4 = PipelineConfig::new(4, QuantEngine::GptqRust);
    g4.n_calib_segments = 32;
    let mut r4 = PipelineConfig::new(4, QuantEngine::Rtn);
    r4.n_calib_segments = 32;
    let mut g3 = PipelineConfig::new(3, QuantEngine::GptqRust);
    g3.n_calib_segments = 32;
    let mut r3 = PipelineConfig::new(3, QuantEngine::Rtn);
    r3.n_calib_segments = 32;
    let (p_g4, _) = quantized_ppl(&mut rt, size, g4);
    let (p_r4, _) = quantized_ppl(&mut rt, size, r4);
    let (p_g3, _) = quantized_ppl(&mut rt, size, g3);
    let (p_r3, _) = quantized_ppl(&mut rt, size, r3);
    eprintln!("4-bit: gptq {p_g4:.3} rtn {p_r4:.3}; 3-bit: gptq {p_g3:.3} rtn {p_r3:.3}");
    assert!(p_g3 < p_r3, "3-bit: GPTQ {p_g3} !< RTN {p_r3}");
    // gap in log-ppl space grows when dropping to 3 bits
    let gap4 = (p_r4.ln() - p_g4.ln()).max(0.0);
    let gap3 = p_r3.ln() - p_g3.ln();
    assert!(gap3 >= gap4 * 0.8, "3-bit gap {gap3} vs 4-bit gap {gap4}");
}

#[test]
fn artifact_engine_agrees_with_rust_engine() {
    // Same pipeline, solver swapped for the gptq_layer artifact contract
    // (the AOT L2 graph under PJRT, the reference solver otherwise):
    // perplexities must agree tightly.
    let Some(mut rt) = runtime() else { return };
    let size = "nano";
    if !rt.supports("gptq_layer_192x64_b4") {
        eprintln!("SKIP: gptq_layer_192x64_b4 not executable on this backend");
        return;
    }
    let mut rust_cfg = PipelineConfig::new(4, QuantEngine::GptqRust);
    rust_cfg.n_calib_segments = 16;
    let mut art_cfg = PipelineConfig::new(4, QuantEngine::GptqArtifact);
    art_cfg.n_calib_segments = 16;
    let (p_rust, _) = quantized_ppl(&mut rt, size, rust_cfg);
    let (p_art, _) = quantized_ppl(&mut rt, size, art_cfg);
    let rel = (p_rust - p_art).abs() / p_rust;
    eprintln!("engines: rust {p_rust:.4} vs artifact {p_art:.4} (rel {rel:.4})");
    assert!(rel < 0.05, "engine disagreement: rust {p_rust} vs artifact {p_art}");
}

#[test]
fn grouping_helps_at_2bit() {
    // Table 6's story end-to-end: 2-bit per-row collapses; groups recover.
    let Some(mut rt) = runtime() else { return };
    let size = "nano";
    let mut coarse = PipelineConfig::new(2, QuantEngine::GptqRust);
    coarse.n_calib_segments = 32;
    let mut fine = PipelineConfig::new(2, QuantEngine::GptqRust).with_groupsize(16);
    fine.n_calib_segments = 32;
    let (p_coarse, _) = quantized_ppl(&mut rt, size, coarse);
    let (p_fine, qc) = quantized_ppl(&mut rt, size, fine);
    eprintln!("2-bit: per-row {p_coarse:.2}, g=16 {p_fine:.2}");
    assert!(p_fine < p_coarse, "grouping should reduce 2-bit ppl");
    assert_eq!(qc.groupsize, 16);
}
